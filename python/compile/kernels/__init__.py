"""L1 Pallas kernels (interpret mode) + pure-jnp reference oracles."""

from . import ref  # noqa: F401
from .bias_grad import bias_grad, row_sq_norms  # noqa: F401
from .clip_reduce import weighted_sum  # noqa: F401
from .ghost_norm import ghost_norm  # noqa: F401
