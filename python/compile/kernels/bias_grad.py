"""Pallas kernel for Algorithm 1, line 5: per-sample bias gradients.

The per-sample bias gradient of a layer ``s = a W + 1 b`` is
``dL_i/db = sum_T dL/ds_i`` — a reduction of the output gradient over the
feature axis T.  This is the *entire* DP overhead of bias training: no
activation tensor, no O(BTpd) contraction, and the cost is independent of
whether the network input dimension T is 10 or 10^5 (the red column of
Table 2 in the paper).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks
``(B blocks, p blocks, T blocks)`` with T innermost, so the output block
``[B_blk, p_blk]`` stays resident in VMEM while ``[B_blk, T_blk, p_blk]``
tiles of the output gradient stream through — the same HBM->VMEM schedule a
hand-written Mosaic kernel would use for a sequential reduction.  VMEM
footprint per step: ``B_blk*T_blk*p_blk + B_blk*p_blk`` floats; the kernel is
bandwidth-bound (pure VPU reduction, no MXU), so roofline is HBM bandwidth.

Executed with ``interpret=True``: on the CPU PJRT backend a real Mosaic
lowering would emit a custom-call the CPU plugin cannot run; interpret mode
lowers to plain HLO with identical numerics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM-friendly block sizes (tuned in EXPERIMENTS.md §Perf; the
# structure — T innermost, output-resident — is the optimization, interpret
# wall-clock is not a TPU proxy).
_BLK_B = 8
_BLK_T = 128
_BLK_P = 128


def pad_to(x, axis, mult):
    """Zero-pad ``axis`` of ``x`` up to a multiple of ``mult``.

    Pallas interpret mode fills out-of-bounds reads of partial trailing
    blocks with NaN; zero padding keeps every reduction here exact.
    """
    n = x.shape[axis]
    rem = (-n) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


def _bias_grad_kernel(g_ref, out_ref):
    """One grid step: accumulate a T-tile's contribution to [B_blk, p_blk]."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.sum(g_ref[...], axis=1)


@functools.partial(jax.jit, static_argnames=("blk_b", "blk_t", "blk_p"))
def bias_grad(g, *, blk_b=_BLK_B, blk_t=_BLK_T, blk_p=_BLK_P):
    """Per-sample bias gradients ``[B, p]`` from output gradients ``[B, T, p]``.

    Args:
      g: output gradient ``dL/ds`` of shape ``[B, T, p]``.  A ``[B, p]`` input
        (layer without a feature axis) is returned unchanged.
      blk_b / blk_t / blk_p: VMEM tile sizes.

    Returns:
      ``[B, p]`` per-sample bias gradients, f32.
    """
    if g.ndim == 2:
        return g
    b, t, p = g.shape
    blk_b, blk_t, blk_p = min(blk_b, b), min(blk_t, t), min(blk_p, p)
    g = pad_to(pad_to(pad_to(g, 0, blk_b), 1, blk_t), 2, blk_p)
    bp, tp, pp = g.shape
    grid = (bp // blk_b, pp // blk_p, tp // blk_t)
    out = pl.pallas_call(
        _bias_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_b, blk_t, blk_p), lambda i, j, k: (i, k, j)),
        ],
        out_specs=pl.BlockSpec((blk_b, blk_p), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, pp), g.dtype),
        interpret=True,
    )(g)
    return out[:b, :p]


def _row_sq_kernel(g_ref, out_ref):
    """One grid step: accumulate a P-tile's squared sum into [B_blk]."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    blk = g_ref[...]
    out_ref[...] += jnp.sum(blk * blk, axis=1)


@functools.partial(jax.jit, static_argnames=("blk_b", "blk_p"))
def row_sq_norms(g, *, blk_b=64, blk_p=512):
    """Per-row squared L2 norms ``[B]`` of per-sample gradients ``[B, P]``.

    Together with :func:`bias_grad` this is the fused "compute per-example
    gradient and its norm" step of Algorithm 1.  P is tiled so that arbitrary
    parameter counts stream through a fixed VMEM budget.
    """
    b, p = g.shape
    blk_b, blk_p = min(blk_b, b), min(blk_p, p)
    g = pad_to(pad_to(g, 0, blk_b), 1, blk_p)
    bp, pp = g.shape
    grid = (bp // blk_b, pp // blk_p)
    out = pl.pallas_call(
        _row_sq_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((blk_b, blk_p), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((blk_b,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), g.dtype),
        interpret=True,
    )(g)
    return out[:b]
