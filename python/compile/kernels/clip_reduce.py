"""Pallas kernel for Algorithm 1, line 9: sum of clipped per-sample grads.

Given per-sample gradients ``G [B, P]`` and per-sample weights ``c [B]``
(clipping factor x batch mask), compute ``sum_i c_i G_i`` — a [B]-weighted
reduction over the batch axis.  The clip factors themselves are an O(B)
computation done in plain jnp (``ref.clip_factors``); the expensive part is
streaming the ``B x P`` gradient matrix once, which this kernel tiles.

TPU mapping: grid ``(P blocks, B blocks)`` with B innermost; the output
``[P_blk]`` tile stays VMEM-resident while ``[B_blk, P_blk]`` gradient tiles
stream through, each step issuing a ``[B_blk] x [B_blk, P_blk]`` vector-
matrix product on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLK_B = 64
_BLK_P = 512


def _weighted_sum_kernel(c_ref, g_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        c_ref[...], g_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("blk_b", "blk_p"))
def weighted_sum(g, c, *, blk_b=_BLK_B, blk_p=_BLK_P):
    """Clipped-gradient aggregation ``sum_i c_i g_i``.

    Args:
      g: per-sample gradients ``[B, P]``.
      c: per-sample weights ``[B]`` (clip factor x mask; masked-out padding
        examples contribute exactly zero).
      blk_b / blk_p: tile sizes.

    Returns:
      ``[P]`` aggregated gradient, f32 (noise is added by the rust
      coordinator once per logical Poisson batch — see DESIGN.md §6).
    """
    from .bias_grad import pad_to

    b, p = g.shape
    blk_b, blk_p = min(blk_b, b), min(blk_p, p)
    g = pad_to(pad_to(g, 0, blk_b), 1, blk_p)
    c = pad_to(c, 0, blk_b)
    bp, pp = g.shape
    grid = (pp // blk_p, bp // blk_b)
    out = pl.pallas_call(
        _weighted_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_b,), lambda i, j: (j,)),
            pl.BlockSpec((blk_b, blk_p), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((blk_p,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.float32),
        interpret=True,
    )(c, g)
    return out[:p]
