"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an entry here with identical semantics; the
pytest suite (``python/tests``) asserts ``allclose`` between the Pallas
implementation (interpret mode) and these references over hypothesis-driven
shape/value sweeps.  These functions are also used directly by the L2 step
builders when ``use_pallas=False`` (a debug escape hatch — artifacts shipped
by ``aot.py`` are built with the Pallas path).
"""

from __future__ import annotations

import jax.numpy as jnp

AUTO_S_STABILIZER = 0.01  # gamma in AUTO-S clipping R/(||g|| + gamma) (Bu et al., 2022b)


def bias_grad(g):
    """Per-sample bias gradient from the output gradient (Alg. 1, line 5).

    For a linear layer ``s = a @ W + 1 b``, the per-sample bias gradient is
    ``dL_i/db = sum_T dL/ds_i`` — no activation needed.

    Args:
      g: output gradient ``dL/ds`` of shape ``[B, T, p]`` (or ``[B, p]`` for
        layers without a feature axis — returned unchanged).

    Returns:
      Per-sample bias gradients of shape ``[B, p]``.
    """
    if g.ndim == 2:
        return g
    return jnp.sum(g, axis=tuple(range(1, g.ndim - 1)))


def row_sq_norms(g):
    """Per-row squared L2 norms of a flat per-sample gradient matrix.

    Args:
      g: per-sample gradients ``[B, P]``.

    Returns:
      ``[B]`` with ``||g_i||_2^2``.
    """
    return jnp.sum(g * g, axis=-1)


def ghost_norm(a, e):
    """Squared per-sample weight-gradient norms via the ghost-norm trick.

    For ``s = a @ W`` the per-sample weight gradient is ``g_i = e_i^T a_i``
    and ``||g_i||_F^2 = <a_i a_i^T, e_i e_i^T>`` — an O(B T^2 (p + d))
    computation that never materializes ``g_i`` (Goodfellow 2015; Li et al.
    2021).  This is the baseline DP-full path; note its T^2 term, the cost
    the paper's DP-BiTFiT avoids.

    Args:
      a: layer input ``[B, T, d]``.
      e: output gradient ``dL/ds`` ``[B, T, p]``.

    Returns:
      ``[B]`` with ``||e_i^T a_i||_F^2``.
    """
    aat = jnp.einsum("btd,bsd->bts", a, a)
    eet = jnp.einsum("btp,bsp->bts", e, e)
    return jnp.sum(aat * eet, axis=(1, 2))


def clip_factors(sq_norms, clip_r, mode):
    """Per-sample clipping factors C_i from squared gradient norms.

    Args:
      sq_norms: ``[B]`` squared per-sample grad norms.
      clip_r: scalar clipping threshold R.
      mode: ``"abadi"`` -> ``min(R/||g||, 1)`` (Abadi et al., 2016) or
        ``"autos"`` -> ``R/(||g|| + 0.01)`` (AUTO-S, Bu et al., 2022b).

    Returns:
      ``[B]`` clipping factors.
    """
    norms = jnp.sqrt(jnp.maximum(sq_norms, 0.0))
    if mode == "abadi":
        return jnp.minimum(clip_r / jnp.maximum(norms, 1e-12), 1.0)
    if mode == "autos":
        return clip_r / (norms + AUTO_S_STABILIZER)
    raise ValueError(f"unknown clipping mode {mode!r}")


def weighted_sum(g, c):
    """Sum of per-sample gradients weighted by clip factors: ``sum_i c_i g_i``.

    Args:
      g: per-sample gradients ``[B, P]``.
      c: per-sample weights (clip factor x mask) ``[B]``.

    Returns:
      ``[P]`` clipped gradient sum (Alg. 1, line 9).
    """
    return jnp.einsum("b,bp->p", c, g)
