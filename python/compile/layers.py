"""Neural-net layers with per-sample-parameter support (the "expand trick").

JAX is functional, so the paper's PyTorch hook machinery maps onto two
mechanisms (DESIGN.md §1):

* **Expand trick** — a trainable tensor is fed to the graph expanded over the
  batch axis (``[B, ...]``, row i used only by sample i).  One ordinary
  backward pass then yields *exact per-sample gradients* for the trainable
  subset.  Every layer here accepts either a shared parameter (base ndim) or
  a per-sample parameter (base ndim + 1) and dispatches on ``ndim``.

* **Activation-free bias add** — :func:`bias_add_ps` is a ``custom_vjp``
  whose backward calls the Pallas ``bias_grad`` kernel and whose residual
  set is *empty*: nothing from the forward pass is saved for the bias path.
  This is the functional statement of the paper's "no forward hooks / no
  stored activations" property (§2, Eq. 3).

All parameters are plain ``jnp`` arrays inside nested dicts; no framework.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels

# --------------------------------------------------------------------------
# activation-free bias add (the paper's mechanism)
# --------------------------------------------------------------------------


@jax.custom_vjp
def bias_add_ps(s, b):
    """Add a per-sample bias ``b [B, p]`` to pre-activations ``s [B, ..., p]``.

    Backward w.r.t. ``b`` is the Pallas per-sample bias-grad kernel (sum of
    the output gradient over all middle axes); backward w.r.t. ``s`` is the
    identity.  Residuals: none — the forward stores nothing.
    """
    return s + b.reshape(b.shape[:1] + (1,) * (s.ndim - 2) + b.shape[1:])


def _bias_add_fwd(s, b):
    return bias_add_ps(s, b), None


def _bias_add_bwd(_res, g):
    if g.ndim > 3:
        gb = kernels.bias_grad(g.reshape(g.shape[0], -1, g.shape[-1]))
    else:
        gb = kernels.bias_grad(g)
    return g, gb


bias_add_ps.defvjp(_bias_add_fwd, _bias_add_bwd)


def bias_add(s, b):
    """Bias add dispatching on shared ``[p]`` vs per-sample ``[B, p]`` bias."""
    if b.ndim == 1:
        return s + b
    return bias_add_ps(s, b)


# --------------------------------------------------------------------------
# shared/per-sample parameter helpers
# --------------------------------------------------------------------------


def pmat(x, w):
    """Matmul with a shared ``[d, p]`` or per-sample ``[B, d, p]`` weight."""
    if w.ndim == 2:
        return x @ w
    if x.ndim == 3:
        return jnp.einsum("btd,bdp->btp", x, w)
    return jnp.einsum("bd,bdp->bp", x, w)


def pscale(x, gamma):
    """Elementwise scale with shared ``[p]`` or per-sample ``[B, p]`` gamma."""
    if gamma.ndim == 1:
        return x * gamma
    return x * gamma.reshape(gamma.shape[:1] + (1,) * (x.ndim - 2) + gamma.shape[1:])


# --------------------------------------------------------------------------
# layers
# --------------------------------------------------------------------------


def linear(x, p, *, site=None, ctx=None):
    """``x @ W + b``; records the ghost-clipping site if ``ctx`` collects."""
    s = pmat(x, p["w"])
    s = _site(s, x, site, ctx)
    if "b" in p:
        s = bias_add(s, p["b"])
    return s


def layer_norm(x, p, *, site=None, ctx=None, eps=1e-5):
    """LayerNorm with trainable scale (weight) and shift (bias).

    For ghost clipping the *affine output* is the perturbation site: with
    ``out = xhat * gamma + beta + z`` and ``e = dL/dz``, the per-sample
    grads are ``grad_gamma_i = sum_T e * xhat`` and ``grad_beta_i =
    sum_T e`` — both computable from (e, xhat).
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xhat = (x - mu) / jnp.sqrt(var + eps)
    out = pscale(xhat, p["gamma"])
    out = bias_add(out, p["beta"])
    if ctx is not None and site is not None:
        ctx.ln_sites.append((site, xhat))
        ctx.site_shapes[site] = out.shape
        z = ctx.zs.get(site)
        if z is not None:
            out = out + z
    return out


def group_norm(x, p, groups, *, site=None, ctx=None, eps=1e-5):
    """GroupNorm over NHWC (DP-compatible normalization, App. A.2)."""
    b, h, w, c = x.shape
    xg = x.reshape(b, h, w, groups, c // groups)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xhat = ((xg - mu) / jnp.sqrt(var + eps)).reshape(b, h, w, c)
    out = pscale(xhat, p["gamma"])
    out = bias_add(out, p["beta"])
    if ctx is not None and site is not None:
        ctx.ln_sites.append((site, xhat.reshape(b, -1, c)))
        ctx.site_shapes[site] = out.shape
        z = ctx.zs.get(site)
        if z is not None:
            out = out + z.reshape(out.shape)
    return out


def conv2d(x, p, *, stride=1, site=None, ctx=None):
    """3x3 same-padding conv, NHWC; weight ``[kh, kw, cin, cout]``.

    Bias-less when ``p`` has no ``"b"`` key — the ResNet situation of
    App. A.2 that motivates DP-BiTFiT-Add.
    """
    w = p["w"]
    s = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if ctx is not None and site is not None:
        # ghost clipping views a conv as a linear layer over unfolded patches
        patches = jax.lax.conv_general_dilated_patches(
            x,
            filter_shape=w.shape[:2],
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        bsz = x.shape[0]
        a = patches.reshape(bsz, -1, patches.shape[-1])
        s2 = s.reshape(bsz, -1, s.shape[-1])
        s2 = _site(s2, a, site, ctx)
        s = s2.reshape(s.shape)
    if "b" in p:
        s = bias_add(s, p["b"])
    return s


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def attention(x, p, heads, *, causal, use_lora=False, ctx=None, prefix=""):
    """Multi-head self-attention with combined qkv projection.

    With ``use_lora`` the qkv projection gains a low-rank update
    ``x @ lora_a @ lora_b`` (LoRA on the attention projections, Hu et al.).
    """
    b, t, d = x.shape
    qkv = linear(x, p["qkv"], site=prefix + "qkv", ctx=ctx)  # [B,T,3d]
    if use_lora:
        qkv = qkv + lora_delta(x, p["qkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads_of(z):
        return z.reshape(b, t, heads, d // heads).transpose(0, 2, 1, 3)

    q, k, v = heads_of(q), heads_of(k), heads_of(v)
    att = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(d / heads)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, d)
    return linear(out, p["proj"], site=prefix + "proj", ctx=ctx)


def mlp(x, p, *, ctx=None, prefix=""):
    h = gelu(linear(x, p["fc1"], site=prefix + "fc1", ctx=ctx))
    return linear(h, p["fc2"], site=prefix + "fc2", ctx=ctx)


def lora_delta(x, p, scale=2.0):
    """LoRA low-rank update ``scale * x @ A @ B`` (Hu et al., 2021)."""
    return pmat(pmat(x, p["lora_a"]), p["lora_b"]) * scale


def adapter(x, p):
    """Bottleneck adapter ``x + GeLU(x W_down) W_up`` (Houlsby et al., 2019)."""
    h = gelu(bias_add(pmat(x, p["adapter_down"]), p["adapter_down_b"]))
    return x + bias_add(pmat(h, p["adapter_up"]), p["adapter_up_b"])


def transformer_block(x, p, heads, *, causal, use_lora=False, use_adapter=False,
                      ctx=None, prefix=""):
    """Pre-LN transformer block, optionally with LoRA on qkv or adapters."""
    h = layer_norm(x, p["ln1"], site=prefix + "ln1", ctx=ctx)
    a = attention(h, p["attn"], heads, causal=causal, use_lora=use_lora,
                  ctx=ctx, prefix=prefix + "attn_")
    if use_adapter:
        a = adapter(a, p["adapter1"])
    x = x + a
    h = layer_norm(x, p["ln2"], site=prefix + "ln2", ctx=ctx)
    m = mlp(h, p["mlp"], ctx=ctx, prefix=prefix + "mlp_")
    if use_adapter:
        m = adapter(m, p["adapter2"])
    return x + m


# --------------------------------------------------------------------------
# ghost-clipping site collection
# --------------------------------------------------------------------------


class GhostCtx:
    """Collects (activation, site-name) pairs and LN x-hats during a forward.

    Used only by the GhostClip baseline step (2 backprops, stored
    activations) — DP-BiTFiT never instantiates one.
    """

    def __init__(self, zs=None):
        self.zs = zs if zs is not None else {}
        self.sites = []        # [(name, a [B,T,d])] for linear/conv sites
        self.ln_sites = []     # [(name, xhat [B,T,p])] for layer norms
        self.emb_sites = []    # [(name, token_ids or None)] for embeddings
        self.site_shapes = {}  # name -> shape of the pre-activation s


def _site(s, a, site, ctx):
    """Register a ghost site: record activation, add the z perturbation."""
    if ctx is None or site is None:
        return s
    ctx.sites.append((site, a))
    ctx.site_shapes[site] = s.shape
    z = ctx.zs.get(site)
    if z is not None:
        s = s + z
    return s


def embed_site(s, name, token_ids, ctx):
    """Register an embedding-lookup ghost site (one-hot ghost norm)."""
    if ctx is None:
        return s
    ctx.emb_sites.append((name, token_ids))
    ctx.site_shapes[name] = s.shape
    z = ctx.zs.get(name)
    if z is not None:
        s = s + z
    return s
