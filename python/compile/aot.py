"""AOT compilation: lower every training/eval/decode step to HLO text.

Interchange format is **HLO text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the rust side's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs, per artifact ``<name>``:
  artifacts/<name>.hlo.txt   — the HLO module
  artifacts/<name>.meta.json — input/output names, dtypes, shapes + sizes
and per model ``<model>``:
  artifacts/<model>.layout.json — canonical flat parameter layout + the
      trainable-subset masks every method uses (lets the rust coordinator
      split/merge full <-> (frozen, trainable) and re-init heads)
  artifacts/<model>.init.bin    — deterministic f32 init (full flat vector)
plus a global artifacts/manifest.json.

Python runs ONCE at build time; the rust binary is self-contained after
``make artifacts``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import methods, model

# --------------------------------------------------------------------------
# model registry (sizes chosen for a 1-core CPU testbed; DESIGN.md §5)
# --------------------------------------------------------------------------

C = model.TransformerCfg
MODELS = {
    # RoBERTa analogs (GLUE-analog classification, Tables 3/12/17, Fig 1)
    "cls-base": ("cls", C(vocab=512, t=64, d=128, layers=4, heads=4, ff=512, n_cls=4)),
    "cls-large": ("cls", C(vocab=512, t=64, d=192, layers=6, heads=6, ff=768, n_cls=4)),
    "cls-lora": ("cls", C(vocab=512, t=64, d=128, layers=4, heads=4, ff=512, n_cls=4, use_lora=True)),
    "cls-adapter": ("cls", C(vocab=512, t=64, d=128, layers=4, heads=4, ff=512, n_cls=4, use_adapter=True)),
    # GPT-2 analogs (E2E-analog generation, Tables 4/13, Fig 4)
    "lm-small": ("lm", C(vocab=384, t=48, d=64, layers=2, heads=2, ff=256, causal=True)),
    "lm-medium": ("lm", C(vocab=384, t=48, d=96, layers=3, heads=3, ff=384, causal=True)),
    "lm-large": ("lm", C(vocab=384, t=48, d=128, layers=4, heads=4, ff=512, causal=True)),
    # ViT analogs (CIFAR analogs, Tables 5/14/15, Fig 5)
    "vit-c10": ("vit", model.VitCfg(img=32, patch=4, d=96, layers=4, heads=4, ff=384, n_cls=10)),
    "vit-c20": ("vit", model.VitCfg(img=32, patch=4, d=96, layers=4, heads=4, ff=384, n_cls=20)),
    # ResNet analogs (CelebA-analog multi-label, Tables 6/16, §3.4)
    "cnn-small": ("cnn", model.CnnCfg(img=32, channels=(16, 32, 64), groups=4, n_out=8)),
    "cnn-small-bias": ("cnn", model.CnnCfg(img=32, channels=(16, 32, 64), groups=4, n_out=8, with_conv_bias=True)),
}

# Figure 3 sweeps: sequence-length (text) and resolution (image)
for _t in (32, 64, 128, 256):
    MODELS[f"cls-t{_t}"] = (
        "cls",
        C(vocab=512, t=_t, d=64, layers=2, heads=2, ff=256, n_cls=4),
    )
for _r in (16, 32, 64):
    MODELS[f"cnn-r{_r}"] = (
        "cnn",
        model.CnnCfg(img=_r, channels=(8, 16), groups=4, n_out=8),
    )

DEFAULT_B = 8

# (model, method) pairs to lower; "train" artifacts unless noted.
_ACC = ["dp-bitfit", "dp-full-ghost", "nondp-full", "nondp-bitfit"]
ARTIFACTS = []


def _add(mdl, method, *, step="train", clip="abadi", b=DEFAULT_B):
    ARTIFACTS.append(dict(model=mdl, method=method, step=step, clip=clip, b=b))


for _m in _ACC + ["dp-full-opacus", "dp-lastlayer"]:
    _add("cls-base", _m)
_add("cls-base", "dp-bitfit", clip="autos")
_add("cls-base", "dp-full-ghost", clip="autos")
_add("cls-lora", "dp-lora")
_add("cls-lora", "nondp-full")  # LoRA-std baseline uses the same model shape
_add("cls-adapter", "dp-adapter")
_add("cls-adapter", "nondp-full")
for _m in _ACC:
    _add("cls-large", _m)
_add("cls-large", "dp-bitfit", clip="autos")
_add("cls-large", "dp-full-ghost", clip="autos")
for _mdl in ("lm-small", "lm-medium", "lm-large"):
    for _m in _ACC:
        _add(_mdl, _m)
    _add(_mdl, "eval", step="eval")
    _add(_mdl, "decode", step="decode")
for _mdl in ("cls-base", "cls-large", "cls-lora", "cls-adapter"):
    _add(_mdl, "eval", step="eval")
for _mdl in ("vit-c10", "vit-c20"):
    for _m in ("dp-bitfit", "dp-full-opacus", "dp-full-ghost", "dp-lastlayer", "nondp-full"):
        _add(_mdl, _m)
    _add(_mdl, "eval", step="eval")
for _m in ("dp-bitfit", "dp-full-opacus", "dp-full-ghost", "dp-lastlayer", "nondp-full"):
    _add("cnn-small", _m)
_add("cnn-small", "eval", step="eval")
_add("cnn-small-bias", "dp-bitfit-add")
_add("cnn-small-bias", "nondp-full")
_add("cnn-small-bias", "eval", step="eval")
# Figure 3 sweeps (fixed B, varying T / resolution)
for _t in (32, 64, 128, 256):
    for _m in ("dp-bitfit", "dp-full-ghost", "dp-full-opacus", "nondp-full"):
        _add(f"cls-t{_t}", _m)
for _r in (16, 32, 64):
    for _m in ("dp-bitfit", "dp-full-ghost", "dp-full-opacus", "nondp-full"):
        _add(f"cnn-r{_r}", _m)


# --------------------------------------------------------------------------
# lowering machinery
# --------------------------------------------------------------------------


def artifact_name(entry):
    n = f"{entry['model']}__{entry['method']}"
    if entry["step"] == "train" and entry["clip"] != "abadi":
        n += f"__{entry['clip']}"
    return n


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def keep_all_inputs(fn):
    """Force every input into the lowered HLO signature.

    jax.jit drops unused arguments (e.g. ``clip_r`` in non-DP steps, the
    empty ``frozen`` vector in full fine-tuning), which would make artifact
    signatures method-dependent.  Adding a zero-valued dependency on each
    argument to the first output keeps the uniform DESIGN.md §6 contract;
    XLA folds the zeros away after the signature is fixed.
    """

    def wrapped(*args):
        dep = jnp.float32(0.0)
        for a in args:
            flat = jnp.ravel(a).astype(jnp.float32)
            dep = dep + 0.0 * jnp.sum(flat[:1])
        out = fn(*args)
        if isinstance(out, tuple):
            return (out[0] + dep,) + out[1:]
        return out + dep

    return wrapped


def data_specs(kind, cfg, b):
    """(x_spec, y_spec) for a model family."""
    if kind in ("cls", "lm"):
        x = _spec((b, cfg.t), jnp.int32)
        y = _spec((b, cfg.t), jnp.int32) if kind == "lm" else _spec((b,), jnp.int32)
    elif kind == "vit":
        x = _spec((b, cfg.img, cfg.img, 3))
        y = _spec((b,), jnp.int32)
    else:  # cnn
        x = _spec((b, cfg.img, cfg.img, 3))
        y = _spec((b, cfg.n_out)) if cfg.multi_label else _spec((b,), jnp.int32)
    return x, y


def build_step(bundle, entry):
    """(fn, input_specs, input_names, output_names, pf, pt)."""
    b = entry["b"]
    x_spec, y_spec = data_specs(bundle.kind, bundle.cfg, b)
    if entry["step"] == "train":
        method = entry["method"]
        subset = methods.METHOD_SUBSET[method]
        fn = methods.STEP_BUILDERS[method](bundle, entry["clip"])
        trainable = methods.trainable_mask(bundle, subset)
        _unf, pf, pt = model.make_unflatten(bundle.spec, trainable)
        specs = [_spec((pf,)), _spec((pt,)), x_spec, y_spec, _spec((b,)), _spec(())]
        names = ["frozen", "trainable", "x", "y", "mask", "clip_r"]
        outs = ["loss_sum", "grad", "sq_norms"]
    elif entry["step"] == "eval":
        fn = methods.make_eval_step(bundle, "full")
        trainable = methods.trainable_mask(bundle, "full")
        _unf, pf, pt = model.make_unflatten(bundle.spec, trainable)
        specs = [_spec((pf,)), _spec((pt,)), x_spec, y_spec, _spec((b,))]
        names = ["frozen", "trainable", "x", "y", "mask"]
        outs = ["loss_sum", "correct"]
    elif entry["step"] == "decode":
        fn = methods.make_decode_step(bundle)
        trainable = methods.trainable_mask(bundle, "full")
        _unf, pf, pt = model.make_unflatten(bundle.spec, trainable)
        specs = [_spec((pf,)), _spec((pt,)), x_spec, _spec((b,), jnp.int32)]
        names = ["frozen", "trainable", "x", "pos"]
        outs = ["logits"]
    else:
        raise ValueError(entry["step"])
    return fn, specs, names, outs, pf, pt


def export_model(out_dir, mdl_name, kind, cfg):
    """Write layout.json + init.bin for one model; returns (bundle, manifest entry)."""
    bundle, params = methods.make_bundle(kind, cfg)
    flat = np.asarray(model.flatten_params(params), dtype=np.float32)
    leaves, off = [], 0
    for name, shape in bundle.spec:
        size = int(math.prod(shape)) if shape else 1
        leaves.append(
            {"name": name, "shape": list(shape), "size": size, "offset": off,
             "is_head": name.startswith("head")}
        )
        off += size
    subsets = {}
    for subset in ("full", "bitfit", "lastlayer"):
        subsets[subset] = methods.trainable_mask(bundle, subset)
    if kind == "cnn" and cfg.with_conv_bias:
        subsets["bitfit_add"] = methods.trainable_mask(bundle, "bitfit_add")
    if getattr(cfg, "use_lora", False):
        subsets["lora"] = methods.trainable_mask(bundle, "lora")
    if getattr(cfg, "use_adapter", False):
        subsets["adapter"] = methods.trainable_mask(bundle, "adapter")
    layout = {
        "model": mdl_name,
        "kind": kind,
        "n_params": int(off),
        "leaves": leaves,
        "subsets": subsets,
    }
    with open(os.path.join(out_dir, f"{mdl_name}.layout.json"), "w") as f:
        json.dump(layout, f)
    flat.tofile(os.path.join(out_dir, f"{mdl_name}.init.bin"))
    cfg_d = dataclasses.asdict(cfg)
    cfg_d = {k: (list(v) if isinstance(v, tuple) else v) for k, v in cfg_d.items()}
    entry = {"kind": kind, "cfg": cfg_d, "n_params": int(off)}
    return bundle, entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"models": {}, "artifacts": []}
    bundles = {}
    for mdl_name, (kind, cfg) in MODELS.items():
        bundle, entry = export_model(args.out, mdl_name, kind, cfg)
        bundles[mdl_name] = bundle
        manifest["models"][mdl_name] = entry
        print(f"model {mdl_name}: {entry['n_params']} params")

    for entry in ARTIFACTS:
        name = artifact_name(entry)
        if args.only and args.only not in name:
            continue
        bundle = bundles[entry["model"]]
        fn, specs, in_names, out_names, pf, pt = build_step(bundle, entry)
        fn = keep_all_inputs(fn)
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(os.path.join(args.out, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *specs)
        if not isinstance(out_shapes, tuple):
            out_shapes = (out_shapes,)
        meta = {
            "name": name,
            "model": entry["model"],
            "method": entry["method"],
            "step": entry["step"],
            "clip": entry["clip"] if entry["step"] == "train" else None,
            "subset": methods.METHOD_SUBSET.get(entry["method"], "full"),
            "batch": entry["b"],
            "pf": int(pf),
            "pt": int(pt),
            "inputs": [
                {"name": n, "dtype": str(s.dtype), "shape": list(s.shape)}
                for n, s in zip(in_names, specs)
            ],
            "outputs": [
                {"name": n, "dtype": str(s.dtype), "shape": list(s.shape)}
                for n, s in zip(out_names, out_shapes)
            ],
        }
        with open(os.path.join(args.out, f"{name}.meta.json"), "w") as f:
            json.dump(meta, f)
        print(f"artifact {name}: {len(text)} chars, pf={pf} pt={pt}")

    manifest["artifacts"] = [artifact_name(e) for e in ARTIFACTS]
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    print(f"manifest lists {len(manifest['artifacts'])} artifacts in {args.out}")


if __name__ == "__main__":
    main()
