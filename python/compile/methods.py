"""DP fine-tuning step builders — Algorithm 1 and every baseline in Table 2.

Each builder returns a pure function with the artifact signature of
DESIGN.md §6 (train steps return ``(loss_sum, clipped_grad_sum, sq_norms)``)
that ``aot.py`` lowers to HLO text.  The implementations are *cost-faithful*
to the codebases the paper benchmarks:

* ``expand``  — per-sample grads for a trainable subset via the expand trick
  (one backward, activation-free bias path).  Used by DP-BiTFiT,
  DP-BiTFiT-Add, DP-last-layer, DP-LoRA, DP-Adapter.
* ``opacus``  — per-sample grads for *all* parameters instantiated via
  ``vmap(grad)`` (Opacus: +O(B·pd) space).
* ``ghost``   — GhostClip: backward #1 computes per-sample grad *norms* via
  the O(BT^2) Pallas ghost-norm kernel over stored activations, backward #2
  re-weights the loss by the clip factors (2 backprops, +O(BT^2) space).
* ``nondp``   — standard training on the same trainable subset.

Noise is NOT added here: the rust coordinator accumulates clipped sums over
microbatches of one logical Poisson batch, then adds sigma*R*N(0, I) once
(Alg. 1 lines 6-10 live in L3, where the privacy accountant also lives).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import kernels, model
from .kernels import ref
from .layers import GhostCtx


@dataclasses.dataclass(frozen=True)
class Bundle:
    """A model family + config + its canonical parameter spec."""

    kind: str          # "cls" | "lm" | "vit" | "cnn"
    cfg: object
    spec: tuple        # ((name, shape), ...)

    @property
    def n_params(self):
        total = 0
        for _n, shape in self.spec:
            size = 1
            for s in shape:
                size *= s
            total += size
        return total


def make_bundle(kind, cfg):
    key = jax.random.PRNGKey(0)
    init = {
        "cls": model.init_transformer,
        "lm": model.init_transformer,
        "vit": model.init_vit,
        "cnn": model.init_cnn,
    }[kind]
    params = init(key, cfg)
    return Bundle(kind, cfg, tuple(model.param_spec(params))), params


def per_example_loss(bundle, params, x, y, ctx=None):
    f = {
        "cls": model.per_example_loss_cls,
        "lm": model.per_example_loss_lm,
        "vit": model.per_example_loss_vit,
        "cnn": model.per_example_loss_cnn,
    }[bundle.kind]
    return f(params, x, y, bundle.cfg, ctx)


def trainable_mask(bundle, method):
    train_head = bundle.kind != "lm"  # §4.3: new head for downstream tasks
    return model.select_trainable(bundle.spec, method, train_head=train_head)


# --------------------------------------------------------------------------
# DP steps
# --------------------------------------------------------------------------


def make_dp_step_expand(bundle, method, clip_mode):
    """Per-sample grads via the expand trick (DP-BiTFiT & friends)."""
    trainable = trainable_mask(bundle, method)
    unflatten, _pf, pt = model.make_unflatten(bundle.spec, trainable)

    def step(frozen_flat, train_flat, x, y, mask, clip_r):
        b = x.shape[0]
        t_exp = jnp.broadcast_to(train_flat, (b, pt))

        def loss_fn(t_exp_):
            params = unflatten(frozen_flat, t_exp_)
            per_ex = per_example_loss(bundle, params, x, y)
            return jnp.sum(per_ex * mask)

        loss, g_ps = jax.value_and_grad(loss_fn)(t_exp)      # g_ps [B, Pt]
        sq = kernels.row_sq_norms(g_ps)                       # Pallas
        c = ref.clip_factors(sq, clip_r, clip_mode) * mask
        grad = kernels.weighted_sum(g_ps, c)                  # Pallas
        return loss, grad, sq

    return step


def make_dp_step_opacus(bundle, clip_mode):
    """DP full fine-tuning, Opacus style: instantiate [B, P] grads."""
    trainable = trainable_mask(bundle, "full")
    unflatten, _pf, _pt = model.make_unflatten(bundle.spec, trainable)

    def step(frozen_flat, train_flat, x, y, mask, clip_r):
        def one(train_flat_, xi, yi):
            params = unflatten(frozen_flat, train_flat_)
            return per_example_loss(bundle, params, xi[None], yi[None])[0]

        per_ex, g_ps = jax.vmap(
            lambda xi, yi: jax.value_and_grad(one)(train_flat, xi, yi)
        )(x, y)                                               # [B], [B, P]
        loss = jnp.sum(per_ex * mask)
        sq = kernels.row_sq_norms(g_ps)
        c = ref.clip_factors(sq, clip_r, clip_mode) * mask
        grad = kernels.weighted_sum(g_ps, c)
        return loss, grad, sq

    return step


def _ghost_probe(bundle, unflatten, frozen_flat, train_flat, x, y):
    """Static site inventory (names, categories, shapes) via abstract eval."""
    info = {"shapes": {}, "linear": [], "ln": [], "emb": []}

    def probe(frozen_, train_, x_, y_):
        params = unflatten(frozen_, train_)
        ctx = GhostCtx(zs={})
        per_example_loss(bundle, params, x_, y_, ctx=ctx)
        info["shapes"] = dict(ctx.site_shapes)
        info["linear"] = [name for name, _a in ctx.sites]
        info["ln"] = [name for name, _xh in ctx.ln_sites]
        info["emb"] = [(name, tok is not None) for name, tok in ctx.emb_sites]
        return 0.0

    jax.eval_shape(probe, frozen_flat, train_flat, x, y)
    return info


def make_dp_step_ghost(bundle, clip_mode):
    """DP full fine-tuning, GhostClip style (Li et al., 2021).

    Backward #1 (w.r.t. the zero site-perturbations ``z``) yields every
    layer's output gradient ``e_l``; per-sample norms follow from the ghost
    identity at O(BT^2) — the T^2 term the paper's headline figures are
    about.  Backward #2 re-weights per-example losses by the clip factors.
    """
    trainable = trainable_mask(bundle, "full")
    unflatten, _pf, _pt = model.make_unflatten(bundle.spec, trainable)

    def step(frozen_flat, train_flat, x, y, mask, clip_r):
        info = _ghost_probe(bundle, unflatten, frozen_flat, train_flat, x, y)
        zs0 = {k: jnp.zeros(v, jnp.float32) for k, v in info["shapes"].items()}
        params = unflatten(frozen_flat, train_flat)

        def loss_fn(zs):
            ctx = GhostCtx(zs=zs)
            per_ex = per_example_loss(bundle, params, x, y, ctx=ctx)
            aux = {"a": dict(ctx.sites), "xhat": dict(ctx.ln_sites)}
            return jnp.sum(per_ex * mask), aux

        (loss, aux), es = jax.value_and_grad(loss_fn, has_aux=True)(zs0)

        sq = jnp.zeros((x.shape[0],), jnp.float32)
        # linear/conv sites: ghost weight norm + bias norm
        for site in info["linear"]:
            a, e = aux["a"][site], es[site]
            if e.ndim == 2:  # [B, p] head-style site: grad is the outer e a^T
                sq = sq + ref.row_sq_norms(e) * ref.row_sq_norms(a)
                sq = sq + ref.row_sq_norms(e)  # bias
            else:
                sq = sq + kernels.ghost_norm(a, e)            # Pallas, O(BT^2)
                gb = kernels.bias_grad(e)
                sq = sq + kernels.row_sq_norms(gb)
        # layer/group-norm sites: gamma and beta per-sample grads from xhat
        for site in info["ln"]:
            xhat, e = aux["xhat"][site], es[site]
            if e.ndim > 3:
                e = e.reshape(e.shape[0], -1, e.shape[-1])
            g_gamma = jnp.sum(e * xhat, axis=1)
            sq = sq + ref.row_sq_norms(g_gamma)
            sq = sq + kernels.row_sq_norms(kernels.bias_grad(e))
        # embedding sites: one-hot ghost norm (token) + identity (positional)
        for site, has_tokens in info["emb"]:
            e = es[site]
            sq = sq + jnp.sum(e * e, axis=(1, 2))             # positional
            if has_tokens:
                eq = (x[:, :, None] == x[:, None, :]).astype(jnp.float32)
                eet = jnp.einsum("btd,bsd->bts", e, e)
                sq = sq + jnp.sum(eq * eet, axis=(1, 2))      # token table
            else:
                sq = sq + ref.row_sq_norms(e[:, 0, :])        # ViT CLS token

        c = ref.clip_factors(sq, clip_r, clip_mode) * mask
        c = jax.lax.stop_gradient(c)

        def loss2(train_flat_):
            params2 = unflatten(frozen_flat, train_flat_)
            per_ex2 = per_example_loss(bundle, params2, x, y)
            return jnp.sum(per_ex2 * c)

        grad = jax.grad(loss2)(train_flat)                    # backward #2
        return loss, grad, sq

    return step


def make_nondp_step(bundle, method):
    """Standard (non-private) training on the same trainable subset."""
    trainable = trainable_mask(bundle, method)
    unflatten, _pf, _pt = model.make_unflatten(bundle.spec, trainable)

    def step(frozen_flat, train_flat, x, y, mask, _clip_r):
        def loss_fn(train_flat_):
            params = unflatten(frozen_flat, train_flat_)
            per_ex = per_example_loss(bundle, params, x, y)
            return jnp.sum(per_ex * mask)

        loss, grad = jax.value_and_grad(loss_fn)(train_flat)
        return loss, grad, jnp.zeros((x.shape[0],), jnp.float32)

    return step


# --------------------------------------------------------------------------
# eval / decode steps
# --------------------------------------------------------------------------


def make_eval_step(bundle, method):
    """Returns ``(loss_sum, correct_or_tokens)`` on a masked batch."""
    trainable = trainable_mask(bundle, method)
    unflatten, _pf, _pt = model.make_unflatten(bundle.spec, trainable)

    def step(frozen_flat, train_flat, x, y, mask):
        params = unflatten(frozen_flat, train_flat)
        if bundle.kind == "lm":
            logits = model.lm_logits(params, x, bundle.cfg)
            nll = -jnp.take_along_axis(
                jax.nn.log_softmax(logits, axis=-1), y[..., None], axis=-1
            )[..., 0]
            valid = (y != model.PAD_ID).astype(jnp.float32) * mask[:, None]
            return jnp.sum(nll * valid), jnp.sum(valid)
        if bundle.kind == "cnn" and bundle.cfg.multi_label:
            logits = model.cnn_logits(params, x, bundle.cfg)
            per_ex = per_example_loss(bundle, params, x, y)
            pred = (logits > 0.0).astype(jnp.float32)
            acc = jnp.mean((pred == y).astype(jnp.float32), axis=-1)
            return jnp.sum(per_ex * mask), jnp.sum(acc * mask)
        logits_fn = {
            "cls": lambda: model.cls_logits(params, x, bundle.cfg),
            "vit": lambda: model.vit_logits(params, x, bundle.cfg),
            "cnn": lambda: model.cnn_logits(params, x, bundle.cfg),
        }[bundle.kind]
        logits = logits_fn()
        per_ex = per_example_loss(bundle, params, x, y)
        correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        return jnp.sum(per_ex * mask), jnp.sum(correct * mask)

    return step


def make_decode_step(bundle):
    """LM next-token logits at per-sample positions (greedy decoding in L3)."""
    trainable = trainable_mask(bundle, "full")
    unflatten, _pf, _pt = model.make_unflatten(bundle.spec, trainable)

    def step(frozen_flat, train_flat, x, pos):
        params = unflatten(frozen_flat, train_flat)
        logits = model.lm_logits(params, x, bundle.cfg)  # [B, T, V]
        return logits[jnp.arange(x.shape[0]), pos, :]

    return step


STEP_BUILDERS = {
    "dp-bitfit": lambda b, clip: make_dp_step_expand(b, "bitfit", clip),
    "dp-bitfit-add": lambda b, clip: make_dp_step_expand(b, "bitfit_add", clip),
    "dp-lastlayer": lambda b, clip: make_dp_step_expand(b, "lastlayer", clip),
    "dp-lora": lambda b, clip: make_dp_step_expand(b, "lora", clip),
    "dp-adapter": lambda b, clip: make_dp_step_expand(b, "adapter", clip),
    "dp-full-opacus": lambda b, clip: make_dp_step_opacus(b, clip),
    "dp-full-ghost": lambda b, clip: make_dp_step_ghost(b, clip),
    "nondp-full": lambda b, _clip: make_nondp_step(b, "full"),
    "nondp-bitfit": lambda b, _clip: make_nondp_step(b, "bitfit"),
}

# the trainable subset each step method operates on (for layout export)
METHOD_SUBSET = {
    "dp-bitfit": "bitfit",
    "dp-bitfit-add": "bitfit_add",
    "dp-lastlayer": "lastlayer",
    "dp-lora": "lora",
    "dp-adapter": "adapter",
    "dp-full-opacus": "full",
    "dp-full-ghost": "full",
    "nondp-full": "full",
    "nondp-bitfit": "bitfit",
}
