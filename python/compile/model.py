"""L2 model zoo: pure-JAX models used by the DP fine-tuning step builders.

Four families mirroring the paper's workloads (at 1-CPU-core scale; see
DESIGN.md §5 for the substitution table):

* :class:`TransformerCfg` with ``causal=True`` and ``n_cls=0`` — decoder LM
  (GPT-2 analog, E2E generation task, Table 4/13, Fig 4-top).
* :class:`TransformerCfg` with ``causal=False`` and ``n_cls>0`` — encoder
  classifier (RoBERTa analog, GLUE tasks, Tables 3/12/17, Figs 1/3-top).
* :class:`VitCfg` — tiny ViT (CIFAR analog, Tables 5/14/15, Fig 5).
* :class:`CnnCfg` — conv+GroupNorm net with *bias-less* convolutions by
  default (the ResNet situation of App. A.2; CelebA analog, Tables 6/16) and
  a ``with_conv_bias`` variant for DP-BiTFiT-Add (§3.4).

Parameters are nested dicts of jnp arrays; creation order fixes the canonical
flat layout exported to rust (``layout.json``).  All models are per-sample
separable (no batch norm), which is what makes the expand trick exact.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from . import layers

PAD_ID = 0  # token 0 is padding everywhere; CLS for classifiers is token 1.


# --------------------------------------------------------------------------
# configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerCfg:
    """Transformer config (encoder classifier when n_cls>0, else causal LM)."""

    vocab: int = 512
    t: int = 64
    d: int = 128
    layers: int = 4
    heads: int = 4
    ff: int = 512
    causal: bool = False
    n_cls: int = 0
    use_lora: bool = False
    use_adapter: bool = False
    lora_r: int = 8
    adapter_r: int = 16


@dataclasses.dataclass(frozen=True)
class VitCfg:
    """Tiny vision transformer over ``img x img`` RGB images."""

    img: int = 32
    patch: int = 4
    d: int = 96
    layers: int = 4
    heads: int = 4
    ff: int = 384
    n_cls: int = 10

    @property
    def tokens(self):
        return (self.img // self.patch) ** 2 + 1  # + CLS token


@dataclasses.dataclass(frozen=True)
class CnnCfg:
    """Small conv+GN network; convs are bias-less unless with_conv_bias."""

    img: int = 32
    channels: tuple = (16, 32, 64)
    groups: int = 4
    n_out: int = 8          # attributes (multi-label) or classes
    multi_label: bool = True
    with_conv_bias: bool = False  # True => the DP-BiTFiT-Add variant


# --------------------------------------------------------------------------
# initialization
# --------------------------------------------------------------------------


def _dense(key, d_in, d_out, *, bias=True, scale=None):
    kw, _ = jax.random.split(key)
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(kw, (d_in, d_out), jnp.float32) * std}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def _ln(d):
    return {"gamma": jnp.ones((d,), jnp.float32), "beta": jnp.zeros((d,), jnp.float32)}


def _block(key, cfg):
    k = jax.random.split(key, 8)
    p = {
        "ln1": _ln(cfg.d),
        "attn": {
            "qkv": _dense(k[0], cfg.d, 3 * cfg.d),
            "proj": _dense(k[1], cfg.d, cfg.d),
        },
        "ln2": _ln(cfg.d),
        "mlp": {
            "fc1": _dense(k[2], cfg.d, cfg.ff),
            "fc2": _dense(k[3], cfg.ff, cfg.d),
        },
    }
    if cfg.use_lora:
        p["attn"]["qkv"]["lora_a"] = jax.random.normal(
            k[4], (cfg.d, cfg.lora_r), jnp.float32
        ) / math.sqrt(cfg.d)
        p["attn"]["qkv"]["lora_b"] = jnp.zeros((cfg.lora_r, 3 * cfg.d), jnp.float32)
    if cfg.use_adapter:
        for name, kk in (("adapter1", k[5]), ("adapter2", k[6])):
            p[name] = {
                "adapter_down": jax.random.normal(kk, (cfg.d, cfg.adapter_r), jnp.float32)
                / math.sqrt(cfg.d),
                "adapter_down_b": jnp.zeros((cfg.adapter_r,), jnp.float32),
                "adapter_up": jnp.zeros((cfg.adapter_r, cfg.d), jnp.float32),
                "adapter_up_b": jnp.zeros((cfg.d,), jnp.float32),
            }
    return p


def init_transformer(key, cfg: TransformerCfg):
    keys = jax.random.split(key, cfg.layers + 3)
    params = {
        "embed": {
            "tok": jax.random.normal(keys[0], (cfg.vocab, cfg.d), jnp.float32) * 0.02,
            "pos": jax.random.normal(keys[1], (cfg.t, cfg.d), jnp.float32) * 0.02,
        }
    }
    for i in range(cfg.layers):
        params[f"block{i:02d}"] = _block(keys[2 + i], cfg)
    params["ln_f"] = _ln(cfg.d)
    out = cfg.n_cls if cfg.n_cls > 0 else cfg.vocab
    params["head"] = _dense(keys[-1], cfg.d, out, scale=0.02)
    return params


def init_vit(key, cfg: VitCfg):
    keys = jax.random.split(key, cfg.layers + 4)
    pdim = cfg.patch * cfg.patch * 3
    tcfg = _vit_block_cfg(cfg)
    params = {
        "embed": {
            "patch": _dense(keys[0], pdim, cfg.d),
            "cls": jax.random.normal(keys[1], (cfg.d,), jnp.float32) * 0.02,
            "pos": jax.random.normal(keys[2], (cfg.tokens, cfg.d), jnp.float32) * 0.02,
        }
    }
    for i in range(cfg.layers):
        params[f"block{i:02d}"] = _block(keys[3 + i], tcfg)
    params["ln_f"] = _ln(cfg.d)
    params["head"] = _dense(keys[-1], cfg.d, cfg.n_cls, scale=0.02)
    return params


def init_cnn(key, cfg: CnnCfg):
    keys = jax.random.split(key, len(cfg.channels) + 2)
    params = {}
    cin = 3
    for i, c in enumerate(cfg.channels):
        kw = jax.random.normal(keys[i], (3, 3, cin, c), jnp.float32) / math.sqrt(
            9 * cin
        )
        conv = {"w": kw}
        if cfg.with_conv_bias:
            conv["b"] = jnp.zeros((c,), jnp.float32)
        params[f"stage{i}"] = {"conv": conv, "gn": _ln(c)}
        cin = c
    params["head"] = _dense(keys[-1], cfg.channels[-1], cfg.n_out, scale=0.02)
    return params


def _vit_block_cfg(cfg: VitCfg) -> TransformerCfg:
    return TransformerCfg(d=cfg.d, heads=cfg.heads, ff=cfg.ff, causal=False)


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------


def transformer_hidden(params, x, cfg: TransformerCfg, ctx=None):
    """Token ids ``[B, T]`` -> final hidden states ``[B, T, d]``."""
    h = params["embed"]["tok"][x] + params["embed"]["pos"][None, :, :]
    h = layers.embed_site(h, "embed", x, ctx)
    for i in range(cfg.layers):
        h = layers.transformer_block(
            h, params[f"block{i:02d}"], cfg.heads,
            causal=cfg.causal, use_lora=cfg.use_lora, use_adapter=cfg.use_adapter,
            ctx=ctx, prefix=f"block{i:02d}_",
        )
    return layers.layer_norm(h, params["ln_f"], site="ln_f", ctx=ctx)


def cls_logits(params, x, cfg: TransformerCfg, ctx=None):
    """Classifier logits from the position-0 (CLS) hidden state."""
    h = transformer_hidden(params, x, cfg, ctx)
    return layers.linear(h[:, 0, :], params["head"], site="head", ctx=ctx)


def lm_logits(params, x, cfg: TransformerCfg, ctx=None):
    h = transformer_hidden(params, x, cfg, ctx)
    return layers.linear(h, params["head"], site="head", ctx=ctx)


def patchify(img, patch):
    """``[B, H, W, 3]`` -> ``[B, (H/p)*(W/p), p*p*3]`` patch tokens."""
    b, h, w, c = img.shape
    nh, nw = h // patch, w // patch
    x = img.reshape(b, nh, patch, nw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, nh * nw, patch * patch * c)


def vit_logits(params, img, cfg: VitCfg, ctx=None):
    tokens = patchify(img, cfg.patch)
    h = layers.linear(tokens, params["embed"]["patch"], site="patch", ctx=ctx)
    cls = jnp.broadcast_to(params["embed"]["cls"], (h.shape[0], 1, cfg.d))
    h = jnp.concatenate([cls, h], axis=1) + params["embed"]["pos"][None, :, :]
    h = layers.embed_site(h, "embed", None, ctx)
    tcfg = _vit_block_cfg(cfg)
    for i in range(cfg.layers):
        h = layers.transformer_block(
            h, params[f"block{i:02d}"], cfg.heads, causal=False,
            ctx=ctx, prefix=f"block{i:02d}_",
        )
    h = layers.layer_norm(h, params["ln_f"], site="ln_f", ctx=ctx)
    return layers.linear(h[:, 0, :], params["head"], site="head", ctx=ctx)


def cnn_logits(params, img, cfg: CnnCfg, ctx=None):
    h = img
    for i in range(len(cfg.channels)):
        stage = params[f"stage{i}"]
        stride = 1 if i == 0 else 2
        h = layers.conv2d(h, stage["conv"], stride=stride, site=f"stage{i}_conv", ctx=ctx)
        h = layers.group_norm(h, stage["gn"], cfg.groups, site=f"stage{i}_gn", ctx=ctx)
        h = jax.nn.relu(h)
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    return layers.linear(h, params["head"], site="head", ctx=ctx)


# --------------------------------------------------------------------------
# per-example losses (the quantity DP-SGD clips)
# --------------------------------------------------------------------------


def _xent(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]


def per_example_loss_cls(params, x, y, cfg: TransformerCfg, ctx=None):
    """Classification: per-example cross entropy ``[B]``."""
    return _xent(cls_logits(params, x, cfg, ctx), y)


def per_example_loss_lm(params, x, y, cfg: TransformerCfg, ctx=None):
    """Causal LM: per-example mean NLL over non-pad target tokens ``[B]``.

    ``x`` are input tokens, ``y`` the next-token targets (PAD_ID = ignore).
    """
    logits = lm_logits(params, x, cfg, ctx)
    nll = _xent(logits, y)  # [B, T]
    valid = (y != PAD_ID).astype(jnp.float32)
    return jnp.sum(nll * valid, axis=1) / jnp.maximum(jnp.sum(valid, axis=1), 1.0)


def per_example_loss_vit(params, img, y, cfg: VitCfg, ctx=None):
    return _xent(vit_logits(params, img, cfg, ctx), y)


def per_example_loss_cnn(params, img, y, cfg: CnnCfg, ctx=None):
    logits = cnn_logits(params, img, cfg, ctx)
    if cfg.multi_label:
        # mean binary cross entropy over attributes; y is {0,1}^A
        z = jax.nn.log_sigmoid(logits)
        zneg = jax.nn.log_sigmoid(-logits)
        return -jnp.mean(y * z + (1.0 - y) * zneg, axis=-1)
    return _xent(logits, y)


# --------------------------------------------------------------------------
# canonical flattening + trainable-subset selectors
# --------------------------------------------------------------------------


def param_spec(params, prefix=""):
    """Canonical ``[(name, shape)]`` in insertion (creation) order."""
    out = []
    for k, v in params.items():
        name = f"{prefix}{k}" if not prefix else f"{prefix}/{k}"
        if isinstance(v, dict):
            out.extend(param_spec(v, name))
        else:
            out.append((name, tuple(v.shape)))
    return out


def flatten_params(params):
    """Concatenate all leaves (canonical order) into one f32 vector."""
    leaves = []

    def walk(p):
        for v in p.values():
            if isinstance(v, dict):
                walk(v)
            else:
                leaves.append(v.reshape(-1))

    walk(params)
    return jnp.concatenate(leaves)


def select_trainable(spec, method, *, train_head=True):
    """Boolean trainable mask over the canonical leaf order.

    ``method`` in {full, bitfit, bitfit_add, lastlayer, lora, adapter}.
    ``train_head`` follows §4.3: downstream tasks replace the classifier head,
    so PEFT methods train it alongside their own parameters; for generation
    (pretrained head) pass ``train_head=False``.
    """
    mask = []
    for name, _shape in spec:
        is_bias = name.endswith("/b") or name.endswith("/beta")
        is_head = name.startswith("head")
        is_lora = "lora_" in name
        is_adapter = "adapter" in name
        if method == "full":
            m = True
        elif method in ("bitfit", "bitfit_add"):
            m = is_bias or (train_head and is_head)
        elif method == "lastlayer":
            m = is_head
        elif method == "lora":
            m = is_lora or (train_head and is_head)
        elif method == "adapter":
            m = is_adapter or (train_head and is_head)
        else:
            raise ValueError(f"unknown method {method!r}")
        mask.append(bool(m))
    return mask


def make_unflatten(spec, trainable):
    """Build ``unflatten(frozen_flat, train_flat_or_expanded) -> params``.

    If the trainable argument is 2-D (``[B, Pt]``, the expand trick), the
    trainable leaves come out per-sample with a leading batch axis.
    """
    entries = []  # (name-path-as-list, shape, size, trainable)
    fo = to = 0
    offsets = []
    for (name, shape), tr in zip(spec, trainable):
        size = int(math.prod(shape)) if shape else 1
        if tr:
            offsets.append((to, True))
            to += size
        else:
            offsets.append((fo, False))
            fo += size
        entries.append((name.split("/"), shape, size))
    pf, pt = fo, to

    def unflatten(frozen_flat, train_arr):
        expanded = train_arr.ndim == 2
        params = {}
        for (path, shape, size), (off, tr) in zip(entries, offsets):
            if tr:
                if expanded:
                    b = train_arr.shape[0]
                    leaf = train_arr[:, off:off + size].reshape((b,) + shape)
                else:
                    leaf = train_arr[off:off + size].reshape(shape)
            else:
                leaf = frozen_flat[off:off + size].reshape(shape)
            d = params
            for k in path[:-1]:
                d = d.setdefault(k, {})
            d[path[-1]] = leaf
        return params

    return unflatten, pf, pt


def split_flat(full_flat, spec, trainable):
    """Split a full flat vector into (frozen_flat, train_flat) per the mask."""
    frozen, train = [], []
    off = 0
    for (name, shape), tr in zip(spec, trainable):
        size = int(math.prod(shape)) if shape else 1
        (train if tr else frozen).append(full_flat[off:off + size])
        off += size
    z = jnp.zeros((0,), jnp.float32)
    return (
        jnp.concatenate(frozen) if frozen else z,
        jnp.concatenate(train) if train else z,
    )
