"""AOT artifact consistency: manifest <-> meta <-> layout <-> init.bin.

These tests validate the interchange contract of DESIGN.md §6 over the
actually-emitted artifacts (skipped if `make artifacts` has not run).
"""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


def load(name):
    with open(os.path.join(ART, name)) as f:
        return json.load(f)


def test_manifest_artifacts_exist_on_disk():
    man = load("manifest.json")
    assert len(man["artifacts"]) >= 80
    for name in man["artifacts"]:
        assert os.path.exists(os.path.join(ART, f"{name}.hlo.txt")), name
        assert os.path.exists(os.path.join(ART, f"{name}.meta.json")), name


def test_layouts_are_contiguous_and_sized():
    man = load("manifest.json")
    for model, entry in man["models"].items():
        layout = load(f"{model}.layout.json")
        off = 0
        for leaf in layout["leaves"]:
            assert leaf["offset"] == off, (model, leaf["name"])
            size = int(np.prod(leaf["shape"])) if leaf["shape"] else 1
            assert leaf["size"] == size
            off += size
        assert off == layout["n_params"] == entry["n_params"]
        init = np.fromfile(os.path.join(ART, f"{model}.init.bin"), dtype=np.float32)
        assert init.shape[0] == off
        assert np.isfinite(init).all()


def test_meta_pf_pt_match_layout_subsets():
    man = load("manifest.json")
    for name in man["artifacts"]:
        meta = load(f"{name}.meta.json")
        layout = load(f"{meta['model']}.layout.json")
        subset = meta["subset"]
        mask = layout["subsets"][subset]
        sizes = [leaf["size"] for leaf in layout["leaves"]]
        pt = sum(s for s, m in zip(sizes, mask) if m)
        pf = sum(s for s, m in zip(sizes, mask) if not m)
        assert meta["pt"] == pt, name
        assert meta["pf"] == pf, name
        # input specs agree with pf/pt
        ins = {i["name"]: i for i in meta["inputs"]}
        assert ins["frozen"]["shape"] == [pf]
        assert ins["trainable"]["shape"] == [pt]


def test_train_artifacts_have_uniform_signature():
    man = load("manifest.json")
    for name in man["artifacts"]:
        meta = load(f"{name}.meta.json")
        if meta["step"] != "train":
            continue
        names = [i["name"] for i in meta["inputs"]]
        assert names == ["frozen", "trainable", "x", "y", "mask", "clip_r"], name
        outs = [o["name"] for o in meta["outputs"]]
        assert outs == ["loss_sum", "grad", "sq_norms"], name
        b = meta["batch"]
        assert {tuple(i["shape"]) for i in meta["inputs"] if i["name"] == "mask"} == {(b,)}
        assert meta["outputs"][1]["shape"] == [meta["pt"]]
        assert meta["outputs"][2]["shape"] == [b]


def test_bitfit_subsets_are_tiny():
    man = load("manifest.json")
    for model, entry in man["models"].items():
        layout = load(f"{model}.layout.json")
        mask = layout["subsets"]["bitfit"]
        sizes = [leaf["size"] for leaf in layout["leaves"]]
        pt = sum(s for s, m in zip(sizes, mask) if m)
        frac = pt / entry["n_params"]
        # biases (+ small head) only; the tiny sweep CNNs (~1.5k params)
        # have proportionally larger bias shares, like the paper's note
        # that parameter efficiency *improves* with model size (§3.1)
        limit = 0.2 if entry["kind"] == "cnn" else 0.05
        assert frac < limit, (model, frac)


def test_hlo_text_is_parseable_header():
    man = load("manifest.json")
    name = man["artifacts"][0]
    with open(os.path.join(ART, f"{name}.hlo.txt")) as f:
        head = f.read(200)
    assert "HloModule" in head
