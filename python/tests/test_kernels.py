"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SHAPES_3D = st.tuples(
    st.integers(1, 9), st.integers(1, 200), st.integers(1, 160)
)


def arr(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=12, deadline=None)
@given(shape=SHAPES_3D, seed=st.integers(0, 2**16))
def test_bias_grad_matches_ref(shape, seed):
    rng = np.random.default_rng(seed)
    g = arr(rng, shape)
    np.testing.assert_allclose(
        kernels.bias_grad(g), ref.bias_grad(g), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 9), p=st.integers(1, 1200), seed=st.integers(0, 2**16))
def test_row_sq_norms_matches_ref(b, p, seed):
    rng = np.random.default_rng(seed)
    g = arr(rng, (b, p))
    np.testing.assert_allclose(
        kernels.row_sq_norms(g), ref.row_sq_norms(g), rtol=2e-4, atol=1e-5
    )


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 5),
    t=st.integers(1, 170),
    d=st.integers(1, 24),
    p=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_ghost_norm_matches_ref(b, t, d, p, seed):
    rng = np.random.default_rng(seed)
    a = arr(rng, (b, t, d))
    e = arr(rng, (b, t, p))
    np.testing.assert_allclose(
        kernels.ghost_norm(a, e), ref.ghost_norm(a, e), rtol=5e-3, atol=1e-3
    )


@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 9), p=st.integers(1, 1200), seed=st.integers(0, 2**16))
def test_weighted_sum_matches_ref(b, p, seed):
    rng = np.random.default_rng(seed)
    g = arr(rng, (b, p))
    c = arr(rng, (b,))
    np.testing.assert_allclose(
        kernels.weighted_sum(g, c), ref.weighted_sum(g, c), rtol=2e-4, atol=1e-4
    )


def test_ghost_norm_equals_instantiated_grad_norm():
    """The ghost identity itself: ||e^T a||_F^2 via T x T Gram products."""
    rng = np.random.default_rng(0)
    a = arr(rng, (4, 33, 8))
    e = arr(rng, (4, 33, 12))
    explicit = jnp.einsum("btp,btd->bpd", e, a)
    want = jnp.sum(explicit**2, axis=(1, 2))
    np.testing.assert_allclose(kernels.ghost_norm(a, e), want, rtol=5e-3)


def test_clip_factors_modes():
    sq = jnp.asarray([0.25, 4.0, 1e-8])
    ab = ref.clip_factors(sq, 1.0, "abadi")
    np.testing.assert_allclose(ab, [1.0, 0.5, 1.0], rtol=1e-5)
    au = ref.clip_factors(sq, 1.0, "autos")
    # AUTO-S: R/(norm + 0.01); never exceeds R/norm sensitivity
    norms = np.sqrt(np.asarray(sq))
    assert np.all(np.asarray(au) * norms <= 1.0 + 1e-6)
    with pytest.raises(ValueError):
        ref.clip_factors(sq, 1.0, "bogus")


def test_bias_grad_2d_passthrough():
    g = jnp.ones((3, 7))
    np.testing.assert_array_equal(kernels.bias_grad(g), g)


def test_kernels_handle_block_boundaries_exactly():
    """Shapes exactly at / around the default block sizes (NaN-padding bug)."""
    for p in (511, 512, 513, 1024, 1025):
        g = jnp.ones((4, p), jnp.float32)
        np.testing.assert_allclose(kernels.row_sq_norms(g), p, rtol=1e-6)
    for t in (127, 128, 129, 256):
        g = jnp.ones((2, t, 130), jnp.float32)
        np.testing.assert_allclose(kernels.bias_grad(g), float(t), rtol=1e-6)
