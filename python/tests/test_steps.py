"""L2 correctness: per-sample gradients, method equivalences, model shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import methods, model

jax.config.update("jax_platform_name", "cpu")

B = 4
R = jnp.float32(1.0)
MASK = jnp.ones((B,), jnp.float32)


def tiny_cls(**kw):
    cfg = model.TransformerCfg(
        vocab=64, t=16, d=32, layers=2, heads=2, ff=64, n_cls=3, **kw
    )
    return methods.make_bundle("cls", cfg)


def cls_batch(seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(1, 64, size=(B, 16)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 3, size=(B,)), jnp.int32)
    return x, y


def split(bundle, params, subset):
    tr = methods.trainable_mask(bundle, subset)
    flat = model.flatten_params(params)
    return model.split_flat(flat, bundle.spec, tr), tr


class TestExpandTrick:
    """The expand trick yields EXACT per-sample gradients."""

    def test_matches_naive_per_example_loop(self):
        bundle, params = tiny_cls()
        (frozen, train), tr = split(bundle, params, "bitfit")
        unf, _pf, pt = model.make_unflatten(bundle.spec, tr)
        x, y = cls_batch()

        t_exp = jnp.broadcast_to(train, (B, pt))

        def loss_fn(t):
            p = unf(frozen, t)
            return jnp.sum(methods.per_example_loss(bundle, p, x, y))

        gps = jax.grad(loss_fn)(t_exp)
        for i in range(B):
            def loss_i(t):
                p = unf(frozen, t)
                return methods.per_example_loss(bundle, p, x[i:i+1], y[i:i+1])[0]
            gi = jax.grad(loss_i)(train)
            np.testing.assert_allclose(gps[i], gi, rtol=3e-4, atol=1e-6)

    def test_activation_free_bias_vjp_matches_autodiff(self):
        """custom_vjp bias_add_ps == plain addition under grad."""
        from compile.layers import bias_add_ps

        rng = np.random.default_rng(1)
        s = jnp.asarray(rng.normal(size=(3, 5, 7)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(3, 7)), jnp.float32)

        def with_vjp(s, b):
            return jnp.sum(jnp.tanh(bias_add_ps(s, b)) ** 2)

        def plain(s, b):
            return jnp.sum(jnp.tanh(s + b[:, None, :]) ** 2)

        g1 = jax.grad(with_vjp, argnums=(0, 1))(s, b)
        g2 = jax.grad(plain, argnums=(0, 1))(s, b)
        np.testing.assert_allclose(g1[0], g2[0], rtol=1e-5)
        np.testing.assert_allclose(g1[1], g2[1], rtol=1e-5)


class TestMethodEquivalence:
    """GhostClip and Opacus implementations agree exactly (same math)."""

    @pytest.mark.parametrize("clip", ["abadi", "autos"])
    def test_ghost_equals_opacus_cls(self, clip):
        bundle, params = tiny_cls()
        (frozen, train), _ = split(bundle, params, "full")
        x, y = cls_batch(2)
        lg, gg, sg = jax.jit(methods.make_dp_step_ghost(bundle, clip))(
            frozen, train, x, y, MASK, R
        )
        lo, go, so = jax.jit(methods.make_dp_step_opacus(bundle, clip))(
            frozen, train, x, y, MASK, R
        )
        np.testing.assert_allclose(float(lg), float(lo), rtol=1e-5)
        np.testing.assert_allclose(sg, so, rtol=5e-3)
        np.testing.assert_allclose(gg, go, rtol=5e-3, atol=2e-5)

    def test_clipped_grad_norm_bounded_by_batch_sensitivity(self):
        """sum_i C_i g_i has norm <= B*R under Abadi clipping."""
        bundle, params = tiny_cls()
        (frozen, train), _ = split(bundle, params, "bitfit")
        x, y = cls_batch(3)
        step = jax.jit(methods.make_dp_step_expand(bundle, "bitfit", "abadi"))
        _, grad, sq = step(frozen, train, x, y, MASK, R)
        assert float(jnp.linalg.norm(grad)) <= B * float(R) + 1e-4
        assert np.all(np.asarray(sq) >= 0)

    def test_mask_excludes_examples_exactly(self):
        bundle, params = tiny_cls()
        (frozen, train), _ = split(bundle, params, "bitfit")
        x, y = cls_batch(4)
        step = jax.jit(methods.make_dp_step_expand(bundle, "bitfit", "abadi"))
        m = jnp.asarray([1.0, 1.0, 0.0, 0.0], jnp.float32)
        l_half, g_half, _ = step(frozen, train, x, y, m, R)
        # recompute with a physically smaller batch of the 2 masked-in rows,
        # padded back to B with zero-mask junk rows
        x2 = jnp.concatenate([x[:2], x[:2]], axis=0)
        y2 = jnp.concatenate([y[:2], y[:2]], axis=0)
        l2, g2, _ = step(frozen, train, x2, y2, m, R)
        np.testing.assert_allclose(float(l_half), float(l2), rtol=1e-5)
        np.testing.assert_allclose(g_half, g2, rtol=1e-4, atol=1e-6)


class TestTrainableSubsets:
    def test_bitfit_selects_only_biases_and_head(self):
        bundle, _ = tiny_cls()
        tr = model.select_trainable(bundle.spec, "bitfit", train_head=True)
        for (name, _shape), m in zip(bundle.spec, tr):
            is_bias = name.endswith("/b") or name.endswith("/beta")
            is_head = name.startswith("head")
            assert m == (is_bias or is_head), name

    def test_bitfit_fraction_is_small(self):
        cfg = model.TransformerCfg(vocab=512, t=64, d=128, layers=4, heads=4, ff=512, causal=True)
        bundle, params = methods.make_bundle("lm", cfg)
        tr = methods.trainable_mask(bundle, "bitfit")
        _, pf, pt = model.make_unflatten(bundle.spec, tr)
        frac = pt / (pf + pt)
        assert frac < 0.01, frac  # < 1% of params (paper: ~0.1%)

    def test_split_merge_roundtrip(self):
        bundle, params = tiny_cls()
        flat = model.flatten_params(params)
        tr = methods.trainable_mask(bundle, "bitfit")
        frozen, train = model.split_flat(flat, bundle.spec, tr)
        unf, _, _ = model.make_unflatten(bundle.spec, tr)
        rebuilt = model.flatten_params(unf(frozen, train))
        np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))


class TestModels:
    def test_lm_loss_is_mean_nll_over_nonpad_targets(self):
        cfg = model.TransformerCfg(vocab=64, t=8, d=16, layers=1, heads=2, ff=32, causal=True)
        bundle, params = methods.make_bundle("lm", cfg)
        x = jnp.asarray([[5, 6, 7, 8, 9, 10, 11, 12]], jnp.int32)
        y = jnp.asarray([[6, 7, 8, 0, 0, 0, 0, 0]], jnp.int32)  # 3 supervised
        loss = methods.per_example_loss(bundle, params, x, y)
        logits = model.lm_logits(params, x, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        want = -(logp[0, 0, 6] + logp[0, 1, 7] + logp[0, 2, 8]) / 3.0
        np.testing.assert_allclose(float(loss[0]), float(want), rtol=1e-5)

    def test_vit_patchify_is_invertible_count(self):
        img = jnp.arange(2 * 8 * 8 * 3, dtype=jnp.float32).reshape(2, 8, 8, 3)
        p = model.patchify(img, 4)
        assert p.shape == (2, 4, 48)
        # every pixel appears exactly once
        np.testing.assert_allclose(jnp.sort(p.ravel()), jnp.sort(img.ravel()))

    def test_causal_lm_cannot_see_future(self):
        cfg = model.TransformerCfg(vocab=64, t=8, d=16, layers=1, heads=2, ff=32, causal=True)
        bundle, params = methods.make_bundle("lm", cfg)
        x1 = jnp.asarray([[5, 6, 7, 8, 9, 10, 11, 12]], jnp.int32)
        x2 = x1.at[0, 7].set(3)  # change only the LAST token
        l1 = model.lm_logits(params, x1, cfg)
        l2 = model.lm_logits(params, x2, cfg)
        # logits at positions < 7 are unchanged
        np.testing.assert_allclose(l1[:, :7], l2[:, :7], atol=1e-6)
        assert not np.allclose(l1[:, 7], l2[:, 7])

    def test_cnn_bias_variants_differ_only_in_bias_leaves(self):
        c1 = model.CnnCfg(img=16, channels=(8, 16), groups=4, n_out=4)
        c2 = model.CnnCfg(img=16, channels=(8, 16), groups=4, n_out=4, with_conv_bias=True)
        b1, _ = methods.make_bundle("cnn", c1)
        b2, _ = methods.make_bundle("cnn", c2)
        extra = set(n for n, _ in b2.spec) - set(n for n, _ in b1.spec)
        assert extra == {"stage0/conv/b", "stage1/conv/b"}
