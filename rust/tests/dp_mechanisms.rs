//! Statistical battery for the DP mechanisms below the accountant: the
//! Poisson sampler (whose distribution the amplification analysis
//! *assumes* — a biased sampler silently voids the epsilon guarantee) and
//! the per-sample clipping functions (whose norm bound *is* the
//! sensitivity the Gaussian noise is calibrated to).
//!
//! The statistical checks use a fixed seed, so they are deterministic:
//! the 4-sigma confidence bands are about catching a broken generator or
//! a broken sampler loop, and a seeded ChaCha stream lands inside them
//! reproducibly.

use fastdp::dp::clip::{clip_factor, clip_in_place, ClipMode, AUTO_S_STABILIZER};
use fastdp::dp::sampler::PoissonSampler;
use fastdp::util::rng::ChaChaRng;

#[test]
fn poisson_mean_batch_size_is_within_four_sigma_of_nq() {
    let (n, q) = (20_000usize, 0.05f64);
    let rounds = 100usize;
    let mut s = PoissonSampler::new(n, q, 1234);
    let mut total = 0usize;
    for _ in 0..rounds {
        total += s.sample().len();
    }
    let mean = total as f64 / rounds as f64;
    let expect = s.expected_batch(); // n * q = 1000
    // per-draw variance n*q*(1-q); the mean of `rounds` draws concentrates
    let sigma_mean = (n as f64 * q * (1.0 - q) / rounds as f64).sqrt();
    assert!(
        (mean - expect).abs() <= 4.0 * sigma_mean,
        "mean batch {mean} outside {expect} +- {:.2}",
        4.0 * sigma_mean
    );
}

#[test]
fn poisson_same_seed_is_deterministic_draw_by_draw() {
    let mut a = PoissonSampler::new(5000, 0.02, 42);
    let mut b = PoissonSampler::new(5000, 0.02, 42);
    for round in 0..20 {
        assert_eq!(a.sample(), b.sample(), "diverged at round {round}");
    }
}

#[test]
fn poisson_disjoint_seeds_are_independent() {
    // two independent q-samplers intersect in ~ n*q^2 indices per draw;
    // correlated streams (e.g. a shared RNG) would blow far past the band
    let (n, q) = (20_000usize, 0.05f64);
    let rounds = 20usize;
    let mut a = PoissonSampler::new(n, q, 7);
    let mut b = PoissonSampler::new(n, q, 8);
    let mut inter_total = 0usize;
    let mut any_diff = false;
    for _ in 0..rounds {
        let sa = a.sample();
        let sb = b.sample();
        any_diff |= sa != sb;
        // both index lists are sorted ascending: merge-count the overlap
        let (mut i, mut j, mut inter) = (0usize, 0usize, 0usize);
        while i < sa.len() && j < sb.len() {
            match sa[i].cmp(&sb[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    inter += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        inter_total += inter;
    }
    assert!(any_diff, "disjoint seeds must not produce identical batches");
    let mean_inter = inter_total as f64 / rounds as f64;
    let expect = n as f64 * q * q; // 50
    let sigma_mean = (n as f64 * q * q * (1.0 - q * q) / rounds as f64).sqrt();
    assert!(
        (mean_inter - expect).abs() <= 4.0 * sigma_mean,
        "mean intersection {mean_inter} outside {expect} +- {:.2}",
        4.0 * sigma_mean
    );
}

#[test]
fn clipped_norm_never_exceeds_r_for_any_mode() {
    let mut rng = ChaChaRng::new(77, 0xC11F);
    for case in 0..200 {
        let dim = 1 + rng.below(128);
        // norms spanning 1e-3 .. 1e3 around each radius
        let scale = 10f64.powf(rng.uniform() * 6.0 - 3.0);
        let g: Vec<f32> = (0..dim).map(|_| (rng.gaussian() * scale) as f32).collect();
        let r = 0.05 + rng.uniform() * 5.0;
        for mode in [ClipMode::Abadi, ClipMode::AutoS] {
            let mut gc = g.clone();
            let factor = clip_in_place(&mut gc, r, mode);
            let norm: f64 = gc.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            assert!(
                norm <= r * (1.0 + 1e-5),
                "case {case} {mode:?}: post-clip norm {norm} > R = {r}"
            );
            // the returned factor is the one the formula promises
            let sq: f64 = g.iter().map(|&x| (x as f64).powi(2)).sum();
            assert_eq!(factor.to_bits(), clip_factor(sq, r, mode).to_bits(), "case {case}");
        }
    }
}

#[test]
fn abadi_is_the_identity_below_the_radius() {
    // Abadi's min(R/||g||, 1) promises a fixed point whenever sq_norm <= R^2
    for &(sq, r) in &[(0.0f64, 1.0f64), (1e-12, 0.5), (0.2499, 0.5), (0.25, 0.5), (99.9, 10.0)] {
        assert!(sq <= r * r, "test case must sit below the radius");
        assert_eq!(clip_factor(sq, r, ClipMode::Abadi), 1.0, "sq={sq} r={r}");
    }
    // and in-place clipping leaves the vector bit-identical there
    let g0 = vec![0.3f32, -0.2, 0.1];
    let mut g = g0.clone();
    let factor = clip_in_place(&mut g, 1.0, ClipMode::Abadi);
    assert_eq!(factor, 1.0);
    assert_eq!(g, g0);
}

#[test]
fn auto_s_never_promises_identity_but_always_bounds_sensitivity() {
    // AUTO-S = R / (||g|| + gamma): strictly below 1 even at the radius...
    let at_radius = clip_factor(1.0, 1.0, ClipMode::AutoS);
    assert!(at_radius < 1.0);
    assert!((at_radius - 1.0 / (1.0 + AUTO_S_STABILIZER)).abs() < 1e-12);
    // ...scales tiny gradients UP (that is its point: no vanishing bias
    // gradients)...
    assert!(clip_factor(1e-6, 1.0, ClipMode::AutoS) > 1.0);
    // ...and still never lets ||C g|| exceed R, anywhere
    for &sq in &[1e-10f64, 1e-4, 0.01, 1.0, 25.0, 1e8] {
        let c = clip_factor(sq, 1.0, ClipMode::AutoS);
        assert!(c * sq.sqrt() <= 1.0 + 1e-9, "sq={sq}");
    }
}
