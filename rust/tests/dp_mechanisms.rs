//! Statistical battery for the DP mechanisms below the accountant: the
//! Poisson sampler (whose distribution the amplification analysis
//! *assumes* — a biased sampler silently voids the epsilon guarantee) and
//! the per-sample clipping functions (whose norm bound *is* the
//! sensitivity the Gaussian noise is calibrated to).
//!
//! The statistical checks use a fixed seed, so they are deterministic:
//! the 4-sigma confidence bands are about catching a broken generator or
//! a broken sampler loop, and a seeded ChaCha stream lands inside them
//! reproducibly.

use fastdp::dp::add_gaussian_noise;
use fastdp::dp::clip::{clip_factor, clip_in_place, ClipMode, AUTO_S_STABILIZER};
use fastdp::dp::sampler::PoissonSampler;
use fastdp::engine::{Engine, JobSpec, Method, OptimKind};
use fastdp::util::rng::ChaChaRng;

#[test]
fn poisson_mean_batch_size_is_within_four_sigma_of_nq() {
    let (n, q) = (20_000usize, 0.05f64);
    let rounds = 100usize;
    let mut s = PoissonSampler::new(n, q, 1234);
    let mut total = 0usize;
    for _ in 0..rounds {
        total += s.sample().len();
    }
    let mean = total as f64 / rounds as f64;
    let expect = s.expected_batch(); // n * q = 1000
    // per-draw variance n*q*(1-q); the mean of `rounds` draws concentrates
    let sigma_mean = (n as f64 * q * (1.0 - q) / rounds as f64).sqrt();
    assert!(
        (mean - expect).abs() <= 4.0 * sigma_mean,
        "mean batch {mean} outside {expect} +- {:.2}",
        4.0 * sigma_mean
    );
}

#[test]
fn poisson_same_seed_is_deterministic_draw_by_draw() {
    let mut a = PoissonSampler::new(5000, 0.02, 42);
    let mut b = PoissonSampler::new(5000, 0.02, 42);
    for round in 0..20 {
        assert_eq!(a.sample(), b.sample(), "diverged at round {round}");
    }
}

#[test]
fn poisson_disjoint_seeds_are_independent() {
    // two independent q-samplers intersect in ~ n*q^2 indices per draw;
    // correlated streams (e.g. a shared RNG) would blow far past the band
    let (n, q) = (20_000usize, 0.05f64);
    let rounds = 20usize;
    let mut a = PoissonSampler::new(n, q, 7);
    let mut b = PoissonSampler::new(n, q, 8);
    let mut inter_total = 0usize;
    let mut any_diff = false;
    for _ in 0..rounds {
        let sa = a.sample();
        let sb = b.sample();
        any_diff |= sa != sb;
        // both index lists are sorted ascending: merge-count the overlap
        let (mut i, mut j, mut inter) = (0usize, 0usize, 0usize);
        while i < sa.len() && j < sb.len() {
            match sa[i].cmp(&sb[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    inter += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        inter_total += inter;
    }
    assert!(any_diff, "disjoint seeds must not produce identical batches");
    let mean_inter = inter_total as f64 / rounds as f64;
    let expect = n as f64 * q * q; // 50
    let sigma_mean = (n as f64 * q * q * (1.0 - q * q) / rounds as f64).sqrt();
    assert!(
        (mean_inter - expect).abs() <= 4.0 * sigma_mean,
        "mean intersection {mean_inter} outside {expect} +- {:.2}",
        4.0 * sigma_mean
    );
}

#[test]
fn clipped_norm_never_exceeds_r_for_any_mode() {
    let mut rng = ChaChaRng::new(77, 0xC11F);
    for case in 0..200 {
        let dim = 1 + rng.below(128);
        // norms spanning 1e-3 .. 1e3 around each radius
        let scale = 10f64.powf(rng.uniform() * 6.0 - 3.0);
        let g: Vec<f32> = (0..dim).map(|_| (rng.gaussian() * scale) as f32).collect();
        let r = 0.05 + rng.uniform() * 5.0;
        for mode in [ClipMode::Abadi, ClipMode::AutoS] {
            let mut gc = g.clone();
            let factor = clip_in_place(&mut gc, r, mode);
            let norm: f64 = gc.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            assert!(
                norm <= r * (1.0 + 1e-5),
                "case {case} {mode:?}: post-clip norm {norm} > R = {r}"
            );
            // the returned factor is the one the formula promises
            let sq: f64 = g.iter().map(|&x| (x as f64).powi(2)).sum();
            assert_eq!(factor.to_bits(), clip_factor(sq, r, mode).to_bits(), "case {case}");
        }
    }
}

#[test]
fn abadi_is_the_identity_below_the_radius() {
    // Abadi's min(R/||g||, 1) promises a fixed point whenever sq_norm <= R^2
    for &(sq, r) in &[(0.0f64, 1.0f64), (1e-12, 0.5), (0.2499, 0.5), (0.25, 0.5), (99.9, 10.0)] {
        assert!(sq <= r * r, "test case must sit below the radius");
        assert_eq!(clip_factor(sq, r, ClipMode::Abadi), 1.0, "sq={sq} r={r}");
    }
    // and in-place clipping leaves the vector bit-identical there
    let g0 = vec![0.3f32, -0.2, 0.1];
    let mut g = g0.clone();
    let factor = clip_in_place(&mut g, 1.0, ClipMode::Abadi);
    assert_eq!(factor, 1.0);
    assert_eq!(g, g0);
}

#[test]
fn auto_s_never_promises_identity_but_always_bounds_sensitivity() {
    // AUTO-S = R / (||g|| + gamma): strictly below 1 even at the radius...
    let at_radius = clip_factor(1.0, 1.0, ClipMode::AutoS);
    assert!(at_radius < 1.0);
    assert!((at_radius - 1.0 / (1.0 + AUTO_S_STABILIZER)).abs() < 1e-12);
    // ...scales tiny gradients UP (that is its point: no vanishing bias
    // gradients)...
    assert!(clip_factor(1e-6, 1.0, ClipMode::AutoS) > 1.0);
    // ...and still never lets ||C g|| exceed R, anywhere
    for &sq in &[1e-10f64, 1e-4, 0.01, 1.0, 25.0, 1e8] {
        let c = clip_factor(sq, 1.0, ClipMode::AutoS);
        assert!(c * sq.sqrt() <= 1.0 + 1e-9, "sq={sq}");
    }
}

// -------------------------------------------------------------------------
// the Gaussian mechanism itself: the noise added to the clipped sum must
// actually be N(0, (sigma * R)^2) per coordinate, independent across
// coordinates — the accountant's epsilon is *for that distribution*
// -------------------------------------------------------------------------

#[test]
fn gaussian_noise_mean_and_variance_sit_in_the_four_sigma_band() {
    let n = 200_000usize;
    let (sigma, clip_r) = (2.0f64, 0.5f64); // sigma * R = 1: unit noise std
    let mut g = vec![0.0f32; n];
    let mut rng = ChaChaRng::new(99, 0x6A55);
    add_gaussian_noise(&mut g, sigma, clip_r, &mut rng);

    let mean = g.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
    // mean of n unit-variance draws has std 1/sqrt(n)
    let mean_band = 4.0 / (n as f64).sqrt();
    assert!(mean.abs() <= mean_band, "noise mean {mean} outside +-{mean_band:.2e}");

    let var = g.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / (n - 1) as f64;
    // sample variance of gaussians has std sqrt(2/(n-1)) around 1
    let var_band = 4.0 * (2.0 / (n - 1) as f64).sqrt();
    assert!(
        (var - 1.0).abs() <= var_band,
        "noise variance {var} outside 1 +- {var_band:.2e}"
    );

    // excess kurtosis pins the *shape*: 0 for a gaussian, 4-sigma band
    // with std sqrt(24/n) — a uniform (-1.2) or laplace (+3) would fail
    let m4 = g.iter().map(|&x| (x as f64 - mean).powi(4)).sum::<f64>() / n as f64;
    let kurt = m4 / (var * var) - 3.0;
    let kurt_band = 4.0 * (24.0 / n as f64).sqrt();
    assert!(kurt.abs() <= kurt_band, "excess kurtosis {kurt} outside +-{kurt_band:.2e}");
}

#[test]
fn gaussian_noise_is_independent_across_coordinates() {
    // lag-1 autocorrelation of independent draws is ~N(0, 1/n); a stuck
    // or block-repeating generator correlates adjacent coordinates
    let n = 200_000usize;
    let mut g = vec![0.0f32; n];
    let mut rng = ChaChaRng::new(7, 0x6A55);
    add_gaussian_noise(&mut g, 1.0, 1.0, &mut rng);
    let mean = g.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
    let var = g.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
    let lag1 = g
        .windows(2)
        .map(|w| (w[0] as f64 - mean) * (w[1] as f64 - mean))
        .sum::<f64>()
        / ((n - 1) as f64 * var);
    let band = 4.0 / (n as f64).sqrt();
    assert!(lag1.abs() <= band, "lag-1 autocorrelation {lag1} outside +-{band:.2e}");
}

#[test]
fn gaussian_noise_scales_with_sigma_times_r_and_zero_sigma_is_exact() {
    let n = 50_000usize;
    let mut a = vec![0.0f32; n];
    let mut b = vec![0.0f32; n];
    let mut ra = ChaChaRng::new(5, 0x6A55);
    let mut rb = ChaChaRng::new(5, 0x6A55);
    add_gaussian_noise(&mut a, 1.0, 0.2, &mut ra);
    add_gaussian_noise(&mut b, 4.0, 0.2, &mut rb);
    let rms = |v: &[f32]| {
        (v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / v.len() as f64).sqrt()
    };
    let ratio = rms(&b) / rms(&a);
    assert!((ratio - 4.0).abs() < 0.2, "quadrupling sigma scaled RMS by {ratio}");

    // sigma = 0 must be the exact identity (non-private runs add nothing,
    // not even a rounding step)
    let g0: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
    let mut g = g0.clone();
    add_gaussian_noise(&mut g, 0.0, 0.5, &mut ChaChaRng::new(1, 0x6A55));
    assert_eq!(g, g0);
}

// -------------------------------------------------------------------------
// the noise stream inside a real session: seeded, deterministic, and
// bit-stable across snapshot/resume (the audit's paired trainings depend
// on exact same-seed reproducibility)
// -------------------------------------------------------------------------

fn noisy_spec(seed: u64, steps: u64) -> JobSpec {
    JobSpec::builder("cls-base", Method::BiTFiT)
        .sigma(1.0)
        .delta(1e-5)
        .optim(OptimKind::Sgd)
        .lr(0.05)
        .clip_r(0.1)
        .batch(8)
        .steps(steps)
        .n_train(32)
        .seed(seed)
        .build()
        .expect("valid spec")
}

fn bits_of(params: &[f32]) -> Vec<u32> {
    params.iter().map(|p| p.to_bits()).collect()
}

#[test]
fn session_noise_stream_is_seed_deterministic() {
    let run = |seed: u64| {
        let mut engine = Engine::interpreter();
        let spec = noisy_spec(seed, 4);
        let data = engine.dataset(&spec.model, "sst2", spec.n_train, 3).unwrap();
        let mut s = engine.session(&spec).unwrap();
        for _ in 0..spec.steps {
            s.run_step(&data).unwrap();
        }
        bits_of(&s.full_params())
    };
    assert_eq!(run(21), run(21), "same seed must reproduce noise bit-for-bit");
    assert_ne!(run(21), run(22), "different seeds must draw different noise");
}

#[test]
fn session_noise_stream_survives_save_resume_bit_exactly() {
    let path = std::env::temp_dir()
        .join(format!("fastdp-dp-mech-resume-{}.ckpt", std::process::id()));
    let spec = noisy_spec(13, 6);

    // straight-through run
    let mut engine = Engine::interpreter();
    let data = engine.dataset(&spec.model, "sst2", spec.n_train, 3).unwrap();
    let mut s = engine.session(&spec).unwrap();
    for _ in 0..3 {
        s.run_step(&data).unwrap();
    }
    s.save_state(&path).unwrap();
    for _ in 3..6 {
        s.run_step(&data).unwrap();
    }
    let straight = bits_of(&s.full_params());

    // resumed run must continue the noise stream exactly where it left off
    let mut engine2 = Engine::interpreter();
    let mut r = engine2.resume_session(&spec, &path).unwrap();
    for _ in 3..6 {
        r.run_step(&data).unwrap();
    }
    let resumed = bits_of(&r.full_params());
    std::fs::remove_file(&path).ok();

    assert_eq!(straight, resumed, "resume must not fork the noise stream");
}
