//! Serve-scheduler contracts (`fastdp::serve`):
//!
//! * **Multiplexing is invisible.** A tenant scheduled through
//!   `serve::Scheduler` — with cross-tenant coalesced panel sweeps on —
//!   finishes with **bit-identical** parameters and spent epsilon to the
//!   same spec run alone through `Session::run_step`, across tenant
//!   counts {1, 4, 16} x worker threads {1, 8}, batched and unbatched.
//!   (The solo baseline is computed once: the blocked tier is itself
//!   bit-identical across thread counts, so one baseline pins them all.)
//! * **Fallbacks are invisible too.** Mixed-artifact tenants (which never
//!   share a coalesced sweep) and non-panel kernel tiers (where
//!   `run_multi` declines) take the per-tenant path and still match solo.
//! * **Admission is typed.** A full tenant budget or memory budget refuses
//!   with `ServeError::TenantBudgetFull` / `MemoryBudgetFull` without
//!   disturbing admitted tenants; the memory budget charges each shared
//!   frozen copy once (two same-model tenants fit where two private
//!   copies would not).
//! * **Epsilon caps are hard and pre-step.** A capped tenant is retired
//!   mid-stream (`TenantExit::EpsCapReached`) with `spent <= cap <
//!   projected` — never over-spent — while uncapped tenants in the same
//!   scheduler run to completion.

use fastdp::engine::{Engine, InterpreterBackend, JobSpec, KernelMode, Method, OptimKind};
use fastdp::serve::{capacity_report, Scheduler, ServeConfig, ServeError, TenantExit};

/// DP-BiTFiT spec, sigma pinned (no calibration), small but multi-chunk.
fn spec_for(model: &str, seed: u64, steps: u64) -> JobSpec {
    JobSpec::builder(model, Method::BiTFiT)
        .sigma(0.8)
        .delta(1e-5)
        .optim(OptimKind::Adam)
        .lr(5e-3)
        .clip_r(0.1)
        .batch(64)
        .steps(steps)
        .n_train(256)
        .seed(seed)
        .build()
        .unwrap()
}

fn engine_with(threads: usize, mode: KernelMode) -> Engine {
    Engine::new(Box::new(InterpreterBackend::with_config(Some(threads), Some(mode))))
}

/// Final (param bits, epsilon bits) — the whole trajectory summary.
type Fingerprint = (Vec<u32>, u64);

fn fingerprint_of(session: &fastdp::engine::Session) -> Fingerprint {
    (
        session.full_params().iter().map(|v| v.to_bits()).collect(),
        session.privacy_spent().epsilon.to_bits(),
    )
}

/// Solo baseline: the plain `run_step` loop the scheduler must reproduce.
fn solo(model: &str, seed: u64, steps: u64, threads: usize, mode: KernelMode) -> Fingerprint {
    let mut engine = engine_with(threads, mode);
    let spec = spec_for(model, seed, steps);
    let task = engine.default_task(model).unwrap();
    let data = engine.dataset(model, task, spec.n_train, spec.seed).unwrap();
    let mut session = engine.session(&spec).unwrap();
    for _ in 0..spec.steps {
        session.run_step(&data).unwrap();
    }
    fingerprint_of(&session)
}

/// Run `seeds.len()` tenants (tenant i = `spec_for(model, seeds[i], ..)`)
/// through one scheduler; return each tenant's fingerprint.
fn serve_run(
    model: &str,
    seeds: &[u64],
    steps: u64,
    threads: usize,
    mode: KernelMode,
    batching: bool,
) -> Vec<Fingerprint> {
    let cfg = ServeConfig { batching, ..ServeConfig::default() };
    let mut sched = Scheduler::new(engine_with(threads, mode), cfg);
    for (i, &seed) in seeds.iter().enumerate() {
        let spec = spec_for(model, seed, steps);
        let task = sched.engine().default_task(model).unwrap();
        let data = sched.engine().dataset(model, task, spec.n_train, spec.seed).unwrap();
        sched.admit(&format!("tenant-{i}"), &spec, data, None).unwrap();
    }
    sched.run_to_completion().unwrap();
    for id in 0..sched.len() {
        assert!(
            matches!(sched.exit(id), Some(TenantExit::Completed { steps: s, .. }) if *s == steps),
            "tenant {id} must complete its {steps}-step target"
        );
    }
    (0..sched.len()).map(|id| fingerprint_of(sched.session(id))).collect()
}

const STEPS: u64 = 3;

#[test]
fn batched_tenants_match_solo_bit_for_bit() {
    let model = "cls-base";
    // one baseline per tenant seed; the blocked tier is bit-identical
    // across thread counts, so threads=1 pins every serve config below
    let solos: Vec<Fingerprint> =
        (0..16).map(|i| solo(model, 100 + i, STEPS, 1, KernelMode::Blocked)).collect();
    for &threads in &[1usize, 8] {
        for &n in &[1usize, 4, 16] {
            let seeds: Vec<u64> = (0..n as u64).map(|i| 100 + i).collect();
            let got = serve_run(model, &seeds, STEPS, threads, KernelMode::Blocked, true);
            for (i, fp) in got.iter().enumerate() {
                assert_eq!(
                    fp, &solos[i],
                    "tenant {i} of {n} (threads={threads}) diverged from its solo run"
                );
            }
        }
    }
}

#[test]
fn unbatched_scheduling_is_the_same_trajectory() {
    let model = "cls-base";
    let seeds = [100u64, 101, 102, 103];
    let batched = serve_run(model, &seeds, STEPS, 8, KernelMode::Blocked, true);
    let unbatched = serve_run(model, &seeds, STEPS, 8, KernelMode::Blocked, false);
    assert_eq!(batched, unbatched, "batching must be a pure throughput knob");
}

#[test]
fn simd_tier_batches_bit_identically_too() {
    let model = "cls-base";
    let seeds = [100u64, 101, 102, 103];
    let solos: Vec<Fingerprint> =
        seeds.iter().map(|&s| solo(model, s, STEPS, 1, KernelMode::Simd)).collect();
    let got = serve_run(model, &seeds, STEPS, 8, KernelMode::Simd, true);
    assert_eq!(got, solos);
}

#[test]
fn mixed_artifact_tenants_fall_back_and_still_match_solo() {
    // cls-base and lm-small never share shapes, so with batching on every
    // group is a singleton and the solo path runs — results must be
    // indistinguishable from training alone
    let cfg = ServeConfig::default();
    let mut sched = Scheduler::new(engine_with(2, KernelMode::Blocked), cfg);
    for (i, model) in ["cls-base", "lm-small", "cls-base"].iter().enumerate() {
        let spec = spec_for(model, 200 + i as u64, STEPS);
        let task = sched.engine().default_task(model).unwrap();
        let data = sched.engine().dataset(model, task, spec.n_train, spec.seed).unwrap();
        sched.admit(&format!("tenant-{i}"), &spec, data, None).unwrap();
    }
    sched.run_to_completion().unwrap();
    for (i, model) in ["cls-base", "lm-small", "cls-base"].iter().enumerate() {
        let want = solo(model, 200 + i as u64, STEPS, 2, KernelMode::Blocked);
        assert_eq!(fingerprint_of(sched.session(i)), want, "tenant {i} ({model})");
    }
}

#[test]
fn non_panel_tier_declines_coalescing_but_matches_its_solo() {
    // fused has no run_multi path: the scheduler must detect the None and
    // run every chunk per-tenant, matching the fused solo trajectory
    let model = "cls-base";
    let seeds = [300u64, 301];
    let solos: Vec<Fingerprint> =
        seeds.iter().map(|&s| solo(model, s, STEPS, 2, KernelMode::Fused)).collect();
    let got = serve_run(model, &seeds, STEPS, 2, KernelMode::Fused, true);
    assert_eq!(got, solos);
}

#[test]
fn tenant_budget_refuses_with_typed_error() {
    let cfg = ServeConfig { max_tenants: 2, ..ServeConfig::default() };
    let mut sched = Scheduler::new(engine_with(1, KernelMode::Blocked), cfg);
    for i in 0..2u64 {
        let spec = spec_for("cls-base", 400 + i, STEPS);
        let data = sched.engine().dataset("cls-base", "sst2", spec.n_train, spec.seed).unwrap();
        sched.admit(&format!("tenant-{i}"), &spec, data, None).unwrap();
    }
    let spec = spec_for("cls-base", 402, STEPS);
    let data = sched.engine().dataset("cls-base", "sst2", spec.n_train, spec.seed).unwrap();
    match sched.admit("tenant-2", &spec, data, None) {
        Err(ServeError::TenantBudgetFull { admitted, max_tenants }) => {
            assert_eq!(admitted, 2);
            assert_eq!(max_tenants, 2);
        }
        other => panic!("expected TenantBudgetFull, got {other:?}"),
    }
    // the refusal must not have disturbed the admitted tenants
    assert_eq!(sched.len(), 2);
    sched.run_to_completion().unwrap();
}

#[test]
fn memory_budget_charges_shared_frozen_once() {
    // probe the real per-session footprint with an unlimited scheduler
    let (resident, frozen) = {
        let mut probe = Scheduler::new(engine_with(1, KernelMode::Blocked), ServeConfig::default());
        let spec = spec_for("cls-base", 500, STEPS);
        let data = probe.engine().dataset("cls-base", "sst2", spec.n_train, spec.seed).unwrap();
        let id = probe.admit("probe", &spec, data, None).unwrap();
        (probe.session(id).resident_bytes(), probe.session(id).frozen_bytes())
    };
    assert!(frozen > resident, "cls-base frozen backbone dwarfs BiTFiT state");

    // budget fits ONE frozen copy + two tenants' mutable state: only the
    // engine's shared-frozen dedupe makes the second admission possible
    let budget = frozen + 2 * resident + resident / 2;
    let cfg =
        ServeConfig { mem_budget_bytes: Some(budget), ..ServeConfig::default() };
    let mut sched = Scheduler::new(engine_with(1, KernelMode::Blocked), cfg);
    for i in 0..2u64 {
        let spec = spec_for("cls-base", 500 + i, STEPS);
        let data = sched.engine().dataset("cls-base", "sst2", spec.n_train, spec.seed).unwrap();
        sched.admit(&format!("tenant-{i}"), &spec, data, None).unwrap();
    }
    assert!(budget < 2 * (frozen + resident), "budget must not fit two private copies");
    // a third tenant (another `resident` + shared frozen) exceeds it
    let spec = spec_for("cls-base", 502, STEPS);
    let data = sched.engine().dataset("cls-base", "sst2", spec.n_train, spec.seed).unwrap();
    match sched.admit("tenant-2", &spec, data, None) {
        Err(ServeError::MemoryBudgetFull { needed_bytes, free_bytes }) => {
            assert!(needed_bytes > free_bytes, "{needed_bytes} vs {free_bytes}");
        }
        other => panic!("expected MemoryBudgetFull, got {other:?}"),
    }
    assert_eq!(sched.len(), 2);

    let report = capacity_report(&sched);
    assert_eq!(report.tenants, 2);
    assert_eq!(report.shared_frozen_bytes, frozen, "one frozen copy serves both tenants");
    assert_eq!(report.unshared_frozen_bytes, 2 * frozen);
    assert_eq!(report.resident_bytes, 2 * resident);
    assert!(report.sessions_per_gb > 0.0);
}

#[test]
fn eps_cap_retires_mid_stream_without_overspending() {
    let model = "cls-base";
    let long = 50u64;
    // probe the accountant trajectory solo; a cap placed between the ε
    // totals after steps 3 and 4 must retire the tenant at exactly step 3
    let eps_at: Vec<f64> = {
        let mut engine = engine_with(2, KernelMode::Blocked);
        let spec = spec_for(model, 600, long);
        let task = engine.default_task(model).unwrap();
        let data = engine.dataset(model, task, spec.n_train, spec.seed).unwrap();
        let mut session = engine.session(&spec).unwrap();
        (0..5)
            .map(|_| {
                session.run_step(&data).unwrap();
                session.privacy_spent().epsilon
            })
            .collect()
    };
    assert!(eps_at[3] > eps_at[2], "the accountant must keep spending");
    let cap = 0.5 * (eps_at[2] + eps_at[3]);
    let mut sched = Scheduler::new(engine_with(2, KernelMode::Blocked), ServeConfig::default());
    // tenant 0 capped, tenant 1 uncapped — same spec otherwise
    for (i, eps_cap) in [(0u64, Some(cap)), (1, None)] {
        let spec = spec_for(model, 600 + i, long);
        let task = sched.engine().default_task(model).unwrap();
        let data = sched.engine().dataset(model, task, spec.n_train, spec.seed).unwrap();
        sched.admit(&format!("tenant-{i}"), &spec, data, eps_cap).unwrap();
    }
    sched.run_to_completion().unwrap();

    match sched.exit(0) {
        Some(&TenantExit::EpsCapReached { spent, projected, cap: c }) => {
            assert_eq!(c, cap);
            assert!(spent <= cap, "retired tenant over-spent: {spent} > {cap}");
            assert!(projected > cap, "retirement requires a crossing projection");
        }
        other => panic!("expected EpsCapReached, got {other:?}"),
    }
    let capped = sched.session(0);
    assert_eq!(capped.step(), 3, "the cap sits between the step-3 and step-4 ε totals");
    assert!(capped.privacy_spent().epsilon <= cap, "accountant agrees: never over cap");
    // the uncapped tenant kept running after its neighbour retired
    assert!(
        matches!(sched.exit(1), Some(TenantExit::Completed { steps, .. }) if *steps == long),
        "uncapped tenant must finish all {long} steps: {:?}",
        sched.exit(1)
    );
}

#[test]
fn replicated_jobs_are_refused_at_admission() {
    let mut sched = Scheduler::new(engine_with(1, KernelMode::Blocked), ServeConfig::default());
    let spec = JobSpec::builder("cls-base", Method::BiTFiT)
        .sigma(0.8)
        .delta(1e-5)
        .batch(64)
        .steps(1)
        .n_train(256)
        .seed(1)
        .replicas(2)
        .build()
        .unwrap();
    let data = sched.engine().dataset("cls-base", "sst2", spec.n_train, spec.seed).unwrap();
    assert!(matches!(
        sched.admit("tenant-0", &spec, data, None),
        Err(ServeError::Unsupported(_))
    ));
    assert!(sched.is_empty());
}
