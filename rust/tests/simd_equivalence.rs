//! Simd-tier equivalence contract (`FASTDP_KERNELS=simd`):
//!
//! * outputs (per-sample norms, clipped gradient sums, losses) must match
//!   the fused oracle within the ghost-tier 1e-4 relative tolerance
//!   across a sweep of shapes — all four model families x {full, bitfit,
//!   lastlayer}, with parametric (t, img, n_cls) variations and
//!   pseudo-randomly drawn block widths (the panels compute in f32, so
//!   the contract is tolerance, never bitwise, vs fused);
//! * multi-step training trajectories must stay within tolerance of the
//!   fused path (f32 rounding does not compound past it);
//! * within the tier, outputs must be **bit-identical** across
//!   `FASTDP_THREADS` in {1, 2, 8}, across any block width, *and* across
//!   forced feature levels (portable scalar vs the best level the host
//!   detects) — the instruction set is a pure dispatch knob.
//!
//! The kernel tier, block width and feature level are pinned via
//! `InterpreterBackend::with_config` / `set_block_rows` /
//! `set_simd_level` (never resolved from the environment), so these
//! assertions stay meaningful under the ci.sh `FASTDP_KERNELS` /
//! `FASTDP_SIMD` matrix.
//!
//! Inputs come from `bench::synth_step_inputs` — the same generator the
//! throughput harness's probes use — with the mask and clip radius
//! overridden to exercise masked rows and real DP clipping.

use fastdp::bench::synth_step_inputs;
use fastdp::engine::{Backend, InterpreterBackend, KernelMode, SimdLevel, StepRunner};
use fastdp::util::tensor::Tensor;

/// Per-element relative tolerance for simd vs fused (the ghost-tier
/// contract: the panels round to f32 with compensated accumulation).
const RTOL: f32 = 1e-4;
/// Absolute floor below which values are considered equal.
const ATOL: f32 = 1e-6;

/// Shape sweep: every trainable-leaf combination the factor plan can
/// take, across all four families, plus parametric shape variations so
/// (d, h, out, vocab, t, B) all move.  Tuples carry a seed used to draw
/// this case's block widths.
const CASES: &[(&str, u64)] = &[
    // cls: full (embed scatter + enc), bitfit, lastlayer + seq-len sweep
    ("cls-base__dp-full-opacus", 1),
    ("cls-base__dp-bitfit", 2),
    ("cls-base__dp-lastlayer", 3),
    ("cls-t17__dp-full-opacus", 4),
    ("cls-t128__dp-bitfit", 5),
    // lm: the T x T Gram path, position-panelled
    ("lm-small__dp-full-opacus", 6),
    ("lm-small__dp-bitfit", 7),
    ("lm-small__dp-lastlayer", 8),
    ("lm-medium__dp-bitfit", 9),
    // vit: pixel features re-read from the batch in phase B
    ("vit-c10__dp-full-opacus", 10),
    ("vit-c10__dp-bitfit", 11),
    ("vit-c20__dp-lastlayer", 12),
    // cnn: bias-less first layer (full), BiTFiT-Add twin, image sweep
    ("cnn-small__dp-full-opacus", 13),
    ("cnn-small__dp-bitfit", 14),
    ("cnn-small-bias__dp-bitfit-add", 15),
    ("cnn-r8__dp-full-opacus", 16),
    // clip-mode coverage and the non-DP (c = 1) path
    ("cls-base__dp-bitfit__autos", 17),
    ("lm-small__dp-full-opacus__autos", 18),
    ("cls-base__nondp-full", 19),
    ("vit-c10__nondp-bitfit", 20),
];

/// Tiny deterministic generator for per-case block widths (the
/// "property-style" part of the sweep; no external RNG dependency).
fn draw_blocks(seed: u64, n: usize) -> Vec<usize> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(0xD1B54A32D192ED03);
    (0..n)
        .map(|_| {
            s ^= s >> 27;
            s = s.wrapping_mul(0x2545F4914F6CDD1D);
            1 + (s >> 33) as usize % 40 // widths in [1, 40]
        })
        .collect()
}

/// Synthetic train inputs with the last 3 rows masked out and a clip
/// radius small enough that DP clipping really fires.
fn train_inputs(backend: &InterpreterBackend, step: &dyn StepRunner, seed: u64) -> Vec<Tensor> {
    let meta = step.meta().clone();
    let b = meta.batch;
    let mut inputs = synth_step_inputs(backend, &meta, seed).unwrap();
    let mut mask = vec![1.0f32; b];
    for m in mask.iter_mut().skip(b.saturating_sub(3)) {
        *m = 0.0;
    }
    inputs[4] = Tensor::f32(vec![b], mask);
    inputs[5] = Tensor::scalar_f32(0.05);
    inputs
}

/// Run one step of `artifact` under (threads, mode, block, level) on the
/// shared inputs.  `level` only matters for `KernelMode::Simd`.
fn outputs(
    artifact: &str,
    threads: usize,
    mode: KernelMode,
    block: Option<usize>,
    level: Option<SimdLevel>,
) -> Vec<Tensor> {
    let mut backend = InterpreterBackend::with_config(Some(threads), Some(mode));
    backend.set_block_rows(block);
    backend.set_simd_level(level);
    let step = backend.load(artifact).unwrap();
    let inputs = train_inputs(&backend, step.as_ref(), 41);
    step.run(&inputs).unwrap()
}

fn assert_tensors_close(a: &[Tensor], b: &[Tensor], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: output arity");
    for (ti, (ta, tb)) in a.iter().zip(b).enumerate() {
        let (va, vb) = (ta.as_f32(), tb.as_f32());
        assert_eq!(va.len(), vb.len(), "{tag}: output {ti} length");
        for (i, (&x, &y)) in va.iter().zip(vb).enumerate() {
            let scale = x.abs().max(y.abs()).max(ATOL);
            assert!(
                (x - y).abs() / scale < RTOL,
                "{tag}: output {ti}[{i}]: fused {x} vs simd {y}"
            );
        }
    }
}

fn bits_of(out: &[Tensor]) -> Vec<Vec<u32>> {
    out.iter().map(|t| t.as_f32().iter().map(|v| v.to_bits()).collect()).collect()
}

#[test]
fn simd_norms_and_grads_match_fused_across_shapes() {
    for &(artifact, seed) in CASES {
        let fused = outputs(artifact, 2, KernelMode::Fused, None, None);
        for blk in draw_blocks(seed, 2) {
            let simd = outputs(artifact, 2, KernelMode::Simd, Some(blk), None);
            // outputs are [loss, grad, sq_norms]: the norms are the
            // analytic claim, the grad the factor accumulation
            assert_tensors_close(&fused, &simd, &format!("{artifact} blk={blk}"));
            // sq_norms must be finite, non-negative, zero on masked rows
            let b = fused[2].len();
            let sq = simd[2].as_f32();
            assert!(sq.iter().all(|&s| s.is_finite() && s >= 0.0), "{artifact}");
            for row in b - 3..b {
                assert_eq!(sq[row], 0.0, "{artifact}: masked row {row} has a norm");
            }
        }
    }
}

#[test]
fn simd_outputs_bit_identical_across_threads_blocks_and_levels() {
    for &(artifact, seed) in CASES {
        let base = bits_of(&outputs(artifact, 1, KernelMode::Simd, Some(8), None));
        for threads in [2usize, 8] {
            assert_eq!(
                base,
                bits_of(&outputs(artifact, threads, KernelMode::Simd, Some(8), None)),
                "{artifact}: simd threads=1 vs {threads}"
            );
        }
        for blk in draw_blocks(seed ^ 0x51D0, 3) {
            assert_eq!(
                base,
                bits_of(&outputs(artifact, 2, KernelMode::Simd, Some(blk), None)),
                "{artifact}: simd block=8 vs block={blk}"
            );
        }
        // the forced-scalar fallback is the same computation as the best
        // detected level — the FMA-free lane scheme's whole point
        assert_eq!(
            base,
            bits_of(&outputs(artifact, 2, KernelMode::Simd, Some(8), Some(SimdLevel::Scalar))),
            "{artifact}: simd detected level vs forced scalar"
        );
        // and the env-default width is the same computation too
        assert_eq!(
            base,
            bits_of(&outputs(artifact, 2, KernelMode::Simd, None, None)),
            "{artifact}: simd pinned vs default width"
        );
    }
}

#[test]
fn simd_training_trajectory_matches_fused() {
    // several SGD steps per artifact: parameters must stay within
    // tolerance of the fused trajectory (f32 rounding does not compound
    // past it); the scalar level doubles as fallback-path coverage
    for artifact in ["cls-base__dp-bitfit", "lm-small__dp-bitfit", "cnn-small__dp-full-opacus"] {
        let run = |mode: KernelMode, block: Option<usize>, level: Option<SimdLevel>| -> Vec<f32> {
            let mut backend = InterpreterBackend::with_config(Some(2), Some(mode));
            backend.set_block_rows(block);
            backend.set_simd_level(level);
            let step = backend.load(artifact).unwrap();
            let meta = step.meta().clone();
            let mut inputs = train_inputs(&backend, step.as_ref(), 57);
            let pt = meta.pt;
            let b = meta.batch as f32;
            for _ in 0..3 {
                let out = step.run(&inputs).unwrap();
                let grad = out[1].as_f32();
                let mut train = inputs[1].as_f32().to_vec();
                for (p, g) in train.iter_mut().zip(grad) {
                    *p -= 0.5 * g / b;
                }
                inputs[1] = Tensor::f32(vec![pt], train);
            }
            inputs[1].as_f32().to_vec()
        };
        let fused = run(KernelMode::Fused, None, None);
        for (blk, level) in [(1usize, None), (7, Some(SimdLevel::Scalar)), (32, None)] {
            let simd = run(KernelMode::Simd, Some(blk), level);
            for (i, (&x, &y)) in fused.iter().zip(&simd).enumerate() {
                let scale = x.abs().max(y.abs()).max(1e-5);
                assert!(
                    (x - y).abs() / scale < 1e-3,
                    "{artifact} blk={blk}: param {i} diverged: fused {x} vs simd {y}"
                );
            }
        }
    }
}

#[test]
fn simd_handles_all_masked_and_all_active_extremes() {
    for artifact in ["cls-base__dp-bitfit", "lm-small__dp-full-opacus"] {
        for level in [None, Some(SimdLevel::Scalar)] {
            let mut backend = InterpreterBackend::with_config(Some(2), Some(KernelMode::Simd));
            backend.set_block_rows(Some(8));
            backend.set_simd_level(level);
            let step = backend.load(artifact).unwrap();
            let meta = step.meta().clone();
            let b = meta.batch;
            let mut inputs = synth_step_inputs(&backend, &meta, 3).unwrap();
            inputs[5] = Tensor::scalar_f32(0.05);
            // all rows masked: zero loss, zero grad, zero norms
            inputs[4] = Tensor::f32(vec![b], vec![0.0; b]);
            let out = step.run(&inputs).unwrap();
            assert_eq!(out[0].item_f32(), 0.0, "{artifact}");
            assert!(out[1].as_f32().iter().all(|&g| g == 0.0), "{artifact}");
            assert!(out[2].as_f32().iter().all(|&s| s == 0.0), "{artifact}");
            // all rows active: per-sample clipped norms bound the summed grad
            inputs[4] = Tensor::f32(vec![b], vec![1.0; b]);
            let out = step.run(&inputs).unwrap();
            let norm = fastdp::util::tensor::l2_norm(out[1].as_f32());
            assert!(
                norm <= b as f64 * 0.05 + 1e-4,
                "{artifact}: clipped sum norm {norm} exceeds B*R"
            );
        }
    }
}
