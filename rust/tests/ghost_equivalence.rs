//! Ghost-tier equivalence contract (`FASTDP_KERNELS=ghost`):
//!
//! * per-sample squared norms computed by book-keeping must match the
//!   materialized fused oracle within a tight relative tolerance, across
//!   all four model families x {full, bitfit, lastlayer} x both clip
//!   modes (plus non-DP rows);
//! * the clipped gradient sum and the parameters after several training
//!   steps must agree with the fused path within tolerance;
//! * within the tier, outputs must be **bit-identical** across
//!   `FASTDP_THREADS` in {1, 2, 8} — ghost reassociates reductions vs
//!   fused, so its cross-thread contract is its own.
//!
//! Inputs come from `bench::synth_step_inputs` — the same generator the
//! throughput harness's probes use — with the mask and clip radius
//! overridden to exercise masked rows and real DP clipping.

use fastdp::bench::synth_step_inputs;
use fastdp::engine::{Backend, InterpreterBackend, KernelMode, StepRunner};
use fastdp::util::tensor::Tensor;

/// Per-element relative tolerance for ghost vs fused (both paths compute
/// in f64 and cast to f32; only reduction order differs).
const RTOL: f32 = 1e-4;
/// Absolute floor below which values are considered equal.
const ATOL: f32 = 1e-6;

/// One artifact per (family, subset): every trainable-leaf combination
/// the ghost plan can take, including the embedding scatter (full on
/// token models), the bias-less CNN, and BiTFiT-Add.
const ARTIFACTS: &[&str] = &[
    // cls: full (embed scatter + enc), bitfit, lastlayer
    "cls-base__dp-full-opacus",
    "cls-base__dp-bitfit",
    "cls-base__dp-lastlayer",
    // lm: the T x T Gram path
    "lm-small__dp-full-opacus",
    "lm-small__dp-bitfit",
    "lm-small__dp-lastlayer",
    // vit: pixel features re-read from the batch in phase B
    "vit-c10__dp-full-opacus",
    "vit-c10__dp-bitfit",
    "vit-c10__dp-lastlayer",
    // cnn: bias-less first layer (full), BiTFiT-Add twin
    "cnn-small__dp-full-opacus",
    "cnn-small__dp-bitfit",
    "cnn-small-bias__dp-bitfit-add",
    // clip-mode coverage (paper Table 12) and the non-DP (c = 1) path
    "cls-base__dp-bitfit__autos",
    "lm-small__dp-full-opacus__autos",
    "vit-c10__dp-bitfit__abadi",
    "cls-base__nondp-full",
    "lm-small__nondp-bitfit",
];

/// Synthetic train inputs with the last 3 rows masked out and a clip
/// radius small enough that DP clipping really fires.
fn train_inputs(backend: &InterpreterBackend, step: &dyn StepRunner, seed: u64) -> Vec<Tensor> {
    let meta = step.meta().clone();
    let b = meta.batch;
    let mut inputs = synth_step_inputs(backend, &meta, seed).unwrap();
    let mut mask = vec![1.0f32; b];
    for m in mask.iter_mut().skip(b.saturating_sub(3)) {
        *m = 0.0;
    }
    inputs[4] = Tensor::f32(vec![b], mask);
    inputs[5] = Tensor::scalar_f32(0.05);
    inputs
}

/// Run one step of `artifact` under (threads, mode) on the shared inputs.
fn outputs(artifact: &str, threads: usize, mode: KernelMode) -> Vec<Tensor> {
    let mut backend = InterpreterBackend::with_config(Some(threads), Some(mode));
    let step = backend.load(artifact).unwrap();
    let inputs = train_inputs(&backend, step.as_ref(), 41);
    step.run(&inputs).unwrap()
}

fn assert_tensors_close(a: &[Tensor], b: &[Tensor], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: output arity");
    for (ti, (ta, tb)) in a.iter().zip(b).enumerate() {
        let (va, vb) = (ta.as_f32(), tb.as_f32());
        assert_eq!(va.len(), vb.len(), "{tag}: output {ti} length");
        for (i, (&x, &y)) in va.iter().zip(vb).enumerate() {
            let scale = x.abs().max(y.abs()).max(ATOL);
            assert!(
                (x - y).abs() / scale < RTOL,
                "{tag}: output {ti}[{i}]: fused {x} vs ghost {y}"
            );
        }
    }
}

#[test]
fn ghost_norms_and_grads_match_fused_oracle() {
    for artifact in ARTIFACTS {
        let fused = outputs(artifact, 2, KernelMode::Fused);
        let ghost = outputs(artifact, 2, KernelMode::Ghost);
        // outputs are [loss, grad, sq_norms]: the norms are the ghost
        // tier's analytic claim, the grad its clipped accumulation
        assert_tensors_close(&fused, &ghost, artifact);
        // sq_norms must be present and sane: finite, non-negative, zero
        // exactly on the masked rows
        let b = fused[2].len();
        let sq = ghost[2].as_f32();
        assert!(sq.iter().all(|&s| s.is_finite() && s >= 0.0), "{artifact}");
        for row in b - 3..b {
            assert_eq!(sq[row], 0.0, "{artifact}: masked row {row} has a norm");
        }
    }
}

#[test]
fn ghost_outputs_bit_identical_across_thread_counts() {
    for artifact in ARTIFACTS {
        let bits = |threads: usize| -> Vec<Vec<u32>> {
            outputs(artifact, threads, KernelMode::Ghost)
                .iter()
                .map(|t| t.as_f32().iter().map(|v| v.to_bits()).collect())
                .collect()
        };
        let base = bits(1);
        for threads in [2usize, 8] {
            assert_eq!(base, bits(threads), "{artifact}: ghost threads=1 vs {threads}");
        }
    }
}

#[test]
fn ghost_training_trajectory_matches_fused() {
    // several SGD steps per artifact: parameters must stay within
    // tolerance of the fused trajectory (errors do not compound past it)
    for artifact in ["cls-base__dp-bitfit", "lm-small__dp-bitfit", "cnn-small__dp-full-opacus"] {
        let run = |mode: KernelMode| -> Vec<f32> {
            let mut backend = InterpreterBackend::with_config(Some(2), Some(mode));
            let step = backend.load(artifact).unwrap();
            let meta = step.meta().clone();
            let mut inputs = train_inputs(&backend, step.as_ref(), 57);
            let pt = meta.pt;
            let b = meta.batch as f32;
            for _ in 0..5 {
                let out = step.run(&inputs).unwrap();
                let grad = out[1].as_f32();
                let mut train = inputs[1].as_f32().to_vec();
                for (p, g) in train.iter_mut().zip(grad) {
                    *p -= 0.5 * g / b;
                }
                inputs[1] = Tensor::f32(vec![pt], train);
            }
            inputs[1].as_f32().to_vec()
        };
        let fused = run(KernelMode::Fused);
        let ghost = run(KernelMode::Ghost);
        for (i, (&x, &y)) in fused.iter().zip(&ghost).enumerate() {
            let scale = x.abs().max(y.abs()).max(1e-5);
            assert!(
                (x - y).abs() / scale < 1e-3,
                "{artifact}: param {i} diverged: fused {x} vs ghost {y}"
            );
        }
    }
}

#[test]
fn ghost_handles_all_masked_and_all_active_extremes() {
    for artifact in ["cls-base__dp-bitfit", "lm-small__dp-full-opacus"] {
        let mut backend = InterpreterBackend::with_config(Some(2), Some(KernelMode::Ghost));
        let step = backend.load(artifact).unwrap();
        let meta = step.meta().clone();
        let b = meta.batch;
        let mut inputs = synth_step_inputs(&backend, &meta, 3).unwrap();
        inputs[5] = Tensor::scalar_f32(0.05);
        // all rows masked: zero loss, zero grad, zero norms
        inputs[4] = Tensor::f32(vec![b], vec![0.0; b]);
        let out = step.run(&inputs).unwrap();
        assert_eq!(out[0].item_f32(), 0.0, "{artifact}");
        assert!(out[1].as_f32().iter().all(|&g| g == 0.0), "{artifact}");
        assert!(out[2].as_f32().iter().all(|&s| s == 0.0), "{artifact}");
        // all rows active: per-sample clipped norms bound the summed grad
        inputs[4] = Tensor::f32(vec![b], vec![1.0; b]);
        let out = step.run(&inputs).unwrap();
        let norm = fastdp::util::tensor::l2_norm(out[1].as_f32());
        assert!(
            norm <= b as f64 * 0.05 + 1e-4,
            "{artifact}: clipped sum norm {norm} exceeds B*R"
        );
    }
}
