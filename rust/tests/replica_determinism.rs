//! Data-parallel replication must be invisible to the training trajectory:
//! for every reference architecture (cls / lm / vit / cnn), a session run
//! with `replicas` ∈ {1, 2, 4} produces **bit-identical** per-step losses,
//! final parameters and eval metrics — and `replicas = 1` *is* the
//! pre-existing in-process fused path, so the replicated runs are pinned to
//! it, not merely to each other.  This is the cross-replica extension of
//! the `FASTDP_THREADS` contract in `tests/parallel_determinism.rs`:
//! replicas reduce per-chunk clipped gradient sums in fixed replica order
//! (= global chunk order), so no float is ever folded in a different
//! order (see `coordinator::distributed`).
//!
//! The second half checks the paper's §3.1 claim on *measured* wire bytes:
//! a real DP-BiTFiT run must ship >= 100x less per-exchange traffic than
//! full fine-tuning of the same model under the same sampling schedule.

use fastdp::engine::{Engine, JobSpec, Method, OptimKind, Session};

/// One spec per architecture family: DP, sigma fixed (no calibration in the
/// loop), logical batch big enough to spread chunks over 4 replicas.
fn family_spec(model: &str, method: Method, replicas: usize) -> JobSpec {
    JobSpec::builder(model, method)
        .sigma(0.8)
        .delta(1e-5)
        .optim(OptimKind::Adam)
        .lr(5e-3)
        .clip_r(0.1)
        .batch(128)
        .steps(4)
        .n_train(256)
        .seed(23)
        .replicas(replicas)
        .build()
        .unwrap()
}

/// Train a session to completion; return (per-step loss bits, final param
/// bits, eval metric bits).
fn run_family(model: &str, method: Method, replicas: usize) -> (Vec<u64>, Vec<u32>, [u64; 2]) {
    let mut engine = Engine::interpreter();
    let spec = family_spec(model, method, replicas);
    let task = engine.default_task(model).unwrap();
    let train = engine.dataset(model, task, spec.n_train, 31).unwrap();
    let test = engine.dataset(model, task, 64, 32).unwrap();
    let mut session = engine.session(&spec).unwrap();
    let mut losses = Vec::new();
    for _ in 0..spec.steps {
        let s = session.run_step(&train).unwrap();
        losses.push(s.loss.to_bits());
        if replicas > 1 {
            let comm = s.comm.expect("replicated steps report CommStats");
            assert_eq!(comm.workers, replicas);
        } else {
            assert!(s.comm.is_none(), "in-process steps carry no CommStats");
        }
    }
    let params: Vec<u32> = session.full_params().iter().map(|v| v.to_bits()).collect();
    let eval = session.evaluate(&test, 64).unwrap();
    (losses, params, [eval.metric_a.to_bits(), eval.metric_b.to_bits()])
}

#[test]
fn all_families_bit_identical_across_replica_counts() {
    for (model, method) in [
        ("cls-base", Method::BiTFiT),
        ("lm-small", Method::BiTFiT),
        ("vit-c10", Method::LastLayer),
        ("cnn-small-bias", Method::BiTFiTAdd),
    ] {
        // replicas = 1 is the pre-existing in-process fused path — the
        // baseline every replicated run must match bit-for-bit
        let base = run_family(model, method, 1);
        for replicas in [2usize, 4] {
            let got = run_family(model, method, replicas);
            assert_eq!(got.0, base.0, "{model}: losses, replicas={replicas}");
            assert_eq!(got.1, base.1, "{model}: params, replicas={replicas}");
            assert_eq!(got.2, base.2, "{model}: eval, replicas={replicas}");
        }
    }
}

#[test]
fn full_subset_replication_is_bit_identical_too() {
    // the widest exchange (every parameter trainable) over replicas
    let base = run_family("cls-base", Method::Full { ghost: true }, 1);
    let got = run_family("cls-base", Method::Full { ghost: true }, 2);
    assert_eq!(got.0, base.0);
    assert_eq!(got.1, base.1);
    assert_eq!(got.2, base.2);
}

/// Train with `replicas` workers, return (session, per-step batch sizes).
fn run_replicated(model: &str, method: Method, replicas: usize) -> (Session, Vec<usize>) {
    let mut engine = Engine::interpreter();
    let spec = family_spec(model, method, replicas);
    let task = engine.default_task(model).unwrap();
    let train = engine.dataset(model, task, spec.n_train, 31).unwrap();
    let mut session = engine.session(&spec).unwrap();
    let mut batches = Vec::new();
    for _ in 0..spec.steps {
        batches.push(session.run_step(&train).unwrap().batch);
    }
    (session, batches)
}

#[test]
fn measured_bitfit_traffic_is_over_100x_below_full_finetuning() {
    // same model, same seed => identical Poisson draws, so the byte ratio
    // is exactly the trainable-dimension ratio D / D_bias (§3.1)
    let (bitfit, batches_a) = run_replicated("cls-base", Method::BiTFiT, 2);
    let (full, batches_b) = run_replicated("cls-base", Method::Full { ghost: true }, 2);
    assert_eq!(batches_a, batches_b, "both runs must sample identical logical batches");
    let bitfit_comm = bitfit.comm_stats().expect("replicated run measures traffic");
    let full_comm = full.comm_stats().expect("replicated run measures traffic");
    assert!(bitfit_comm.total_bytes() > 0);
    let ratio = full_comm.total_bytes() as f64 / bitfit_comm.total_bytes() as f64;
    assert!(
        ratio >= 100.0,
        "BiTFiT must cut >= 100x per-exchange traffic: {} / {} = {ratio:.1}x",
        full_comm.total_bytes(),
        bitfit_comm.total_bytes()
    );
    // and the measured ratio is exactly the parameter-dimension ratio
    let want = full_comm.grad_len as f64 / bitfit_comm.grad_len as f64;
    assert!((ratio - want).abs() < 1e-9, "measured {ratio} vs dimension ratio {want}");
}

#[test]
fn wire_bytes_match_the_analytic_exchange_accounting() {
    // bytes_to_leader = (sum over steps of chunk count) * pt * 4;
    // bytes_from_leader = (active replicas per step) * pt * 4 summed
    let replicas = 2usize;
    let mut engine = Engine::interpreter();
    let spec = family_spec("cls-base", Method::BiTFiT, replicas);
    let task = engine.default_task("cls-base").unwrap();
    let train = engine.dataset("cls-base", task, spec.n_train, 31).unwrap();
    let mut session = engine.session(&spec).unwrap();
    let b = session.meta().batch;
    let pt = session.trainable_len();
    let ceil_div = |a: usize, b: usize| (a + b - 1) / b;
    let (mut want_up, mut want_down) = (0u64, 0u64);
    for _ in 0..spec.steps {
        let s = session.run_step(&train).unwrap();
        let chunks = ceil_div(s.batch, b);
        want_up += (chunks * pt * 4) as u64;
        // contiguous assignment: ceil(C/N) chunks per replica, so the
        // number of replicas that actually get traffic is ceil(C / per)
        let active =
            if chunks == 0 { 0 } else { ceil_div(chunks, ceil_div(chunks, replicas)) };
        want_down += (active * pt * 4) as u64;
    }
    let comm = session.comm_stats().unwrap();
    assert_eq!(comm.bytes_to_leader, want_up);
    assert_eq!(comm.bytes_from_leader, want_down);
    assert_eq!(comm.rounds, spec.steps as usize);
    assert_eq!(comm.grad_len, pt);
}
