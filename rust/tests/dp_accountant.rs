//! Accountant correctness battery: the RDP and GDP accountants and the
//! sigma calibrator are the layer a DP training system lives or dies on
//! (Yu et al. 2021; Li et al. 2022), so their analytic properties are
//! pinned here as tests rather than trusted:
//!
//! * epsilon is monotone **increasing** in `steps` and in `q`, and
//!   monotone **decreasing** in `sigma` — for both accountants;
//! * the two accountants agree within a documented tolerance band on the
//!   paper's table regimes (GDP's CLT approximation is the tighter one;
//!   we require `gdp <= 1.1 * rdp` and `rdp <= 3 * gdp`);
//! * `calibrate_sigma` round-trips: the sigma it returns spends at most
//!   the target epsilon and at least 95% of it, across a grid of
//!   (q, T, eps*).

use fastdp::dp::{calibrate, gdp, rdp};

const DELTA: f64 = 1e-5;

/// Representative (q, sigma, T) regimes from the paper's experiment
/// tables: GLUE-scale text classification (n ~ 67k, B = 1000, ~3 epochs),
/// E2E generation (n ~ 42k, B = 1024, ~10 epochs), CIFAR-scale vision
/// (n = 50k, B = 1000, ~3 epochs), and the classic Abadi MNIST regime.
fn paper_regimes() -> Vec<(f64, f64, u64)> {
    vec![
        (1000.0 / 67349.0, 0.85, 202),  // SST-2-like, eps ~ 8
        (1000.0 / 67349.0, 1.35, 202),  // SST-2-like, eps ~ 3
        (1024.0 / 42061.0, 0.9, 410),   // E2E-like, eps ~ 8
        (1000.0 / 50000.0, 1.0, 150),   // CIFAR-like
        (0.01, 4.0, 10_000),            // Abadi et al. MNIST
    ]
}

#[test]
fn epsilon_is_monotone_in_steps_for_both_accountants() {
    for &(q, sigma) in &[(0.005, 0.7), (0.02, 1.0), (0.1, 2.0)] {
        let steps = [50u64, 200, 800, 3200];
        for w in steps.windows(2) {
            let (t1, t2) = (w[0], w[1]);
            let (r1, r2) = (rdp::epsilon(q, sigma, t1, DELTA), rdp::epsilon(q, sigma, t2, DELTA));
            assert!(r2 > r1, "rdp not increasing in T: q={q} sigma={sigma} {t1}->{t2}: {r1} {r2}");
            let (g1, g2) = (gdp::epsilon(q, sigma, t1, DELTA), gdp::epsilon(q, sigma, t2, DELTA));
            assert!(g2 > g1, "gdp not increasing in T: q={q} sigma={sigma} {t1}->{t2}: {g1} {g2}");
        }
    }
}

#[test]
fn epsilon_is_monotone_in_q_for_both_accountants() {
    for &(sigma, steps) in &[(0.7f64, 200u64), (1.2, 1000), (2.5, 4000)] {
        let qs = [0.002, 0.01, 0.05, 0.2];
        for w in qs.windows(2) {
            let (q1, q2) = (w[0], w[1]);
            let (r1, r2) = (rdp::epsilon(q1, sigma, steps, DELTA), rdp::epsilon(q2, sigma, steps, DELTA));
            assert!(r2 > r1, "rdp not increasing in q: sigma={sigma} T={steps} {q1}->{q2}: {r1} {r2}");
            let (g1, g2) = (gdp::epsilon(q1, sigma, steps, DELTA), gdp::epsilon(q2, sigma, steps, DELTA));
            assert!(g2 > g1, "gdp not increasing in q: sigma={sigma} T={steps} {q1}->{q2}: {g1} {g2}");
        }
    }
}

#[test]
fn epsilon_is_monotone_decreasing_in_sigma_for_both_accountants() {
    for &(q, steps) in &[(0.005f64, 500u64), (0.02, 1000), (0.1, 200)] {
        let sigmas = [0.6, 0.9, 1.4, 2.2, 4.0];
        for w in sigmas.windows(2) {
            let (s1, s2) = (w[0], w[1]);
            let (r1, r2) = (rdp::epsilon(q, s1, steps, DELTA), rdp::epsilon(q, s2, steps, DELTA));
            assert!(r2 < r1, "rdp not decreasing in sigma: q={q} T={steps} {s1}->{s2}: {r1} {r2}");
            let (g1, g2) = (gdp::epsilon(q, s1, steps, DELTA), gdp::epsilon(q, s2, steps, DELTA));
            assert!(g2 < g1, "gdp not decreasing in sigma: q={q} T={steps} {s1}->{s2}: {g1} {g2}");
        }
    }
}

#[test]
fn accountants_agree_on_the_paper_regimes() {
    // Documented tolerance band: the GDP-CLT bound is expected to be the
    // tighter of the two but never wildly different — within 10% above RDP
    // at the top, within 3x below it at the bottom.  A violation means one
    // accountant regressed, not that the band is too tight.
    for (q, sigma, steps) in paper_regimes() {
        let e_rdp = rdp::epsilon(q, sigma, steps, DELTA);
        let e_gdp = gdp::epsilon(q, sigma, steps, DELTA);
        assert!(e_rdp.is_finite() && e_rdp > 0.0, "rdp degenerate at q={q} sigma={sigma} T={steps}");
        assert!(e_gdp.is_finite() && e_gdp > 0.0, "gdp degenerate at q={q} sigma={sigma} T={steps}");
        assert!(
            e_gdp <= e_rdp * 1.1 + 0.05,
            "gdp {e_gdp} above band vs rdp {e_rdp} (q={q} sigma={sigma} T={steps})"
        );
        assert!(
            e_rdp <= e_gdp * 3.0,
            "rdp {e_rdp} above band vs gdp {e_gdp} (q={q} sigma={sigma} T={steps})"
        );
    }
}

#[test]
fn streaming_accountant_matches_closed_form_on_paper_regimes() {
    for (q, sigma, steps) in paper_regimes() {
        // cap the loop so the 10k-step regime stays fast
        let steps = steps.min(500);
        let mut acc = rdp::RdpAccountant::new(DELTA);
        acc.steps(q, sigma, steps);
        let (streamed, _) = acc.epsilon();
        let closed = rdp::epsilon(q, sigma, steps, DELTA);
        assert!(
            (streamed - closed).abs() < 1e-9,
            "streamed {streamed} vs closed {closed} (q={q} sigma={sigma} T={steps})"
        );
    }
}

#[test]
fn calibrate_sigma_round_trips_across_the_grid() {
    for &q in &[0.005f64, 0.02, 0.1] {
        for &steps in &[100u64, 500, 2000] {
            for &target in &[1.0f64, 3.0, 8.0] {
                let sigma = calibrate::calibrate_sigma(q, steps, target, DELTA);
                assert!(sigma > 0.0 && sigma.is_finite());
                let spent = rdp::epsilon(q, sigma, steps, DELTA);
                assert!(
                    spent <= target + 1e-6,
                    "over budget: q={q} T={steps} eps*={target}: sigma={sigma} spends {spent}"
                );
                assert!(
                    spent >= target * 0.95,
                    "calibration too loose (must be within 5%): q={q} T={steps} \
                     eps*={target}: sigma={sigma} spends {spent}"
                );
            }
        }
    }
}

#[test]
fn calibrated_noise_is_monotone_in_the_budget() {
    // a tighter budget must always demand more noise, everywhere on the grid
    for &q in &[0.01f64, 0.05] {
        for &steps in &[200u64, 1000] {
            let s8 = calibrate::calibrate_sigma(q, steps, 8.0, DELTA);
            let s3 = calibrate::calibrate_sigma(q, steps, 3.0, DELTA);
            let s1 = calibrate::calibrate_sigma(q, steps, 1.0, DELTA);
            assert!(s1 > s3 && s3 > s8, "q={q} T={steps}: {s1} {s3} {s8}");
        }
    }
}
