//! End-to-end privacy audit gate (tier 1).
//!
//! Attacks real engine trainings and holds the accountant to its claim:
//!
//! * clean DP cells must come out **unflagged** — no attack or probe may
//!   witness more epsilon than the accountant claims;
//! * the non-private column must **memorise** its planted canary
//!   (verbatim greedy extraction) while the DP column must not — the
//!   audit has teeth only if the attack works when privacy is off;
//! * every `FaultMode` mutation of the mechanism must be **flagged** —
//!   the auditor is itself audited against known-broken mechanisms.

use fastdp::audit::{self, report, AuditSpec, EPS_LOW, EPS_MID};
use fastdp::dp::fault::FaultMode;
use fastdp::engine::Method;

#[test]
fn clean_cells_stay_within_the_accountants_claim() {
    let mut cells = vec![
        AuditSpec::cell(Method::BiTFiT, Some(EPS_LOW)),
        AuditSpec::cell(Method::Full { ghost: true }, Some(EPS_MID)),
    ];
    for cell in &mut cells {
        cell.trials = 6;
    }
    for outcome in audit::run_grid(&cells).expect("clean audit cells must run") {
        assert!(outcome.private, "{}: cell should be private", outcome.method);
        assert!(
            outcome.claimed_eps.is_finite() && outcome.claimed_eps > 0.0,
            "{}: accountant claimed eps {}",
            outcome.method,
            outcome.claimed_eps
        );
        assert!(
            outcome.empirical_eps <= outcome.claimed_eps,
            "{}: empirical eps {} exceeds claimed {}",
            outcome.method,
            outcome.empirical_eps,
            outcome.claimed_eps
        );
        assert!(!outcome.flagged, "{}: clean cell flagged", outcome.method);
        let mi = outcome.mi.expect("MI ran");
        assert_eq!(mi.trials, 6);
        let (noise, clip) = outcome.probes.expect("probes ran on a private cell");
        assert!(
            noise.ok,
            "{}: noise probe recovered sigma {} of claimed {}",
            outcome.method, noise.sigma_hat, noise.sigma_claimed
        );
        assert!(
            clip.ok,
            "{}: clip probe ratio {} (sum {} vs bound {})",
            outcome.method, clip.ratio, clip.sum_norm, clip.bound
        );
    }
}

#[test]
fn nondp_training_memorises_the_canary_and_dp_does_not() {
    let mut nondp = AuditSpec::cell(Method::Full { ghost: true }, None);
    let mut dp = AuditSpec::cell(Method::Full { ghost: true }, Some(EPS_LOW));
    for cell in [&mut nondp, &mut dp] {
        cell.trials = 0; // extraction only: no paired MI trainings
        cell.extraction = true;
    }

    let leaked = audit::run_cell(&nondp).expect("non-private cell runs");
    let guarded = audit::run_cell(&dp).expect("DP cell runs");

    let x = leaked.extraction.expect("extraction ran");
    assert_eq!(x.rank, 1, "true secret must outrank every decoy, got rank {}", x.rank);
    assert!(
        x.match_rate >= 0.5,
        "greedy decode reproduced only {:.0}% of the secret",
        100.0 * x.match_rate
    );
    assert!(x.extracted, "non-private training must leak its canary");
    assert!(!leaked.flagged, "a non-private cell makes no claim to violate");

    let g = guarded.extraction.expect("extraction ran");
    assert!(
        !g.extracted,
        "DP training leaked its canary (rank {}, match {})",
        g.rank, g.match_rate
    );
    assert!(
        g.match_rate < x.match_rate,
        "DP match rate {} not below non-private {}",
        g.match_rate,
        x.match_rate
    );
    assert!(!guarded.flagged, "clean DP cell flagged");
}

#[test]
fn every_fault_mode_is_flagged() {
    for fault in [FaultMode::SkipNoise, FaultMode::SkipClip, FaultMode::HalfSigma] {
        let mut cell = AuditSpec::cell(Method::BiTFiT, Some(EPS_LOW));
        cell.trials = 0; // the probes are the detector at test-sized budgets
        cell.fault = fault;
        let outcome = audit::run_cell(&cell).expect("faulted cell still runs");
        assert!(
            outcome.flagged,
            "{}: broken mechanism not flagged (empirical {} vs claimed {})",
            fault.name(),
            outcome.empirical_eps,
            outcome.claimed_eps
        );
        assert!(
            outcome.empirical_eps > outcome.claimed_eps,
            "{}: flag without an epsilon excess",
            fault.name()
        );
        let (noise, clip) = outcome.probes.expect("probes ran");
        assert!(
            !noise.ok || !clip.ok,
            "{}: no probe caught the fault (sigma_hat {}, clip ratio {})",
            fault.name(),
            noise.sigma_hat,
            clip.ratio
        );
    }
}

#[test]
fn audit_report_roundtrips_through_the_schema() {
    let mut cells = audit::quick_grid(2);
    for cell in &mut cells {
        cell.extraction = false; // schema test: keep the trainings minimal
    }
    let outcomes = audit::run_grid(&cells).expect("quick grid runs");
    let doc = report::audit_json(&outcomes, "tier1-smoke");
    report::validate_audit_json(&doc).expect("emitted document must validate");
    // the document is self-describing enough to re-find the grid
    assert!(doc.contains("\"privacy_audit\""));
    assert!(doc.contains("\"eps0.7\"") && doc.contains("\"inf\""));
}
