//! Transport must be invisible to the training trajectory: a replicated
//! session exchanging gradients over **TCP loopback with framed, CRC-checked
//! messages** produces bit-identical losses, parameters and eval metrics to
//! the in-process channel path — which is itself pinned to the
//! single-replica fused run (`tests/replica_determinism.rs`).  That holds
//! for replicas ∈ {1, 2, 4} and for both the fused and blocked kernel
//! tiers, because the wire carries the exact f32 bytes the channel would
//! have moved (`raw-f32le`) and the leader folds them in the same fixed
//! replica order.
//!
//! The `bf16` compact codec is allowed to perturb the trajectory — it
//! truncates mantissas on the wire — but only within a small bounded drift,
//! and it must buy its keep: >= 40% fewer upstream bytes per exchange.
//!
//! Everything here drives the public `JobSpec` API; transport, codec and
//! deadline flow through the spec exactly as `--transport` / `--wire` /
//! `--recv-timeout-ms` set them from the CLI.

use fastdp::engine::{
    Engine, InterpreterBackend, JobSpec, KernelMode, Method, OptimKind, TransportKind, WireCodec,
};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("fastdp-transport-{name}-{}", std::process::id()))
}

/// The replica-determinism family spec, extended with transport knobs.
fn spec(replicas: usize, kind: TransportKind, wire: WireCodec, steps: u64) -> JobSpec {
    JobSpec::builder("cls-base", Method::BiTFiT)
        .sigma(0.8)
        .delta(1e-5)
        .optim(OptimKind::Adam)
        .lr(5e-3)
        .clip_r(0.1)
        .batch(128)
        .steps(steps)
        .n_train(256)
        .seed(23)
        .replicas(replicas)
        .transport(kind)
        .wire(wire)
        .recv_timeout_ms(30_000)
        .build()
        .unwrap()
}

fn engine_for(tier: KernelMode) -> Engine {
    // pin the kernel tier explicitly so the matrix is what it claims to be,
    // whatever the ambient kernel-mode configuration says
    Engine::new(Box::new(InterpreterBackend::with_config(None, Some(tier))))
}

/// Train to completion; return (per-step loss bits, final param bits,
/// eval metric bits, upstream wire bytes).
fn run(
    tier: KernelMode,
    replicas: usize,
    kind: TransportKind,
    wire: WireCodec,
    steps: u64,
) -> (Vec<u64>, Vec<u32>, [u64; 2], u64) {
    let mut engine = engine_for(tier);
    let spec = spec(replicas, kind, wire, steps);
    let task = engine.default_task("cls-base").unwrap();
    let train = engine.dataset("cls-base", task, spec.n_train, 31).unwrap();
    let test = engine.dataset("cls-base", task, 64, 32).unwrap();
    let mut session = engine.session(&spec).unwrap();
    let mut losses = Vec::new();
    for _ in 0..spec.steps {
        losses.push(session.run_step(&train).unwrap().loss.to_bits());
    }
    let params: Vec<u32> = session.full_params().iter().map(|v| v.to_bits()).collect();
    let eval = session.evaluate(&test, 64).unwrap();
    let up = session.comm_stats().map(|c| c.bytes_to_leader).unwrap_or(0);
    (losses, params, [eval.metric_a.to_bits(), eval.metric_b.to_bits()], up)
}

#[test]
fn tcp_raw_is_bit_identical_to_channel_and_single_replica_on_both_tiers() {
    for tier in [KernelMode::Fused, KernelMode::Blocked] {
        // replicas = 1 never spawns a group: the in-process baseline
        let base = run(tier, 1, TransportKind::Channel, WireCodec::RawF32le, 4);
        for replicas in [2usize, 4] {
            let chan = run(tier, replicas, TransportKind::Channel, WireCodec::RawF32le, 4);
            let tcp = run(tier, replicas, TransportKind::Tcp, WireCodec::RawF32le, 4);
            for (got, label) in [(&chan, "channel"), (&tcp, "tcp")] {
                assert_eq!(got.0, base.0, "{tier:?} x{replicas} {label}: losses");
                assert_eq!(got.1, base.1, "{tier:?} x{replicas} {label}: params");
                assert_eq!(got.2, base.2, "{tier:?} x{replicas} {label}: eval");
            }
            // and the two transports account identical raw wire volume
            assert_eq!(chan.3, tcp.3, "{tier:?} x{replicas}: upstream bytes");
            assert!(tcp.3 > 0, "replicated runs must measure traffic");
        }
    }
}

#[test]
fn bf16_wire_tracks_raw_within_tolerance_and_cuts_upstream_bytes_by_40pct() {
    for kind in [TransportKind::Channel, TransportKind::Tcp] {
        // 3-step trajectories: the leader keeps f32 master weights, so the
        // wire truncation enters only through the gradient sums
        let raw = run(KernelMode::Fused, 2, kind, WireCodec::RawF32le, 3);
        let compact = run(KernelMode::Fused, 2, kind, WireCodec::Bf16, 3);

        // per-step losses within 1e-2 relative
        for (step, (a, b)) in raw.0.iter().zip(&compact.0).enumerate() {
            let (a, b) = (f64::from_bits(*a), f64::from_bits(*b));
            let rel = (a - b).abs() / a.abs().max(1e-12);
            assert!(rel <= 1e-2, "{kind:?} step {step}: loss {a} vs {b} (rel {rel:.2e})");
        }
        // final parameters within 1e-2 relative l2
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (a, b) in raw.1.iter().zip(&compact.1) {
            let (a, b) = (f32::from_bits(*a) as f64, f32::from_bits(*b) as f64);
            num += (a - b) * (a - b);
            den += a * a;
        }
        let rel = (num / den.max(1e-24)).sqrt();
        assert!(rel <= 1e-2, "{kind:?}: param drift rel-l2 {rel:.2e} exceeds 1e-2");

        // the compact codec must cut upstream bytes by at least 40%
        // (bf16 is exactly half of f32 on the wire)
        assert!(raw.3 > 0 && compact.3 > 0);
        let reduction = 1.0 - compact.3 as f64 / raw.3 as f64;
        assert!(
            reduction >= 0.40,
            "{kind:?}: bf16 cut upstream bytes by only {:.0}% ({} -> {})",
            reduction * 100.0,
            raw.3,
            compact.3
        );
    }
}

#[test]
fn snapshot_resume_over_tcp_is_bit_identical_to_the_uninterrupted_run() {
    // a worker (in fact the whole group) is lost mid-run; the session
    // snapshot restarts a fresh TCP replica group that must continue the
    // trajectory bit-for-bit — the engine-level face of `ReplicaGroup::rejoin`
    let steps = 4u64;
    let job = spec(2, TransportKind::Tcp, WireCodec::RawF32le, steps);
    let mut engine = engine_for(KernelMode::Fused);
    let task = engine.default_task("cls-base").unwrap();
    let train = engine.dataset("cls-base", task, job.n_train, 31).unwrap();
    let test = engine.dataset("cls-base", task, 64, 32).unwrap();

    let mut straight = engine.session(&job).unwrap();
    for _ in 0..steps {
        straight.run_step(&train).unwrap();
    }

    let mut first_half = engine.session(&job).unwrap();
    for _ in 0..2 {
        first_half.run_step(&train).unwrap();
    }
    let path = tmp("tcp-resume");
    first_half.save_state(&path).unwrap();
    drop(first_half); // the old replica group (and its sockets) die here

    let mut resumed = engine.resume_session(&job, &path).unwrap();
    assert_eq!(resumed.step(), 2);
    for _ in 2..steps {
        resumed.run_step(&train).unwrap();
    }
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(
        bits(&straight.full_params()),
        bits(&resumed.full_params()),
        "resumed TCP group must continue bit-identically"
    );
    let (pa, pb) = (straight.privacy_spent(), resumed.privacy_spent());
    assert_eq!(pa.epsilon.to_bits(), pb.epsilon.to_bits());
    let (ea, eb) = (straight.evaluate(&test, 64).unwrap(), resumed.evaluate(&test, 64).unwrap());
    assert_eq!(ea.metric_a.to_bits(), eb.metric_a.to_bits());
    assert_eq!(ea.metric_b.to_bits(), eb.metric_b.to_bits());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn transport_spec_knobs_survive_describe_and_validation() {
    let job = spec(2, TransportKind::Tcp, WireCodec::Bf16, 2);
    let text = job.describe();
    assert!(text.contains("transport    tcp wire bf16"), "{text}");
    assert!(text.contains("30000 ms"), "{text}");
    // single-replica jobs have no exchange, so no transport line
    let solo = spec(1, TransportKind::Tcp, WireCodec::Bf16, 2);
    assert!(!solo.describe().contains("transport"), "{}", solo.describe());
}
