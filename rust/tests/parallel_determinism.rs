//! The parallel fused interpreter path must be *bit-identical* — across
//! worker counts {1, 2, 8} and vs the legacy scalar kernels — for every
//! reference architecture (cls / lm / vit / cnn) and for train, eval and
//! decode steps.  Per-row work is reduced in fixed row order (see
//! `runtime::pool`), so any divergence here is a real kernel bug, not
//! floating-point reassociation noise.
//!
//! Inputs come from `bench::synth_step_inputs` — the same generator the
//! throughput harness's determinism probe uses — with the mask and clip
//! radius overridden to exercise masked rows and real DP clipping.

use fastdp::bench::synth_step_inputs;
use fastdp::engine::{Backend, InterpreterBackend, KernelMode, StepRunner};
use fastdp::util::tensor::Tensor;

/// Synthetic train inputs with the last 3 rows masked out (inactive-row
/// skip path) and a clip radius small enough that DP clipping fires.
fn train_inputs(backend: &InterpreterBackend, step: &dyn StepRunner, seed: u64) -> Vec<Tensor> {
    let meta = step.meta().clone();
    let b = meta.batch;
    let mut inputs = synth_step_inputs(backend, &meta, seed).unwrap();
    let mut mask = vec![1.0f32; b];
    for m in mask.iter_mut().skip(b.saturating_sub(3)) {
        *m = 0.0;
    }
    inputs[4] = Tensor::f32(vec![b], mask);
    inputs[5] = Tensor::scalar_f32(0.05);
    inputs
}

/// Run one step of `artifact` under (threads, mode) and return the f32 bit
/// patterns of every output tensor.
fn output_bits(artifact: &str, threads: usize, mode: KernelMode) -> Vec<Vec<u32>> {
    let mut backend = InterpreterBackend::with_config(Some(threads), Some(mode));
    let step = backend.load(artifact).unwrap();
    let inputs = train_inputs(&backend, step.as_ref(), 29);
    let out = step.run(&inputs).unwrap();
    out.iter().map(|t| t.as_f32().iter().map(|v| v.to_bits()).collect()).collect()
}

/// One train artifact per architecture family, plus full-subset variants
/// that exercise the embedding/enc-weight backward paths.
const TRAIN_ARTIFACTS: &[&str] = &[
    "cls-base__dp-bitfit",
    "cls-base__dp-full-opacus",
    "lm-small__dp-bitfit",
    "lm-small__nondp-full",
    "vit-c10__dp-lastlayer",
    "vit-c10__dp-full-ghost",
    "cnn-small__dp-bitfit",
    "cnn-small-bias__dp-bitfit-add",
];

#[test]
fn train_outputs_bit_identical_across_thread_counts() {
    for artifact in TRAIN_ARTIFACTS {
        let base = output_bits(artifact, 1, KernelMode::Fused);
        for threads in [2usize, 8] {
            let got = output_bits(artifact, threads, KernelMode::Fused);
            assert_eq!(base, got, "{artifact}: fused threads=1 vs threads={threads}");
        }
    }
}

#[test]
fn fused_outputs_bit_identical_to_legacy_scalar_path() {
    for artifact in TRAIN_ARTIFACTS {
        let fused = output_bits(artifact, 8, KernelMode::Fused);
        let legacy = output_bits(artifact, 1, KernelMode::Legacy);
        assert_eq!(fused, legacy, "{artifact}: fused vs legacy");
    }
}

#[test]
fn eval_outputs_bit_identical_across_thread_counts() {
    for model in ["cls-base", "lm-small", "vit-c10", "cnn-small"] {
        let artifact = format!("{model}__eval");
        let run = |threads: usize| -> Vec<Vec<u32>> {
            let mut backend = InterpreterBackend::with_threads(threads);
            let step = backend.load(&artifact).unwrap();
            let meta = step.meta().clone();
            let inputs = synth_step_inputs(&backend, &meta, 31).unwrap();
            let out = step.run(&inputs).unwrap();
            out.iter().map(|t| t.as_f32().iter().map(|v| v.to_bits()).collect()).collect()
        };
        let base = run(1);
        for threads in [2usize, 8] {
            assert_eq!(base, run(threads), "{artifact}: eval threads=1 vs {threads}");
        }
    }
}

#[test]
fn decode_outputs_bit_identical_across_thread_counts() {
    let run = |threads: usize| -> Vec<u32> {
        let mut backend = InterpreterBackend::with_threads(threads);
        let step = backend.load("lm-small__decode").unwrap();
        let meta = step.meta().clone();
        let full = backend.init_params("lm-small").unwrap();
        let b = meta.batch;
        let t = meta.inputs[2].shape[1];
        let x: Vec<i32> = (0..b * t).map(|i| (i % 383) as i32 + 1).collect();
        let pos: Vec<i32> = (0..b as i32).map(|i| 3 + i).collect();
        let out = step
            .run(&[
                Tensor::f32(vec![0], vec![]),
                Tensor::f32(vec![full.len()], full),
                Tensor::i32(vec![b, t], x),
                Tensor::i32(vec![b], pos),
            ])
            .unwrap();
        out[0].as_f32().iter().map(|v| v.to_bits()).collect()
    };
    let base = run(1);
    for threads in [2usize, 8] {
        assert_eq!(base, run(threads), "decode threads=1 vs {threads}");
    }
}

#[test]
fn thread_override_and_env_defaults_agree() {
    // a backend with no thread override resolves FASTDP_THREADS when
    // loading; an explicit override must produce the same bits regardless.
    // The kernel tier is pinned to fused: the ghost tier is only
    // tolerance-equal to fused (see tests/ghost_equivalence.rs), so an
    // env-resolved kernel mode would make this bit-compare meaningless
    // under the ci.sh FASTDP_KERNELS matrix.
    let a = output_bits("cls-base__dp-bitfit", 1, KernelMode::Fused);
    let b = output_bits("cls-base__dp-bitfit", 8, KernelMode::Fused);
    assert_eq!(a, b);
    let mut backend = InterpreterBackend::with_config(None, Some(KernelMode::Fused));
    let step = backend.load("cls-base__dp-bitfit").unwrap();
    let inputs = train_inputs(&backend, step.as_ref(), 29);
    let out = step.run(&inputs).unwrap();
    let bits: Vec<Vec<u32>> =
        out.iter().map(|t| t.as_f32().iter().map(|v| v.to_bits()).collect()).collect();
    assert_eq!(a, bits);
}
