//! Frame- and codec-level robustness of the replica transport: every way a
//! peer can misbehave on the wire — truncating a frame, corrupting bytes,
//! advertising an absurd length, or disconnecting mid-exchange — must
//! surface as a **typed** [`FrameError`], never a panic, a hang, or a
//! silently short read.  The second half pins the wire codecs themselves:
//! `raw-f32le` round-trips bitwise (it is the determinism contract), and
//! `bf16` is an idempotent, sign/Inf/NaN-correct rounding with bounded
//! relative error.
//!
//! These tests speak raw `TcpStream`/`TcpListener` on purpose: fault
//! injection has to sit *below* the transport layer to prove the layer
//! defends itself.  (The `net-io` lint rule only polices `src/`, exactly so
//! tests like this one can exist.)

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use fastdp::coordinator::transport::{
    read_frame, write_frame, FrameError, WireCodec, FRAME_MAGIC, MAX_FRAME,
};

/// Serialize one well-formed frame into a byte vector.
fn framed(payload: &[u8]) -> Vec<u8> {
    let mut buf = Vec::new();
    write_frame(&mut buf, payload).expect("Vec<u8> writes are infallible");
    buf
}

#[test]
fn well_formed_frames_round_trip() {
    for payload in [&b""[..], &b"x"[..], &[0u8; 4096][..], b"FDPF"] {
        let buf = framed(payload);
        // magic | len u32 LE | payload | crc32 LE
        assert_eq!(&buf[..4], &FRAME_MAGIC);
        assert_eq!(buf.len(), 8 + payload.len() + 4);
        let got = read_frame(&mut &buf[..]).expect("round trip");
        assert_eq!(got, payload);
    }
}

#[test]
fn truncated_stream_is_a_typed_closed_error_at_every_cut_point() {
    let buf = framed(b"bias gradient payload");
    // cut inside the header, inside the payload and inside the trailing CRC
    for cut in [0, 3, 7, 8, 12, buf.len() - 1] {
        let err = read_frame(&mut &buf[..cut]).expect_err("truncation must error");
        assert!(
            matches!(err, FrameError::Closed(_)),
            "cut at {cut}: want Closed, got {err:?}"
        );
    }
}

#[test]
fn corrupted_payload_or_crc_is_a_typed_corrupt_error() {
    let clean = framed(b"0123456789abcdef");
    // flip one bit in every byte position after the magic: length corruption
    // shows up as Closed/TooLarge (the stream desyncs), payload and CRC
    // corruption must be caught by the checksum — never returned as data
    for i in 4..clean.len() {
        let mut buf = clean.clone();
        buf[i] ^= 0x01;
        match read_frame(&mut &buf[..]) {
            Ok(payload) => panic!("byte {i} flipped but payload {payload:?} was accepted"),
            Err(FrameError::Closed(_)) | Err(FrameError::TooLarge(_)) => {
                assert!((4..8).contains(&i), "byte {i}: only length bytes may desync");
            }
            Err(FrameError::Corrupt(_)) => {}
            Err(other) => panic!("byte {i}: unexpected error {other:?}"),
        }
    }
}

#[test]
fn bad_magic_is_rejected_before_the_payload_is_read() {
    let mut buf = framed(b"hello");
    buf[0] = b'X';
    let err = read_frame(&mut &buf[..]).expect_err("bad magic");
    assert!(matches!(err, FrameError::Corrupt(_)), "{err:?}");
}

#[test]
fn oversized_length_prefix_is_rejected_without_allocating() {
    // a hostile peer advertises a multi-gigabyte payload; the reader must
    // refuse from the 8-byte header alone (this test would OOM otherwise)
    for len in [MAX_FRAME as u32 + 1, u32::MAX] {
        let mut buf = Vec::new();
        buf.extend_from_slice(&FRAME_MAGIC);
        buf.extend_from_slice(&len.to_le_bytes());
        let err = read_frame(&mut &buf[..]).expect_err("oversized length");
        match err {
            FrameError::TooLarge(n) => assert_eq!(n, len as usize),
            other => panic!("want TooLarge, got {other:?}"),
        }
    }
}

#[test]
fn mid_exchange_disconnect_over_tcp_is_closed_not_a_hang() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    let peer = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).expect("connect");
        // one good frame, then half of a second frame, then a hard close
        write_frame(&mut s, b"good").expect("first frame");
        let partial = framed(b"this frame will be cut off mid-payload");
        s.write_all(&partial[..partial.len() / 2]).expect("partial write");
        // dropping the stream closes the socket mid-frame
    });
    let (mut conn, _) = listener.accept().expect("accept");
    conn.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
    assert_eq!(read_frame(&mut conn).expect("intact frame"), b"good");
    let err = read_frame(&mut conn).expect_err("peer died mid-frame");
    assert!(matches!(err, FrameError::Closed(_)), "{err:?}");
    peer.join().expect("peer thread");
}

#[test]
fn slow_peer_surfaces_as_timeout_on_a_deadlined_socket() {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr");
    // the peer connects but never writes — a classic straggler
    let peer = std::thread::spawn(move || {
        let s = TcpStream::connect(addr).expect("connect");
        let mut one = [0u8; 1];
        // park until the leader hangs up (read_exact errors on close)
        let _ = (&s).read_exact(&mut one);
    });
    let (mut conn, _) = listener.accept().expect("accept");
    conn.set_read_timeout(Some(Duration::from_millis(50))).expect("read timeout");
    let err = read_frame(&mut conn).expect_err("no bytes within the deadline");
    assert!(matches!(err, FrameError::Timeout), "{err:?}");
    drop(conn);
    peer.join().expect("peer thread");
}

// ---------------------------------------------------------------- codecs --

/// Deterministic xorshift64* stream — no ambient randomness in tests.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// A float in roughly [-8, 8) — the magnitude band of clipped gradient
    /// sums and bias parameters.
    fn f32(&mut self) -> f32 {
        ((self.next() >> 40) as f32 / (1u64 << 20) as f32 - 0.5) * 16.0
    }
}

#[test]
fn raw_f32le_round_trip_is_bitwise_for_every_bit_pattern_class() {
    let specials = [
        0.0f32,
        -0.0,
        1.0,
        -1.0,
        f32::MIN_POSITIVE,
        f32::MIN_POSITIVE / 2.0, // subnormal
        f32::MAX,
        f32::MIN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
    ];
    let mut rng = Rng(0x5eed_0001);
    let mut vals: Vec<f32> = specials.to_vec();
    vals.extend((0..4096).map(|_| rng.f32()));
    let bytes = WireCodec::RawF32le.encode(&vals);
    assert_eq!(bytes.len(), vals.len() * WireCodec::RawF32le.bytes_per_elem());
    let back = WireCodec::RawF32le.decode(&bytes).expect("decode");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&vals), bits(&back), "raw-f32le must be a bitwise identity");
}

#[test]
fn bf16_round_trip_is_idempotent_with_bounded_relative_error() {
    let mut rng = Rng(0xb16b_00b5);
    let vals: Vec<f32> = (0..4096).map(|_| rng.f32()).collect();
    let bytes = WireCodec::Bf16.encode(&vals);
    assert_eq!(bytes.len(), vals.len() * WireCodec::Bf16.bytes_per_elem());
    assert_eq!(bytes.len() * 2, vals.len() * 4, "bf16 must halve the wire");
    let once = WireCodec::Bf16.decode(&bytes).expect("decode");
    for (v, o) in vals.iter().zip(&once) {
        // round-to-nearest-even on an 8-bit mantissa: rel err <= 2^-8
        let rel = (v - o).abs() / v.abs().max(f32::MIN_POSITIVE);
        assert!(rel <= 1.0 / 256.0 + 1e-7, "value {v} decoded to {o} (rel {rel})");
        assert_eq!(v.is_sign_negative(), o.is_sign_negative(), "sign of {v}");
    }
    // idempotence: a decoded value re-encodes to the identical bytes, so a
    // relay through any number of bf16 hops is lossless after the first
    let twice = WireCodec::Bf16.decode(&WireCodec::Bf16.encode(&once)).expect("decode twice");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&once), bits(&twice), "bf16 must be idempotent after one hop");
}

#[test]
fn bf16_preserves_infinities_zeroes_and_canonicalizes_nan() {
    let vals = [0.0f32, -0.0, f32::INFINITY, f32::NEG_INFINITY, f32::NAN, -f32::NAN];
    let back = WireCodec::Bf16.decode(&WireCodec::Bf16.encode(&vals)).expect("decode");
    assert_eq!(back[0].to_bits(), 0.0f32.to_bits());
    assert_eq!(back[1].to_bits(), (-0.0f32).to_bits());
    assert_eq!(back[2], f32::INFINITY);
    assert_eq!(back[3], f32::NEG_INFINITY);
    assert!(back[4].is_nan() && !back[4].is_sign_negative(), "NaN stays NaN");
    assert!(back[5].is_nan() && back[5].is_sign_negative(), "NaN keeps its sign");
}

#[test]
fn codec_decode_rejects_misaligned_payloads() {
    assert!(WireCodec::RawF32le.decode(&[0u8; 7]).is_err(), "raw needs 4-byte multiples");
    assert!(WireCodec::Bf16.decode(&[0u8; 3]).is_err(), "bf16 needs 2-byte multiples");
    assert!(WireCodec::RawF32le.decode(&[]).expect("empty is fine").is_empty());
    assert!(WireCodec::Bf16.decode(&[]).expect("empty is fine").is_empty());
}

#[test]
fn frames_carry_codec_payloads_over_a_real_socket_unchanged() {
    // end-to-end: encode with each codec, frame it, push it through a real
    // loopback socket, read it back, decode — the composition the replica
    // exchange actually uses
    let mut rng = Rng(0xdead_beef);
    let vals: Vec<f32> = (0..513).map(|_| rng.f32()).collect();
    for codec in [WireCodec::RawF32le, WireCodec::Bf16] {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().expect("local addr");
        let payload = codec.encode(&vals);
        let sent = payload.clone();
        let peer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).expect("connect");
            write_frame(&mut s, &sent).expect("send");
        });
        let (mut conn, _) = listener.accept().expect("accept");
        conn.set_read_timeout(Some(Duration::from_secs(10))).expect("read timeout");
        let got = read_frame(&mut conn).expect("framed payload");
        assert_eq!(got, payload, "{} payload must survive the socket", codec.name());
        let decoded = codec.decode(&got).expect("decode");
        assert_eq!(decoded.len(), vals.len());
        peer.join().expect("peer thread");
    }
}
