//! Tier-1 gate: the full `fastdp-lint` pass over the real source tree
//! must report zero findings.
//!
//! This is what gives the lint teeth — deleting a `// SAFETY:` comment,
//! adding a raw `std::env::var` read outside `runtime/env.rs`, or routing
//! an unclipped per-sample gradient into a sink breaks `cargo test` (and
//! therefore every ci.sh cell), not just the optional lint stage.

use std::path::Path;

#[test]
fn lint_is_clean_on_the_real_tree() {
    // CARGO_MANIFEST_DIR is rust/; the repo root is its parent.
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a repo root above it");
    let cfg = fastdp_lint::repo_config(repo_root);
    let rep = fastdp_lint::run(&cfg);
    assert!(
        rep.findings.is_empty(),
        "fastdp-lint found {} violation(s):\n{}",
        rep.findings.len(),
        fastdp_lint::render(&rep.findings)
    );
    // a scan that silently saw nothing would also "pass" — guard scope
    assert!(
        rep.files_scanned > 20,
        "suspiciously few files scanned ({}) — did the tree layout move?",
        rep.files_scanned
    );
}

#[test]
fn allow_annotations_are_visible_in_the_report() {
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap();
    let rep = fastdp_lint::run(&fastdp_lint::repo_config(repo_root));
    // the replica-worker spawn in coordinator/distributed.rs is the one
    // sanctioned thread-spawn site outside the pool; it must surface as
    // an allowed finding, not vanish
    assert!(
        rep.allowed.iter().any(|f| f.rule == "thread-spawn"
            && f.file == "coordinator/distributed.rs"),
        "expected the allowed replica-worker spawn in the report: {:?}",
        rep.allowed
    );
}
