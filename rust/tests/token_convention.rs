//! The crate-wide padding-token convention, asserted across all four
//! kernel tiers (see `fused::pool_tokens` / `fused::load_token`):
//!
//! * canonical id 0 is the **padding row**: negative ids, 0 itself, and
//!   exact multiples of `vocab` all canonicalize to it;
//! * Cls pooling *skips* padding tokens — they contribute nothing to the
//!   pooled mean, its normalizer, or the embedding gradient;
//! * single-token loads (Lm) cannot skip, so padding ids load the
//!   padding row's embedding;
//! * out-of-range ids wrap modulo the vocabulary.
//!
//! The regression: `pool_tokens` used to keep `t > 0` tokens whose id
//! wrapped onto 0 (counting padding in the mean), while `load_token`
//! clamped negatives onto row 0 — two conventions.  These tests pin the
//! unified one on every tier: fused == legacy bitwise, ghost/blocked
//! within their documented tolerance, and padding spelled as `0`, `-k`
//! or `k * vocab` is indistinguishable.

use fastdp::bench::synth_step_inputs;
use fastdp::engine::{Backend, InterpreterBackend, KernelMode, StepRunner};
use fastdp::kernels::fused::canon_token;
use fastdp::util::tensor::Tensor;

const RTOL: f32 = 1e-4;
const ATOL: f32 = 1e-6;

/// Inputs for `artifact` with the token tensor replaced by `toks`.
fn inputs_with_tokens(
    backend: &InterpreterBackend,
    step: &dyn StepRunner,
    toks: Vec<i32>,
) -> Vec<Tensor> {
    let meta = step.meta().clone();
    let mut inputs = synth_step_inputs(backend, &meta, 77).unwrap();
    let shape = meta.inputs[2].shape.clone();
    assert_eq!(shape.iter().product::<usize>(), toks.len(), "token tensor shape");
    inputs[2] = Tensor::i32(shape, toks);
    inputs[5] = Tensor::scalar_f32(0.05); // clipping really fires
    inputs
}

fn run(artifact: &str, mode: KernelMode, toks: &[i32]) -> Vec<Tensor> {
    let mut backend = InterpreterBackend::with_config(Some(2), Some(mode));
    backend.set_block_rows(Some(4));
    let step = backend.load(artifact).unwrap();
    let inputs = inputs_with_tokens(&backend, step.as_ref(), toks.to_vec());
    step.run(&inputs).unwrap()
}

fn bits_of(out: &[Tensor]) -> Vec<Vec<u32>> {
    out.iter().map(|t| t.as_f32().iter().map(|v| v.to_bits()).collect()).collect()
}

fn assert_close(a: &[Tensor], b: &[Tensor], tag: &str) {
    for (ti, (ta, tb)) in a.iter().zip(b).enumerate() {
        for (i, (&x, &y)) in ta.as_f32().iter().zip(tb.as_f32()).enumerate() {
            let scale = x.abs().max(y.abs()).max(ATOL);
            assert!((x - y).abs() / scale < RTOL, "{tag}: output {ti}[{i}]: {x} vs {y}");
        }
    }
}

/// A token stream exercising every edge: negatives, zero, `vocab`,
/// multiples and near-multiples of `vocab`, plus ordinary ids.
fn edge_tokens(n: usize, vocab: i32) -> Vec<i32> {
    let specials =
        [-5, 0, vocab, -1, 2 * vocab, vocab + 3, vocab - 1, 1, i32::MAX % vocab, 7];
    (0..n).map(|i| specials[i % specials.len()]).collect()
}

#[test]
fn canon_token_defines_the_convention() {
    let vocab = 512usize;
    assert_eq!(canon_token(-5, vocab), 0, "negatives are padding");
    assert_eq!(canon_token(0, vocab), 0, "zero is padding");
    assert_eq!(canon_token(512, vocab), 0, "vocab wraps onto padding");
    assert_eq!(canon_token(1024, vocab), 0, "multiples wrap onto padding");
    assert_eq!(canon_token(515, vocab), 3, "out-of-range ids wrap");
    assert_eq!(canon_token(511, vocab), 511, "in-range ids pass through");
}

#[test]
fn edge_token_ids_agree_across_all_tiers() {
    // cls pools (skip path), lm loads per position (clamp path); full
    // subsets exercise the embedding gradient, bitfit the bias-only path
    for (artifact, vocab) in [
        ("cls-base__dp-full-opacus", 512),
        ("cls-base__dp-bitfit", 512),
        ("lm-small__dp-full-opacus", 384),
        ("lm-small__dp-bitfit", 384),
    ] {
        let mut backend = InterpreterBackend::new();
        let step = backend.load(artifact).unwrap();
        let n = step.meta().inputs[2].elements();
        let toks = edge_tokens(n, vocab);
        let fused = run(artifact, KernelMode::Fused, &toks);
        let legacy = run(artifact, KernelMode::Legacy, &toks);
        assert_eq!(bits_of(&fused), bits_of(&legacy), "{artifact}: fused vs legacy");
        assert_close(&fused, &run(artifact, KernelMode::Ghost, &toks), artifact);
        assert_close(&fused, &run(artifact, KernelMode::Blocked, &toks), artifact);
        // nothing exploded on the edge ids
        assert!(fused.iter().all(|t| t.as_f32().iter().all(|v| v.is_finite())), "{artifact}");
    }
}

#[test]
fn padding_spellings_are_indistinguishable_in_pooling() {
    // same row content, padding written three different ways: id 0, a
    // negative id, and an exact multiple of vocab — every tier must
    // produce bit-identical outputs for its own run
    let artifact = "cls-base__dp-full-opacus";
    let mut backend = InterpreterBackend::new();
    let step = backend.load(artifact).unwrap();
    let shape = step.meta().inputs[2].shape.clone();
    let (b, t) = (shape[0], shape[1]);
    let content = |pad: i32| -> Vec<i32> {
        (0..b * t)
            .map(|i| {
                // half of each row is real tokens, half padding
                if (i % t) < t / 2 {
                    1 + (i % 300) as i32
                } else {
                    pad
                }
            })
            .collect()
    };
    for mode in
        [KernelMode::Fused, KernelMode::Legacy, KernelMode::Ghost, KernelMode::Blocked]
    {
        let zero = bits_of(&run(artifact, mode, &content(0)));
        assert_eq!(zero, bits_of(&run(artifact, mode, &content(-7))), "{mode:?}: -7 vs 0");
        assert_eq!(zero, bits_of(&run(artifact, mode, &content(512))), "{mode:?}: 512 vs 0");
        assert_eq!(zero, bits_of(&run(artifact, mode, &content(1024))), "{mode:?}: 1024 vs 0");
    }
}

#[test]
fn all_padding_rows_are_well_defined() {
    // a row of nothing but padding pools to zero features: the forward
    // pass sees biases only, gradients stay finite, and the embedding
    // receives no scatter from that row
    let artifact = "cls-base__dp-full-opacus";
    let mut backend = InterpreterBackend::new();
    let step = backend.load(artifact).unwrap();
    let shape = step.meta().inputs[2].shape.clone();
    let (b, t) = (shape[0], shape[1]);
    // row 0 entirely padding (mixed spellings), the rest ordinary
    let toks: Vec<i32> = (0..b * t)
        .map(|i| {
            if i < t {
                [0, -3, 512][i % 3]
            } else {
                1 + (i % 300) as i32
            }
        })
        .collect();
    let fused = run(artifact, KernelMode::Fused, &toks);
    let legacy = run(artifact, KernelMode::Legacy, &toks);
    assert_eq!(bits_of(&fused), bits_of(&legacy), "fused vs legacy");
    assert_close(&fused, &run(artifact, KernelMode::Ghost, &toks), "ghost");
    assert_close(&fused, &run(artifact, KernelMode::Blocked, &toks), "blocked");
    assert!(fused.iter().all(|t| t.as_f32().iter().all(|v| v.is_finite())));
    // the all-padding row still has a (bias-driven) gradient and norm
    assert!(fused[2].as_f32()[0] > 0.0, "all-padding row norm");
}
