//! Integration tests over the PJRT runtime + real AOT artifacts.
//!
//! These tests require `make artifacts` to have run (they are skipped with a
//! message if `artifacts/manifest.json` is absent, so `cargo test` works in
//! a fresh checkout).

use fastdp::runtime::Runtime;
use fastdp::util::rng::ChaChaRng;
use fastdp::util::tensor::Tensor;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn batch_inputs(rng: &mut ChaChaRng, b: usize, t: usize, vocab: i32, n_cls: i32) -> (Tensor, Tensor) {
    let x: Vec<i32> = (0..b * t).map(|_| 1 + (rng.next_u32() as i32).rem_euclid(vocab - 1)).collect();
    let y: Vec<i32> = (0..b).map(|_| (rng.next_u32() as i32).rem_euclid(n_cls)).collect();
    (Tensor::i32(vec![b, t], x), Tensor::i32(vec![b], y))
}

#[test]
fn bitfit_step_runs_and_is_finite() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let exe = rt.load("cls-base__dp-bitfit").unwrap();
    let meta = exe.meta.clone();
    assert_eq!(meta.step, "train");
    let layout = rt.layout(&meta.model).unwrap();
    let full = rt.init_params(&meta.model).unwrap();
    assert_eq!(full.len(), layout.n_params);
    let (frozen, train) = layout.split(&full, &meta.subset);
    assert_eq!(frozen.len(), meta.pf);
    assert_eq!(train.len(), meta.pt);

    let b = meta.batch;
    let mut rng = ChaChaRng::new(0, 0);
    let (x, y) = batch_inputs(&mut rng, b, 64, 512, 4);
    let out = exe
        .run(&[
            Tensor::f32(vec![meta.pf], frozen.clone()),
            Tensor::f32(vec![meta.pt], train.clone()),
            x,
            y,
            Tensor::f32(vec![b], vec![1.0; b]),
            Tensor::scalar_f32(1.0),
        ])
        .unwrap();
    assert_eq!(out.len(), 3);
    let loss = out[0].item_f32();
    assert!(loss.is_finite() && loss > 0.0, "loss = {loss}");
    let grad = out[1].as_f32();
    assert_eq!(grad.len(), meta.pt);
    assert!(grad.iter().all(|g| g.is_finite()));
    assert!(grad.iter().any(|&g| g != 0.0), "gradient all zero");
    // per-sample clipped contributions have norm <= R each; sum <= B * R
    let gnorm = fastdp::util::tensor::l2_norm(grad);
    assert!(gnorm <= b as f64 + 1e-3, "clipped grad norm {gnorm} > B*R");
    let sq = out[2].as_f32();
    assert!(sq.iter().all(|&s| s.is_finite() && s >= 0.0));
}

#[test]
fn mask_zeroes_padded_examples() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let exe = rt.load("cls-base__dp-bitfit").unwrap();
    let meta = exe.meta.clone();
    let layout = rt.layout(&meta.model).unwrap();
    let full = rt.init_params(&meta.model).unwrap();
    let (frozen, train) = layout.split(&full, &meta.subset);
    let b = meta.batch;
    let mut rng = ChaChaRng::new(1, 0);
    let (x, y) = batch_inputs(&mut rng, b, 64, 512, 4);

    let run = |mask: Vec<f32>| {
        exe.run(&[
            Tensor::f32(vec![meta.pf], frozen.clone()),
            Tensor::f32(vec![meta.pt], train.clone()),
            x.clone(),
            y.clone(),
            Tensor::f32(vec![b], mask),
            Tensor::scalar_f32(1.0),
        ])
        .unwrap()
    };
    // all-zero mask => zero loss and zero gradient
    let out = run(vec![0.0; b]);
    assert_eq!(out[0].item_f32(), 0.0);
    assert!(out[1].as_f32().iter().all(|&g| g == 0.0));
    // half mask: grad must differ from full mask (mask participates)
    let full_out = run(vec![1.0; b]);
    let mut half = vec![1.0; b];
    for m in half.iter_mut().skip(b / 2) {
        *m = 0.0;
    }
    let half_out = run(half);
    assert_ne!(full_out[1].as_f32(), half_out[1].as_f32());
}

#[test]
fn training_reduces_loss_sgd() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let exe = rt.load("cls-base__nondp-bitfit").unwrap();
    let meta = exe.meta.clone();
    let layout = rt.layout(&meta.model).unwrap();
    let full = rt.init_params(&meta.model).unwrap();
    let (frozen, mut train) = layout.split(&full, &meta.subset);
    let b = meta.batch;
    let mut rng = ChaChaRng::new(2, 0);
    let (x, y) = batch_inputs(&mut rng, b, 64, 512, 4);
    let frozen_t = Tensor::f32(vec![meta.pf], frozen);
    let mask = Tensor::f32(vec![b], vec![1.0; b]);
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..12 {
        let out = exe
            .run(&[
                frozen_t.clone(),
                Tensor::f32(vec![meta.pt], train.clone()),
                x.clone(),
                y.clone(),
                mask.clone(),
                Tensor::scalar_f32(1.0),
            ])
            .unwrap();
        last = out[0].item_f32() / b as f32;
        first.get_or_insert(last);
        let grad = out[1].as_f32();
        for (p, g) in train.iter_mut().zip(grad) {
            *p -= 0.05 * g / b as f32;
        }
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.9,
        "loss did not decrease: {first} -> {last}"
    );
}

#[test]
fn device_resident_frozen_params_match_host_path() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let exe = rt.load("cls-base__dp-bitfit").unwrap();
    let meta = exe.meta.clone();
    let layout = rt.layout(&meta.model).unwrap();
    let full = rt.init_params(&meta.model).unwrap();
    let (frozen, train) = layout.split(&full, &meta.subset);
    let b = meta.batch;
    let mut rng = ChaChaRng::new(3, 0);
    let (x, y) = batch_inputs(&mut rng, b, 64, 512, 4);
    let frozen_t = Tensor::f32(vec![meta.pf], frozen);
    let train_t = Tensor::f32(vec![meta.pt], train);
    let mask = Tensor::f32(vec![b], vec![1.0; b]);
    let r = Tensor::scalar_f32(1.0);

    let host_out = exe
        .run(&[frozen_t.clone(), train_t.clone(), x.clone(), y.clone(), mask.clone(), r.clone()])
        .unwrap();
    let dev = exe.upload(&frozen_t).unwrap();
    let mixed_out = exe
        .run_mixed(
            &[&dev],
            &[None, Some(&train_t), Some(&x), Some(&y), Some(&mask), Some(&r)],
        )
        .unwrap();
    assert_eq!(host_out[0].item_f32(), mixed_out[0].item_f32());
    assert_eq!(host_out[1].as_f32(), mixed_out[1].as_f32());
}

#[test]
fn eval_and_decode_artifacts_run() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    // eval on cls-base
    let exe = rt.load("cls-base__eval").unwrap();
    let meta = exe.meta.clone();
    let full = rt.init_params(&meta.model).unwrap();
    let b = meta.batch;
    let mut rng = ChaChaRng::new(4, 0);
    let (x, y) = batch_inputs(&mut rng, b, 64, 512, 4);
    let out = exe
        .run(&[
            Tensor::f32(vec![0], vec![]),
            Tensor::f32(vec![full.len()], full),
            x,
            y,
            Tensor::f32(vec![b], vec![1.0; b]),
        ])
        .unwrap();
    assert!(out[0].item_f32().is_finite());
    assert!(out[1].item_f32() >= 0.0 && out[1].item_f32() <= b as f32);

    // decode on lm-small
    let exe = rt.load("lm-small__decode").unwrap();
    let meta = exe.meta.clone();
    let full = rt.init_params(&meta.model).unwrap();
    let b = meta.batch;
    let x: Vec<i32> = (0..b * 48).map(|i| (i % 383) as i32 + 1).collect();
    let pos: Vec<i32> = (0..b as i32).map(|i| 5 + i).collect();
    let out = exe
        .run(&[
            Tensor::f32(vec![0], vec![]),
            Tensor::f32(vec![full.len()], full),
            Tensor::i32(vec![b, 48], x),
            Tensor::i32(vec![b], pos),
        ])
        .unwrap();
    assert_eq!(out[0].shape, vec![b, 384]);
    assert!(out[0].as_f32().iter().all(|v| v.is_finite()));
}
