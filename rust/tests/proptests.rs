//! Property-based tests over the coordinator's invariants (a minimal
//! seeded-random framework — no proptest crate in this environment; failing
//! cases print their seed so they replay deterministically).

use fastdp::coordinator::checkpoint::Checkpoint;
use fastdp::coordinator::optim::{OptimKind, Optimizer};
use fastdp::dp::clip::{clip_factor, clip_in_place, ClipMode};
use fastdp::dp::{calibrate, gdp, rdp};
use fastdp::runtime::{Layout, LayoutLeaf};
use fastdp::util::json;
use fastdp::util::rng::ChaChaRng;

/// Run `f` over `n` seeded cases; failures report the failing seed.
fn forall(n: u64, f: impl Fn(&mut ChaChaRng) + std::panic::RefUnwindSafe) {
    for seed in 0..n {
        let mut rng = ChaChaRng::new(seed, 0xFACADE);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if result.is_err() {
            panic!("property failed at seed {seed}");
        }
    }
}

fn random_layout(rng: &mut ChaChaRng) -> (Layout, Vec<f32>) {
    let n_leaves = 1 + rng.below(12);
    let mut leaves = Vec::new();
    let mut offset = 0usize;
    for i in 0..n_leaves {
        let size = 1 + rng.below(40);
        leaves.push(LayoutLeaf {
            name: format!("leaf{i}"),
            shape: vec![size],
            size,
            offset,
            is_head: i == n_leaves - 1,
        });
        offset += size;
    }
    let mask: Vec<bool> = (0..n_leaves).map(|_| rng.uniform() < 0.4).collect();
    let mut subsets = std::collections::BTreeMap::new();
    subsets.insert("s".to_string(), mask);
    subsets.insert("full".to_string(), vec![true; n_leaves]);
    let full: Vec<f32> = (0..offset).map(|_| rng.gaussian() as f32).collect();
    (
        Layout { model: "m".into(), kind: "cls".into(), n_params: offset, leaves, subsets },
        full,
    )
}

#[test]
fn prop_layout_split_merge_roundtrips() {
    forall(200, |rng| {
        let (layout, full) = random_layout(rng);
        for subset in ["s", "full"] {
            let (frozen, train) = layout.split(&full, subset);
            assert_eq!(frozen.len() + train.len(), full.len());
            assert_eq!(layout.merge(&frozen, &train, subset), full);
            assert_eq!(layout.subset_size(subset), train.len());
        }
    });
}

#[test]
fn prop_clipped_vectors_never_exceed_r() {
    forall(300, |rng| {
        let n = 1 + rng.below(64);
        let scale = 10f64.powf(rng.uniform() * 6.0 - 3.0);
        let g: Vec<f32> = (0..n).map(|_| (rng.gaussian() * scale) as f32).collect();
        let r = 0.01 + rng.uniform() * 10.0;
        for mode in [ClipMode::Abadi, ClipMode::AutoS] {
            let mut gc = g.clone();
            clip_in_place(&mut gc, r, mode);
            let norm: f64 = gc.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
            assert!(norm <= r * 1.0001, "{mode:?}: {norm} > {r}");
        }
        // Abadi never scales up; AUTO-S factor decreases with the norm
        let sq: f64 = g.iter().map(|&x| (x as f64).powi(2)).sum();
        assert!(clip_factor(sq, r, ClipMode::Abadi) <= 1.0);
        assert!(clip_factor(sq, r, ClipMode::AutoS) <= clip_factor(sq / 4.0, r, ClipMode::AutoS));
    });
}

#[test]
fn prop_rdp_epsilon_monotone_and_calibration_inverts() {
    forall(20, |rng| {
        let q = 0.001 + rng.uniform() * 0.2;
        let sigma = 0.5 + rng.uniform() * 4.0;
        let steps = 50 + rng.below(2000) as u64;
        let e = rdp::epsilon(q, sigma, steps, 1e-5);
        assert!(rdp::epsilon(q, sigma * 1.5, steps, 1e-5) <= e + 1e-12);
        assert!(rdp::epsilon(q, sigma, steps * 2, 1e-5) >= e - 1e-12);
        assert!(rdp::epsilon(q, sigma, steps, 1e-3) <= e + 1e-12); // looser delta
        if e > 0.05 {
            let s2 = calibrate::calibrate_sigma(q, steps, e, 1e-5);
            assert!((s2 - sigma).abs() / sigma < 0.05, "sigma {sigma} -> {s2}");
        }
        let eg = gdp::epsilon(q, sigma, steps, 1e-5);
        assert!(eg <= e * 1.15 + 0.05, "gdp {eg} rdp {e}");
    });
}

#[test]
fn prop_gaussian_noise_is_unbiased_and_scaled() {
    forall(8, |rng| {
        let sigma = 0.5 + rng.uniform() * 2.0;
        let r = 0.05 + rng.uniform();
        let n = 30_000;
        let mut g = vec![0.0f32; n];
        let mut noise_rng = ChaChaRng::new(rng.next_u64(), 1);
        fastdp::dp::add_gaussian_noise(&mut g, sigma, r, &mut noise_rng);
        let mean: f64 = g.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 = g.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        let want = (sigma * r).powi(2);
        assert!(mean.abs() < 4.0 * (want / n as f64).sqrt() + 1e-3);
        assert!((var - want).abs() / want < 0.1, "var {var} want {want}");
    });
}

#[test]
fn prop_optimizers_descend_quadratics() {
    forall(30, |rng| {
        let kind = match rng.below(3) {
            0 => OptimKind::Sgd,
            1 => OptimKind::Adam,
            _ => OptimKind::AdamW,
        };
        let n = 1 + rng.below(8);
        let target: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
        let mut p = vec![0.0f32; n];
        let mut o = Optimizer::new(kind, 0.05, n);
        let loss = |p: &[f32]| -> f64 {
            p.iter().zip(&target).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        let l0 = loss(&p).max(1e-6);
        for _ in 0..300 {
            let grad: Vec<f32> = p.iter().zip(&target).map(|(a, b)| 2.0 * (a - b)).collect();
            o.step(&mut p, &grad);
        }
        assert!(loss(&p) < l0 * 0.2 + 1e-2, "{kind:?} did not descend");
    });
}

#[test]
fn prop_json_roundtrips_random_documents() {
    fn random_json(rng: &mut ChaChaRng, depth: usize) -> json::Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.uniform() < 0.5),
            2 => json::Json::Num((rng.gaussian() * 100.0).round()),
            3 => json::Json::Str(format!("s{}", rng.next_u32())),
            4 => json::Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => json::Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(200, |rng| {
        let doc = random_json(rng, 3);
        let text = json::write(&doc);
        let back = json::parse(&text).unwrap();
        assert_eq!(back, doc);
    });
}

#[test]
fn prop_checkpoints_roundtrip_and_reject_any_flip() {
    forall(20, |rng| {
        let n = 1 + rng.below(500);
        let ck = Checkpoint {
            model: format!("m{}", rng.below(100)),
            step: rng.next_u64() % 10_000,
            params: (0..n).map(|_| rng.gaussian() as f32).collect(),
        };
        let path = std::env::temp_dir().join(format!(
            "fastdp-prop-{}-{}",
            std::process::id(),
            rng.next_u32()
        ));
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap(), ck);
        // flip one random payload byte -> must be rejected (CRC)
        let mut bytes = std::fs::read(&path).unwrap();
        let header = 4 + 4 + 4 + ck.model.len() + 8 + 8;
        if bytes.len() > header + 4 {
            let i = header + rng.below(bytes.len() - header - 4);
            bytes[i] ^= 1 << rng.below(8);
            std::fs::write(&path, &bytes).unwrap();
            assert!(Checkpoint::load(&path).is_err(), "corruption not detected");
        }
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_poisson_sampler_marginals() {
    // each index included with probability ~q; nothing deterministic
    let n = 2000;
    let q = 0.1;
    let mut counts = vec![0u32; n];
    let rounds = 300;
    let mut s = fastdp::dp::sampler::PoissonSampler::new(n, q, 99);
    for _ in 0..rounds {
        for i in s.sample() {
            counts[i] += 1;
        }
    }
    let mean = counts.iter().map(|&c| c as f64).sum::<f64>() / n as f64 / rounds as f64;
    assert!((mean - q).abs() < 0.01, "marginal {mean}");
    assert!(counts.iter().all(|&c| c < rounds as u32));
}
