//! End-to-end smoke tests of `fastdp::engine` on the reference interpreter
//! backend — these run with NO artifact directory present, which is exactly
//! the point: the full train -> checkpoint -> eval path must work from a
//! fresh checkout in CI.

use fastdp::engine::{Engine, EngineError, JobSpec, Method, OptimKind, Privacy};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("fastdp-engine-e2e-{name}-{}", std::process::id()))
}

#[test]
fn train_checkpoint_eval_roundtrip_on_interpreter() {
    let mut engine = Engine::interpreter();
    assert_eq!(engine.backend_name(), "interpreter");

    let n = 256;
    let steps = 8u64;
    let spec = JobSpec::builder("cls-base", Method::BiTFiT)
        .task("sst2")
        .eps(8.0)
        .delta(1e-5)
        .optim(OptimKind::Adam)
        .lr(5e-3)
        .clip_r(0.1)
        .batch(64)
        .steps(steps)
        .n_train(n)
        .seed(11)
        .build()
        .unwrap();
    let train = engine.dataset("cls-base", "sst2", n, 11).unwrap();
    let test = engine.dataset("cls-base", "sst2", 128, 12).unwrap();

    let mut session = engine.session(&spec).unwrap();
    assert!(session.is_dp());
    assert!(session.privacy_spent().sigma > 0.0, "eps budget must calibrate sigma");
    let mut last_eps = 0.0;
    for _ in 0..steps {
        let s = session.run_step(&train).unwrap();
        assert!(s.loss.is_finite(), "loss {}", s.loss);
        assert!(s.grad_norm.is_finite());
        assert!(s.epsilon >= last_eps, "epsilon must be monotone");
        last_eps = s.epsilon;
    }
    let spent = session.privacy_spent();
    assert!(spent.epsilon > 0.0 && spent.epsilon <= 8.0 + 1e-6, "eps {}", spent.epsilon);
    assert_eq!(spent.steps, steps);

    // checkpoint -> reload -> evaluate identically
    let path = tmp("roundtrip");
    session.checkpoint(&path).unwrap();
    let direct = session.evaluate(&test, 128).unwrap();
    let reloaded = engine.load_checkpoint("cls-base", &path).unwrap();
    assert_eq!(reloaded, session.full_params());
    let via_ckpt = engine.evaluate("cls-base", &reloaded, &test, 128).unwrap();
    assert_eq!(via_ckpt.metric_a, direct.metric_a);
    assert_eq!(via_ckpt.metric_b, direct.metric_b);
    assert!(direct.accuracy() >= 0.0 && direct.accuracy() <= 1.0);
    // wrong model is a typed checkpoint error
    assert!(matches!(
        engine.load_checkpoint("lm-small", &path),
        Err(EngineError::Checkpoint(_))
    ));
    std::fs::remove_file(&path).ok();
}

#[test]
fn nonprivate_training_learns_on_interpreter() {
    let mut engine = Engine::interpreter();
    let n = 256;
    let steps = 30u64;
    let spec = JobSpec::builder("cls-base", Method::Full { ghost: true })
        .task("sst2")
        .optim(OptimKind::Adam)
        .lr(2e-2)
        .batch(64)
        .steps(steps)
        .n_train(n)
        .seed(3)
        .build()
        .unwrap();
    assert_eq!(spec.privacy, Privacy::NonPrivate);
    let train = engine.dataset("cls-base", "sst2", n, 31).unwrap();
    let mut session = engine.session(&spec).unwrap();
    assert!(!session.is_dp());
    let mut first = None;
    let mut last = f64::INFINITY;
    for _ in 0..steps {
        let s = session.run_step(&train).unwrap();
        first.get_or_insert(s.loss);
        last = s.loss;
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.9,
        "non-private full training should reduce loss: {first} -> {last}"
    );
    assert_eq!(session.privacy_spent().epsilon, 0.0);
}

#[test]
fn two_phase_session_switches_and_composes() {
    let mut engine = Engine::interpreter();
    let n = 256;
    let total = 6u64;
    let spec = JobSpec::builder("cls-base", Method::TwoPhase { full_steps: 3, full_lr: 1e-3 })
        .task("sst2")
        .sigma(1.0)
        .delta(1e-5)
        .lr(5e-3)
        .batch(64)
        .steps(total)
        .n_train(n)
        .build()
        .unwrap();
    let train = engine.dataset("cls-base", "sst2", n, 7).unwrap();
    let mut session = engine.session(&spec).unwrap();
    let full_pt = session.trainable_len();
    assert_eq!(session.phase_label(), "full");
    let mut eps_at_switch = 0.0;
    for i in 0..total {
        let s = session.run_step(&train).unwrap();
        if i == 2 {
            eps_at_switch = s.epsilon;
        }
    }
    assert_eq!(session.phase_label(), "bitfit");
    let bitfit_pt = session.trainable_len();
    assert!(bitfit_pt < full_pt, "bitfit ({bitfit_pt}) must train fewer params than full ({full_pt})");
    // the accountant composed across the switch
    let spent = session.privacy_spent();
    assert!(spent.epsilon > eps_at_switch, "eps must keep growing after the switch");
    assert_eq!(spent.steps, total);
}

#[test]
fn sessions_share_one_cached_backend() {
    let mut engine = Engine::interpreter();
    let n = 128;
    let spec_a = JobSpec::builder("cls-base", Method::BiTFiT)
        .sigma(0.5)
        .batch(32)
        .steps(4)
        .n_train(n)
        .seed(1)
        .build()
        .unwrap();
    let spec_b = JobSpec::builder("cls-base", Method::LastLayer)
        .sigma(0.5)
        .batch(32)
        .steps(4)
        .n_train(n)
        .seed(2)
        .build()
        .unwrap();
    let data = engine.dataset("cls-base", "sst2", n, 5).unwrap();
    // two live sessions over one engine, stepped in interleaved order
    let mut a = engine.session(&spec_a).unwrap();
    let mut b = engine.session(&spec_b).unwrap();
    for _ in 0..4 {
        let sa = a.run_step(&data).unwrap();
        let sb = b.run_step(&data).unwrap();
        assert!(sa.loss.is_finite() && sb.loss.is_finite());
    }
    assert!(a.trainable_len() > b.trainable_len());
}

#[test]
fn image_and_lm_paths_run_end_to_end() {
    let mut engine = Engine::interpreter();
    // ViT on the CIFAR-analog
    let n = 128;
    let spec = JobSpec::builder("vit-c10", Method::BiTFiT)
        .task("cifar")
        .eps(4.0)
        .batch(32)
        .steps(3)
        .n_train(n)
        .build()
        .unwrap();
    let data = engine.dataset("vit-c10", "cifar", n, 9).unwrap();
    let mut session = engine.session(&spec).unwrap();
    for _ in 0..3 {
        session.run_step(&data).unwrap();
    }
    let out = session.evaluate(&data, 64).unwrap();
    assert!(out.metric_a.is_finite() && out.n == 64);

    // LM on the E2E-analog, including greedy decode
    let (lm_data, gen) = engine.dataset_e2e("lm-small", 64, 13).unwrap();
    let spec = JobSpec::builder("lm-small", Method::BiTFiT)
        .task("e2e")
        .sigma(0.7)
        .optim(OptimKind::AdamW)
        .batch(32)
        .steps(2)
        .n_train(64)
        .build()
        .unwrap();
    let mut session = engine.session(&spec).unwrap();
    for _ in 0..2 {
        session.run_step(&lm_data).unwrap();
    }
    let out = session.evaluate(&lm_data, 32).unwrap();
    assert!(out.perplexity().is_finite() && out.perplexity() > 0.0);
    let dec = engine.decoder("lm-small").unwrap();
    let prompts: Vec<Vec<i32>> =
        gen.iter().take(4).map(|g| g.lm.input[..g.prompt_len].to_vec()).collect();
    let hyps = fastdp::coordinator::decode::greedy_decode(
        dec.as_ref(),
        &session.full_params(),
        &prompts,
        8,
        fastdp::data::tokenizer::EOS,
    )
    .unwrap();
    assert_eq!(hyps.len(), 4);
}

#[test]
fn session_state_roundtrips_across_the_two_phase_switch() {
    // Save a complete session snapshot mid-phase-1 of an X+BiTFiT job,
    // resume it in a fresh session, finish training: final parameters and
    // privacy spent must be bit/value-identical to the uninterrupted run —
    // the snapshot carries optimizer moments, RNG streams and the RDP
    // accountant across the full/bitfit artifact switch.
    let n = 256;
    let total = 6u64;
    let spec = JobSpec::builder("cls-base", Method::TwoPhase { full_steps: 3, full_lr: 1e-3 })
        .task("sst2")
        .sigma(1.0)
        .delta(1e-5)
        .lr(5e-3)
        .batch(64)
        .steps(total)
        .n_train(n)
        .seed(77)
        .build()
        .unwrap();
    let mut engine = Engine::interpreter();
    let train = engine.dataset("cls-base", "sst2", n, 41).unwrap();
    let test = engine.dataset("cls-base", "sst2", 128, 42).unwrap();

    // uninterrupted reference run
    let mut straight = engine.session(&spec).unwrap();
    for _ in 0..total {
        straight.run_step(&train).unwrap();
    }

    // interrupted run: stop after 2 steps (mid-phase-1, still "full")
    let mut first_half = engine.session(&spec).unwrap();
    for _ in 0..2 {
        first_half.run_step(&train).unwrap();
    }
    assert_eq!(first_half.phase_label(), "full", "save point must be inside phase 1");
    let path = tmp("two-phase-state");
    first_half.save_state(&path).unwrap();

    let mut resumed = engine.resume_session(&spec, &path).unwrap();
    assert_eq!(resumed.step(), 2);
    assert_eq!(resumed.phase_label(), "full");
    for _ in 2..total {
        resumed.run_step(&train).unwrap();
    }
    assert_eq!(resumed.phase_label(), "bitfit", "run must have crossed the switch");

    // params bit-identical, privacy value-identical
    let a = straight.full_params();
    let b = resumed.full_params();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&a), bits(&b), "resumed params must match the uninterrupted run");
    let (pa, pb) = (straight.privacy_spent(), resumed.privacy_spent());
    assert_eq!(pa.epsilon.to_bits(), pb.epsilon.to_bits());
    assert_eq!(pa.steps, pb.steps);
    // and evaluation agrees exactly
    let (ea, eb) = (straight.evaluate(&test, 128).unwrap(), resumed.evaluate(&test, 128).unwrap());
    assert_eq!(ea.metric_a.to_bits(), eb.metric_a.to_bits());
    assert_eq!(ea.metric_b.to_bits(), eb.metric_b.to_bits());

    // a wrong-model resume is a typed checkpoint error
    let other = JobSpec::builder("lm-small", Method::BiTFiT)
        .sigma(1.0)
        .batch(32)
        .steps(2)
        .n_train(64)
        .build()
        .unwrap();
    assert!(matches!(
        engine.resume_session(&other, &path),
        Err(EngineError::Checkpoint(_))
    ));
    // and so is resuming under a non-private spec (sampler mismatch)
    let nonprivate = JobSpec::builder("cls-base", Method::TwoPhase { full_steps: 3, full_lr: 1e-3 })
        .task("sst2")
        .lr(5e-3)
        .batch(64)
        .steps(total)
        .n_train(n)
        .seed(77)
        .build()
        .unwrap();
    assert!(matches!(
        engine.resume_session(&nonprivate, &path),
        Err(EngineError::Checkpoint(_))
    ));
    std::fs::remove_file(&path).ok();
}

#[test]
fn unknown_model_is_a_typed_error() {
    let mut engine = Engine::interpreter();
    let spec = JobSpec::builder("gpt5-colossal", Method::BiTFiT)
        .sigma(1.0)
        .build()
        .unwrap();
    assert!(matches!(engine.session(&spec), Err(EngineError::UnknownModel(_))));
}
