//! Vendored, dependency-free subset of the `anyhow` API.
//!
//! This environment is offline (no crates.io), so the repository vendors the
//! slice of `anyhow` it actually uses: [`Error`], [`Result`], the [`Context`]
//! extension trait, and the `anyhow!` / `bail!` / `ensure!` macros.  Errors
//! are stored as a flattened message chain; `{}` prints the outermost
//! message, `{:#}` (and `{:?}`) print the full `a: b: c` chain, matching how
//! the binary reports errors.

use std::fmt;

/// An error: a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(context.to_string());
        chain.extend(self.chain);
        Error { chain }
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, msg) in self.chain.iter().enumerate() {
            if i > 0 {
                write!(f, ": ")?;
            }
            write!(f, "{msg}")?;
        }
        Ok(())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f)
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what makes the blanket `From` below coherent (same trick as real anyhow).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, format string, or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn context_chains_and_formats() {
        let err = io_fail().context("loading config").unwrap_err();
        let plain = format!("{err}");
        let full = format!("{err:#}");
        assert_eq!(plain, "loading config");
        assert!(full.starts_with("loading config: "), "{full}");
        assert!(full.len() > plain.len());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let msg = String::from("owned");
        assert_eq!(anyhow!(msg).to_string(), "owned");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert_eq!(none.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }
}
