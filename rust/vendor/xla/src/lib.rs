//! Compile-time stub of the slice of the `xla` crate (PJRT C API bindings)
//! that `fastdp::runtime` uses.
//!
//! This environment ships no `xla_extension` shared library, so the real
//! crate cannot link.  The stub keeps the whole PJRT code path *compiling*
//! (and `Literal` is fully functional as a host container, so conversion
//! round-trip tests pass), while `PjRtClient::compile` returns a runtime
//! error — executing HLO requires the real backend.  Swapping this path
//! dependency for the real `xla` crate re-enables PJRT with no source
//! changes in `fastdp`.

use std::fmt;
use std::path::Path;

/// Stub error type (the real crate's `Error` is richer).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT unavailable (built against the vendored xla stub; \
         link the real xla_extension crate to execute HLO artifacts)"
    ))
}

/// Element types the runtime moves across the host boundary.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<&[f32]> {
        match data {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<&[i32]> {
        match data {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Typed storage of a host literal.
#[derive(Debug, Clone)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side literal: typed buffer + dims.  Fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    fn len(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "reshape: {} elements cannot take shape {dims:?}",
                self.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the buffer out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("to_vec: literal has a different element type".into()))
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            LiteralData::Tuple(v) => Ok(v.clone()),
            _ => Err(Error("to_tuple: literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module text (opaque in the stub).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {}: {e}", path.display())))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A PJRT device handle.
pub struct PjRtDevice {
    _private: (),
}

/// A device-resident buffer (host-backed in the stub).
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Marker for argument types accepted by `execute*`.
pub trait BufferArg {}
impl BufferArg for Literal {}
impl BufferArg for &PjRtBuffer {}

/// A compiled executable.  Never constructed by the stub (compile fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T: BufferArg>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("execute"))
    }

    pub fn execute_b<T: BufferArg>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("execute_b"))
    }
}

/// A PJRT client.  Construction succeeds (so artifact *metadata* paths work);
/// compilation is where the stub reports itself.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu (xla stub — HLO execution disabled)".to_string()
    }

    pub fn devices(&self) -> Vec<PjRtDevice> {
        vec![PjRtDevice { _private: () }]
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: literal.clone() })
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(Literal::vec1(&[1i32]).reshape(&[3]).is_err());
    }

    #[test]
    fn compile_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        let proto = XlaComputation::from_proto(&HloModuleProto { _text: String::new() });
        let err = c.compile(&proto).unwrap_err().to_string();
        assert!(err.contains("PJRT unavailable"), "{err}");
    }
}
