//! `fastdp-lint` — a repo-native static-analysis pass that enforces the
//! determinism and DP invariants of the `fastdp` engine.
//!
//! The engine's two non-negotiable properties — bitwise-deterministic
//! training and differential privacy — are invisible to `rustc` and
//! `clippy`: nothing in the type system says "this per-sample gradient
//! must be clipped before it touches the shared sum" or "iterating this
//! `HashMap` makes the loss nondeterministic".  This crate encodes those
//! invariants as token-level rule passes over the source tree (no `syn`,
//! no dependencies — a hand-rolled lexer in [`lexer`], file structure in
//! [`scan`], the rules in [`rules`], reporting in [`report`]).
//!
//! Run it as `cargo run -p fastdp-lint` from `rust/`, or through the
//! `ci.sh` lint stage (skip with `--no-lint`).  The machine-readable
//! output lands in `LINT_report.json`; the rule catalog, annotation
//! grammar and allow-list syntax are documented in the repository
//! README under "Static analysis".

pub mod lexer;
pub mod report;
pub mod rules;
pub mod scan;

pub use report::{render, to_json, Finding, Report};
pub use rules::{run, LintConfig, RULES};

use std::path::Path;

/// The standard configuration for this repository, rooted at `repo_root`
/// (the directory containing `rust/` and `README.md`).
pub fn repo_config(repo_root: &Path) -> LintConfig {
    LintConfig::for_repo(repo_root)
}
