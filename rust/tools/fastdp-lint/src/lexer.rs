//! A hand-rolled Rust lexer: just enough token structure for rule passes.
//!
//! No `syn`, no dependencies — consistent with the repo's vendored-offline
//! constraint.  The token stream keeps comments (annotation directives and
//! `// SAFETY:` hygiene live there) and resolves the classic ambiguities
//! that break naive scanners: lifetimes vs char literals (`'a` vs `'a'`),
//! raw/byte strings (`r#"…"#`, `b"…"`), nested block comments, and the
//! `env!` macro vs `env::var` call distinction (left to rule passes, which
//! see `!` vs `::` as separate punct tokens).

/// Token kind.  `Comment` covers line, block and doc comments alike.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Lifetime,
    Str,
    Char,
    Num,
    Punct,
    Comment,
}

/// One token: kind, verbatim text, and the 1-based line it starts on.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Multi-byte punctuation, longest first so greedy matching is correct.
const PUNCTS: &[&str] = &[
    "<<=", ">>=", "..=", "::", "->", "=>", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "==",
    "!=", "<=", ">=", "&&", "||", "..", "<<", ">>",
];

/// If `src[i..]` starts a string literal (plain, byte, raw or raw-byte),
/// return the exclusive end index; else `None`.
fn string_end(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    if j < b.len() && b[j] == b'b' {
        j += 1;
    }
    if j < b.len() && b[j] == b'r' {
        // raw (possibly byte) string: r#*" … "#*
        let mut k = j + 1;
        let mut hashes = 0;
        while k < b.len() && b[k] == b'#' {
            hashes += 1;
            k += 1;
        }
        if k < b.len() && b[k] == b'"' {
            k += 1;
            while k < b.len() {
                if b[k] == b'"' && b.len() - k > hashes && b[k + 1..k + 1 + hashes].iter().all(|&c| c == b'#') {
                    return Some(k + 1 + hashes);
                }
                k += 1;
            }
            return Some(b.len());
        }
        return None;
    }
    if j < b.len() && b[j] == b'"' {
        let mut k = j + 1;
        while k < b.len() {
            match b[k] {
                b'\\' => k += 2,
                b'"' => return Some(k + 1),
                _ => k += 1,
            }
        }
        return Some(b.len());
    }
    None
}

/// Tokenize `src`.  Whitespace is dropped; everything else (including
/// comments) becomes a token.  Unterminated constructs run to EOF rather
/// than erroring — the linter should keep scanning whatever it can.
pub fn lex(src: &str) -> Vec<Tok> {
    let b = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    let mut line = 1;
    let push = |out: &mut Vec<Tok>, kind, s: &[u8], line| {
        out.push(Tok { kind, text: String::from_utf8_lossy(s).into_owned(), line });
    };
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // comments
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            push(&mut out, Kind::Comment, &b[start..i], line);
            continue;
        }
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let start = i;
            let start_line = line;
            let mut depth = 1;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            push(&mut out, Kind::Comment, &b[start..i], start_line);
            continue;
        }
        // strings (incl. b"…", r"…", r#"…"#, br#"…"#)
        if c == b'"' || ((c == b'b' || c == b'r') && string_end(b, i).is_some()) {
            if let Some(end) = string_end(b, i) {
                let start_line = line;
                line += b[i..end].iter().filter(|&&c| c == b'\n').count();
                push(&mut out, Kind::Str, &b[i..end], start_line);
                i = end;
                continue;
            }
        }
        // byte char b'x'
        if c == b'b' && i + 1 < b.len() && b[i + 1] == b'\'' {
            let mut k = i + 2;
            while k < b.len() && b[k] != b'\'' {
                if b[k] == b'\\' {
                    k += 1;
                }
                k += 1;
            }
            push(&mut out, Kind::Char, &b[i..(k + 1).min(b.len())], line);
            i = (k + 1).min(b.len());
            continue;
        }
        // lifetime or char literal
        if c == b'\'' {
            if i + 1 < b.len() && b[i + 1] == b'\\' {
                // escaped char literal: skip the escaped character (it may
                // itself be a quote, as in '\''), then scan to the close
                let mut k = i + 3;
                while k < b.len() && b[k] != b'\'' {
                    if b[k] == b'\\' {
                        k += 1;
                    }
                    k += 1;
                }
                push(&mut out, Kind::Char, &b[i..(k + 1).min(b.len())], line);
                i = (k + 1).min(b.len());
                continue;
            }
            if i + 1 < b.len() && is_ident_start(b[i + 1]) {
                let mut k = i + 1;
                while k < b.len() && is_ident_cont(b[k]) {
                    k += 1;
                }
                if k < b.len() && b[k] == b'\'' {
                    // 'a' — a char literal
                    push(&mut out, Kind::Char, &b[i..k + 1], line);
                    i = k + 1;
                } else {
                    // 'a — a lifetime
                    push(&mut out, Kind::Lifetime, &b[i..k], line);
                    i = k;
                }
                continue;
            }
            // e.g. '"' or stray quote: one-char literal
            let end = (i + 3).min(b.len());
            push(&mut out, Kind::Char, &b[i..end], line);
            i = end;
            continue;
        }
        if is_ident_start(c) {
            let start = i;
            while i < b.len() && is_ident_cont(b[i]) {
                i += 1;
            }
            push(&mut out, Kind::Ident, &b[start..i], line);
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < b.len() && (is_ident_cont(b[i]) || (b[i] == b'.' && i + 1 < b.len() && b[i + 1].is_ascii_digit() && b[i - 1] != b'.')) {
                i += 1;
            }
            push(&mut out, Kind::Num, &b[start..i], line);
            continue;
        }
        // punctuation, longest match first
        let rest = &b[i..];
        let mut matched = 1;
        for p in PUNCTS {
            if rest.starts_with(p.as_bytes()) {
                matched = p.len();
                break;
            }
        }
        push(&mut out, Kind::Punct, &b[i..i + matched], line);
        i += matched;
    }
    out
}

/// The contents of a string literal token (quotes/prefix/hashes stripped),
/// or `None` for other kinds.
pub fn str_content(t: &Tok) -> Option<&str> {
    if t.kind != Kind::Str {
        return None;
    }
    let s = t.text.trim_start_matches('b').trim_start_matches('r').trim_matches('#');
    Some(s.trim_matches('"'))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn lifetimes_vs_chars() {
        let t = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(t.contains(&(Kind::Lifetime, "'a".into())));
        assert!(t.contains(&(Kind::Char, "'x'".into())));
        let esc = kinds(r"let c = '\n';");
        assert!(esc.contains(&(Kind::Char, "'\\n'".into())));
    }

    #[test]
    fn raw_and_byte_strings() {
        let t = kinds(r###"let a = r#"hi "there""#; let b = b"raw"; let c = br#"x"#;"###);
        let strs: Vec<_> = t.iter().filter(|(k, _)| *k == Kind::Str).collect();
        assert_eq!(strs.len(), 3, "{strs:?}");
    }

    #[test]
    fn env_macro_vs_env_var_tokens() {
        let t = kinds(r#"env!("X"); std::env::var("Y");"#);
        // env! lexes as ident + `!`, env::var as ident `::` ident
        let i = t.iter().position(|(k, s)| *k == Kind::Ident && s == "env").unwrap();
        assert_eq!(t[i + 1].1, "!");
        let j = t.iter().rposition(|(k, s)| *k == Kind::Ident && s == "env").unwrap();
        assert_eq!(t[j + 1].1, "::");
        assert_eq!(t[j + 2].1, "var");
    }

    #[test]
    fn comments_and_lines() {
        let t = lex("// one\nlet x = 1; /* two\nlines */ y");
        assert_eq!(t[0].kind, Kind::Comment);
        assert_eq!(t[0].line, 1);
        let y = t.iter().find(|t| t.text == "y").unwrap();
        assert_eq!(y.line, 3);
    }

    #[test]
    fn compound_punct() {
        let t = kinds("a += b; c..=d; e::f");
        assert!(t.contains(&(Kind::Punct, "+=".into())));
        assert!(t.contains(&(Kind::Punct, "..=".into())));
        assert!(t.contains(&(Kind::Punct, "::".into())));
    }

    #[test]
    fn str_content_strips() {
        let t = lex(r#"let s = "FASTDP_X";"#);
        let s = t.iter().find(|t| t.kind == Kind::Str).unwrap();
        assert_eq!(str_content(s), Some("FASTDP_X"));
    }
}
