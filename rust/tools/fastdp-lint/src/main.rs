//! CLI entry point: lint the tree, print findings, write the JSON report.
//!
//! ```text
//! fastdp-lint [--root <repo-root>] [--json <path>] [--quiet]
//! ```
//!
//! * `--root` defaults to the parent of the `rust/` workspace this binary
//!   was built from (so `cargo run -p fastdp-lint` from `rust/` just works).
//! * `--json` defaults to `<root>/LINT_report.json`.
//! * Exit status is 1 if any (non-allowed) finding fired, else 0.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut json: Option<PathBuf> = None;
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--json" => json = args.next().map(PathBuf::from),
            "--quiet" => quiet = true,
            "--help" | "-h" => {
                println!("usage: fastdp-lint [--root <repo-root>] [--json <path>] [--quiet]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("fastdp-lint: unknown argument {other:?}");
                return ExitCode::from(2);
            }
        }
    }
    // CARGO_MANIFEST_DIR = …/rust/tools/fastdp-lint; the repo root is
    // three levels up.  A compile-time constant, not an env knob.
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .ancestors()
            .nth(3)
            .expect("manifest dir has a repo root above it")
            .to_path_buf()
    });
    let cfg = fastdp_lint::repo_config(&root);
    let rep = fastdp_lint::run(&cfg);

    let json_path = json.unwrap_or_else(|| root.join("LINT_report.json"));
    let doc = fastdp_lint::to_json(&rep, fastdp_lint::RULES);
    if let Err(e) = std::fs::write(&json_path, doc) {
        eprintln!("fastdp-lint: cannot write {}: {e}", json_path.display());
        return ExitCode::from(2);
    }

    if !quiet {
        if !rep.findings.is_empty() {
            println!("{}", fastdp_lint::render(&rep.findings));
        }
        println!(
            "fastdp-lint: {} finding(s), {} allowed, {} files scanned -> {}",
            rep.findings.len(),
            rep.allowed.len(),
            rep.files_scanned,
            json_path.display()
        );
    }
    if rep.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
