//! Per-file structure on top of the token stream: function items, lint
//! annotation directives, allow-lists, and `#[cfg(test)]` spans.
//!
//! Annotation grammar (all inside ordinary `//` comments):
//!
//! * `// fastdp-lint: per-sample-grad` — the next `fn` produces
//!   per-sample gradient data (taint source).
//! * `// fastdp-lint: clip-boundary` — the next `fn` clips; taint does
//!   not survive a call to it.
//! * `// fastdp-lint: noise-site` — the next `fn` injects the Gaussian
//!   noise of the DP mechanism.
//! * `// fastdp-lint: dp-sink` — before a `fn`: calling it is a sink
//!   (shared accumulator / optimizer / wire).  Inside a body: a
//!   checkpoint — taint must be clear when control passes this line.
//! * `// fastdp-lint: allow(rule-a, rule-b) <reason>` — suppress those
//!   rules' findings on this line or the next.

use std::path::{Path, PathBuf};

use crate::lexer::{lex, Kind, Tok};

/// Fn-level directive kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Directive {
    PerSampleGrad,
    ClipBoundary,
    NoiseSite,
    DpSink,
}

impl Directive {
    pub fn parse(word: &str) -> Option<Directive> {
        match word {
            "per-sample-grad" => Some(Directive::PerSampleGrad),
            "clip-boundary" => Some(Directive::ClipBoundary),
            "noise-site" => Some(Directive::NoiseSite),
            "dp-sink" => Some(Directive::DpSink),
            _ => None,
        }
    }
}

/// A `fn` item: name, directives attached above it, and token spans.
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// Token index of the name ident (signature spans name → body).
    pub name_idx: usize,
    pub line: usize,
    pub directives: Vec<Directive>,
    /// Token-index range of the body, `start` at `{`, `end` at matching
    /// `}` (exclusive of neither); `None` for bodyless trait fns.
    pub body: Option<(usize, usize)>,
}

/// One lexed + structured source file.
pub struct SourceFile {
    pub path: PathBuf,
    /// Unix-style path relative to the scan root (e.g. `kernels/fused.rs`).
    pub rel: String,
    pub toks: Vec<Tok>,
    pub fns: Vec<FnItem>,
    /// Line ranges (inclusive) covered by `#[cfg(test)] mod … { … }`.
    pub test_ranges: Vec<(usize, usize)>,
    /// `(line, rules)` for each `allow(...)` annotation.
    pub allows: Vec<(usize, Vec<String>)>,
}

/// Parse the directive (or allow-list) out of one comment's text.
pub(crate) fn comment_directive(text: &str) -> Option<Result<Directive, Vec<String>>> {
    let rest = text.split("fastdp-lint:").nth(1)?.trim();
    if let Some(inner) = rest.strip_prefix("allow(") {
        let rules = inner
            .split(')')
            .next()
            .unwrap_or("")
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        return Some(Err(rules));
    }
    let word = rest.split_whitespace().next()?;
    Directive::parse(word).map(Ok)
}

/// Tokens that may sit between a directive comment and its `fn` without
/// detaching it (visibility, safety, ABI, attribute punctuation).
fn is_fn_prefix(t: &Tok) -> bool {
    match t.kind {
        Kind::Str => true, // extern "C"
        Kind::Ident => {
            matches!(t.text.as_str(), "pub" | "crate" | "super" | "self" | "in" | "unsafe" | "extern" | "const" | "async")
        }
        Kind::Punct => matches!(t.text.as_str(), "(" | ")"),
        _ => false,
    }
}

impl SourceFile {
    pub fn load(path: &Path, rel: &str) -> std::io::Result<SourceFile> {
        let src = std::fs::read_to_string(path)?;
        Ok(SourceFile::from_source(path.to_path_buf(), rel, &src))
    }

    pub fn from_source(path: PathBuf, rel: &str, src: &str) -> SourceFile {
        let toks = lex(src);
        let mut sf = SourceFile {
            path,
            rel: rel.replace('\\', "/"),
            toks,
            fns: Vec::new(),
            test_ranges: Vec::new(),
            allows: Vec::new(),
        };
        sf.scan_structure();
        sf
    }

    /// Skip an attribute starting at `#`; returns the index after `]`.
    fn skip_attr(&self, mut i: usize) -> usize {
        // at '#', optionally '!', then '[' … matching ']'
        i += 1;
        if i < self.toks.len() && self.toks[i].text == "!" {
            i += 1;
        }
        if i >= self.toks.len() || self.toks[i].text != "[" {
            return i;
        }
        let mut depth = 0usize;
        while i < self.toks.len() {
            match self.toks[i].text.as_str() {
                "[" => depth += 1,
                "]" => {
                    depth -= 1;
                    if depth == 0 {
                        return i + 1;
                    }
                }
                _ => {}
            }
            i += 1;
        }
        i
    }

    /// Does the attribute span `[start, end)` mention `cfg` + `test`?
    fn attr_is_cfg_test(&self, start: usize, end: usize) -> bool {
        let mut saw_cfg = false;
        let mut saw_test = false;
        for t in &self.toks[start..end.min(self.toks.len())] {
            if t.kind == Kind::Ident {
                saw_cfg |= t.text == "cfg";
                saw_test |= t.text == "test";
            }
        }
        saw_cfg && saw_test
    }

    /// Find the matching `}` for the `{` at token index `open`.
    pub fn match_brace(&self, open: usize) -> usize {
        let mut depth = 0usize;
        let mut i = open;
        while i < self.toks.len() {
            if self.toks[i].kind == Kind::Punct {
                match self.toks[i].text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            return i;
                        }
                    }
                    _ => {}
                }
            }
            i += 1;
        }
        self.toks.len().saturating_sub(1)
    }

    fn scan_structure(&mut self) {
        let mut pending: Vec<Directive> = Vec::new();
        let mut i = 0;
        while i < self.toks.len() {
            let t = &self.toks[i];
            match t.kind {
                Kind::Comment => {
                    match comment_directive(&t.text) {
                        Some(Ok(d)) => pending.push(d),
                        Some(Err(rules)) => self.allows.push((t.line, rules)),
                        None => {}
                    }
                    i += 1;
                }
                Kind::Punct if t.text == "#" => {
                    let end = self.skip_attr(i);
                    if self.attr_is_cfg_test(i, end) {
                        // attr → (prefix tokens) → `mod name {` marks a test mod
                        let mut j = end;
                        while j < self.toks.len()
                            && (self.toks[j].kind == Kind::Comment || is_fn_prefix(&self.toks[j]))
                        {
                            j += 1;
                        }
                        if j < self.toks.len() && self.toks[j].text == "mod" {
                            // find the opening brace of the mod body
                            let mut k = j + 1;
                            while k < self.toks.len() && self.toks[k].text != "{" && self.toks[k].text != ";" {
                                k += 1;
                            }
                            if k < self.toks.len() && self.toks[k].text == "{" {
                                let close = self.match_brace(k);
                                self.test_ranges.push((self.toks[k].line, self.toks[close].line));
                            }
                        }
                    }
                    i = end;
                }
                Kind::Ident if t.text == "fn" => {
                    // `fn` keyword: an item if followed by a name (a bare
                    // `fn(…)` pointer type is not)
                    if i + 1 < self.toks.len() && self.toks[i + 1].kind == Kind::Ident {
                        let name = self.toks[i + 1].text.clone();
                        let line = self.toks[i + 1].line;
                        // scan to body `{` (or `;`) at paren depth 0
                        let mut k = i + 2;
                        let mut paren = 0i32;
                        let mut body = None;
                        while k < self.toks.len() {
                            match self.toks[k].text.as_str() {
                                "(" | "[" => paren += 1,
                                ")" | "]" => paren -= 1,
                                "{" if paren == 0 => {
                                    let close = self.match_brace(k);
                                    body = Some((k, close));
                                    break;
                                }
                                ";" if paren == 0 => break,
                                _ => {}
                            }
                            k += 1;
                        }
                        self.fns.push(FnItem {
                            name,
                            name_idx: i + 1,
                            line,
                            directives: std::mem::take(&mut pending),
                            body,
                        });
                        // continue scanning *inside* the body too (nested
                        // fns, and the structure scan only needs item
                        // starts) — so just advance past the signature
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                _ => {
                    if !is_fn_prefix(t) {
                        pending.clear();
                    }
                    i += 1;
                }
            }
        }
    }

    /// Module path segments for call resolution: `kernels/fused.rs` →
    /// `["kernels", "fused"]`; `dp/mod.rs` → `["dp"]`; `lib.rs` → `[]`.
    pub fn module_segs(&self) -> Vec<String> {
        let mut segs: Vec<String> = self.rel.trim_end_matches(".rs").split('/').map(String::from).collect();
        if segs.last().map(|s| s.as_str()) == Some("mod") {
            segs.pop();
        }
        if segs.last().map(|s| s.as_str()) == Some("lib") {
            segs.pop();
        }
        segs
    }

    pub fn in_test(&self, line: usize) -> bool {
        self.test_ranges.iter().any(|&(a, b)| line >= a && line <= b)
    }

    /// Is `rule` allowed (suppressed) at `line`?  An `allow` annotation
    /// covers its own line and the following one.
    pub fn is_allowed(&self, rule: &str, line: usize) -> bool {
        self.allows.iter().any(|(l, rules)| {
            (*l == line || l + 1 == line) && rules.iter().any(|r| r == rule)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(src: &str) -> SourceFile {
        SourceFile::from_source(PathBuf::from("mem.rs"), "kernels/fused.rs", src)
    }

    #[test]
    fn fn_items_and_directives() {
        let f = sf("// fastdp-lint: per-sample-grad\npub fn backward(x: usize) -> usize { x }\nfn plain() {}\n");
        assert_eq!(f.fns.len(), 2);
        assert_eq!(f.fns[0].name, "backward");
        assert_eq!(f.fns[0].directives, vec![Directive::PerSampleGrad]);
        assert!(f.fns[1].directives.is_empty());
    }

    #[test]
    fn directive_survives_attrs_and_vis() {
        let f = sf("// fastdp-lint: clip-boundary\n#[inline]\npub(crate) fn clip() {}\n");
        assert_eq!(f.fns[0].directives, vec![Directive::ClipBoundary]);
    }

    #[test]
    fn directive_detaches_across_items() {
        let f = sf("// fastdp-lint: clip-boundary\nconst X: usize = 1;\nfn later() {}\n");
        // the const item consumed the pending directive ("const" is a fn
        // prefix, but `X`'s`=` clears) — later() must not inherit it
        assert!(f.fns[0].directives.is_empty());
    }

    #[test]
    fn cfg_test_ranges() {
        let f = sf("fn live() {}\n#[cfg(test)]\nmod tests {\n  fn helper() {}\n}\n");
        assert_eq!(f.test_ranges.len(), 1);
        assert!(f.in_test(4));
        assert!(!f.in_test(1));
    }

    #[test]
    fn allow_parses_and_covers_next_line() {
        let f = sf("// fastdp-lint: allow(thread-spawn, dp-flow) replica workers\nfn x() {}\n");
        assert!(f.is_allowed("thread-spawn", 1));
        assert!(f.is_allowed("dp-flow", 2));
        assert!(!f.is_allowed("dp-flow", 3));
        assert!(!f.is_allowed("hash-iteration", 2));
    }

    #[test]
    fn fn_pointer_type_is_not_an_item() {
        let f = sf("type J = Box<dyn Fn(usize)>; static F: fn(usize) -> usize = id;\nfn id(x: usize) -> usize { x }\n");
        assert_eq!(f.fns.len(), 1);
        assert_eq!(f.fns[0].name, "id");
    }

    #[test]
    fn module_segs_variants() {
        let m = SourceFile::from_source(PathBuf::from("m"), "dp/mod.rs", "");
        assert_eq!(m.module_segs(), vec!["dp"]);
        let l = SourceFile::from_source(PathBuf::from("m"), "lib.rs", "");
        assert!(l.module_segs().is_empty());
        let f = SourceFile::from_source(PathBuf::from("m"), "kernels/fused.rs", "");
        assert_eq!(f.module_segs(), vec!["kernels", "fused"]);
    }
}
