//! Findings, the report document, and its hand-rolled JSON rendering
//! (`LINT_report.json` — no serde, consistent with the no-deps rule).

/// One finding (or one allow-suppressed would-be finding).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the scanned tree, unix-style.
    pub file: String,
    pub line: usize,
    pub message: String,
}

/// The full lint result.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// Suppressed by `// fastdp-lint: allow(...)` — kept for visibility.
    pub allowed: Vec<Finding>,
    pub files_scanned: usize,
}

impl Report {
    /// Sort both lists so output order is independent of scan order.
    pub fn normalize(&mut self) {
        let key = |f: &Finding| (f.file.clone(), f.line, f.rule, f.message.clone());
        self.findings.sort_by_key(key);
        self.findings.dedup();
        self.allowed.sort_by_key(key);
        self.allowed.dedup();
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn finding_json(f: &Finding) -> String {
    format!(
        "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
        f.rule,
        json_escape(&f.file),
        f.line,
        json_escape(&f.message)
    )
}

/// Render the machine-readable report document.
///
/// Schema (documented in the README "Static analysis" section):
/// `{ tool, version, rules: [..], summary: {findings, allowed,
/// files_scanned}, findings: [{rule, file, line, message}], allowed: [..] }`
pub fn to_json(r: &Report, rules: &[&str]) -> String {
    let list = |fs: &[Finding]| {
        if fs.is_empty() {
            "[]".to_string()
        } else {
            format!("[\n{}\n  ]", fs.iter().map(finding_json).collect::<Vec<_>>().join(",\n"))
        }
    };
    format!(
        "{{\n  \"tool\": \"fastdp-lint\",\n  \"version\": 1,\n  \"rules\": [{}],\n  \
         \"summary\": {{\"findings\": {}, \"allowed\": {}, \"files_scanned\": {}}},\n  \
         \"findings\": {},\n  \"allowed\": {}\n}}\n",
        rules.iter().map(|r| format!("\"{r}\"")).collect::<Vec<_>>().join(", "),
        r.findings.len(),
        r.allowed.len(),
        r.files_scanned,
        list(&r.findings),
        list(&r.allowed)
    )
}

/// Human-readable rendering, one line per finding.
pub fn render(findings: &[Finding]) -> String {
    findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_shape_and_escaping() {
        let mut r = Report::default();
        r.findings.push(Finding {
            rule: "dp-flow",
            file: "engine/interp.rs".into(),
            line: 7,
            message: "tainted \"x\" reaches sink".into(),
        });
        r.files_scanned = 3;
        let j = to_json(&r, &["dp-flow"]);
        assert!(j.contains("\"tool\": \"fastdp-lint\""));
        assert!(j.contains("\\\"x\\\""));
        assert!(j.contains("\"findings\": 1"));
        assert!(j.contains("\"files_scanned\": 3"));
    }

    #[test]
    fn normalize_sorts_and_dedups() {
        let mk = |file: &str, line| Finding {
            rule: "unsafe-safety",
            file: file.into(),
            line,
            message: "m".into(),
        };
        let mut r = Report {
            findings: vec![mk("b.rs", 2), mk("a.rs", 9), mk("b.rs", 2)],
            ..Report::default()
        };
        r.normalize();
        assert_eq!(r.findings.len(), 2);
        assert_eq!(r.findings[0].file, "a.rs");
    }
}
