//! The rule passes.
//!
//! | rule            | guards against                                          |
//! |-----------------|---------------------------------------------------------|
//! | `hash-iteration`| `HashMap`/`HashSet` iteration feeding accumulation or   |
//! |                 | output ordering in `kernels/`, `engine/`, `coordinator/`|
//! |                 | or `nlg/` (hasher order ⇒ nondeterministic bits)        |
//! | `thread-spawn`  | `std::thread::{spawn,scope,Builder}` outside the pool   |
//! | `net-io`        | raw `std::net` sockets outside the transport module     |
//! | `dp-flow`       | per-sample gradient taint reaching a sink unclipped     |
//! | `dp-noise`      | a crate with per-sample sources but no noise site       |
//! | `unsafe-safety` | `unsafe` blocks without a `// SAFETY:` comment          |
//! | `env-registry`  | raw `env::var` / `FASTDP_*` names outside `runtime/env` |
//! | `doc-drift`     | lib.rs layer map or README env table vs reality         |
//!
//! Everything here is token-level and name-based — a deliberately simple
//! approximation (no type inference, no real name resolution).  Calls are
//! resolved by name with a module-qualifier filter (`ghost::row_cls(`
//! prefers fns in a module segment named `ghost`), then same-file, then
//! the union of all same-named fns; taint flows through a linear scan of
//! each body in token order, which over-approximates branches.

// the taint fixpoint mutates `nodes[i]` while reading callee entries by
// resolved index, so the index loop is not iterator-rewritable
#![allow(clippy::needless_range_loop)]

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use crate::lexer::{str_content, Kind};
use crate::report::{Finding, Report};
use crate::scan::{comment_directive, Directive, SourceFile};

/// All rule names, in report order.
pub const RULES: &[&str] = &[
    "hash-iteration",
    "thread-spawn",
    "net-io",
    "dp-flow",
    "dp-noise",
    "unsafe-safety",
    "env-registry",
    "doc-drift",
];

/// What to scan and where the privileged modules live.
pub struct LintConfig {
    /// The crate source tree — all rules run here.
    pub src_root: PathBuf,
    /// Extra trees (benches, tests) — hygiene rules only.
    pub aux_roots: Vec<PathBuf>,
    /// README for the doc-drift env-table check.
    pub readme: Option<PathBuf>,
    /// The env registry module (exempt from `env-registry`).
    pub env_rel: String,
    /// The thread-pool module (exempt from `thread-spawn`).
    pub pool_rel: String,
    /// The replica-transport module (exempt from `net-io`).
    pub transport_rel: String,
    /// Dir prefixes (with trailing `/`) where `hash-iteration` applies.
    pub determinism_dirs: Vec<String>,
}

impl LintConfig {
    /// Config for a bare source tree (fixtures); no README, no aux roots.
    pub fn for_tree(src_root: &Path) -> LintConfig {
        LintConfig {
            src_root: src_root.to_path_buf(),
            aux_roots: Vec::new(),
            readme: None,
            env_rel: "runtime/env.rs".to_string(),
            pool_rel: "runtime/pool.rs".to_string(),
            transport_rel: "coordinator/transport.rs".to_string(),
            determinism_dirs: ["kernels/", "engine/", "coordinator/", "nlg/", "audit/", "serve/"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        }
    }

    /// Config for the real repository layout rooted at `repo_root`.
    pub fn for_repo(repo_root: &Path) -> LintConfig {
        let rust = repo_root.join("rust");
        let mut cfg = LintConfig::for_tree(&rust.join("src"));
        cfg.aux_roots = vec![rust.join("benches"), rust.join("tests")];
        cfg.readme = Some(repo_root.join("README.md"));
        cfg
    }
}

/// Recursively collect `.rs` files under `root`, sorted for deterministic
/// scan (and report) order.
fn rs_files(root: &Path) -> Vec<(PathBuf, String)> {
    fn walk(dir: &Path, root: &Path, out: &mut Vec<(PathBuf, String)>) {
        let rd = match std::fs::read_dir(dir) {
            Ok(rd) => rd,
            Err(_) => return,
        };
        let mut entries: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, root, out);
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                let rel = p
                    .strip_prefix(root)
                    .unwrap_or(&p)
                    .to_string_lossy()
                    .replace('\\', "/");
                out.push((p, rel));
            }
        }
    }
    let mut out = Vec::new();
    walk(root, root, &mut out);
    out
}

struct Ctx<'a> {
    cfg: &'a LintConfig,
    report: Report,
}

impl Ctx<'_> {
    fn emit(&mut self, sf: &SourceFile, rule: &'static str, line: usize, message: String) {
        let f = Finding { rule, file: sf.rel.clone(), line, message };
        if sf.is_allowed(rule, line) {
            self.report.allowed.push(f);
        } else {
            self.report.findings.push(f);
        }
    }
}

fn code_indices(sf: &SourceFile) -> Vec<usize> {
    (0..sf.toks.len()).filter(|&i| sf.toks[i].kind != Kind::Comment).collect()
}

// ---------------------------------------------------------------- hygiene

fn rule_unsafe(ctx: &mut Ctx, sf: &SourceFile) {
    let code = code_indices(sf);
    for (ci, &ti) in code.iter().enumerate() {
        let t = &sf.toks[ti];
        if t.kind != Kind::Ident || t.text != "unsafe" {
            continue;
        }
        let next = code.get(ci + 1).map(|&j| sf.toks[j].text.as_str());
        let what = match next {
            Some("{") => "block",
            Some("impl") => "impl",
            _ => continue, // `unsafe fn` declarations are callee-side
        };
        let covered = sf.toks.iter().any(|c| {
            c.kind == Kind::Comment
                && c.text.contains("SAFETY")
                && c.line <= t.line
                && c.line + 6 >= t.line
        });
        if !covered {
            ctx.emit(
                sf,
                "unsafe-safety",
                t.line,
                format!("`unsafe` {what} without a `// SAFETY:` comment on the preceding lines"),
            );
        }
    }
}

fn rule_thread(ctx: &mut Ctx, sf: &SourceFile) {
    if sf.rel == ctx.cfg.pool_rel {
        return;
    }
    let code = code_indices(sf);
    for w in 0..code.len().saturating_sub(2) {
        let [a, b, c] = [&sf.toks[code[w]], &sf.toks[code[w + 1]], &sf.toks[code[w + 2]]];
        if a.kind == Kind::Ident
            && a.text == "thread"
            && b.text == "::"
            && matches!(c.text.as_str(), "spawn" | "scope" | "Builder")
            && !sf.in_test(a.line)
        {
            ctx.emit(
                sf,
                "thread-spawn",
                a.line,
                format!(
                    "std::thread::{} outside runtime/pool.rs — route parallelism through the \
                     worker pool so reductions stay in fixed order",
                    c.text
                ),
            );
        }
    }
}

/// Raw `std::net` use outside the sanctioned transport module: ad-hoc
/// sockets bypass the framed, CRC-checked, deadline-bounded exchange layer
/// (and its wire accounting), so replica traffic must go through
/// `coordinator/transport.rs`.  Matches `net :: <Ident>` triples (plain
/// imports, `std::net::TcpStream::connect`, ...) and `net :: {` group
/// imports; tests may open raw sockets (fault injection needs them).
fn rule_net(ctx: &mut Ctx, sf: &SourceFile) {
    if sf.rel == ctx.cfg.transport_rel {
        return;
    }
    let code = code_indices(sf);
    for w in 0..code.len().saturating_sub(2) {
        let [a, b, c] = [&sf.toks[code[w]], &sf.toks[code[w + 1]], &sf.toks[code[w + 2]]];
        if a.kind == Kind::Ident
            && a.text == "net"
            && b.text == "::"
            && (c.kind == Kind::Ident || c.text == "{")
            && !sf.in_test(a.line)
        {
            ctx.emit(
                sf,
                "net-io",
                a.line,
                "raw std::net use outside coordinator/transport.rs — sockets must go through \
                 the framed transport layer so exchanges stay CRC-checked, deadline-bounded \
                 and wire-accounted"
                    .to_string(),
            );
        }
    }
}

fn rule_env(ctx: &mut Ctx, sf: &SourceFile, exempt: bool) {
    if exempt {
        return;
    }
    let code = code_indices(sf);
    for w in 0..code.len().saturating_sub(2) {
        let [a, b, c] = [&sf.toks[code[w]], &sf.toks[code[w + 1]], &sf.toks[code[w + 2]]];
        if a.kind == Kind::Ident && a.text == "env" && b.text == "::" && c.text == "var" {
            ctx.emit(
                sf,
                "env-registry",
                a.line,
                "raw std::env::var read — declare the knob in runtime/env.rs and use its typed \
                 accessor"
                    .to_string(),
            );
        }
    }
    for t in &sf.toks {
        if let Some(s) = str_content(t) {
            if s.starts_with("FASTDP_") {
                ctx.emit(
                    sf,
                    "env-registry",
                    t.line,
                    format!("knob name {s:?} outside the runtime/env.rs registry"),
                );
            }
        }
    }
}

// ----------------------------------------------------------- determinism

const ITER_METHODS: &[&str] =
    &["iter", "keys", "values", "into_iter", "into_keys", "into_values", "drain"];
const EVIDENCE_IDENTS: &[&str] = &[
    "sum", "product", "push", "extend", "collect", "insert", "entry", "or_insert",
    "or_insert_with", "fold", "write", "push_str",
];
const EVIDENCE_PUNCTS: &[&str] = &["+=", "-=", "*=", "/="];

fn is_evidence(sf: &SourceFile, ti: usize) -> bool {
    let t = &sf.toks[ti];
    match t.kind {
        Kind::Ident => EVIDENCE_IDENTS.contains(&t.text.as_str()),
        Kind::Punct => EVIDENCE_PUNCTS.contains(&t.text.as_str()),
        _ => false,
    }
}

/// Flag iteration over hash-ordered containers that feeds accumulation or
/// ordered output.  Detection is per-file and name-based: bindings whose
/// declared type or initializer mentions `HashMap`/`HashSet` (or calls an
/// in-file fn returning one) become "hash symbols"; a `for` loop or
/// iterator-method chain rooted at a hash symbol with accumulation
/// evidence (`+=`, `.sum()`, `.push(...)`, `.insert(...)`, …) in its body
/// or statement is a finding.
fn rule_hash(ctx: &mut Ctx, sf: &SourceFile) {
    let code = code_indices(sf);
    let tx = |ci: usize| sf.toks[code[ci]].text.as_str();
    let is_hash_name = |s: &str| s == "HashMap" || s == "HashSet";

    // in-file fns returning a hash container
    let mut hash_fns: BTreeSet<String> = BTreeSet::new();
    for f in &sf.fns {
        if let Some((open, _)) = f.body {
            let sig: Vec<&str> = sf.toks[f.name_idx..open]
                .iter()
                .filter(|t| t.kind != Kind::Comment)
                .map(|t| t.text.as_str())
                .collect();
            if let Some(arrow) = sig.iter().position(|&s| s == "->") {
                if sig[arrow..].iter().any(|&s| is_hash_name(s)) {
                    hash_fns.insert(f.name.clone());
                }
            }
        }
    }

    // hash-typed bindings: `name: … HashMap …` and `let name = … HashMap/… hashfn( …`
    let mut hash_vars: BTreeSet<String> = BTreeSet::new();
    for ci in 0..code.len() {
        let t = &sf.toks[code[ci]];
        if t.kind == Kind::Ident && ci + 1 < code.len() && tx(ci + 1) == ":" {
            let mut angle = 0i32;
            for k in ci + 2..(ci + 32).min(code.len()) {
                match tx(k) {
                    "<" => angle += 1,
                    ">" => angle -= 1,
                    ">>" => angle -= 2,
                    "," | ";" | ")" | "{" | "=" if angle <= 0 => break,
                    s if is_hash_name(s) => {
                        hash_vars.insert(t.text.clone());
                        break;
                    }
                    _ => {}
                }
            }
        }
        if t.kind == Kind::Ident && t.text == "let" {
            let mut j = ci + 1;
            if j < code.len() && tx(j) == "mut" {
                j += 1;
            }
            if j + 1 < code.len() && sf.toks[code[j]].kind == Kind::Ident {
                let name = sf.toks[code[j]].text.clone();
                for k in j + 1..(j + 80).min(code.len()) {
                    match tx(k) {
                        ";" => break,
                        s if is_hash_name(s) => {
                            hash_vars.insert(name);
                            break;
                        }
                        s if hash_fns.contains(s) && k + 1 < code.len() && tx(k + 1) == "(" => {
                            hash_vars.insert(name);
                            break;
                        }
                        _ => {}
                    }
                }
            }
        }
    }

    let mut flagged: BTreeSet<usize> = BTreeSet::new(); // lines already reported
    let mut hit = |ctx: &mut Ctx, line: usize, sym: &str, via: &str| {
        if flagged.insert(line) && !sf.in_test(line) {
            ctx.emit(
                sf,
                "hash-iteration",
                line,
                format!(
                    "iteration over hash-ordered `{sym}` feeds {via} — hasher order makes the \
                     result nondeterministic; use BTreeMap/sorted keys"
                ),
            );
        }
    };

    // for-loops: `for pat in <hash-rooted expr> { …evidence… }`
    for ci in 0..code.len() {
        if tx(ci) != "for" || sf.toks[code[ci]].kind != Kind::Ident {
            continue;
        }
        if ci + 1 < code.len() && tx(ci + 1) == "<" {
            continue; // for<'a> HRTB
        }
        // find `in` at depth 0 before the body `{`
        let mut depth = 0i32;
        let mut in_at = None;
        for k in ci + 1..(ci + 60).min(code.len()) {
            match tx(k) {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => break,
                "in" if depth == 0 => {
                    in_at = Some(k);
                    break;
                }
                _ => {}
            }
        }
        let Some(in_at) = in_at else { continue }; // `impl … for …`
        // root of the iterated expression
        let mut e = in_at + 1;
        while e < code.len() && matches!(tx(e), "&" | "mut") {
            e += 1;
        }
        if e >= code.len() || sf.toks[code[e]].kind != Kind::Ident {
            continue;
        }
        let mut sym = sf.toks[code[e]].text.clone();
        if sym == "self" && e + 2 < code.len() && tx(e + 1) == "." {
            sym = sf.toks[code[e + 2]].text.clone();
        }
        let rooted = hash_vars.contains(&sym)
            || (hash_fns.contains(&sym) && e + 1 < code.len() && tx(e + 1) == "(");
        if !rooted {
            continue;
        }
        // body range: first `{` at depth 0 after `in`
        let mut depth = 0i32;
        let mut open = None;
        for k in in_at + 1..(in_at + 80).min(code.len()) {
            match tx(k) {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "{" if depth == 0 => {
                    open = Some(code[k]);
                    break;
                }
                _ => {}
            }
        }
        let Some(open) = open else { continue };
        let close = sf.match_brace(open);
        if (open..close).any(|ti| is_evidence(sf, ti)) {
            hit(ctx, sf.toks[code[ci]].line, &sym, "accumulation/ordered output in the loop body");
        }
    }

    // method chains: `sym.iter()… / sym(…).into_keys()…` followed by
    // evidence before the end of the statement
    for ci in 0..code.len() {
        let t = &sf.toks[code[ci]];
        if t.kind != Kind::Ident {
            continue;
        }
        let mut probe = None; // index after the iteration-method call opens
        if hash_vars.contains(&t.text)
            && ci + 3 < code.len()
            && tx(ci + 1) == "."
            && ITER_METHODS.contains(&tx(ci + 2))
            && tx(ci + 3) == "("
        {
            probe = Some(ci + 4);
        } else if hash_fns.contains(&t.text) && ci + 1 < code.len() && tx(ci + 1) == "(" {
            // skip the call's argument list, then look for `.iter_method(`
            let mut depth = 0i32;
            let mut k = ci + 1;
            while k < code.len() {
                match tx(k) {
                    "(" => depth += 1,
                    ")" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                k += 1;
            }
            if k + 3 < code.len()
                && tx(k + 1) == "."
                && ITER_METHODS.contains(&tx(k + 2))
                && tx(k + 3) == "("
            {
                probe = Some(k + 4);
            }
        }
        let Some(start) = probe else { continue };
        let mut depth = 0i32;
        for k in start..(start + 150).min(code.len()) {
            match tx(k) {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                ";" if depth <= 0 => break,
                _ => {}
            }
            if is_evidence(sf, code[k]) {
                hit(ctx, t.line, &t.text, "an accumulating iterator chain");
                break;
            }
        }
    }
}

// -------------------------------------------------------------- DP taint

#[derive(Debug)]
enum Event {
    Call { name: String, qual: Option<String>, line: usize },
    Marker { line: usize },
}

#[derive(Default, Clone, Copy)]
struct Flags {
    source: bool,
    boundary: bool,
    noise: bool,
    sink: bool,
}

struct FnNode {
    file: usize,
    name: String,
    line: usize,
    flags: Flags,
    events: Vec<Event>,
    emits: bool,
}

fn fn_flags(directives: &[Directive]) -> Flags {
    let mut f = Flags::default();
    for d in directives {
        match d {
            Directive::PerSampleGrad => f.source = true,
            Directive::ClipBoundary => f.boundary = true,
            Directive::NoiseSite => f.noise = true,
            Directive::DpSink => f.sink = true,
        }
    }
    f
}

/// Extract call sites and dp-sink markers from one fn body, in token order.
fn body_events(sf: &SourceFile, open: usize, close: usize) -> Vec<Event> {
    let mut events = Vec::new();
    let idx: Vec<usize> = (open + 1..close).collect();
    let code: Vec<usize> = idx.iter().copied().filter(|&i| sf.toks[i].kind != Kind::Comment).collect();
    let pos_in_code: BTreeMap<usize, usize> = code.iter().enumerate().map(|(k, &i)| (i, k)).collect();
    for &ti in &idx {
        let t = &sf.toks[ti];
        if t.kind == Kind::Comment {
            if let Some(Ok(Directive::DpSink)) = comment_directive(&t.text) {
                events.push(Event::Marker { line: t.line });
            }
            continue;
        }
        if t.kind != Kind::Ident {
            continue;
        }
        let ci = pos_in_code[&ti];
        if ci + 1 >= code.len() || sf.toks[code[ci + 1]].text != "(" {
            continue;
        }
        // not a nested `fn name(` definition
        if ci > 0 && sf.toks[code[ci - 1]].text == "fn" {
            continue;
        }
        let qual = if ci >= 2 && sf.toks[code[ci - 1]].text == "::" {
            let q = &sf.toks[code[ci - 2]];
            if q.kind == Kind::Ident && !matches!(q.text.as_str(), "crate" | "super" | "self") {
                Some(q.text.clone())
            } else {
                None
            }
        } else {
            None
        };
        events.push(Event::Call { name: t.text.clone(), qual, line: t.line });
    }
    events
}

/// The `dp-flow` + `dp-noise` passes over the whole source set.
fn rule_dp(ctx: &mut Ctx, files: &[SourceFile]) {
    // fn table (non-test fns with bodies)
    let mut nodes: Vec<FnNode> = Vec::new();
    let mut table: BTreeMap<String, Vec<usize>> = BTreeMap::new();
    for (fi, sf) in files.iter().enumerate() {
        for f in &sf.fns {
            let Some((open, close)) = f.body else { continue };
            if sf.in_test(f.line) {
                continue;
            }
            let flags = fn_flags(&f.directives);
            let n = FnNode {
                file: fi,
                name: f.name.clone(),
                line: f.line,
                flags,
                events: body_events(sf, open, close),
                emits: flags.source,
            };
            table.entry(f.name.clone()).or_default().push(nodes.len());
            nodes.push(n);
        }
    }

    let resolve_ids = |name: &str, qual: &Option<String>, file: usize, nodes: &[FnNode]| -> Vec<usize> {
        let Some(all) = table.get(name) else { return Vec::new() };
        if let Some(q) = qual {
            let matched: Vec<usize> = all
                .iter()
                .copied()
                .filter(|&n| files[nodes[n].file].module_segs().iter().any(|s| s == q))
                .collect();
            if !matched.is_empty() {
                return matched;
            }
        }
        let local: Vec<usize> = all.iter().copied().filter(|&n| nodes[n].file == file).collect();
        if !local.is_empty() {
            return local;
        }
        all.clone()
    };

    // fixpoint: a fn "emits taint" if annotated per-sample-grad, or its
    // linear body scan ends tainted; clip-boundary fns never emit.
    for _ in 0..nodes.len() + 1 {
        let mut changed = false;
        for i in 0..nodes.len() {
            if nodes[i].flags.boundary {
                continue; // emits stays false
            }
            let mut state = nodes[i].flags.source;
            for ev in &nodes[i].events {
                if let Event::Call { name, qual, .. } = ev {
                    let ids = resolve_ids(name, qual, nodes[i].file, &nodes);
                    if ids.iter().any(|&n| nodes[n].flags.boundary) {
                        state = false;
                    } else if ids.iter().any(|&n| nodes[n].emits) {
                        state = true;
                    }
                }
            }
            let emits = nodes[i].flags.source || state;
            if emits != nodes[i].emits {
                nodes[i].emits = emits;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    // findings: taint must be clear at every sink call / dp-sink marker
    let mut noise_called = false;
    let mut findings: Vec<(usize, usize, String)> = Vec::new(); // (file, line, msg)
    for i in 0..nodes.len() {
        let mut state = nodes[i].flags.source;
        for ev in &nodes[i].events {
            match ev {
                Event::Marker { line } => {
                    if state {
                        findings.push((
                            nodes[i].file,
                            *line,
                            format!(
                                "per-sample-tainted data live at a dp-sink marker in `{}` \
                                 without crossing a clip boundary",
                                nodes[i].name
                            ),
                        ));
                        state = false; // report each marker breach once
                    }
                }
                Event::Call { name, qual, line } => {
                    let ids = resolve_ids(name, qual, nodes[i].file, &nodes);
                    if ids.iter().any(|&n| nodes[n].flags.noise) {
                        noise_called = true;
                    }
                    if state && ids.iter().any(|&n| nodes[n].flags.sink) {
                        findings.push((
                            nodes[i].file,
                            *line,
                            format!(
                                "per-sample-tainted data reaches dp-sink `{name}` in `{}` \
                                 without crossing a clip boundary",
                                nodes[i].name
                            ),
                        ));
                    }
                    if ids.iter().any(|&n| nodes[n].flags.boundary) {
                        state = false;
                    } else if ids.iter().any(|&n| nodes[n].emits) {
                        state = true;
                    }
                }
            }
        }
    }
    for (fi, line, msg) in findings {
        ctx.emit(&files[fi], "dp-flow", line, msg);
    }

    // dp-noise: sources declared => a noise-site must exist and be called
    let first_source = nodes.iter().find(|n| n.flags.source);
    let first_noise = nodes.iter().find(|n| n.flags.noise);
    match (first_source, first_noise) {
        (Some(src), None) => ctx.emit(
            &files[src.file],
            "dp-noise",
            src.line,
            "per-sample-grad sources are annotated but no fn is annotated noise-site — the DP \
             mechanism has no noise injection point"
                .to_string(),
        ),
        (Some(_), Some(noise)) if !noise_called => ctx.emit(
            &files[noise.file],
            "dp-noise",
            noise.line,
            format!("noise-site `{}` is never called outside tests", noise.name),
        ),
        _ => {}
    }
}

// -------------------------------------------------------------- doc drift

fn rule_doc(ctx: &mut Ctx, files: &[SourceFile], cfg: &LintConfig) {
    // lib.rs layer map vs `pub mod` set
    if let Some(lib) = files.iter().find(|f| f.rel == "lib.rs") {
        let code = code_indices(lib);
        let mut mods: BTreeMap<String, usize> = BTreeMap::new(); // name -> line
        for w in 0..code.len().saturating_sub(3) {
            let t = |k: usize| &lib.toks[code[w + k]];
            if t(0).text == "pub" && t(1).text == "mod" && t(2).kind == Kind::Ident && t(3).text == ";"
            {
                mods.insert(t(2).text.clone(), t(2).line);
            }
        }
        let mut bullets: BTreeMap<String, usize> = BTreeMap::new();
        for t in &lib.toks {
            if t.kind != Kind::Comment || !t.text.starts_with("//!") || !t.text.contains("* [`") {
                continue;
            }
            if let Some(frag) = t.text.split("* [`").nth(1) {
                if let Some(name) = frag.split("`]").next() {
                    if !name.is_empty() && name.chars().all(|c| c.is_alphanumeric() || c == '_') {
                        bullets.insert(name.to_string(), t.line);
                    }
                }
            }
        }
        if !bullets.is_empty() {
            for (m, line) in &mods {
                if !bullets.contains_key(m) {
                    ctx.emit(
                        lib,
                        "doc-drift",
                        *line,
                        format!("module `{m}` is missing from the lib.rs layer map"),
                    );
                }
            }
            for (b, line) in &bullets {
                if !mods.contains_key(b) {
                    ctx.emit(
                        lib,
                        "doc-drift",
                        *line,
                        format!("lib.rs layer map lists `{b}` but there is no such `pub mod`"),
                    );
                }
            }
        }
    }

    // README env-var table vs the runtime/env.rs registry
    let (Some(readme_path), Some(env_file)) =
        (cfg.readme.as_ref(), files.iter().find(|f| f.rel == cfg.env_rel))
    else {
        return;
    };
    let Ok(readme) = std::fs::read_to_string(readme_path) else { return };
    // Registry names only: skip the file's test mod (it asserts on the bare
    // "FASTDP_" prefix) and require at least one character after the prefix.
    let declared: BTreeSet<String> = env_file
        .toks
        .iter()
        .filter(|t| !env_file.in_test(t.line))
        .filter_map(str_content)
        .filter(|s| s.starts_with("FASTDP_") && s.len() > "FASTDP_".len())
        .map(String::from)
        .collect();
    let mut rows: BTreeMap<String, usize> = BTreeMap::new();
    for (ln, line) in readme.lines().enumerate() {
        let lt = line.trim_start();
        if !lt.starts_with('|') {
            continue;
        }
        for part in lt.split('`') {
            if part.starts_with("FASTDP_")
                && part.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
            {
                rows.entry(part.to_string()).or_insert(ln + 1);
            }
        }
    }
    let readme_sf = SourceFile::from_source(readme_path.clone(), "README.md", "");
    for (knob, line) in &rows {
        if !declared.contains(knob) {
            ctx.emit(
                &readme_sf,
                "doc-drift",
                *line,
                format!("README documents `{knob}` but runtime/env.rs does not declare it"),
            );
        }
    }
    for knob in &declared {
        if !rows.contains_key(knob) {
            ctx.emit(
                &readme_sf,
                "doc-drift",
                1,
                format!("knob `{knob}` (runtime/env.rs) is missing from the README env-var table"),
            );
        }
    }
}

// ------------------------------------------------------------------ entry

/// Run every rule over the configured trees.
pub fn run(cfg: &LintConfig) -> Report {
    let mut src_files: Vec<SourceFile> = Vec::new();
    for (p, rel) in rs_files(&cfg.src_root) {
        if let Ok(sf) = SourceFile::load(&p, &rel) {
            src_files.push(sf);
        }
    }
    let mut aux_files: Vec<SourceFile> = Vec::new();
    for root in &cfg.aux_roots {
        let prefix = root.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        for (p, rel) in rs_files(root) {
            if let Ok(sf) = SourceFile::load(&p, &format!("{prefix}/{rel}")) {
                aux_files.push(sf);
            }
        }
    }

    let mut ctx = Ctx { cfg, report: Report::default() };
    for sf in &src_files {
        rule_unsafe(&mut ctx, sf);
        rule_thread(&mut ctx, sf);
        rule_net(&mut ctx, sf);
        rule_env(&mut ctx, sf, sf.rel == cfg.env_rel);
        if cfg.determinism_dirs.iter().any(|d| sf.rel.starts_with(d.as_str())) {
            rule_hash(&mut ctx, sf);
        }
    }
    for sf in &aux_files {
        rule_unsafe(&mut ctx, sf);
        rule_env(&mut ctx, sf, false);
    }
    rule_dp(&mut ctx, &src_files);
    rule_doc(&mut ctx, &src_files, cfg);

    ctx.report.files_scanned = src_files.len() + aux_files.len();
    ctx.report.normalize();
    ctx.report
}
