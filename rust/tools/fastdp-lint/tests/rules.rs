//! Fixture tests: every rule fires on its `*_bad` tree and stays silent
//! on its `*_good` counterpart.  The fixtures under `tests/fixtures/` are
//! data (never compiled) — each is a miniature `src/` tree laid out the
//! way `LintConfig::for_tree` expects.

use std::path::PathBuf;

use fastdp_lint::{run, LintConfig, Report};

fn lint(fixture: &str) -> Report {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(fixture);
    assert!(root.is_dir(), "missing fixture tree {}", root.display());
    run(&LintConfig::for_tree(&root))
}

fn fired(r: &Report) -> Vec<&'static str> {
    r.findings.iter().map(|f| f.rule).collect()
}

#[test]
fn hash_iteration_fires_on_hash_loops() {
    let bad = lint("hash_iter_bad");
    let rules = fired(&bad);
    assert!(rules.contains(&"hash-iteration"), "{:?}", bad.findings);
    // both the accumulating for-loop and the .keys() ordering loop
    assert!(rules.iter().filter(|r| **r == "hash-iteration").count() >= 2, "{:?}", bad.findings);
}

#[test]
fn hash_iteration_silent_on_btreemap_and_lookups() {
    let good = lint("hash_iter_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn thread_spawn_fires_outside_pool() {
    let bad = lint("thread_bad");
    assert!(fired(&bad).contains(&"thread-spawn"), "{:?}", bad.findings);
}

#[test]
fn thread_spawn_exempts_pool_and_honors_allow() {
    let good = lint("thread_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
    // the annotated spawn is recorded as allowed, not dropped silently
    assert_eq!(good.allowed.len(), 1, "{:?}", good.allowed);
    assert_eq!(good.allowed[0].rule, "thread-spawn");
}

#[test]
fn thread_spawn_fires_in_serve_tree() {
    // serve/ is scheduler territory: all parallelism belongs to the pool
    let bad = lint("serve_thread_bad");
    assert!(fired(&bad).contains(&"thread-spawn"), "{:?}", bad.findings);
}

#[test]
fn thread_spawn_honors_allow_in_serve_tree() {
    let good = lint("serve_thread_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
    assert_eq!(good.allowed.len(), 1, "{:?}", good.allowed);
    assert_eq!(good.allowed[0].rule, "thread-spawn");
}

#[test]
fn net_io_fires_on_raw_sockets_outside_transport() {
    let bad = lint("net_io_bad");
    assert!(fired(&bad).contains(&"net-io"), "{:?}", bad.findings);
}

#[test]
fn net_io_exempts_transport_and_honors_allow() {
    let good = lint("net_io_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
    // the annotated probe is recorded as allowed, not dropped silently;
    // the transport module itself is exempt by path (no entry at all)
    assert_eq!(good.allowed.len(), 1, "{:?}", good.allowed);
    assert_eq!(good.allowed[0].rule, "net-io");
}

#[test]
fn dp_flow_fires_on_unclipped_sink() {
    let bad = lint("taint_bad");
    let hits: Vec<_> = bad.findings.iter().filter(|f| f.rule == "dp-flow").collect();
    assert_eq!(hits.len(), 1, "{:?}", bad.findings);
    assert!(hits[0].message.contains("accumulate"), "{}", hits[0].message);
}

#[test]
fn dp_flow_silent_when_clip_precedes_sink() {
    let good = lint("taint_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn dp_flow_fires_on_simd_tier_unclipped_sink() {
    // the simd tier's dh/dfeat panel kernels are per-sample-grad sources
    // and its position epilogue the clip boundary; the rule must cover
    // that shape of the flow too
    let bad = lint("simd_taint_bad");
    let hits: Vec<_> = bad.findings.iter().filter(|f| f.rule == "dp-flow").collect();
    assert_eq!(hits.len(), 1, "{:?}", bad.findings);
    assert!(hits[0].message.contains("accumulate_factor_rows"), "{}", hits[0].message);
    assert!(hits[0].message.contains("run_train_simd"), "{}", hits[0].message);
}

#[test]
fn dp_flow_silent_when_simd_epilogue_clips_before_sink() {
    let good = lint("simd_taint_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn dp_flow_fires_on_audit_loss_readout_without_training_boundary() {
    // the audit harness shape: paired canary datasets are per-sample data
    // (the source), the session training loop is the clip boundary, and
    // the NLL readout is the sink — reading the loss of raw paired data
    // without a training in between is a flow violation
    let bad = lint("audit_taint_bad");
    let hits: Vec<_> = bad.findings.iter().filter(|f| f.rule == "dp-flow").collect();
    assert_eq!(hits.len(), 1, "{:?}", bad.findings);
    assert!(hits[0].message.contains("sequence_nll"), "{}", hits[0].message);
    assert!(hits[0].message.contains("mi_attack"), "{}", hits[0].message);
}

#[test]
fn dp_flow_silent_when_audit_trains_between_pairing_and_readout() {
    let good = lint("audit_taint_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn dp_noise_fires_when_no_noise_site_declared() {
    let bad = lint("noise_bad");
    assert_eq!(fired(&bad), vec!["dp-noise"], "{:?}", bad.findings);
}

#[test]
fn unsafe_fires_without_safety_comment() {
    let bad = lint("unsafe_bad");
    assert_eq!(fired(&bad), vec!["unsafe-safety"], "{:?}", bad.findings);
    let good = lint("unsafe_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn env_registry_fires_on_raw_reads_and_literals() {
    let bad = lint("env_bad");
    let rules = fired(&bad);
    // one finding for the raw env::var call, one for the FASTDP_ literal
    assert_eq!(rules.iter().filter(|r| **r == "env-registry").count(), 2, "{:?}", bad.findings);
    let good = lint("env_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}

#[test]
fn doc_drift_fires_on_stale_layer_map() {
    let bad = lint("doc_drift_bad");
    let rules = fired(&bad);
    // one missing module, one stale bullet
    assert_eq!(rules.iter().filter(|r| **r == "doc-drift").count(), 2, "{:?}", bad.findings);
    let good = lint("doc_drift_good");
    assert!(good.findings.is_empty(), "{:?}", good.findings);
}
