pub fn spawn_watchdog() {
    // fastdp-lint: allow(thread-spawn) serve watchdog outlives the pool
    let h = std::thread::spawn(|| 1 + 1);
    let _ = h.join();
}
