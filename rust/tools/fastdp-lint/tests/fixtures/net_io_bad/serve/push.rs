//! A metrics pusher that opens its own socket instead of going through the
//! transport layer — the `net-io` rule must fire.

pub fn push_metrics() -> std::io::Result<()> {
    let stream = std::net::TcpStream::connect("127.0.0.1:9000")?;
    let _ = stream;
    Ok(())
}
