pub fn spawn_per_tenant() {
    let h = std::thread::spawn(|| 1 + 1);
    let _ = h.join();
}
