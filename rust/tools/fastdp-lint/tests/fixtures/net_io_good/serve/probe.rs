//! A readiness probe with an explicit allow annotation — suppressed, but
//! surfaced in the report's allowed list.

pub fn probe_port() -> bool {
    // fastdp-lint: allow(net-io) readiness probe runs before the transport exists
    std::net::TcpStream::connect("127.0.0.1:1").is_ok()
}
