//! The sanctioned socket layer — raw `std::net` is allowed here by path.

pub fn bind_loopback() -> std::io::Result<std::net::TcpListener> {
    std::net::TcpListener::bind("127.0.0.1:0")
}
