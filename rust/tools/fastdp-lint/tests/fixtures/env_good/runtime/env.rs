// the registry module — the one place env reads are allowed
pub fn threads() -> Option<usize> {
    std::env::var("FASTDP_THREADS").ok()?.parse().ok()
}
