pub fn worker_count() -> usize {
    crate::runtime::env::threads().unwrap_or(1)
}
