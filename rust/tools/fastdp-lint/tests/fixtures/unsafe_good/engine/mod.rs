pub fn first(v: &[f32]) -> f32 {
    assert!(!v.is_empty());
    // SAFETY: the assert above guarantees index 0 is in bounds
    unsafe { *v.get_unchecked(0) }
}
