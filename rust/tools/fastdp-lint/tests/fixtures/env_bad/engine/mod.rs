pub fn threads() -> Option<usize> {
    std::env::var("FASTDP_THREADS").ok()?.parse().ok()
}
