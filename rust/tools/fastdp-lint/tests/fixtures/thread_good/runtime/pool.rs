// the pool module owns thread creation — exempt from thread-spawn
pub fn start_worker() {
    let h = std::thread::spawn(|| 1 + 1);
    let _ = h.join();
}
