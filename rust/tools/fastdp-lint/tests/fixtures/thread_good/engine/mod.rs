pub fn run_replicas() {
    // fastdp-lint: allow(thread-spawn) long-lived replica workers
    let h = std::thread::spawn(|| 1 + 1);
    let _ = h.join();
}
