// fastdp-lint: per-sample-grad
pub fn backward(x: f32) -> f32 {
    x * 2.0
}

// fastdp-lint: dp-sink
pub fn accumulate(_g: f32) {}

// fastdp-lint: noise-site
pub fn add_noise(g: f32) -> f32 {
    g + 0.1
}

pub fn train(x: f32) -> f32 {
    let g = backward(x);
    accumulate(g); // unclipped per-sample gradient hits the shared sum
    add_noise(0.0)
}
