// fastdp-lint: per-sample-grad
pub fn backward(x: f32) -> f32 {
    x * 2.0
}

// fastdp-lint: clip-boundary
pub fn clip_in_place(g: f32) -> f32 {
    g.min(1.0)
}

// fastdp-lint: dp-sink
pub fn accumulate(_g: f32) {}

// fastdp-lint: noise-site
pub fn add_noise(g: f32) -> f32 {
    g + 0.1
}

pub fn train(x: f32) -> f32 {
    let g = backward(x);
    let g = clip_in_place(g);
    accumulate(g);
    add_noise(0.0)
}
