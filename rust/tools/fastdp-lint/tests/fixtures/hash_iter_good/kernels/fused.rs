use std::collections::{BTreeMap, HashMap};

pub fn fold_grads(grads: &BTreeMap<u64, f32>) -> f32 {
    let mut total = 0.0_f32;
    for (_k, v) in grads.iter() {
        total += *v;
    }
    total
}

// point lookups on a HashMap are fine — only iteration order is tainted
pub fn lookup(slots: &HashMap<String, usize>, name: &str) -> Option<usize> {
    slots.get(name).copied()
}
