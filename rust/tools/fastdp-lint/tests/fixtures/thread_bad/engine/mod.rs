pub fn run_parallel() {
    let h = std::thread::spawn(|| 1 + 1);
    let _ = h.join();
}
