// fastdp-lint: per-sample-grad
pub fn backward(x: f32) -> f32 {
    x * 2.0
}

// fastdp-lint: clip-boundary
pub fn clip_in_place(g: f32) -> f32 {
    g.min(1.0)
}

// fastdp-lint: dp-sink
pub fn accumulate(_g: f32) {}

// per-sample sources exist but nothing is annotated noise-site: the
// mechanism clips yet never adds noise -> dp-noise must fire
pub fn train(x: f32) {
    let g = backward(x);
    let g = clip_in_place(g);
    accumulate(g);
}
