// fastdp-lint: per-sample-grad
pub fn paired_datasets(seed: u64) -> f32 {
    seed as f32
}

// fastdp-lint: clip-boundary
pub fn train_audit_model(d: f32) -> f32 {
    d.min(1.0)
}

// fastdp-lint: dp-sink
pub fn sequence_nll(_params: f32) -> f32 {
    0.0
}

pub fn mi_attack(seed: u64) -> f32 {
    let pair = paired_datasets(seed);
    // loss readout on the raw pair: no training boundary in between
    sequence_nll(pair)
}
