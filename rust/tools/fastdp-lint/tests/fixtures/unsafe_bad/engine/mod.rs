pub fn first(v: &[f32]) -> f32 {
    unsafe { *v.get_unchecked(0) }
}
