//! Layer map:
//!
//! * [`kernels`] — the math kernels.
//! * [`coordinator`] — listed here but no such module exists.

pub mod engine;
pub mod kernels;
