use std::collections::HashMap;

pub fn fold_grads(grads: &HashMap<u64, f32>) -> f32 {
    let mut total = 0.0_f32;
    for (_k, v) in grads.iter() {
        total += *v;
    }
    total
}

pub fn collect_names() -> Vec<String> {
    let mut slots = HashMap::new();
    slots.insert("b1".to_string(), 0usize);
    let mut out = Vec::new();
    for name in slots.keys() {
        out.push(name.clone());
    }
    out
}
