// fastdp-lint: per-sample-grad
pub fn dh_panel(x: f32) -> f32 {
    x * 2.0
}

// fastdp-lint: per-sample-grad
pub fn dfeat_panel(x: f32) -> f32 {
    x * 3.0
}

// fastdp-lint: clip-boundary
pub fn pos_epilogue(g: f32) -> f32 {
    g.min(1.0)
}

// fastdp-lint: dp-sink
pub fn accumulate_factor_rows(_g: f32) {}

// fastdp-lint: noise-site
pub fn add_noise(g: f32) -> f32 {
    g + 0.1
}

pub fn run_train_simd(x: f32) -> f32 {
    let g = dh_panel(x) + dfeat_panel(x);
    let g = pos_epilogue(g);
    accumulate_factor_rows(g);
    add_noise(0.0)
}
