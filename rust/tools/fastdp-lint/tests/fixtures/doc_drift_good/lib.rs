//! Layer map:
//!
//! * [`engine`] — execution.
//! * [`kernels`] — the math kernels.

pub mod engine;
pub mod kernels;
