//! Render and validate the `BENCH_privacy_audit.json` document.
//!
//! One row per grid cell.  JSON has no infinity, so a non-private cell
//! carries `"private": false` and the sentinel `-1` for `claimed_eps`;
//! skipped measurements (MI with zero trials, probes on non-private
//! cells, extraction when not requested) use `-1` sentinels too, so every
//! row has every key and downstream tooling never branches on presence.

use crate::util::json::{self, Json};

use super::CellOutcome;

/// Render the audit document.  `sweep` identifies the grid configuration
/// (quick vs full, trial count) exactly as the throughput bench does, so
/// comparisons only happen between like runs.
pub fn audit_json(cells: &[CellOutcome], sweep: &str) -> String {
    let row = |c: &CellOutcome| {
        let (mi_trials, mi_tp, mi_fp, mi_eps) = match &c.mi {
            Some(m) => (m.trials as f64, m.tp as f64, m.fp as f64, m.eps),
            None => (-1.0, -1.0, -1.0, -1.0),
        };
        let (sigma_hat, clip_ratio, probes_ok) = match &c.probes {
            Some((np, cp)) => (np.sigma_hat, cp.ratio, Json::Bool(np.ok && cp.ok)),
            None => (-1.0, -1.0, Json::Null),
        };
        let (x_match, x_rank, x_extracted) = match &c.extraction {
            Some(e) => (e.match_rate, e.rank as f64, Json::Bool(e.extracted)),
            None => (-1.0, -1.0, Json::Null),
        };
        json::obj(vec![
            ("model", Json::Str(c.model.clone())),
            ("method", Json::Str(c.method.clone())),
            ("eps_label", Json::Str(c.eps_label.clone())),
            ("tier", Json::Str(c.tier.clone())),
            ("fault", Json::Str(c.fault.clone())),
            ("private", Json::Bool(c.private)),
            (
                "claimed_eps",
                Json::Num(if c.claimed_eps.is_finite() { c.claimed_eps } else { -1.0 }),
            ),
            ("empirical_eps", Json::Num(c.empirical_eps)),
            ("flagged", Json::Bool(c.flagged)),
            ("mi_trials", Json::Num(mi_trials)),
            ("mi_tp", Json::Num(mi_tp)),
            ("mi_fp", Json::Num(mi_fp)),
            ("mi_eps", Json::Num(mi_eps)),
            ("sigma_claimed", Json::Num(c.sigma_claimed)),
            ("sigma_hat", Json::Num(sigma_hat)),
            ("clip_ratio", Json::Num(clip_ratio)),
            ("probes_ok", probes_ok),
            ("extract_match_rate", Json::Num(x_match)),
            ("extract_rank", Json::Num(x_rank)),
            ("extracted", x_extracted),
        ])
    };
    let doc = json::obj(vec![
        ("bench", Json::Str("privacy_audit".to_string())),
        ("created_by", Json::Str("benches/privacy_audit.rs".to_string())),
        ("sweep", Json::Str(sweep.to_string())),
        ("alpha", Json::Num(super::bound::ALPHA)),
        ("rows", Json::Arr(cells.iter().map(row).collect())),
    ]);
    json::write(&doc)
}

/// Validate an emitted `BENCH_privacy_audit.json` document: schema keys
/// plus the audit's core invariant — an unflagged private row really does
/// sit at `empirical_eps <= claimed_eps`.
pub fn validate_audit_json(src: &str) -> Result<(), String> {
    let v = json::parse(src)?;
    if v.get("bench").and_then(|b| b.as_str()) != Some("privacy_audit") {
        return Err("bench field is not \"privacy_audit\"".to_string());
    }
    if v.get("sweep").and_then(|s| s.as_str()).is_none() {
        return Err("missing sweep config string".to_string());
    }
    if v.get("alpha").and_then(|a| a.as_f64()).is_none() {
        return Err("missing numeric field \"alpha\"".to_string());
    }
    let rows = v
        .get("rows")
        .and_then(|r| r.as_arr())
        .ok_or_else(|| "missing rows array".to_string())?;
    if rows.is_empty() {
        return Err("rows array is empty".to_string());
    }
    for (i, row) in rows.iter().enumerate() {
        for key in ["model", "method", "eps_label", "tier", "fault"] {
            if row.get(key).and_then(|s| s.as_str()).is_none() {
                return Err(format!("row {i}: missing string field {key:?}"));
            }
        }
        for key in ["private", "flagged"] {
            if row.get(key).and_then(|b| b.as_bool()).is_none() {
                return Err(format!("row {i}: missing bool field {key:?}"));
            }
        }
        for key in [
            "claimed_eps",
            "empirical_eps",
            "mi_trials",
            "mi_tp",
            "mi_fp",
            "mi_eps",
            "sigma_claimed",
            "sigma_hat",
            "clip_ratio",
            "extract_match_rate",
            "extract_rank",
        ] {
            if row.get(key).and_then(|n| n.as_f64()).is_none() {
                return Err(format!("row {i}: missing numeric field {key:?}"));
            }
        }
        for key in ["probes_ok", "extracted"] {
            match row.get(key) {
                Some(Json::Bool(_)) | Some(Json::Null) => {}
                _ => return Err(format!("row {i}: field {key:?} must be bool or null")),
            }
        }
        let private = row.get("private").and_then(|b| b.as_bool()).unwrap_or(false);
        let flagged = row.get("flagged").and_then(|b| b.as_bool()).unwrap_or(false);
        let claimed = row.get("claimed_eps").and_then(|n| n.as_f64()).unwrap_or(-1.0);
        let empirical = row.get("empirical_eps").and_then(|n| n.as_f64()).unwrap_or(0.0);
        if private && !flagged && claimed >= 0.0 && empirical > claimed {
            return Err(format!(
                "row {i}: empirical eps {empirical} exceeds claimed {claimed} but is not flagged"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{attack::MiOutcome, CellOutcome};
    use super::*;

    fn cell(private: bool, claimed: f64, empirical: f64, flagged: bool) -> CellOutcome {
        CellOutcome {
            model: "lm-small".to_string(),
            method: "bitfit".to_string(),
            eps_label: if private { "low" } else { "inf" }.to_string(),
            tier: "fused".to_string(),
            fault: "none".to_string(),
            private,
            sigma_claimed: if private { 1.5 } else { 0.0 },
            claimed_eps: claimed,
            empirical_eps: empirical,
            flagged,
            mi: Some(MiOutcome { trials: 6, tp: 4, fp: 1, eps: empirical }),
            probes: None,
            extraction: None,
        }
    }

    #[test]
    fn roundtrip_validates() {
        let cells =
            [cell(true, 0.7, 0.2, false), cell(false, f64::INFINITY, 3.0, false)];
        let doc = audit_json(&cells, "test-sweep");
        validate_audit_json(&doc).expect("clean document must validate");
        // the sentinel survives the roundtrip
        let v = json::parse(&doc).unwrap();
        let rows = v.get("rows").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(rows[1].get("claimed_eps").and_then(|n| n.as_f64()), Some(-1.0));
        assert_eq!(rows[1].get("probes_ok"), Some(&Json::Null));
    }

    #[test]
    fn validator_rejects_broken_documents() {
        assert!(validate_audit_json("{}").is_err());
        let wrong = audit_json(&[cell(true, 0.7, 0.2, false)], "s")
            .replace("privacy_audit", "step_throughput");
        assert!(validate_audit_json(&wrong).is_err());
        // an unflagged violation of the core invariant must not validate
        let bad = audit_json(&[cell(true, 0.7, 2.0, false)], "s");
        assert!(validate_audit_json(&bad).is_err());
        // the same cell, flagged, is a legitimate fault report
        let flagged = audit_json(&[cell(true, 0.7, 2.0, true)], "s");
        assert!(validate_audit_json(&flagged).is_ok());
    }
}
