//! White-box mechanism probes: recover the noise multiplier and the
//! clipping bound a `Session` *actually* applied from its parameter
//! trajectory, without reading any internal state.
//!
//! Both probes exploit the SGD update rule `p -= lr * g` with q = 1
//! sampling (every example in every batch, so paired runs see identical
//! batches) and one step from the deterministic init:
//!
//! * **noise**: two sessions differing only in sigma (claimed vs 0) share
//!   every pre-noise float, so the parameter difference is exactly
//!   `-lr * noise / B` — its RMS over the trainable coordinates estimates
//!   `sigma * R` to well under 1% at ~10k coordinates.
//! * **clip**: with sigma = 0 the one-step displacement is
//!   `-lr * sum(clipped per-sample grads) / B`, and Abadi clipping bounds
//!   that sum's norm by `m * R` (triangle inequality over the m sampled
//!   rows).  A ratio above 1 is impossible for a correct clipper; raw
//!   untrained-LM gradients overshoot a small R by orders of magnitude.
//!
//! The probes are what catch faults membership inference cannot: at
//! auditable trial counts a halved sigma shifts scores far less than one
//! Clopper–Pearson confidence interval, but it halves the probe's
//! `sigma_hat` exactly.

use crate::dp::fault::FaultMode;
use crate::engine::{Engine, EngineError, JobSpec, Method, OptimKind};

/// Probe learning rate (any value works; the estimators divide it out).
const LR: f64 = 0.1;
/// Examples per probe session (q = 1, so also the logical batch).
const N_NOISE: usize = 32;
const N_CLIP: usize = 24;
/// Clip probe radius: far below an untrained LM's raw per-sample gradient
/// norm, so disabled clipping is unmissable.
const R_CLIP: f64 = 0.02;
/// Tolerances: estimator error is well under 1%, so generous margins keep
/// every kernel tier and fault mode on the correct side.
const SIGMA_OK_FRACTION: f64 = 0.7;
const CLIP_OK_RATIO: f64 = 1.25;

/// Outcome of the noise-recovery probe.
#[derive(Debug, Clone, Copy)]
pub struct NoiseProbe {
    pub sigma_claimed: f64,
    /// RMS-recovered noise multiplier.
    pub sigma_hat: f64,
    /// `sigma_hat` within [`SIGMA_OK_FRACTION`] of the claim.
    pub ok: bool,
}

/// Outcome of the clipping probe.
#[derive(Debug, Clone, Copy)]
pub struct ClipProbe {
    /// Recovered `|sum of per-sample contributions|`.
    pub sum_norm: f64,
    /// The triangle-inequality ceiling `m * R` for a correct clipper.
    pub bound: f64,
    /// `sum_norm / bound`; <= 1 (+ float slack) iff clipping is applied.
    pub ratio: f64,
    pub ok: bool,
}

fn probe_spec(
    model: &str,
    method: Method,
    sigma: f64,
    clip_r: f64,
    n: usize,
    seed: u64,
) -> Result<JobSpec, EngineError> {
    JobSpec::builder(model, method)
        .sigma(sigma)
        .delta(1e-5)
        .optim(OptimKind::Sgd)
        .lr(LR)
        .clip_r(clip_r)
        .batch(n) // q = 1: both paired sessions sample every example
        .steps(1)
        .n_train(n)
        .seed(seed)
        .build()
}

/// Train two one-step sessions that differ only in sigma and recover the
/// injected noise multiplier from the parameter difference.
pub fn noise_probe(
    engine: &mut Engine,
    model: &str,
    method: Method,
    sigma_claimed: f64,
    fault: FaultMode,
    seed: u64,
) -> Result<NoiseProbe, EngineError> {
    let data = engine.dataset(model, "pretrain-lm", N_NOISE, seed)?;
    let run = |engine: &mut Engine, sigma: f64| -> Result<(Vec<f32>, usize), EngineError> {
        let spec = probe_spec(model, method, sigma, 0.1, N_NOISE, seed)?;
        let mut s = engine.session(&spec)?;
        s.set_fault(fault);
        s.run_step(&data)?;
        Ok((s.full_params(), s.trainable_len()))
    };
    let (with_noise, pt) = run(engine, sigma_claimed)?;
    let (without_noise, _) = run(engine, 0.0)?;
    // frozen coordinates are bit-identical, so the sum runs over exactly
    // the pt trainable ones
    let sum_sq: f64 = with_noise
        .iter()
        .zip(&without_noise)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    let sigma_hat = (sum_sq / pt.max(1) as f64).sqrt() * N_NOISE as f64 / (LR * 0.1);
    let ok = sigma_hat >= SIGMA_OK_FRACTION * sigma_claimed;
    Ok(NoiseProbe { sigma_claimed, sigma_hat, ok })
}

/// Train one noiseless one-step session and compare the recovered gradient
/// sum against the clipper's triangle-inequality ceiling.
pub fn clip_probe(
    engine: &mut Engine,
    model: &str,
    method: Method,
    fault: FaultMode,
    seed: u64,
) -> Result<ClipProbe, EngineError> {
    let data = engine.dataset(model, "pretrain-lm", N_CLIP, seed)?;
    let spec = probe_spec(model, method, 0.0, R_CLIP, N_CLIP, seed)?;
    let mut s = engine.session(&spec)?;
    s.set_fault(fault);
    let before = s.full_params();
    let stats = s.run_step(&data)?;
    let after = s.full_params();
    let sum_sq: f64 =
        before.iter().zip(&after).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
    let sum_norm = sum_sq.sqrt() * N_CLIP as f64 / LR;
    let bound = stats.batch as f64 * R_CLIP;
    let ratio = if bound > 0.0 { sum_norm / bound } else { 0.0 };
    Ok(ClipProbe { sum_norm, bound, ratio, ok: ratio <= CLIP_OK_RATIO })
}
