//! Membership inference over canary-paired models.
//!
//! The attack instantiates the DP neighbouring-dataset definition
//! literally: two datasets that differ in exactly one record (the canary),
//! trained with independently seeded mechanisms, then distinguished by the
//! trained model's loss on that record.  Per trial the seeds advance, and
//! the canary's negative log-likelihood under each model becomes one
//! "in" score and one "out" score; thresholding the pooled scores yields
//! TP/FP counts, which [`crate::audit::bound`] converts into an empirical
//! epsilon lower bound.  A mechanism whose claimed epsilon is *below* the
//! witnessed bound is broken — that is the audit's core test.

use crate::coordinator::task_data::TaskData;
use crate::data::synth_text::{self, Canary};
use crate::dp::fault::FaultMode;
use crate::engine::{evaluate_params, Engine, EngineError, JobSpec};

use super::bound;

/// Outcome of one membership-inference run.
#[derive(Debug, Clone, Copy)]
pub struct MiOutcome {
    pub trials: usize,
    /// "in" models correctly called in (score above threshold).
    pub tp: u64,
    /// "out" models wrongly called in.
    pub fp: u64,
    /// Clopper–Pearson empirical epsilon witness (both directions).
    pub eps: f64,
}

/// Build the neighbouring dataset pair: a clean split and the same split
/// with exactly one record replaced by the canary (the add/remove-one
/// adjacency the accountant's guarantee quantifies over).  Everything is
/// deterministic under `seed`, so every trial reuses the identical pair.
// fastdp-lint: per-sample-grad
pub fn paired_datasets(
    n: usize,
    t_len: usize,
    vocab: usize,
    canary: &Canary,
    seed: u64,
) -> (TaskData, TaskData) {
    let tok = synth_text::tokenizer(vocab);
    let clean = synth_text::pretrain_lm(n, t_len, &tok, seed);
    let mut planted = clean.clone();
    synth_text::plant_canaries(&mut planted, t_len, std::slice::from_ref(canary), 1, seed);
    (
        TaskData::Lm { examples: planted, t: t_len },
        TaskData::Lm { examples: clean, t: t_len },
    )
}

/// Train one model for the audit: a full `Session` through the engine
/// façade (Poisson sampling, per-sample clipping, noise, accounting) with
/// the cell's fault armed, returning the trained parameter vector.
// fastdp-lint: clip-boundary
pub fn train_audit_model(
    engine: &mut Engine,
    spec: &JobSpec,
    fault: FaultMode,
    data: &TaskData,
) -> Result<Vec<f32>, EngineError> {
    let mut session = engine.session(spec)?;
    session.set_fault(fault);
    for _ in 0..spec.steps {
        session.run_step(data)?;
    }
    Ok(session.full_params())
}

/// Summed NLL of `completion` given `prompt` under a trained model — the
/// audit's only loss readout (membership scores and extraction ranking
/// both flow through here).
// fastdp-lint: dp-sink
pub fn sequence_nll(
    engine: &mut Engine,
    model: &str,
    params: &[f32],
    prompt: &[i32],
    completion: &[i32],
    t_len: usize,
) -> Result<f64, EngineError> {
    let probe = Canary { prompt: prompt.to_vec(), completion: completion.to_vec() };
    let data = TaskData::Lm { examples: vec![probe.lm_example(t_len)], t: t_len };
    let eval = engine.evaluator(model)?;
    Ok(evaluate_params(eval.as_ref(), params, &data, 1)?.metric_a)
}

/// Run `trials` paired trainings and score the canary-loss attack.
pub fn mi_attack(
    engine: &mut Engine,
    base: &JobSpec,
    canary: &Canary,
    t_len: usize,
    vocab: usize,
    trials: usize,
    fault: FaultMode,
) -> Result<MiOutcome, EngineError> {
    assert!(trials > 0, "mi_attack needs at least one trial");
    let (canary_in, canary_out) =
        paired_datasets(base.n_train, t_len, vocab, canary, base.seed ^ 0xDA7A5E);
    let mut scores_in = Vec::with_capacity(trials);
    let mut scores_out = Vec::with_capacity(trials);
    for trial in 0..trials {
        // the in and out models draw INDEPENDENT seeds: the DP guarantee
        // is over the mechanism's randomness, so sharing noise across the
        // pair would hand the attacker common-mode cancellation the
        // epsilon bound does not cover (and deterministically separate
        // even a correct mechanism)
        let mut spec_in = base.clone();
        spec_in.seed = base.seed.wrapping_add(1 + 2 * trial as u64);
        let mut spec_out = base.clone();
        spec_out.seed = base.seed.wrapping_add(2 + 2 * trial as u64);
        let params_in = train_audit_model(engine, &spec_in, fault, &canary_in)?;
        let params_out = train_audit_model(engine, &spec_out, fault, &canary_out)?;
        let nll_in = sequence_nll(
            engine,
            &base.model,
            &params_in,
            &canary.prompt,
            &canary.completion,
            t_len,
        )?;
        let nll_out = sequence_nll(
            engine,
            &base.model,
            &params_out,
            &canary.prompt,
            &canary.completion,
            t_len,
        )?;
        scores_in.push(-nll_in);
        scores_out.push(-nll_out);
    }
    // threshold at the lower median of the pooled scores: with real
    // memorization the two score sets separate and this lands between them
    let mut pooled: Vec<f64> = scores_in.iter().chain(&scores_out).copied().collect();
    pooled.sort_by(f64::total_cmp);
    let threshold = pooled[trials - 1];
    let tp = scores_in.iter().filter(|&&s| s > threshold).count() as u64;
    let fp = scores_out.iter().filter(|&&s| s > threshold).count() as u64;
    let eps = bound::eps_lower_bound(tp, fp, trials as u64, bound::ALPHA, base.privacy.delta());
    Ok(MiOutcome { trials, tp, fp, eps })
}
