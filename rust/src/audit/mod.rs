//! Empirical privacy auditing: attack the trained models and check the
//! accountant's claim against what an adversary actually achieves.
//!
//! The analytical DP stack ([`crate::dp`]) proves an epsilon *upper*
//! bound; this module measures an epsilon *lower* bound by attacking real
//! [`crate::engine::Session`] runs, closing the loop end-to-end:
//!
//! * [`attack`] — membership inference on canary-paired models (the
//!   neighbouring-dataset game, played with real trainings),
//! * [`extract`] — secret extraction: greedy decode + exposure rank of a
//!   planted canary,
//! * [`probe`] — white-box recovery of the applied noise multiplier and
//!   clipping bound from one-step SGD trajectories,
//! * [`bound`] — exact Clopper–Pearson confidence bounds turning attack
//!   counts into an epsilon witness,
//! * [`report`] — the `BENCH_privacy_audit.json` schema.
//!
//! A cell of the audit grid (method × epsilon × kernel tier, optionally
//! with a [`FaultMode`] armed) is **flagged** when the empirical epsilon
//! exceeds the accountant's claim — which must never happen for the
//! unfaulted mechanism and must always happen when a fault breaks it.
//! Faults too subtle for membership inference at auditable trial counts
//! (a halved sigma moves attack accuracy by less than one confidence
//! interval) are caught by the probes instead: a failed probe feeds the
//! *measured* mechanism parameters back through the RDP accountant, and
//! that implied epsilon becomes the empirical claim.

pub mod attack;
pub mod bound;
pub mod extract;
pub mod probe;
pub mod report;

use crate::data::synth_text;
use crate::dp::fault::FaultMode;
use crate::dp::rdp;
use crate::engine::{
    Engine, EngineError, InterpreterBackend, JobSpec, KernelMode, Method, OptimKind, TaskData,
};

use attack::MiOutcome;
use extract::Extraction;
use probe::{ClipProbe, NoiseProbe};

/// The audit trains the small LM everywhere: it is the only model family
/// with a decode fragment (extraction needs one), and canaries are text.
pub const MODEL: &str = "lm-small";
pub const DELTA: f64 = 1e-5;
/// Grid epsilon targets: tight, moderate, and non-private.
pub const EPS_LOW: f64 = 0.7;
pub const EPS_MID: f64 = 3.0;
/// Cap for "the mechanism leaks everything" (JSON-safe stand-in for
/// infinity when a probe measures an effectively zero sigma).
const EPS_CAP: f64 = 1e9;
/// Below this sigma the RDP accountant's assertion would trip; the
/// implied epsilon is the cap instead.
const SIGMA_FLOOR: f64 = 0.3;
/// Secret length in tokens (6 word ids from the canary bank).
const COMPLETION_LEN: usize = 6;
/// Extraction trains longer and full-batch so the non-private column
/// memorises its canary within a test-sized budget.
const EXTRACT_STEPS: u64 = 80;
const CANARY_COPIES: usize = 8;

/// One cell of the audit grid.
#[derive(Debug, Clone, Copy)]
pub struct AuditSpec {
    pub method: Method,
    /// Epsilon target; `None` trains non-privately.
    pub eps: Option<f64>,
    pub tier: KernelMode,
    pub fault: FaultMode,
    /// Paired membership-inference trainings (0 skips the MI attack).
    pub trials: usize,
    pub steps: u64,
    pub n_train: usize,
    pub logical_batch: usize,
    /// Also run the extraction attack (trains one extra, longer model).
    pub extraction: bool,
    pub seed: u64,
}

impl AuditSpec {
    /// A cell with the default audit-sized training configuration.
    pub fn cell(method: Method, eps: Option<f64>) -> AuditSpec {
        AuditSpec {
            method,
            eps,
            tier: KernelMode::Fused,
            fault: FaultMode::None,
            trials: 6,
            steps: 14,
            n_train: 48,
            logical_batch: 16,
            extraction: false,
            seed: 11,
        }
    }
}

/// Everything the audit measured for one grid cell.
#[derive(Debug, Clone)]
pub struct CellOutcome {
    pub model: String,
    pub method: String,
    pub eps_label: String,
    pub tier: String,
    pub fault: String,
    pub private: bool,
    /// Noise multiplier the plan resolved (0 for non-private cells).
    pub sigma_claimed: f64,
    /// Accountant's projected epsilon (infinite for non-private cells).
    pub claimed_eps: f64,
    /// Largest epsilon any attack or probe witnessed.
    pub empirical_eps: f64,
    /// The audit verdict: empirical exceeds claimed.
    pub flagged: bool,
    pub mi: Option<MiOutcome>,
    pub probes: Option<(NoiseProbe, ClipProbe)>,
    pub extraction: Option<Extraction>,
}

fn eps_label(eps: Option<f64>) -> String {
    match eps {
        None => "inf".to_string(),
        Some(e) => format!("eps{e}"),
    }
}

/// Epsilon the RDP accountant assigns to the *measured* mechanism
/// parameters — what a probe-detected fault actually spends.
fn implied_eps(q: f64, sigma_eff: f64, steps: u64) -> f64 {
    if sigma_eff < SIGMA_FLOOR {
        EPS_CAP
    } else {
        rdp::epsilon(q, sigma_eff, steps, DELTA).min(EPS_CAP)
    }
}

/// Audit one grid cell: train, attack, probe, and compare against the
/// accountant's claim.
pub fn run_cell(spec: &AuditSpec) -> Result<CellOutcome, EngineError> {
    let mut engine =
        Engine::new(Box::new(InterpreterBackend::with_config(None, Some(spec.tier))));
    let shape = engine.model_info(MODEL)?.shape;
    let (t_len, vocab) = (shape.t, shape.vocab);
    let tok = synth_text::tokenizer(vocab);
    let canary = synth_text::canaries(1, COMPLETION_LEN, &tok, spec.seed).remove(0);

    let mut builder = JobSpec::builder(MODEL, spec.method)
        .optim(OptimKind::Adam)
        .lr(1e-2)
        .clip_r(0.1)
        .batch(spec.logical_batch)
        .steps(spec.steps)
        .n_train(spec.n_train)
        .seed(spec.seed);
    if let Some(e) = spec.eps {
        builder = builder.eps(e).delta(DELTA);
    }
    let base = builder.build()?;
    let plan = base.plan();
    let private = base.privacy.is_private();
    let sigma_claimed = plan.sigma;
    let claimed_eps = if private { plan.eps_projected } else { f64::INFINITY };

    let mi = if spec.trials > 0 {
        Some(attack::mi_attack(
            &mut engine,
            &base,
            &canary,
            t_len,
            vocab,
            spec.trials,
            spec.fault,
        )?)
    } else {
        None
    };

    let probes = if private && sigma_claimed > 0.0 {
        let np = probe::noise_probe(
            &mut engine,
            MODEL,
            spec.method,
            sigma_claimed,
            spec.fault,
            spec.seed ^ 0x9B0B,
        )?;
        let cp =
            probe::clip_probe(&mut engine, MODEL, spec.method, spec.fault, spec.seed ^ 0xC11F)?;
        Some((np, cp))
    } else {
        None
    };

    // clean probes leave the accountant's claim standing; a failed probe
    // re-runs the accountant on the measured sigma (derated by any excess
    // gradient mass a broken clipper let through)
    let implied = match &probes {
        Some((np, cp)) if !np.ok || !cp.ok => {
            let mut sigma_eff = if np.ok { sigma_claimed } else { np.sigma_hat };
            if !cp.ok {
                sigma_eff /= cp.ratio.max(1.0);
            }
            implied_eps(plan.q, sigma_eff, spec.steps)
        }
        _ => 0.0,
    };

    let mi_eps = mi.as_ref().map(|m| m.eps).unwrap_or(0.0);
    let empirical_eps = mi_eps.max(implied);
    let flagged =
        private && claimed_eps.is_finite() && empirical_eps > claimed_eps * (1.0 + 1e-9);

    let extraction = if spec.extraction {
        let mut xspec = base.clone();
        xspec.steps = EXTRACT_STEPS;
        xspec.logical_batch = spec.n_train; // q = 1: every example every step
        let mut examples =
            synth_text::pretrain_lm(spec.n_train, t_len, &tok, spec.seed ^ 0x5EC5);
        synth_text::plant_canaries(
            &mut examples,
            t_len,
            std::slice::from_ref(&canary),
            CANARY_COPIES,
            spec.seed,
        );
        let data = TaskData::Lm { examples, t: t_len };
        let params = attack::train_audit_model(&mut engine, &xspec, spec.fault, &data)?;
        Some(extract::extract_canary(
            &mut engine,
            MODEL,
            &params,
            &canary,
            t_len,
            vocab,
            spec.seed,
        )?)
    } else {
        None
    };

    Ok(CellOutcome {
        model: MODEL.to_string(),
        method: spec.method.name().to_string(),
        eps_label: eps_label(spec.eps),
        tier: spec.tier.name().to_string(),
        fault: spec.fault.name().to_string(),
        private,
        sigma_claimed,
        claimed_eps,
        empirical_eps,
        flagged,
        mi,
        probes,
        extraction,
    })
}

/// Audit every cell in order (grids are plain vectors — iteration order,
/// and therefore the report, is deterministic).
pub fn run_grid(specs: &[AuditSpec]) -> Result<Vec<CellOutcome>, EngineError> {
    specs.iter().map(run_cell).collect()
}

/// The audited epsilon column: tight, moderate, non-private.
pub fn eps_grid() -> [Option<f64>; 3] {
    [Some(EPS_LOW), Some(EPS_MID), None]
}

/// The audited fine-tuning methods: full (ghost clipping), BiTFiT, and
/// linear probing — the paper's three parameter regimes.
pub fn method_grid() -> [Method; 3] {
    [Method::Full { ghost: true }, Method::BiTFiT, Method::LastLayer]
}

/// Every kernel tier: the guarantee must hold however the step executes.
pub fn tier_grid() -> [KernelMode; 4] {
    [KernelMode::Fused, KernelMode::Ghost, KernelMode::Blocked, KernelMode::Simd]
}

/// The full bench grid: method × epsilon × tier, extraction on the fused
/// tier only (tiers share the training numerics, so one extraction per
/// method/eps pair carries the signal).
pub fn full_grid(trials: usize) -> Vec<AuditSpec> {
    let mut out = Vec::new();
    for method in method_grid() {
        for eps in eps_grid() {
            for tier in tier_grid() {
                let mut cell = AuditSpec::cell(method, eps);
                cell.tier = tier;
                cell.trials = trials;
                cell.extraction = tier == KernelMode::Fused;
                out.push(cell);
            }
        }
    }
    out
}

/// Smoke-sized grid for CI: BiTFiT at the tight epsilon and non-private,
/// fused tier, extraction on both cells.
pub fn quick_grid(trials: usize) -> Vec<AuditSpec> {
    [Some(EPS_LOW), None]
        .into_iter()
        .map(|eps| {
            let mut cell = AuditSpec::cell(Method::BiTFiT, eps);
            cell.trials = trials;
            cell.extraction = true;
            cell
        })
        .collect()
}
