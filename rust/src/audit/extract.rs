//! Secret extraction: can an attacker read a planted canary back out of
//! the trained model?
//!
//! Two complementary measurements, both black-box over the trained
//! parameter vector:
//!
//! * **greedy decode** — prompt the model with the canary trigger and
//!   count how many of the secret's tokens the argmax continuation
//!   reproduces (`match_rate`).  A model that memorised the canary
//!   completes it verbatim; a DP model continues with generic corpus text.
//! * **ranked exposure** — score the true secret's NLL against decoy
//!   secrets drawn from the same word bank (the canary-exposure protocol
//!   of Carlini et al., "The Secret Sharer").  `rank == 1` means the true
//!   secret beats every decoy; under no memorisation rank is uniform over
//!   the candidates.
//!
//! `extracted` requires both signals (rank 1 *and* a majority token
//! match), so a single lucky rank draw — probability 1/candidates under
//! the null — cannot flag a correct DP run.

use crate::data::synth_text::{self, Canary};
use crate::engine::{Engine, EngineError};
use crate::util::rng::ChaChaRng;

use super::attack::sequence_nll;

/// Decoys ranked against the true secret (16 candidates total).
const DECOYS: usize = 15;

/// Outcome of the extraction attack on one trained model.
#[derive(Debug, Clone, Copy)]
pub struct Extraction {
    /// Fraction of secret tokens the greedy continuation reproduced.
    pub match_rate: f64,
    /// Rank of the true secret among [`candidates`](Self::candidates)
    /// by NLL (1 = best).
    pub rank: usize,
    pub candidates: usize,
    /// Summed NLL of the true secret given the trigger.
    pub nll_true: f64,
    /// Both signals fired: rank 1 and a majority greedy match.
    pub extracted: bool,
}

/// Draw decoy completions from the same word bank as real secrets so the
/// ranking measures memorisation, not vocabulary mismatch.  Regenerates on
/// collision with the true secret (or another decoy).
fn decoy_completions(canary: &Canary, vocab: usize, seed: u64) -> Vec<Vec<i32>> {
    let tok = synth_text::tokenizer(vocab);
    let bank = synth_text::word_bank();
    let mut rng = ChaChaRng::new(seed, 0xDEC0);
    let len = canary.completion.len();
    let mut out: Vec<Vec<i32>> = Vec::with_capacity(DECOYS);
    while out.len() < DECOYS {
        let cand: Vec<i32> =
            (0..len).map(|_| tok.encode_word(bank[rng.below(bank.len())])).collect();
        if cand != canary.completion && !out.contains(&cand) {
            out.push(cand);
        }
    }
    out
}

/// Attack one trained model: greedy-decode the trigger and rank the true
/// secret against decoys.
pub fn extract_canary(
    engine: &mut Engine,
    model: &str,
    params: &[f32],
    canary: &Canary,
    t_len: usize,
    vocab: usize,
    seed: u64,
) -> Result<Extraction, EngineError> {
    use crate::coordinator::decode::greedy_decode;
    use crate::data::tokenizer::{EOS, SEP};

    // greedy continuation of "trigger SEP" compared token-for-token
    let mut prompt = canary.prompt.clone();
    prompt.push(SEP);
    let step = engine.decoder(model)?;
    let decoded =
        greedy_decode(step.as_ref(), params, &[prompt], canary.completion.len(), EOS)?;
    let hits = decoded[0]
        .iter()
        .zip(&canary.completion)
        .filter(|&(&got, &want)| got as i32 == want)
        .count();
    let match_rate = hits as f64 / canary.completion.len().max(1) as f64;

    // exposure rank of the true secret among decoys
    let nll_true =
        sequence_nll(engine, model, params, &canary.prompt, &canary.completion, t_len)?;
    let mut rank = 1usize;
    for decoy in decoy_completions(canary, vocab, seed) {
        if sequence_nll(engine, model, params, &canary.prompt, &decoy, t_len)? < nll_true {
            rank += 1;
        }
    }
    let extracted = rank == 1 && match_rate >= 0.5;
    Ok(Extraction { match_rate, rank, candidates: DECOYS + 1, nll_true, extracted })
}
