//! Exact one-sided Clopper–Pearson binomial bounds and the empirical
//! epsilon lower bound they imply.
//!
//! A membership-inference attack with true-positive rate TPR and
//! false-positive rate FPR on neighbouring datasets witnesses
//! `eps >= ln((TPR - delta) / FPR)` for any (eps, delta)-DP mechanism
//! (Kairouz et al., "The Composition Theorem for Differential Privacy").
//! With `n` paired trials we only observe counts, so the witnessed bound
//! uses a one-sided lower confidence bound on TPR and a one-sided upper
//! confidence bound on FPR — the Clopper–Pearson construction, evaluated
//! exactly (trial counts are small) and inverted by bisection.

use crate::dp::rdp::ln_gamma;

/// One-sided confidence level used throughout the audit (95%).
pub const ALPHA: f64 = 0.05;

fn ln_binom(n: u64, k: u64) -> f64 {
    ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0)
}

/// Exact upper tail `P(X >= x)` for `X ~ Binomial(n, p)`.
fn tail_ge(n: u64, x: u64, p: f64) -> f64 {
    if x == 0 {
        return 1.0;
    }
    if x > n || p <= 0.0 {
        return 0.0;
    }
    if p >= 1.0 {
        return 1.0;
    }
    let (lp, lq) = (p.ln(), (1.0 - p).ln());
    (x..=n)
        .map(|i| (ln_binom(n, i) + i as f64 * lp + (n - i) as f64 * lq).exp())
        .sum::<f64>()
        .min(1.0)
}

/// Exact lower tail `P(X <= x)`.
fn tail_le(n: u64, x: u64, p: f64) -> f64 {
    if x >= n {
        return 1.0;
    }
    if p >= 1.0 {
        return 0.0;
    }
    if p <= 0.0 {
        return 1.0;
    }
    let (lp, lq) = (p.ln(), (1.0 - p).ln());
    (0..=x)
        .map(|i| (ln_binom(n, i) + i as f64 * lp + (n - i) as f64 * lq).exp())
        .sum::<f64>()
        .min(1.0)
}

/// One-sided Clopper–Pearson **lower** bound: the largest `p` ruled out
/// from below, i.e. the solution of `P(X >= x; n, p) = alpha` (0 when
/// `x == 0`).  Bisection returns the inner endpoint, so the bound is
/// conservative (never overstates the rate).
pub fn cp_lower(x: u64, n: u64, alpha: f64) -> f64 {
    assert!(x <= n && n > 0, "x = {x} of n = {n}");
    if x == 0 {
        return 0.0;
    }
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if tail_ge(n, x, mid) < alpha {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

/// One-sided Clopper–Pearson **upper** bound: the solution of
/// `P(X <= x; n, p) = alpha` (1 when `x == n`).  Returns the outer
/// endpoint, so the bound is conservative (never understates the rate).
pub fn cp_upper(x: u64, n: u64, alpha: f64) -> f64 {
    assert!(x <= n && n > 0, "x = {x} of n = {n}");
    if x == n {
        return 1.0;
    }
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if tail_le(n, x, mid) > alpha {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi
}

/// Empirical epsilon witnessed by `tp` true positives and `fp` false
/// positives over `n` paired trials, at confidence `1 - alpha` and the
/// mechanism's `delta`.  Both attack directions are scored — calling the
/// high-score side "in" and calling the low-score side "out" (TNR/FNR
/// swap) — and the larger witness is returned, clamped at 0 (no attack
/// ever witnesses a negative epsilon).
pub fn eps_lower_bound(tp: u64, fp: u64, n: u64, alpha: f64, delta: f64) -> f64 {
    assert!(tp <= n && fp <= n && n > 0);
    let one_direction = |hits: u64, false_alarms: u64| -> f64 {
        let rate_lb = cp_lower(hits, n, alpha);
        let false_ub = cp_upper(false_alarms, n, alpha);
        if rate_lb - delta <= 0.0 || false_ub <= 0.0 {
            return 0.0;
        }
        ((rate_lb - delta) / false_ub).ln().max(0.0)
    };
    one_direction(tp, fp).max(one_direction(n - fp, n - tp))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cp_bounds_match_closed_forms() {
        // P(X >= n; p) = p^n  =>  cp_lower(n, n) = alpha^(1/n)
        // P(X <= 0; p) = (1-p)^n  =>  cp_upper(0, n) = 1 - alpha^(1/n)
        for n in [1u64, 4, 6, 12, 30] {
            let root = ALPHA.powf(1.0 / n as f64);
            assert!((cp_lower(n, n, ALPHA) - root).abs() < 1e-9, "n = {n}");
            assert!((cp_upper(0, n, ALPHA) - (1.0 - root)).abs() < 1e-9, "n = {n}");
        }
        assert_eq!(cp_lower(0, 10, ALPHA), 0.0);
        assert_eq!(cp_upper(10, 10, ALPHA), 1.0);
    }

    #[test]
    fn cp_bounds_are_conservative_and_monotone() {
        for n in [6u64, 20] {
            let mut prev_lo = -1.0;
            let mut prev_hi = 0.0;
            for x in 0..=n {
                let lo = cp_lower(x, n, ALPHA);
                let hi = cp_upper(x, n, ALPHA);
                assert!(lo <= x as f64 / n as f64 + 1e-9, "lower bound above the MLE");
                assert!(hi >= x as f64 / n as f64 - 1e-9, "upper bound below the MLE");
                assert!(lo > prev_lo - 1e-12 && hi > prev_hi - 1e-12, "not monotone in x");
                // the bound actually holds at the returned endpoint
                if x > 0 {
                    assert!(tail_ge(n, x, lo) <= ALPHA + 1e-9);
                }
                if x < n {
                    assert!(tail_le(n, x, hi) <= ALPHA + 1e-9);
                }
                prev_lo = lo;
                prev_hi = hi;
            }
        }
    }

    #[test]
    fn eps_bound_values() {
        // perfect separation at 6 trials: tpr_lb = 0.05^(1/6), fpr_ub = 1 - 0.05^(1/6)
        let root: f64 = ALPHA.powf(1.0 / 6.0);
        let want = ((root - 1e-5) / (1.0 - root)).ln();
        let got = eps_lower_bound(6, 0, 6, ALPHA, 1e-5);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        assert!(got < 0.5, "6 perfect trials must witness less than eps 0.5, got {got}");
        // a chance-level attack witnesses nothing
        assert_eq!(eps_lower_bound(3, 3, 6, ALPHA, 1e-5), 0.0);
        // the reversed direction is scored too: all-negative calls are as
        // strong a witness as all-positive ones
        assert!((eps_lower_bound(0, 6, 6, ALPHA, 1e-5) - got).abs() < 1e-12);
        // more trials at perfect separation witness more
        assert!(eps_lower_bound(20, 0, 20, ALPHA, 1e-5) > got);
    }
}
