//! Shared bench harness: fine-tune-and-evaluate jobs + step timing, all on
//! top of `fastdp::engine` (so every bench runs against either backend).
//!
//! Every `benches/*.rs` target regenerates one paper table/figure through
//! these helpers.  Wall-clock scale is controlled by env vars so the same
//! code runs as a quick smoke or a full reproduction:
//!   FASTDP_BENCH_STEPS  — fine-tuning steps per run (default 30)
//!   FASTDP_BENCH_QUICK  — set to skip the slowest sweep points
//!
//! The throughput harness (`benches/throughput.rs`) additionally uses the
//! [`interp_throughput`] / [`interp_output_bits`] helpers below to sweep
//! kernel mode x worker count on the interpreter backend and emit
//! `BENCH_step_throughput.json` (schema validated by
//! [`validate_throughput_json`]; documented in the README "Performance"
//! section).

use crate::coordinator::optim::OptimKind;
use crate::coordinator::pretrain::{pretrained_params, PretrainSpec};
use crate::dp::clip::ClipMode;
use crate::engine::{Backend, Engine, EngineError, InterpreterBackend, JobSpec, Method};
use crate::kernels::{KernelMode, SimdLevel};
use crate::runtime::ArtifactMeta;
use crate::util::json::{self, Json};
use crate::util::rng::ChaChaRng;
use crate::util::tensor::Tensor;

pub fn bench_steps(default: usize) -> usize {
    crate::runtime::env::bench_steps().unwrap_or(default)
}

pub fn quick() -> bool {
    crate::runtime::env::bench_quick()
}

/// A fine-tune-then-evaluate job specification.
#[derive(Debug, Clone)]
pub struct FtJob {
    pub model: String,
    /// Artifact method fragment, e.g. `dp-bitfit` / `nondp-full`.
    pub method: String,
    pub task: String,
    pub pretrain_task: String,
    pub steps: usize,
    pub batch: usize,
    pub lr: f64,
    /// Target epsilon for `dp-*` methods (ignored for `nondp-*`).
    pub eps: f64,
    pub clip_mode: ClipMode,
    pub seed: u64,
    pub n_train: usize,
    pub n_eval: usize,
}

impl FtJob {
    pub fn new(model: &str, method: &str, task: &str) -> FtJob {
        let pretrain_task = match task {
            "e2e" => "pretrain-lm",
            "cifar" => "cifar-pretrain",
            "celeba" => "celeba",
            _ => "pretrain-cls",
        };
        FtJob {
            model: model.to_string(),
            method: method.to_string(),
            task: task.to_string(),
            pretrain_task: pretrain_task.to_string(),
            steps: bench_steps(30),
            batch: 128,
            lr: if method.contains("bitfit") || method.contains("lastlayer") { 5e-3 } else { 5e-4 },
            eps: if method.starts_with("dp-") { 8.0 } else { 0.0 },
            clip_mode: ClipMode::Abadi,
            seed: 3,
            n_train: 4096,
            n_eval: 1024,
        }
    }

    /// Translate into an engine `JobSpec`.
    pub fn spec(&self) -> Result<JobSpec, EngineError> {
        let (method, private) = Method::parse(&self.method)
            .ok_or_else(|| EngineError::spec(format!("unknown method {:?}", self.method)))?;
        let mut b = JobSpec::builder(&self.model, method)
            .task(&self.task)
            .optim(if self.task == "e2e" { OptimKind::AdamW } else { OptimKind::Adam })
            .lr(self.lr)
            .clip_r(0.1)
            .clip_mode(self.clip_mode)
            .batch(self.batch)
            .steps(self.steps.max(1) as u64)
            .n_train(self.n_train)
            .seed(self.seed);
        if private {
            b = if self.eps > 0.0 {
                b.eps(self.eps).delta(1e-5)
            } else {
                // DP pipeline (Poisson sampling, clipping) with no noise
                b.sigma(0.0).delta(1e-5)
            };
        }
        b.build()
    }
}

/// Outcome of one fine-tuning job.
#[derive(Debug, Clone, Copy)]
pub struct FtOutcome {
    /// classification: accuracy in [0,1]; LM: metric_a = nll, metric_b = tokens
    pub metric_a: f64,
    pub metric_b: f64,
    pub accuracy: f64,
    pub eps_spent: f64,
    pub sec_per_step: f64,
}

/// Pretrain (cached) -> reset head -> fine-tune -> evaluate.
///
/// Returns the outcome and the fine-tuned full parameter vector.
pub fn finetune(engine: &mut Engine, job: &FtJob) -> Result<(FtOutcome, Vec<f32>), EngineError> {
    let mut spec = PretrainSpec::new(&job.model, &job.pretrain_task);
    if job.pretrain_task == "celeba" {
        // CelebA runs fine-tune from scratch-ish backbone (paper uses
        // ImageNet-pretrained ResNet; our analog pretrains on the same
        // attribute distribution with a different seed)
        spec.seed = 17;
    }
    let mut params = pretrained_params(engine, &spec, true)?;
    if job.task != "e2e" {
        engine.reset_head(&job.model, &mut params)?;
    }
    let train = engine.dataset(&job.model, &job.task, job.n_train, job.seed * 100 + 1)?;
    let test = engine.dataset(&job.model, &job.task, job.n_eval, job.seed * 100 + 2)?;

    let job_spec = job.spec()?;
    let mut session = engine.session_from(&job_spec, params)?;
    let t0 = std::time::Instant::now();
    for _ in 0..job.steps {
        session.run_step(&train)?;
    }
    let sec_per_step = t0.elapsed().as_secs_f64() / job.steps.max(1) as f64;
    let eps_spent = session.privacy_spent().epsilon;
    let out = session.evaluate(&test, job.n_eval)?;
    Ok((
        FtOutcome {
            metric_a: out.metric_a,
            metric_b: out.metric_b,
            accuracy: out.accuracy(),
            eps_spent,
            sec_per_step,
        },
        session.full_params(),
    ))
}

/// Measure seconds per microbatch example of a train step (init params,
/// synthetic batch, `iters` timed runs after one warmup).
pub fn step_time(engine: &mut Engine, artifact: &str, iters: usize) -> Result<f64, EngineError> {
    let step = engine.runner(artifact)?;
    let meta = step.meta().clone();
    let layout = engine.layout(&meta.model)?;
    let full = engine.init_params(&meta.model)?;
    let (frozen, train) = layout.split(&full, &meta.subset);
    let b = meta.batch;
    let inputs: Vec<Tensor> = {
        let mut v =
            vec![Tensor::f32(vec![meta.pf], frozen), Tensor::f32(vec![meta.pt], train)];
        for spec in &meta.inputs[2..] {
            let n = spec.elements();
            if spec.dtype == "int32" {
                v.push(Tensor::i32(spec.shape.clone(), vec![1; n]));
            } else if spec.shape.is_empty() {
                v.push(Tensor::scalar_f32(1.0));
            } else {
                v.push(Tensor::f32(spec.shape.clone(), vec![0.5; n]));
            }
        }
        v
    };
    step.run(&inputs)?; // warmup
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        step.run(&inputs)?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters.max(1) as f64 / b as f64)
}

/// Estimated training memory (bytes) for one of our trained models under a
/// method, via the analytical model of `analysis::complexity`.
pub fn memory_estimate(
    engine: &Engine,
    model: &str,
    method: &str,
    b: u64,
) -> Result<u64, EngineError> {
    let info = engine.model_info(model)?;
    let shape = &info.shape;
    let (t, d, layers) = match shape.kind.as_str() {
        "cls" | "lm" => (shape.t as u64, info.d as u64, info.layers as u64),
        "vit" => {
            let patch = info.patch.max(1) as u64;
            (((shape.img as u64) / patch).pow(2).max(1) + 1, info.d as u64, info.layers as u64)
        }
        _ => ((shape.img as u64).pow(2), 32, 3),
    };
    let net = crate::analysis::complexity::Network::uniform(
        layers.max(1) as usize,
        b,
        t.max(1),
        d.max(16),
        d.max(16),
    );
    let m = parse_method(method);
    Ok(net.memory_bytes(m))
}

// ---------------------------------------------------------------------------
// Step-throughput harness (benches/throughput.rs)
// ---------------------------------------------------------------------------

/// One measured throughput point: a (model, method, kernel-mode, workers)
/// cell of the sweep.
#[derive(Debug, Clone)]
pub struct ThroughputPoint {
    pub model: String,
    pub method: String,
    /// `"fused"`, `"ghost"`, `"blocked"`, `"simd"` or `"legacy"`.
    pub kernels: String,
    pub threads: usize,
    /// Block width of a blocked- or simd-tier cell (`FASTDP_BLOCK_ROWS`);
    /// 0 for the row-at-a-time tiers.
    pub block_rows: usize,
    pub sec_per_step: f64,
    pub steps_per_sec: f64,
    /// Microbatch rows per second (`batch / sec_per_step`).
    pub rows_per_sec: f64,
    /// Analytical peak gradient-side scratch of the cell
    /// (`InterpreterBackend::train_scratch_bytes`) — the per-cell memory
    /// column reproducing Table 2's complexity claims.
    pub peak_scratch_bytes: u64,
    /// Structural roofline utilization: the step's idealized runtime on
    /// the `analysis::roofline` chip model (≈6·B·npos·(pf+pt) flops vs
    /// parameter + per-row HBM traffic, whichever bound dominates)
    /// divided by the measured `sec_per_step`.  A structural proxy for
    /// cross-cell comparison within one sweep, not a hardware claim;
    /// finite and positive for every cell.
    pub roofline_utilization: f64,
}

/// Per-(model, method) roll-up: best fused and ghost points vs the
/// single-thread legacy scalar baseline.
#[derive(Debug, Clone)]
pub struct ThroughputSummary {
    pub model: String,
    pub method: String,
    /// Worker count of the fastest fused point.
    pub best_threads: usize,
    pub scalar_steps_per_sec: f64,
    pub fused_steps_per_sec: f64,
    /// Best ghost-tier throughput over the swept worker counts.
    pub ghost_steps_per_sec: f64,
    /// Best blocked-tier throughput over the swept worker counts and
    /// block widths.
    pub blocked_steps_per_sec: f64,
    /// Best simd-tier throughput over the swept worker counts and block
    /// widths (feature level left to runtime detection).
    pub simd_steps_per_sec: f64,
    /// Best rows/sec over every swept cell of this (model, method) — the
    /// number the `ci.sh` bench regression gate compares against the
    /// repo-root `BENCH_step_throughput.json` snapshot.
    pub best_rows_per_sec: f64,
    /// `fused_steps_per_sec / scalar_steps_per_sec` (the pre-PR path).
    pub speedup_vs_scalar: f64,
    /// Were loss/grad/sq_norms bit-identical across all swept worker
    /// counts *and* vs the legacy path (fused tier), bit-identical across
    /// worker counts within the ghost tier, bit-identical across worker
    /// counts *and block widths* within the blocked tier, and
    /// bit-identical across worker counts, block widths *and forced
    /// feature levels* within the simd tier?
    pub deterministic: bool,
    /// Did the ghost outputs match the fused oracle within the documented
    /// relative tolerance?
    pub ghost_within_tolerance: bool,
    /// Did the blocked outputs match the fused oracle within the same
    /// documented relative tolerance?
    pub blocked_within_tolerance: bool,
    /// Did the simd outputs match the fused oracle within the same
    /// documented relative tolerance?
    pub simd_within_tolerance: bool,
}

/// DP-vs-non-DP cost of one model under one kernel tier at a fixed worker
/// count (the paper's headline: for BiTFiT this ratio should stay close
/// to 1, and the ghost tier is what carries it at scale).
#[derive(Debug, Clone)]
pub struct DpOverhead {
    pub model: String,
    /// Kernel tier the ratio was measured under.
    pub kernels: String,
    pub threads: usize,
    pub dp_steps_per_sec: f64,
    pub nondp_steps_per_sec: f64,
    /// `nondp_steps_per_sec / dp_steps_per_sec`; 1.0 means DP is free.
    pub overhead_ratio: f64,
}

/// Deterministic full-shape synthetic inputs for a train or eval
/// artifact: init params split per the step's subset, seeded x/y, an
/// all-active mask, and (train steps only) a clip radius of 0.1 so DP
/// clipping really runs.  Shared by the throughput harness and the
/// parallel-determinism test suite so both probe the *same* inputs;
/// callers wanting masked rows or a different radius overwrite
/// `inputs[4]` / `inputs[5]` on the returned vector.
pub fn synth_step_inputs(
    backend: &InterpreterBackend,
    meta: &ArtifactMeta,
    seed: u64,
) -> Result<Vec<Tensor>, EngineError> {
    let layout = backend.layout(&meta.model)?;
    let full = backend.init_params(&meta.model)?;
    let (frozen, train) = layout.split(&full, &meta.subset);
    let b = meta.batch;
    let mut rng = ChaChaRng::new(seed, 0xBE2C);
    let x_spec = &meta.inputs[2];
    let y_spec = &meta.inputs[3];
    let x = if x_spec.dtype == "int32" {
        Tensor::i32(
            x_spec.shape.clone(),
            (0..x_spec.elements()).map(|_| 1 + rng.below(300) as i32).collect(),
        )
    } else {
        Tensor::f32(
            x_spec.shape.clone(),
            (0..x_spec.elements()).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect(),
        )
    };
    let y = if y_spec.dtype == "int32" {
        Tensor::i32(
            y_spec.shape.clone(),
            (0..y_spec.elements()).map(|_| rng.below(4) as i32).collect(),
        )
    } else {
        Tensor::f32(
            y_spec.shape.clone(),
            (0..y_spec.elements()).map(|_| (rng.uniform() < 0.5) as i32 as f32).collect(),
        )
    };
    let mut inputs = vec![
        Tensor::f32(vec![meta.pf], frozen),
        Tensor::f32(vec![meta.pt], train),
        x,
        y,
        Tensor::f32(vec![b], vec![1.0; b]),
    ];
    if meta.inputs.len() > 5 {
        inputs.push(Tensor::scalar_f32(0.1)); // clip_r (train steps)
    }
    Ok(inputs)
}

/// Time `iters` executions of one interpreter train step (after one warmup
/// that also populates the step's scratch caches).  `block_rows` pins the
/// blocked tier's block width (ignored by the other tiers; `None` defers
/// to `FASTDP_BLOCK_ROWS`).
pub fn interp_throughput(
    model: &str,
    method: &str,
    threads: usize,
    mode: KernelMode,
    block_rows: Option<usize>,
    iters: usize,
) -> Result<ThroughputPoint, EngineError> {
    let mut backend = InterpreterBackend::with_config(Some(threads), Some(mode));
    backend.set_block_rows(block_rows);
    let artifact = format!("{model}__{method}");
    let step = backend.load(&artifact)?;
    let meta = step.meta().clone();
    let peak_scratch_bytes = backend.train_scratch_bytes(&artifact, mode, threads)?;
    let inputs = synth_step_inputs(&backend, &meta, 7)?;
    step.run(&inputs)?; // warmup
    let iters = iters.max(1);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        step.run(&inputs)?;
    }
    let sec_per_step = t0.elapsed().as_secs_f64() / iters as f64;
    Ok(ThroughputPoint {
        model: model.to_string(),
        method: method.to_string(),
        kernels: mode.name().to_string(),
        threads,
        block_rows: if matches!(mode, KernelMode::Blocked | KernelMode::Simd) {
            block_rows.unwrap_or_else(crate::kernels::blocked::block_rows_from_env)
        } else {
            0
        },
        sec_per_step,
        steps_per_sec: 1.0 / sec_per_step,
        rows_per_sec: meta.batch as f64 / sec_per_step,
        peak_scratch_bytes,
        roofline_utilization: step_roofline_seconds(&meta) / sec_per_step,
    })
}

/// Idealized step time on the `analysis::roofline` chip model — the
/// numerator of [`ThroughputPoint::roofline_utilization`].  Built as a
/// structural proxy from the artifact's own parameter counts: the
/// forward/backward/clip sweep costs ~6 flops per (row, position,
/// parameter) — positions only multiply work on the LM Gram path — and
/// moves every parameter once per row plus one resident copy over HBM.
/// Strictly positive for every artifact (pf + pt >= 1, batch >= 1), so
/// the resulting utilization is always finite.
fn step_roofline_seconds(meta: &ArtifactMeta) -> f64 {
    use crate::analysis::roofline::{Chip, KernelEstimate};
    let b = meta.batch.max(1) as u64;
    let params = (meta.pf + meta.pt).max(1) as u64;
    let npos = if meta.model.starts_with("lm") {
        (meta.inputs[2].elements() / meta.batch.max(1)).max(1) as u64
    } else {
        1
    };
    let est = KernelEstimate {
        name: format!("interp_step[{}__{}]", meta.model, meta.method),
        vmem_bytes: 4 * params,
        hbm_bytes: 4 * (b * params + params),
        flops: 6 * b * npos * params,
        hbm_lower_bound: 4 * params,
    };
    est.seconds(Chip::tpu_like())
}

/// One train step's f32 outputs (loss, grad, sq_norms) as plain values —
/// the tolerance-comparison twin of [`interp_output_bits`] used to check
/// the factor-based tiers against the fused oracle.
pub fn interp_outputs(
    model: &str,
    method: &str,
    threads: usize,
    mode: KernelMode,
) -> Result<Vec<Vec<f32>>, EngineError> {
    interp_outputs_blocked(model, method, threads, mode, None)
}

/// [`interp_outputs`] with the blocked tier's block width pinned — the
/// probe behind the bench's block-width bit-identity check.
pub fn interp_outputs_blocked(
    model: &str,
    method: &str,
    threads: usize,
    mode: KernelMode,
    block_rows: Option<usize>,
) -> Result<Vec<Vec<f32>>, EngineError> {
    let mut backend = InterpreterBackend::with_config(Some(threads), Some(mode));
    backend.set_block_rows(block_rows);
    let step = backend.load(&format!("{model}__{method}"))?;
    let meta = step.meta().clone();
    let inputs = synth_step_inputs(&backend, &meta, 7)?;
    let out = step.run(&inputs)?;
    Ok(out.iter().map(|t| t.as_f32().to_vec()).collect())
}

/// [`interp_outputs_blocked`] for the simd tier with the instruction-set
/// level forced (`None` defers to runtime detection and any registered
/// override) — the probe behind the bench's cross-level bit-identity
/// check.
pub fn interp_outputs_simd(
    model: &str,
    method: &str,
    threads: usize,
    block_rows: Option<usize>,
    level: Option<SimdLevel>,
) -> Result<Vec<Vec<f32>>, EngineError> {
    let mut backend = InterpreterBackend::with_config(Some(threads), Some(KernelMode::Simd));
    backend.set_block_rows(block_rows);
    backend.set_simd_level(level);
    let step = backend.load(&format!("{model}__{method}"))?;
    let meta = step.meta().clone();
    let inputs = synth_step_inputs(&backend, &meta, 7)?;
    let out = step.run(&inputs)?;
    Ok(out.iter().map(|t| t.as_f32().to_vec()).collect())
}

/// Largest element-wise relative difference between two output sets
/// (absolute floor 1e-6 so zeros compare cleanly).
pub fn max_rel_diff(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    let mut worst = 0.0f64;
    for (ta, tb) in a.iter().zip(b) {
        for (&x, &y) in ta.iter().zip(tb) {
            let scale = (x.abs().max(y.abs()) as f64).max(1e-6);
            worst = worst.max((x as f64 - y as f64).abs() / scale);
        }
    }
    worst
}

/// Bit patterns of a value set from [`interp_outputs`] (f32 copies are
/// bitwise-exact, so bits derived from values are the step's true bits).
pub fn output_bits_of(values: &[Vec<f32>]) -> Vec<Vec<u32>> {
    values.iter().map(|t| t.iter().map(|v| v.to_bits()).collect()).collect()
}

/// Bit patterns of one train step's outputs (loss, grad, sq_norms) — the
/// determinism probe: equal vectors mean bit-identical results.
pub fn interp_output_bits(
    model: &str,
    method: &str,
    threads: usize,
    mode: KernelMode,
) -> Result<Vec<Vec<u32>>, EngineError> {
    Ok(output_bits_of(&interp_outputs(model, method, threads, mode)?))
}

/// Render the `BENCH_step_throughput.json` document.  `sweep` is a
/// free-form string identifying the measurement configuration (quick
/// mode, steps, thread/block lists); the regression gate only compares
/// documents whose sweep strings match, so smoke runs are never judged
/// against full-sweep numbers.
pub fn throughput_json(
    points: &[ThroughputPoint],
    summaries: &[ThroughputSummary],
    overheads: &[DpOverhead],
    steps_per_point: usize,
    sweep: &str,
) -> String {
    let point = |p: &ThroughputPoint| {
        json::obj(vec![
            ("model", Json::Str(p.model.clone())),
            ("method", Json::Str(p.method.clone())),
            ("kernels", Json::Str(p.kernels.clone())),
            ("threads", Json::Num(p.threads as f64)),
            ("block_rows", Json::Num(p.block_rows as f64)),
            ("sec_per_step", Json::Num(p.sec_per_step)),
            ("steps_per_sec", Json::Num(p.steps_per_sec)),
            ("rows_per_sec", Json::Num(p.rows_per_sec)),
            ("peak_scratch_bytes", Json::Num(p.peak_scratch_bytes as f64)),
            ("roofline_utilization", Json::Num(p.roofline_utilization)),
        ])
    };
    let summary = |s: &ThroughputSummary| {
        json::obj(vec![
            ("model", Json::Str(s.model.clone())),
            ("method", Json::Str(s.method.clone())),
            ("best_threads", Json::Num(s.best_threads as f64)),
            ("scalar_steps_per_sec", Json::Num(s.scalar_steps_per_sec)),
            ("fused_steps_per_sec", Json::Num(s.fused_steps_per_sec)),
            ("ghost_steps_per_sec", Json::Num(s.ghost_steps_per_sec)),
            ("blocked_steps_per_sec", Json::Num(s.blocked_steps_per_sec)),
            ("simd_steps_per_sec", Json::Num(s.simd_steps_per_sec)),
            ("best_rows_per_sec", Json::Num(s.best_rows_per_sec)),
            ("speedup_vs_scalar", Json::Num(s.speedup_vs_scalar)),
            ("deterministic", Json::Bool(s.deterministic)),
            ("ghost_within_tolerance", Json::Bool(s.ghost_within_tolerance)),
            ("blocked_within_tolerance", Json::Bool(s.blocked_within_tolerance)),
            ("simd_within_tolerance", Json::Bool(s.simd_within_tolerance)),
        ])
    };
    let overhead = |o: &DpOverhead| {
        json::obj(vec![
            ("model", Json::Str(o.model.clone())),
            ("kernels", Json::Str(o.kernels.clone())),
            ("threads", Json::Num(o.threads as f64)),
            ("dp_steps_per_sec", Json::Num(o.dp_steps_per_sec)),
            ("nondp_steps_per_sec", Json::Num(o.nondp_steps_per_sec)),
            ("overhead_ratio", Json::Num(o.overhead_ratio)),
        ])
    };
    let doc = json::obj(vec![
        ("bench", Json::Str("step_throughput".to_string())),
        ("created_by", Json::Str("benches/throughput.rs".to_string())),
        ("sweep", Json::Str(sweep.to_string())),
        ("steps_per_point", Json::Num(steps_per_point as f64)),
        (
            "host_parallelism",
            Json::Num(crate::runtime::pool::host_parallelism() as f64),
        ),
        ("points", Json::Arr(points.iter().map(point).collect())),
        ("summary", Json::Arr(summaries.iter().map(summary).collect())),
        ("dp_overhead", Json::Arr(overheads.iter().map(overhead).collect())),
    ]);
    json::write(&doc)
}

/// Validate an emitted `BENCH_step_throughput.json` document against the
/// schema documented in the README (used by the `ci.sh` bench-smoke stage
/// and by the harness itself right after writing).
pub fn validate_throughput_json(src: &str) -> Result<(), String> {
    let v = json::parse(src)?;
    let field = |obj: &Json, key: &str| -> Result<(), String> {
        obj.get(key).map(|_| ()).ok_or_else(|| format!("missing field {key:?}"))
    };
    if v.get("bench").and_then(|b| b.as_str()) != Some("step_throughput") {
        return Err("bench field is not \"step_throughput\"".to_string());
    }
    if v.get("sweep").and_then(|s| s.as_str()).is_none() {
        return Err("missing sweep config string".to_string());
    }
    for key in ["steps_per_point", "host_parallelism"] {
        if v.get(key).and_then(|n| n.as_f64()).is_none() {
            return Err(format!("missing numeric field {key:?}"));
        }
    }
    let points = v
        .get("points")
        .and_then(|p| p.as_arr())
        .ok_or_else(|| "missing points array".to_string())?;
    if points.is_empty() {
        return Err("points array is empty".to_string());
    }
    let point_keys = [
        "model",
        "method",
        "kernels",
        "threads",
        "block_rows",
        "sec_per_step",
        "steps_per_sec",
        "rows_per_sec",
        "peak_scratch_bytes",
        "roofline_utilization",
    ];
    for p in points {
        for key in point_keys {
            field(p, key)?;
        }
    }
    let summary = v
        .get("summary")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| "missing summary array".to_string())?;
    let summary_keys = [
        "model",
        "method",
        "best_threads",
        "scalar_steps_per_sec",
        "fused_steps_per_sec",
        "ghost_steps_per_sec",
        "blocked_steps_per_sec",
        "simd_steps_per_sec",
        "best_rows_per_sec",
        "speedup_vs_scalar",
        "deterministic",
        "ghost_within_tolerance",
        "blocked_within_tolerance",
        "simd_within_tolerance",
    ];
    for s in summary {
        for key in summary_keys {
            field(s, key)?;
        }
    }
    let overhead = v
        .get("dp_overhead")
        .and_then(|o| o.as_arr())
        .ok_or_else(|| "missing dp_overhead array".to_string())?;
    for o in overhead {
        for key in [
            "model",
            "kernels",
            "threads",
            "dp_steps_per_sec",
            "nondp_steps_per_sec",
            "overhead_ratio",
        ] {
            field(o, key)?;
        }
    }
    Ok(())
}

/// Compare a freshly emitted `BENCH_step_throughput.json` document against
/// a baseline snapshot and fail on a throughput regression: for every
/// (model, method) summary present in **both** documents, the new
/// `best_rows_per_sec` must be at least `(1 - max_drop)` of the
/// baseline's.  Documents with different `sweep` configuration strings
/// are never compared (a smoke run must not be judged against a
/// full-sweep snapshot — the gate reports the mismatch and passes), and
/// rows only one document has (or baseline rows predating the
/// `best_rows_per_sec` field) are skipped, so the gate survives sweep
/// and schema growth.  Returns the human-readable comparison lines on
/// success; the offending lines in the error on failure.
pub fn gate_throughput_regression(
    new_doc: &str,
    baseline_doc: &str,
    max_drop: f64,
) -> Result<Vec<String>, String> {
    let parse = |src: &str| -> Result<(String, Vec<(String, String, f64)>), String> {
        let v = json::parse(src)?;
        let sweep = v.get("sweep").and_then(|s| s.as_str()).unwrap_or_default().to_string();
        let arr = v
            .get("summary")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| "missing summary array".to_string())?;
        let mut out = Vec::new();
        for s in arr {
            let model = s.get("model").and_then(|m| m.as_str()).unwrap_or_default();
            let method = s.get("method").and_then(|m| m.as_str()).unwrap_or_default();
            if let Some(r) = s.get("best_rows_per_sec").and_then(|r| r.as_f64()) {
                out.push((model.to_string(), method.to_string(), r));
            }
        }
        Ok((sweep, out))
    };
    let (new_sweep, new) = parse(new_doc).map_err(|e| format!("new document: {e}"))?;
    let (base_sweep, base) = parse(baseline_doc).map_err(|e| format!("baseline: {e}"))?;
    if new_sweep != base_sweep {
        return Ok(vec![format!(
            "skipped: sweep config mismatch (new {new_sweep:?} vs baseline {base_sweep:?}) \
             — refresh the snapshot with this configuration to re-arm the gate"
        )]);
    }
    let mut report = Vec::new();
    let mut failures = Vec::new();
    for (model, method, old_r) in &base {
        let Some((_, _, new_r)) =
            new.iter().find(|(m, me, _)| m == model && me == method)
        else {
            continue;
        };
        if *old_r <= 0.0 {
            continue;
        }
        let ratio = new_r / old_r;
        let line = format!(
            "{model}__{method}: {new_r:.1} rows/s vs snapshot {old_r:.1} ({:+.1}%)",
            (ratio - 1.0) * 100.0
        );
        if ratio < 1.0 - max_drop {
            failures.push(line);
        } else {
            report.push(line);
        }
    }
    if failures.is_empty() {
        Ok(report)
    } else {
        Err(format!(
            "throughput regression > {:.0}% vs baseline:\n  {}",
            max_drop * 100.0,
            failures.join("\n  ")
        ))
    }
}

/// Map artifact method names onto complexity-table methods.
pub fn parse_method(method: &str) -> crate::analysis::complexity::Method {
    use crate::analysis::complexity::Method;
    match method {
        "dp-bitfit" | "dp-bitfit-add" => Method::DpBias,
        "nondp-bitfit" => Method::NonDpBias,
        "dp-full-ghost" => Method::GhostClipFull,
        "dp-full-opacus" => Method::OpacusFull,
        "dp-lora" => Method::DpLora { rank: 8 },
        "dp-adapter" => Method::DpAdapter { rank: 16 },
        _ => Method::NonDpFull,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_doc() -> String {
        sample_doc_with_rows(64.0)
    }

    fn sample_doc_with_rows(best_rows_per_sec: f64) -> String {
        let points = vec![ThroughputPoint {
            model: "cls-base".into(),
            method: "dp-bitfit".into(),
            kernels: "fused".into(),
            threads: 2,
            block_rows: 0,
            sec_per_step: 0.5,
            steps_per_sec: 2.0,
            rows_per_sec: 64.0,
            peak_scratch_bytes: 6084 * 8,
            roofline_utilization: 0.25,
        }];
        let summaries = vec![ThroughputSummary {
            model: "cls-base".into(),
            method: "dp-bitfit".into(),
            best_threads: 2,
            scalar_steps_per_sec: 0.5,
            fused_steps_per_sec: 2.0,
            ghost_steps_per_sec: 2.1,
            blocked_steps_per_sec: 4.2,
            simd_steps_per_sec: 4.4,
            best_rows_per_sec,
            speedup_vs_scalar: 4.0,
            deterministic: true,
            ghost_within_tolerance: true,
            blocked_within_tolerance: true,
            simd_within_tolerance: true,
        }];
        let overheads = vec![DpOverhead {
            model: "cls-base".into(),
            kernels: "ghost".into(),
            threads: 2,
            dp_steps_per_sec: 2.0,
            nondp_steps_per_sec: 2.2,
            overhead_ratio: 1.1,
        }];
        throughput_json(&points, &summaries, &overheads, 3, "quick steps=3 threads=1,2")
    }

    #[test]
    fn throughput_json_roundtrips_and_validates() {
        let doc = sample_doc();
        validate_throughput_json(&doc).unwrap();
        let v = json::parse(&doc).unwrap();
        assert_eq!(v.req("bench").as_str(), Some("step_throughput"));
        assert_eq!(v.req("points").as_arr().unwrap().len(), 1);
        let s = &v.req("summary").as_arr().unwrap()[0];
        assert_eq!(s.req("speedup_vs_scalar").as_f64(), Some(4.0));
        assert_eq!(s.req("deterministic").as_bool(), Some(true));
    }

    #[test]
    fn validator_rejects_broken_documents() {
        assert!(validate_throughput_json("{}").is_err());
        assert!(validate_throughput_json("not json").is_err());
        // right shape, wrong bench tag
        let doc = sample_doc().replace("step_throughput", "other_bench");
        assert!(validate_throughput_json(&doc).is_err());
        // empty points array is rejected
        let doc = sample_doc();
        let start = doc.find("\"points\"").unwrap();
        let open = doc[start..].find('[').unwrap() + start;
        let close = doc[open..].find(']').unwrap() + open;
        let broken = format!("{}{}", &doc[..open + 1], &doc[close..]);
        assert!(validate_throughput_json(&broken).is_err());
    }

    #[test]
    fn gate_passes_within_budget_and_fails_beyond_it() {
        let base = sample_doc_with_rows(100.0);
        // 10% drop passes a 20% gate
        let ok = gate_throughput_regression(&sample_doc_with_rows(90.0), &base, 0.2).unwrap();
        assert_eq!(ok.len(), 1, "one compared row");
        // 30% drop fails it, and the message names the cell
        let err = gate_throughput_regression(&sample_doc_with_rows(70.0), &base, 0.2)
            .unwrap_err();
        assert!(err.contains("cls-base__dp-bitfit"), "{err}");
        // an improvement always passes
        gate_throughput_regression(&sample_doc_with_rows(250.0), &base, 0.2).unwrap();
        // disjoint (model, method) sets compare nothing and pass
        let other = sample_doc_with_rows(100.0).replace("cls-base", "lm-large");
        let ok = gate_throughput_regression(&sample_doc_with_rows(1.0), &other, 0.2).unwrap();
        assert!(ok.is_empty());
        // different sweep configurations are never compared: a tiny smoke
        // run against a full-sweep snapshot passes with a mismatch note
        let full = sample_doc_with_rows(100.0).replace("quick steps=3", "full steps=30");
        let ok = gate_throughput_regression(&sample_doc_with_rows(1.0), &full, 0.2).unwrap();
        assert_eq!(ok.len(), 1);
        assert!(ok[0].contains("sweep config mismatch"), "{}", ok[0]);
        // broken baselines are typed errors, not panics
        assert!(gate_throughput_regression(&base, "not json", 0.2).is_err());
    }

    #[test]
    fn interp_throughput_measures_and_is_deterministic() {
        let p =
            interp_throughput("cls-base", "dp-bitfit", 2, KernelMode::Fused, None, 1).unwrap();
        assert!(p.sec_per_step > 0.0 && p.sec_per_step.is_finite());
        assert!(p.steps_per_sec > 0.0 && p.rows_per_sec > p.steps_per_sec);
        assert_eq!(p.kernels, "fused");
        assert_eq!(p.block_rows, 0, "row-at-a-time tiers record no block width");
        assert!(p.peak_scratch_bytes > 0);
        // same inputs, different worker counts and kernels: identical bits
        let a = interp_output_bits("cls-base", "dp-bitfit", 1, KernelMode::Fused).unwrap();
        let b = interp_output_bits("cls-base", "dp-bitfit", 2, KernelMode::Fused).unwrap();
        let c = interp_output_bits("cls-base", "dp-bitfit", 1, KernelMode::Legacy).unwrap();
        assert_eq!(a, b);
        assert_eq!(a, c);
        // ghost: bit-identical across worker counts within the tier, and
        // within tolerance of the fused oracle
        let g1 = interp_output_bits("cls-base", "dp-bitfit", 1, KernelMode::Ghost).unwrap();
        let g2 = interp_output_bits("cls-base", "dp-bitfit", 2, KernelMode::Ghost).unwrap();
        assert_eq!(g1, g2);
        let f = interp_outputs("cls-base", "dp-bitfit", 1, KernelMode::Fused).unwrap();
        let g = interp_outputs("cls-base", "dp-bitfit", 1, KernelMode::Ghost).unwrap();
        assert!(max_rel_diff(&f, &g) < 1e-4, "ghost diverges: {}", max_rel_diff(&f, &g));
        // blocked: bit-identical across worker counts AND block widths,
        // within tolerance of the fused oracle
        let bl = |threads: usize, blk: usize| {
            output_bits_of(
                &interp_outputs_blocked(
                    "cls-base",
                    "dp-bitfit",
                    threads,
                    KernelMode::Blocked,
                    Some(blk),
                )
                .unwrap(),
            )
        };
        let base_bits = bl(1, 8);
        assert_eq!(base_bits, bl(2, 8));
        assert_eq!(base_bits, bl(1, 3));
        assert_eq!(base_bits, bl(2, 32));
        let blk =
            interp_outputs_blocked("cls-base", "dp-bitfit", 1, KernelMode::Blocked, Some(8))
                .unwrap();
        assert!(max_rel_diff(&f, &blk) < 1e-4, "blocked diverges: {}", max_rel_diff(&f, &blk));
        // simd: bit-identical across worker counts AND forced feature
        // levels, within tolerance of the fused oracle
        let sd = |threads: usize, level: Option<SimdLevel>| {
            output_bits_of(
                &interp_outputs_simd("cls-base", "dp-bitfit", threads, Some(8), level).unwrap(),
            )
        };
        let simd_bits = sd(1, None);
        assert_eq!(simd_bits, sd(2, None));
        assert_eq!(simd_bits, sd(2, Some(SimdLevel::Scalar)));
        let sm = interp_outputs_simd("cls-base", "dp-bitfit", 1, Some(8), None).unwrap();
        assert!(max_rel_diff(&f, &sm) < 1e-4, "simd diverges: {}", max_rel_diff(&f, &sm));
    }

    #[test]
    fn roofline_utilization_is_finite_for_every_tier() {
        for mode in
            [KernelMode::Fused, KernelMode::Ghost, KernelMode::Blocked, KernelMode::Simd]
        {
            let p = interp_throughput("cls-base", "dp-bitfit", 1, mode, Some(8), 1).unwrap();
            assert!(
                p.roofline_utilization.is_finite() && p.roofline_utilization > 0.0,
                "{}: utilization {}",
                mode.name(),
                p.roofline_utilization
            );
        }
        // the LM Gram path scales the flop proxy by positions
        let p = interp_throughput("lm-small", "dp-bitfit", 1, KernelMode::Simd, None, 1).unwrap();
        assert!(p.roofline_utilization.is_finite() && p.roofline_utilization > 0.0);
        assert!(p.block_rows > 0, "simd cells record their block width");
    }
}
