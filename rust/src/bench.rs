//! Shared bench harness: fine-tune-and-evaluate jobs + step timing.
//!
//! Every `benches/*.rs` target regenerates one paper table/figure through
//! these helpers.  Wall-clock scale is controlled by env vars so the same
//! code runs as a quick smoke or a full reproduction:
//!   FASTDP_BENCH_STEPS  — fine-tuning steps per run (default 30)
//!   FASTDP_BENCH_QUICK  — set to skip the slowest sweep points

use anyhow::Result;

use crate::coordinator::optim::OptimKind;
use crate::coordinator::pretrain::{pretrained_params, reset_head, PretrainSpec};
use crate::coordinator::trainer::{evaluate_params, Trainer, TrainerConfig};
use crate::coordinator::workloads;
use crate::dp::calibrate;
use crate::runtime::Runtime;
use crate::util::tensor::Tensor;

pub fn bench_steps(default: usize) -> usize {
    std::env::var("FASTDP_BENCH_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

pub fn quick() -> bool {
    std::env::var("FASTDP_BENCH_QUICK").is_ok()
}

/// A fine-tune-then-evaluate job specification.
#[derive(Debug, Clone)]
pub struct FtJob {
    pub model: String,
    pub artifact: String,
    pub task: String,
    pub pretrain_task: String,
    pub steps: usize,
    pub batch: usize,
    pub lr: f64,
    /// Target epsilon; 0.0 => non-private.
    pub eps: f64,
    pub clip_mode_suffix: Option<String>,
    pub seed: u64,
    pub n_train: usize,
    pub n_eval: usize,
}

impl FtJob {
    pub fn new(model: &str, method: &str, task: &str) -> FtJob {
        let pretrain_task = match task {
            "e2e" => "pretrain-lm",
            "cifar" => "cifar-pretrain",
            "celeba" => "celeba",
            _ => "pretrain-cls",
        };
        FtJob {
            model: model.to_string(),
            artifact: format!("{model}__{method}"),
            task: task.to_string(),
            pretrain_task: pretrain_task.to_string(),
            steps: bench_steps(30),
            batch: 128,
            lr: if method.contains("bitfit") || method.contains("lastlayer") { 5e-3 } else { 5e-4 },
            eps: if method.starts_with("dp-") { 8.0 } else { 0.0 },
            clip_mode_suffix: None,
            seed: 3,
            n_train: 4096,
            n_eval: 1024,
        }
    }

    fn artifact_name(&self) -> String {
        match &self.clip_mode_suffix {
            Some(s) => format!("{}__{s}", self.artifact),
            None => self.artifact.clone(),
        }
    }
}

/// Outcome of one fine-tuning job.
#[derive(Debug, Clone, Copy)]
pub struct FtOutcome {
    /// classification: accuracy in [0,1]; LM: metric_a = nll, metric_b = tokens
    pub metric_a: f64,
    pub metric_b: f64,
    pub accuracy: f64,
    pub eps_spent: f64,
    pub sec_per_step: f64,
}

/// Pretrain (cached) -> reset head -> fine-tune -> evaluate.
///
/// Returns the outcome and the fine-tuned full parameter vector.
pub fn finetune(rt: &mut Runtime, job: &FtJob) -> Result<(FtOutcome, Vec<f32>)> {
    let mut spec = PretrainSpec::new(&job.model, &job.pretrain_task);
    if job.pretrain_task == "celeba" {
        // CelebA runs fine-tune from scratch-ish backbone (paper uses
        // ImageNet-pretrained ResNet; our analog pretrains on the same
        // attribute distribution with a different seed)
        spec.seed = 17;
    }
    let mut params = pretrained_params(rt, &spec, true)?;
    if job.task != "e2e" {
        reset_head(rt, &job.model, &mut params)?;
    }
    let train = workloads::build(rt, &job.model, &job.task, job.n_train, job.seed * 100 + 1)?;
    let test = workloads::build(rt, &job.model, &job.task, job.n_eval, job.seed * 100 + 2)?;
    let eval_exe = rt.load(&format!("{}__eval", job.model))?;

    let mut tc = TrainerConfig::new(&job.artifact_name());
    tc.logical_batch = job.batch;
    tc.lr = job.lr;
    tc.optim = if job.task == "e2e" { OptimKind::AdamW } else { OptimKind::Adam };
    tc.clip_r = 0.1;
    tc.seed = job.seed;
    if job.eps > 0.0 {
        tc.sigma = calibrate::calibrate_sigma(
            job.batch as f64 / job.n_train as f64,
            job.steps as u64,
            job.eps,
            1e-5,
        );
    }
    let mut t = Trainer::new(rt, tc, train.len(), Some(params))?;
    let t0 = std::time::Instant::now();
    for _ in 0..job.steps {
        t.train_step(&train)?;
    }
    let sec_per_step = t0.elapsed().as_secs_f64() / job.steps.max(1) as f64;
    let eps_spent = t.accountant.as_ref().map(|a| a.epsilon().0).unwrap_or(0.0);
    let full = t.full_params();
    let (a, b, n) = evaluate_params(&eval_exe, &full, &test, job.n_eval)?;
    Ok((
        FtOutcome {
            metric_a: a,
            metric_b: b,
            accuracy: b / n.max(1) as f64,
            eps_spent,
            sec_per_step,
        },
        full,
    ))
}

/// Measure seconds per microbatch execution of a train artifact (init
/// params, synthetic batch, `iters` timed runs after one warmup).
pub fn step_time(rt: &mut Runtime, artifact: &str, iters: usize) -> Result<f64> {
    let exe = rt.load(artifact)?;
    let meta = exe.meta.clone();
    let layout = rt.layout(&meta.model)?;
    let full = rt.init_params(&meta.model)?;
    let (frozen, train) = layout.split(&full, &meta.subset);
    let b = meta.batch;
    let inputs: Vec<Tensor> = {
        let mut v = vec![
            Tensor::f32(vec![meta.pf], frozen),
            Tensor::f32(vec![meta.pt], train),
        ];
        for spec in &meta.inputs[2..] {
            let n = spec.elements();
            if spec.dtype == "int32" {
                v.push(Tensor::i32(spec.shape.clone(), vec![1; n]));
            } else if spec.shape.is_empty() {
                v.push(Tensor::scalar_f32(1.0));
            } else {
                v.push(Tensor::f32(spec.shape.clone(), vec![0.5; n]));
            }
        }
        v
    };
    exe.run(&inputs)?; // warmup
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        exe.run(&inputs)?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters.max(1) as f64 / b as f64)
}

/// Estimated training memory (bytes) for one of our trained models under a
/// method, via the analytical model of `analysis::complexity`.
pub fn memory_estimate(rt: &Runtime, model: &str, method: &str, b: u64) -> Result<u64> {
    let shape = workloads::model_shape(rt, model)?;
    let entry = &rt.manifest.models[model];
    let cfg = &entry.cfg;
    let g = |k: &str| cfg.get(k).and_then(|v| v.as_usize()).unwrap_or(0) as u64;
    let (t, d, layers) = match shape.kind.as_str() {
        "cls" | "lm" => (g("t"), g("d"), g("layers")),
        "vit" => ((g("img") / g("patch")).pow(2) + 1, g("d"), g("layers")),
        _ => (g("img").pow(2), 32, 3),
    };
    let net = crate::analysis::complexity::Network::uniform(
        layers.max(1) as usize,
        b,
        t.max(1),
        d.max(16),
        d.max(16),
    );
    let m = parse_method(method);
    Ok(net.memory_bytes(m))
}

/// Map artifact method names onto complexity-table methods.
pub fn parse_method(method: &str) -> crate::analysis::complexity::Method {
    use crate::analysis::complexity::Method;
    match method {
        "dp-bitfit" | "dp-bitfit-add" => Method::DpBias,
        "nondp-bitfit" => Method::NonDpBias,
        "dp-full-ghost" => Method::GhostClipFull,
        "dp-full-opacus" => Method::OpacusFull,
        "dp-lora" => Method::DpLora { rank: 8 },
        "dp-adapter" => Method::DpAdapter { rank: 16 },
        _ => Method::NonDpFull,
    }
}
