//! Shared bench harness: fine-tune-and-evaluate jobs + step timing, all on
//! top of `fastdp::engine` (so every bench runs against either backend).
//!
//! Every `benches/*.rs` target regenerates one paper table/figure through
//! these helpers.  Wall-clock scale is controlled by env vars so the same
//! code runs as a quick smoke or a full reproduction:
//!   FASTDP_BENCH_STEPS  — fine-tuning steps per run (default 30)
//!   FASTDP_BENCH_QUICK  — set to skip the slowest sweep points

use crate::coordinator::optim::OptimKind;
use crate::coordinator::pretrain::{pretrained_params, PretrainSpec};
use crate::dp::clip::ClipMode;
use crate::engine::{Engine, EngineError, JobSpec, Method};
use crate::util::tensor::Tensor;

pub fn bench_steps(default: usize) -> usize {
    std::env::var("FASTDP_BENCH_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

pub fn quick() -> bool {
    std::env::var("FASTDP_BENCH_QUICK").is_ok()
}

/// A fine-tune-then-evaluate job specification.
#[derive(Debug, Clone)]
pub struct FtJob {
    pub model: String,
    /// Artifact method fragment, e.g. `dp-bitfit` / `nondp-full`.
    pub method: String,
    pub task: String,
    pub pretrain_task: String,
    pub steps: usize,
    pub batch: usize,
    pub lr: f64,
    /// Target epsilon for `dp-*` methods (ignored for `nondp-*`).
    pub eps: f64,
    pub clip_mode: ClipMode,
    pub seed: u64,
    pub n_train: usize,
    pub n_eval: usize,
}

impl FtJob {
    pub fn new(model: &str, method: &str, task: &str) -> FtJob {
        let pretrain_task = match task {
            "e2e" => "pretrain-lm",
            "cifar" => "cifar-pretrain",
            "celeba" => "celeba",
            _ => "pretrain-cls",
        };
        FtJob {
            model: model.to_string(),
            method: method.to_string(),
            task: task.to_string(),
            pretrain_task: pretrain_task.to_string(),
            steps: bench_steps(30),
            batch: 128,
            lr: if method.contains("bitfit") || method.contains("lastlayer") { 5e-3 } else { 5e-4 },
            eps: if method.starts_with("dp-") { 8.0 } else { 0.0 },
            clip_mode: ClipMode::Abadi,
            seed: 3,
            n_train: 4096,
            n_eval: 1024,
        }
    }

    /// Translate into an engine `JobSpec`.
    pub fn spec(&self) -> Result<JobSpec, EngineError> {
        let (method, private) = Method::parse(&self.method)
            .ok_or_else(|| EngineError::spec(format!("unknown method {:?}", self.method)))?;
        let mut b = JobSpec::builder(&self.model, method)
            .task(&self.task)
            .optim(if self.task == "e2e" { OptimKind::AdamW } else { OptimKind::Adam })
            .lr(self.lr)
            .clip_r(0.1)
            .clip_mode(self.clip_mode)
            .batch(self.batch)
            .steps(self.steps.max(1) as u64)
            .n_train(self.n_train)
            .seed(self.seed);
        if private {
            b = if self.eps > 0.0 {
                b.eps(self.eps).delta(1e-5)
            } else {
                // DP pipeline (Poisson sampling, clipping) with no noise
                b.sigma(0.0).delta(1e-5)
            };
        }
        b.build()
    }
}

/// Outcome of one fine-tuning job.
#[derive(Debug, Clone, Copy)]
pub struct FtOutcome {
    /// classification: accuracy in [0,1]; LM: metric_a = nll, metric_b = tokens
    pub metric_a: f64,
    pub metric_b: f64,
    pub accuracy: f64,
    pub eps_spent: f64,
    pub sec_per_step: f64,
}

/// Pretrain (cached) -> reset head -> fine-tune -> evaluate.
///
/// Returns the outcome and the fine-tuned full parameter vector.
pub fn finetune(engine: &mut Engine, job: &FtJob) -> Result<(FtOutcome, Vec<f32>), EngineError> {
    let mut spec = PretrainSpec::new(&job.model, &job.pretrain_task);
    if job.pretrain_task == "celeba" {
        // CelebA runs fine-tune from scratch-ish backbone (paper uses
        // ImageNet-pretrained ResNet; our analog pretrains on the same
        // attribute distribution with a different seed)
        spec.seed = 17;
    }
    let mut params = pretrained_params(engine, &spec, true)?;
    if job.task != "e2e" {
        engine.reset_head(&job.model, &mut params)?;
    }
    let train = engine.dataset(&job.model, &job.task, job.n_train, job.seed * 100 + 1)?;
    let test = engine.dataset(&job.model, &job.task, job.n_eval, job.seed * 100 + 2)?;

    let job_spec = job.spec()?;
    let mut session = engine.session_from(&job_spec, params)?;
    let t0 = std::time::Instant::now();
    for _ in 0..job.steps {
        session.run_step(&train)?;
    }
    let sec_per_step = t0.elapsed().as_secs_f64() / job.steps.max(1) as f64;
    let eps_spent = session.privacy_spent().epsilon;
    let out = session.evaluate(&test, job.n_eval)?;
    Ok((
        FtOutcome {
            metric_a: out.metric_a,
            metric_b: out.metric_b,
            accuracy: out.accuracy(),
            eps_spent,
            sec_per_step,
        },
        session.full_params(),
    ))
}

/// Measure seconds per microbatch example of a train step (init params,
/// synthetic batch, `iters` timed runs after one warmup).
pub fn step_time(engine: &mut Engine, artifact: &str, iters: usize) -> Result<f64, EngineError> {
    let step = engine.runner(artifact)?;
    let meta = step.meta().clone();
    let layout = engine.layout(&meta.model)?;
    let full = engine.init_params(&meta.model)?;
    let (frozen, train) = layout.split(&full, &meta.subset);
    let b = meta.batch;
    let inputs: Vec<Tensor> = {
        let mut v =
            vec![Tensor::f32(vec![meta.pf], frozen), Tensor::f32(vec![meta.pt], train)];
        for spec in &meta.inputs[2..] {
            let n = spec.elements();
            if spec.dtype == "int32" {
                v.push(Tensor::i32(spec.shape.clone(), vec![1; n]));
            } else if spec.shape.is_empty() {
                v.push(Tensor::scalar_f32(1.0));
            } else {
                v.push(Tensor::f32(spec.shape.clone(), vec![0.5; n]));
            }
        }
        v
    };
    step.run(&inputs)?; // warmup
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        step.run(&inputs)?;
    }
    Ok(t0.elapsed().as_secs_f64() / iters.max(1) as f64 / b as f64)
}

/// Estimated training memory (bytes) for one of our trained models under a
/// method, via the analytical model of `analysis::complexity`.
pub fn memory_estimate(
    engine: &Engine,
    model: &str,
    method: &str,
    b: u64,
) -> Result<u64, EngineError> {
    let info = engine.model_info(model)?;
    let shape = &info.shape;
    let (t, d, layers) = match shape.kind.as_str() {
        "cls" | "lm" => (shape.t as u64, info.d as u64, info.layers as u64),
        "vit" => {
            let patch = info.patch.max(1) as u64;
            (((shape.img as u64) / patch).pow(2).max(1) + 1, info.d as u64, info.layers as u64)
        }
        _ => ((shape.img as u64).pow(2), 32, 3),
    };
    let net = crate::analysis::complexity::Network::uniform(
        layers.max(1) as usize,
        b,
        t.max(1),
        d.max(16),
        d.max(16),
    );
    let m = parse_method(method);
    Ok(net.memory_bytes(m))
}

/// Map artifact method names onto complexity-table methods.
pub fn parse_method(method: &str) -> crate::analysis::complexity::Method {
    use crate::analysis::complexity::Method;
    match method {
        "dp-bitfit" | "dp-bitfit-add" => Method::DpBias,
        "nondp-bitfit" => Method::NonDpBias,
        "dp-full-ghost" => Method::GhostClipFull,
        "dp-full-opacus" => Method::OpacusFull,
        "dp-lora" => Method::DpLora { rank: 8 },
        "dp-adapter" => Method::DpAdapter { rank: 16 },
        _ => Method::NonDpFull,
    }
}
