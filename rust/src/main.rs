//! `fastdp` CLI entrypoint (subcommands filled in by `coordinator::cli`).

fn main() {
    if let Err(e) = fastdp::coordinator::cli::main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
