//! Typed errors for the engine API.
//!
//! Everything the engine can reject is enumerated here; `EngineError`
//! implements `std::error::Error`, so callers that live in `anyhow`-land
//! (examples, the CLI) can still use `?` on engine results.

use std::fmt;

/// The engine's error type.
#[derive(Debug)]
pub enum EngineError {
    /// A `JobSpec` failed validation (builder reports the offending field).
    InvalidSpec(String),
    /// The backend does not know the requested model.
    UnknownModel(String),
    /// The backend cannot provide the requested artifact/step.
    UnknownArtifact { name: String, detail: String },
    /// Dataset construction failed (unknown task, shape mismatch, ...).
    Data(String),
    /// The backend failed to load or execute a step.
    Backend { backend: String, detail: String },
    /// Checkpoint I/O failed (missing file, CRC mismatch, wrong model, ...).
    Checkpoint(String),
    /// Metric-sink I/O failed.
    Metrics(String),
}

impl EngineError {
    /// Shorthand for a backend failure.
    pub fn backend(backend: &str, detail: impl fmt::Display) -> EngineError {
        EngineError::Backend { backend: backend.to_string(), detail: detail.to_string() }
    }

    /// Shorthand for an invalid-spec failure.
    pub fn spec(detail: impl fmt::Display) -> EngineError {
        EngineError::InvalidSpec(detail.to_string())
    }
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::InvalidSpec(d) => write!(f, "invalid job spec: {d}"),
            EngineError::UnknownModel(m) => write!(f, "unknown model {m:?}"),
            EngineError::UnknownArtifact { name, detail } => {
                write!(f, "artifact {name:?} unavailable: {detail}")
            }
            EngineError::Data(d) => write!(f, "dataset error: {d}"),
            EngineError::Backend { backend, detail } => {
                write!(f, "backend {backend:?} failed: {detail}")
            }
            EngineError::Checkpoint(d) => write!(f, "checkpoint error: {d}"),
            EngineError::Metrics(d) => write!(f, "metrics error: {d}"),
        }
    }
}

impl std::error::Error for EngineError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_variant() {
        let e = EngineError::spec("batch must be positive");
        assert!(e.to_string().contains("invalid job spec"));
        let e = EngineError::backend("interpreter", "boom");
        assert!(e.to_string().contains("interpreter"));
        // EngineError flows into anyhow-land via std::error::Error
        let a: anyhow::Error = EngineError::UnknownModel("x".into()).into();
        assert!(a.to_string().contains("unknown model"));
    }
}
