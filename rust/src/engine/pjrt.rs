//! The PJRT backend: AOT HLO artifacts executed through `runtime::Runtime`.
//!
//! This is a thin adapter — compilation caching, device upload and the
//! literal/buffer paths all live in [`crate::runtime`]; this module maps
//! them onto the [`Backend`] / [`StepRunner`] contract and converts errors
//! into typed [`EngineError`]s.

use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::coordinator::workloads::ModelShape;
use crate::runtime::{ArtifactMeta, Executable, Layout, Runtime};
use crate::util::tensor::Tensor;

use super::backend::{Backend, ModelInfo, Pinned, StepRunner};
use super::error::EngineError;

const NAME: &str = "pjrt";

/// Backend over a compiled artifact directory.
pub struct PjrtBackend {
    rt: Runtime,
}

impl PjrtBackend {
    /// Open an artifact directory (expects `manifest.json` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<PjrtBackend, EngineError> {
        let rt = Runtime::open(dir).map_err(|e| EngineError::backend(NAME, format!("{e:#}")))?;
        Ok(PjrtBackend { rt })
    }

    /// Whether `dir` looks like an artifact directory.
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.json").exists()
    }

    /// What a user who wanted this backend can run instead: the reference
    /// interpreter's kernel tiers, enumerated from [`KernelMode`] so a new
    /// tier can never go missing from the message (the tier vocabulary and
    /// the knob name both live in one place).
    pub fn interpreter_tier_hint() -> String {
        use crate::kernels::KernelMode;
        let tiers = [
            KernelMode::Fused,
            KernelMode::Ghost,
            KernelMode::Blocked,
            KernelMode::Simd,
            KernelMode::Legacy,
        ];
        let names: Vec<&str> = tiers.iter().map(|m| m.name()).collect();
        format!(
            "the interpreter serves every step via its {} kernel tiers ({}={})",
            names.join("/"),
            crate::runtime::env::KERNELS.name,
            KernelMode::default().name()
        )
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        NAME
    }

    fn platform(&self) -> String {
        self.rt.platform()
    }

    fn models(&self) -> Vec<String> {
        self.rt.manifest.models.keys().cloned().collect()
    }

    fn artifacts(&self) -> Vec<String> {
        self.rt.manifest.artifacts.clone()
    }

    fn model_info(&self, model: &str) -> Result<ModelInfo, EngineError> {
        let entry = self
            .rt
            .manifest
            .models
            .get(model)
            .ok_or_else(|| EngineError::UnknownModel(model.to_string()))?;
        let g = |k: &str| entry.cfg.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
        Ok(ModelInfo {
            shape: ModelShape {
                kind: entry.kind.clone(),
                t: g("t"),
                vocab: g("vocab"),
                img: g("img"),
                n_cls: g("n_cls"),
                n_out: g("n_out"),
            },
            n_params: entry.n_params,
            d: g("d"),
            layers: g("layers"),
            patch: g("patch"),
        })
    }

    fn layout(&self, model: &str) -> Result<Layout, EngineError> {
        self.rt.layout(model).map_err(|e| EngineError::backend(NAME, format!("{e:#}")))
    }

    fn init_params(&self, model: &str) -> Result<Vec<f32>, EngineError> {
        self.rt.init_params(model).map_err(|e| EngineError::backend(NAME, format!("{e:#}")))
    }

    fn artifact_meta(&self, artifact: &str) -> Result<ArtifactMeta, EngineError> {
        ArtifactMeta::load(self.rt.artifact_dir(), artifact).map_err(|e| {
            EngineError::UnknownArtifact { name: artifact.to_string(), detail: format!("{e:#}") }
        })
    }

    fn load(&mut self, artifact: &str) -> Result<Rc<dyn StepRunner>, EngineError> {
        let exe = self.rt.load(artifact).map_err(|e| EngineError::UnknownArtifact {
            name: artifact.to_string(),
            detail: format!("{e:#}"),
        })?;
        Ok(Rc::new(PjrtStep { exe }))
    }

    fn cache_dir(&self) -> Option<PathBuf> {
        Some(self.rt.artifact_dir().to_path_buf())
    }
}

/// A compiled PJRT executable as a [`StepRunner`].
struct PjrtStep {
    exe: Rc<Executable>,
}

impl StepRunner for PjrtStep {
    fn meta(&self) -> &ArtifactMeta {
        &self.exe.meta
    }

    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, EngineError> {
        self.exe.run(inputs).map_err(|e| EngineError::backend(NAME, format!("{e:#}")))
    }

    fn pin(&self, t: &Tensor) -> Result<Pinned, EngineError> {
        let dev = self.exe.upload(t).map_err(|e| EngineError::backend(NAME, format!("{e:#}")))?;
        Ok(Pinned::Device(dev))
    }

    fn run_pinned(
        &self,
        pinned: &[&Pinned],
        host: &[Option<&Tensor>],
    ) -> Result<Vec<Tensor>, EngineError> {
        let mut device: Vec<&crate::runtime::DeviceInput> = Vec::with_capacity(pinned.len());
        for p in pinned {
            match p {
                Pinned::Device(d) => device.push(d),
                Pinned::Host(_) => {
                    return Err(EngineError::backend(
                        NAME,
                        "run_pinned received a host-pinned input from another backend",
                    ));
                }
            }
        }
        self.exe
            .run_mixed(&device, host)
            .map_err(|e| EngineError::backend(NAME, format!("{e:#}")))
    }

    fn prefers_pinned(&self) -> bool {
        // The buffer path trips an xla_extension 0.5.1 assertion in some
        // interleavings (see runtime::mod docs); keep it opt-in.
        crate::runtime::env::device_resident()
    }
}
