//! The reference interpreter backend: a dependency-free, pure-Rust
//! implementation of the step contract the AOT artifacts expose.
//!
//! Every model is a small reference network with the same
//! frozen/trainable-split, per-sample-clipped-gradient semantics as the
//! compiled artifacts (Algorithm 1 lines 3-9 per microbatch):
//!
//! * `cls-*`  — masked-mean token embedding -> hidden -> softmax head.
//! * `lm-*`   — per-token embedding -> hidden -> vocab softmax (causal by
//!              construction: position t sees only token t).
//! * `vit-*`  — flattened pixels -> hidden -> softmax head.
//! * `cnn-*`  — flattened pixels -> hidden -> sigmoid multi-label head;
//!              `cnn-small` has **no** first-layer bias (the paper's
//!              bias-less CNN, §3.4), `cnn-small-bias` adds it back
//!              (BiTFiT-Add).
//!
//! Model names are parsed, not enumerated: `cls-t128` gives a sequence
//! length of 128, `cnn-r32` a 32x32 image, `vit-c20` 20 classes — so the
//! dimension-sweep benches run against the interpreter too.  Everything is
//! deterministic given the model name; there is **no artifact directory**.
//!
//! Trainable subsets: `full`, `bitfit` (biases + head), `lastlayer` (head
//! only).  LoRA/adapter methods approximate to `bitfit` here — the
//! interpreter is a correctness reference, not a parameter-efficiency
//! simulator.
//!
//! ## Execution
//!
//! Rows run through the kernel tier of [`crate::kernels`] on the
//! persistent pool of [`crate::runtime::pool`].  The default **fused**
//! tier writes each row's per-sample gradient straight into its per-row
//! shard (scaled in place by the clip factor) and reduces shards in fixed
//! row order, so outputs are bit-identical for any `FASTDP_THREADS` value
//! (and to the pre-optimization scalar path, `FASTDP_KERNELS=legacy`).
//! The **ghost** tier (`FASTDP_KERNELS=ghost`) never materializes a
//! per-sample gradient at all: phase A computes each row's squared norm
//! analytically from stored activation/output-gradient factors (folding
//! the clip factor into them), and phase B accumulates the clipped sum
//! straight into the shared gradient — serially over rows for bias/embed
//! leaves, pooled over *matrix rows* for weight leaves, every entry summed
//! in fixed (row, position) order, so ghost outputs are bit-identical
//! across thread counts too (and match fused to floating-point tolerance;
//! see `tests/ghost_equivalence.rs`).  The **blocked** tier
//! (`FASTDP_KERNELS=blocked`) keeps ghost's factor bookkeeping but runs
//! phase A over row-*blocks* (LM: position blocks inside each row),
//! streaming each weight panel once per block instead of once per row —
//! bit-identical across thread counts *and* block widths
//! (`FASTDP_BLOCK_ROWS`; see `tests/blocked_equivalence.rs`), tolerance
//! vs fused.  The **simd** tier (`FASTDP_KERNELS=simd`) runs the blocked
//! panel sweeps on explicit f32 vector lanes with compensated (Neumaier)
//! accumulators — the instruction-set level is detected once per process
//! and can be forced down with `FASTDP_SIMD` — bit-identical across
//! thread counts, block widths *and* forced feature levels (see
//! `tests/simd_equivalence.rs`), tolerance vs fused.  A loaded step caches its
//! trainable-slot table, its frozen/train -> full scatter plan, its
//! factor layout, and all scratch buffers, so the steady state does no
//! per-row heap allocation and never re-merges parameters from scratch.
//!
//! Gradients are computed analytically in f64 and verified against finite
//! differences in the unit tests below.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::coordinator::workloads::ModelShape;
use crate::dp::clip::{clip_factor, ClipMode};
use crate::kernels::{
    blocked, fused, ghost, legacy, loss, simd, BlockedCtx, BlockedWorkspace, GhostPlan, KernelMode,
    NetView, SimdCtx, SimdLevel, SimdWorkspace, TrainSlots, Workspace,
};
use crate::runtime::pool;
use crate::runtime::{ArtifactMeta, IoSpec, Layout, LayoutLeaf};
use crate::util::rng::ChaChaRng;
use crate::util::tensor::Tensor;

use super::backend::{check_input_refs, Backend, ModelInfo, MultiTrainJob, Pinned, StepRunner};
use super::error::EngineError;

const NAME: &str = "interpreter";

/// Built-in model names (parametric names like `cls-t128` also resolve).
const BUILTIN_MODELS: &[&str] = &[
    "cls-base",
    "cls-large",
    "lm-small",
    "lm-medium",
    "lm-large",
    "vit-c10",
    "vit-c20",
    "cnn-small",
    "cnn-small-bias",
];

const TRAIN_FRAGMENTS: &[&str] = &[
    "nondp-full",
    "dp-full-ghost",
    "dp-full-opacus",
    "nondp-bitfit",
    "dp-bitfit",
    "dp-bitfit-add",
    "dp-lastlayer",
];

/// The dependency-free reference backend.
#[derive(Default)]
pub struct InterpreterBackend {
    // RefCell so the read-only Backend methods (&self) share the cache
    models: std::cell::RefCell<HashMap<String, Rc<RefModel>>>,
    steps: HashMap<String, Rc<RefStep>>,
    /// Worker-count override baked into steps loaded afterwards
    /// (`None` => steps resolve `FASTDP_THREADS` once when loaded).
    threads: Option<usize>,
    /// Kernel-mode override baked into steps loaded afterwards
    /// (`None` => steps resolve `FASTDP_KERNELS` once when loaded).
    kernels: Option<KernelMode>,
    /// Block-width override for the blocked tier (`None` => steps resolve
    /// `FASTDP_BLOCK_ROWS` once when loaded).
    block_rows: Option<usize>,
    /// Feature-level override for the simd tier (`None` => steps resolve
    /// `FASTDP_SIMD` / runtime detection once when loaded).  Always
    /// clamped to what the host supports.
    simd_level: Option<SimdLevel>,
}

impl InterpreterBackend {
    pub fn new() -> InterpreterBackend {
        InterpreterBackend::default()
    }

    /// An interpreter whose steps always run with `n` workers, ignoring
    /// `FASTDP_THREADS` (used by benches/tests for reproducible sweeps).
    pub fn with_threads(n: usize) -> InterpreterBackend {
        InterpreterBackend::with_config(Some(n), None)
    }

    /// An interpreter with explicit worker-count and kernel-mode overrides
    /// (`None` defers to the environment, read once per loaded step).
    pub fn with_config(threads: Option<usize>, kernels: Option<KernelMode>) -> InterpreterBackend {
        InterpreterBackend {
            threads: threads.map(|n| n.max(1)),
            kernels,
            ..InterpreterBackend::default()
        }
    }

    /// Override the worker count.  Drops the step cache so the next
    /// `load` re-bakes the configuration (step handles already held by
    /// callers keep their old worker count).
    pub fn set_threads(&mut self, threads: Option<usize>) {
        self.threads = threads.map(|n| n.max(1));
        self.steps.clear();
    }

    /// Override the kernel mode.  Drops the step cache so the next `load`
    /// re-bakes the configuration (step handles already held by callers
    /// keep their old mode).
    pub fn set_kernels(&mut self, kernels: Option<KernelMode>) {
        self.kernels = kernels;
        self.steps.clear();
    }

    /// Override the blocked tier's block width (rows per weight-panel
    /// sweep; LM: token positions).  `None` defers to `FASTDP_BLOCK_ROWS`.
    /// Drops the step cache so the next `load` re-bakes the configuration.
    /// A pure throughput knob: blocked outputs are bit-identical at any
    /// width (see `tests/blocked_equivalence.rs`).
    pub fn set_block_rows(&mut self, block_rows: Option<usize>) {
        self.block_rows = block_rows.map(|n| n.max(1));
        self.steps.clear();
    }

    /// Force the simd tier's instruction-set level (clamped to host
    /// support at load).  `None` defers to `FASTDP_SIMD` / runtime
    /// detection.  Drops the step cache so the next `load` re-bakes the
    /// configuration.  A pure dispatch knob: simd outputs are
    /// bit-identical at every level (see `tests/simd_equivalence.rs`) —
    /// this override exists so tests and benches can prove that without
    /// touching the process environment.
    pub fn set_simd_level(&mut self, level: Option<SimdLevel>) {
        self.simd_level = level;
        self.steps.clear();
    }

    fn model_ref(&self, name: &str) -> Result<Rc<RefModel>, EngineError> {
        if let Some(m) = self.models.borrow().get(name) {
            return Ok(m.clone());
        }
        let m = Rc::new(RefModel::parse(name)?);
        self.models.borrow_mut().insert(name.to_string(), m.clone());
        Ok(m)
    }

    /// Analytical peak *gradient-side* scratch (bytes) of one train
    /// artifact under a kernel tier — the buffers Table 2's memory column
    /// is about: per-row gradient shards (fused) or ghost factor rows,
    /// plus the shared gradient accumulator and per-worker workspaces.
    /// Used by `benches/throughput.rs` for the per-cell
    /// `peak_scratch_bytes` column.
    pub fn train_scratch_bytes(
        &self,
        artifact: &str,
        mode: KernelMode,
        threads: usize,
    ) -> Result<u64, EngineError> {
        let (model, kind) = parse_artifact(artifact)?;
        let m = self.model_ref(&model)?;
        let meta = m.meta_for(artifact, &kind)?;
        if meta.step != "train" {
            return Err(EngineError::backend(NAME, "train_scratch_bytes: train artifacts only"));
        }
        let slots = m.train_slots_packed(&meta.subset);
        let (b, pt) = (meta.batch as u64, meta.pt as u64);
        // one worker workspace: feat/dfeat + hpre/hact/dh + logits/dlogits
        let ws = (2 * m.feat_dim() + 3 * m.h + 2 * m.out) as u64;
        let t = threads.max(1) as u64;
        let words = match mode {
            // per-row g + grad_sum, single-threaded (plus per-row churn)
            KernelMode::Legacy => 2 * pt + ws,
            KernelMode::Fused => b * pt + pt + t * ws,
            KernelMode::Ghost => b * ghost_plan(&m, &slots).row_stride as u64 + pt + t * ws,
            KernelMode::Blocked => {
                // header-first factor rows + per-worker B_blk-row panels:
                // O(pt + B·rs + W·B_blk·(feat + h + out)) — no pt-sized
                // per-row buffer, like ghost
                let rs = (blocked::ROW_HDR + ghost_plan(&m, &slots).row_stride) as u64;
                let blk = self.block_rows.unwrap_or_else(blocked::block_rows_from_env);
                let panel = effective_block(blk, m.kind == RefKind::Lm, m.t, meta.batch, threads);
                let panel_ws =
                    BlockedWorkspace::words(panel, m.feat_dim(), m.h, m.out) as u64;
                let embed64 = (m.vocab * m.d) as u64;
                b * rs + pt + t * panel_ws + embed64
            }
            KernelMode::Simd => {
                // blocked's factor rows and accumulator, but f32 panels
                // (about half the panel bytes) and no widened embedding
                // table; mixed f32/f64 words, so count bytes directly
                let rs = (blocked::ROW_HDR + ghost_plan(&m, &slots).row_stride) as u64;
                let blk = self.block_rows.unwrap_or_else(blocked::block_rows_from_env);
                let panel = effective_block(blk, m.kind == RefKind::Lm, m.t, meta.batch, threads);
                let panel_bytes = SimdWorkspace::bytes(panel, m.feat_dim(), m.h, m.out) as u64;
                return Ok((b * rs + pt) * 8 + t * panel_bytes);
            }
        };
        Ok(words * 8)
    }
}

/// Panel width the blocked tier actually uses: the requested block width,
/// capped by the sequence length on LM models (the block runs over token
/// positions there) and, elsewhere, so that a microbatch still yields at
/// least one row-block task per worker.  Per-row results are invariant to
/// this cap (see `kernels::blocked`), so it is a pure throughput choice.
fn effective_block(requested: usize, is_lm: bool, t: usize, batch: usize, threads: usize) -> usize {
    let threads = threads.max(1);
    if is_lm {
        requested.min(t.max(1)).max(1)
    } else {
        requested.min((batch + threads - 1) / threads).max(1)
    }
}

/// Build the ghost factor layout for a model + trainable subset (shared by
/// `RefStep::new` and the analytic scratch estimator above).
fn ghost_plan(m: &RefModel, slots: &TrainSlots) -> GhostPlan {
    let token = matches!(m.kind, RefKind::Cls | RefKind::Lm);
    let npos = if m.kind == RefKind::Lm { m.t } else { 1 };
    let ids = if token && slots.embed.is_some() { m.t } else { 0 };
    GhostPlan::new(m.h, m.out, m.feat_dim(), npos, slots, token, ids)
}

/// Phase B of the factor-based tiers (ghost, blocked): accumulate the
/// clipped per-sample gradients straight into `grad_sum` from the stored
/// factor rows — bias/embed leaves serially in row order, weight leaves
/// pooled over *matrix* rows, every entry summed in fixed (row, position)
/// order, so the result is independent of the worker count (and, for the
/// blocked tier, of the block width).  `stride` is the distance between
/// consecutive rows' slices inside `factors` and `off` the offset of the
/// ghost factors within each slice (the blocked tier stores a
/// `[active, loss, sq]` header first; ghost passes `stride = row_stride`,
/// `off = 0`).
///
/// A `dp-sink` for the lint's taint pass: the factors fed in must already
/// carry their clip factor (folded in by the ghost/blocked epilogues).
// fastdp-lint: dp-sink
#[allow(clippy::too_many_arguments)]
fn accumulate_factor_rows(
    m: &RefModel,
    slots: &TrainSlots,
    plan: &GhostPlan,
    factors: &[f64],
    stride: usize,
    off: usize,
    rows: &[RowOut],
    b: usize,
    x: &Tensor,
    threads: usize,
    grad_sum: &mut [f64],
) {
    let out_w = m.out;
    let row_fac =
        |row: usize| &factors[row * stride + off..row * stride + off + plan.row_stride];
    // serial over rows in fixed order: the exact bias-leaf gradients and
    // the embedding scatter
    for (row, ro) in rows.iter().take(b).enumerate() {
        if !ro.active {
            continue;
        }
        let rb = row_fac(row);
        if let Some(g0) = slots.head_b {
            for (gk, &v) in grad_sum[g0..g0 + out_w].iter_mut().zip(plan.bias_d(rb)) {
                *gk += v;
            }
        }
        if let Some(g0) = slots.enc_b {
            for (gj, &v) in grad_sum[g0..g0 + m.h].iter_mut().zip(plan.bias_dh(rb)) {
                *gj += v;
            }
        }
        if let Some(g0) = slots.embed {
            for k in 0..plan.n_ids(rb) {
                let tok = plan.id(rb, k);
                let p = if plan.npos > 1 { k } else { 0 };
                let df = plan.dfeat(rb, p);
                let ge = &mut grad_sum[g0 + tok * m.d..g0 + (tok + 1) * m.d];
                for (gv, &v) in ge.iter_mut().zip(df) {
                    *gv += v;
                }
            }
        }
    }
    // pooled weight leaves: one task per matrix row; every entry sums its
    // (row, position) contributions in fixed order, so the result is
    // independent of the worker count
    if let Some(g0) = slots.head_w {
        let h = m.h;
        let hw = &mut grad_sum[g0..g0 + h * out_w];
        let mut unit = vec![(); h];
        let mut ctxs = vec![(); threads];
        pool::for_each_sharded(h, &mut ctxs, &mut unit, hw, out_w, |j, _c, shard| {
            for (row, ro) in rows.iter().take(b).enumerate() {
                if !ro.active {
                    continue;
                }
                let rb = row_fac(row);
                for p in 0..plan.np(rb) {
                    let aj = plan.a(rb, p)[j];
                    if aj == 0.0 {
                        continue;
                    }
                    for (sv, &dv) in shard.iter_mut().zip(plan.d(rb, p)) {
                        *sv += aj * dv;
                    }
                }
            }
        });
    }
    if let Some(g0) = slots.enc_w {
        let fw = plan.fw;
        let h = m.h;
        let ew = &mut grad_sum[g0..g0 + fw * h];
        let mut unit = vec![(); fw];
        let mut ctxs = vec![(); threads];
        // image models re-read pixel features from the batch (the same
        // f32 -> f64 widening the forward pass used); token models read
        // the stored pooled/token features
        let x_pix: &[f32] = if plan.store_f { &[] } else { x.as_f32() };
        pool::for_each_sharded(fw, &mut ctxs, &mut unit, ew, h, |i, _c, shard| {
            for (row, ro) in rows.iter().take(b).enumerate() {
                if !ro.active {
                    continue;
                }
                let rb = row_fac(row);
                for p in 0..plan.np(rb) {
                    let fi = if plan.store_f {
                        plan.f(rb, p)[i]
                    } else {
                        x_pix[row * fw + i] as f64
                    };
                    if fi == 0.0 {
                        continue;
                    }
                    for (sv, &dv) in shard.iter_mut().zip(plan.dh(rb, p)) {
                        *sv += fi * dv;
                    }
                }
            }
        });
    }
}

impl Backend for InterpreterBackend {
    fn name(&self) -> &'static str {
        NAME
    }

    fn platform(&self) -> String {
        "pure-rust reference interpreter (no artifacts required)".to_string()
    }

    fn models(&self) -> Vec<String> {
        BUILTIN_MODELS.iter().map(|s| s.to_string()).collect()
    }

    fn artifacts(&self) -> Vec<String> {
        let mut v = Vec::new();
        for m in BUILTIN_MODELS {
            for f in TRAIN_FRAGMENTS {
                v.push(format!("{m}__{f}"));
            }
            v.push(format!("{m}__eval"));
            if m.starts_with("lm") {
                v.push(format!("{m}__decode"));
            }
        }
        v
    }

    fn model_info(&self, model: &str) -> Result<ModelInfo, EngineError> {
        let m = self.model_ref(model)?;
        Ok(m.info())
    }

    fn layout(&self, model: &str) -> Result<Layout, EngineError> {
        Ok(self.model_ref(model)?.layout.clone())
    }

    fn init_params(&self, model: &str) -> Result<Vec<f32>, EngineError> {
        Ok(self.model_ref(model)?.init_params())
    }

    fn artifact_meta(&self, artifact: &str) -> Result<ArtifactMeta, EngineError> {
        let (model, kind) = parse_artifact(artifact)?;
        let m = self.model_ref(&model)?;
        m.meta_for(artifact, &kind)
    }

    fn load(&mut self, artifact: &str) -> Result<Rc<dyn StepRunner>, EngineError> {
        if let Some(s) = self.steps.get(artifact) {
            return Ok(s.clone());
        }
        let (model, kind) = parse_artifact(artifact)?;
        let m = self.model_ref(&model)?;
        let meta = m.meta_for(artifact, &kind)?;
        let step = Rc::new(RefStep::new(
            m,
            meta,
            self.threads,
            self.kernels,
            self.block_rows,
            self.simd_level,
        ));
        self.steps.insert(artifact.to_string(), step.clone());
        Ok(step)
    }

    /// Real data-parallel replication: each worker thread builds its own
    /// interpreter (inheriting this backend's thread/kernel overrides) and
    /// loads the artifact.  Step outputs are bit-identical across worker
    /// configurations (see `tests/parallel_determinism.rs`), so sharding a
    /// logical batch over replicas cannot change the training trajectory.
    fn replica_group(
        &self,
        artifact: &str,
        n: usize,
        opts: &crate::coordinator::transport::TransportOpts,
    ) -> Option<Result<crate::coordinator::distributed::ReplicaGroup, EngineError>> {
        let (threads, kernels, block_rows, simd_level) =
            (self.threads, self.kernels, self.block_rows, self.simd_level);
        let artifact = artifact.to_string();
        Some(crate::coordinator::distributed::ReplicaGroup::spawn_with(
            n,
            move || {
                let mut be = InterpreterBackend::with_config(threads, kernels);
                be.block_rows = block_rows;
                be.simd_level = simd_level;
                be.load(&artifact)
            },
            *opts,
        ))
    }
}

/// What an artifact name asks for.
enum StepKind {
    Train { fragment: String, clip: Option<String> },
    Eval,
    Decode,
}

/// Split `model__method[__clip]` / `model__eval` / `model__decode`.
fn parse_artifact(artifact: &str) -> Result<(String, StepKind), EngineError> {
    let parts: Vec<&str> = artifact.split("__").collect();
    let unknown = |detail: &str| EngineError::UnknownArtifact {
        name: artifact.to_string(),
        detail: detail.to_string(),
    };
    if parts.len() < 2 || parts.len() > 3 {
        return Err(unknown("expected model__method[__clipmode]"));
    }
    let model = parts[0].to_string();
    let kind = match parts[1] {
        "eval" => StepKind::Eval,
        "decode" => StepKind::Decode,
        frag => StepKind::Train {
            fragment: frag.to_string(),
            clip: parts.get(2).map(|s| s.to_string()),
        },
    };
    Ok((model, kind))
}

/// Architecture family of a reference model.
#[derive(Debug, Clone, Copy, PartialEq)]
enum RefKind {
    Cls,
    Lm,
    Vit,
    Cnn,
}

/// A reference model: dims + canonical flat-parameter layout.
struct RefModel {
    name: String,
    kind: RefKind,
    vocab: usize,
    t: usize,
    /// Embedding width (Cls/Lm); 0 for image models.
    d: usize,
    /// Hidden width.
    h: usize,
    /// Output width (n_cls / vocab / n_out).
    out: usize,
    img: usize,
    layout: Layout,
}

impl RefModel {
    fn parse(name: &str) -> Result<RefModel, EngineError> {
        let (kind, vocab, t, d, h, out, img, first_bias) = if name.starts_with("cls") {
            let t = name.strip_prefix("cls-t").and_then(|s| s.parse().ok()).unwrap_or(64);
            let d = if name == "cls-large" { 48 } else { 32 };
            (RefKind::Cls, 512, t, d, d, 4, 0, true)
        } else if name.starts_with("lm") {
            let d = match name {
                "lm-medium" => 32,
                "lm-large" => 40,
                _ => 24,
            };
            (RefKind::Lm, 384, 48, d, d, 384, 0, true)
        } else if name.starts_with("vit") {
            let n_cls = name.strip_prefix("vit-c").and_then(|s| s.parse().ok()).unwrap_or(10);
            (RefKind::Vit, 0, 0, 0, 32, n_cls, 16, true)
        } else if name.starts_with("cnn") {
            let img = name.strip_prefix("cnn-r").and_then(|s| s.parse().ok()).unwrap_or(16);
            (RefKind::Cnn, 0, 0, 0, 24, 8, img, name.contains("bias"))
        } else {
            return Err(EngineError::UnknownModel(name.to_string()));
        };
        let feat = match kind {
            RefKind::Cls | RefKind::Lm => d,
            RefKind::Vit | RefKind::Cnn => img * img * 3,
        };
        let mut leaves = Vec::new();
        let mut offset = 0usize;
        let mut push = |leaves: &mut Vec<LayoutLeaf>, name: &str, shape: Vec<usize>, head: bool| {
            let size: usize = shape.iter().product();
            leaves.push(LayoutLeaf {
                name: name.to_string(),
                shape,
                size,
                offset,
                is_head: head,
            });
            offset += size;
        };
        // (trainable-in-bitfit?, leaf) pairs, in canonical order
        let mut bitfit = Vec::new();
        if matches!(kind, RefKind::Cls | RefKind::Lm) {
            push(&mut leaves, "embed", vec![vocab, d], false);
            bitfit.push(false);
        }
        push(&mut leaves, "enc/w", vec![feat, h], false);
        bitfit.push(false);
        if first_bias {
            push(&mut leaves, "enc/b", vec![h], false);
            bitfit.push(true);
        }
        push(&mut leaves, "head/w", vec![h, out], true);
        bitfit.push(true);
        push(&mut leaves, "head/b", vec![out], true);
        bitfit.push(true);
        let n = leaves.len();
        let lastlayer: Vec<bool> = leaves.iter().map(|l| l.is_head).collect();
        let layout = Layout {
            model: name.to_string(),
            kind: match kind {
                RefKind::Cls => "cls",
                RefKind::Lm => "lm",
                RefKind::Vit => "vit",
                RefKind::Cnn => "cnn",
            }
            .to_string(),
            n_params: offset,
            leaves,
            subsets: std::collections::BTreeMap::from([
                ("full".to_string(), vec![true; n]),
                ("bitfit".to_string(), bitfit),
                ("lastlayer".to_string(), lastlayer),
            ]),
        };
        Ok(RefModel { name: name.to_string(), kind, vocab, t, d, h, out, img, layout })
    }

    fn feat_dim(&self) -> usize {
        match self.kind {
            RefKind::Cls | RefKind::Lm => self.d,
            RefKind::Vit | RefKind::Cnn => self.img * self.img * 3,
        }
    }

    fn microbatch(&self) -> usize {
        match self.kind {
            RefKind::Lm => 16,
            _ => 32,
        }
    }

    fn info(&self) -> ModelInfo {
        ModelInfo {
            shape: ModelShape {
                kind: self.layout.kind.clone(),
                t: self.t,
                vocab: self.vocab,
                img: self.img,
                n_cls: if self.kind == RefKind::Vit || self.kind == RefKind::Cls {
                    self.out
                } else {
                    0
                },
                n_out: if self.kind == RefKind::Cnn { self.out } else { 0 },
            },
            n_params: self.layout.n_params,
            d: self.h,
            layers: 1,
            patch: if self.kind == RefKind::Vit { 4 } else { 0 },
        }
    }

    /// Deterministic init: weights ~ N(0, 1/fan_in), embeddings ~ N(0, 0.25),
    /// biases zero.  Seeded from the model name.
    fn init_params(&self) -> Vec<f32> {
        let seed = self.name.bytes().fold(0u64, |a, b| a.wrapping_mul(31).wrapping_add(b as u64));
        let mut rng = ChaChaRng::new(seed, 0x1217);
        let mut out = vec![0.0f32; self.layout.n_params];
        for leaf in &self.layout.leaves {
            let dst = &mut out[leaf.offset..leaf.offset + leaf.size];
            if leaf.name == "embed" {
                rng.fill_gaussian(dst, 0.5);
            } else if leaf.name.ends_with("/w") {
                let fan_in = leaf.shape[0].max(1) as f64;
                rng.fill_gaussian(dst, 1.0 / fan_in.sqrt());
            }
            // biases stay zero
        }
        out
    }

    fn leaf_slice<'a>(&self, full: &'a [f32], name: &str) -> Option<&'a [f32]> {
        self.layout
            .leaves
            .iter()
            .find(|l| l.name == name)
            .map(|l| &full[l.offset..l.offset + l.size])
    }

    /// Borrowed flat views + dims over a merged full parameter vector.
    fn net_view<'a>(&self, full: &'a [f32]) -> NetView<'a> {
        NetView {
            embed: self.leaf_slice(full, "embed").unwrap_or(&[]),
            enc_w: self.leaf_slice(full, "enc/w").expect("enc/w leaf"),
            enc_b: self.leaf_slice(full, "enc/b"),
            head_w: self.leaf_slice(full, "head/w").expect("head/w leaf"),
            head_b: self.leaf_slice(full, "head/b").expect("head/b leaf"),
            d: self.d,
            h: self.h,
            out: self.out,
            vocab: self.vocab,
            feat: self.feat_dim(),
        }
    }

    /// Ranges of each trainable leaf inside the flat trainable vector
    /// (legacy-path representation).
    fn train_slots(&self, subset: &str) -> HashMap<String, (usize, usize)> {
        let mask = &self.layout.subsets[subset];
        let mut slots = HashMap::new();
        let mut off = 0usize;
        for (leaf, &tr) in self.layout.leaves.iter().zip(mask) {
            if tr {
                slots.insert(leaf.name.clone(), (off, leaf.size));
                off += leaf.size;
            }
        }
        slots
    }

    /// Trainable-leaf offsets as a flat struct (fused-path representation;
    /// computed once per loaded step).
    fn train_slots_packed(&self, subset: &str) -> TrainSlots {
        let mask = &self.layout.subsets[subset];
        let mut slots = TrainSlots::default();
        let mut off = 0usize;
        for (leaf, &tr) in self.layout.leaves.iter().zip(mask) {
            if !tr {
                continue;
            }
            match leaf.name.as_str() {
                "embed" => slots.embed = Some(off),
                "enc/w" => slots.enc_w = Some(off),
                "enc/b" => slots.enc_b = Some(off),
                "head/w" => slots.head_w = Some(off),
                "head/b" => slots.head_b = Some(off),
                _ => {}
            }
            off += leaf.size;
        }
        slots.pt = off;
        slots
    }

    /// The fixed (frozen, train) -> full scatter plan for a subset, so the
    /// hot path re-fills one cached buffer instead of calling
    /// `Layout::merge` (which allocates) per microbatch.
    fn merge_plan(&self, subset: &str) -> Vec<CopyRange> {
        let mask = &self.layout.subsets[subset];
        let (mut fo, mut to) = (0usize, 0usize);
        let mut plan = Vec::with_capacity(self.layout.leaves.len());
        for (leaf, &tr) in self.layout.leaves.iter().zip(mask) {
            let src = if tr { to } else { fo };
            plan.push(CopyRange { dst: leaf.offset, src, len: leaf.size, from_train: tr });
            if tr {
                to += leaf.size;
            } else {
                fo += leaf.size;
            }
        }
        plan
    }

    fn subset_for_fragment(&self, fragment: &str) -> Result<&'static str, EngineError> {
        let rest = fragment
            .strip_prefix("dp-")
            .or_else(|| fragment.strip_prefix("nondp-"))
            .unwrap_or(fragment);
        let subset = if rest.starts_with("full") {
            "full"
        } else if rest.starts_with("bitfit") {
            "bitfit"
        } else if rest == "lastlayer" {
            "lastlayer"
        } else if rest == "lora" || rest == "adapter" {
            // closest low-parameter analog the reference net has
            "bitfit"
        } else {
            return Err(EngineError::UnknownArtifact {
                name: format!("{}__{fragment}", self.name),
                detail: format!("unknown method fragment {rest:?}"),
            });
        };
        Ok(subset)
    }

    fn x_spec(&self, b: usize) -> IoSpec {
        match self.kind {
            RefKind::Cls | RefKind::Lm => {
                IoSpec { name: "x".into(), dtype: "int32".into(), shape: vec![b, self.t] }
            }
            RefKind::Vit | RefKind::Cnn => IoSpec {
                name: "x".into(),
                dtype: "float32".into(),
                shape: vec![b, self.img, self.img, 3],
            },
        }
    }

    fn y_spec(&self, b: usize) -> IoSpec {
        match self.kind {
            RefKind::Cls | RefKind::Vit => {
                IoSpec { name: "y".into(), dtype: "int32".into(), shape: vec![b] }
            }
            RefKind::Lm => IoSpec { name: "y".into(), dtype: "int32".into(), shape: vec![b, self.t] },
            RefKind::Cnn => {
                IoSpec { name: "y".into(), dtype: "float32".into(), shape: vec![b, self.out] }
            }
        }
    }

    fn meta_for(&self, artifact: &str, kind: &StepKind) -> Result<ArtifactMeta, EngineError> {
        let b = self.microbatch();
        let f32s = |name: &str, shape: Vec<usize>| IoSpec {
            name: name.into(),
            dtype: "float32".into(),
            shape,
        };
        match kind {
            StepKind::Train { fragment, clip } => {
                if let Some(c) = clip {
                    if ClipMode::parse(c).is_none() {
                        return Err(EngineError::UnknownArtifact {
                            name: artifact.to_string(),
                            detail: format!("unknown clip mode {c:?}"),
                        });
                    }
                }
                let subset = self.subset_for_fragment(fragment)?;
                let pt = self.layout.subset_size(subset);
                let pf = self.layout.n_params - pt;
                Ok(ArtifactMeta {
                    name: artifact.to_string(),
                    model: self.name.clone(),
                    method: fragment.clone(),
                    step: "train".to_string(),
                    clip: clip.clone(),
                    subset: subset.to_string(),
                    batch: b,
                    pf,
                    pt,
                    inputs: vec![
                        f32s("frozen", vec![pf]),
                        f32s("train", vec![pt]),
                        self.x_spec(b),
                        self.y_spec(b),
                        f32s("mask", vec![b]),
                        f32s("clip_r", vec![]),
                    ],
                    outputs: vec![
                        f32s("loss", vec![]),
                        f32s("grad", vec![pt]),
                        f32s("sq_norms", vec![b]),
                    ],
                })
            }
            StepKind::Eval => Ok(ArtifactMeta {
                name: artifact.to_string(),
                model: self.name.clone(),
                method: "eval".to_string(),
                step: "eval".to_string(),
                clip: None,
                subset: "full".to_string(),
                batch: b,
                pf: 0,
                pt: self.layout.n_params,
                inputs: vec![
                    f32s("unused", vec![0]),
                    f32s("params", vec![self.layout.n_params]),
                    self.x_spec(b),
                    self.y_spec(b),
                    f32s("mask", vec![b]),
                ],
                outputs: vec![f32s("metric_a", vec![]), f32s("metric_b", vec![])],
            }),
            StepKind::Decode => {
                if self.kind != RefKind::Lm {
                    return Err(EngineError::UnknownArtifact {
                        name: artifact.to_string(),
                        detail: format!("{} is not a language model", self.name),
                    });
                }
                Ok(ArtifactMeta {
                    name: artifact.to_string(),
                    model: self.name.clone(),
                    method: "decode".to_string(),
                    step: "decode".to_string(),
                    clip: None,
                    subset: "full".to_string(),
                    batch: b,
                    pf: 0,
                    pt: self.layout.n_params,
                    inputs: vec![
                        f32s("unused", vec![0]),
                        f32s("params", vec![self.layout.n_params]),
                        IoSpec { name: "x".into(), dtype: "int32".into(), shape: vec![b, self.t] },
                        IoSpec { name: "pos".into(), dtype: "int32".into(), shape: vec![b] },
                    ],
                    outputs: vec![f32s("logits", vec![b, self.vocab])],
                })
            }
        }
    }
}

/// One fixed copy in the (frozen, train) -> full scatter plan.
struct CopyRange {
    dst: usize,
    src: usize,
    len: usize,
    from_train: bool,
}

/// Per-row result of a pooled row kernel, reduced in fixed row order.
#[derive(Clone, Copy, Default)]
struct RowOut {
    /// Train: raw row loss.  Eval: metric_a contribution.
    a: f64,
    /// Train: squared per-sample gradient norm.  Eval: metric_b contribution.
    b: f64,
    /// False for masked-out rows (their shards are skipped in the reduce).
    active: bool,
}

/// Cached buffers of one loaded step — allocated on first run, reused for
/// every subsequent microbatch.
#[derive(Default)]
struct Scratch {
    /// Merged full parameter vector (refilled in place via the scatter plan).
    full: Vec<f32>,
    /// Per-row clipped-gradient shards (`batch * pt`; fused tier only).
    partials: Vec<f64>,
    /// Per-row factor rows: `batch * plan.row_stride` on the ghost tier;
    /// header-first `[active, loss, sq | factors]` rows on the blocked
    /// tier (`n_tasks * task_rows * (ROW_HDR + plan.row_stride)`).
    factors: Vec<f64>,
    /// f64 gradient accumulator for the fixed-order reduction.
    grad_sum: Vec<f64>,
    /// Per-row kernel results.
    rows: Vec<RowOut>,
    /// One workspace per worker thread.
    workspaces: Vec<Workspace>,
    /// One panel workspace per worker thread (blocked tier).
    blocked_ws: Vec<BlockedWorkspace>,
    /// One f32-lane panel workspace per worker thread (simd tier).
    simd_ws: Vec<SimdWorkspace>,
    /// The embedding table widened to f64 once per step (blocked tier;
    /// empty for image models).
    embed64: Vec<f64>,
    /// Cached decode logits buffer (`batch * vocab`), fully overwritten by
    /// the pooled shards each call.
    decode_out: Vec<f32>,
    /// Multi-tenant sweep buffers (`run_multi`): per-job merged full
    /// parameter vectors (flattened `n_jobs * n_params`), per-job widened
    /// embedding tables (blocked tier), and the coalesced factor shards /
    /// task slots spanning every job.  Kept apart from the solo-path
    /// buffers so batched and unbatched executions can interleave without
    /// resizing each other's scratch.
    multi_full: Vec<f32>,
    multi_embed64: Vec<f64>,
    multi_factors: Vec<f64>,
    multi_rows: Vec<RowOut>,
}

impl Scratch {
    fn ensure_workspaces(&mut self, n: usize, feat: usize, h: usize, out: usize) {
        while self.workspaces.len() < n {
            self.workspaces.push(Workspace::new(feat, h, out));
        }
    }

    fn ensure_blocked(&mut self, n: usize, block: usize, feat: usize, h: usize, out: usize) {
        // a step always asks for the same block width, but be safe if the
        // panels were sized by a smaller earlier request
        if self.blocked_ws.first().is_some_and(|w| w.block < block) {
            self.blocked_ws.clear();
        }
        while self.blocked_ws.len() < n {
            self.blocked_ws.push(BlockedWorkspace::new(block, feat, h, out));
        }
    }

    fn ensure_simd(&mut self, n: usize, block: usize, feat: usize, h: usize, out: usize) {
        if self.simd_ws.first().is_some_and(|w| w.block < block) {
            self.simd_ws.clear();
        }
        while self.simd_ws.len() < n {
            self.simd_ws.push(SimdWorkspace::new(block, feat, h, out));
        }
    }
}

/// An executable interpreter step.
struct RefStep {
    model: Rc<RefModel>,
    meta: ArtifactMeta,
    /// Trainable-leaf offsets under this step's subset (train steps).
    slots: TrainSlots,
    /// Fixed (frozen, train) -> full scatter plan (train steps).
    merge_plan: Vec<CopyRange>,
    /// Worker count, resolved once at load (override or `FASTDP_THREADS`)
    /// so the hot path never touches the process environment.
    threads: usize,
    /// Kernel mode, resolved once at load (override or `FASTDP_KERNELS`).
    kernels: KernelMode,
    /// Block width of the blocked tier, resolved once at load (override
    /// or `FASTDP_BLOCK_ROWS`).
    block_rows: usize,
    /// Instruction-set level of the simd tier, resolved once at load
    /// (override or `FASTDP_SIMD` / runtime detection, clamped to host
    /// support either way).
    simd: SimdLevel,
    /// Per-row factor layout of the factor-based tiers (train steps
    /// loaded with `KernelMode::Ghost`, `Blocked` or `Simd` only).
    ghost: Option<GhostPlan>,
    scratch: RefCell<Scratch>,
}

impl RefStep {
    fn new(
        model: Rc<RefModel>,
        meta: ArtifactMeta,
        threads: Option<usize>,
        kernels: Option<KernelMode>,
        block_rows: Option<usize>,
        simd_level: Option<SimdLevel>,
    ) -> RefStep {
        let (slots, merge_plan) = if meta.step == "train" {
            (model.train_slots_packed(&meta.subset), model.merge_plan(&meta.subset))
        } else {
            (TrainSlots::default(), Vec::new())
        };
        let kernels = kernels.unwrap_or_else(KernelMode::from_env);
        let ghost = if meta.step == "train"
            && matches!(kernels, KernelMode::Ghost | KernelMode::Blocked | KernelMode::Simd)
        {
            Some(ghost_plan(&model, &slots))
        } else {
            None
        };
        let simd_level = SimdLevel::resolve(simd_level);
        if kernels == KernelMode::Simd {
            simd::record_level(simd_level);
        }
        RefStep {
            model,
            meta,
            slots,
            merge_plan,
            threads: threads.unwrap_or_else(pool::default_threads),
            kernels,
            block_rows: block_rows.unwrap_or_else(blocked::block_rows_from_env),
            simd: simd_level,
            ghost,
            scratch: RefCell::new(Scratch::default()),
        }
    }

    fn is_dp(&self) -> bool {
        self.meta.method.starts_with("dp-")
    }

    fn clip_mode(&self) -> ClipMode {
        self.meta.clip.as_deref().and_then(ClipMode::parse).unwrap_or(ClipMode::Abadi)
    }

    /// Worker count for this run (capped by the microbatch).
    fn resolve_threads(&self, b: usize) -> usize {
        self.threads.clamp(1, b.max(1))
    }

    fn run_refs(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, EngineError> {
        check_input_refs(&self.meta, inputs)?;
        match self.meta.step.as_str() {
            "train" => self.run_train(inputs),
            "eval" => self.run_eval(inputs),
            "decode" => self.run_decode(inputs),
            other => Err(EngineError::backend(NAME, format!("unknown step kind {other:?}"))),
        }
    }

    fn run_train(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, EngineError> {
        match self.kernels {
            KernelMode::Legacy => return self.run_train_legacy(inputs),
            KernelMode::Ghost => return self.run_train_ghost(inputs),
            KernelMode::Blocked => return self.run_train_blocked(inputs),
            KernelMode::Simd => return self.run_train_simd(inputs),
            KernelMode::Fused => {}
        }
        let m = &*self.model;
        let frozen = inputs[0].as_f32();
        let train = inputs[1].as_f32();
        let x = inputs[2];
        let y = inputs[3];
        let mask = inputs[4].as_f32();
        let clip_r = inputs[5].item_f32() as f64;
        let pt = self.meta.pt;
        let b = self.meta.batch;
        let dp = self.is_dp();
        let mode = self.clip_mode();
        let threads = self.resolve_threads(b);

        let mut scratch = self.scratch.borrow_mut();
        let s = &mut *scratch;
        s.full.resize(m.layout.n_params, 0.0);
        s.partials.resize(b * pt, 0.0);
        if s.rows.len() < b {
            s.rows.resize(b, RowOut::default());
        }
        s.ensure_workspaces(threads, m.feat_dim(), m.h, m.out);
        s.grad_sum.clear();
        s.grad_sum.resize(pt, 0.0);
        for r in &self.merge_plan {
            let src = if r.from_train { train } else { frozen };
            s.full[r.dst..r.dst + r.len].copy_from_slice(&src[r.src..r.src + r.len]);
        }
        let net = m.net_view(&s.full);
        let slots = self.slots;
        let kind = m.kind;
        let t_len = m.t;
        let out_w = m.out;
        let npix = m.img * m.img * 3;
        pool::for_each_sharded(
            b,
            &mut s.workspaces[..threads],
            &mut s.rows[..b],
            &mut s.partials[..b * pt],
            pt,
            |row, ws, shard| {
                if mask[row] <= 0.0 {
                    return RowOut::default();
                }
                // the row's per-sample gradient accumulates directly in
                // its shard and is clip-scaled there — no second copy
                for v in shard.iter_mut() {
                    *v = 0.0;
                }
                let row_loss = match kind {
                    RefKind::Cls => {
                        let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                        let label = (y.as_i32()[row].max(0) as usize) % out_w;
                        fused::row_cls(&net, &slots, ws, shard, toks, label)
                    }
                    RefKind::Lm => {
                        let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                        let targets = &y.as_i32()[row * t_len..(row + 1) * t_len];
                        fused::row_lm(&net, &slots, ws, shard, toks, targets)
                    }
                    RefKind::Vit => {
                        let pix = &x.as_f32()[row * npix..(row + 1) * npix];
                        let label = (y.as_i32()[row].max(0) as usize) % out_w;
                        fused::row_vit(&net, &slots, ws, shard, pix, label)
                    }
                    RefKind::Cnn => {
                        let pix = &x.as_f32()[row * npix..(row + 1) * npix];
                        let targets = &y.as_f32()[row * out_w..(row + 1) * out_w];
                        fused::row_cnn(&net, &slots, ws, shard, pix, targets)
                    }
                };
                let sq = fused::clip_in_place(shard, dp, clip_r, mode);
                RowOut { a: row_loss, b: sq, active: true }
            },
        );
        // fixed-order reduction: row shards accumulate in row order on this
        // thread, so the result is independent of the worker count
        // fastdp-lint: dp-sink
        let mut loss_sum = 0.0f64;
        let mut sq_norms = vec![0.0f32; b];
        for row in 0..b {
            let ro = s.rows[row];
            if !ro.active {
                continue;
            }
            sq_norms[row] = ro.b as f32;
            let shard = &s.partials[row * pt..(row + 1) * pt];
            for (gs, &v) in s.grad_sum.iter_mut().zip(shard) {
                *gs += v;
            }
            loss_sum += ro.a * mask[row] as f64;
        }
        Ok(vec![
            Tensor::scalar_f32(loss_sum as f32),
            Tensor::f32(vec![pt], s.grad_sum.iter().map(|&v| v as f32).collect()),
            Tensor::f32(vec![b], sq_norms),
        ])
    }

    /// The ghost-norm book-keeping path (`FASTDP_KERNELS=ghost`; see
    /// [`crate::kernels::ghost`]): per-sample squared norms computed
    /// analytically from stored activation/output-gradient factors, then a
    /// clipped accumulation straight into the shared gradient sum — the
    /// O(B·pt) per-row gradient buffer of the fused tier is never
    /// allocated.  Phase A parallelizes over rows (each row owns its
    /// factor shard); phase B accumulates bias/embed leaves serially in
    /// row order and weight leaves pooled over matrix rows, every entry
    /// summed in fixed (row, position) order — bit-identical across
    /// `FASTDP_THREADS`.
    fn run_train_ghost(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, EngineError> {
        let m = &*self.model;
        let plan = self.ghost.as_ref().expect("ghost plan built at load");
        let frozen = inputs[0].as_f32();
        let train = inputs[1].as_f32();
        let x = inputs[2];
        let y = inputs[3];
        let mask = inputs[4].as_f32();
        let clip_r = inputs[5].item_f32() as f64;
        let pt = self.meta.pt;
        let b = self.meta.batch;
        let dp = self.is_dp();
        let mode = self.clip_mode();
        let threads = self.resolve_threads(b);
        let rs = plan.row_stride;

        let mut scratch = self.scratch.borrow_mut();
        let s = &mut *scratch;
        s.full.resize(m.layout.n_params, 0.0);
        s.factors.resize(b * rs, 0.0);
        if s.rows.len() < b {
            s.rows.resize(b, RowOut::default());
        }
        s.ensure_workspaces(threads, m.feat_dim(), m.h, m.out);
        s.grad_sum.clear();
        s.grad_sum.resize(pt, 0.0);
        for r in &self.merge_plan {
            let src = if r.from_train { train } else { frozen };
            s.full[r.dst..r.dst + r.len].copy_from_slice(&src[r.src..r.src + r.len]);
        }
        let net = m.net_view(&s.full);
        let slots = self.slots;
        let ctx = ghost::GhostCtx { net: &net, slots: &slots, plan, dp, clip_r, mode };
        let kind = m.kind;
        let t_len = m.t;
        let out_w = m.out;
        let npix = m.img * m.img * 3;
        // phase A: per-row factors + analytic norms, one factor shard per row
        pool::for_each_sharded(
            b,
            &mut s.workspaces[..threads],
            &mut s.rows[..b],
            &mut s.factors[..b * rs],
            rs,
            |row, ws, rb| {
                if mask[row] <= 0.0 {
                    return RowOut::default();
                }
                let (row_loss, sq) = match kind {
                    RefKind::Cls => {
                        let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                        let label = (y.as_i32()[row].max(0) as usize) % out_w;
                        ghost::row_cls(&ctx, ws, toks, label, rb)
                    }
                    RefKind::Lm => {
                        let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                        let targets = &y.as_i32()[row * t_len..(row + 1) * t_len];
                        ghost::row_lm(&ctx, ws, toks, targets, rb)
                    }
                    RefKind::Vit => {
                        let pix = &x.as_f32()[row * npix..(row + 1) * npix];
                        let label = (y.as_i32()[row].max(0) as usize) % out_w;
                        ghost::row_vit(&ctx, ws, pix, label, rb)
                    }
                    RefKind::Cnn => {
                        let pix = &x.as_f32()[row * npix..(row + 1) * npix];
                        let targets = &y.as_f32()[row * out_w..(row + 1) * out_w];
                        ghost::row_cnn(&ctx, ws, pix, targets, rb)
                    }
                };
                RowOut { a: row_loss, b: sq, active: true }
            },
        );
        // per-row outputs in fixed row order
        let mut loss_sum = 0.0f64;
        let mut sq_norms = vec![0.0f32; b];
        for (row, ro) in s.rows.iter().take(b).enumerate() {
            if !ro.active {
                continue;
            }
            sq_norms[row] = ro.b as f32;
            loss_sum += ro.a * mask[row] as f64;
        }
        // phase B: clipped accumulation from stored factors
        accumulate_factor_rows(
            m,
            &slots,
            plan,
            &s.factors,
            rs,
            0,
            &s.rows,
            b,
            x,
            threads,
            &mut s.grad_sum,
        );
        Ok(vec![
            Tensor::scalar_f32(loss_sum as f32),
            Tensor::f32(vec![pt], s.grad_sum.iter().map(|&v| v as f32).collect()),
            Tensor::f32(vec![b], sq_norms),
        ])
    }

    /// The cache-blocked batched path (`FASTDP_KERNELS=blocked`; see
    /// [`crate::kernels::blocked`]): phase A pools over row-*blocks*
    /// (LM: rows, each internally blocked over token positions), running
    /// the forward/backward/factor passes for a whole block per
    /// weight-panel sweep and storing ghost-layout factors behind a
    /// per-row `[active, loss, sq]` header; phase B is exactly the ghost
    /// tier's fixed-order accumulation.  Outputs are bit-identical across
    /// any `FASTDP_THREADS` *and* any `FASTDP_BLOCK_ROWS` value, and
    /// match fused within the 1e-4 tolerance contract (see
    /// `tests/blocked_equivalence.rs`).
    fn run_train_blocked(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, EngineError> {
        let m = &*self.model;
        let plan = self.ghost.as_ref().expect("factor plan built at load");
        let frozen = inputs[0].as_f32();
        let train = inputs[1].as_f32();
        let x = inputs[2];
        let y = inputs[3];
        let mask = inputs[4].as_f32();
        let clip_r = inputs[5].item_f32() as f64;
        let pt = self.meta.pt;
        let b = self.meta.batch;
        let dp = self.is_dp();
        let mode = self.clip_mode();
        let threads = self.resolve_threads(b);
        let is_lm = m.kind == RefKind::Lm;
        let rw = blocked::ROW_HDR + plan.row_stride;
        // block geometry: non-LM pools over row-blocks; LM pools over rows
        // and blocks each row's positions inside the kernel
        let eff = effective_block(self.block_rows, is_lm, m.t, b, threads);
        let (n_tasks, task_rows) = if is_lm { (b, 1) } else { ((b + eff - 1) / eff, eff) };
        let shard_stride = task_rows * rw;

        let mut scratch = self.scratch.borrow_mut();
        let s = &mut *scratch;
        s.full.resize(m.layout.n_params, 0.0);
        s.factors.resize(n_tasks * shard_stride, 0.0);
        if s.rows.len() < b.max(n_tasks) {
            s.rows.resize(b.max(n_tasks), RowOut::default());
        }
        s.ensure_blocked(threads, eff, m.feat_dim(), m.h, m.out);
        s.grad_sum.clear();
        s.grad_sum.resize(pt, 0.0);
        for r in &self.merge_plan {
            let src = if r.from_train { train } else { frozen };
            s.full[r.dst..r.dst + r.len].copy_from_slice(&src[r.src..r.src + r.len]);
        }
        let net = m.net_view(&s.full);
        // widen the embedding table once per step (exact, so the blocked
        // forward stays value-identical to the per-gather widening)
        s.embed64.resize(net.embed.len(), 0.0);
        for (dst, &v) in s.embed64.iter_mut().zip(net.embed) {
            *dst = v as f64;
        }
        let slots = self.slots;
        let ctx =
            BlockedCtx { net: &net, slots: &slots, plan, embed64: &s.embed64, dp, clip_r, mode };
        let kind = m.kind;
        let t_len = m.t;
        let out_w = m.out;
        let npix = m.img * m.img * 3;
        // phase A: one task per block (LM: per row), factors + headers
        // into the task's shard
        pool::for_each_sharded(
            n_tasks,
            &mut s.blocked_ws[..threads],
            &mut s.rows[..n_tasks],
            &mut s.factors[..n_tasks * shard_stride],
            shard_stride,
            |task, bw, shard| {
                if is_lm {
                    let row = task;
                    if mask[row] <= 0.0 {
                        shard[..blocked::ROW_HDR].fill(0.0);
                        return RowOut::default();
                    }
                    let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                    let targets = &y.as_i32()[row * t_len..(row + 1) * t_len];
                    blocked::row_lm_blocked(&ctx, bw, shard, toks, targets);
                    return RowOut::default();
                }
                let r0 = task * task_rows;
                let nb = (b - r0).min(task_rows);
                let mrows = &mask[r0..r0 + nb];
                match kind {
                    RefKind::Cls => {
                        let toks = &x.as_i32()[r0 * t_len..(r0 + nb) * t_len];
                        let ys = &y.as_i32()[r0..r0 + nb];
                        blocked::block_cls(&ctx, bw, shard, toks, t_len, ys, mrows, nb);
                    }
                    RefKind::Vit => {
                        let pix = &x.as_f32()[r0 * npix..(r0 + nb) * npix];
                        let ys = &y.as_i32()[r0..r0 + nb];
                        blocked::block_vit(&ctx, bw, shard, pix, ys, mrows, nb);
                    }
                    RefKind::Cnn => {
                        let pix = &x.as_f32()[r0 * npix..(r0 + nb) * npix];
                        let ts = &y.as_f32()[r0 * out_w..(r0 + nb) * out_w];
                        blocked::block_cnn(&ctx, bw, shard, pix, ts, mrows, nb);
                    }
                    RefKind::Lm => unreachable!("LM pools per row above"),
                }
                RowOut::default()
            },
        );
        // headers -> per-row results; blocks are contiguous row runs, so
        // row r's slice always starts at r * rw
        let mut loss_sum = 0.0f64;
        let mut sq_norms = vec![0.0f32; b];
        for row in 0..b {
            let hdr = &s.factors[row * rw..row * rw + blocked::ROW_HDR];
            let ro = RowOut { a: hdr[1], b: hdr[2], active: hdr[0] != 0.0 };
            s.rows[row] = ro;
            if !ro.active {
                continue;
            }
            sq_norms[row] = ro.b as f32;
            loss_sum += ro.a * mask[row] as f64;
        }
        // phase B: exactly the ghost tier's fixed-order accumulation,
        // reading the factors from behind each row's header
        accumulate_factor_rows(
            m,
            &slots,
            plan,
            &s.factors,
            rw,
            blocked::ROW_HDR,
            &s.rows,
            b,
            x,
            threads,
            &mut s.grad_sum,
        );
        Ok(vec![
            Tensor::scalar_f32(loss_sum as f32),
            Tensor::f32(vec![pt], s.grad_sum.iter().map(|&v| v as f32).collect()),
            Tensor::f32(vec![b], sq_norms),
        ])
    }

    /// The simd tier: blocked's two-phase structure (f32-lane panel
    /// sweeps into header-first factor rows, then the shared fixed-order
    /// phase-B accumulation) with no f64 widening on the panel hot path —
    /// weights and embeddings feed the lanes as the f32 slices they
    /// already are, so the blocked tier's per-step `embed64` table and
    /// per-panel `wrow` widening both disappear.
    fn run_train_simd(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, EngineError> {
        let m = &*self.model;
        let plan = self.ghost.as_ref().expect("factor plan built at load");
        let frozen = inputs[0].as_f32();
        let train = inputs[1].as_f32();
        let x = inputs[2];
        let y = inputs[3];
        let mask = inputs[4].as_f32();
        let clip_r = inputs[5].item_f32() as f64;
        let pt = self.meta.pt;
        let b = self.meta.batch;
        let dp = self.is_dp();
        let mode = self.clip_mode();
        let threads = self.resolve_threads(b);
        let is_lm = m.kind == RefKind::Lm;
        let rw = blocked::ROW_HDR + plan.row_stride;
        // identical block geometry to the blocked tier: non-LM pools over
        // row-blocks; LM pools over rows and panels positions per row
        let eff = effective_block(self.block_rows, is_lm, m.t, b, threads);
        let (n_tasks, task_rows) = if is_lm { (b, 1) } else { ((b + eff - 1) / eff, eff) };
        let shard_stride = task_rows * rw;

        let mut scratch = self.scratch.borrow_mut();
        let s = &mut *scratch;
        s.full.resize(m.layout.n_params, 0.0);
        s.factors.resize(n_tasks * shard_stride, 0.0);
        if s.rows.len() < b.max(n_tasks) {
            s.rows.resize(b.max(n_tasks), RowOut::default());
        }
        s.ensure_simd(threads, eff, m.feat_dim(), m.h, m.out);
        s.grad_sum.clear();
        s.grad_sum.resize(pt, 0.0);
        for r in &self.merge_plan {
            let src = if r.from_train { train } else { frozen };
            s.full[r.dst..r.dst + r.len].copy_from_slice(&src[r.src..r.src + r.len]);
        }
        let net = m.net_view(&s.full);
        let slots = self.slots;
        let ctx = SimdCtx { net: &net, slots: &slots, plan, level: self.simd, dp, clip_r, mode };
        let kind = m.kind;
        let t_len = m.t;
        let out_w = m.out;
        let npix = m.img * m.img * 3;
        // phase A: one task per block (LM: per row), factors + headers
        // into the task's shard
        pool::for_each_sharded(
            n_tasks,
            &mut s.simd_ws[..threads],
            &mut s.rows[..n_tasks],
            &mut s.factors[..n_tasks * shard_stride],
            shard_stride,
            |task, sw, shard| {
                if is_lm {
                    let row = task;
                    if mask[row] <= 0.0 {
                        shard[..blocked::ROW_HDR].fill(0.0);
                        return RowOut::default();
                    }
                    let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                    let targets = &y.as_i32()[row * t_len..(row + 1) * t_len];
                    simd::row_lm_simd(&ctx, sw, shard, toks, targets);
                    return RowOut::default();
                }
                let r0 = task * task_rows;
                let nb = (b - r0).min(task_rows);
                let mrows = &mask[r0..r0 + nb];
                match kind {
                    RefKind::Cls => {
                        let toks = &x.as_i32()[r0 * t_len..(r0 + nb) * t_len];
                        let ys = &y.as_i32()[r0..r0 + nb];
                        simd::panel_cls(&ctx, sw, shard, toks, t_len, ys, mrows, nb);
                    }
                    RefKind::Vit => {
                        let pix = &x.as_f32()[r0 * npix..(r0 + nb) * npix];
                        let ys = &y.as_i32()[r0..r0 + nb];
                        simd::panel_vit(&ctx, sw, shard, pix, ys, mrows, nb);
                    }
                    RefKind::Cnn => {
                        let pix = &x.as_f32()[r0 * npix..(r0 + nb) * npix];
                        let ts = &y.as_f32()[r0 * out_w..(r0 + nb) * out_w];
                        simd::panel_cnn(&ctx, sw, shard, pix, ts, mrows, nb);
                    }
                    RefKind::Lm => unreachable!("LM pools per row above"),
                }
                RowOut::default()
            },
        );
        // headers -> per-row results (contiguous row runs, as in blocked)
        let mut loss_sum = 0.0f64;
        let mut sq_norms = vec![0.0f32; b];
        for row in 0..b {
            let hdr = &s.factors[row * rw..row * rw + blocked::ROW_HDR];
            let ro = RowOut { a: hdr[1], b: hdr[2], active: hdr[0] != 0.0 };
            s.rows[row] = ro;
            if !ro.active {
                continue;
            }
            sq_norms[row] = ro.b as f32;
            loss_sum += ro.a * mask[row] as f64;
        }
        // phase B: the shared fixed-order factor accumulation — the simd
        // panels widened their factors exactly, so this path is reused
        // verbatim
        accumulate_factor_rows(
            m,
            &slots,
            plan,
            &s.factors,
            rw,
            blocked::ROW_HDR,
            &s.rows,
            b,
            x,
            threads,
            &mut s.grad_sum,
        );
        Ok(vec![
            Tensor::scalar_f32(loss_sum as f32),
            Tensor::f32(vec![pt], s.grad_sum.iter().map(|&v| v as f32).collect()),
            Tensor::f32(vec![b], sq_norms),
        ])
    }

    /// The coalesced multi-tenant panel sweep behind
    /// [`StepRunner::run_multi`]: N same-artifact train microbatches — one
    /// per tenant — run as ONE pool dispatch over the union of their
    /// (tenant, block) tasks, amortizing worker wakeup and weight-panel
    /// traffic across tenants the way the blocked tier amortizes it across
    /// rows.
    ///
    /// Bit-identity contract: each job keeps its own merged parameter
    /// vector, its own `BlockedCtx`/`SimdCtx`, the *same* block
    /// partitioning a solo run would use (`effective_block` depends only
    /// on shape/batch/threads, all shared), and its own phase-B
    /// fixed-order accumulation over its own factor region — so
    /// `out[j]` is bit-identical to `run_train_blocked`/`run_train_simd`
    /// on job `j` alone.  Only the dispatch is shared; no float from one
    /// tenant ever meets a float from another.
    fn run_train_multi(&self, jobs: &[[&Tensor; 6]]) -> Result<Vec<Vec<Tensor>>, EngineError> {
        let m = &*self.model;
        let plan = self.ghost.as_ref().expect("factor plan built at load");
        let pt = self.meta.pt;
        let b = self.meta.batch;
        let dp = self.is_dp();
        let mode = self.clip_mode();
        let threads = self.resolve_threads(b);
        let is_lm = m.kind == RefKind::Lm;
        let rw = blocked::ROW_HDR + plan.row_stride;
        // identical geometry to the solo tiers — shared by every job
        // because effective_block sees only (shape, batch, threads)
        let eff = effective_block(self.block_rows, is_lm, m.t, b, threads);
        let (n_tasks, task_rows) = if is_lm { (b, 1) } else { ((b + eff - 1) / eff, eff) };
        let shard_stride = task_rows * rw;
        let nj = jobs.len();
        let np = m.layout.n_params;
        let kind = m.kind;
        let t_len = m.t;
        let out_w = m.out;
        let npix = m.img * m.img * 3;
        let slots = self.slots;

        let mut scratch = self.scratch.borrow_mut();
        let s = &mut *scratch;
        s.multi_full.resize(nj * np, 0.0);
        s.multi_factors.resize(nj * n_tasks * shard_stride, 0.0);
        if s.multi_rows.len() < nj * n_tasks {
            s.multi_rows.resize(nj * n_tasks, RowOut::default());
        }
        match self.kernels {
            KernelMode::Blocked => s.ensure_blocked(threads, eff, m.feat_dim(), m.h, m.out),
            KernelMode::Simd => s.ensure_simd(threads, eff, m.feat_dim(), m.h, m.out),
            _ => unreachable!("run_multi guards the kernel tier"),
        }
        // per-job parameter merge into the job's region of one flat buffer
        for (j, job) in jobs.iter().enumerate() {
            let frozen = job[0].as_f32();
            let train = job[1].as_f32();
            let full = &mut s.multi_full[j * np..(j + 1) * np];
            for r in &self.merge_plan {
                let src = if r.from_train { train } else { frozen };
                full[r.dst..r.dst + r.len].copy_from_slice(&src[r.src..r.src + r.len]);
            }
        }
        let clip_rs: Vec<f64> = jobs.iter().map(|job| job[5].item_f32() as f64).collect();
        let masks: Vec<&[f32]> = jobs.iter().map(|job| job[4].as_f32()).collect();
        match self.kernels {
            KernelMode::Blocked => {
                // widen each job's embedding table once (exactly as solo)
                let el = m.net_view(&s.multi_full[..np]).embed.len();
                s.multi_embed64.resize(nj * el, 0.0);
                if el > 0 {
                    let (mf, me) = (&s.multi_full, &mut s.multi_embed64);
                    for j in 0..nj {
                        let src = m.net_view(&mf[j * np..(j + 1) * np]).embed;
                        for (dst, &v) in me[j * el..(j + 1) * el].iter_mut().zip(src) {
                            *dst = v as f64;
                        }
                    }
                }
                let nets: Vec<NetView> =
                    (0..nj).map(|j| m.net_view(&s.multi_full[j * np..(j + 1) * np])).collect();
                let ctxs: Vec<BlockedCtx> = (0..nj)
                    .map(|j| BlockedCtx {
                        net: &nets[j],
                        slots: &slots,
                        plan,
                        embed64: &s.multi_embed64[j * el..(j + 1) * el],
                        dp,
                        clip_r: clip_rs[j],
                        mode,
                    })
                    .collect();
                // phase A: ONE dispatch over the union of every job's tasks
                pool::for_each_sharded(
                    nj * n_tasks,
                    &mut s.blocked_ws[..threads],
                    &mut s.multi_rows[..nj * n_tasks],
                    &mut s.multi_factors[..nj * n_tasks * shard_stride],
                    shard_stride,
                    |g, bw, shard| {
                        let j = g / n_tasks;
                        let task = g - j * n_tasks;
                        let ctx = &ctxs[j];
                        let x = jobs[j][2];
                        let y = jobs[j][3];
                        let mask = masks[j];
                        if is_lm {
                            let row = task;
                            if mask[row] <= 0.0 {
                                shard[..blocked::ROW_HDR].fill(0.0);
                                return RowOut::default();
                            }
                            let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                            let targets = &y.as_i32()[row * t_len..(row + 1) * t_len];
                            blocked::row_lm_blocked(ctx, bw, shard, toks, targets);
                            return RowOut::default();
                        }
                        let r0 = task * task_rows;
                        let nb = (b - r0).min(task_rows);
                        let mrows = &mask[r0..r0 + nb];
                        match kind {
                            RefKind::Cls => {
                                let toks = &x.as_i32()[r0 * t_len..(r0 + nb) * t_len];
                                let ys = &y.as_i32()[r0..r0 + nb];
                                blocked::block_cls(ctx, bw, shard, toks, t_len, ys, mrows, nb);
                            }
                            RefKind::Vit => {
                                let pix = &x.as_f32()[r0 * npix..(r0 + nb) * npix];
                                let ys = &y.as_i32()[r0..r0 + nb];
                                blocked::block_vit(ctx, bw, shard, pix, ys, mrows, nb);
                            }
                            RefKind::Cnn => {
                                let pix = &x.as_f32()[r0 * npix..(r0 + nb) * npix];
                                let ts = &y.as_f32()[r0 * out_w..(r0 + nb) * out_w];
                                blocked::block_cnn(ctx, bw, shard, pix, ts, mrows, nb);
                            }
                            RefKind::Lm => unreachable!("LM pools per row above"),
                        }
                        RowOut::default()
                    },
                );
            }
            KernelMode::Simd => {
                let nets: Vec<NetView> =
                    (0..nj).map(|j| m.net_view(&s.multi_full[j * np..(j + 1) * np])).collect();
                let ctxs: Vec<SimdCtx> = (0..nj)
                    .map(|j| SimdCtx {
                        net: &nets[j],
                        slots: &slots,
                        plan,
                        level: self.simd,
                        dp,
                        clip_r: clip_rs[j],
                        mode,
                    })
                    .collect();
                pool::for_each_sharded(
                    nj * n_tasks,
                    &mut s.simd_ws[..threads],
                    &mut s.multi_rows[..nj * n_tasks],
                    &mut s.multi_factors[..nj * n_tasks * shard_stride],
                    shard_stride,
                    |g, sw, shard| {
                        let j = g / n_tasks;
                        let task = g - j * n_tasks;
                        let ctx = &ctxs[j];
                        let x = jobs[j][2];
                        let y = jobs[j][3];
                        let mask = masks[j];
                        if is_lm {
                            let row = task;
                            if mask[row] <= 0.0 {
                                shard[..blocked::ROW_HDR].fill(0.0);
                                return RowOut::default();
                            }
                            let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                            let targets = &y.as_i32()[row * t_len..(row + 1) * t_len];
                            simd::row_lm_simd(ctx, sw, shard, toks, targets);
                            return RowOut::default();
                        }
                        let r0 = task * task_rows;
                        let nb = (b - r0).min(task_rows);
                        let mrows = &mask[r0..r0 + nb];
                        match kind {
                            RefKind::Cls => {
                                let toks = &x.as_i32()[r0 * t_len..(r0 + nb) * t_len];
                                let ys = &y.as_i32()[r0..r0 + nb];
                                simd::panel_cls(ctx, sw, shard, toks, t_len, ys, mrows, nb);
                            }
                            RefKind::Vit => {
                                let pix = &x.as_f32()[r0 * npix..(r0 + nb) * npix];
                                let ys = &y.as_i32()[r0..r0 + nb];
                                simd::panel_vit(ctx, sw, shard, pix, ys, mrows, nb);
                            }
                            RefKind::Cnn => {
                                let pix = &x.as_f32()[r0 * npix..(r0 + nb) * npix];
                                let ts = &y.as_f32()[r0 * out_w..(r0 + nb) * out_w];
                                simd::panel_cnn(ctx, sw, shard, pix, ts, mrows, nb);
                            }
                            RefKind::Lm => unreachable!("LM pools per row above"),
                        }
                        RowOut::default()
                    },
                );
            }
            _ => unreachable!("run_multi guards the kernel tier"),
        }
        // per-job demux in fixed job order: headers -> per-row results,
        // then the job's own phase-B fixed-order accumulation
        let mut outs = Vec::with_capacity(nj);
        for (j, job) in jobs.iter().enumerate() {
            let jf = &s.multi_factors[j * n_tasks * shard_stride..(j + 1) * n_tasks * shard_stride];
            let mask = job[4].as_f32();
            let mut loss_sum = 0.0f64;
            let mut sq_norms = vec![0.0f32; b];
            let mut rows = vec![RowOut::default(); b];
            for (row, slot) in rows.iter_mut().enumerate() {
                let hdr = &jf[row * rw..row * rw + blocked::ROW_HDR];
                let ro = RowOut { a: hdr[1], b: hdr[2], active: hdr[0] != 0.0 };
                *slot = ro;
                if !ro.active {
                    continue;
                }
                sq_norms[row] = ro.b as f32;
                loss_sum += ro.a * mask[row] as f64;
            }
            s.grad_sum.clear();
            s.grad_sum.resize(pt, 0.0);
            accumulate_factor_rows(
                m,
                &slots,
                plan,
                jf,
                rw,
                blocked::ROW_HDR,
                &rows,
                b,
                job[2],
                threads,
                &mut s.grad_sum,
            );
            outs.push(vec![
                Tensor::scalar_f32(loss_sum as f32),
                Tensor::f32(vec![pt], s.grad_sum.iter().map(|&v| v as f32).collect()),
                Tensor::f32(vec![b], sq_norms),
            ]);
        }
        Ok(outs)
    }

    /// The pre-optimization scalar path (see [`crate::kernels::legacy`]):
    /// single-threaded, allocates per row, re-merges parameters per call.
    fn run_train_legacy(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, EngineError> {
        let m = &*self.model;
        let frozen = inputs[0].as_f32();
        let train = inputs[1].as_f32();
        let x = inputs[2];
        let y = inputs[3];
        let mask = inputs[4].as_f32();
        let clip_r = inputs[5].item_f32() as f64;
        let full = m.layout.merge(frozen, train, &self.meta.subset);
        let net = m.net_view(&full);
        let slots = m.train_slots(&self.meta.subset);
        let pt = self.meta.pt;
        let b = self.meta.batch;
        let dp = self.is_dp();
        let mode = self.clip_mode();
        let embed_slot = slots.get("embed").copied();
        let scatter_ctx =
            legacy::BackwardCtx { net: &net, slots: &slots, want_dfeat: embed_slot.is_some() };
        let plain_ctx = legacy::BackwardCtx { net: &net, slots: &slots, want_dfeat: false };

        let mut loss_sum = 0.0f64;
        let mut grad_sum = vec![0.0f64; pt];
        let mut sq_norms = vec![0.0f32; b];
        let mut g = vec![0.0f64; pt];
        for row in 0..b {
            if mask[row] <= 0.0 {
                continue;
            }
            for v in g.iter_mut() {
                *v = 0.0;
            }
            let mut row_loss = 0.0f64;
            match m.kind {
                RefKind::Cls => {
                    let toks = &x.as_i32()[row * m.t..(row + 1) * m.t];
                    let (feat, active) = legacy::pooled_feat(&net, toks);
                    let fwd = legacy::forward_feat(&net, feat);
                    let label = (y.as_i32()[row].max(0) as usize) % m.out;
                    let (loss, dl) = legacy::softmax_ce(&fwd.logits, label);
                    row_loss = loss;
                    let dfeat =
                        legacy::backward_feat(&scatter_ctx, &fwd, &dl, &mut g);
                    if let (Some((off, _)), Some(dfeat)) = (embed_slot, dfeat) {
                        if !active.is_empty() {
                            let inv = 1.0 / active.len() as f64;
                            for &tok in &active {
                                let ge = &mut g[off + tok * m.d..off + (tok + 1) * m.d];
                                for i in 0..m.d {
                                    ge[i] += dfeat[i] * inv;
                                }
                            }
                        }
                    }
                }
                RefKind::Lm => {
                    let toks = &x.as_i32()[row * m.t..(row + 1) * m.t];
                    let targets = &y.as_i32()[row * m.t..(row + 1) * m.t];
                    for p in 0..m.t {
                        let target = targets[p];
                        if target <= 0 {
                            continue; // pad / ignore
                        }
                        let (feat, tok) = legacy::token_feat(&net, toks[p]);
                        let fwd = legacy::forward_feat(&net, feat);
                        let (loss, dl) = legacy::softmax_ce(&fwd.logits, target as usize % m.out);
                        row_loss += loss;
                        let dfeat =
                            legacy::backward_feat(&scatter_ctx, &fwd, &dl, &mut g);
                        if let (Some((off, _)), Some(dfeat)) = (embed_slot, dfeat) {
                            let ge = &mut g[off + tok * m.d..off + (tok + 1) * m.d];
                            for i in 0..m.d {
                                ge[i] += dfeat[i];
                            }
                        }
                    }
                }
                RefKind::Vit | RefKind::Cnn => {
                    let npix = m.img * m.img * 3;
                    let pix = &x.as_f32()[row * npix..(row + 1) * npix];
                    let fwd = legacy::forward_feat(&net, legacy::pixel_feat(pix));
                    if m.kind == RefKind::Vit {
                        let label = (y.as_i32()[row].max(0) as usize) % m.out;
                        let (loss, dl) = legacy::softmax_ce(&fwd.logits, label);
                        row_loss = loss;
                        legacy::backward_feat(&plain_ctx, &fwd, &dl, &mut g);
                    } else {
                        let targets: Vec<f64> = y.as_f32()[row * m.out..(row + 1) * m.out]
                            .iter()
                            .map(|&v| v as f64)
                            .collect();
                        let (loss, dl) = legacy::sigmoid_bce(&fwd.logits, &targets);
                        row_loss = loss;
                        legacy::backward_feat(&plain_ctx, &fwd, &dl, &mut g);
                    }
                }
            }
            let sq: f64 = g.iter().map(|&v| v * v).sum();
            sq_norms[row] = sq as f32;
            let c = if dp { clip_factor(sq, clip_r, mode) } else { 1.0 };
            // fastdp-lint: dp-sink
            for (gs, &gi) in grad_sum.iter_mut().zip(&g) {
                *gs += c * gi;
            }
            loss_sum += row_loss * mask[row] as f64;
        }
        Ok(vec![
            Tensor::scalar_f32(loss_sum as f32),
            Tensor::f32(vec![pt], grad_sum.iter().map(|&v| v as f32).collect()),
            Tensor::f32(vec![b], sq_norms),
        ])
    }

    fn run_eval(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, EngineError> {
        let m = &*self.model;
        let full = inputs[1].as_f32();
        let x = inputs[2];
        let y = inputs[3];
        let mask = inputs[4].as_f32();
        let b = self.meta.batch;
        let threads = self.resolve_threads(b);

        let mut scratch = self.scratch.borrow_mut();
        let s = &mut *scratch;
        if s.rows.len() < b {
            s.rows.resize(b, RowOut::default());
        }
        s.ensure_workspaces(threads, m.feat_dim(), m.h, m.out);
        let net = m.net_view(full);
        let kind = m.kind;
        let t_len = m.t;
        let out_w = m.out;
        let npix = m.img * m.img * 3;
        pool::for_each(b, &mut s.workspaces[..threads], &mut s.rows[..b], |row, ws| {
            if mask[row] <= 0.0 {
                return RowOut::default();
            }
            match kind {
                RefKind::Cls => {
                    let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                    fused::pool_tokens(&net, ws, toks);
                    fused::forward(&net, ws);
                    let label = (y.as_i32()[row].max(0) as usize) % out_w;
                    let l = loss::softmax_ce_into(&ws.logits, label, &mut ws.dlogits);
                    let hit = (loss::argmax(&ws.logits) == label) as u32 as f64;
                    RowOut { a: l, b: hit, active: true }
                }
                RefKind::Lm => {
                    let toks = &x.as_i32()[row * t_len..(row + 1) * t_len];
                    let targets = &y.as_i32()[row * t_len..(row + 1) * t_len];
                    let (mut nll, mut count) = (0.0f64, 0.0f64);
                    for (p, &target) in targets.iter().enumerate() {
                        if target <= 0 {
                            continue;
                        }
                        fused::load_token(&net, ws, toks[p]);
                        fused::forward(&net, ws);
                        nll += loss::softmax_ce_into(
                            &ws.logits,
                            target as usize % out_w,
                            &mut ws.dlogits,
                        );
                        count += 1.0;
                    }
                    RowOut { a: nll, b: count, active: true }
                }
                RefKind::Vit => {
                    let pix = &x.as_f32()[row * npix..(row + 1) * npix];
                    fused::load_pixels(ws, pix);
                    fused::forward(&net, ws);
                    let label = (y.as_i32()[row].max(0) as usize) % out_w;
                    let l = loss::softmax_ce_into(&ws.logits, label, &mut ws.dlogits);
                    let hit = (loss::argmax(&ws.logits) == label) as u32 as f64;
                    RowOut { a: l, b: hit, active: true }
                }
                RefKind::Cnn => {
                    let pix = &x.as_f32()[row * npix..(row + 1) * npix];
                    fused::load_pixels(ws, pix);
                    fused::forward(&net, ws);
                    let targets = &y.as_f32()[row * out_w..(row + 1) * out_w];
                    let l = loss::sigmoid_bce_into(&ws.logits, targets, &mut ws.dlogits);
                    let correct = ws
                        .logits
                        .iter()
                        .zip(targets)
                        .filter(|(&l, &t)| (l > 0.0) == (t > 0.5))
                        .count();
                    RowOut { a: l, b: correct as f64 / out_w as f64, active: true }
                }
            }
        });
        let (mut a_sum, mut b_sum) = (0.0f64, 0.0f64);
        for ro in &s.rows[..b] {
            if !ro.active {
                continue;
            }
            a_sum += ro.a;
            b_sum += ro.b;
        }
        Ok(vec![Tensor::scalar_f32(a_sum as f32), Tensor::scalar_f32(b_sum as f32)])
    }

    fn run_decode(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>, EngineError> {
        let m = &*self.model;
        let full = inputs[1].as_f32();
        let x = inputs[2].as_i32();
        let pos = inputs[3].as_i32();
        let b = self.meta.batch;
        let threads = self.resolve_threads(b);

        let mut scratch = self.scratch.borrow_mut();
        let s = &mut *scratch;
        if s.rows.len() < b {
            s.rows.resize(b, RowOut::default());
        }
        s.ensure_workspaces(threads, m.feat_dim(), m.h, m.out);
        let net = m.net_view(full);
        let t_len = m.t;
        let vocab = m.vocab;
        // the pooled shards write into the step-cached buffer (resized
        // once, every element overwritten each call); the returned tensor
        // clones it — one memcpy, not a fresh zero-filled b*vocab
        // allocation per call
        s.decode_out.resize(b * vocab, 0.0);
        pool::for_each_sharded(
            b,
            &mut s.workspaces[..threads],
            &mut s.rows[..b],
            &mut s.decode_out[..b * vocab],
            vocab,
            |row, ws, lrow| {
                let p = (pos[row].max(0) as usize).min(t_len - 1);
                fused::load_token(&net, ws, x[row * t_len + p]);
                fused::forward(&net, ws);
                for (o, &l) in lrow.iter_mut().zip(&ws.logits) {
                    *o = l as f32;
                }
                RowOut::default()
            },
        );
        Ok(vec![Tensor::f32(vec![b, vocab], s.decode_out.clone())])
    }
}

impl StepRunner for RefStep {
    fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, EngineError> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    fn pin(&self, t: &Tensor) -> Result<Pinned, EngineError> {
        Ok(Pinned::Host(std::sync::Arc::new(t.clone())))
    }

    fn pin_shared(&self, t: std::sync::Arc<Tensor>) -> Result<Pinned, EngineError> {
        // host pinning retains the Arc itself: N same-model sessions share
        // ONE frozen parameter vector instead of N deep clones
        Ok(Pinned::Host(t))
    }

    fn run_pinned(
        &self,
        pinned: &[&Pinned],
        host: &[Option<&Tensor>],
    ) -> Result<Vec<Tensor>, EngineError> {
        // borrow every input — the steady-state train path copies nothing
        let mut refs: Vec<&Tensor> = Vec::with_capacity(host.len());
        let mut pi = 0usize;
        for slot in host {
            match slot {
                Some(t) => refs.push(*t),
                None => {
                    let p = pinned.get(pi).ok_or_else(|| {
                        EngineError::backend(NAME, "run_pinned: not enough pinned inputs")
                    })?;
                    pi += 1;
                    match p {
                        Pinned::Host(t) => refs.push(t.as_ref()),
                        Pinned::Device(_) => {
                            return Err(EngineError::backend(
                                NAME,
                                "run_pinned received a device buffer from another backend",
                            ));
                        }
                    }
                }
            }
        }
        self.run_refs(&refs)
    }

    fn prefers_pinned(&self) -> bool {
        true
    }

    fn run_multi(
        &self,
        jobs: &[MultiTrainJob<'_>],
    ) -> Option<Result<Vec<Vec<Tensor>>, EngineError>> {
        // only the panel-sweep tiers have a coalesced path: their phase A is
        // already a pool dispatch over independent (block -> factor shard)
        // tasks, so tasks from different tenants compose into one dispatch
        if self.meta.step != "train"
            || !matches!(self.kernels, KernelMode::Blocked | KernelMode::Simd)
            || jobs.is_empty()
        {
            return None;
        }
        let mut resolved: Vec<[&Tensor; 6]> = Vec::with_capacity(jobs.len());
        for j in jobs {
            let frozen = match j.frozen {
                Pinned::Host(t) => t.as_ref(),
                Pinned::Device(_) => {
                    return Some(Err(EngineError::backend(
                        NAME,
                        "run_multi received a device buffer from another backend",
                    )));
                }
            };
            let refs = [frozen, j.train, j.x, j.y, j.mask, j.clip_r];
            if let Err(e) = check_input_refs(&self.meta, &refs) {
                return Some(Err(e));
            }
            resolved.push(refs);
        }
        Some(self.run_train_multi(&resolved))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(artifact: &str) -> (InterpreterBackend, Rc<dyn StepRunner>) {
        let mut b = InterpreterBackend::new();
        let s = b.load(artifact).unwrap();
        (b, s)
    }

    /// Build full-shape train inputs for a step, with `rows` active examples.
    ///
    /// Deliberately NOT `crate::bench::synth_step_inputs` (the shared
    /// generator used by the throughput harness and the determinism
    /// suite): the finite-difference and clipping tests below have
    /// tolerances tuned against exactly these input constants, so this
    /// pre-existing generator stays frozen with them.
    fn train_inputs(
        backend: &InterpreterBackend,
        step: &dyn StepRunner,
        rows: usize,
        seed: u64,
    ) -> Vec<Tensor> {
        let meta = step.meta().clone();
        let layout = backend.layout(&meta.model).unwrap();
        let full = backend.init_params(&meta.model).unwrap();
        let (frozen, train) = layout.split(&full, &meta.subset);
        let b = meta.batch;
        let mut rng = ChaChaRng::new(seed, 0x7E57);
        let x_spec = &meta.inputs[2];
        let y_spec = &meta.inputs[3];
        let x = if x_spec.dtype == "int32" {
            let n = x_spec.elements();
            Tensor::i32(
                x_spec.shape.clone(),
                (0..n).map(|_| 1 + rng.below(300) as i32).collect(),
            )
        } else {
            let n = x_spec.elements();
            Tensor::f32(
                x_spec.shape.clone(),
                (0..n).map(|_| (rng.uniform() * 2.0 - 1.0) as f32).collect(),
            )
        };
        let y = if y_spec.dtype == "int32" {
            let n = y_spec.elements();
            Tensor::i32(y_spec.shape.clone(), (0..n).map(|_| rng.below(2) as i32).collect())
        } else {
            let n = y_spec.elements();
            Tensor::f32(
                y_spec.shape.clone(),
                (0..n).map(|_| (rng.uniform() < 0.5) as i32 as f32).collect(),
            )
        };
        let mut mask = vec![0.0f32; b];
        for m in mask.iter_mut().take(rows) {
            *m = 1.0;
        }
        vec![
            Tensor::f32(vec![meta.pf], frozen),
            Tensor::f32(vec![meta.pt], train),
            x,
            y,
            Tensor::f32(vec![b], mask),
            Tensor::scalar_f32(1000.0), // R large enough that clipping is a no-op
        ]
    }

    #[test]
    fn parses_parametric_model_names() {
        let b = InterpreterBackend::new();
        assert_eq!(b.model_info("cls-t128").unwrap().shape.t, 128);
        assert_eq!(b.model_info("cnn-r32").unwrap().shape.img, 32);
        assert_eq!(b.model_info("vit-c20").unwrap().shape.n_cls, 20);
        assert!(matches!(b.model_info("mamba-7b"), Err(EngineError::UnknownModel(_))));
        // bias-less CNN really has no enc/b leaf
        let l = b.layout("cnn-small").unwrap();
        assert!(l.leaves.iter().all(|leaf| leaf.name != "enc/b"));
        let l = b.layout("cnn-small-bias").unwrap();
        assert!(l.leaves.iter().any(|leaf| leaf.name == "enc/b"));
    }

    #[test]
    fn layout_is_consistent() {
        let b = InterpreterBackend::new();
        for model in BUILTIN_MODELS {
            let layout = b.layout(model).unwrap();
            let init = b.init_params(model).unwrap();
            assert_eq!(init.len(), layout.n_params, "{model}");
            let (frozen, train) = layout.split(&init, "bitfit");
            assert_eq!(layout.merge(&frozen, &train, "bitfit"), init, "{model}");
            assert!(layout.subset_size("bitfit") < layout.subset_size("full"), "{model}");
            // init is deterministic
            assert_eq!(b.init_params(model).unwrap(), init, "{model}");
        }
    }

    #[test]
    fn merge_plan_matches_layout_merge() {
        let b = InterpreterBackend::new();
        for model in BUILTIN_MODELS {
            let m = b.model_ref(model).unwrap();
            let init = m.init_params();
            for subset in ["full", "bitfit", "lastlayer"] {
                let (frozen, train) = m.layout.split(&init, subset);
                let expect = m.layout.merge(&frozen, &train, subset);
                let mut got = vec![0.0f32; m.layout.n_params];
                for r in m.merge_plan(subset) {
                    let src = if r.from_train { &train } else { &frozen };
                    got[r.dst..r.dst + r.len].copy_from_slice(&src[r.src..r.src + r.len]);
                }
                assert_eq!(got, expect, "{model}/{subset}");
            }
        }
    }

    #[test]
    fn packed_slots_match_hashmap_slots() {
        let b = InterpreterBackend::new();
        for model in BUILTIN_MODELS {
            let m = b.model_ref(model).unwrap();
            for subset in ["full", "bitfit", "lastlayer"] {
                let map = m.train_slots(subset);
                let packed = m.train_slots_packed(subset);
                let lookup = |name: &str| map.get(name).map(|&(off, _)| off);
                assert_eq!(packed.embed, lookup("embed"), "{model}/{subset}");
                assert_eq!(packed.enc_w, lookup("enc/w"), "{model}/{subset}");
                assert_eq!(packed.enc_b, lookup("enc/b"), "{model}/{subset}");
                assert_eq!(packed.head_w, lookup("head/w"), "{model}/{subset}");
                assert_eq!(packed.head_b, lookup("head/b"), "{model}/{subset}");
                assert_eq!(packed.pt, m.layout.subset_size(subset), "{model}/{subset}");
            }
        }
    }

    #[test]
    fn masked_rows_contribute_nothing() {
        for artifact in ["cls-base__dp-bitfit", "lm-small__dp-bitfit", "cnn-small-bias__dp-bitfit-add"]
        {
            let (backend, step) = load(artifact);
            let mut inputs = train_inputs(&backend, step.as_ref(), 4, 9);
            let out4 = step.run(&inputs).unwrap();
            // zero mask => zero loss + zero grad
            let b = step.meta().batch;
            inputs[4] = Tensor::f32(vec![b], vec![0.0; b]);
            let out0 = step.run(&inputs).unwrap();
            assert_eq!(out0[0].item_f32(), 0.0, "{artifact}");
            assert!(out0[1].as_f32().iter().all(|&g| g == 0.0), "{artifact}");
            assert!(out4[0].item_f32() > 0.0, "{artifact}");
            assert!(out4[1].as_f32().iter().any(|&g| g != 0.0), "{artifact}");
        }
    }

    #[test]
    fn analytic_gradients_match_finite_differences() {
        for artifact in [
            "cls-base__nondp-full",
            "cls-base__nondp-bitfit",
            "lm-small__nondp-full",
            "vit-c10__nondp-full",
            "cnn-small-bias__nondp-full",
            "cnn-small__nondp-full",
        ] {
            let (backend, step) = load(artifact);
            let inputs = train_inputs(&backend, step.as_ref(), 3, 11);
            let out = step.run(&inputs).unwrap();
            let grad = out[1].as_f32().to_vec();
            let loss0 = out[0].item_f32() as f64;
            let pt = step.meta().pt;
            // probe a few parameters spread across the trainable vector
            let mut rng = ChaChaRng::new(5, 0xF1D);
            let eps = 2e-3f32;
            for _ in 0..6 {
                let i = rng.below(pt);
                let mut pert = inputs.clone();
                let mut train = pert[1].as_f32().to_vec();
                train[i] += eps;
                pert[1] = Tensor::f32(vec![pt], train);
                let loss1 = step.run(&pert).unwrap()[0].item_f32() as f64;
                let numeric = (loss1 - loss0) / eps as f64;
                let analytic = grad[i] as f64;
                let scale = analytic.abs().max(numeric.abs()).max(0.05);
                assert!(
                    (numeric - analytic).abs() / scale < 0.08,
                    "{artifact} param {i}: numeric {numeric} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn dp_clipping_bounds_per_sample_norms() {
        let (backend, step) = load("cls-base__dp-bitfit");
        let mut inputs = train_inputs(&backend, step.as_ref(), 8, 13);
        let r = 0.05f32;
        inputs[5] = Tensor::scalar_f32(r);
        let out = step.run(&inputs).unwrap();
        // sum of 8 clipped per-sample grads has norm <= 8 * R
        let norm = crate::util::tensor::l2_norm(out[1].as_f32());
        assert!(norm <= 8.0 * r as f64 + 1e-5, "norm {norm}");
        // squared norms output is finite and non-negative
        assert!(out[2].as_f32().iter().all(|&s| s.is_finite() && s >= 0.0));
        // nondp twin does NOT clip: same inputs, bigger gradient
        let (backend2, step2) = load("cls-base__nondp-bitfit");
        let mut inputs2 = train_inputs(&backend2, step2.as_ref(), 8, 13);
        inputs2[5] = Tensor::scalar_f32(r);
        let out2 = step2.run(&inputs2).unwrap();
        let norm2 = crate::util::tensor::l2_norm(out2[1].as_f32());
        assert!(norm2 > norm, "clipped {norm} vs unclipped {norm2}");
    }

    #[test]
    fn training_reduces_loss_with_sgd() {
        let (backend, step) = load("cls-base__nondp-full");
        let meta = step.meta().clone();
        let layout = backend.layout(&meta.model).unwrap();
        let full = backend.init_params(&meta.model).unwrap();
        let (frozen, mut train) = layout.split(&full, &meta.subset);
        let b = meta.batch;
        let base = train_inputs(&backend, step.as_ref(), b, 21);
        let (x, y, mask) = (base[2].clone(), base[3].clone(), base[4].clone());
        let frozen_t = Tensor::f32(vec![meta.pf], frozen);
        let mut first = None;
        let mut last = 0.0f32;
        for _ in 0..20 {
            let out = step
                .run(&[
                    frozen_t.clone(),
                    Tensor::f32(vec![meta.pt], train.clone()),
                    x.clone(),
                    y.clone(),
                    mask.clone(),
                    Tensor::scalar_f32(1000.0),
                ])
                .unwrap();
            last = out[0].item_f32() / b as f32;
            first.get_or_insert(last);
            let grad = out[1].as_f32();
            for (p, g) in train.iter_mut().zip(grad) {
                *p -= 0.5 * g / b as f32;
            }
        }
        let first = first.unwrap();
        assert!(last < first * 0.8, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn eval_and_decode_contracts() {
        let (backend, _step) = load("lm-small__eval");
        let mut b2 = InterpreterBackend::new();
        let eval = b2.load("lm-small__eval").unwrap();
        let meta = eval.meta().clone();
        assert_eq!(meta.step, "eval");
        let full = backend.init_params("lm-small").unwrap();
        let b = meta.batch;
        let t = 48;
        let x: Vec<i32> = (0..b * t).map(|i| (i % 383) as i32 + 1).collect();
        let y: Vec<i32> = (0..b * t).map(|i| ((i + 1) % 383) as i32 + 1).collect();
        let out = eval
            .run(&[
                Tensor::f32(vec![0], vec![]),
                Tensor::f32(vec![full.len()], full.clone()),
                Tensor::i32(vec![b, t], x.clone()),
                Tensor::i32(vec![b, t], y),
                Tensor::f32(vec![b], vec![1.0; b]),
            ])
            .unwrap();
        assert!(out[0].item_f32() > 0.0); // summed nll
        assert_eq!(out[1].item_f32(), (b * t) as f32); // every target counted
        let dec = b2.load("lm-small__decode").unwrap();
        assert_eq!(dec.meta().step, "decode");
        let pos: Vec<i32> = (0..b as i32).map(|i| 5 + i).collect();
        let out = dec
            .run(&[
                Tensor::f32(vec![0], vec![]),
                Tensor::f32(vec![full.len()], full),
                Tensor::i32(vec![b, t], x),
                Tensor::i32(vec![b], pos),
            ])
            .unwrap();
        assert_eq!(out[0].shape, vec![b, 384]);
        assert!(out[0].as_f32().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn unknown_artifacts_are_typed_errors() {
        let mut b = InterpreterBackend::new();
        assert!(matches!(
            b.load("cls-base__dp-quantum"),
            Err(EngineError::UnknownArtifact { .. })
        ));
        assert!(matches!(b.load("cls-base"), Err(EngineError::UnknownArtifact { .. })));
        assert!(matches!(b.load("vit-c10__decode"), Err(EngineError::UnknownArtifact { .. })));
        assert!(matches!(
            b.load("cls-base__dp-bitfit__banana"),
            Err(EngineError::UnknownArtifact { .. })
        ));
    }

    #[test]
    fn ghost_scratch_beats_fused_scratch() {
        let b = InterpreterBackend::new();
        for artifact in [
            "cls-base__dp-bitfit",
            "cls-base__dp-full-opacus",
            "vit-c10__dp-full-opacus",
            "cnn-small__dp-bitfit",
        ] {
            let fused = b.train_scratch_bytes(artifact, KernelMode::Fused, 4).unwrap();
            let ghost = b.train_scratch_bytes(artifact, KernelMode::Ghost, 4).unwrap();
            let legacy = b.train_scratch_bytes(artifact, KernelMode::Legacy, 1).unwrap();
            assert!(ghost < fused, "{artifact}: ghost {ghost} >= fused {fused}");
            assert!(legacy < fused, "{artifact}: legacy {legacy} >= fused {fused}");
        }
        // blocked pays per-worker panels on top of ghost's factor rows, so
        // it only undercuts fused where the O(B*pt) shards are the story —
        // the full-subset artifacts (on bitfit pt is tiny and the panels
        // dominate; the bench grid records both columns per cell)
        for artifact in ["cls-base__dp-full-opacus", "vit-c10__dp-full-opacus"] {
            let fused = b.train_scratch_bytes(artifact, KernelMode::Fused, 4).unwrap();
            let ghost = b.train_scratch_bytes(artifact, KernelMode::Ghost, 4).unwrap();
            let blocked = b.train_scratch_bytes(artifact, KernelMode::Blocked, 4).unwrap();
            assert!(blocked < fused, "{artifact}: blocked {blocked} >= fused {fused}");
            assert!(blocked >= ghost, "{artifact}: blocked {blocked} < ghost {ghost}");
            // simd keeps blocked's factor rows but drops the widened
            // embedding table and halves the panel words
            let simd = b.train_scratch_bytes(artifact, KernelMode::Simd, 4).unwrap();
            assert!(simd < blocked, "{artifact}: simd {simd} >= blocked {blocked}");
        }
        // eval artifacts have no train scratch to estimate
        assert!(b.train_scratch_bytes("lm-small__eval", KernelMode::Fused, 1).is_err());
    }

    #[test]
    fn ghost_step_matches_fused_within_tolerance() {
        // one quick in-module sanity check (the full property suite lives
        // in tests/ghost_equivalence.rs)
        let mut bf = InterpreterBackend::with_config(Some(2), Some(KernelMode::Fused));
        let mut bg = InterpreterBackend::with_config(Some(2), Some(KernelMode::Ghost));
        let sf = bf.load("cls-base__dp-bitfit").unwrap();
        let sg = bg.load("cls-base__dp-bitfit").unwrap();
        let inputs = train_inputs(&bf, sf.as_ref(), 8, 23);
        let of = sf.run(&inputs).unwrap();
        let og = sg.run(&inputs).unwrap();
        for (tf, tg) in of.iter().zip(&og) {
            for (&a, &b) in tf.as_f32().iter().zip(tg.as_f32()) {
                let scale = a.abs().max(b.abs()).max(1e-6);
                assert!(((a - b).abs() / scale) < 1e-4, "ghost {b} vs fused {a}");
            }
        }
    }

    #[test]
    fn simd_step_matches_fused_within_tolerance() {
        // quick in-module sanity check (the full property suite lives in
        // tests/simd_equivalence.rs); forced-scalar vs fused so the
        // fallback path is covered even on avx2 hosts
        let mut bf = InterpreterBackend::with_config(Some(2), Some(KernelMode::Fused));
        let mut bs = InterpreterBackend::with_config(Some(2), Some(KernelMode::Simd));
        bs.set_simd_level(Some(SimdLevel::Scalar));
        let sf = bf.load("cls-base__dp-bitfit").unwrap();
        let ss = bs.load("cls-base__dp-bitfit").unwrap();
        let inputs = train_inputs(&bf, sf.as_ref(), 8, 23);
        let of = sf.run(&inputs).unwrap();
        let os = ss.run(&inputs).unwrap();
        for (tf, ts) in of.iter().zip(&os) {
            for (&a, &b) in tf.as_f32().iter().zip(ts.as_f32()) {
                let scale = a.abs().max(b.abs()).max(1e-6);
                assert!(((a - b).abs() / scale) < 1e-4, "simd {b} vs fused {a}");
            }
        }
    }

    #[test]
    fn blocked_step_matches_fused_within_tolerance() {
        // one quick in-module sanity check per family (the full property
        // suite lives in tests/blocked_equivalence.rs)
        for artifact in ["cls-base__dp-bitfit", "lm-small__dp-bitfit"] {
            let mut bf = InterpreterBackend::with_config(Some(2), Some(KernelMode::Fused));
            let mut bb = InterpreterBackend::with_config(Some(2), Some(KernelMode::Blocked));
            bb.set_block_rows(Some(8));
            let sf = bf.load(artifact).unwrap();
            let sb = bb.load(artifact).unwrap();
            let inputs = train_inputs(&bf, sf.as_ref(), 8, 23);
            let of = sf.run(&inputs).unwrap();
            let ob = sb.run(&inputs).unwrap();
            for (tf, tb) in of.iter().zip(&ob) {
                for (&a, &b) in tf.as_f32().iter().zip(tb.as_f32()) {
                    let scale = a.abs().max(b.abs()).max(1e-6);
                    assert!(((a - b).abs() / scale) < 1e-4, "{artifact}: blocked {b} vs fused {a}");
                }
            }
        }
    }

    #[test]
    fn run_pinned_borrows_and_matches_run() {
        let (backend, step) = load("cls-base__dp-bitfit");
        let inputs = train_inputs(&backend, step.as_ref(), 8, 17);
        let by_run = step.run(&inputs).unwrap();
        let pinned = step.pin(&inputs[0]).unwrap();
        let by_pinned = step
            .run_pinned(
                &[&pinned],
                &[
                    None,
                    Some(&inputs[1]),
                    Some(&inputs[2]),
                    Some(&inputs[3]),
                    Some(&inputs[4]),
                    Some(&inputs[5]),
                ],
            )
            .unwrap();
        assert_eq!(by_run, by_pinned);
    }
}
