//! `fastdp::engine` — the public entry point for running (DP) fine-tuning
//! jobs.
//!
//! The engine is a PrivacyEngine-style façade: you describe a job as a typed
//! [`JobSpec`] (model, [`Method`], [`Privacy`] budget, optimizer, sampling
//! plan), hand it to an [`Engine`] that owns a pluggable [`Backend`] plus
//! metric sinks, and get back a [`Session`] with `run_step` / `evaluate` /
//! `checkpoint` / `privacy_spent`.  Multiple sessions can run concurrently
//! over one engine: compiled steps are cached in the backend and shared.
//!
//! ```no_run
//! use fastdp::engine::{Engine, JobSpec, Method};
//!
//! let mut engine = Engine::auto("artifacts"); // PJRT if artifacts exist, else interpreter
//! let spec = JobSpec::builder("cls-base", Method::BiTFiT)
//!     .task("sst2")
//!     .eps(8.0)          // target (eps, delta); sigma is calibrated
//!     .batch(256)
//!     .steps(60)
//!     .n_train(4096)
//!     .build()?;
//! let data = engine.dataset(&spec.model, "sst2", spec.n_train, 11)?;
//! let mut session = engine.session(&spec)?;
//! for _ in 0..spec.steps {
//!     session.run_step(&data)?;
//! }
//! println!("eps spent: {:.2}", session.privacy_spent().epsilon);
//! session.checkpoint("runs/sst2.ckpt")?;
//! # Ok::<(), fastdp::engine::EngineError>(())
//! ```
//!
//! Two backends ship with the crate: [`PjrtBackend`] (AOT HLO artifacts via
//! PJRT — the fast path) and [`InterpreterBackend`] (a dependency-free
//! pure-Rust reference that needs no artifact directory — CI, tests, and
//! laptops).  [`Engine::auto`] picks for you.
//!
//! Two more session capabilities ride on the facade: `JobSpec::replicas`
//! runs the job data-parallel over N real replica workers with measured
//! wire traffic and a bit-identical trajectory (`coordinator::distributed`),
//! and `Session::save_state` / [`Engine::resume_session`] snapshot and
//! resume a mid-run session — optimizer moments, RNG streams and the RDP
//! accountant included — with bit-identical continuation.

mod backend;
mod error;
mod interp;
mod pjrt;
mod session;
mod spec;

pub use backend::{
    check_input_refs, check_inputs, Backend, ModelInfo, MultiTrainJob, Pinned, StepRunner,
};
pub use error::EngineError;
pub use interp::InterpreterBackend;
pub use pjrt::PjrtBackend;
pub use session::{evaluate_params, EvalOutcome, PrivacySpent, Session, StepStats};
// crate-internal: the serve scheduler drives sessions chunk-granularly
pub(crate) use session::PreparedStep;
pub use spec::{JobPlan, JobSpec, JobSpecBuilder, Method, PhaseSpec, Privacy};

// Engine-level re-exports so drivers only import `fastdp::engine`.
pub use crate::coordinator::checkpoint::SessionState;
pub use crate::coordinator::distributed::{CommStats, ReplicaGroup};
pub use crate::coordinator::optim::{LrSchedule, OptimKind};
pub use crate::coordinator::transport::{TransportKind, TransportOpts, WireCodec};
pub use crate::coordinator::task_data::TaskData;
pub use crate::coordinator::workloads::ModelShape;
pub use crate::dp::clip::ClipMode;
pub use crate::kernels::{KernelMode, SimdLevel};
pub use crate::runtime::Layout;

use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::metrics::JsonlSink;
use crate::coordinator::workloads;
use crate::data::GenExample;

/// The façade owning a backend + metric-sink configuration.
pub struct Engine {
    backend: Box<dyn Backend>,
    metrics_dir: Option<PathBuf>,
    /// In-memory cache of derived parameter vectors (pretrained backbones),
    /// so backends without a disk home (interpreter) don't re-pretrain per
    /// job.
    params_cache: std::collections::HashMap<String, Vec<f32>>,
    /// Content-keyed dedupe of frozen parameter vectors: every session
    /// assembled from this engine shares one immutable copy per distinct
    /// frozen split (see `session::FrozenCache`) — N same-model BiTFiT
    /// sessions cost one backbone, not N.
    frozen_cache: session::FrozenCache,
}

impl Engine {
    /// Wrap an explicit backend.
    pub fn new(backend: Box<dyn Backend>) -> Engine {
        Engine {
            backend,
            metrics_dir: None,
            params_cache: std::collections::HashMap::new(),
            frozen_cache: session::FrozenCache::default(),
        }
    }

    /// The dependency-free reference interpreter (no artifacts needed).
    pub fn interpreter() -> Engine {
        Engine::new(Box::new(InterpreterBackend::new()))
    }

    /// The PJRT backend over a compiled artifact directory.
    pub fn pjrt(artifact_dir: impl AsRef<Path>) -> Result<Engine, EngineError> {
        Ok(Engine::new(Box::new(PjrtBackend::open(artifact_dir)?)))
    }

    /// PJRT when `artifact_dir` holds a manifest, else the interpreter.
    ///
    /// A present-but-broken artifact directory falls back to the interpreter
    /// with a loud stderr warning (numbers from the reference interpreter are
    /// correctness-grade, not performance-grade).
    pub fn auto(artifact_dir: impl AsRef<Path>) -> Engine {
        if PjrtBackend::available(&artifact_dir) {
            match Engine::pjrt(&artifact_dir) {
                // built against the vendored xla stub, PJRT can open
                // artifacts but never execute them — don't commit to it
                Ok(e) if e.platform().contains("xla stub") => eprintln!(
                    "warning: artifact directory {} exists but this binary links the xla stub \
                     (no HLO execution); {}",
                    artifact_dir.as_ref().display(),
                    PjrtBackend::interpreter_tier_hint()
                ),
                Ok(e) => return e,
                Err(e) => eprintln!(
                    "warning: artifact directory {} exists but the PJRT backend failed to open \
                     ({e}); {}",
                    artifact_dir.as_ref().display(),
                    PjrtBackend::interpreter_tier_hint()
                ),
            }
        }
        Engine::interpreter()
    }

    /// Short backend identifier (`"pjrt"` / `"interpreter"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Human-readable platform description.
    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Directory where per-run JSONL metric logs are written (one file per
    /// session, named after [`JobSpec::run_name`]).
    pub fn set_metrics_dir(&mut self, dir: impl AsRef<Path>) {
        self.metrics_dir = Some(dir.as_ref().to_path_buf());
    }

    /// Models the backend can serve.
    pub fn models(&self) -> Vec<String> {
        self.backend.models()
    }

    /// Step artifacts the backend can serve.
    pub fn artifacts(&self) -> Vec<String> {
        self.backend.artifacts()
    }

    pub fn model_info(&self, model: &str) -> Result<ModelInfo, EngineError> {
        self.backend.model_info(model)
    }

    /// The flat-parameter layout contract for a model.
    pub fn layout(&self, model: &str) -> Result<Layout, EngineError> {
        self.backend.layout(model)
    }

    /// The model's deterministic initial parameter vector.
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>, EngineError> {
        self.backend.init_params(model)
    }

    /// Artifact metadata without loading the step.
    pub fn artifact_meta(&self, artifact: &str) -> Result<crate::runtime::ArtifactMeta, EngineError> {
        self.backend.artifact_meta(artifact)
    }

    /// Load (and cache) an executable step by artifact name.
    pub fn runner(&mut self, artifact: &str) -> Result<Rc<dyn StepRunner>, EngineError> {
        self.backend.load(artifact)
    }

    /// The model's eval step.
    pub fn evaluator(&mut self, model: &str) -> Result<Rc<dyn StepRunner>, EngineError> {
        self.backend.load(&format!("{model}__eval"))
    }

    /// The model's greedy-decode step (LMs only).
    pub fn decoder(&mut self, model: &str) -> Result<Rc<dyn StepRunner>, EngineError> {
        self.backend.load(&format!("{model}__decode"))
    }

    /// Default task for a model (by its kind).
    pub fn default_task(&self, model: &str) -> Result<&'static str, EngineError> {
        Ok(workloads::default_task(&self.model_info(model)?.shape.kind))
    }

    /// Build a synthetic dataset shaped for `model`.
    pub fn dataset(
        &self,
        model: &str,
        task: &str,
        n: usize,
        seed: u64,
    ) -> Result<TaskData, EngineError> {
        workloads::build(&self.model_info(model)?.shape, task, n, seed)
    }

    /// E2E generation data plus reference sets for the NLG metrics.
    pub fn dataset_e2e(
        &self,
        model: &str,
        n: usize,
        seed: u64,
    ) -> Result<(TaskData, Vec<GenExample>), EngineError> {
        workloads::build_e2e(&self.model_info(model)?.shape, n, seed)
    }

    /// Reset a model's head leaves to their deterministic init values
    /// (downstream tasks replace the classification head, paper §4.3).
    pub fn reset_head(&self, model: &str, params: &mut [f32]) -> Result<(), EngineError> {
        let layout = self.layout(model)?;
        let init = self.init_params(model)?;
        layout.copy_head(params, &init);
        Ok(())
    }

    /// Where derived state (pretrained checkpoints) may be cached.
    pub fn cache_dir(&self) -> Option<PathBuf> {
        self.backend.cache_dir()
    }

    /// Look up an in-memory cached parameter vector (pretrained backbones).
    pub fn cached_params(&self, key: &str) -> Option<Vec<f32>> {
        self.params_cache.get(key).cloned()
    }

    /// Store a parameter vector in the in-memory cache.
    pub fn cache_params(&mut self, key: &str, params: Vec<f32>) {
        self.params_cache.insert(key.to_string(), params);
    }

    /// Start a session from the model's deterministic init parameters.
    pub fn session(&mut self, spec: &JobSpec) -> Result<Session, EngineError> {
        let params = self.init_params(&spec.model)?;
        self.session_from(spec, params)
    }

    /// Start a session from an explicit (e.g. pretrained) parameter vector.
    pub fn session_from(
        &mut self,
        spec: &JobSpec,
        params: Vec<f32>,
    ) -> Result<Session, EngineError> {
        // sigma comes from the same resolution `--dry-run` prints, so plan
        // and training can never disagree
        let sigma = spec.plan().sigma;
        let layout = self.layout(&spec.model)?;
        let mut phases = Vec::new();
        for phase in spec.phases() {
            let runner = self.backend.load(&phase.artifact)?;
            let meta = runner.meta();
            if meta.step != "train" {
                return Err(EngineError::Data(format!(
                    "{} is not a train artifact",
                    phase.artifact
                )));
            }
            // data-parallel mode: one persistent replica group per phase
            // (workers idle until their phase starts); replicas = 1 keeps
            // the in-process path with no worker threads at all
            let replicas = if spec.replicas > 1 {
                let opts = spec.transport_opts();
                match self.backend.replica_group(&phase.artifact, spec.replicas, &opts) {
                    Some(group) => Some(group?),
                    None => {
                        return Err(EngineError::backend(
                            self.backend.name(),
                            format!(
                                "backend cannot run data-parallel replicas \
                                 (spec asked for {}); use the interpreter backend \
                                 or replicas = 1",
                                spec.replicas
                            ),
                        ));
                    }
                }
            } else {
                None
            };
            phases.push((phase, runner, replicas));
        }
        // best-effort: a missing eval artifact must not block training-only
        // jobs (the old Trainer had no eval requirement); Session::evaluate
        // reports the gap if it is ever called
        let eval_runner = self.evaluator(&spec.model).ok();
        let sink = match &self.metrics_dir {
            Some(dir) => {
                // never truncate an earlier session's log: pick the first
                // free run_name[__N].jsonl
                let base = spec.run_name();
                let mut path = dir.join(format!("{base}.jsonl"));
                let mut n = 1u32;
                while path.exists() && n < 10_000 {
                    n += 1;
                    path = dir.join(format!("{base}__{n}.jsonl"));
                }
                Some(JsonlSink::create(path).map_err(|e| EngineError::Metrics(format!("{e:#}")))?)
            }
            None => None,
        };
        Session::assemble(
            spec.clone(),
            phases,
            eval_runner,
            layout,
            params,
            sigma,
            sink,
            Some(self.frozen_cache.clone()),
        )
    }

    /// Evaluate a checkpointed/explicit parameter vector on a dataset.
    pub fn evaluate(
        &mut self,
        model: &str,
        params: &[f32],
        data: &TaskData,
        max_examples: usize,
    ) -> Result<EvalOutcome, EngineError> {
        let eval = self.evaluator(model)?;
        evaluate_params(eval.as_ref(), params, data, max_examples)
    }

    /// Resume a session from a [`SessionState`] snapshot written by
    /// `Session::save_state`.  The spec must describe the same job (model,
    /// phases, privacy regime); the resumed session continues the run
    /// bit-identically.
    pub fn resume_session(
        &mut self,
        spec: &JobSpec,
        path: impl AsRef<Path>,
    ) -> Result<Session, EngineError> {
        let st = SessionState::load(path).map_err(|e| EngineError::Checkpoint(format!("{e:#}")))?;
        if st.model != spec.model {
            return Err(EngineError::Checkpoint(format!(
                "session state is for model {:?}, the spec says {:?}",
                st.model, spec.model
            )));
        }
        let mut session = self.session_from(spec, st.params.clone())?;
        session.restore_state(&st)?;
        Ok(session)
    }

    /// Load a checkpoint, verifying it belongs to `model`.
    pub fn load_checkpoint(
        &self,
        model: &str,
        path: impl AsRef<Path>,
    ) -> Result<Vec<f32>, EngineError> {
        let ck = Checkpoint::load(path).map_err(|e| EngineError::Checkpoint(format!("{e:#}")))?;
        if ck.model != model {
            return Err(EngineError::Checkpoint(format!(
                "checkpoint is for model {:?}, wanted {model:?}",
                ck.model
            )));
        }
        Ok(ck.params)
    }
}
