//! `JobSpec`: the typed, validated description of one training job.
//!
//! A spec is backend-independent: it names a model, a fine-tuning
//! [`Method`], a [`Privacy`] budget (target epsilon *or* an explicit noise
//! multiplier — never both), the optimizer/schedule, and the sampling plan.
//! [`JobSpec::plan`] resolves it (artifact names, sampling rate q, calibrated
//! sigma, projected epsilon) without touching any backend — that is what
//! `fastdp train --dry-run` prints.

use crate::coordinator::optim::{LrSchedule, OptimKind};
use crate::coordinator::transport::{
    TransportKind, TransportOpts, WireCodec, DEFAULT_RECV_TIMEOUT_MS,
};
use crate::dp::clip::ClipMode;
use crate::dp::{calibrate, rdp};
use crate::runtime::env;

use super::error::EngineError;

/// Fine-tuning method (paper §2-3; two-phase is App. A.2.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    /// Bias-term fine-tuning (the paper's method).
    BiTFiT,
    /// BiTFiT on a bias-augmented model (§3.4, "BiTFiT-Add").
    BiTFiTAdd,
    /// Full fine-tuning; `ghost` selects ghost-norm clipping over Opacus-style
    /// per-sample gradient instantiation.
    Full { ghost: bool },
    /// Linear probing: train the head only.
    LastLayer,
    /// LoRA adapters (the `cls-lora` model family).
    Lora,
    /// Houlsby adapters (the `cls-adapter` model family).
    Adapter,
    /// X+BiTFiT: `full_steps` of full fine-tuning at `full_lr`, then BiTFiT
    /// for the remaining steps at the spec's learning rate.
    TwoPhase { full_steps: u64, full_lr: f64 },
}

impl Method {
    /// The artifact method fragment for this method under a privacy regime,
    /// e.g. `dp-bitfit` / `nondp-full` (matches the AOT artifact naming).
    pub fn fragment(&self, private: bool) -> String {
        let base = match self {
            Method::BiTFiT => {
                if private {
                    "dp-bitfit"
                } else {
                    "nondp-bitfit"
                }
            }
            Method::BiTFiTAdd => {
                if private {
                    "dp-bitfit-add"
                } else {
                    "nondp-bitfit"
                }
            }
            Method::Full { ghost } => {
                if !private {
                    "nondp-full"
                } else if *ghost {
                    "dp-full-ghost"
                } else {
                    "dp-full-opacus"
                }
            }
            Method::LastLayer => {
                if private {
                    "dp-lastlayer"
                } else {
                    "nondp-lastlayer"
                }
            }
            Method::Lora => {
                if private {
                    "dp-lora"
                } else {
                    "nondp-full"
                }
            }
            Method::Adapter => {
                if private {
                    "dp-adapter"
                } else {
                    "nondp-full"
                }
            }
            Method::TwoPhase { .. } => {
                if private {
                    "dp-bitfit"
                } else {
                    "nondp-bitfit"
                }
            }
        };
        base.to_string()
    }

    /// Parse an artifact method fragment (`dp-bitfit`, `nondp-full`, ...)
    /// into `(method, private)`.
    pub fn parse(fragment: &str) -> Option<(Method, bool)> {
        let (private, rest) = if let Some(r) = fragment.strip_prefix("dp-") {
            (true, r)
        } else if let Some(r) = fragment.strip_prefix("nondp-") {
            (false, r)
        } else {
            // bare method names mean "let the privacy budget decide"
            (true, fragment)
        };
        let m = match rest {
            "bitfit" => Method::BiTFiT,
            "bitfit-add" => Method::BiTFiTAdd,
            "full" | "full-ghost" => Method::Full { ghost: true },
            "full-opacus" => Method::Full { ghost: false },
            "lastlayer" => Method::LastLayer,
            "lora" => Method::Lora,
            "adapter" => Method::Adapter,
            _ => return None,
        };
        Some((m, private))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::BiTFiT => "bitfit",
            Method::BiTFiTAdd => "bitfit-add",
            Method::Full { ghost: true } => "full-ghost",
            Method::Full { ghost: false } => "full-opacus",
            Method::LastLayer => "lastlayer",
            Method::Lora => "lora",
            Method::Adapter => "adapter",
            Method::TwoPhase { .. } => "two-phase",
        }
    }
}

/// Privacy budget: a target `(eps, delta)` to calibrate sigma for, an
/// explicit noise multiplier, or non-private training.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Privacy {
    NonPrivate,
    Eps { eps: f64, delta: f64 },
    Sigma { sigma: f64, delta: f64 },
}

impl Privacy {
    pub fn is_private(&self) -> bool {
        !matches!(self, Privacy::NonPrivate)
    }

    pub fn delta(&self) -> f64 {
        match self {
            Privacy::NonPrivate => 0.0,
            Privacy::Eps { delta, .. } | Privacy::Sigma { delta, .. } => *delta,
        }
    }
}

/// A validated training-job specification.  Construct via [`JobSpec::builder`].
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub model: String,
    pub method: Method,
    /// Dataset task; `None` means the model kind's default task.
    pub task: Option<String>,
    pub privacy: Privacy,
    pub optim: OptimKind,
    pub lr: f64,
    pub schedule: LrSchedule,
    /// Clipping threshold R (paper default 0.1 for text, Table 8).
    pub clip_r: f64,
    pub clip_mode: ClipMode,
    /// Logical (Poisson-expected) batch size.
    pub logical_batch: usize,
    /// Planned total steps (drives eps -> sigma calibration).
    pub steps: u64,
    /// Training-set size (drives the sampling rate q).
    pub n_train: usize,
    pub seed: u64,
    /// Data-parallel replica workers (1 = in-process single-replica
    /// training).  Replicas shard the logical batch's microbatch chunks and
    /// exchange clipped gradient sums / updated trainable parameters with
    /// the leader; results are bit-identical for any value (see
    /// `coordinator::distributed`).
    pub replicas: usize,
    /// How replica exchange traffic moves (`channel` in-process / `tcp`
    /// framed loopback).  Irrelevant — and harmless — when `replicas` is 1.
    pub transport: TransportKind,
    /// Byte layout of the per-exchange payloads (`raw-f32le` bit-identical
    /// / `bf16` half-width under the 1e-2 short-trajectory tolerance).
    pub wire: WireCodec,
    /// Leader-side deadline (milliseconds) for any single replica reply
    /// before the exchange fails typed and the group poisons.
    pub recv_timeout_ms: u64,
    /// Run name for metric sinks; defaults to `model__method`.
    pub name: Option<String>,
}

impl JobSpec {
    pub fn builder(model: &str, method: Method) -> JobSpecBuilder {
        JobSpecBuilder::new(model, method)
    }

    /// Run name used for logs/metrics.
    pub fn run_name(&self) -> String {
        self.name
            .clone()
            .unwrap_or_else(|| format!("{}__{}", self.model, self.method.name()))
    }

    /// Poisson sampling rate q = B / n.
    pub fn q(&self) -> f64 {
        (self.logical_batch as f64 / self.n_train as f64).min(1.0)
    }

    /// The resolved replica-transport configuration (what the backend's
    /// `replica_group` receives when `replicas > 1`).
    pub fn transport_opts(&self) -> TransportOpts {
        TransportOpts {
            kind: self.transport,
            wire: self.wire,
            recv_timeout: std::time::Duration::from_millis(self.recv_timeout_ms),
        }
    }

    /// Artifact name suffix for the clip mode (`__autos` for AUTO-S).
    fn clip_suffix(&self) -> &'static str {
        match self.clip_mode {
            ClipMode::Abadi => "",
            ClipMode::AutoS => "__autos",
        }
    }

    /// Artifact names per phase, with per-phase steps and learning rates.
    pub fn phases(&self) -> Vec<PhaseSpec> {
        let private = self.privacy.is_private();
        match self.method {
            Method::TwoPhase { full_steps, full_lr } => {
                let full_steps = full_steps.min(self.steps);
                let mut v = Vec::new();
                if full_steps > 0 {
                    v.push(PhaseSpec {
                        label: "full",
                        artifact: format!(
                            "{}__{}{}",
                            self.model,
                            Method::Full { ghost: true }.fragment(private),
                            self.clip_suffix()
                        ),
                        steps: full_steps,
                        lr: full_lr,
                    });
                }
                let remaining = self.steps - full_steps;
                if remaining > 0 || v.is_empty() {
                    v.push(PhaseSpec {
                        label: "bitfit",
                        artifact: format!(
                            "{}__{}{}",
                            self.model,
                            Method::BiTFiT.fragment(private),
                            self.clip_suffix()
                        ),
                        steps: remaining,
                        lr: self.lr,
                    });
                }
                v
            }
            _ => vec![PhaseSpec {
                label: self.method.name(),
                artifact: format!(
                    "{}__{}{}",
                    self.model,
                    self.method.fragment(private),
                    self.clip_suffix()
                ),
                steps: self.steps,
                lr: self.lr,
            }],
        }
    }

    /// Resolve the spec into a concrete execution plan — pure math, no
    /// backend.  Calibrates sigma for `Privacy::Eps` budgets.
    pub fn plan(&self) -> JobPlan {
        let q = self.q();
        let (sigma, eps_target) = match self.privacy {
            Privacy::NonPrivate => (0.0, None),
            Privacy::Sigma { sigma, .. } => (sigma, None),
            Privacy::Eps { eps, delta } => {
                (calibrate::calibrate_sigma(q, self.steps, eps, delta), Some(eps))
            }
        };
        let eps_projected = if self.privacy.is_private() && sigma > 0.0 {
            rdp::epsilon(q, sigma, self.steps, self.privacy.delta())
        } else {
            0.0
        };
        JobPlan { q, sigma, eps_target, eps_projected, phases: self.phases() }
    }
}

/// One phase of a resolved job (two for X+BiTFiT, one otherwise).
#[derive(Debug, Clone)]
pub struct PhaseSpec {
    pub label: &'static str,
    pub artifact: String,
    pub steps: u64,
    pub lr: f64,
}

/// The resolved execution plan for a [`JobSpec`].
#[derive(Debug, Clone)]
pub struct JobPlan {
    pub q: f64,
    /// Resolved noise multiplier (0 for non-private runs).
    pub sigma: f64,
    /// The eps target, when the budget was given as `Privacy::Eps`.
    pub eps_target: Option<f64>,
    /// Epsilon the RDP accountant projects for the planned steps.
    pub eps_projected: f64,
    pub phases: Vec<PhaseSpec>,
}

impl JobPlan {
    /// Human-readable rendering (used by `fastdp train --dry-run`).
    pub fn describe(&self, spec: &JobSpec) -> String {
        let mut s = String::new();
        s.push_str(&format!("job {}\n", spec.run_name()));
        s.push_str(&format!("  model        {}\n", spec.model));
        s.push_str(&format!("  method       {}\n", spec.method.name()));
        s.push_str(&format!(
            "  task         {}\n",
            spec.task.as_deref().unwrap_or("(model default)")
        ));
        match spec.privacy {
            Privacy::NonPrivate => s.push_str("  privacy      non-private\n"),
            Privacy::Eps { eps, delta } => {
                s.push_str(&format!("  privacy      eps <= {eps} at delta = {delta}\n"))
            }
            Privacy::Sigma { sigma, delta } => {
                s.push_str(&format!("  privacy      sigma = {sigma} at delta = {delta}\n"))
            }
        }
        s.push_str(&format!(
            "  optimizer    {:?} lr {} schedule {:?}\n",
            spec.optim, spec.lr, spec.schedule
        ));
        s.push_str(&format!(
            "  clipping     R = {} mode {}\n",
            spec.clip_r,
            spec.clip_mode.name()
        ));
        s.push_str(&format!(
            "  sampling     |B| = {} of n = {} (q = {:.5}), {} steps, seed {}\n",
            spec.logical_batch,
            spec.n_train,
            self.q,
            spec.steps,
            spec.seed
        ));
        if spec.replicas > 1 {
            s.push_str(&format!(
                "  replicas     {} data-parallel workers (bit-identical to 1)\n",
                spec.replicas
            ));
            s.push_str(&format!(
                "  transport    {} wire {} (reply deadline {} ms)\n",
                spec.transport.name(),
                spec.wire.name(),
                spec.recv_timeout_ms
            ));
        }
        if spec.privacy.is_private() {
            s.push_str(&format!(
                "  resolved     sigma = {:.4}, projected eps = {:.3}\n",
                self.sigma, self.eps_projected
            ));
        }
        s.push_str("  phases:\n");
        for p in &self.phases {
            s.push_str(&format!(
                "    {:<8} {:>6} steps  lr {:<8}  artifact {}\n",
                p.label, p.steps, p.lr, p.artifact
            ));
        }
        s
    }
}

/// Builder with validation; `build()` returns typed [`EngineError`]s, never
/// panics.
#[derive(Debug, Clone)]
pub struct JobSpecBuilder {
    model: String,
    method: Method,
    task: Option<String>,
    eps: Option<f64>,
    sigma: Option<f64>,
    delta: f64,
    optim: OptimKind,
    lr: f64,
    schedule: LrSchedule,
    clip_r: f64,
    clip_mode: ClipMode,
    logical_batch: usize,
    steps: u64,
    n_train: usize,
    seed: u64,
    replicas: usize,
    transport: Option<TransportKind>,
    wire: Option<WireCodec>,
    recv_timeout_ms: Option<u64>,
    name: Option<String>,
}

impl JobSpecBuilder {
    pub fn new(model: &str, method: Method) -> JobSpecBuilder {
        JobSpecBuilder {
            model: model.to_string(),
            method,
            task: None,
            eps: None,
            sigma: None,
            delta: 1e-5,
            optim: OptimKind::Adam,
            lr: 5e-3,
            schedule: LrSchedule::Constant,
            clip_r: 0.1,
            clip_mode: ClipMode::Abadi,
            logical_batch: 64,
            steps: 100,
            n_train: 4096,
            seed: 0,
            replicas: 1,
            transport: None,
            wire: None,
            recv_timeout_ms: None,
            name: None,
        }
    }

    pub fn task(mut self, task: &str) -> Self {
        self.task = Some(task.to_string());
        self
    }

    /// Target epsilon (sigma will be calibrated). Mutually exclusive with
    /// [`Self::sigma`].
    pub fn eps(mut self, eps: f64) -> Self {
        self.eps = Some(eps);
        self
    }

    /// Explicit noise multiplier. Mutually exclusive with [`Self::eps`].
    pub fn sigma(mut self, sigma: f64) -> Self {
        self.sigma = Some(sigma);
        self
    }

    pub fn delta(mut self, delta: f64) -> Self {
        self.delta = delta;
        self
    }

    pub fn optim(mut self, optim: OptimKind) -> Self {
        self.optim = optim;
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = lr;
        self
    }

    pub fn schedule(mut self, schedule: LrSchedule) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn clip_r(mut self, clip_r: f64) -> Self {
        self.clip_r = clip_r;
        self
    }

    pub fn clip_mode(mut self, mode: ClipMode) -> Self {
        self.clip_mode = mode;
        self
    }

    pub fn batch(mut self, logical_batch: usize) -> Self {
        self.logical_batch = logical_batch;
        self
    }

    pub fn steps(mut self, steps: u64) -> Self {
        self.steps = steps;
        self
    }

    pub fn n_train(mut self, n_train: usize) -> Self {
        self.n_train = n_train;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Data-parallel replica workers; 1 (the default) trains in-process.
    pub fn replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    /// Replica exchange transport; the default resolves from the
    /// environment registry and falls back to in-process channels.
    pub fn transport(mut self, kind: TransportKind) -> Self {
        self.transport = Some(kind);
        self
    }

    /// Per-exchange payload codec; the default resolves from the
    /// environment registry and falls back to bit-identical `raw-f32le`.
    pub fn wire(mut self, wire: WireCodec) -> Self {
        self.wire = Some(wire);
        self
    }

    /// Leader-side reply deadline in milliseconds (must be >= 1).
    pub fn recv_timeout_ms(mut self, ms: u64) -> Self {
        self.recv_timeout_ms = Some(ms);
        self
    }

    pub fn name(mut self, name: &str) -> Self {
        self.name = Some(name.to_string());
        self
    }

    /// Validate and build the spec.
    pub fn build(self) -> Result<JobSpec, EngineError> {
        if self.model.is_empty() {
            return Err(EngineError::spec("model name is empty"));
        }
        if self.logical_batch == 0 {
            return Err(EngineError::spec("logical batch must be positive"));
        }
        if self.n_train == 0 {
            return Err(EngineError::spec("n_train must be positive"));
        }
        if self.steps == 0 {
            return Err(EngineError::spec("steps must be positive"));
        }
        if !(self.lr.is_finite() && self.lr > 0.0) {
            return Err(EngineError::spec(format!("learning rate {} must be finite and positive", self.lr)));
        }
        if !(self.clip_r.is_finite() && self.clip_r > 0.0) {
            return Err(EngineError::spec(format!("clip threshold {} must be finite and positive", self.clip_r)));
        }
        if let Method::TwoPhase { full_lr, .. } = self.method {
            if !(full_lr.is_finite() && full_lr > 0.0) {
                return Err(EngineError::spec("two-phase full_lr must be finite and positive"));
            }
        }
        if self.replicas == 0 {
            return Err(EngineError::spec("replicas must be >= 1 (1 = in-process)"));
        }
        if self.replicas > 64 {
            return Err(EngineError::spec(format!(
                "replicas = {} is past the supported group size (64)",
                self.replicas
            )));
        }
        if matches!(self.method, Method::Lora | Method::Adapter)
            && self.eps.is_none()
            && self.sigma.is_none()
        {
            // there is no non-private adapter artifact; falling back to full
            // fine-tuning would silently invalidate parameter-efficiency runs
            return Err(EngineError::spec(format!(
                "method {} requires a privacy budget (eps or sigma); \
                 non-private adapter training is not supported",
                self.method.name()
            )));
        }
        let recv_timeout_ms = match self.recv_timeout_ms {
            Some(0) => {
                return Err(EngineError::spec("replica reply deadline must be >= 1 ms"));
            }
            Some(ms) => ms,
            None => env::recv_timeout_ms().unwrap_or(DEFAULT_RECV_TIMEOUT_MS),
        };
        let privacy = match (self.eps, self.sigma) {
            (Some(_), Some(_)) => {
                return Err(EngineError::spec(
                    "eps and sigma are both set; pick one (eps calibrates sigma)",
                ));
            }
            (Some(eps), None) => {
                if !(eps.is_finite() && eps > 0.0) {
                    return Err(EngineError::spec(format!("eps {eps} must be finite and positive")));
                }
                if !(self.delta > 0.0 && self.delta < 1.0) {
                    return Err(EngineError::spec(format!("delta {} must lie in (0, 1)", self.delta)));
                }
                Privacy::Eps { eps, delta: self.delta }
            }
            (None, Some(sigma)) => {
                if !(sigma.is_finite() && sigma >= 0.0) {
                    return Err(EngineError::spec(format!(
                        "sigma {sigma} must be finite and non-negative"
                    )));
                }
                if !(self.delta > 0.0 && self.delta < 1.0) {
                    return Err(EngineError::spec(format!("delta {} must lie in (0, 1)", self.delta)));
                }
                Privacy::Sigma { sigma, delta: self.delta }
            }
            (None, None) => Privacy::NonPrivate,
        };
        Ok(JobSpec {
            model: self.model,
            method: self.method,
            task: self.task,
            privacy,
            optim: self.optim,
            lr: self.lr,
            schedule: self.schedule,
            clip_r: self.clip_r,
            clip_mode: self.clip_mode,
            logical_batch: self.logical_batch,
            steps: self.steps,
            n_train: self.n_train,
            seed: self.seed,
            replicas: self.replicas,
            transport: self.transport.unwrap_or_else(TransportKind::from_env),
            wire: self.wire.unwrap_or_else(WireCodec::from_env),
            recv_timeout_ms,
            name: self.name,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> JobSpecBuilder {
        JobSpec::builder("cls-base", Method::BiTFiT)
    }

    #[test]
    fn valid_spec_builds() {
        let spec = base().task("sst2").eps(8.0).batch(256).steps(50).build().unwrap();
        assert_eq!(spec.model, "cls-base");
        assert!(spec.privacy.is_private());
        assert_eq!(spec.phases().len(), 1);
        assert_eq!(spec.phases()[0].artifact, "cls-base__dp-bitfit");
        assert_eq!(spec.replicas, 1, "default is in-process single-replica");
        let spec = base().sigma(1.0).replicas(4).build().unwrap();
        assert_eq!(spec.replicas, 4);
        assert!(spec.plan().describe(&spec).contains("4 data-parallel workers"));
    }

    #[test]
    fn nonprivate_artifact_naming() {
        let spec = base().build().unwrap();
        assert_eq!(spec.privacy, Privacy::NonPrivate);
        assert_eq!(spec.phases()[0].artifact, "cls-base__nondp-bitfit");
        let full = JobSpec::builder("lm-small", Method::Full { ghost: true })
            .sigma(1.0)
            .build()
            .unwrap();
        assert_eq!(full.phases()[0].artifact, "lm-small__dp-full-ghost");
    }

    #[test]
    fn rejects_eps_and_sigma_together() {
        let err = base().eps(8.0).sigma(1.0).build().unwrap_err();
        assert!(matches!(err, EngineError::InvalidSpec(_)), "{err}");
        assert!(err.to_string().contains("both"), "{err}");
    }

    #[test]
    fn rejects_bad_numbers() {
        assert!(matches!(base().sigma(-1.0).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().sigma(f64::NAN).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().eps(-2.0).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().eps(f64::INFINITY).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().batch(0).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().steps(0).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().n_train(0).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().lr(0.0).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().lr(f64::NAN).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().clip_r(-0.1).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().replicas(0).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().replicas(65).build(), Err(EngineError::InvalidSpec(_))));
        assert!(matches!(base().eps(8.0).delta(1.5).build(), Err(EngineError::InvalidSpec(_))));
        // adapters have no non-private artifact: require a budget
        assert!(matches!(
            JobSpec::builder("cls-lora", Method::Lora).build(),
            Err(EngineError::InvalidSpec(_))
        ));
        assert!(JobSpec::builder("cls-lora", Method::Lora).eps(8.0).build().is_ok());
    }

    #[test]
    fn eps_budget_calibrates_sigma_in_plan() {
        let spec = base().eps(8.0).batch(256).steps(60).n_train(4096).build().unwrap();
        let plan = spec.plan();
        assert!(plan.sigma > 0.0);
        assert!(plan.eps_projected <= 8.0 + 1e-6);
        assert!(plan.eps_projected > 8.0 * 0.9, "calibration too loose: {}", plan.eps_projected);
        let text = plan.describe(&spec);
        assert!(text.contains("sigma"), "{text}");
        assert!(text.contains("cls-base__dp-bitfit"), "{text}");
    }

    #[test]
    fn two_phase_splits_steps() {
        let spec = JobSpec::builder("vit-c10", Method::TwoPhase { full_steps: 8, full_lr: 1e-3 })
            .sigma(1.0)
            .steps(32)
            .build()
            .unwrap();
        let phases = spec.phases();
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].steps, 8);
        assert_eq!(phases[0].artifact, "vit-c10__dp-full-ghost");
        assert_eq!(phases[1].steps, 24);
        assert_eq!(phases[1].artifact, "vit-c10__dp-bitfit");
        // degenerate: all steps in phase 1
        let spec = JobSpec::builder("vit-c10", Method::TwoPhase { full_steps: 99, full_lr: 1e-3 })
            .sigma(1.0)
            .steps(32)
            .build()
            .unwrap();
        let phases = spec.phases();
        assert_eq!(phases.len(), 1);
        assert_eq!(phases[0].steps, 32);
        assert_eq!(phases[0].label, "full");
    }

    #[test]
    fn transport_flows_into_the_spec_and_validates() {
        let spec = base()
            .sigma(1.0)
            .replicas(2)
            .transport(TransportKind::Tcp)
            .wire(WireCodec::Bf16)
            .recv_timeout_ms(500)
            .build()
            .unwrap();
        assert_eq!(spec.transport, TransportKind::Tcp);
        assert_eq!(spec.wire, WireCodec::Bf16);
        assert_eq!(spec.recv_timeout_ms, 500);
        let opts = spec.transport_opts();
        assert_eq!(opts.kind, TransportKind::Tcp);
        assert_eq!(opts.wire, WireCodec::Bf16);
        assert_eq!(opts.recv_timeout, std::time::Duration::from_millis(500));
        let text = spec.plan().describe(&spec);
        assert!(text.contains("transport    tcp wire bf16"), "{text}");
        // a zero deadline would mean "always poison": reject it
        let err = base().recv_timeout_ms(0).build().unwrap_err();
        assert!(matches!(err, EngineError::InvalidSpec(_)), "{err}");
        // unset fields resolve to a usable configuration
        let spec = base().build().unwrap();
        assert!(spec.recv_timeout_ms >= 1);
        // single-replica specs never print a transport line
        assert!(!spec.plan().describe(&spec).contains("transport"));
    }

    #[test]
    fn method_fragment_parse_roundtrip() {
        for (m, private) in [
            (Method::BiTFiT, true),
            (Method::BiTFiT, false),
            (Method::BiTFiTAdd, true),
            (Method::Full { ghost: true }, true),
            (Method::Full { ghost: false }, true),
            (Method::Full { ghost: true }, false),
            (Method::LastLayer, true),
            (Method::Lora, true),
            (Method::Adapter, true),
        ] {
            let frag = m.fragment(private);
            let (m2, p2) = Method::parse(&frag).unwrap_or_else(|| panic!("parse {frag}"));
            assert_eq!(p2, private, "{frag}");
            // nondp fragments may collapse (bitfit-add -> bitfit, lora -> full)
            if private {
                assert_eq!(m2, m, "{frag}");
            }
        }
        assert!(Method::parse("banana").is_none());
    }
}
