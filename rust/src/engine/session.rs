//! `Session`: one training job running over a backend.
//!
//! This is Algorithm 1 at the logical-batch level, lifted off the raw PJRT
//! runtime and onto the [`StepRunner`] contract: Poisson-sample a logical
//! batch, stream it through the step in fixed-shape masked microbatches
//! (per-sample clipping happens inside the step; clipped sums accumulate
//! exactly across chunks), add Gaussian noise once, average by the expected
//! batch size, descend with the flat-vector optimizer, advance the RDP
//! accountant.  Two-phase X+BiTFiT jobs switch artifacts mid-run while the
//! accountant composes across the switch.
//!
//! Hot-path invariant: nothing parameter-sized is cloned per step.  The
//! frozen vector is pinned into the backend once per phase, the trainable
//! vector is one `Tensor` the optimizer updates in place, and the clip
//! radius is a prebuilt scalar — `run_step` hands the runner borrowed
//! inputs via `run_pinned` (backends that don't prefer pinning, i.e. PJRT's
//! literal path, still get owned clones).

use std::rc::Rc;

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::metrics::JsonlSink;
use crate::coordinator::optim::Optimizer;
use crate::coordinator::task_data::TaskData;
use crate::dp::rdp::RdpAccountant;
use crate::dp::sampler::PoissonSampler;
use crate::runtime::{ArtifactMeta, Layout};
use crate::util::rng::ChaChaRng;
use crate::util::tensor::Tensor;
use crate::util::Timers;

use super::backend::{Pinned, StepRunner};
use super::error::EngineError;
use super::spec::{JobSpec, PhaseSpec};

/// Per-step statistics.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub step: u64,
    pub loss: f64,
    pub batch: usize,
    pub grad_norm: f64,
    pub epsilon: f64,
}

/// Privacy spent so far by a session.
#[derive(Debug, Clone, Copy)]
pub struct PrivacySpent {
    pub epsilon: f64,
    pub delta: f64,
    pub sigma: f64,
    pub q: f64,
    pub steps: u64,
}

/// Outcome of an evaluation pass.
///
/// For classifiers `metric_a` is summed loss and `metric_b` the correct
/// count; for LMs `metric_a` is summed NLL and `metric_b` the token count.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutcome {
    pub metric_a: f64,
    pub metric_b: f64,
    pub n: usize,
}

impl EvalOutcome {
    /// Classification accuracy in [0, 1].
    pub fn accuracy(&self) -> f64 {
        self.metric_b / self.n.max(1) as f64
    }

    /// LM perplexity (`exp(nll / tokens)`).
    pub fn perplexity(&self) -> f64 {
        crate::nlg::perplexity(self.metric_a, self.metric_b)
    }
}

/// One phase of a running session.
struct Phase {
    spec: PhaseSpec,
    runner: Rc<dyn StepRunner>,
}

/// A training session handed out by [`super::Engine::session`].
pub struct Session {
    spec: JobSpec,
    phases: Vec<Phase>,
    active: usize,
    /// Steps remaining before the active phase ends.
    phase_left: u64,
    layout: Layout,
    /// Frozen parameters of the active phase.  Backends that prefer the
    /// pinned path retain their own copy once per phase (`pinned_frozen`),
    /// so this is never cloned per step on that path; `full_params` reads
    /// it directly.
    frozen: Tensor,
    /// Trainable parameters of the active phase, updated in place.
    train: Tensor,
    /// Prebuilt scalar clip-radius input (constant for the whole job).
    clip_r_t: Tensor,
    pinned_frozen: Option<Pinned>,
    optimizer: Optimizer,
    sampler: Option<PoissonSampler>,
    accountant: Option<RdpAccountant>,
    /// `None` when the backend had no eval step for this model (training
    /// still works; `evaluate` reports the gap).
    eval_runner: Option<Rc<dyn StepRunner>>,
    sink: Option<JsonlSink>,
    noise_rng: ChaChaRng,
    data_rng: ChaChaRng,
    sigma: f64,
    q: f64,
    step: u64,
    pub timers: Timers,
}

impl Session {
    /// Assemble a session (called by `Engine::session`).
    pub(super) fn assemble(
        spec: JobSpec,
        phases: Vec<(PhaseSpec, Rc<dyn StepRunner>)>,
        eval_runner: Option<Rc<dyn StepRunner>>,
        layout: Layout,
        start_params: Vec<f32>,
        sigma: f64,
        sink: Option<JsonlSink>,
    ) -> Result<Session, EngineError> {
        if start_params.len() != layout.n_params {
            return Err(EngineError::Data(format!(
                "starting params have {} values, model {} has {}",
                start_params.len(),
                spec.model,
                layout.n_params
            )));
        }
        let phases: Vec<Phase> =
            phases.into_iter().map(|(spec, runner)| Phase { spec, runner }).collect();
        let q = spec.q();
        let meta = phases[0].runner.meta().clone();
        let is_dp = meta.method.starts_with("dp-");
        let sampler = if is_dp {
            Some(PoissonSampler::new(spec.n_train, q, spec.seed ^ 0x5A17))
        } else {
            None
        };
        let accountant = if is_dp && sigma > 0.0 {
            Some(RdpAccountant::new(spec.privacy.delta()))
        } else {
            None
        };
        let mut session = Session {
            noise_rng: ChaChaRng::new(spec.seed, 0x4015E),
            data_rng: ChaChaRng::new(spec.seed, 0xDA7A),
            phase_left: phases[0].spec.steps,
            optimizer: Optimizer::new(spec.optim, phases[0].spec.lr, 0),
            active: 0,
            layout,
            frozen: Tensor::f32(vec![0], vec![]),
            train: Tensor::f32(vec![0], vec![]),
            clip_r_t: Tensor::scalar_f32(spec.clip_r as f32),
            pinned_frozen: None,
            sampler,
            accountant,
            eval_runner,
            sink,
            sigma,
            q,
            step: 0,
            timers: Timers::new(),
            phases,
            spec,
        };
        session.load_phase_params(&start_params)?;
        Ok(session)
    }

    /// Split `full` for the active phase's subset and (re)build the
    /// optimizer + pinned frozen input.
    fn load_phase_params(&mut self, full: &[f32]) -> Result<(), EngineError> {
        let phase = &self.phases[self.active];
        let meta = phase.runner.meta();
        let (frozen, train) = self.layout.split(full, &meta.subset);
        if frozen.len() != meta.pf || train.len() != meta.pt {
            return Err(EngineError::Data(format!(
                "layout split ({}, {}) disagrees with artifact {} ({}, {})",
                frozen.len(),
                train.len(),
                meta.name,
                meta.pf,
                meta.pt
            )));
        }
        self.frozen = Tensor::f32(vec![meta.pf], frozen);
        self.train = Tensor::f32(vec![meta.pt], train);
        self.pinned_frozen = if phase.runner.prefers_pinned() {
            Some(phase.runner.pin(&self.frozen)?)
        } else {
            None
        };
        self.optimizer = Optimizer::new(self.spec.optim, phase.spec.lr, meta.pt);
        Ok(())
    }

    /// Advance to the next phase (two-phase jobs), carrying the accountant.
    fn switch_phase(&mut self) -> Result<(), EngineError> {
        let full = self.full_params();
        self.active += 1;
        self.phase_left = self.phases[self.active].spec.steps;
        self.load_phase_params(&full)
    }

    /// The active phase's step metadata.
    pub fn meta(&self) -> &ArtifactMeta {
        self.phases[self.active].runner.meta()
    }

    /// The job spec this session runs.
    pub fn spec(&self) -> &JobSpec {
        &self.spec
    }

    /// Label of the active phase (`"bitfit"`, `"full"`, ...).
    pub fn phase_label(&self) -> &'static str {
        self.phases[self.active].spec.label
    }

    /// Is this a DP run (noise + Poisson sampling + accounting)?
    pub fn is_dp(&self) -> bool {
        self.sampler.is_some()
    }

    /// Steps taken so far.
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Trainable parameter count in the active phase.
    pub fn trainable_len(&self) -> usize {
        self.train.len()
    }

    /// Current merged full parameter vector.
    pub fn full_params(&self) -> Vec<f32> {
        self.layout.merge(self.frozen.as_f32(), self.train.as_f32(), &self.meta().subset)
    }

    /// Privacy spent so far.
    pub fn privacy_spent(&self) -> PrivacySpent {
        PrivacySpent {
            epsilon: self.accountant.as_ref().map(|a| a.epsilon().0).unwrap_or(0.0),
            delta: self.spec.privacy.delta(),
            sigma: self.sigma,
            q: self.q,
            steps: self.step,
        }
    }

    fn sample_indices(&mut self) -> Vec<usize> {
        let n = self.spec.n_train;
        if let Some(s) = &mut self.sampler {
            s.sample()
        } else {
            // non-private: fixed-size uniform sample without replacement
            let mut idxs: Vec<usize> = (0..n).collect();
            self.data_rng.shuffle(&mut idxs);
            idxs.truncate(self.spec.logical_batch.min(n));
            idxs
        }
    }

    /// One logical-batch training step.
    pub fn run_step(&mut self, data: &TaskData) -> Result<StepStats, EngineError> {
        if data.len() != self.spec.n_train {
            return Err(EngineError::Data(format!(
                "dataset has {} examples but the spec says n_train = {}",
                data.len(),
                self.spec.n_train
            )));
        }
        if self.phase_left == 0 && self.active + 1 < self.phases.len() {
            self.switch_phase()?;
        }
        let t0 = std::time::Instant::now();
        let idxs = self.sample_indices();
        self.timers.add("sample", t0.elapsed().as_secs_f64());
        let runner = self.phases[self.active].runner.clone();
        let meta = runner.meta();
        let b = meta.batch;
        let pt = meta.pt;
        let mut grad = vec![0.0f32; pt];
        let mut loss_sum = 0.0f64;
        for chunk in idxs.chunks(b) {
            let t1 = std::time::Instant::now();
            let (x, y, mask) = data.fill(chunk, b);
            self.timers.add("fill", t1.elapsed().as_secs_f64());
            let t2 = std::time::Instant::now();
            // pinned path: every input is borrowed — no parameter-sized
            // clones anywhere in the steady state
            let out = match &self.pinned_frozen {
                Some(pinned) => runner.run_pinned(
                    &[pinned],
                    &[
                        None,
                        Some(&self.train),
                        Some(&x),
                        Some(&y),
                        Some(&mask),
                        Some(&self.clip_r_t),
                    ],
                )?,
                None => runner.run(&[
                    self.frozen.clone(),
                    self.train.clone(),
                    x,
                    y,
                    mask,
                    self.clip_r_t.clone(),
                ])?,
            };
            self.timers.add("execute", t2.elapsed().as_secs_f64());
            loss_sum += out[0].item_f32() as f64;
            crate::util::tensor::axpy(&mut grad, 1.0, out[1].as_f32());
        }
        let denom = if self.is_dp() {
            // fixed normalization by the expected batch (standard DP-SGD)
            self.spec.logical_batch as f64
        } else {
            idxs.len().max(1) as f64
        };
        if self.is_dp() && self.sigma > 0.0 {
            crate::dp::add_gaussian_noise(
                &mut grad,
                self.sigma,
                self.spec.clip_r,
                &mut self.noise_rng,
            );
        }
        for g in grad.iter_mut() {
            *g /= denom as f32;
        }
        let grad_norm = crate::util::tensor::l2_norm(&grad);
        let lr_base = self.phases[self.active].spec.lr;
        let lr = self.spec.schedule.at(lr_base, self.step);
        self.optimizer.step_lr(self.train.as_f32_mut(), &grad, lr);
        if let Some(acc) = &mut self.accountant {
            acc.step(self.q, self.sigma);
        }
        self.step += 1;
        self.phase_left = self.phase_left.saturating_sub(1);
        let stats = StepStats {
            step: self.step,
            loss: loss_sum / idxs.len().max(1) as f64,
            batch: idxs.len(),
            grad_norm,
            epsilon: self.accountant.as_ref().map(|a| a.epsilon().0).unwrap_or(0.0),
        };
        if let Some(sink) = &mut self.sink {
            sink.step(stats.step, stats.loss, stats.epsilon)
                .map_err(|e| EngineError::Metrics(format!("{e:#}")))?;
        }
        Ok(stats)
    }

    /// Evaluate the current parameters over (up to) `max_examples`.
    pub fn evaluate(
        &self,
        data: &TaskData,
        max_examples: usize,
    ) -> Result<EvalOutcome, EngineError> {
        let eval = self.eval_runner.as_ref().ok_or_else(|| EngineError::UnknownArtifact {
            name: format!("{}__eval", self.spec.model),
            detail: "the backend could not load the eval step when this session was created"
                .to_string(),
        })?;
        evaluate_params(eval.as_ref(), &self.full_params(), data, max_examples)
    }

    /// Write a CRC-protected checkpoint of the current full parameters.
    pub fn checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<(), EngineError> {
        Checkpoint {
            model: self.meta().model.clone(),
            step: self.step,
            params: self.full_params(),
        }
        .save(path)
        .map_err(|e| EngineError::Checkpoint(format!("{e:#}")))
    }
}

/// Evaluate a full parameter vector with an eval step runner.
pub fn evaluate_params(
    eval: &dyn StepRunner,
    full: &[f32],
    data: &TaskData,
    max_examples: usize,
) -> Result<EvalOutcome, EngineError> {
    let meta = eval.meta();
    if meta.step != "eval" {
        return Err(EngineError::Data(format!("{} is not an eval artifact", meta.name)));
    }
    let b = meta.batch;
    let n = data.len().min(max_examples);
    let full_t = Tensor::f32(vec![full.len()], full.to_vec());
    let empty = Tensor::f32(vec![0], vec![]);
    // pin the (large, unchanging) parameter vector once; backends that
    // prefer the pinned path then borrow it per chunk instead of cloning
    let pinned = if eval.prefers_pinned() { Some(eval.pin(&full_t)?) } else { None };
    let (mut a_sum, mut b_sum) = (0.0f64, 0.0f64);
    let idxs: Vec<usize> = (0..n).collect();
    for chunk in idxs.chunks(b) {
        let (x, y, mask) = data.fill(chunk, b);
        let out = match &pinned {
            Some(p) => eval.run_pinned(
                &[p],
                &[Some(&empty), None, Some(&x), Some(&y), Some(&mask)],
            )?,
            None => eval.run(&[empty.clone(), full_t.clone(), x, y, mask])?,
        };
        a_sum += out[0].item_f32() as f64;
        b_sum += out[1].item_f32() as f64;
    }
    Ok(EvalOutcome { metric_a: a_sum, metric_b: b_sum, n })
}

