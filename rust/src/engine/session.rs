//! `Session`: one training job running over a backend.
//!
//! This is Algorithm 1 at the logical-batch level, lifted off the raw PJRT
//! runtime and onto the [`StepRunner`] contract: Poisson-sample a logical
//! batch, stream it through the step in fixed-shape masked microbatches
//! (per-sample clipping happens inside the step; clipped sums accumulate
//! exactly across chunks), add Gaussian noise once, average by the expected
//! batch size, descend with the flat-vector optimizer, advance the RDP
//! accountant.  Two-phase X+BiTFiT jobs switch artifacts mid-run while the
//! accountant composes across the switch.
//!
//! Hot-path invariant: nothing parameter-sized is cloned per step.  The
//! frozen vector is pinned into the backend once per phase, the trainable
//! vector is one `Tensor` the optimizer updates in place, and the clip
//! radius is a prebuilt scalar — `run_step` hands the runner borrowed
//! inputs via `run_pinned` (backends that don't prefer pinning, i.e. PJRT's
//! literal path, still get owned clones).
//!
//! With `JobSpec::replicas > 1` the microbatch chunks are sharded over a
//! [`ReplicaGroup`] of data-parallel workers instead of looping locally;
//! the leader-side reduction replays the identical chunk-order float fold,
//! so the trajectory is bit-identical to the in-process path (and
//! `run_step` additionally reports the measured wire traffic).
//!
//! A session can be snapshotted mid-run ([`Session::save_state`]) and
//! resumed (`Engine::resume_session`) with bit-identical continuation: the
//! snapshot carries optimizer moments, RNG states and accountant orders.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use crate::coordinator::checkpoint::{Checkpoint, SessionState};
use crate::coordinator::distributed::{CommStats, ReplicaGroup};
use crate::coordinator::metrics::JsonlSink;
use crate::coordinator::optim::Optimizer;
use crate::coordinator::task_data::TaskData;
use crate::dp::fault::FaultMode;
use crate::dp::rdp::RdpAccountant;
use crate::dp::sampler::PoissonSampler;
use crate::runtime::{ArtifactMeta, Layout};
use crate::util::rng::ChaChaRng;
use crate::util::tensor::Tensor;
use crate::util::Timers;

use super::backend::{MultiTrainJob, Pinned, StepRunner};
use super::error::EngineError;
use super::spec::{JobSpec, PhaseSpec};

/// Engine-owned dedupe map for frozen parameter vectors, keyed by content
/// fingerprint: same-model sessions (and phases landing on identical
/// splits) share ONE immutable copy instead of each holding a
/// parameter-sized clone.  Entries live as long as the engine — frozen
/// state stays resident so later admissions keep hitting the share.
pub(crate) type FrozenCache = Rc<RefCell<HashMap<u64, Arc<Tensor>>>>;

/// FNV-1a over the f32 bit patterns (cheap, deterministic; collisions are
/// disambiguated by a full content compare before sharing).
fn frozen_fingerprint(data: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &v in data {
        for byte in v.to_bits().to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Per-step statistics.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub step: u64,
    pub loss: f64,
    pub batch: usize,
    pub grad_norm: f64,
    pub epsilon: f64,
    /// Measured replica traffic for this step (`None` in-process).
    pub comm: Option<CommStats>,
}

/// Privacy spent so far by a session.
#[derive(Debug, Clone, Copy)]
pub struct PrivacySpent {
    pub epsilon: f64,
    pub delta: f64,
    pub sigma: f64,
    pub q: f64,
    pub steps: u64,
}

/// Outcome of an evaluation pass.
///
/// For classifiers `metric_a` is summed loss and `metric_b` the correct
/// count; for LMs `metric_a` is summed NLL and `metric_b` the token count.
#[derive(Debug, Clone, Copy)]
pub struct EvalOutcome {
    pub metric_a: f64,
    pub metric_b: f64,
    pub n: usize,
}

impl EvalOutcome {
    /// Classification accuracy in [0, 1].
    pub fn accuracy(&self) -> f64 {
        self.metric_b / self.n.max(1) as f64
    }

    /// LM perplexity (`exp(nll / tokens)`).
    pub fn perplexity(&self) -> f64 {
        crate::nlg::perplexity(self.metric_a, self.metric_b)
    }
}

/// A sampled, filled logical batch mid-step: the output of
/// [`Session::prepare_step`], consumed by [`Session::finish_step`] after
/// every chunk's kernel outputs have been absorbed.
///
/// This is the chunk-granular decomposition of `run_step` that the serve
/// scheduler multiplexes on: chunks from different sessions are executed
/// (possibly coalesced into one multi-tenant sweep) between `prepare` and
/// `finish`, while all DP state transitions — noise, normalization,
/// optimizer, accountant — stay inside the owning session.
pub(crate) struct PreparedStep {
    pub(crate) chunks: Vec<(Tensor, Tensor, Tensor)>,
    /// Realized logical-batch size (`idxs.len()`, not the padded capacity).
    batch: usize,
    pub(crate) grad: Vec<f32>,
    pub(crate) loss_sum: f64,
    pub(crate) comm: Option<CommStats>,
}

impl PreparedStep {
    pub(crate) fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Fold one chunk's kernel outputs (loss scalar + clipped gradient
    /// sum) into the step — the identical chunk-order float fold
    /// `run_step` performs, so absorbing demuxed multi-tenant outputs in
    /// chunk order is bit-identical to the solo loop.
    pub(crate) fn absorb(&mut self, out: &[Tensor]) {
        self.loss_sum += out[0].item_f32() as f64;
        crate::util::tensor::axpy(&mut self.grad, 1.0, out[1].as_f32());
    }
}

/// One phase of a running session.
struct Phase {
    spec: PhaseSpec,
    runner: Rc<dyn StepRunner>,
    /// Data-parallel workers for this phase's artifact (`None` when
    /// `JobSpec::replicas == 1`).
    replicas: Option<ReplicaGroup>,
}

/// A training session handed out by [`super::Engine::session`].
pub struct Session {
    spec: JobSpec,
    phases: Vec<Phase>,
    active: usize,
    /// Steps remaining before the active phase ends.
    phase_left: u64,
    layout: Layout,
    /// Frozen parameters of the active phase, behind an `Arc`: host-pinning
    /// backends retain the same allocation (`pin_shared`), and same-model
    /// sessions assembled from one engine share ONE copy via the engine's
    /// [`FrozenCache`] — a BiTFiT session's marginal cost is bias state +
    /// optimizer + accountant, not a parameter-sized clone.
    frozen: Arc<Tensor>,
    /// Engine-owned frozen dedupe map (`None` for sessions assembled
    /// without an engine, e.g. directly in tests).
    frozen_cache: Option<FrozenCache>,
    /// Trainable parameters of the active phase, updated in place.
    train: Tensor,
    /// Prebuilt scalar clip-radius input (constant for the whole job).
    clip_r_t: Tensor,
    pinned_frozen: Option<Pinned>,
    optimizer: Optimizer,
    sampler: Option<PoissonSampler>,
    accountant: Option<RdpAccountant>,
    /// Traffic of replica groups already retired at phase switches.
    retired_comm: Option<CommStats>,
    /// `None` when the backend had no eval step for this model (training
    /// still works; `evaluate` reports the gap).
    eval_runner: Option<Rc<dyn StepRunner>>,
    sink: Option<JsonlSink>,
    noise_rng: ChaChaRng,
    data_rng: ChaChaRng,
    sigma: f64,
    q: f64,
    step: u64,
    /// Injected DP fault ([`FaultMode::None`] outside the audit harness);
    /// armed only through [`Session::set_fault`].
    fault: FaultMode,
    pub timers: Timers,
}

impl Session {
    /// Assemble a session (called by `Engine::session`).
    #[allow(clippy::too_many_arguments)]
    pub(super) fn assemble(
        spec: JobSpec,
        phases: Vec<(PhaseSpec, Rc<dyn StepRunner>, Option<ReplicaGroup>)>,
        eval_runner: Option<Rc<dyn StepRunner>>,
        layout: Layout,
        start_params: Vec<f32>,
        sigma: f64,
        sink: Option<JsonlSink>,
        frozen_cache: Option<FrozenCache>,
    ) -> Result<Session, EngineError> {
        if start_params.len() != layout.n_params {
            return Err(EngineError::Data(format!(
                "starting params have {} values, model {} has {}",
                start_params.len(),
                spec.model,
                layout.n_params
            )));
        }
        let phases: Vec<Phase> = phases
            .into_iter()
            .map(|(spec, runner, replicas)| Phase { spec, runner, replicas })
            .collect();
        let q = spec.q();
        let meta = phases[0].runner.meta().clone();
        let is_dp = meta.method.starts_with("dp-");
        let sampler = if is_dp {
            Some(PoissonSampler::new(spec.n_train, q, spec.seed ^ 0x5A17))
        } else {
            None
        };
        let accountant = if is_dp && sigma > 0.0 {
            Some(RdpAccountant::new(spec.privacy.delta()))
        } else {
            None
        };
        let mut session = Session {
            noise_rng: ChaChaRng::new(spec.seed, 0x4015E),
            data_rng: ChaChaRng::new(spec.seed, 0xDA7A),
            phase_left: phases[0].spec.steps,
            optimizer: Optimizer::new(spec.optim, phases[0].spec.lr, 0),
            active: 0,
            layout,
            frozen: Arc::new(Tensor::f32(vec![0], vec![])),
            frozen_cache,
            train: Tensor::f32(vec![0], vec![]),
            clip_r_t: Tensor::scalar_f32(spec.clip_r as f32),
            pinned_frozen: None,
            sampler,
            accountant,
            retired_comm: None,
            eval_runner,
            sink,
            sigma,
            q,
            step: 0,
            fault: FaultMode::None,
            timers: Timers::new(),
            phases,
            spec,
        };
        session.load_phase_params(&start_params)?;
        Ok(session)
    }

    /// Split `full` for the active phase's subset and (re)build the
    /// optimizer + pinned frozen input; with replicas, also broadcast the
    /// new frozen vector to the phase's workers (bootstrap traffic).
    fn load_phase_params(&mut self, full: &[f32]) -> Result<(), EngineError> {
        let phase = &self.phases[self.active];
        let meta = phase.runner.meta();
        let (pf, pt) = (meta.pf, meta.pt);
        let lr = phase.spec.lr;
        let (frozen, train) = self.layout.split(full, &meta.subset);
        if frozen.len() != pf || train.len() != pt {
            return Err(EngineError::Data(format!(
                "layout split ({}, {}) disagrees with artifact {} ({}, {})",
                frozen.len(),
                train.len(),
                meta.name,
                pf,
                pt
            )));
        }
        self.frozen = self.shared_frozen(Tensor::f32(vec![pf], frozen));
        self.train = Tensor::f32(vec![pt], train);
        // replicated phases train exclusively through the workers' own
        // pinned copies, so the leader skips its (otherwise unused) pin
        let replicated = self.phases[self.active].replicas.is_some();
        self.pinned_frozen = if !replicated && self.phases[self.active].runner.prefers_pinned() {
            // pin the shared Arc itself — host-pinning backends copy nothing
            Some(self.phases[self.active].runner.pin_shared(self.frozen.clone())?)
        } else {
            None
        };
        if let Some(group) = self.phases[self.active].replicas.as_mut() {
            group.broadcast_frozen(self.frozen.as_f32())?;
        }
        self.optimizer = Optimizer::new(self.spec.optim, lr, pt);
        Ok(())
    }

    /// Deduplicate a freshly split frozen vector through the engine's
    /// [`FrozenCache`]: on a fingerprint hit the content is compared in
    /// full, and only a true match shares the existing `Arc` (a collision
    /// falls back to a private copy — correctness never rides on the hash).
    fn shared_frozen(&self, t: Tensor) -> Arc<Tensor> {
        let Some(cache) = &self.frozen_cache else {
            return Arc::new(t);
        };
        let key = frozen_fingerprint(t.as_f32());
        let mut map = cache.borrow_mut();
        if let Some(existing) = map.get(&key) {
            if existing.shape == t.shape && existing.as_f32() == t.as_f32() {
                return existing.clone();
            }
            return Arc::new(t);
        }
        let arc = Arc::new(t);
        map.insert(key, arc.clone());
        arc
    }

    /// Retire one phase's replica workers (dropping the group joins its
    /// threads), folding their measured traffic into `retired_comm` so
    /// `comm_stats` stays complete.
    fn retire_replicas(&mut self, phase: usize) {
        if let Some(group) = self.phases[phase].replicas.take() {
            let s = group.stats();
            match &mut self.retired_comm {
                Some(t) => t.merge(&s),
                None => self.retired_comm = Some(s),
            }
        }
    }

    /// Advance to the next phase (two-phase jobs), carrying the accountant.
    /// The finished phase's replica workers are retired here.
    fn switch_phase(&mut self) -> Result<(), EngineError> {
        let full = self.full_params();
        self.retire_replicas(self.active);
        self.active += 1;
        self.phase_left = self.phases[self.active].spec.steps;
        self.load_phase_params(&full)
    }

    /// The active phase's step metadata.
    pub fn meta(&self) -> &ArtifactMeta {
        self.phases[self.active].runner.meta()
    }

    /// The job spec this session runs.
    pub fn spec(&self) -> &JobSpec {
        &self.spec
    }

    /// Label of the active phase (`"bitfit"`, `"full"`, ...).
    pub fn phase_label(&self) -> &'static str {
        self.phases[self.active].spec.label
    }

    /// Is this a DP run (noise + Poisson sampling + accounting)?
    pub fn is_dp(&self) -> bool {
        self.sampler.is_some()
    }

    /// Arm a deliberate DP fault (audit-harness mutation testing ONLY).
    ///
    /// The fault silently weakens the mechanism — skipped noise, disabled
    /// clipping, halved sigma — while the accountant keeps claiming the
    /// unbroken guarantee; `crate::audit` must detect the gap
    /// (`tests/privacy_audit.rs` asserts it does for every mode).  Never
    /// reachable from the environment in production: the `FASTDP_FAULT`
    /// knob is honored only by the audit harness and refused by the CLI
    /// (`dp::fault::refuse_outside_audit`).
    #[doc(hidden)]
    pub fn set_fault(&mut self, fault: FaultMode) {
        self.fault = fault;
        // SkipClip works by handing the kernels an inflated radius (the
        // Abadi min(R/norm, 1) factor becomes 1, i.e. no clipping); noise
        // and accounting keep the spec's radius, like a real bug would.
        self.clip_r_t = Tensor::scalar_f32(fault.effective_clip_r(self.spec.clip_r) as f32);
    }

    /// Steps taken so far.
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Trainable parameter count in the active phase.
    pub fn trainable_len(&self) -> usize {
        self.train.len()
    }

    /// Current merged full parameter vector.
    pub fn full_params(&self) -> Vec<f32> {
        self.layout.merge(self.frozen.as_f32(), self.train.as_f32(), &self.meta().subset)
    }

    /// Privacy spent so far.
    pub fn privacy_spent(&self) -> PrivacySpent {
        PrivacySpent {
            epsilon: self.accountant.as_ref().map(|a| a.epsilon().0).unwrap_or(0.0),
            delta: self.spec.privacy.delta(),
            sigma: self.sigma,
            q: self.q,
            steps: self.step,
        }
    }

    /// Epsilon the accountant would report after `extra_steps` more steps
    /// at this session's (q, sigma) — a clone-and-advance projection; the
    /// live accountant is untouched.  `0.0` for non-DP sessions.
    pub fn projected_epsilon(&self, extra_steps: u64) -> f64 {
        match &self.accountant {
            Some(acc) => {
                let mut a = acc.clone();
                for _ in 0..extra_steps {
                    a.step(self.q, self.sigma);
                }
                a.epsilon().0
            }
            None => 0.0,
        }
    }

    /// Approximate bytes of per-session mutable state: trainable params
    /// (f32) + optimizer moments (f64) + accountant orders (f64).  The
    /// frozen vector is EXCLUDED — it is shared (see [`FrozenCache`]) and
    /// reported separately by [`Session::frozen_bytes`].
    pub fn resident_bytes(&self) -> usize {
        let (_, m, v) = self.optimizer.state();
        self.train.len() * 4
            + (m.len() + v.len()) * 8
            + self.accountant.as_ref().map(|a| a.accumulated().len() * 8).unwrap_or(0)
    }

    /// Bytes of the (possibly shared) frozen parameter vector.
    pub fn frozen_bytes(&self) -> usize {
        self.frozen.len() * 4
    }

    /// Identity of the frozen allocation — equal for sessions sharing one
    /// copy (capacity reports count distinct values once).
    pub fn frozen_ptr(&self) -> usize {
        Arc::as_ptr(&self.frozen) as usize
    }

    fn sample_indices(&mut self) -> Vec<usize> {
        let n = self.spec.n_train;
        if let Some(s) = &mut self.sampler {
            s.sample()
        } else {
            // non-private: fixed-size uniform sample without replacement
            let mut idxs: Vec<usize> = (0..n).collect();
            self.data_rng.shuffle(&mut idxs);
            idxs.truncate(self.spec.logical_batch.min(n));
            idxs
        }
    }

    /// One logical-batch training step: prepare (sample + fill), execute
    /// every chunk, finish (noise + normalize + descend + account).
    pub fn run_step(&mut self, data: &TaskData) -> Result<StepStats, EngineError> {
        let mut prep = self.prepare_step(data)?;
        if self.phases[self.active].replicas.is_some() {
            // data-parallel: ship contiguous chunk runs to the replica
            // workers, reduce their clipped gradient sums in fixed replica
            // order — the identical chunk-order float fold the in-process
            // loop below performs, so the trajectory is bit-identical for
            // any replica count
            let t2 = std::time::Instant::now();
            let clip_r = self.clip_r_t.item_f32();
            let chunks = std::mem::take(&mut prep.chunks);
            let group = self.phases[self.active].replicas.as_mut().expect("checked above");
            let (replica_loss, stats) =
                group.run_batch(self.train.as_f32(), clip_r, chunks, &mut prep.grad)?;
            prep.loss_sum = replica_loss;
            prep.comm = Some(stats);
            self.timers.add("execute", t2.elapsed().as_secs_f64());
        } else {
            let t2 = std::time::Instant::now();
            for i in 0..prep.n_chunks() {
                let out = {
                    let (x, y, mask) = &prep.chunks[i];
                    self.run_chunk(x, y, mask)?
                };
                prep.absorb(&out);
            }
            self.timers.add("execute", t2.elapsed().as_secs_f64());
        }
        self.finish_step(prep)
    }

    /// Phase 1 of a step: validate, switch phase if due, Poisson-sample
    /// the logical batch and fill every fixed-shape masked microbatch
    /// chunk.  Filling is a pure function of the sampled indices, so
    /// pre-filling all chunks (rather than interleaving with execution)
    /// changes no bits.
    pub(crate) fn prepare_step(&mut self, data: &TaskData) -> Result<PreparedStep, EngineError> {
        if data.len() != self.spec.n_train {
            return Err(EngineError::Data(format!(
                "dataset has {} examples but the spec says n_train = {}",
                data.len(),
                self.spec.n_train
            )));
        }
        if self.phase_left == 0 && self.active + 1 < self.phases.len() {
            self.switch_phase()?;
        }
        let t0 = std::time::Instant::now();
        let idxs = self.sample_indices();
        self.timers.add("sample", t0.elapsed().as_secs_f64());
        let meta = self.phases[self.active].runner.meta();
        let (b, pt) = (meta.batch, meta.pt);
        let t1 = std::time::Instant::now();
        let chunks: Vec<(Tensor, Tensor, Tensor)> =
            idxs.chunks(b).map(|chunk| data.fill(chunk, b)).collect();
        self.timers.add("fill", t1.elapsed().as_secs_f64());
        Ok(PreparedStep {
            chunks,
            batch: idxs.len(),
            grad: vec![0.0f32; pt],
            loss_sum: 0.0,
            comm: None,
        })
    }

    /// Execute one prepared chunk through the active runner (pinned path:
    /// every input borrowed — no parameter-sized clones in steady state).
    pub(crate) fn run_chunk(
        &self,
        x: &Tensor,
        y: &Tensor,
        mask: &Tensor,
    ) -> Result<Vec<Tensor>, EngineError> {
        let runner = &self.phases[self.active].runner;
        match &self.pinned_frozen {
            Some(pinned) => runner.run_pinned(
                &[pinned],
                &[
                    None,
                    Some(&self.train),
                    Some(x),
                    Some(y),
                    Some(mask),
                    Some(&self.clip_r_t),
                ],
            ),
            None => runner.run(&[
                (*self.frozen).clone(),
                self.train.clone(),
                x.clone(),
                y.clone(),
                mask.clone(),
                self.clip_r_t.clone(),
            ]),
        }
    }

    /// The active runner (serve scheduler: coalesced-sweep dispatch).
    pub(crate) fn runner(&self) -> Rc<dyn StepRunner> {
        self.phases[self.active].runner.clone()
    }

    /// Is the active phase replicated?  (The serve scheduler refuses such
    /// sessions; their chunks are owned by the replica group.)
    pub(crate) fn has_replicas(&self) -> bool {
        self.phases[self.active].replicas.is_some()
    }

    /// This session's slice of a multi-tenant coalesced sweep for one
    /// prepared chunk.  `None` when the frozen vector is not pinned (the
    /// coalesced path requires the pinned steady state).
    pub(crate) fn multi_inputs<'a>(
        &'a self,
        chunk: &'a (Tensor, Tensor, Tensor),
    ) -> Option<MultiTrainJob<'a>> {
        let pinned = self.pinned_frozen.as_ref()?;
        Some(MultiTrainJob {
            frozen: pinned,
            train: &self.train,
            x: &chunk.0,
            y: &chunk.1,
            mask: &chunk.2,
            clip_r: &self.clip_r_t,
        })
    }

    /// Phase 3 of a step: noise once, normalize, descend, account, log.
    /// Consumes the prepared step after all its chunks were absorbed.
    pub(crate) fn finish_step(&mut self, prep: PreparedStep) -> Result<StepStats, EngineError> {
        let PreparedStep { batch, mut grad, loss_sum, comm, .. } = prep;
        let denom = if self.is_dp() {
            // fixed normalization by the expected batch (standard DP-SGD)
            self.spec.logical_batch as f64
        } else {
            batch.max(1) as f64
        };
        if self.is_dp() && self.sigma > 0.0 && self.fault != FaultMode::SkipNoise {
            // an armed fault may weaken sigma here; the accountant below
            // still records the full spec sigma (the injected bug)
            crate::dp::add_gaussian_noise(
                &mut grad,
                self.fault.effective_sigma(self.sigma),
                self.spec.clip_r,
                &mut self.noise_rng,
            );
        }
        for g in grad.iter_mut() {
            *g /= denom as f32;
        }
        let grad_norm = crate::util::tensor::l2_norm(&grad);
        let lr_base = self.phases[self.active].spec.lr;
        let lr = self.spec.schedule.at(lr_base, self.step);
        self.optimizer.step_lr(self.train.as_f32_mut(), &grad, lr);
        if let Some(acc) = &mut self.accountant {
            acc.step(self.q, self.sigma);
        }
        self.step += 1;
        self.phase_left = self.phase_left.saturating_sub(1);
        let stats = StepStats {
            step: self.step,
            loss: loss_sum / batch.max(1) as f64,
            batch,
            grad_norm,
            epsilon: self.accountant.as_ref().map(|a| a.epsilon().0).unwrap_or(0.0),
            comm,
        };
        if let Some(sink) = &mut self.sink {
            sink.step(stats.step, stats.loss, stats.epsilon)
                .map_err(|e| EngineError::Metrics(format!("{e:#}")))?;
        }
        Ok(stats)
    }

    /// Evaluate the current parameters over (up to) `max_examples`.
    pub fn evaluate(
        &self,
        data: &TaskData,
        max_examples: usize,
    ) -> Result<EvalOutcome, EngineError> {
        let eval = self.eval_runner.as_ref().ok_or_else(|| EngineError::UnknownArtifact {
            name: format!("{}__eval", self.spec.model),
            detail: "the backend could not load the eval step when this session was created"
                .to_string(),
        })?;
        evaluate_params(eval.as_ref(), &self.full_params(), data, max_examples)
    }

    /// Write a CRC-protected checkpoint of the current full parameters.
    pub fn checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<(), EngineError> {
        Checkpoint {
            model: self.meta().model.clone(),
            step: self.step,
            params: self.full_params(),
        }
        .save(path)
        .map_err(|e| EngineError::Checkpoint(format!("{e:#}")))
    }

    /// Cumulative measured replica traffic across all phases (`None` for
    /// in-process sessions; see [`CommStats`]).
    pub fn comm_stats(&self) -> Option<CommStats> {
        let mut total: Option<CommStats> = self.retired_comm;
        for p in &self.phases {
            if let Some(g) = &p.replicas {
                let s = g.stats();
                match &mut total {
                    Some(t) => t.merge(&s),
                    None => total = Some(s),
                }
            }
        }
        total
    }

    /// Write a complete mid-run snapshot: parameters plus phase position,
    /// optimizer moments, RNG states and accountant orders.  A session
    /// resumed from it (`Engine::resume_session`) continues the run
    /// **bit-identically** — same Poisson draws, same noise, same updates —
    /// as if it had never stopped.
    pub fn save_state(&self, path: impl AsRef<std::path::Path>) -> Result<(), EngineError> {
        let (optim_t, m, v) = self.optimizer.state();
        SessionState {
            model: self.meta().model.clone(),
            step: self.step,
            active_phase: self.active as u32,
            phase_left: self.phase_left,
            params: self.full_params(),
            optim_t,
            optim_m: m.to_vec(),
            optim_v: v.to_vec(),
            noise_rng: self.noise_rng.state(),
            data_rng: self.data_rng.state(),
            sampler_rng: self.sampler.as_ref().map(|s| s.rng_state()),
            rdp_acc: self
                .accountant
                .as_ref()
                .map(|a| a.accumulated().to_vec())
                .unwrap_or_default(),
        }
        .save(path)
        .map_err(|e| EngineError::Checkpoint(format!("{e:#}")))
    }

    /// Overwrite this freshly-assembled session with a saved snapshot.
    ///
    /// Precondition (upheld by `Engine::resume_session`, the only caller):
    /// the session was just assembled from `st.params`, so phase 0's
    /// parameter split — and, for replicated jobs, its one frozen
    /// broadcast — already match the snapshot; reloading is only needed
    /// when the snapshot sits in a later phase.
    pub(super) fn restore_state(&mut self, st: &SessionState) -> Result<(), EngineError> {
        let target = st.active_phase as usize;
        if target >= self.phases.len() {
            return Err(EngineError::Checkpoint(format!(
                "state is in phase {} but the job has {} phases (spec mismatch?)",
                st.active_phase,
                self.phases.len()
            )));
        }
        self.phase_left = st.phase_left;
        self.step = st.step;
        if self.active != target {
            // skipped phases never run: retire their replica workers
            for i in self.active..target {
                self.retire_replicas(i);
            }
            self.active = target;
            self.load_phase_params(&st.params)?;
        }
        self.optimizer
            .restore(st.optim_t, st.optim_m.clone(), st.optim_v.clone())
            .map_err(EngineError::Checkpoint)?;
        self.noise_rng = ChaChaRng::from_state(&st.noise_rng);
        self.data_rng = ChaChaRng::from_state(&st.data_rng);
        match (&mut self.sampler, &st.sampler_rng) {
            (Some(s), Some(words)) => s.restore_rng(words),
            (None, None) => {}
            _ => {
                return Err(EngineError::Checkpoint(
                    "session and saved state disagree about Poisson sampling \
                     (was the spec's privacy budget changed?)"
                        .to_string(),
                ));
            }
        }
        match (&mut self.accountant, st.rdp_acc.is_empty()) {
            (Some(a), false) => a.restore(&st.rdp_acc).map_err(EngineError::Checkpoint)?,
            (None, true) => {}
            _ => {
                return Err(EngineError::Checkpoint(
                    "session and saved state disagree about RDP accounting \
                     (was the spec's privacy budget changed?)"
                        .to_string(),
                ));
            }
        }
        Ok(())
    }
}

/// Evaluate a full parameter vector with an eval step runner.
pub fn evaluate_params(
    eval: &dyn StepRunner,
    full: &[f32],
    data: &TaskData,
    max_examples: usize,
) -> Result<EvalOutcome, EngineError> {
    let meta = eval.meta();
    if meta.step != "eval" {
        return Err(EngineError::Data(format!("{} is not an eval artifact", meta.name)));
    }
    let b = meta.batch;
    let n = data.len().min(max_examples);
    let full_t = Tensor::f32(vec![full.len()], full.to_vec());
    let empty = Tensor::f32(vec![0], vec![]);
    // pin the (large, unchanging) parameter vector once; backends that
    // prefer the pinned path then borrow it per chunk instead of cloning
    let pinned = if eval.prefers_pinned() { Some(eval.pin(&full_t)?) } else { None };
    let (mut a_sum, mut b_sum) = (0.0f64, 0.0f64);
    let idxs: Vec<usize> = (0..n).collect();
    for chunk in idxs.chunks(b) {
        let (x, y, mask) = data.fill(chunk, b);
        let out = match &pinned {
            Some(p) => eval.run_pinned(
                &[p],
                &[Some(&empty), None, Some(&x), Some(&y), Some(&mask)],
            )?,
            None => eval.run(&[empty.clone(), full_t.clone(), x, y, mask])?,
        };
        a_sum += out[0].item_f32() as f64;
        b_sum += out[1].item_f32() as f64;
    }
    Ok(EvalOutcome { metric_a: a_sum, metric_b: b_sum, n })
}

