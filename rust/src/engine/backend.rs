//! The pluggable execution backend: the contract between the engine and
//! whatever actually runs model steps.
//!
//! Two implementations ship with the crate:
//! * [`super::pjrt::PjrtBackend`] — the AOT HLO artifacts executed via PJRT
//!   (the fast path; requires a compiled artifact directory).
//! * [`super::interp::InterpreterBackend`] — a dependency-free pure-Rust
//!   reference implementation of the same step contract, so the full
//!   train/checkpoint/eval path runs (and is testable in CI) with no
//!   artifact directory present.
//!
//! A backend hands out [`StepRunner`]s keyed by artifact name
//! (`<model>__<method>[__<clipmode>]`); the runner's [`ArtifactMeta`]
//! describes its fixed-shape I/O contract.  Device residency is exposed via
//! [`StepRunner::pin`] / [`StepRunner::run_pinned`], so inputs that do not
//! change between steps (the frozen parameter vector) can stay resident.

use std::path::PathBuf;
use std::rc::Rc;

use crate::coordinator::distributed::ReplicaGroup;
use crate::coordinator::transport::TransportOpts;
use crate::coordinator::workloads::ModelShape;
use crate::runtime::{ArtifactMeta, Layout};
use crate::util::tensor::Tensor;

use super::error::EngineError;

/// Everything the engine needs to know about a model.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    /// Dataset-relevant dimensions (kind, t, vocab, img, n_cls, n_out).
    pub shape: ModelShape,
    pub n_params: usize,
    /// Hidden width (analytic memory/throughput models).
    pub d: usize,
    /// Layer count (analytic memory/throughput models).
    pub layers: usize,
    /// ViT patch size (0 when the model has no patch structure).
    pub patch: usize,
}

/// An input pinned for reuse across step executions (device-resident under
/// PJRT, host-retained under the interpreter).
///
/// The host variant holds an `Arc` so one immutable copy (the frozen
/// parameter vector) can be shared by every session of the same model —
/// pinning a tensor that is already behind an `Arc`
/// ([`StepRunner::pin_shared`]) copies nothing.
pub enum Pinned {
    Device(crate::runtime::DeviceInput),
    Host(std::sync::Arc<Tensor>),
}

/// One tenant's microbatch in a coalesced multi-job train sweep
/// ([`StepRunner::run_multi`]): the same six-slot input layout as
/// `run`/`run_pinned`, with the frozen vector supplied pinned.
pub struct MultiTrainJob<'a> {
    pub frozen: &'a Pinned,
    pub train: &'a Tensor,
    pub x: &'a Tensor,
    pub y: &'a Tensor,
    pub mask: &'a Tensor,
    pub clip_r: &'a Tensor,
}

/// A loaded, executable step (train / eval / decode).
pub trait StepRunner {
    /// The step's I/O contract and provenance.
    fn meta(&self) -> &ArtifactMeta;

    /// Execute with host tensors (one fixed-shape microbatch).
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, EngineError>;

    /// Pin one input for reuse across steps (device residency hook).
    fn pin(&self, t: &Tensor) -> Result<Pinned, EngineError>;

    /// Pin a tensor that is already shared behind an `Arc`.  Host-pinning
    /// backends retain the `Arc` itself (zero copy; N same-model sessions
    /// share ONE frozen vector); the default forwards to [`Self::pin`]
    /// for backends that must upload (PJRT).
    fn pin_shared(&self, t: std::sync::Arc<Tensor>) -> Result<Pinned, EngineError> {
        self.pin(&t)
    }

    /// Execute with a mix of pinned and host inputs; `host[i]` slots that are
    /// `None` are taken from `pinned` in order.
    fn run_pinned(
        &self,
        pinned: &[&Pinned],
        host: &[Option<&Tensor>],
    ) -> Result<Vec<Tensor>, EngineError>;

    /// Whether the pinned path is the preferred steady-state path.  (The
    /// PJRT buffer path trips an xla_extension 0.5.1 assertion in some
    /// interleavings, so it stays opt-in there; the interpreter always
    /// prefers it.)
    fn prefers_pinned(&self) -> bool {
        false
    }

    /// Coalesce several **same-artifact** train microbatches — one per
    /// tenant — into a single panel sweep, amortizing worker dispatch and
    /// weight-panel traffic across tenants the way the blocked tier
    /// amortizes it across rows.
    ///
    /// Contract: `out[j]` is **bit-identical** to what
    /// `run_pinned(&[jobs[j].frozen], ...)` would return for job `j` alone
    /// — each job keeps its own parameters, block partitioning and
    /// fixed-order reduction; only the worker dispatch is shared.
    ///
    /// `None` means this runner has no coalesced path (non-panel kernel
    /// tiers, PJRT) and the caller must fall back to per-job execution.
    fn run_multi(
        &self,
        _jobs: &[MultiTrainJob<'_>],
    ) -> Option<Result<Vec<Vec<Tensor>>, EngineError>> {
        None
    }
}

/// A pluggable execution backend.
pub trait Backend {
    /// Short backend identifier (`"pjrt"` / `"interpreter"`).
    fn name(&self) -> &'static str;

    /// Human-readable platform description.
    fn platform(&self) -> String;

    /// Models this backend can serve.
    fn models(&self) -> Vec<String>;

    /// Step artifacts this backend can serve.
    fn artifacts(&self) -> Vec<String>;

    fn model_info(&self, model: &str) -> Result<ModelInfo, EngineError>;

    /// The flat-parameter layout contract for a model.
    fn layout(&self, model: &str) -> Result<Layout, EngineError>;

    /// The model's deterministic initial parameter vector.
    fn init_params(&self, model: &str) -> Result<Vec<f32>, EngineError>;

    /// Artifact metadata without loading/compiling the step.
    fn artifact_meta(&self, artifact: &str) -> Result<ArtifactMeta, EngineError>;

    /// Load (and cache) an executable step by artifact name.
    fn load(&mut self, artifact: &str) -> Result<Rc<dyn StepRunner>, EngineError>;

    /// Directory for cached derived state (pretrained checkpoints);
    /// `None` when the backend has no on-disk home (interpreter).
    fn cache_dir(&self) -> Option<PathBuf> {
        None
    }

    /// Spawn an `n`-worker data-parallel [`ReplicaGroup`] executing a train
    /// artifact, each replica on its own thread with its own step instance
    /// (see `coordinator::distributed` for the bit-identical aggregation
    /// contract), exchanging traffic over the job's transport/codec
    /// configuration (`opts`).  `None` means the backend cannot replicate —
    /// the default, and PJRT's answer: its device buffers are not
    /// thread-shardable here.
    fn replica_group(
        &self,
        _artifact: &str,
        _n: usize,
        _opts: &TransportOpts,
    ) -> Option<Result<ReplicaGroup, EngineError>> {
        None
    }
}

/// Validate host inputs against a step's input specs (shape check).
pub fn check_inputs(meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<(), EngineError> {
    let refs: Vec<&Tensor> = inputs.iter().collect();
    check_input_refs(meta, &refs)
}

/// Validate borrowed host inputs against a step's input specs (shape
/// check).  The borrowing form lets zero-copy step paths (pinned inputs,
/// cached session tensors) validate without cloning.
pub fn check_input_refs(meta: &ArtifactMeta, inputs: &[&Tensor]) -> Result<(), EngineError> {
    if inputs.len() != meta.inputs.len() {
        return Err(EngineError::Data(format!(
            "artifact {} expects {} inputs, got {}",
            meta.name,
            meta.inputs.len(),
            inputs.len()
        )));
    }
    for (t, spec) in inputs.iter().zip(&meta.inputs) {
        if t.shape != spec.shape {
            return Err(EngineError::Data(format!(
                "input {} of {}: shape {:?} != expected {:?}",
                spec.name, meta.name, t.shape, spec.shape
            )));
        }
    }
    Ok(())
}
