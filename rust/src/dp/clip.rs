//! Per-sample gradient clipping functions (host-side reference).
//!
//! The clipping itself runs inside the AOT artifacts (L2/L1); these
//! implementations mirror `python/compile/kernels/ref.py::clip_factors` and
//! are used by L3 for verification, tests and the host-side (small-vector)
//! paths.

/// Which clipping function to use (paper Table 12 compares them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClipMode {
    /// Abadi et al. 2016: `min(R / ||g||, 1)`.
    Abadi,
    /// AUTO-S (Bu et al. 2022b): `R / (||g|| + 0.01)`.
    AutoS,
}

impl ClipMode {
    pub fn parse(s: &str) -> Option<ClipMode> {
        match s {
            "abadi" => Some(ClipMode::Abadi),
            "autos" => Some(ClipMode::AutoS),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ClipMode::Abadi => "abadi",
            ClipMode::AutoS => "autos",
        }
    }
}

/// The AUTO-S stabilizer gamma.
pub const AUTO_S_STABILIZER: f64 = 0.01;

/// Per-sample clip factor C_i from a squared gradient norm.
// fastdp-lint: clip-boundary
pub fn clip_factor(sq_norm: f64, r: f64, mode: ClipMode) -> f64 {
    let norm = sq_norm.max(0.0).sqrt();
    match mode {
        ClipMode::Abadi => (r / norm.max(1e-12)).min(1.0),
        ClipMode::AutoS => r / (norm + AUTO_S_STABILIZER),
    }
}

/// Clip a gradient vector in place; returns the factor applied.
// fastdp-lint: clip-boundary
pub fn clip_in_place(g: &mut [f32], r: f64, mode: ClipMode) -> f64 {
    let sq: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let c = clip_factor(sq, r, mode);
    for x in g.iter_mut() {
        *x = (*x as f64 * c) as f32;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abadi_caps_at_one() {
        assert_eq!(clip_factor(0.25, 1.0, ClipMode::Abadi), 1.0); // norm 0.5 < R
        assert!((clip_factor(4.0, 1.0, ClipMode::Abadi) - 0.5).abs() < 1e-12); // norm 2
    }

    #[test]
    fn autos_never_exceeds_sensitivity() {
        // AUTO-S guarantees ||C_i g_i|| <= R for any norm
        for &sq in &[1e-8, 0.01, 1.0, 100.0, 1e6] {
            let c = clip_factor(sq, 1.0, ClipMode::AutoS);
            assert!(c * sq.sqrt() <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn clip_in_place_bounds_norm() {
        let mut g = vec![3.0f32, 4.0]; // norm 5
        let c = clip_in_place(&mut g, 1.0, ClipMode::Abadi);
        assert!((c - 0.2).abs() < 1e-9);
        let n: f64 = g.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!((n - 1.0).abs() < 1e-6);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(ClipMode::parse("abadi"), Some(ClipMode::Abadi));
        assert_eq!(ClipMode::parse("autos"), Some(ClipMode::AutoS));
        assert_eq!(ClipMode::parse("x"), None);
        assert_eq!(ClipMode::AutoS.name(), "autos");
    }
}
