//! Test-only DP fault injection: the mutations the privacy auditor must
//! catch.
//!
//! An empirical audit ([`crate::audit`]) is only trustworthy if it can
//! *fail*: each [`FaultMode`] silently breaks one link of the DP mechanism
//! (skip the Gaussian noise, skip per-sample clipping, halve sigma) while
//! the accountant keeps claiming the unbroken guarantee — exactly the bug
//! class no unit test on the accountant's math can see.  The audit
//! mutation tests (`tests/privacy_audit.rs`) arm each mode and assert the
//! empirical epsilon blows past the claim.
//!
//! Faults are armed **programmatically** through the hidden
//! `Session::set_fault` hook; the `FASTDP_FAULT` environment knob is read
//! only by the audit harness ([`from_env`], used by
//! `benches/privacy_audit.rs` for manual fault experiments).  Production
//! entry points refuse the knob loudly ([`refuse_outside_audit`]): a
//! deployed training run can never have its mechanism silently weakened
//! from the environment.

use crate::runtime::env;

/// A deliberate break of the DP mechanism (mutation under audit).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultMode {
    /// No fault: the mechanism runs as specified.
    #[default]
    None,
    /// Silently skip the Gaussian noise addition (Alg. 1 line 10 removed);
    /// the accountant still records the full sigma.
    SkipNoise,
    /// Silently disable per-sample clipping by inflating the clip radius
    /// handed to the kernels by 1e6 (Abadi clipping then scales by ~1, i.e.
    /// gradients pass through unclipped); noise and accounting still use
    /// the spec's radius.
    SkipClip,
    /// Silently halve the noise multiplier actually applied; the
    /// accountant still records the full sigma.
    HalfSigma,
}

impl FaultMode {
    /// Parse a `FASTDP_FAULT` value (`none|skip-noise|skip-clip|half-sigma`).
    pub fn parse(s: &str) -> Option<FaultMode> {
        match s {
            "none" => Some(FaultMode::None),
            "skip-noise" => Some(FaultMode::SkipNoise),
            "skip-clip" => Some(FaultMode::SkipClip),
            "half-sigma" => Some(FaultMode::HalfSigma),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultMode::None => "none",
            FaultMode::SkipNoise => "skip-noise",
            FaultMode::SkipClip => "skip-clip",
            FaultMode::HalfSigma => "half-sigma",
        }
    }

    /// The noise multiplier actually applied under this fault.
    pub fn effective_sigma(&self, sigma: f64) -> f64 {
        match self {
            FaultMode::SkipNoise => 0.0,
            FaultMode::HalfSigma => 0.5 * sigma,
            _ => sigma,
        }
    }

    /// The clip radius handed to the kernels under this fault.
    pub fn effective_clip_r(&self, clip_r: f64) -> f64 {
        match self {
            // large enough that Abadi's min(R/norm, 1) factor is always 1
            FaultMode::SkipClip => clip_r * 1e6,
            _ => clip_r,
        }
    }

    /// Every injectable fault (the audit mutation-test matrix).
    pub fn all_faults() -> [FaultMode; 3] {
        [FaultMode::SkipNoise, FaultMode::SkipClip, FaultMode::HalfSigma]
    }
}

/// Read `FASTDP_FAULT` for the audit harness, warn-once on an invalid
/// value (falls back to no fault).  Only the audit harness may honor the
/// result; see [`refuse_outside_audit`].
pub fn from_env() -> FaultMode {
    match env::fault() {
        None => FaultMode::None,
        Some(s) => match FaultMode::parse(s.trim()) {
            Some(m) => m,
            None => {
                env::warn_invalid(&env::FAULT, &s);
                FaultMode::None
            }
        },
    }
}

/// Production refusal: warn (once, via the registry's warn path) and
/// report whether the knob was set.  Called by non-audit entry points
/// (the CLI) so a stray `FASTDP_FAULT` in the environment is loud and
/// inert instead of silently weakening the mechanism.
pub fn refuse_outside_audit() -> bool {
    if env::fault().is_some() {
        eprintln!(
            "fastdp: FASTDP_FAULT is refused outside the audit harness \
             (benches/privacy_audit.rs, tests); ignoring"
        );
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in [
            FaultMode::None,
            FaultMode::SkipNoise,
            FaultMode::SkipClip,
            FaultMode::HalfSigma,
        ] {
            assert_eq!(FaultMode::parse(m.name()), Some(m));
        }
        assert_eq!(FaultMode::parse("banana"), None);
    }

    #[test]
    fn effective_values() {
        assert_eq!(FaultMode::None.effective_sigma(2.0), 2.0);
        assert_eq!(FaultMode::SkipNoise.effective_sigma(2.0), 0.0);
        assert_eq!(FaultMode::HalfSigma.effective_sigma(2.0), 1.0);
        assert_eq!(FaultMode::SkipClip.effective_sigma(2.0), 2.0);
        assert_eq!(FaultMode::None.effective_clip_r(0.1), 0.1);
        assert!(FaultMode::SkipClip.effective_clip_r(0.1) > 1e4);
    }
}
