//! Differential-privacy substrate: accountants, calibration, clipping,
//! noise and Poisson subsampling (everything Algorithm 1 needs outside the
//! per-sample-gradient computation, which lives in the AOT artifacts).

pub mod calibrate;
pub mod clip;
pub mod fault;
pub mod gdp;
pub mod rdp;
pub mod sampler;

use crate::util::rng::ChaChaRng;

/// Add sigma * R * N(0, I) to an aggregated clipped gradient (Alg. 1 line 10).
///
/// Called ONCE per logical Poisson batch by the coordinator (microbatches
/// accumulate clipped sums first; noise composes per logical batch).
// fastdp-lint: noise-site
pub fn add_gaussian_noise(grad: &mut [f32], sigma: f64, clip_r: f64, rng: &mut ChaChaRng) {
    if sigma == 0.0 {
        return;
    }
    let s = sigma * clip_r;
    for g in grad.iter_mut() {
        *g += (rng.gaussian() * s) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_has_requested_scale() {
        let mut rng = ChaChaRng::new(0, 1);
        let n = 100_000;
        let mut g = vec![0.0f32; n];
        add_gaussian_noise(&mut g, 2.0, 0.5, &mut rng); // stddev 1.0
        let mean: f64 = g.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 = g.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sigma_zero_is_identity() {
        let mut rng = ChaChaRng::new(0, 1);
        let mut g = vec![1.5f32; 8];
        add_gaussian_noise(&mut g, 0.0, 1.0, &mut rng);
        assert_eq!(g, vec![1.5f32; 8]);
    }
}
