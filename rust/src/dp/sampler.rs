//! Poisson subsampling (Algorithm 1, line 2).
//!
//! Each example independently joins the batch with probability `q`; the
//! privacy amplification analysis of the RDP accountant assumes exactly
//! this sampler (not shuffling!), so the trainer uses it for all DP runs.

use crate::util::rng::ChaChaRng;

/// Poisson sampler over dataset indices `0..n`.
pub struct PoissonSampler {
    pub n: usize,
    pub q: f64,
    rng: ChaChaRng,
}

impl PoissonSampler {
    pub fn new(n: usize, q: f64, seed: u64) -> PoissonSampler {
        assert!((0.0..=1.0).contains(&q), "q in [0,1]");
        PoissonSampler { n, q, rng: ChaChaRng::new(seed, 0xB10B) }
    }

    /// One logical batch: every index independently with probability q.
    pub fn sample(&mut self) -> Vec<usize> {
        let mut out = Vec::with_capacity((self.n as f64 * self.q * 1.5) as usize + 4);
        for i in 0..self.n {
            if self.rng.uniform() < self.q {
                out.push(i);
            }
        }
        out
    }

    /// Expected logical batch size.
    pub fn expected_batch(&self) -> f64 {
        self.n as f64 * self.q
    }

    /// Snapshot the sampler's RNG (session-state checkpoints).
    pub fn rng_state(&self) -> [u32; crate::util::rng::RNG_STATE_WORDS] {
        self.rng.state()
    }

    /// Restore the sampler's RNG from a [`PoissonSampler::rng_state`]
    /// snapshot; subsequent draws continue the saved sequence exactly.
    pub fn restore_rng(&mut self, state: &[u32; crate::util::rng::RNG_STATE_WORDS]) {
        self.rng = ChaChaRng::from_state(state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_size_concentrates() {
        let mut s = PoissonSampler::new(10_000, 0.05, 7);
        let mut total = 0usize;
        let rounds = 50;
        for _ in 0..rounds {
            let b = s.sample();
            total += b.len();
            // indices sorted unique in range
            assert!(b.windows(2).all(|w| w[0] < w[1]));
            assert!(b.iter().all(|&i| i < 10_000));
        }
        let mean = total as f64 / rounds as f64;
        let expect = s.expected_batch();
        assert!((mean - expect).abs() < expect * 0.1, "mean {mean} vs {expect}");
    }

    #[test]
    fn q_zero_and_one() {
        let mut s0 = PoissonSampler::new(100, 0.0, 1);
        assert!(s0.sample().is_empty());
        let mut s1 = PoissonSampler::new(100, 1.0, 1);
        assert_eq!(s1.sample().len(), 100);
    }

    #[test]
    fn rng_state_roundtrip_resumes_draws() {
        let mut a = PoissonSampler::new(500, 0.1, 21);
        a.sample();
        let snap = a.rng_state();
        let want: Vec<Vec<usize>> = (0..5).map(|_| a.sample()).collect();
        let mut b = PoissonSampler::new(500, 0.1, 21);
        b.restore_rng(&snap);
        let got: Vec<Vec<usize>> = (0..5).map(|_| b.sample()).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn deterministic_under_seed() {
        let mut a = PoissonSampler::new(1000, 0.1, 42);
        let mut b = PoissonSampler::new(1000, 0.1, 42);
        assert_eq!(a.sample(), b.sample());
        let mut c = PoissonSampler::new(1000, 0.1, 43);
        assert_ne!(a.sample(), c.sample());
    }
}
