//! Noise calibration: find the smallest sigma meeting a target (eps, delta).
//!
//! The paper's experiments fix (eps, delta, epochs, batch size) and derive
//! sigma; this module inverts the RDP accountant by bisection.  The result
//! is conservative (epsilon(sigma) <= target within tolerance).

use super::rdp;

/// Smallest noise multiplier sigma such that `steps` DP-SGD steps at
/// sampling rate `q` spend at most `target_eps` at `delta`.
pub fn calibrate_sigma(q: f64, steps: u64, target_eps: f64, delta: f64) -> f64 {
    assert!(target_eps > 0.0);
    if q == 0.0 {
        return 0.0;
    }
    let eps = |sigma: f64| rdp::epsilon(q, sigma, steps, delta);
    let (mut lo, mut hi) = (0.1f64, 2.0f64);
    // grow hi until private enough; shrink lo until not
    while eps(hi) > target_eps {
        hi *= 2.0;
        assert!(hi < 1e4, "cannot reach eps={target_eps} (q={q}, T={steps})");
    }
    while eps(lo) < target_eps && lo > 1e-3 {
        lo /= 2.0;
    }
    for _ in 0..80 {
        let mid = 0.5 * (lo + hi);
        if eps(mid) > target_eps {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    hi
}

/// Training-run privacy plan: sampling rate, steps, sigma and the epsilon
/// actually spent (<= target).
#[derive(Debug, Clone)]
pub struct PrivacyPlan {
    pub q: f64,
    pub steps: u64,
    pub sigma: f64,
    pub delta: f64,
    pub target_eps: f64,
    pub spent_eps: f64,
}

/// Build a plan from dataset size, logical batch size, epochs and (eps, delta).
pub fn plan(n: usize, batch: usize, epochs: f64, target_eps: f64, delta: f64) -> PrivacyPlan {
    let q = (batch as f64 / n as f64).min(1.0);
    let steps = ((epochs * n as f64) / batch as f64).ceil() as u64;
    let sigma = calibrate_sigma(q, steps, target_eps, delta);
    let spent = rdp::epsilon(q, sigma, steps, delta);
    PrivacyPlan { q, steps, sigma, delta, target_eps, spent_eps: spent }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_meets_target() {
        for &(q, t, eps) in &[(0.02, 500u64, 8.0), (0.1, 180, 3.0), (0.004, 3000, 1.0)] {
            let sigma = calibrate_sigma(q, t, eps, 1e-5);
            let spent = rdp::epsilon(q, sigma, t, 1e-5);
            assert!(spent <= eps + 1e-6, "spent {spent} > {eps}");
            // and not overly conservative: within 2% of the target
            assert!(spent >= eps * 0.98, "spent {spent} << {eps} (sigma {sigma})");
        }
    }

    #[test]
    fn tighter_budget_needs_more_noise() {
        let s8 = calibrate_sigma(0.05, 400, 8.0, 1e-5);
        let s3 = calibrate_sigma(0.05, 400, 3.0, 1e-5);
        let s1 = calibrate_sigma(0.05, 400, 1.0, 1e-5);
        assert!(s1 > s3 && s3 > s8, "{s1} {s3} {s8}");
    }

    #[test]
    fn plan_is_consistent() {
        let p = plan(50_000, 1000, 3.0, 2.0, 1e-5);
        assert_eq!(p.steps, 150);
        assert!((p.q - 0.02).abs() < 1e-12);
        assert!(p.spent_eps <= 2.0 + 1e-6);
        assert!(p.sigma > 0.3);
    }
}
