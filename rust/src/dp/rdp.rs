//! Rényi-DP accountant for the sampled Gaussian mechanism (Mironov et al.,
//! 2019) with the improved RDP -> (eps, delta) conversion.
//!
//! This is the accountant the paper uses ("we compute eps using a conversion
//! from RDP", §4).  One DP-SGD step with Poisson sampling rate `q` and noise
//! multiplier `sigma` satisfies RDP(alpha) at each order alpha; T steps
//! compose additively in RDP; the final (eps, delta) is the minimum over the
//! alpha grid of the conversion bound.

/// Default integer Rényi-order grid (2..=255 is ample for fine-tuning
/// regimes; order 2 handles very noisy runs, large orders tight low-noise).
pub fn default_alphas() -> Vec<u32> {
    let mut v: Vec<u32> = (2..=64).collect();
    v.extend([72, 80, 96, 128, 160, 192, 256].iter());
    v
}

/// ln(n choose k) via ln-gamma.
fn ln_binom(n: u32, k: u32) -> f64 {
    ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0)
}

/// Lanczos ln-gamma (g = 7, n = 9), |err| < 1e-13 over our domain.
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection
        return std::f64::consts::PI.ln()
            - (std::f64::consts::PI * x).sin().ln()
            - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Stable log(sum(exp(xs))).
fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return f64::NEG_INFINITY;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// RDP of ONE sampled-Gaussian step at integer order `alpha`.
///
/// `q` is the Poisson sampling probability, `sigma` the noise multiplier
/// (noise stddev / clipping threshold).  Uses the binomial expansion
/// (Mironov et al. 2019, eq. 6), exact for integer alpha:
///
/// RDP(alpha) = 1/(alpha-1) * log( sum_k C(alpha,k) (1-q)^(alpha-k) q^k
///                                  * exp(k(k-1)/(2 sigma^2)) )
pub fn rdp_step(q: f64, sigma: f64, alpha: u32) -> f64 {
    assert!(alpha >= 2, "alpha must be >= 2");
    assert!((0.0..=1.0).contains(&q), "q in [0,1]");
    assert!(sigma > 0.0, "sigma > 0");
    if q == 0.0 {
        return 0.0;
    }
    let a = alpha as f64;
    if (q - 1.0).abs() < 1e-15 {
        // plain Gaussian mechanism
        return a / (2.0 * sigma * sigma);
    }
    let terms: Vec<f64> = (0..=alpha)
        .map(|k| {
            let kf = k as f64;
            ln_binom(alpha, k)
                + (a - kf) * (1.0 - q).ln()
                + kf * q.ln()
                + kf * (kf - 1.0) / (2.0 * sigma * sigma)
        })
        .collect();
    log_sum_exp(&terms) / (a - 1.0)
}

/// RDP of `steps` composed sampled-Gaussian steps over an alpha grid.
pub fn rdp_composed(q: f64, sigma: f64, steps: u64, alphas: &[u32]) -> Vec<f64> {
    alphas
        .iter()
        .map(|&a| steps as f64 * rdp_step(q, sigma, a))
        .collect()
}

/// Improved RDP -> (eps, delta) conversion (Balle et al. 2020; the Opacus
/// formula): eps = rdp(a) + ln((a-1)/a) - (ln(delta) + ln(a)) / (a-1),
/// minimized over the grid.  Returns (eps, best_alpha).
pub fn rdp_to_dp(alphas: &[u32], rdp: &[f64], delta: f64) -> (f64, u32) {
    assert_eq!(alphas.len(), rdp.len());
    assert!(delta > 0.0 && delta < 1.0);
    let mut best = (f64::INFINITY, alphas[0]);
    for (&a, &r) in alphas.iter().zip(rdp) {
        let af = a as f64;
        let eps = r + ((af - 1.0) / af).ln() - (delta.ln() + af.ln()) / (af - 1.0);
        if eps < best.0 {
            best = (eps.max(0.0), a);
        }
    }
    best
}

/// End-to-end: epsilon spent by `steps` DP-SGD steps at (q, sigma, delta).
pub fn epsilon(q: f64, sigma: f64, steps: u64, delta: f64) -> f64 {
    if q == 0.0 || steps == 0 {
        return 0.0; // nothing released: perfectly private
    }
    let alphas = default_alphas();
    let rdp = rdp_composed(q, sigma, steps, &alphas);
    rdp_to_dp(&alphas, &rdp, delta).0
}

/// Streaming accountant carried by the training loop.
#[derive(Debug, Clone)]
pub struct RdpAccountant {
    alphas: Vec<u32>,
    acc: Vec<f64>,
    pub delta: f64,
}

impl RdpAccountant {
    pub fn new(delta: f64) -> RdpAccountant {
        let alphas = default_alphas();
        let acc = vec![0.0; alphas.len()];
        RdpAccountant { alphas, acc, delta }
    }

    /// Record one sampled-Gaussian step.
    pub fn step(&mut self, q: f64, sigma: f64) {
        for (a, r) in self.alphas.iter().zip(self.acc.iter_mut()) {
            *r += rdp_step(q, sigma, *a);
        }
    }

    /// Record `n` identical steps at once.
    pub fn steps(&mut self, q: f64, sigma: f64, n: u64) {
        for (a, r) in self.alphas.iter().zip(self.acc.iter_mut()) {
            *r += n as f64 * rdp_step(q, sigma, *a);
        }
    }

    /// Current (epsilon, best alpha).
    pub fn epsilon(&self) -> (f64, u32) {
        if self.acc.iter().all(|&r| r == 0.0) {
            return (0.0, self.alphas[0]); // nothing released yet
        }
        rdp_to_dp(&self.alphas, &self.acc, self.delta)
    }

    /// Accumulated RDP at each grid order (session-state checkpoints).
    pub fn accumulated(&self) -> &[f64] {
        &self.acc
    }

    /// Restore accumulated RDP from an [`RdpAccountant::accumulated`]
    /// snapshot.  Fails if the snapshot was taken over a different grid.
    pub fn restore(&mut self, acc: &[f64]) -> Result<(), String> {
        if acc.len() != self.alphas.len() {
            return Err(format!(
                "accountant snapshot has {} orders, grid has {}",
                acc.len(),
                self.alphas.len()
            ));
        }
        self.acc = acc.to_vec();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_factorials() {
        for n in 1..15u32 {
            let f: f64 = (1..=n).map(|k| k as f64).product::<f64>().ln();
            assert!((ln_gamma(n as f64 + 1.0) - f).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn no_subsampling_is_plain_gaussian() {
        // q = 1: RDP(alpha) = alpha / (2 sigma^2) exactly
        for &alpha in &[2u32, 8, 32] {
            for &sigma in &[0.5f64, 1.0, 4.0] {
                let want = alpha as f64 / (2.0 * sigma * sigma);
                assert!((rdp_step(1.0, sigma, alpha) - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn zero_sampling_is_free() {
        assert_eq!(rdp_step(0.0, 1.0, 8), 0.0);
        assert_eq!(epsilon(0.0, 1.0, 1000, 1e-5), 0.0);
    }

    #[test]
    fn monotone_in_sigma_q_steps() {
        let e = |q, s, t| epsilon(q, s, t, 1e-5);
        assert!(e(0.01, 1.0, 1000) > e(0.01, 2.0, 1000)); // more noise, less eps
        assert!(e(0.02, 1.0, 1000) > e(0.01, 1.0, 1000)); // more sampling, more eps
        assert!(e(0.01, 1.0, 2000) > e(0.01, 1.0, 1000)); // more steps, more eps
    }

    #[test]
    fn subsampling_amplifies() {
        // sampled mechanism must be no worse than the unsampled one
        let alphas = default_alphas();
        for &a in &alphas[..8] {
            assert!(rdp_step(0.1, 1.0, a) <= rdp_step(1.0, 1.0, a) + 1e-12);
        }
    }

    #[test]
    fn abadi_mnist_regime_magnitude() {
        // The classic DP-SGD regime (q=0.01, sigma=4, T=10000, delta=1e-5)
        // is known to land at eps ~ 1.2-1.5 with a moments/RDP accountant.
        let eps = epsilon(0.01, 4.0, 10_000, 1e-5);
        assert!(eps > 0.8 && eps < 2.0, "eps = {eps}");
    }

    #[test]
    fn streaming_matches_batch() {
        let mut acc = RdpAccountant::new(1e-5);
        for _ in 0..100 {
            acc.step(0.02, 1.5);
        }
        let (e1, _) = acc.epsilon();
        let e2 = epsilon(0.02, 1.5, 100, 1e-5);
        assert!((e1 - e2).abs() < 1e-9);
        let mut acc2 = RdpAccountant::new(1e-5);
        acc2.steps(0.02, 1.5, 100);
        assert!((acc2.epsilon().0 - e2).abs() < 1e-9);
    }
}
