//! Gaussian-DP (f-DP) accountant (Dong, Roth, Su; Bu et al. 2020) as an
//! independent cross-check of the RDP accountant.
//!
//! DP-SGD with Poisson rate `q`, noise multiplier `sigma`, `T` steps is
//! asymptotically mu-GDP with  mu = q * sqrt(T * (exp(1/sigma^2) - 1))
//! (Bu et al. 2020, CLT approximation).  A mu-GDP mechanism satisfies
//! (eps, delta(eps))-DP with
//!   delta(eps) = Phi(-eps/mu + mu/2) - exp(eps) * Phi(-eps/mu - mu/2).

/// Standard normal CDF via erfc (Abramowitz–Stegun 7.1.26, |err| < 1.5e-7).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// The GDP mu for DP-SGD (CLT approximation of Bu et al. 2020).
pub fn dp_sgd_mu(q: f64, sigma: f64, steps: u64) -> f64 {
    q * ((steps as f64) * ((1.0 / (sigma * sigma)).exp() - 1.0)).sqrt()
}

/// delta as a function of eps for a mu-GDP mechanism.
pub fn delta_of_eps(mu: f64, eps: f64) -> f64 {
    norm_cdf(-eps / mu + mu / 2.0) - eps.exp() * norm_cdf(-eps / mu - mu / 2.0)
}

/// Invert delta(eps) = delta by bisection (delta is decreasing in eps).
pub fn eps_of_delta(mu: f64, delta: f64) -> f64 {
    assert!(mu > 0.0 && delta > 0.0 && delta < 1.0);
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    while delta_of_eps(mu, hi) > delta {
        hi *= 2.0;
        if hi > 1e6 {
            return f64::INFINITY;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if delta_of_eps(mu, mid) > delta {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

/// End-to-end GDP epsilon for DP-SGD.
pub fn epsilon(q: f64, sigma: f64, steps: u64, delta: f64) -> f64 {
    if q == 0.0 {
        return 0.0;
    }
    eps_of_delta(dp_sgd_mu(q, sigma, steps), delta)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm_cdf_reference_points() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.0) - 0.8413447).abs() < 1e-5);
        assert!((norm_cdf(-1.96) - 0.0249979).abs() < 1e-5);
        assert!((norm_cdf(3.0) - 0.9986501).abs() < 1e-5);
    }

    #[test]
    fn gdp_dual_known_point() {
        // mu = 1 GDP at delta(eps=0) = Phi(1/2) - Phi(-1/2) ~ 0.3829
        let d = delta_of_eps(1.0, 0.0);
        assert!((d - 0.3829).abs() < 1e-3, "{d}");
    }

    #[test]
    fn eps_of_delta_inverts() {
        for &mu in &[0.3, 1.0, 2.5] {
            let eps = eps_of_delta(mu, 1e-5);
            let d = delta_of_eps(mu, eps);
            assert!((d - 1e-5).abs() < 1e-8, "mu={mu} d={d}");
        }
    }

    #[test]
    fn gdp_and_rdp_agree_in_order_of_magnitude() {
        // GDP (CLT) tends to be tighter than RDP; they should be within ~2x
        // in typical fine-tuning regimes.
        for &(q, s, t) in &[(0.01, 1.0, 2000u64), (0.05, 2.0, 500), (0.02, 1.5, 1000)] {
            let e_gdp = epsilon(q, s, t, 1e-5);
            let e_rdp = crate::dp::rdp::epsilon(q, s, t, 1e-5);
            assert!(e_gdp <= e_rdp * 1.1, "gdp {e_gdp} rdp {e_rdp}");
            assert!(e_gdp * 3.0 > e_rdp, "gdp {e_gdp} rdp {e_rdp}");
        }
    }

    #[test]
    fn monotone_in_steps() {
        assert!(epsilon(0.01, 1.0, 4000, 1e-5) > epsilon(0.01, 1.0, 1000, 1e-5));
    }
}
