//! Model metadata: the published-architecture zoo (Tables 1, 11) and the
//! specs of the small models actually trained by this repo (mirrors the
//! python manifest; see `runtime::Manifest` for the authoritative copy).

pub mod zoo;

/// Short descriptor of a trained-model config used in benches.
#[derive(Debug, Clone)]
pub struct TrainedSpec {
    pub name: &'static str,
    /// Analogous published model in the paper's tables.
    pub paper_analog: &'static str,
    pub kind: &'static str,
}

/// The trained-model registry (must match `python/compile/aot.py::MODELS`).
pub fn trained_specs() -> Vec<TrainedSpec> {
    let s = |name, paper_analog, kind| TrainedSpec { name, paper_analog, kind };
    vec![
        s("cls-base", "RoBERTa-base", "cls"),
        s("cls-large", "RoBERTa-large", "cls"),
        s("cls-lora", "RoBERTa-base + LoRA", "cls"),
        s("cls-adapter", "RoBERTa-base + Adapter", "cls"),
        s("lm-small", "GPT2-small", "lm"),
        s("lm-medium", "GPT2-medium", "lm"),
        s("lm-large", "GPT2-large", "lm"),
        s("vit-c10", "ViT-large (CIFAR10)", "vit"),
        s("vit-c20", "ViT-large (CIFAR100)", "vit"),
        s("cnn-small", "ResNet18 (CelebA)", "cnn"),
        s("cnn-small-bias", "ResNet18 + bias (BiTFiT-Add)", "cnn"),
    ]
}

#[cfg(test)]
mod tests {
    #[test]
    fn registry_nonempty_and_unique() {
        let specs = super::trained_specs();
        assert!(specs.len() >= 10);
        let mut names: Vec<_> = specs.iter().map(|s| s.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), specs.len());
    }
}
