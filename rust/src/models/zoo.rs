//! Model-zoo parameter accounting (paper Tables 1 and 11).
//!
//! Builds each published architecture as a list of primitive layers and
//! counts weight vs bias parameters exactly the way the paper does: "bias"
//! = additive per-channel parameters (linear/conv biases, LayerNorm /
//! BatchNorm shift beta), everything else is "weight".  Totals are checked
//! against the published sizes in `tests` (within tolerance — framework
//! versions differ in heads/pooler details).

/// Parameter counts of one primitive layer.
#[derive(Debug, Clone, Copy, Default)]
pub struct Counts {
    pub weights: u64,
    pub biases: u64,
}

impl Counts {
    pub fn total(&self) -> u64 {
        self.weights + self.biases
    }

    fn add(&mut self, other: Counts) {
        self.weights += other.weights;
        self.biases += other.biases;
    }
}

fn conv(cin: u64, cout: u64, k: u64, bias: bool) -> Counts {
    Counts { weights: k * k * cin * cout, biases: if bias { cout } else { 0 } }
}

fn fc(din: u64, dout: u64, bias: bool) -> Counts {
    Counts { weights: din * dout, biases: if bias { dout } else { 0 } }
}

/// BatchNorm/GroupNorm/LayerNorm affine: gamma is a weight, beta a bias.
fn norm(c: u64) -> Counts {
    Counts { weights: c, biases: c }
}

fn emb(n: u64, d: u64) -> Counts {
    Counts { weights: n * d, biases: 0 }
}

// ------------------------------------------------------------------
// CNNs
// ------------------------------------------------------------------

fn vgg(cfg: &[&[u64]]) -> Counts {
    let mut c = Counts::default();
    let mut cin = 3;
    for stage in cfg {
        for &cout in *stage {
            c.add(conv(cin, cout, 3, true));
            cin = cout;
        }
    }
    c.add(fc(512 * 7 * 7, 4096, true));
    c.add(fc(4096, 4096, true));
    c.add(fc(4096, 1000, true));
    c
}

/// ResNet basic block (two 3x3 convs); bias-less convs + BN (App. A.2).
fn basic_block(cin: u64, cout: u64, downsample: bool) -> Counts {
    let mut c = Counts::default();
    c.add(conv(cin, cout, 3, false));
    c.add(norm(cout));
    c.add(conv(cout, cout, 3, false));
    c.add(norm(cout));
    if downsample {
        c.add(conv(cin, cout, 1, false));
        c.add(norm(cout));
    }
    c
}

/// ResNet bottleneck block (1x1 -> 3x3 -> 1x1, expansion-4 output `cout`).
/// Wide ResNets double `width` (the 3x3 planes) but keep `cout` standard.
fn bottleneck(cin: u64, width: u64, cout: u64, downsample: bool) -> Counts {
    let mut c = Counts::default();
    c.add(conv(cin, width, 1, false));
    c.add(norm(width));
    c.add(conv(width, width, 3, false));
    c.add(norm(width));
    c.add(conv(width, cout, 1, false));
    c.add(norm(cout));
    if downsample {
        c.add(conv(cin, cout, 1, false));
        c.add(norm(cout));
    }
    c
}

fn resnet(layers: &[u64; 4], bottleneck_blocks: bool, width_mult: u64) -> Counts {
    let mut c = Counts::default();
    c.add(conv(3, 64, 7, false));
    c.add(norm(64));
    let base = [64u64, 128, 256, 512];
    let mut cin = 64;
    for (stage, &n) in layers.iter().enumerate() {
        let w = base[stage] * width_mult;
        for b in 0..n {
            if bottleneck_blocks {
                let down = b == 0; // expansion or stride change
                let cout = base[stage] * 4;
                c.add(bottleneck(cin, w, cout, down));
                cin = cout;
            } else {
                let down = b == 0 && stage > 0;
                c.add(basic_block(cin, base[stage], down));
                cin = base[stage];
            }
        }
    }
    c.add(fc(cin, 1000, true));
    c
}

// ------------------------------------------------------------------
// Transformers
// ------------------------------------------------------------------

/// Standard transformer encoder/decoder block (separate q,k,v or fused is
/// parameter-equivalent): 4 d^2 attention + 8 d^2 MLP + 2 LayerNorms.
fn transformer_block(d: u64, ff: u64) -> Counts {
    let mut c = Counts::default();
    c.add(fc(d, 3 * d, true)); // qkv
    c.add(fc(d, d, true)); // attention out
    c.add(fc(d, ff, true));
    c.add(fc(ff, d, true));
    c.add(norm(d));
    c.add(norm(d));
    c
}

fn gpt2(vocab: u64, ctx: u64, d: u64, l: u64) -> Counts {
    let mut c = Counts::default();
    c.add(emb(vocab, d));
    c.add(emb(ctx, d));
    for _ in 0..l {
        c.add(transformer_block(d, 4 * d));
    }
    c.add(norm(d)); // final LN; LM head is tied to wte
    c
}

fn bert_like(vocab: u64, pos: u64, types: u64, d: u64, l: u64, pooler: bool) -> Counts {
    let mut c = Counts::default();
    c.add(emb(vocab, d));
    c.add(emb(pos, d));
    c.add(emb(types, d));
    c.add(norm(d)); // embedding LN
    for _ in 0..l {
        c.add(transformer_block(d, 4 * d));
    }
    if pooler {
        c.add(fc(d, d, true));
    }
    c
}

fn vit(patch: u64, d: u64, l: u64, ff: u64) -> Counts {
    let mut c = Counts::default();
    c.add(conv(3, d, patch, true)); // patch embedding
    c.add(emb(197, d)); // cls + positional (224/16)^2 + 1
    c.weights += d; // cls token
    for _ in 0..l {
        c.add(transformer_block(d, ff));
    }
    c.add(norm(d));
    c.add(fc(d, 1000, true)); // classification head
    c
}

// ------------------------------------------------------------------
// registry
// ------------------------------------------------------------------

/// A zoo entry: name + computed counts + the paper's published numbers.
#[derive(Debug, Clone)]
pub struct ZooEntry {
    pub name: &'static str,
    pub counts: Counts,
    /// Published total params (Table 11), in millions.
    pub paper_params_m: f64,
    /// Published bias percentage (Table 11).
    pub paper_bias_pct: f64,
}

impl ZooEntry {
    pub fn bias_pct(&self) -> f64 {
        100.0 * self.counts.biases as f64 / self.counts.total() as f64
    }
}

/// All models of paper Table 11 (superset of Table 1).
pub fn zoo() -> Vec<ZooEntry> {
    let e = |name, counts, pm, bp| ZooEntry {
        name,
        counts,
        paper_params_m: pm,
        paper_bias_pct: bp,
    };
    vec![
        e("VGG11", vgg(&[&[64], &[128], &[256, 256], &[512, 512], &[512, 512]]), 133.0, 0.009),
        e("VGG16", vgg(&[&[64, 64], &[128, 128], &[256, 256, 256], &[512, 512, 512], &[512, 512, 512]]), 138.0, 0.009),
        e("VGG19", vgg(&[&[64, 64], &[128, 128], &[256, 256, 256, 256], &[512, 512, 512, 512], &[512, 512, 512, 512]]), 144.0, 0.010),
        e("ResNet18", resnet(&[2, 2, 2, 2], false, 1), 11.7, 0.043),
        e("ResNet34", resnet(&[3, 4, 6, 3], false, 1), 21.8, 0.044),
        e("ResNet50", resnet(&[3, 4, 6, 3], true, 1), 25.6, 0.113),
        e("ResNet101", resnet(&[3, 4, 23, 3], true, 1), 44.5, 0.121),
        e("ResNet152", resnet(&[3, 8, 36, 3], true, 1), 60.2, 0.127),
        e("wide_resnet50_2", resnet(&[3, 4, 6, 3], true, 2), 68.9, 0.051),
        e("wide_resnet101_2", resnet(&[3, 4, 23, 3], true, 2), 126.9, 0.055),
        e("ViT-small-patch16", vit(16, 384, 12, 1536), 22.0, 0.238),
        e("ViT-base-patch16", vit(16, 768, 12, 3072), 86.6, 0.120),
        e("ViT-large-patch16", vit(16, 1024, 24, 4096), 304.0, 0.090),
        e("GPT2-small", gpt2(50257, 1024, 768, 12), 124.0, 0.082),
        e("GPT2-medium", gpt2(50257, 1024, 1024, 24), 355.0, 0.076),
        e("GPT2-large", gpt2(50257, 1024, 1280, 36), 774.0, 0.066),
        e("RoBERTa-base", bert_like(50265, 514, 1, 768, 12, true), 125.0, 0.083),
        e("RoBERTa-large", bert_like(50265, 514, 1, 1024, 24, true), 355.0, 0.077),
        e("BERT-base-uncased", bert_like(30522, 512, 2, 768, 12, true), 109.0, 0.094),
        e("BERT-large-uncased", bert_like(30522, 512, 2, 1024, 24, true), 335.0, 0.081),
    ]
}

/// Lookup by name.
pub fn find(name: &str) -> Option<ZooEntry> {
    zoo().into_iter().find(|z| z.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_published_within_3_percent() {
        for z in zoo() {
            let ours = z.counts.total() as f64 / 1e6;
            let rel = (ours - z.paper_params_m).abs() / z.paper_params_m;
            assert!(rel < 0.03, "{}: ours {ours:.1}M vs paper {}M", z.name, z.paper_params_m);
        }
    }

    #[test]
    fn bias_pct_matches_published_within_35_percent_rel() {
        // bias accounting conventions differ slightly per framework (final
        // heads, poolers); the paper's headline claim — biases are ~0.1% or
        // less — must hold with the right ordering.
        for z in zoo() {
            let rel = (z.bias_pct() - z.paper_bias_pct).abs() / z.paper_bias_pct;
            assert!(
                rel < 0.35,
                "{}: bias {:.3}% vs paper {:.3}%",
                z.name,
                z.bias_pct(),
                z.paper_bias_pct
            );
            assert!(z.bias_pct() < 0.3, "{} bias share suspiciously large", z.name);
        }
    }

    #[test]
    fn known_exact_points() {
        // ResNet18 is a fully standard architecture: exact torchvision count.
        let r18 = find("ResNet18").unwrap();
        assert_eq!(r18.counts.total(), 11_689_512);
        // GPT2-small published count
        let g = find("GPT2-small").unwrap();
        assert!((g.counts.total() as i64 - 124_439_808).abs() < 500_000);
    }

    #[test]
    fn vgg_has_smallest_bias_share() {
        let z = zoo();
        let vgg16 = z.iter().find(|e| e.name == "VGG16").unwrap();
        for other in z.iter().filter(|e| !e.name.starts_with("VGG")) {
            assert!(vgg16.bias_pct() < other.bias_pct(), "{}", other.name);
        }
    }
}
