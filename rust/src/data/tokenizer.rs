//! Deterministic word-level tokenizer shared by all text tasks.
//!
//! The vocabulary is *constructed*, not learned: ids are assigned to a fixed
//! word list so that the python-side artifacts (vocab size 384/512) and the
//! rust-side generators always agree.  Special ids: 0 = PAD, 1 = CLS,
//! 2 = SEP, 3 = EOS, 4 = UNK; words start at 5.

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
pub const EOS: i32 = 3;
pub const UNK: i32 = 4;
pub const FIRST_WORD: i32 = 5;

/// Fixed-vocabulary tokenizer.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    words: Vec<String>,
    index: std::collections::HashMap<String, i32>,
    pub vocab_size: usize,
}

impl Tokenizer {
    /// Build from a word list, capped at `vocab_size - FIRST_WORD` entries.
    pub fn new(words: &[&str], vocab_size: usize) -> Tokenizer {
        let cap = vocab_size - FIRST_WORD as usize;
        let words: Vec<String> = words.iter().take(cap).map(|s| s.to_string()).collect();
        let index = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), FIRST_WORD + i as i32))
            .collect();
        Tokenizer { words, index, vocab_size }
    }

    pub fn encode_word(&self, w: &str) -> i32 {
        *self.index.get(w).unwrap_or(&UNK)
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace().map(|w| self.encode_word(w)).collect()
    }

    pub fn decode_id(&self, id: i32) -> &str {
        match id {
            PAD => "<pad>",
            CLS => "<cls>",
            SEP => "<sep>",
            EOS => "<eos>",
            UNK => "<unk>",
            _ => self
                .words
                .get((id - FIRST_WORD) as usize)
                .map(|s| s.as_str())
                .unwrap_or("<oob>"),
        }
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&i| i >= FIRST_WORD)
            .map(|&i| self.decode_id(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Pad/truncate to `len`; optionally prepend CLS.
    pub fn pad_to(&self, mut ids: Vec<i32>, len: usize, with_cls: bool) -> Vec<i32> {
        if with_cls {
            ids.insert(0, CLS);
        }
        ids.truncate(len);
        while ids.len() < len {
            ids.push(PAD);
        }
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let t = Tokenizer::new(&["the", "food", "was", "great"], 512);
        let ids = t.encode("the food was great");
        assert_eq!(ids, vec![5, 6, 7, 8]);
        assert_eq!(t.decode(&ids), "the food was great");
        assert_eq!(t.encode_word("missing"), UNK);
    }

    #[test]
    fn pad_and_cls() {
        let t = Tokenizer::new(&["a", "b"], 512);
        let p = t.pad_to(vec![5, 6], 5, true);
        assert_eq!(p, vec![CLS, 5, 6, PAD, PAD]);
        let tr = t.pad_to(vec![5, 6, 5, 6, 5, 6], 4, false);
        assert_eq!(tr.len(), 4);
    }

    #[test]
    fn vocab_capped() {
        let many: Vec<String> = (0..1000).map(|i| format!("w{i}")).collect();
        let refs: Vec<&str> = many.iter().map(|s| s.as_str()).collect();
        let t = Tokenizer::new(&refs, 384);
        assert!(t.encode_word("w500") == UNK); // beyond cap
        assert!(t.encode_word("w300") != UNK);
        // every emitted id fits the artifact vocab
        for i in 0..379 {
            let id = t.encode_word(&format!("w{i}"));
            assert!(id < 384);
        }
    }
}
