//! Synthetic workload generators — the paper's datasets, simulated
//! (DESIGN.md §5 documents each substitution).
//!
//! * [`tokenizer`] — deterministic word-level vocabulary shared between the
//!   corpus generators and the LM artifacts.
//! * [`synth_text`] — grammar-generated text: a pretraining corpus, four
//!   GLUE-analog classification tasks (SST2/QNLI/QQP/MNLI shapes), and an
//!   E2E-analog meaning-representation -> utterance generation task.
//! * [`synth_image`] — parametric images: a shapes "CIFAR" analog and an
//!   attribute-factor multi-label "CelebA" analog.

pub mod synth_image;
pub mod synth_text;
pub mod tokenizer;

/// A classification example: token ids (padded) + label.
#[derive(Debug, Clone)]
pub struct TextExample {
    pub tokens: Vec<i32>,
    pub label: i32,
}

/// An LM example: input ids + next-token targets (0 = pad/ignore).
#[derive(Debug, Clone)]
pub struct LmExample {
    pub input: Vec<i32>,
    pub target: Vec<i32>,
}

/// A generation example: prompt ids, padded full sequence + references.
#[derive(Debug, Clone)]
pub struct GenExample {
    pub lm: LmExample,
    /// prompt length (decode starts here)
    pub prompt_len: usize,
    /// reference completions (token ids, no padding) for NLG metrics
    pub references: Vec<Vec<u32>>,
}

/// An image example.
#[derive(Debug, Clone)]
pub struct ImageExample {
    /// NHWC f32 pixels in [-1, 1], flattened
    pub pixels: Vec<f32>,
    /// single label (classification) — unused when multi-label
    pub label: i32,
    /// multi-label attribute vector in {0,1}
    pub attributes: Vec<f32>,
}
