//! Parametric image generators (CIFAR / CelebA analogs — DESIGN.md §5).
//!
//! * `shapes` — each class is a distinct (pattern, hue) combination drawn
//!   with per-example jitter and pixel noise: a classification task whose
//!   difficulty scales with the noise level (CIFAR10/100 analog).
//! * `attributes` — 8 independent binary factors, each controlling one
//!   visual element; the label is the factor vector itself (CelebA
//!   multi-label analog, Tables 6/16).
//!
//! Pixels are NHWC f32 in [-1, 1].

use super::ImageExample;
use crate::util::rng::ChaChaRng;

fn blank(size: usize, level: f32) -> Vec<f32> {
    vec![level; size * size * 3]
}

fn put(img: &mut [f32], size: usize, x: usize, y: usize, c: usize, v: f32) {
    img[(y * size + x) * 3 + c] = v;
}

fn add_noise(img: &mut [f32], rng: &mut ChaChaRng, level: f32) {
    for p in img.iter_mut() {
        *p = (*p + (rng.gaussian() as f32) * level).clamp(-1.0, 1.0);
    }
}

/// Draw one of 5 base patterns with a given hue channel.
fn draw_pattern(img: &mut [f32], size: usize, pattern: usize, hue: usize, rng: &mut ChaChaRng) {
    let jx = rng.below(size / 4) as i64 - (size / 8) as i64;
    let jy = rng.below(size / 4) as i64 - (size / 8) as i64;
    let cx = (size as i64 / 2 + jx) as f32;
    let cy = (size as i64 / 2 + jy) as f32;
    let r = size as f32 * (0.2 + 0.1 * rng.uniform() as f32);
    for y in 0..size {
        for x in 0..size {
            let (fx, fy) = (x as f32, y as f32);
            let on = match pattern {
                0 => ((fx - cx).powi(2) + (fy - cy).powi(2)).sqrt() < r, // disc
                1 => (fx - cx).abs() < r && (fy - cy).abs() < r,          // square
                2 => ((fx / 4.0) as usize) % 2 == 0,                      // v-stripes
                3 => ((fy / 4.0) as usize) % 2 == 0,                      // h-stripes
                _ => (((fx / 4.0) as usize) + ((fy / 4.0) as usize)) % 2 == 0, // checker
            };
            if on {
                put(img, size, x, y, hue % 3, 0.9);
                if hue >= 3 {
                    put(img, size, x, y, (hue + 1) % 3, 0.6);
                }
            }
        }
    }
}

/// CIFAR-analog: `n_cls` classes = (pattern, hue) pairs.
///
/// `noise` controls difficulty; `domain_shift=true` renders on a brighter
/// background (used so pretraining and fine-tuning distributions differ).
pub fn shapes(
    n: usize,
    size: usize,
    n_cls: usize,
    noise: f32,
    domain_shift: bool,
    seed: u64,
) -> Vec<ImageExample> {
    assert!(n_cls <= 30, "5 patterns x 6 hues max");
    let mut rng = ChaChaRng::new(seed, 0xC1FA2);
    (0..n)
        .map(|_| {
            let label = rng.below(n_cls);
            let (pattern, hue) = (label % 5, label / 5);
            let mut img = blank(size, if domain_shift { -0.2 } else { -0.8 });
            draw_pattern(&mut img, size, pattern, hue, &mut rng);
            add_noise(&mut img, &mut rng, noise);
            ImageExample { pixels: img, label: label as i32, attributes: vec![] }
        })
        .collect()
}

/// CelebA-analog: 8 binary attributes, each with a dedicated visual factor.
pub fn attributes(n: usize, size: usize, noise: f32, seed: u64) -> Vec<ImageExample> {
    let mut rng = ChaChaRng::new(seed, 0xCE1EBA);
    (0..n)
        .map(|_| {
            let attrs: Vec<f32> = (0..8).map(|_| (rng.uniform() < 0.5) as i32 as f32).collect();
            let mut img = blank(size, if attrs[0] > 0.5 { 0.2 } else { -0.6 });
            // attr 1: central disc
            if attrs[1] > 0.5 {
                draw_pattern(&mut img, size, 0, 0, &mut rng);
            }
            // attr 2: vertical stripes in green
            if attrs[2] > 0.5 {
                for y in 0..size {
                    for x in (0..size).step_by(6) {
                        put(&mut img, size, x, y, 1, 0.8);
                    }
                }
            }
            // attr 3: top band red
            if attrs[3] > 0.5 {
                for y in 0..size / 6 {
                    for x in 0..size {
                        put(&mut img, size, x, y, 0, 0.9);
                    }
                }
            }
            // attr 4: border
            if attrs[4] > 0.5 {
                for i in 0..size {
                    for c in 0..3 {
                        put(&mut img, size, i, 0, c, 1.0);
                        put(&mut img, size, i, size - 1, c, 1.0);
                        put(&mut img, size, 0, i, c, 1.0);
                        put(&mut img, size, size - 1, i, c, 1.0);
                    }
                }
            }
            // attr 5: bottom-right square blue
            if attrs[5] > 0.5 {
                for y in 2 * size / 3..size {
                    for x in 2 * size / 3..size {
                        put(&mut img, size, x, y, 2, 0.9);
                    }
                }
            }
            // attr 6: diagonal
            if attrs[6] > 0.5 {
                for i in 0..size {
                    put(&mut img, size, i, i, 0, 0.7);
                    put(&mut img, size, i, i, 1, 0.7);
                }
            }
            // attr 7: left band dim cyan
            if attrs[7] > 0.5 {
                for y in 0..size {
                    for x in 0..size / 8 {
                        put(&mut img, size, x, y, 1, 0.5);
                        put(&mut img, size, x, y, 2, 0.5);
                    }
                }
            }
            add_noise(&mut img, &mut rng, noise);
            ImageExample { pixels: img, label: -1, attributes: attrs }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_shapes_and_ranges() {
        let ex = shapes(40, 32, 10, 0.1, false, 1);
        assert_eq!(ex.len(), 40);
        for e in &ex {
            assert_eq!(e.pixels.len(), 32 * 32 * 3);
            assert!((0..10).contains(&e.label));
            assert!(e.pixels.iter().all(|&p| (-1.0..=1.0).contains(&p)));
        }
        // all classes appear over a larger draw
        let big = shapes(500, 16, 10, 0.05, false, 2);
        let mut seen = [false; 10];
        for e in big {
            seen[e.label as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean images of two classes should differ substantially
        let ex = shapes(300, 16, 10, 0.0, false, 3);
        let mean = |cls: i32| -> Vec<f32> {
            let sel: Vec<_> = ex.iter().filter(|e| e.label == cls).collect();
            let mut m = vec![0.0f32; 16 * 16 * 3];
            for e in &sel {
                for (mi, &p) in m.iter_mut().zip(&e.pixels) {
                    *mi += p / sel.len() as f32;
                }
            }
            m
        };
        let (a, b) = (mean(0), mean(3));
        let d: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f32>() / a.len() as f32;
        assert!(d > 0.1, "class means too similar: {d}");
    }

    #[test]
    fn attributes_are_balanced_and_visible() {
        let ex = attributes(400, 16, 0.05, 4);
        let mut counts = [0usize; 8];
        for e in &ex {
            assert_eq!(e.attributes.len(), 8);
            for (i, &a) in e.attributes.iter().enumerate() {
                assert!(a == 0.0 || a == 1.0);
                counts[i] += a as usize;
            }
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 120 && c < 280, "attr {i} count {c}");
        }
        // attr 0 (background) separates mean brightness
        let bright: f32 = ex.iter().filter(|e| e.attributes[0] > 0.5)
            .map(|e| e.pixels.iter().sum::<f32>()).sum();
        let dark: f32 = ex.iter().filter(|e| e.attributes[0] < 0.5)
            .map(|e| e.pixels.iter().sum::<f32>()).sum();
        assert!(bright > dark);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = shapes(5, 16, 10, 0.1, false, 9);
        let b = shapes(5, 16, 10, 0.1, false, 9);
        assert_eq!(a[0].pixels, b[0].pixels);
    }
}
