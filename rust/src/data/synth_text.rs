//! Grammar-generated text workloads (GLUE / E2E analogs — DESIGN.md §5).
//!
//! All generators are deterministic under a seed and emit ids from the fixed
//! [`Tokenizer`] vocabulary, so artifact vocab bounds are respected by
//! construction.  Tasks are *learnable but not trivial*: each label depends
//! on a latent rule plus distractor noise, so accuracy separates trained
//! methods the same way the paper's tables do (fine-tuned > frozen >>
//! random, DP slightly below non-DP).

use super::tokenizer::{Tokenizer, EOS, SEP};
use super::{GenExample, LmExample, TextExample};
use crate::util::rng::ChaChaRng;

// ---------------------------------------------------------------------
// word bank (E2E-domain words first so they fit the LM's smaller vocab)
// ---------------------------------------------------------------------

const NAMES: &[&str] = &[
    "aromi", "bibimbap", "cocum", "fitzbillies", "giraffe", "midsummer",
    "strada", "vaults", "wildwood", "zizzi",
];
const FOODS: &[&str] = &["chinese", "english", "french", "indian", "italian", "japanese"];
const PRICES: &[&str] = &["cheap", "moderate", "high"];
const RATINGS: &[&str] = &["low", "average", "excellent"];
const AREAS: &[&str] = &["riverside", "city", "centre", "suburbs"];
const E2E_GLUE_WORDS: &[&str] = &[
    "name", "food", "price", "rating", "area", "serves", "is", "a", "the",
    "restaurant", "in", "with", "prices", "it", "has", "an", "located",
    "offering", "and", "customer", "quality", "place", "you", "can", "find",
    "eat", "near", "by",
];
const POS_ADJ: &[&str] = &[
    "great", "wonderful", "delicious", "friendly", "superb", "charming",
    "tasty", "lovely", "amazing", "pleasant",
];
const NEG_ADJ: &[&str] = &[
    "terrible", "bland", "awful", "rude", "dreadful", "greasy", "noisy",
    "dirty", "boring", "unpleasant",
];
const NOUNS: &[&str] = &[
    "service", "menu", "staff", "dish", "soup", "dessert", "wine", "bread",
    "salad", "curry", "noodles", "pasta", "steak", "cake", "tea", "coffee",
    "table", "garden", "kitchen", "waiter", "chef", "plate", "sauce", "rice",
];
const VERBS: &[&str] = &[
    "tastes", "looks", "seems", "feels", "smells", "appears", "remains",
    "sounds", "gets", "stays",
];
const FILLERS: &[&str] = &[
    "really", "quite", "very", "somewhat", "rather", "truly", "fairly",
    "pretty", "extremely", "mostly", "today", "tonight", "again", "always",
    "never", "often", "usually",
];

/// Full word bank in canonical id order.
pub fn word_bank() -> Vec<&'static str> {
    let mut v = Vec::new();
    for group in [
        NAMES, FOODS, PRICES, RATINGS, AREAS, E2E_GLUE_WORDS, POS_ADJ, NEG_ADJ,
        NOUNS, VERBS, FILLERS,
    ] {
        v.extend_from_slice(group);
    }
    v
}

/// Tokenizer for a model family's vocab size (384 for lm-*, 512 for cls-*).
pub fn tokenizer(vocab_size: usize) -> Tokenizer {
    Tokenizer::new(&word_bank(), vocab_size)
}

fn pick<'a>(rng: &mut ChaChaRng, xs: &[&'a str]) -> &'a str {
    xs[rng.below(xs.len())]
}

// ---------------------------------------------------------------------
// classification tasks (GLUE analogs)
// ---------------------------------------------------------------------

/// The four GLUE-analog tasks (paper Table 3 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GlueTask {
    /// SST2 analog: sentence sentiment (2 classes).
    Sst2,
    /// QNLI analog: does the sentence answer the question? (2 classes)
    Qnli,
    /// QQP analog: are the two sentences paraphrases? (2 classes)
    Qqp,
    /// MNLI analog: entail / neutral / contradict (3 classes).
    Mnli,
}

impl GlueTask {
    pub fn name(&self) -> &'static str {
        match self {
            GlueTask::Sst2 => "SST2",
            GlueTask::Qnli => "QNLI",
            GlueTask::Qqp => "QQP",
            GlueTask::Mnli => "MNLI",
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            GlueTask::Mnli => 3,
            _ => 2,
        }
    }

    pub fn all() -> [GlueTask; 4] {
        [GlueTask::Sst2, GlueTask::Qnli, GlueTask::Qqp, GlueTask::Mnli]
    }
}

fn sentiment_sentence(rng: &mut ChaChaRng, tok: &Tokenizer, positive: bool) -> Vec<i32> {
    let adjs = if positive { POS_ADJ } else { NEG_ADJ };
    let mut words: Vec<&str> = vec!["the", pick(rng, NOUNS), pick(rng, VERBS), pick(rng, FILLERS), pick(rng, adjs)];
    // distractors: filler words and a neutral clause
    for _ in 0..rng.below(4) {
        words.push(pick(rng, FILLERS));
    }
    words.push("and");
    words.push("the");
    words.push(pick(rng, NOUNS));
    words.push(pick(rng, VERBS));
    words.push(pick(rng, adjs));
    tok.encode(&words.join(" "))
}

/// Generate `n` examples of a GLUE-analog task, padded to `t_len` with CLS.
pub fn glue(task: GlueTask, n: usize, t_len: usize, tok: &Tokenizer, seed: u64) -> Vec<TextExample> {
    let mut rng = ChaChaRng::new(seed, 0x617445);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let (ids, label) = match task {
            GlueTask::Sst2 => {
                let pos = rng.uniform() < 0.5;
                (sentiment_sentence(&mut rng, tok, pos), pos as i32)
            }
            GlueTask::Qnli => {
                let subject = pick(&mut rng, NOUNS);
                let answered = rng.uniform() < 0.5;
                let s_subject = if answered { subject } else { pick(&mut rng, NOUNS) };
                let q = format!("is the {subject} {}", pick(&mut rng, POS_ADJ));
                let s = format!(
                    "the {s_subject} {} {} {}",
                    pick(&mut rng, VERBS),
                    pick(&mut rng, FILLERS),
                    pick(&mut rng, POS_ADJ)
                );
                let mut ids = tok.encode(&q);
                ids.push(SEP);
                ids.extend(tok.encode(&s));
                (ids, (answered && s_subject == subject) as i32)
            }
            GlueTask::Qqp => {
                let noun = pick(&mut rng, NOUNS);
                let adj = pick(&mut rng, POS_ADJ);
                let dup = rng.uniform() < 0.5;
                let s1 = format!("is the {noun} {} {adj}", pick(&mut rng, FILLERS));
                let s2 = if dup {
                    format!("is the {noun} {} {adj}", pick(&mut rng, FILLERS))
                } else {
                    format!(
                        "is the {} {} {}",
                        pick(&mut rng, NOUNS),
                        pick(&mut rng, FILLERS),
                        pick(&mut rng, POS_ADJ)
                    )
                };
                let mut ids = tok.encode(&s1);
                ids.push(SEP);
                ids.extend(tok.encode(&s2));
                // label: duplicate iff noun+adj repeated
                let same = s2.contains(noun) && s2.contains(adj);
                (ids, same as i32)
            }
            GlueTask::Mnli => {
                let noun = pick(&mut rng, NOUNS);
                let pos = rng.uniform() < 0.5;
                let premise_adjs = if pos { POS_ADJ } else { NEG_ADJ };
                let premise_adj = pick(&mut rng, premise_adjs);
                let label = rng.below(3) as i32; // 0 entail, 1 neutral, 2 contradict
                let hyp = match label {
                    0 => format!("the {noun} is {premise_adj}"),
                    1 => format!("the {} is {}", pick(&mut rng, NOUNS), pick(&mut rng, FILLERS)),
                    _ => {
                        let anti = if pos { NEG_ADJ } else { POS_ADJ };
                        format!("the {noun} is {}", pick(&mut rng, anti))
                    }
                };
                let premise = format!(
                    "the {noun} {} {} {premise_adj}",
                    pick(&mut rng, VERBS),
                    pick(&mut rng, FILLERS)
                );
                let mut ids = tok.encode(&premise);
                ids.push(SEP);
                ids.extend(tok.encode(&hyp));
                (ids, label)
            }
        };
        out.push(TextExample { tokens: tok.pad_to(ids, t_len, true), label });
    }
    out
}

// ---------------------------------------------------------------------
// pretraining corpora
// ---------------------------------------------------------------------

/// Generic sentence for LM pretraining / encoder pretraining.
fn corpus_sentence(rng: &mut ChaChaRng, tok: &Tokenizer) -> Vec<i32> {
    let style = rng.below(3);
    let s = match style {
        0 => format!(
            "the {} {} {} {} and the {} {} {}",
            pick(rng, NOUNS), pick(rng, VERBS), pick(rng, FILLERS),
            pick(rng, POS_ADJ), pick(rng, NOUNS), pick(rng, VERBS), pick(rng, NEG_ADJ),
        ),
        1 => format!(
            "{} is a {} restaurant in the {} area with {} prices",
            pick(rng, NAMES), pick(rng, FOODS), pick(rng, AREAS), pick(rng, PRICES),
        ),
        _ => format!(
            "is the {} {} {} it {} {}",
            pick(rng, NOUNS), pick(rng, FILLERS), pick(rng, POS_ADJ),
            pick(rng, VERBS), pick(rng, NEG_ADJ),
        ),
    };
    tok.encode(&s)
}

/// LM pretraining examples: next-token prediction over the corpus.
pub fn pretrain_lm(n: usize, t_len: usize, tok: &Tokenizer, seed: u64) -> Vec<LmExample> {
    let mut rng = ChaChaRng::new(seed, 0x9A3E);
    (0..n)
        .map(|_| {
            let mut ids = corpus_sentence(&mut rng, tok);
            while ids.len() < t_len + 1 {
                ids.push(SEP);
                ids.extend(corpus_sentence(&mut rng, tok));
            }
            ids.truncate(t_len + 1);
            let input = ids[..t_len].to_vec();
            let target = ids[1..t_len + 1].to_vec();
            LmExample { input, target }
        })
        .collect()
}

/// Encoder pretraining: classify the sentence style (3 classes) — a generic
/// feature-inducing task standing in for masked-LM pretraining.
pub fn pretrain_cls(n: usize, t_len: usize, tok: &Tokenizer, seed: u64) -> Vec<TextExample> {
    let mut rng = ChaChaRng::new(seed, 0x9A3F);
    (0..n)
        .map(|_| {
            let style = rng.below(3) as i32;
            let mut r2 = ChaChaRng::new(rng.next_u64(), 7);
            let s = match style {
                0 => format!(
                    "the {} {} {} {}",
                    pick(&mut r2, NOUNS), pick(&mut r2, VERBS),
                    pick(&mut r2, FILLERS), pick(&mut r2, POS_ADJ),
                ),
                1 => format!(
                    "{} is a {} restaurant in the {} area",
                    pick(&mut r2, NAMES), pick(&mut r2, FOODS), pick(&mut r2, AREAS),
                ),
                _ => format!(
                    "is the {} {} {}",
                    pick(&mut r2, NOUNS), pick(&mut r2, FILLERS), pick(&mut r2, NEG_ADJ),
                ),
            };
            TextExample { tokens: tok.pad_to(tok.encode(&s), t_len, true), label: style }
        })
        .collect()
}

// ---------------------------------------------------------------------
// E2E-analog generation
// ---------------------------------------------------------------------

/// A meaning representation: restaurant attributes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mr {
    pub name: usize,
    pub food: usize,
    pub price: usize,
    pub rating: usize,
    pub area: usize,
}

impl Mr {
    fn sample(rng: &mut ChaChaRng) -> Mr {
        Mr {
            name: rng.below(NAMES.len()),
            food: rng.below(FOODS.len()),
            price: rng.below(PRICES.len()),
            rating: rng.below(RATINGS.len()),
            area: rng.below(AREAS.len()),
        }
    }

    /// The linearized MR prompt (mirrors the E2E dataset's "name[X], ..." field).
    pub fn prompt(&self) -> String {
        format!(
            "name {} food {} price {} rating {} area {}",
            NAMES[self.name], FOODS[self.food], PRICES[self.price],
            RATINGS[self.rating], AREAS[self.area],
        )
    }

    /// Reference realizations (template variants, as the E2E corpus has
    /// multiple human references per MR).
    pub fn references(&self) -> Vec<String> {
        let (n, f, p, r, a) = (
            NAMES[self.name], FOODS[self.food], PRICES[self.price],
            RATINGS[self.rating], AREAS[self.area],
        );
        vec![
            format!("{n} serves {f} food in the {a} area with {r} rating and {p} prices"),
            format!("{n} is a {f} restaurant located in the {a} area with {p} prices and {r} rating"),
            format!("in the {a} area {n} offers {f} food with {r} rating and {p} prices"),
        ]
    }
}

/// Generate E2E-analog examples: prompt + one reference as LM training
/// target, all references kept for metric computation.
pub fn e2e(n: usize, t_len: usize, tok: &Tokenizer, seed: u64) -> Vec<GenExample> {
    let mut rng = ChaChaRng::new(seed, 0xE2E);
    (0..n)
        .map(|_| {
            let mr = Mr::sample(&mut rng);
            let refs = mr.references();
            let chosen = rng.below(refs.len());
            let mut ids = tok.encode(&mr.prompt());
            ids.push(SEP);
            let prompt_len = ids.len();
            ids.extend(tok.encode(&refs[chosen]));
            ids.push(EOS);
            ids.truncate(t_len + 1);
            let mut input = ids.clone();
            input.truncate(t_len);
            while input.len() < t_len {
                input.push(0);
            }
            // targets: next token; 0 (pad) for the prompt region and padding
            let mut target = vec![0i32; t_len];
            for i in 0..t_len {
                let is_completion = i + 1 >= prompt_len; // predict from SEP onward
                if is_completion && i + 1 < ids.len() {
                    target[i] = ids[i + 1];
                }
            }
            let references = refs
                .iter()
                .map(|r| {
                    let mut v: Vec<u32> = tok.encode(r).iter().map(|&x| x as u32).collect();
                    v.push(EOS as u32);
                    v
                })
                .collect();
            GenExample { lm: LmExample { input, target }, prompt_len, references }
        })
        .collect()
}

// ---------------------------------------------------------------------
// canaries (privacy-audit secrets; see `crate::audit`)
// ---------------------------------------------------------------------

/// A planted canary: a trigger prompt plus a secret completion.
///
/// The trigger is one restaurant name repeated three times — a trigram no
/// clean generator can emit (names appear at most once per sentence), so
/// canaries are disjoint from the clean split by construction.  The secret
/// is a seeded random word sequence; both parts use word-bank ids only
/// (`>= FIRST_WORD`, within the LM vocab), so tokenizer round-trips are
/// exact and artifact vocab bounds hold.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Canary {
    /// Trigger ids (one NAME id repeated three times).
    pub prompt: Vec<i32>,
    /// Secret ids the attack tries to extract.
    pub completion: Vec<i32>,
}

impl Canary {
    /// The full LM token sequence: `prompt ++ SEP ++ completion ++ EOS`.
    pub fn sequence(&self) -> Vec<i32> {
        let mut ids = self.prompt.clone();
        ids.push(SEP);
        ids.extend_from_slice(&self.completion);
        ids.push(EOS);
        ids
    }

    /// Length of the prompt region including the SEP (the first supervised
    /// prediction sits at the SEP position, as in [`e2e`]).
    pub fn prompt_len(&self) -> usize {
        self.prompt.len() + 1
    }

    /// The canary as a next-token training example at `t_len` (targets
    /// supervise the completion region only, mirroring [`e2e`]).
    pub fn lm_example(&self, t_len: usize) -> LmExample {
        let mut ids = self.sequence();
        ids.truncate(t_len + 1);
        let prompt_len = self.prompt_len();
        let mut input = ids.clone();
        input.truncate(t_len);
        while input.len() < t_len {
            input.push(0);
        }
        let mut target = vec![0i32; t_len];
        for i in 0..t_len {
            if i + 1 >= prompt_len && i + 1 < ids.len() {
                target[i] = ids[i + 1];
            }
        }
        LmExample { input, target }
    }
}

/// Generate `k` canaries with `completion_len`-word secrets, deterministic
/// under `seed`.  Triggers use distinct names (k capped at the name-bank
/// size for distinctness); secrets draw from the full word bank.
pub fn canaries(k: usize, completion_len: usize, tok: &Tokenizer, seed: u64) -> Vec<Canary> {
    assert!(k <= NAMES.len(), "at most {} distinct canary triggers", NAMES.len());
    let mut rng = ChaChaRng::new(seed, 0xCA9A);
    let mut name_order: Vec<usize> = (0..NAMES.len()).collect();
    rng.shuffle(&mut name_order);
    let bank = word_bank();
    (0..k)
        .map(|c| {
            let name_id = tok.encode_word(NAMES[name_order[c]]);
            let completion =
                (0..completion_len).map(|_| tok.encode_word(pick(&mut rng, &bank))).collect();
            Canary { prompt: vec![name_id; 3], completion }
        })
        .collect()
}

/// Replace `copies` seeded-chosen examples per canary with canary training
/// rows (dataset length is preserved — `Session::run_step` requires
/// `len == n_train`).  Returns the replaced indices, grouped per canary in
/// assignment order.  Deterministic under `seed`; requires enough examples
/// to host every copy at a distinct slot.
pub fn plant_canaries(
    examples: &mut [LmExample],
    t_len: usize,
    cs: &[Canary],
    copies: usize,
    seed: u64,
) -> Vec<usize> {
    let need = cs.len() * copies;
    assert!(need <= examples.len(), "{need} canary slots into {} examples", examples.len());
    let mut rng = ChaChaRng::new(seed, 0x91A47);
    let mut slots: Vec<usize> = (0..examples.len()).collect();
    rng.shuffle(&mut slots);
    slots.truncate(need);
    for (c, canary) in cs.iter().enumerate() {
        for &slot in &slots[c * copies..(c + 1) * copies] {
            examples[slot] = canary.lm_example(t_len);
        }
    }
    slots
}

#[cfg(test)]
mod tests {
    use super::super::tokenizer::FIRST_WORD;
    use super::*;

    fn tok() -> Tokenizer {
        tokenizer(384)
    }

    #[test]
    fn word_bank_fits_lm_vocab() {
        assert!(word_bank().len() + 5 <= 384, "{}", word_bank().len());
        // no duplicate words (they would silently shadow ids)
        let mut w = word_bank();
        w.sort();
        let before = w.len();
        w.dedup();
        assert_eq!(before, w.len());
    }

    #[test]
    fn glue_tasks_have_learnable_structure() {
        let t = tok();
        for task in GlueTask::all() {
            let ex = glue(task, 500, 64, &t, 1);
            assert_eq!(ex.len(), 500);
            // labels in range and both classes present
            let mut counts = vec![0usize; task.n_classes()];
            for e in &ex {
                assert_eq!(e.tokens.len(), 64);
                assert!((e.label as usize) < task.n_classes(), "{task:?} {}", e.label);
                counts[e.label as usize] += 1;
                assert!(e.tokens.iter().all(|&t| t >= 0 && t < 384));
            }
            for (c, &n) in counts.iter().enumerate() {
                assert!(n > 50, "{task:?} class {c} has {n} examples");
            }
        }
    }

    #[test]
    fn glue_is_deterministic_per_seed() {
        let t = tok();
        let a = glue(GlueTask::Sst2, 10, 64, &t, 5);
        let b = glue(GlueTask::Sst2, 10, 64, &t, 5);
        let c = glue(GlueTask::Sst2, 10, 64, &t, 6);
        assert_eq!(a[0].tokens, b[0].tokens);
        assert_ne!(
            a.iter().map(|e| e.tokens.clone()).collect::<Vec<_>>(),
            c.iter().map(|e| e.tokens.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn sst2_sentiment_words_separate_labels() {
        let t = tok();
        let pos_ids: Vec<i32> = POS_ADJ.iter().map(|w| t.encode_word(w)).collect();
        let neg_ids: Vec<i32> = NEG_ADJ.iter().map(|w| t.encode_word(w)).collect();
        for e in glue(GlueTask::Sst2, 200, 64, &t, 2) {
            let has_pos = e.tokens.iter().any(|t| pos_ids.contains(t));
            let has_neg = e.tokens.iter().any(|t| neg_ids.contains(t));
            if e.label == 1 {
                assert!(has_pos && !has_neg);
            } else {
                assert!(has_neg && !has_pos);
            }
        }
    }

    #[test]
    fn lm_pretrain_shapes() {
        let t = tok();
        for e in pretrain_lm(20, 48, &t, 3) {
            assert_eq!(e.input.len(), 48);
            assert_eq!(e.target.len(), 48);
            // shifted: target[i] == input[i+1] wherever both non-pad
            for i in 0..47 {
                if e.target[i] != 0 && e.input[i + 1] != 0 {
                    assert_eq!(e.target[i], e.input[i + 1]);
                }
            }
        }
    }

    #[test]
    fn e2e_targets_only_cover_completion() {
        let t = tok();
        for e in e2e(50, 48, &t, 4) {
            // no supervised positions strictly before prompt end - 1
            for i in 0..e.prompt_len.saturating_sub(1) {
                assert_eq!(e.lm.target[i], 0, "target before completion");
            }
            assert!(e.lm.target.iter().any(|&t| t != 0), "no supervision at all");
            assert_eq!(e.references.len(), 3);
            // references decode to distinct strings
            assert_ne!(e.references[0], e.references[1]);
        }
    }

    #[test]
    fn e2e_references_contain_mr_slots() {
        let mr = Mr { name: 0, food: 1, price: 2, rating: 0, area: 3 };
        for r in mr.references() {
            assert!(r.contains(NAMES[0]) && r.contains(FOODS[1]));
        }
    }

    #[test]
    fn canaries_are_deterministic_and_vocab_bounded() {
        let t = tok();
        let a = canaries(3, 6, &t, 7);
        let b = canaries(3, 6, &t, 7);
        let c = canaries(3, 6, &t, 8);
        assert_eq!(a, b, "same seed must yield the same canaries");
        assert_ne!(a, c, "different seeds must yield different secrets");
        // distinct triggers, all ids real words within the LM vocab
        assert_ne!(a[0].prompt, a[1].prompt);
        assert_ne!(a[1].prompt, a[2].prompt);
        for cn in &a {
            assert_eq!(cn.prompt.len(), 3);
            assert_eq!(cn.prompt[0], cn.prompt[1]);
            assert_eq!(cn.prompt[1], cn.prompt[2]);
            assert_eq!(cn.completion.len(), 6);
            for &id in cn.prompt.iter().chain(&cn.completion) {
                assert!(id >= FIRST_WORD && id < 384, "id {id}");
            }
        }
    }

    #[test]
    fn canary_tokenizer_roundtrip() {
        let t = tok();
        for cn in canaries(4, 5, &t, 11) {
            // word-only regions decode and re-encode exactly
            assert_eq!(t.encode(&t.decode(&cn.prompt)), cn.prompt);
            assert_eq!(t.encode(&t.decode(&cn.completion)), cn.completion);
            // the full sequence keeps only SEP/EOS as non-word ids
            for &id in &cn.sequence() {
                assert!(id == SEP || id == EOS || id >= FIRST_WORD);
            }
        }
    }

    #[test]
    fn canaries_are_disjoint_from_clean_split() {
        let t = tok();
        let cs = canaries(2, 6, &t, 3);
        let clean = pretrain_lm(300, 48, &t, 5);
        for cn in &cs {
            let trigger = &cn.prompt; // a name repeated 3x — never generated
            for e in &clean {
                assert!(
                    !e.input.windows(trigger.len()).any(|w| w == trigger.as_slice()),
                    "clean split contains canary trigger"
                );
                assert!(
                    !e.input
                        .windows(cn.completion.len())
                        .any(|w| w == cn.completion.as_slice()),
                    "clean split contains canary secret"
                );
            }
        }
    }

    #[test]
    fn plant_canaries_is_seeded_and_length_preserving() {
        let t = tok();
        let cs = canaries(2, 6, &t, 3);
        let mut a = pretrain_lm(40, 48, &t, 5);
        let mut b = pretrain_lm(40, 48, &t, 5);
        let slots_a = plant_canaries(&mut a, 48, &cs, 3, 9);
        let slots_b = plant_canaries(&mut b, 48, &cs, 3, 9);
        assert_eq!(slots_a, slots_b, "same seed must pick the same slots");
        assert_eq!(slots_a.len(), 6);
        assert_eq!(a.len(), 40, "planting must preserve dataset length");
        let mut sorted = slots_a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 6, "slots must be distinct");
        // canary 0 occupies the first `copies` slots, canary 1 the rest
        for (i, &slot) in slots_a.iter().enumerate() {
            let want = cs[i / 3].lm_example(48);
            assert_eq!(a[slot].input, want.input);
            assert_eq!(a[slot].target, want.target);
        }
        // shapes stay artifact-compatible
        for e in &a {
            assert_eq!(e.input.len(), 48);
            assert_eq!(e.target.len(), 48);
            assert!(e.input.iter().all(|&x| (0..384).contains(&x)));
        }
    }

    #[test]
    fn canary_lm_example_supervises_completion_only() {
        let t = tok();
        let cn = &canaries(1, 6, &t, 2)[0];
        let e = cn.lm_example(48);
        let ids = cn.sequence();
        for i in 0..cn.prompt_len().saturating_sub(1) {
            assert_eq!(e.target[i], 0, "target before completion");
        }
        // supervised region reproduces the secret then EOS
        for (i, &id) in ids.iter().enumerate().skip(cn.prompt_len()) {
            assert_eq!(e.target[i - 1], id);
        }
        assert!(e.target.iter().filter(|&&x| x != 0).count() >= cn.completion.len());
    }
}
