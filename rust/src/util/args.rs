//! Tiny CLI argument parser: `prog <subcommand> [--key value]... [--flag]...`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value or --key value or bare flag
                if let Some((k, v)) = key.split_once('=') {
                    out.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.entry(key.to_string()).or_default().push(v);
                } else {
                    out.options.entry(key.to_string()).or_default().push(String::new());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.options.get(key).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn flag(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("train extra --config cfg.toml --steps=50 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("config"), Some("cfg.toml"));
        assert_eq!(a.usize("steps", 0), 50);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn repeated_and_defaults() {
        let a = parse("x --set a=1 --set b=2");
        assert_eq!(a.get_all("set"), vec!["a=1", "b=2"]);
        assert_eq!(a.f64("lr", 0.5), 0.5);
    }
}
