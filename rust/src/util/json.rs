//! Minimal JSON parser + writer (no serde in this offline environment).
//!
//! Supports the full JSON grammar we emit from `aot.py` (objects, arrays,
//! strings with escapes, numbers, bools, null) and is used to read
//! `*.meta.json`, `*.layout.json`, `manifest.json` and to write metric
//! records. Not a general-purpose validator — errors are reported with byte
//! offsets but recovery is not attempted.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj["k1"]["k2"]` chain; panics with a readable message if missing.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing JSON key {key:?}"))
    }
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Json, String> {
    let b = src.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(format!("trailing bytes at {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {s:?} at byte {start}"))
    }
}

/// Serialize a JSON value (compact).
pub fn write(v: &Json) -> String {
    let mut s = String::new();
    write_into(v, &mut s);
    s
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => write_str(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for metric records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\nyA"}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap()[1], Json::Num(2.5));
        assert_eq!(v.req("a").as_arr().unwrap()[2], Json::Num(-300.0));
        assert_eq!(v.req("b").req("c"), &Json::Bool(true));
        assert_eq!(v.req("s").as_str().unwrap(), "x\nyA");
        let re = parse(&write(&v)).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo ☃""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn integers_survive() {
        let v = parse("[861312, 6276]").unwrap();
        assert_eq!(v.as_arr().unwrap()[0].as_usize().unwrap(), 861312);
        assert_eq!(write(&v), "[861312,6276]");
    }
}
