//! Deterministic, seedable randomness: ChaCha20 stream + Gaussian sampling.
//!
//! DP-SGD's privacy guarantee assumes the Gaussian noise comes from a
//! cryptographically strong source; we implement the ChaCha20 block function
//! (RFC 8439, verified against the RFC test vector) as a counter-mode PRNG
//! and derive uniform/Gaussian variates from it.  No external crates.

/// ChaCha20-based PRNG.
pub struct ChaChaRng {
    key: [u32; 8],
    counter: u64,
    stream: u64,
    buf: [u32; 16],
    pos: usize,
}

#[inline(always)]
fn quarter(st: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    st[a] = st[a].wrapping_add(st[b]);
    st[d] = (st[d] ^ st[a]).rotate_left(16);
    st[c] = st[c].wrapping_add(st[d]);
    st[b] = (st[b] ^ st[c]).rotate_left(12);
    st[a] = st[a].wrapping_add(st[b]);
    st[d] = (st[d] ^ st[a]).rotate_left(8);
    st[c] = st[c].wrapping_add(st[d]);
    st[b] = (st[b] ^ st[c]).rotate_left(7);
}

/// The ChaCha20 block function (RFC 8439 §2.3).
pub fn chacha20_block(key: &[u32; 8], counter: u32, nonce: &[u32; 3]) -> [u32; 16] {
    let mut st = [0u32; 16];
    st[0..4].copy_from_slice(&[0x61707865, 0x3320646e, 0x79622d32, 0x6b206574]);
    st[4..12].copy_from_slice(key);
    st[12] = counter;
    st[13..16].copy_from_slice(nonce);
    let mut w = st;
    for _ in 0..10 {
        quarter(&mut w, 0, 4, 8, 12);
        quarter(&mut w, 1, 5, 9, 13);
        quarter(&mut w, 2, 6, 10, 14);
        quarter(&mut w, 3, 7, 11, 15);
        quarter(&mut w, 0, 5, 10, 15);
        quarter(&mut w, 1, 6, 11, 12);
        quarter(&mut w, 2, 7, 8, 13);
        quarter(&mut w, 3, 4, 9, 14);
    }
    for i in 0..16 {
        w[i] = w[i].wrapping_add(st[i]);
    }
    w
}

impl ChaChaRng {
    /// Seeded RNG; `stream` separates independent consumers (noise vs data
    /// sampling vs init) so adding one never perturbs another.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut key = [0u32; 8];
        key[0] = seed as u32;
        key[1] = (seed >> 32) as u32;
        key[2] = 0x9e3779b9; // golden-ratio padding so a zero seed is non-degenerate
        key[3] = 0x7f4a7c15;
        ChaChaRng { key, counter: 0, stream, buf: [0; 16], pos: 16 }
    }

    fn refill(&mut self) {
        let nonce = [self.stream as u32, (self.stream >> 32) as u32, 0];
        self.buf = chacha20_block(&self.key, self.counter as u32, &nonce);
        self.counter += 1;
        self.pos = 0;
    }

    pub fn next_u32(&mut self) -> u32 {
        if self.pos >= 16 {
            self.refill();
        }
        let v = self.buf[self.pos];
        self.pos += 1;
        v
    }

    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) << 32 | self.next_u32() as u64
    }

    /// Uniform in [0, 1) with 32 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        self.next_u32() as f64 / 4294967296.0
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (one variate per call, no cached
    /// spare — which is what makes [`ChaChaRng::state`] a complete
    /// snapshot of the generator).
    pub fn gaussian(&mut self) -> f64 {
        // open interval to avoid ln(0)
        let u1 = (self.next_u32() as f64 + 1.0) / 4294967297.0;
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with N(0, sigma^2) f32 samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f64) {
        for v in out.iter_mut() {
            *v = (self.gaussian() * sigma) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Snapshot the full generator state as 29 words (key, counter, stream,
    /// block buffer, buffer position) — enough to resume the exact draw
    /// sequence after [`ChaChaRng::from_state`].  Used by session-state
    /// checkpoints: a restored DP run must replay the same Poisson samples
    /// and the same Gaussian noise it would have drawn uninterrupted.
    pub fn state(&self) -> [u32; RNG_STATE_WORDS] {
        let mut w = [0u32; RNG_STATE_WORDS];
        w[..8].copy_from_slice(&self.key);
        w[8] = self.counter as u32;
        w[9] = (self.counter >> 32) as u32;
        w[10] = self.stream as u32;
        w[11] = (self.stream >> 32) as u32;
        w[12..28].copy_from_slice(&self.buf);
        w[28] = self.pos as u32;
        w
    }

    /// Rebuild a generator from a [`ChaChaRng::state`] snapshot.
    pub fn from_state(w: &[u32; RNG_STATE_WORDS]) -> ChaChaRng {
        let mut key = [0u32; 8];
        key.copy_from_slice(&w[..8]);
        let mut buf = [0u32; 16];
        buf.copy_from_slice(&w[12..28]);
        ChaChaRng {
            key,
            counter: w[8] as u64 | (w[9] as u64) << 32,
            stream: w[10] as u64 | (w[11] as u64) << 32,
            buf,
            pos: (w[28] as usize).min(16),
        }
    }
}

/// Word count of a [`ChaChaRng::state`] snapshot.
pub const RNG_STATE_WORDS: usize = 29;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rfc8439_block_vector() {
        // RFC 8439 §2.3.2 test vector
        let key: [u32; 8] = [
            0x03020100, 0x07060504, 0x0b0a0908, 0x0f0e0d0c, 0x13121110, 0x17161514,
            0x1b1a1918, 0x1f1e1d1c,
        ];
        let nonce: [u32; 3] = [0x09000000, 0x4a000000, 0x00000000];
        let out = chacha20_block(&key, 1, &nonce);
        assert_eq!(out[0], 0xe4e7f110);
        assert_eq!(out[1], 0x15593bd1);
        assert_eq!(out[15], 0x4e3c50a2);
    }

    #[test]
    fn deterministic_and_stream_separated() {
        let mut a = ChaChaRng::new(42, 0);
        let mut b = ChaChaRng::new(42, 0);
        let mut c = ChaChaRng::new(42, 1);
        let va: Vec<u32> = (0..40).map(|_| a.next_u32()).collect();
        let vb: Vec<u32> = (0..40).map(|_| b.next_u32()).collect();
        let vc: Vec<u32> = (0..40).map(|_| c.next_u32()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = ChaChaRng::new(7, 0);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn state_roundtrip_resumes_the_exact_sequence() {
        let mut r = ChaChaRng::new(99, 7);
        // land mid-buffer so pos/buf really matter
        for _ in 0..21 {
            r.next_u32();
        }
        let snap = r.state();
        let want: Vec<u64> = (0..100).map(|_| r.next_u64()).collect();
        let mut back = ChaChaRng::from_state(&snap);
        let got: Vec<u64> = (0..100).map(|_| back.next_u64()).collect();
        assert_eq!(got, want);
        // a fresh generator snapshots/restores too (pos = 16 edge)
        let fresh = ChaChaRng::new(1, 2);
        let mut a = ChaChaRng::from_state(&fresh.state());
        let mut b = ChaChaRng::new(1, 2);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_shuffle() {
        let mut r = ChaChaRng::new(1, 0);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
