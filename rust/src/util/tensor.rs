//! Host-side tensors: shape + typed buffer, the L3 <-> PJRT interchange type.

/// Element storage for a [`Tensor`].
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// Single scalar value (rank-0 or one-element tensors).
    pub fn item_f32(&self) -> f32 {
        assert_eq!(self.len(), 1, "item_f32 on non-scalar");
        self.as_f32()[0]
    }
}

/// L2 vector norm of a flat f32 slice.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// y += alpha * x (lengths must match).
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::f32(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32()[5], 1.0);
        let s = Tensor::scalar_f32(2.5);
        assert_eq!(s.item_f32(), 2.5);
        assert_eq!(s.len(), 1);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn math_helpers() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        let mut y = vec![1.0f32, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
    }
}
