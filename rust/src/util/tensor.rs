//! Host-side tensors: shape + typed buffer, the L3 <-> PJRT interchange type.

/// Element storage for a [`Tensor`].
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// Single scalar value (rank-0 or one-element tensors).
    pub fn item_f32(&self) -> f32 {
        assert_eq!(self.len(), 1, "item_f32 on non-scalar");
        self.as_f32()[0]
    }
}

/// Serialize f32s as little-endian bytes — the one byte layout shared by
/// the replica wire protocol and the session-state disk format.  A
/// `dp-sink` for the lint's taint pass: per-sample gradient data must be
/// clipped before it can cross onto the wire or the disk.
// fastdp-lint: dp-sink
pub fn f32s_to_le_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_le_bytes`]; the length must be a multiple of 4.
pub fn f32s_from_le_bytes(bytes: &[u8]) -> Vec<f32> {
    debug_assert_eq!(bytes.len() % 4, 0, "f32 byte buffer length must be a multiple of 4");
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// L2 vector norm of a flat f32 slice.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// y += alpha * x (lengths must match).
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::f32(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32()[5], 1.0);
        let s = Tensor::scalar_f32(2.5);
        assert_eq!(s.item_f32(), 2.5);
        assert_eq!(s.len(), 1);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn math_helpers() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        let mut y = vec![1.0f32, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
    }

    #[test]
    fn le_bytes_roundtrip_is_exact() {
        let xs = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, f32::MAX, -123.456];
        let bytes = f32s_to_le_bytes(&xs);
        assert_eq!(bytes.len(), xs.len() * 4);
        let back = f32s_from_le_bytes(&bytes);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&back), bits(&xs));
        assert!(f32s_from_le_bytes(&[]).is_empty());
    }
}
