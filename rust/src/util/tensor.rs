//! Host-side tensors: shape + typed buffer, the L3 <-> PJRT interchange type.

/// Element storage for a [`Tensor`].
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::f32(vec![], vec![v])
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// Single scalar value (rank-0 or one-element tensors).
    pub fn item_f32(&self) -> f32 {
        assert_eq!(self.len(), 1, "item_f32 on non-scalar");
        self.as_f32()[0]
    }
}

/// Serialize f32s as little-endian bytes — the one byte layout shared by
/// the replica wire protocol and the session-state disk format.  A
/// `dp-sink` for the lint's taint pass: per-sample gradient data must be
/// clipped before it can cross onto the wire or the disk.
// fastdp-lint: dp-sink
pub fn f32s_to_le_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_le_bytes`]; the length must be a multiple of 4.
pub fn f32s_from_le_bytes(bytes: &[u8]) -> Vec<f32> {
    debug_assert_eq!(bytes.len() % 4, 0, "f32 byte buffer length must be a multiple of 4");
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

/// Round an f32 to bf16 (stored in the low 16 bits) with round-to-nearest,
/// ties-to-even — the deterministic truncation the `bf16` wire codec uses.
/// NaNs canonicalize to a sign-preserving quiet NaN so encoding is a pure
/// function of the value; values past the largest finite bf16 round to
/// infinity (the clipped gradients the codec carries never get there).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return (((bits >> 16) & 0x8000) | 0x7fc0) as u16;
    }
    // classic RNE: add half an ulp of the 16-bit target, plus the parity
    // bit of the kept mantissa so exact ties round to the even neighbour
    ((bits.wrapping_add(0x7fff + ((bits >> 16) & 1))) >> 16) as u16
}

/// Widen a bf16 (low 16 bits) back to f32 — exact, every bf16 is an f32.
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Serialize f32s as little-endian bf16 — the compact replica wire codec
/// (2 bytes per element; see `coordinator::transport::WireCodec`).  Like
/// [`f32s_to_le_bytes`], a `dp-sink`: only clipped gradient data may cross
/// onto the wire through it.
// fastdp-lint: dp-sink
pub fn f32s_to_bf16_le_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for v in xs {
        out.extend_from_slice(&f32_to_bf16(*v).to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_bf16_le_bytes`]; the length must be a multiple of 2.
pub fn f32s_from_bf16_le_bytes(bytes: &[u8]) -> Vec<f32> {
    debug_assert_eq!(bytes.len() % 2, 0, "bf16 byte buffer length must be a multiple of 2");
    bytes.chunks_exact(2).map(|c| bf16_to_f32(u16::from_le_bytes([c[0], c[1]]))).collect()
}

/// L2 vector norm of a flat f32 slice.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// y += alpha * x (lengths must match).
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::f32(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32()[5], 1.0);
        let s = Tensor::scalar_f32(2.5);
        assert_eq!(s.item_f32(), 2.5);
        assert_eq!(s.len(), 1);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::f32(vec![2, 2], vec![1.0; 3]);
    }

    #[test]
    fn math_helpers() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        let mut y = vec![1.0f32, 2.0];
        axpy(&mut y, 2.0, &[10.0, 20.0]);
        assert_eq!(y, vec![21.0, 42.0]);
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // exactly representable values pass through
        for v in [0.0f32, -0.0, 1.0, -2.0, 0.5, 1.5] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)).to_bits(), v.to_bits(), "{v}");
        }
        // 1.0 + 2^-8 is exactly halfway between bf16 neighbours 1.0 and
        // 1.0078125; ties go to the even mantissa (1.0)
        let tie = f32::from_bits(0x3f80_8000);
        assert_eq!(f32_to_bf16(tie), 0x3f80);
        // one ulp above the tie rounds up
        let above = f32::from_bits(0x3f80_8001);
        assert_eq!(f32_to_bf16(above), 0x3f81);
        // the next tie (between 1.0078125 and 1.015625) has an odd low
        // mantissa bit and rounds up to even
        let tie2 = f32::from_bits(0x3f81_8000);
        assert_eq!(f32_to_bf16(tie2), 0x3f82);
        // infinities and NaN survive with their signs
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn bf16_relative_error_is_half_ulp() {
        // 8 effective mantissa bits -> RNE error <= 2^-9 relative... with
        // the implicit bit that is half an ulp of 2^-7, i.e. 2^-8
        let mut x = 0x2f1e_4d3fu32; // deterministic LCG seed
        for _ in 0..5000 {
            x = x.wrapping_mul(1664525).wrapping_add(1013904223);
            let v = f32::from_bits((x >> 9) | 0x3c00_0000) - 0.01; // ~[-0.01, 0.03)
            let back = bf16_to_f32(f32_to_bf16(v));
            let tol = 1.0 / 256.0 * v.abs().max(f32::MIN_POSITIVE);
            assert!((back - v).abs() <= tol, "{v} -> {back}");
        }
    }

    #[test]
    fn bf16_bytes_roundtrip_is_deterministic() {
        let xs = vec![0.0f32, -0.0, 1.5, -0.0625, 3.25e-3, -7.5e4];
        let bytes = f32s_to_bf16_le_bytes(&xs);
        assert_eq!(bytes.len(), xs.len() * 2);
        // encoding is a pure function: re-encoding decoded values is stable
        let back = f32s_from_bf16_le_bytes(&bytes);
        assert_eq!(f32s_to_bf16_le_bytes(&back), bytes);
        assert!(f32s_from_bf16_le_bytes(&[]).is_empty());
    }

    #[test]
    fn le_bytes_roundtrip_is_exact() {
        let xs = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, f32::MAX, -123.456];
        let bytes = f32s_to_le_bytes(&xs);
        assert_eq!(bytes.len(), xs.len() * 4);
        let back = f32s_from_le_bytes(&bytes);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&back), bits(&xs));
        assert!(f32s_from_le_bytes(&[]).is_empty());
    }
}
