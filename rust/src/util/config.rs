//! TOML-subset parser for run configuration files.
//!
//! Supports: `[table]` / `[table.sub]` headers, `key = value` with string,
//! integer, float, boolean and homogeneous-array values, `#` comments, and
//! bare/quoted keys.  That covers every config in `configs/`; exotic TOML
//! (dates, inline tables, multiline strings) is intentionally rejected with
//! a line-numbered error.

use std::collections::BTreeMap;

/// A configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat map of `table.key` -> value.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(src: &str) -> Result<Config, String> {
        let mut values = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated table header", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    return Err(format!("line {}: empty table name", lineno + 1));
                }
                prefix = format!("{name}.");
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim().trim_matches('"').to_string();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            values.insert(format!("{prefix}{key}"), val);
        }
        Ok(Config { values })
    }

    pub fn load(path: &str) -> Result<Config, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Config::parse(&src)
    }

    /// Overlay CLI `--set key=value` overrides (parsed with TOML value rules).
    pub fn set(&mut self, key: &str, raw: &str) -> Result<(), String> {
        let v = parse_value(raw).unwrap_or_else(|_| Value::Str(raw.to_string()));
        self.values.insert(key.to_string(), v);
        Ok(())
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.values
            .get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }
    pub fn i64(&self, key: &str, default: i64) -> i64 {
        self.values.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }
    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.values.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn bool(&self, key: &str, default: bool) -> bool {
        self.values.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
    pub fn require(&self, key: &str) -> Result<&Value, String> {
        self.values.get(key).ok_or_else(|| format!("missing config key {key:?}"))
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let mut out = Vec::new();
        for part in split_top_level(inner) {
            out.push(parse_value(part.trim())?);
        }
        return Ok(Value::Arr(out));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let (mut depth, mut start, mut in_str) = (0usize, 0usize, false);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if depth == 0 && !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_types() {
        let cfg = Config::parse(
            r#"
            # training config
            name = "quickstart"
            [train]
            steps = 100          # comment
            lr = 5e-3
            dp = true
            eps = [1, 2, 4, 8]
            [train.noise]
            sigma = 1.1
            "#,
        )
        .unwrap();
        assert_eq!(cfg.str("name", ""), "quickstart");
        assert_eq!(cfg.i64("train.steps", 0), 100);
        assert!((cfg.f64("train.lr", 0.0) - 5e-3).abs() < 1e-12);
        assert!(cfg.bool("train.dp", false));
        assert_eq!(cfg.f64("train.noise.sigma", 0.0), 1.1);
        match cfg.values.get("train.eps").unwrap() {
            Value::Arr(a) => assert_eq!(a.len(), 4),
            _ => panic!(),
        }
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = Config::parse("x 1").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = Config::parse("[t\nx = 1").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn string_with_hash_and_defaults() {
        let cfg = Config::parse("s = \"a # b\"").unwrap();
        assert_eq!(cfg.str("s", ""), "a # b");
        assert_eq!(cfg.i64("missing", 7), 7);
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = Config::parse("a = 1").unwrap();
        cfg.set("a", "2").unwrap();
        cfg.set("b.c", "\"hi\"").unwrap();
        assert_eq!(cfg.i64("a", 0), 2);
        assert_eq!(cfg.str("b.c", ""), "hi");
    }
}
