//! Markdown-ish table printer for bench output (paper-style rows).

/// Accumulates rows and prints a column-aligned table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push(' ');
                s.push_str(c);
                for _ in c.chars().count()..w[i] {
                    s.push(' ');
                }
                s.push_str(" |");
            }
            s
        };
        let mut out = line(&self.header);
        out.push('\n');
        let sep: Vec<String> = w.iter().map(|n| "-".repeat(*n)).collect();
        out.push_str(&line(&sep));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with fixed decimals (bench-table cells).
pub fn fmt(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["method", "acc"]);
        t.row(vec!["DP-BiTFiT".into(), "92.4".into()]);
        t.row(vec!["full".into(), "92.1".into()]);
        let r = t.render();
        assert!(r.contains("| method    | acc  |"), "{r}");
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
