//! Dependency-free substrates: JSON, TOML-subset config, ChaCha20 RNG,
//! host tensors, CLI args, table rendering, timers.
//!
//! This environment has no serde/clap/rand/criterion — these modules
//! implement the subsets the system needs, each with its own unit tests.

pub mod args;
pub mod config;
pub mod json;
pub mod rng;
pub mod table;
pub mod tensor;

use std::time::Instant;

/// A labelled wall-clock timer accumulating per-phase durations.
#[derive(Debug, Default)]
pub struct Timers {
    entries: std::collections::BTreeMap<String, (f64, u64)>,
}

impl Timers {
    pub fn new() -> Timers {
        Timers::default()
    }

    /// Time a closure under `label`.
    pub fn time<T>(&mut self, label: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(label, t0.elapsed().as_secs_f64());
        out
    }

    /// Record an externally measured duration (avoids borrow conflicts on
    /// `&mut self` hot paths).
    pub fn add(&mut self, label: &str, seconds: f64) {
        let e = self.entries.entry(label.to_string()).or_insert((0.0, 0));
        e.0 += seconds;
        e.1 += 1;
    }

    pub fn total(&self, label: &str) -> f64 {
        self.entries.get(label).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn count(&self, label: &str) -> u64 {
        self.entries.get(label).map(|e| e.1).unwrap_or(0)
    }

    /// `label -> (total_seconds, calls)` report, sorted by total desc.
    pub fn report(&self) -> Vec<(String, f64, u64)> {
        let mut v: Vec<_> = self
            .entries
            .iter()
            .map(|(k, (t, n))| (k.clone(), *t, *n))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

/// Peak resident-set size of this process in bytes (Linux, /proc).
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timers_accumulate() {
        let mut t = Timers::new();
        let x = t.time("work", || 21 * 2);
        assert_eq!(x, 42);
        t.time("work", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert_eq!(t.count("work"), 2);
        assert!(t.total("work") > 0.0);
        assert_eq!(t.report()[0].0, "work");
    }

    #[test]
    fn rss_readable() {
        let rss = peak_rss_bytes().unwrap();
        assert!(rss > 1 << 20); // more than 1 MiB
    }
}
