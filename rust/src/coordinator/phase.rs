//! Two-phase X+BiTFiT training (paper App. A.2.2, Tables 14-16).
//!
//! Phase 1 runs DP **full** fine-tuning for X "epochs" (steps here), phase 2
//! switches to DP-BiTFiT for the remainder.  The scheduler remaps the full
//! parameter vector between the two artifacts' (frozen, trainable) splits
//! via the shared layout, and carries the RDP accountant across the switch
//! so the privacy budget composes over the entire run.

use anyhow::Result;

use super::task_data::TaskData;
use super::trainer::{StepStats, Trainer, TrainerConfig};
use crate::runtime::Runtime;

/// Configuration for an X+BiTFiT run.
#[derive(Debug, Clone)]
pub struct TwoPhaseConfig {
    /// Phase-1 artifact (a DP full fine-tuning step).
    pub full_artifact: String,
    /// Phase-2 artifact (the DP-BiTFiT step).
    pub bitfit_artifact: String,
    /// Steps spent in phase 1 ("X" in X+BiTFiT; 0 = pure BiTFiT).
    pub full_steps: u64,
    pub total_steps: u64,
    /// Learning rates per phase (the paper tunes them separately, Table 14).
    pub full_lr: f64,
    pub bitfit_lr: f64,
    pub base: TrainerConfig,
}

/// Outcome of a two-phase run.
pub struct TwoPhaseResult {
    pub params: Vec<f32>,
    pub losses: Vec<f64>,
    pub epsilon: f64,
}

/// Run X+BiTFiT; `params` is the (pretrained) starting full vector.
pub fn run_two_phase(
    rt: &mut Runtime,
    cfg: &TwoPhaseConfig,
    data: &TaskData,
    params: Vec<f32>,
    mut on_step: impl FnMut(&str, StepStats),
) -> Result<TwoPhaseResult> {
    let mut losses = Vec::new();
    let mut params = params;
    let mut accountant = None;

    if cfg.full_steps > 0 {
        let mut tc = cfg.base.clone();
        tc.artifact = cfg.full_artifact.clone();
        tc.lr = cfg.full_lr;
        let mut t = Trainer::new(rt, tc, data.len(), Some(params))?;
        for _ in 0..cfg.full_steps.min(cfg.total_steps) {
            let s = t.train_step(data)?;
            losses.push(s.loss);
            on_step("full", s);
        }
        params = t.full_params();
        accountant = t.accountant.take();
    }

    let remaining = cfg.total_steps.saturating_sub(cfg.full_steps);
    let mut tc = cfg.base.clone();
    tc.artifact = cfg.bitfit_artifact.clone();
    tc.lr = cfg.bitfit_lr;
    let mut t = Trainer::new(rt, tc, data.len(), Some(params))?;
    if let Some(acc) = accountant {
        // carry the spent budget into phase 2 (composition over the run)
        t.accountant = Some(acc);
    }
    for _ in 0..remaining {
        let s = t.train_step(data)?;
        losses.push(s.loss);
        on_step("bitfit", s);
    }
    let epsilon = t.accountant.as_ref().map(|a| a.epsilon().0).unwrap_or(0.0);
    Ok(TwoPhaseResult { params: t.full_params(), losses, epsilon })
}
