//! Optimizers over flat parameter vectors (the descent of Alg. 1 line 11).
//!
//! The private gradient arrives from the artifact + noise pipeline already
//! averaged over the logical batch; these are standard SGD/Adam/AdamW
//! updates, kept in rust so the optimizer state never round-trips through
//! the artifact.

/// Optimizer family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimKind {
    Sgd,
    /// DP-Adam (the paper's text-classification optimizer).
    Adam,
    /// DP-AdamW (the paper's E2E generation optimizer).
    AdamW,
}

impl OptimKind {
    pub fn parse(s: &str) -> Option<OptimKind> {
        match s {
            "sgd" => Some(OptimKind::Sgd),
            "adam" => Some(OptimKind::Adam),
            "adamw" => Some(OptimKind::AdamW),
            _ => None,
        }
    }
}

/// Flat-vector optimizer with internal state.
#[derive(Debug, Clone)]
pub struct Optimizer {
    pub kind: OptimKind,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Optimizer {
    pub fn new(kind: OptimKind, lr: f64, n: usize) -> Optimizer {
        Optimizer {
            kind,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: if kind == OptimKind::AdamW { 0.01 } else { 0.0 },
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    /// Number of parameters this optimizer was sized for.
    pub fn len(&self) -> usize {
        self.m.len()
    }

    /// Internal state `(t, m, v)` for session-state checkpoints.
    pub fn state(&self) -> (u64, &[f64], &[f64]) {
        (self.t, &self.m, &self.v)
    }

    /// Restore internal state captured by [`Optimizer::state`].  Fails if
    /// the moment vectors are sized for a different parameter count.
    pub fn restore(&mut self, t: u64, m: Vec<f64>, v: Vec<f64>) -> Result<(), String> {
        if m.len() != self.m.len() || v.len() != self.v.len() {
            return Err(format!(
                "optimizer snapshot sized ({}, {}), optimizer has {} params",
                m.len(),
                v.len(),
                self.m.len()
            ));
        }
        self.t = t;
        self.m = m;
        self.v = v;
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }

    /// Apply one update with the current learning rate.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        self.step_lr(params, grad, self.lr)
    }

    /// Apply one update with an explicit learning rate (schedules).  A
    /// `dp-sink`: only clipped (and, for DP runs, noised) aggregate
    /// gradients may reach the optimizer state.
    // fastdp-lint: dp-sink
    pub fn step_lr(&mut self, params: &mut [f32], grad: &[f32], lr: f64) {
        assert_eq!(params.len(), grad.len());
        assert_eq!(params.len(), self.m.len(), "optimizer sized for different params");
        self.t += 1;
        match self.kind {
            OptimKind::Sgd => {
                for (p, &g) in params.iter_mut().zip(grad) {
                    *p -= (lr * g as f64) as f32;
                }
            }
            OptimKind::Adam | OptimKind::AdamW => {
                let bc1 = 1.0 - self.beta1.powi(self.t as i32);
                let bc2 = 1.0 - self.beta2.powi(self.t as i32);
                for i in 0..params.len() {
                    let g = grad[i] as f64;
                    self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
                    self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
                    let mhat = self.m[i] / bc1;
                    let vhat = self.v[i] / bc2;
                    let mut upd = lr * mhat / (vhat.sqrt() + self.eps);
                    if self.kind == OptimKind::AdamW {
                        upd += lr * self.weight_decay * params[i] as f64;
                    }
                    params[i] -= upd as f32;
                }
            }
        }
    }
}

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy)]
pub enum LrSchedule {
    Constant,
    /// Linear warmup over `warmup` steps then constant (the paper uses no
    /// decay — Table 9 "learning rate decay: No").
    Warmup { warmup: u64 },
}

impl LrSchedule {
    pub fn at(&self, base_lr: f64, step: u64) -> f64 {
        match self {
            LrSchedule::Constant => base_lr,
            LrSchedule::Warmup { warmup } => {
                if *warmup == 0 || step >= *warmup {
                    base_lr
                } else {
                    base_lr * (step + 1) as f64 / *warmup as f64
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_matches_hand_computation() {
        let mut o = Optimizer::new(OptimKind::Sgd, 0.1, 2);
        let mut p = vec![1.0f32, -2.0];
        o.step(&mut p, &[10.0, -10.0]);
        assert!((p[0] - 0.0).abs() < 1e-6);
        assert!((p[1] - -1.0).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, |first update| ~ lr regardless of grad scale
        for &g in &[1e-3f32, 1.0, 1e3] {
            let mut o = Optimizer::new(OptimKind::Adam, 0.01, 1);
            let mut p = vec![0.0f32];
            o.step(&mut p, &[g]);
            assert!((p[0].abs() - 0.01).abs() < 1e-4, "g={g} p={}", p[0]);
        }
    }

    #[test]
    fn adamw_decays_weights() {
        let mut o = Optimizer::new(OptimKind::AdamW, 0.1, 1);
        let mut p_adamw = vec![10.0f32];
        o.step(&mut p_adamw, &[0.0]);
        // zero gradient: AdamW still shrinks the weight, Adam does not
        let mut o2 = Optimizer::new(OptimKind::Adam, 0.1, 1);
        let mut p_adam = vec![10.0f32];
        o2.step(&mut p_adam, &[0.0]);
        assert!(p_adamw[0] < 10.0);
        assert_eq!(p_adam[0], 10.0);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // minimize (p - 3)^2
        let mut o = Optimizer::new(OptimKind::Adam, 0.05, 1);
        let mut p = vec![0.0f32];
        for _ in 0..500 {
            let g = 2.0 * (p[0] - 3.0);
            o.step(&mut p, &[g]);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "{}", p[0]);
    }

    #[test]
    fn warmup_schedule() {
        let s = LrSchedule::Warmup { warmup: 10 };
        assert!((s.at(1.0, 0) - 0.1).abs() < 1e-12);
        assert!((s.at(1.0, 4) - 0.5).abs() < 1e-12);
        assert_eq!(s.at(1.0, 10), 1.0);
        assert_eq!(s.at(1.0, 100), 1.0);
        assert_eq!(LrSchedule::Constant.at(0.3, 5), 0.3);
    }
}
