//! Workload construction: manifest model config -> synthetic dataset.

use anyhow::{Context, Result};

use super::task_data::TaskData;
use crate::data::synth_image;
use crate::data::synth_text::{self, GlueTask};
use crate::data::GenExample;
use crate::runtime::Runtime;

/// Model-config fields needed to shape a dataset.
#[derive(Debug, Clone)]
pub struct ModelShape {
    pub kind: String,
    pub t: usize,
    pub vocab: usize,
    pub img: usize,
    pub n_cls: usize,
    pub n_out: usize,
}

/// Extract the dataset-relevant shape of a model from the manifest.
pub fn model_shape(rt: &Runtime, model: &str) -> Result<ModelShape> {
    let entry = rt
        .manifest
        .models
        .get(model)
        .with_context(|| format!("unknown model {model:?}"))?;
    let cfg = &entry.cfg;
    let g = |k: &str| cfg.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
    Ok(ModelShape {
        kind: entry.kind.clone(),
        t: g("t"),
        vocab: g("vocab"),
        img: g("img"),
        n_cls: g("n_cls"),
        n_out: g("n_out"),
    })
}

/// Build a dataset for (model, task).
///
/// Tasks: `sst2 | qnli | qqp | mnli | pretrain-cls | pretrain-lm | e2e |
/// cifar | cifar-pretrain | celeba`.
pub fn build(rt: &Runtime, model: &str, task: &str, n: usize, seed: u64) -> Result<TaskData> {
    let shape = model_shape(rt, model)?;
    match task {
        "sst2" | "qnli" | "qqp" | "mnli" => {
            let gt = match task {
                "sst2" => GlueTask::Sst2,
                "qnli" => GlueTask::Qnli,
                "qqp" => GlueTask::Qqp,
                _ => GlueTask::Mnli,
            };
            let tok = synth_text::tokenizer(shape.vocab);
            Ok(TaskData::Text { examples: synth_text::glue(gt, n, shape.t, &tok, seed), t: shape.t })
        }
        "pretrain-cls" => {
            let tok = synth_text::tokenizer(shape.vocab);
            Ok(TaskData::Text {
                examples: synth_text::pretrain_cls(n, shape.t, &tok, seed),
                t: shape.t,
            })
        }
        "pretrain-lm" => {
            let tok = synth_text::tokenizer(shape.vocab);
            Ok(TaskData::Lm { examples: synth_text::pretrain_lm(n, shape.t, &tok, seed), t: shape.t })
        }
        "e2e" => {
            let (data, _) = build_e2e(rt, model, n, seed)?;
            Ok(data)
        }
        "cifar" | "cifar-pretrain" => {
            anyhow::ensure!(shape.kind == "vit", "cifar task needs a vit model");
            let shift = task == "cifar-pretrain";
            Ok(TaskData::Image {
                examples: synth_image::shapes(n, shape.img, shape.n_cls, 0.15, shift, seed),
                size: shape.img,
                n_attrs: 0,
            })
        }
        "celeba" => {
            anyhow::ensure!(shape.kind == "cnn", "celeba task needs a cnn model");
            Ok(TaskData::Image {
                examples: synth_image::attributes(n, shape.img, 0.1, seed),
                size: shape.img,
                n_attrs: shape.n_out,
            })
        }
        _ => anyhow::bail!("unknown task {task:?}"),
    }
}

/// E2E generation data plus the reference sets for NLG metrics.
pub fn build_e2e(rt: &Runtime, model: &str, n: usize, seed: u64) -> Result<(TaskData, Vec<GenExample>)> {
    let shape = model_shape(rt, model)?;
    anyhow::ensure!(shape.kind == "lm", "e2e task needs an lm model");
    let tok = synth_text::tokenizer(shape.vocab);
    let gen = synth_text::e2e(n, shape.t, &tok, seed);
    let data = TaskData::Lm {
        examples: gen.iter().map(|g| g.lm.clone()).collect(),
        t: shape.t,
    };
    Ok((data, gen))
}

/// Default task for a model kind (used by the CLI when --task is omitted).
pub fn default_task(kind: &str) -> &'static str {
    match kind {
        "cls" => "sst2",
        "lm" => "e2e",
        "vit" => "cifar",
        _ => "celeba",
    }
}
