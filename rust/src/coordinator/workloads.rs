//! Workload construction: model shape -> synthetic dataset.
//!
//! Shapes come from the engine (`Engine::model_info`), so datasets build
//! identically against the PJRT and interpreter backends.

use crate::data::synth_image;
use crate::data::synth_text::{self, GlueTask};
use crate::data::GenExample;
use crate::engine::EngineError;

use super::task_data::TaskData;

/// Model-config fields needed to shape a dataset.
#[derive(Debug, Clone)]
pub struct ModelShape {
    pub kind: String,
    pub t: usize,
    pub vocab: usize,
    pub img: usize,
    pub n_cls: usize,
    pub n_out: usize,
}

/// Build a dataset for (model shape, task).
///
/// Tasks: `sst2 | qnli | qqp | mnli | pretrain-cls | pretrain-lm | e2e |
/// cifar | cifar-pretrain | celeba`.
pub fn build(shape: &ModelShape, task: &str, n: usize, seed: u64) -> Result<TaskData, EngineError> {
    match task {
        "sst2" | "qnli" | "qqp" | "mnli" => {
            let gt = match task {
                "sst2" => GlueTask::Sst2,
                "qnli" => GlueTask::Qnli,
                "qqp" => GlueTask::Qqp,
                _ => GlueTask::Mnli,
            };
            let tok = synth_text::tokenizer(shape.vocab);
            Ok(TaskData::Text { examples: synth_text::glue(gt, n, shape.t, &tok, seed), t: shape.t })
        }
        "pretrain-cls" => {
            let tok = synth_text::tokenizer(shape.vocab);
            Ok(TaskData::Text {
                examples: synth_text::pretrain_cls(n, shape.t, &tok, seed),
                t: shape.t,
            })
        }
        "pretrain-lm" => {
            let tok = synth_text::tokenizer(shape.vocab);
            Ok(TaskData::Lm { examples: synth_text::pretrain_lm(n, shape.t, &tok, seed), t: shape.t })
        }
        "e2e" => {
            let (data, _) = build_e2e(shape, n, seed)?;
            Ok(data)
        }
        "cifar" | "cifar-pretrain" => {
            if shape.kind != "vit" {
                return Err(EngineError::Data(format!(
                    "cifar task needs a vit model, got kind {:?}",
                    shape.kind
                )));
            }
            let shift = task == "cifar-pretrain";
            Ok(TaskData::Image {
                examples: synth_image::shapes(n, shape.img, shape.n_cls, 0.15, shift, seed),
                size: shape.img,
                n_attrs: 0,
            })
        }
        "celeba" => {
            if shape.kind != "cnn" {
                return Err(EngineError::Data(format!(
                    "celeba task needs a cnn model, got kind {:?}",
                    shape.kind
                )));
            }
            Ok(TaskData::Image {
                examples: synth_image::attributes(n, shape.img, 0.1, seed),
                size: shape.img,
                n_attrs: shape.n_out,
            })
        }
        _ => Err(EngineError::Data(format!("unknown task {task:?}"))),
    }
}

/// E2E generation data plus the reference sets for NLG metrics.
pub fn build_e2e(
    shape: &ModelShape,
    n: usize,
    seed: u64,
) -> Result<(TaskData, Vec<GenExample>), EngineError> {
    if shape.kind != "lm" {
        return Err(EngineError::Data(format!(
            "e2e task needs an lm model, got kind {:?}",
            shape.kind
        )));
    }
    let tok = synth_text::tokenizer(shape.vocab);
    let gen = synth_text::e2e(n, shape.t, &tok, seed);
    let data = TaskData::Lm { examples: gen.iter().map(|g| g.lm.clone()).collect(), t: shape.t };
    Ok((data, gen))
}

/// Default task for a model kind (used when `--task` / `task(...)` is
/// omitted).
pub fn default_task(kind: &str) -> &'static str {
    match kind {
        "cls" => "sst2",
        "lm" => "e2e",
        "vit" => "cifar",
        _ => "celeba",
    }
}
