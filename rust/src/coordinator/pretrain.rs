//! Non-private pretraining on public synthetic corpora + checkpoint cache.
//!
//! The paper fine-tunes *pretrained* foundation models; we reproduce the
//! structure by pretraining each small model once (standard, non-DP — the
//! paper's assumption is public pretraining data) and caching the
//! checkpoint under `artifacts/pretrained/`.  Examples and benches share
//! the cache, so the expensive phase runs once per (model, task, steps).

use anyhow::Result;

use super::checkpoint::Checkpoint;
use super::optim::OptimKind;
use super::trainer::{Trainer, TrainerConfig};
use super::workloads;
use crate::runtime::Runtime;

/// Pretraining recipe.
#[derive(Debug, Clone)]
pub struct PretrainSpec {
    pub model: String,
    /// `pretrain-cls` / `pretrain-lm` / `cifar-pretrain` / `celeba`.
    pub task: String,
    pub steps: usize,
    pub batch: usize,
    pub lr: f64,
    pub n: usize,
    pub seed: u64,
}

impl PretrainSpec {
    pub fn new(model: &str, task: &str) -> PretrainSpec {
        PretrainSpec {
            model: model.to_string(),
            task: task.to_string(),
            steps: 150,
            batch: 64,
            lr: 1e-3,
            n: 8192,
            seed: 7,
        }
    }

    fn cache_path(&self, rt: &Runtime) -> std::path::PathBuf {
        rt.artifact_dir().join("pretrained").join(format!(
            "{}__{}__{}s.ckpt",
            self.model, self.task, self.steps
        ))
    }
}

/// Pretrain (or load cached) and return the full parameter vector.
///
/// Pass `quiet=false` to log progress lines.
pub fn pretrained_params(rt: &mut Runtime, spec: &PretrainSpec, quiet: bool) -> Result<Vec<f32>> {
    let path = spec.cache_path(rt);
    if let Ok(ck) = Checkpoint::load(&path) {
        if ck.model == spec.model && ck.step == spec.steps as u64 {
            if !quiet {
                println!("pretrained checkpoint: {} (cached)", path.display());
            }
            return Ok(ck.params);
        }
    }
    let artifact = format!("{}__nondp-full", spec.model);
    let data = workloads::build(rt, &spec.model, &spec.task, spec.n, spec.seed)?;
    let mut tc = TrainerConfig::new(&artifact);
    tc.logical_batch = spec.batch;
    tc.lr = spec.lr;
    tc.optim = OptimKind::Adam;
    tc.seed = spec.seed;
    let mut t = Trainer::new(rt, tc, data.len(), None)?;
    if !quiet {
        println!("pretraining {} on {} for {} steps ...", spec.model, spec.task, spec.steps);
    }
    for i in 0..spec.steps {
        let s = t.train_step(&data)?;
        if !quiet && (i % 25 == 0 || i + 1 == spec.steps) {
            println!("  pretrain step {:>4}  loss {:.4}", s.step, s.loss);
        }
    }
    let params = t.full_params();
    Checkpoint { model: spec.model.clone(), step: spec.steps as u64, params: params.clone() }
        .save(&path)?;
    if !quiet {
        println!("cached pretrained checkpoint at {}", path.display());
    }
    Ok(params)
}

/// Reset a model's head leaves to their deterministic init values
/// (downstream tasks replace the classification head, §4.3).
pub fn reset_head(rt: &Runtime, model: &str, params: &mut [f32]) -> Result<()> {
    let layout = rt.layout(model)?;
    let init = rt.init_params(model)?;
    layout.copy_head(params, &init);
    Ok(())
}
