//! Non-private pretraining on public synthetic corpora + checkpoint cache.
//!
//! The paper fine-tunes *pretrained* foundation models; we reproduce the
//! structure by pretraining each small model once (standard, non-DP — the
//! paper's assumption is public pretraining data) and caching the
//! checkpoint under `<cache_dir>/pretrained/` when the backend has an
//! on-disk home (PJRT).  The interpreter backend has no artifact directory
//! and retrains on demand — its reference models are small enough that this
//! is cheap.

use crate::coordinator::checkpoint::Checkpoint;
use crate::engine::{Engine, EngineError, JobSpec, Method, OptimKind};

/// Pretraining recipe.
#[derive(Debug, Clone)]
pub struct PretrainSpec {
    pub model: String,
    /// `pretrain-cls` / `pretrain-lm` / `cifar-pretrain` / `celeba`.
    pub task: String,
    pub steps: usize,
    pub batch: usize,
    pub lr: f64,
    pub n: usize,
    pub seed: u64,
}

impl PretrainSpec {
    pub fn new(model: &str, task: &str) -> PretrainSpec {
        PretrainSpec {
            model: model.to_string(),
            task: task.to_string(),
            steps: 150,
            batch: 64,
            lr: 1e-3,
            n: 8192,
            seed: 7,
        }
    }

    /// The full recipe identity — both cache layers key on this, so specs
    /// differing in any hyperparameter never collide.
    fn recipe(&self) -> String {
        format!(
            "{}__{}__{}s__n{}__b{}__lr{:e}__s{}",
            self.model, self.task, self.steps, self.n, self.batch, self.lr, self.seed
        )
    }

    fn cache_path(&self, engine: &Engine) -> Option<std::path::PathBuf> {
        engine.cache_dir().map(|d| d.join("pretrained").join(format!("{}.ckpt", self.recipe())))
    }
}

/// Pretrain (or load cached) and return the full parameter vector.
///
/// Pass `quiet=false` to log progress lines.
pub fn pretrained_params(
    engine: &mut Engine,
    spec: &PretrainSpec,
    quiet: bool,
) -> Result<Vec<f32>, EngineError> {
    let memo_key = format!("pretrain/{}", spec.recipe());
    if let Some(params) = engine.cached_params(&memo_key) {
        return Ok(params);
    }
    let cache = spec.cache_path(engine);
    if let Some(path) = &cache {
        if let Ok(ck) = Checkpoint::load(path) {
            if ck.model == spec.model && ck.step == spec.steps as u64 {
                if !quiet {
                    println!("pretrained checkpoint: {} (cached)", path.display());
                }
                engine.cache_params(&memo_key, ck.params.clone());
                return Ok(ck.params);
            }
        }
    }
    let data = engine.dataset(&spec.model, &spec.task, spec.n, spec.seed)?;
    let job = JobSpec::builder(&spec.model, Method::Full { ghost: true })
        .task(&spec.task)
        .optim(OptimKind::Adam)
        .lr(spec.lr)
        .batch(spec.batch)
        .steps(spec.steps.max(1) as u64)
        .n_train(spec.n)
        .seed(spec.seed)
        .name(&format!("{}__pretrain", spec.model))
        .build()?;
    let mut session = engine.session(&job)?;
    if !quiet {
        println!("pretraining {} on {} for {} steps ...", spec.model, spec.task, spec.steps);
    }
    for i in 0..spec.steps {
        let s = session.run_step(&data)?;
        if !quiet && (i % 25 == 0 || i + 1 == spec.steps) {
            println!("  pretrain step {:>4}  loss {:.4}", s.step, s.loss);
        }
    }
    let params = session.full_params();
    if let Some(path) = &cache {
        Checkpoint { model: spec.model.clone(), step: spec.steps as u64, params: params.clone() }
            .save(path)
            .map_err(|e| EngineError::Checkpoint(format!("{e:#}")))?;
        if !quiet {
            println!("cached pretrained checkpoint at {}", path.display());
        }
    }
    engine.cache_params(&memo_key, params.clone());
    Ok(params)
}
