//! Dataset -> artifact-input assembly (fixed-shape microbatches + masks).

use crate::data::{ImageExample, LmExample, TextExample};
use crate::util::tensor::Tensor;

/// A training/eval dataset in one of the three task shapes.
#[derive(Debug, Clone)]
pub enum TaskData {
    /// Classification over token sequences (x: i32[B,T], y: i32[B]).
    Text { examples: Vec<TextExample>, t: usize },
    /// Causal LM (x: i32[B,T], y: i32[B,T]).
    Lm { examples: Vec<LmExample>, t: usize },
    /// Images (x: f32[B,S,S,3]; y: i32[B] or f32[B,A] when multi-label).
    Image { examples: Vec<ImageExample>, size: usize, n_attrs: usize },
}

impl TaskData {
    pub fn len(&self) -> usize {
        match self {
            TaskData::Text { examples, .. } => examples.len(),
            TaskData::Lm { examples, .. } => examples.len(),
            TaskData::Image { examples, .. } => examples.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Assemble a fixed-size microbatch from `idxs` (padded + masked).
    ///
    /// Returns (x, y, mask): rows beyond `idxs.len()` are zero-filled with
    /// mask 0, so artifacts see a constant shape `b` while the clipped-sum
    /// semantics stay exact (masked rows contribute exactly zero).
    pub fn fill(&self, idxs: &[usize], b: usize) -> (Tensor, Tensor, Tensor) {
        assert!(idxs.len() <= b, "microbatch too large");
        let mut mask = vec![0.0f32; b];
        for m in mask.iter_mut().take(idxs.len()) {
            *m = 1.0;
        }
        let mask_t = Tensor::f32(vec![b], mask);
        match self {
            TaskData::Text { examples, t } => {
                let mut x = vec![0i32; b * t];
                let mut y = vec![0i32; b];
                for (row, &i) in idxs.iter().enumerate() {
                    x[row * t..(row + 1) * t].copy_from_slice(&examples[i].tokens);
                    y[row] = examples[i].label;
                }
                (Tensor::i32(vec![b, *t], x), Tensor::i32(vec![b], y), mask_t)
            }
            TaskData::Lm { examples, t } => {
                let mut x = vec![0i32; b * t];
                let mut y = vec![0i32; b * t];
                for (row, &i) in idxs.iter().enumerate() {
                    x[row * t..(row + 1) * t].copy_from_slice(&examples[i].input);
                    y[row * t..(row + 1) * t].copy_from_slice(&examples[i].target);
                }
                (
                    Tensor::i32(vec![b, *t], x),
                    Tensor::i32(vec![b, *t], y),
                    mask_t,
                )
            }
            TaskData::Image { examples, size, n_attrs } => {
                let pix = size * size * 3;
                let mut x = vec![0.0f32; b * pix];
                for (row, &i) in idxs.iter().enumerate() {
                    x[row * pix..(row + 1) * pix].copy_from_slice(&examples[i].pixels);
                }
                let x_t = Tensor::f32(vec![b, *size, *size, 3], x);
                let y_t = if *n_attrs > 0 {
                    let mut y = vec![0.0f32; b * n_attrs];
                    for (row, &i) in idxs.iter().enumerate() {
                        y[row * n_attrs..(row + 1) * n_attrs]
                            .copy_from_slice(&examples[i].attributes);
                    }
                    Tensor::f32(vec![b, *n_attrs], y)
                } else {
                    let mut y = vec![0i32; b];
                    for (row, &i) in idxs.iter().enumerate() {
                        y[row] = examples[i].label;
                    }
                    Tensor::i32(vec![b], y)
                };
                (x_t, y_t, mask_t)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_fill_pads_and_masks() {
        let data = TaskData::Text {
            examples: vec![
                TextExample { tokens: vec![1, 2, 3], label: 1 },
                TextExample { tokens: vec![4, 5, 6], label: 0 },
            ],
            t: 3,
        };
        let (x, y, mask) = data.fill(&[1], 4);
        assert_eq!(x.shape, vec![4, 3]);
        assert_eq!(&x.as_i32()[..3], &[4, 5, 6]);
        assert_eq!(&x.as_i32()[3..], &[0; 9]);
        assert_eq!(y.as_i32(), &[0, 0, 0, 0]);
        assert_eq!(mask.as_f32(), &[1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn image_multilabel_fill() {
        let data = TaskData::Image {
            examples: vec![ImageExample {
                pixels: vec![0.5; 4 * 4 * 3],
                label: -1,
                attributes: vec![1.0, 0.0],
            }],
            size: 4,
            n_attrs: 2,
        };
        let (x, y, mask) = data.fill(&[0], 2);
        assert_eq!(x.shape, vec![2, 4, 4, 3]);
        assert_eq!(y.shape, vec![2, 2]);
        assert_eq!(y.as_f32(), &[1.0, 0.0, 0.0, 0.0]);
        assert_eq!(mask.as_f32(), &[1.0, 0.0]);
    }
}
