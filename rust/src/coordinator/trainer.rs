//! The DP training loop — Algorithm 1 at the logical-batch level.
//!
//! Per step: Poisson-sample a logical batch (line 2), stream it through the
//! AOT step artifact in fixed-shape masked microbatches (lines 3-9 run
//! inside the artifact; clipped sums accumulate exactly across chunks), add
//! Gaussian noise once (line 10), average by the expected batch size, and
//! descend with the rust optimizer (line 11).  The RDP accountant advances
//! once per logical batch.
//!
//! Non-DP runs (`sigma == 0`, `nondp-*` artifacts) share the same loop with
//! shuffled fixed-size batches and no noise/accounting.

use std::rc::Rc;

use anyhow::{Context, Result};

use super::optim::{LrSchedule, OptimKind, Optimizer};
use super::task_data::TaskData;
use crate::dp::rdp::RdpAccountant;
use crate::dp::sampler::PoissonSampler;
use crate::runtime::{DeviceInput, Executable, Layout, Runtime};
use crate::util::rng::ChaChaRng;
use crate::util::tensor::Tensor;
use crate::util::Timers;

/// Trainer configuration (see `configs/*.toml`).
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    /// Training-step artifact name, e.g. `cls-base__dp-bitfit`.
    pub artifact: String,
    /// Logical (Poisson-expected) batch size.
    pub logical_batch: usize,
    pub lr: f64,
    pub optim: OptimKind,
    pub schedule: LrSchedule,
    /// Clipping threshold R (paper default 0.1 for text, Table 8).
    pub clip_r: f64,
    /// Noise multiplier; 0 disables DP accounting (non-private runs).
    pub sigma: f64,
    pub delta: f64,
    pub seed: u64,
}

impl TrainerConfig {
    pub fn new(artifact: &str) -> TrainerConfig {
        TrainerConfig {
            artifact: artifact.to_string(),
            logical_batch: 64,
            lr: 5e-3,
            optim: OptimKind::Adam,
            schedule: LrSchedule::Constant,
            clip_r: 0.1,
            sigma: 0.0,
            delta: 1e-5,
            seed: 0,
        }
    }
}

/// Per-step statistics.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub step: u64,
    pub loss: f64,
    pub batch: usize,
    pub grad_norm: f64,
    pub epsilon: f64,
}

/// The coordinator's training driver for one (model, method) artifact.
pub struct Trainer {
    pub cfg: TrainerConfig,
    exe: Rc<Executable>,
    layout: Layout,
    train: Vec<f32>,
    frozen: Tensor,
    frozen_dev: Option<DeviceInput>,
    optimizer: Optimizer,
    sampler: Option<PoissonSampler>,
    pub accountant: Option<RdpAccountant>,
    noise_rng: ChaChaRng,
    data_rng: ChaChaRng,
    pub step: u64,
    pub timers: Timers,
    n_data: usize,
    q: f64,
}

impl Trainer {
    /// Build a trainer; `params` defaults to the model's deterministic init
    /// (pass a pretrained full vector for fine-tuning).
    pub fn new(
        rt: &mut Runtime,
        cfg: TrainerConfig,
        n_data: usize,
        params: Option<Vec<f32>>,
    ) -> Result<Trainer> {
        let exe = rt.load(&cfg.artifact)?;
        let meta = exe.meta.clone();
        anyhow::ensure!(meta.step == "train", "{} is not a train artifact", cfg.artifact);
        let layout = rt.layout(&meta.model)?;
        let full = match params {
            Some(p) => {
                anyhow::ensure!(p.len() == layout.n_params, "param vector size mismatch");
                p
            }
            None => rt.init_params(&meta.model)?,
        };
        let (frozen, train) = layout.split(&full, &meta.subset);
        let frozen = Tensor::f32(vec![meta.pf], frozen);
        let frozen_dev = Some(exe.upload(&frozen).context("uploading frozen params")?);
        let is_dp = meta.method.starts_with("dp-");
        let q = (cfg.logical_batch as f64 / n_data as f64).min(1.0);
        let sampler = if is_dp {
            Some(PoissonSampler::new(n_data, q, cfg.seed ^ 0x5A17))
        } else {
            None
        };
        let accountant = if is_dp && cfg.sigma > 0.0 {
            Some(RdpAccountant::new(cfg.delta))
        } else {
            None
        };
        let optimizer = Optimizer::new(cfg.optim, cfg.lr, meta.pt);
        let _ = &full; // consumed via the (frozen, train) split above
        Ok(Trainer {
            noise_rng: ChaChaRng::new(cfg.seed, 0x4015E),
            data_rng: ChaChaRng::new(cfg.seed, 0xDA7A),
            optimizer,
            sampler,
            accountant,
            exe,
            layout,
            train,
            frozen,
            frozen_dev,
            step: 0,
            timers: Timers::new(),
            n_data,
            cfg,
            q,
        })
    }

    pub fn meta(&self) -> &crate::runtime::ArtifactMeta {
        &self.exe.meta
    }

    /// Is this a DP run (noise + Poisson sampling + accounting)?
    pub fn is_dp(&self) -> bool {
        self.sampler.is_some()
    }

    /// Current merged full parameter vector.
    pub fn full_params(&self) -> Vec<f32> {
        self.layout
            .merge(self.frozen.as_f32(), &self.train, &self.exe.meta.subset)
    }

    /// Trainable parameter count.
    pub fn trainable_len(&self) -> usize {
        self.train.len()
    }

    fn sample_indices(&mut self) -> Vec<usize> {
        if let Some(s) = &mut self.sampler {
            s.sample()
        } else {
            // non-private: fixed-size uniform sample without replacement
            let mut idxs: Vec<usize> = (0..self.n_data).collect();
            self.data_rng.shuffle(&mut idxs);
            idxs.truncate(self.cfg.logical_batch.min(self.n_data));
            idxs
        }
    }

    /// One logical-batch training step.
    pub fn train_step(&mut self, data: &TaskData) -> Result<StepStats> {
        assert_eq!(data.len(), self.n_data, "dataset changed under trainer");
        let t0 = std::time::Instant::now();
        let idxs = self.sample_indices();
        self.timers.add("sample", t0.elapsed().as_secs_f64());
        let b = self.exe.meta.batch;
        let pt = self.exe.meta.pt;
        let mut grad = vec![0.0f32; pt];
        let mut loss_sum = 0.0f64;
        let train_t = Tensor::f32(vec![pt], self.train.clone());
        let clip_r = Tensor::scalar_f32(self.cfg.clip_r as f32);
        for chunk in idxs.chunks(b) {
            let t1 = std::time::Instant::now();
            let (x, y, mask) = data.fill(chunk, b);
            self.timers.add("fill", t1.elapsed().as_secs_f64());
            let t2 = std::time::Instant::now();
            // Default: literal-path execution (stable). The device-resident
            // frozen-params path (`FASTDP_DEVICE_RESIDENT=1`) avoids
            // re-uploading the frozen vector per microbatch but trips an
            // xla_extension 0.5.1 assertion in some interleavings — see
            // EXPERIMENTS.md §Perf for the measured difference.
            let out = if std::env::var("FASTDP_DEVICE_RESIDENT").is_ok() {
                let dev = self.frozen_dev.as_ref().unwrap();
                self.exe
                    .run_mixed(
                        &[dev],
                        &[None, Some(&train_t), Some(&x), Some(&y), Some(&mask), Some(&clip_r)],
                    )
                    .context("executing train step (device-resident path)")?
            } else {
                self.exe
                    .run(&[self.frozen.clone(), train_t.clone(), x, y, mask, clip_r.clone()])
                    .context("executing train step")?
            };
            self.timers.add("execute", t2.elapsed().as_secs_f64());
            loss_sum += out[0].item_f32() as f64;
            crate::util::tensor::axpy(&mut grad, 1.0, out[1].as_f32());
        }
        let denom = if self.is_dp() {
            // fixed normalization by the expected batch (standard DP-SGD)
            self.cfg.logical_batch as f64
        } else {
            idxs.len().max(1) as f64
        };
        if self.is_dp() && self.cfg.sigma > 0.0 {
            crate::dp::add_gaussian_noise(
                &mut grad,
                self.cfg.sigma,
                self.cfg.clip_r,
                &mut self.noise_rng,
            );
        }
        for g in grad.iter_mut() {
            *g /= denom as f32;
        }
        let grad_norm = crate::util::tensor::l2_norm(&grad);
        let lr = self.cfg.schedule.at(self.cfg.lr, self.step);
        self.optimizer.step_lr(&mut self.train, &grad, lr);
        if let Some(acc) = &mut self.accountant {
            acc.step(self.q, self.cfg.sigma);
        }
        self.step += 1;
        Ok(StepStats {
            step: self.step,
            loss: loss_sum / idxs.len().max(1) as f64,
            batch: idxs.len(),
            grad_norm,
            epsilon: self.accountant.as_ref().map(|a| a.epsilon().0).unwrap_or(0.0),
        })
    }

    /// Evaluate with an eval artifact over (up to) `max_examples`.
    ///
    /// Returns `(sum_metric_a, sum_metric_b, n)`: for classifiers a = summed
    /// loss, b = correct count; for LMs a = summed NLL, b = token count.
    pub fn evaluate(
        &self,
        eval_exe: &Executable,
        data: &TaskData,
        max_examples: usize,
    ) -> Result<(f64, f64, usize)> {
        evaluate_params(eval_exe, &self.full_params(), data, max_examples)
    }
}

/// Evaluate a full parameter vector with an eval artifact.
pub fn evaluate_params(
    eval_exe: &Executable,
    full: &[f32],
    data: &TaskData,
    max_examples: usize,
) -> Result<(f64, f64, usize)> {
    let meta = &eval_exe.meta;
    anyhow::ensure!(meta.step == "eval", "not an eval artifact");
    let b = meta.batch;
    let n = data.len().min(max_examples);
    let full_t = Tensor::f32(vec![full.len()], full.to_vec());
    let empty = Tensor::f32(vec![0], vec![]);
    let (mut a_sum, mut b_sum) = (0.0f64, 0.0f64);
    let idxs: Vec<usize> = (0..n).collect();
    for chunk in idxs.chunks(b) {
        let (x, y, mask) = data.fill(chunk, b);
        let out = eval_exe.run(&[empty.clone(), full_t.clone(), x, y, mask])?;
        a_sum += out[0].item_f32() as f64;
        b_sum += out[1].item_f32() as f64;
    }
    Ok((a_sum, b_sum, n))
}
