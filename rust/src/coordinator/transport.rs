//! Replica transport: how leader <-> worker exchange traffic actually moves.
//!
//! PR 3's `ReplicaGroup` proved the bit-identical aggregation contract over
//! in-process `mpsc` channels; this module makes the wire real.  A
//! [`LeaderLink`]/[`WorkerLink`] pair abstracts one leader<->worker duplex
//! connection, with two implementations selected per job
//! ([`TransportKind`]):
//!
//! * **`channel`** (default) — the original `mpsc` path, byte-for-byte
//!   unchanged: structured messages cross thread boundaries directly and
//!   only the payload vectors are serialized (exactly what `CommStats`
//!   counted before this module existed).
//! * **`tcp`** — a localhost TCP socket per worker.  Every message is
//!   serialized and crosses the socket as one length-prefixed, CRC-checked
//!   frame (`"FDPF" | payload_len u32 LE | payload | crc32 LE`, IEEE
//!   polynomial — the checkpoint format's CRC).  Corrupt, truncated or
//!   oversized frames surface as typed faults, never panics.
//!
//! A [`WireCodec`] picks the byte layout of the *per-exchange payloads*
//! (clipped gradient sums up, trainable parameters down): `raw-f32le` is
//! the exact [`f32s_to_le_bytes`] layout (bit-identical training on either
//! transport, any replica count), `bf16` halves the wire via deterministic
//! round-to-nearest-even truncation under the ghost/simd-style tolerance
//! contract (1e-2 relative on short trajectories).  The one-time frozen
//! backbone bootstrap always ships raw — it is provisioning, not the
//! exchange traffic the codec exists to compress.
//!
//! Leader-side receives always take a deadline ([`TransportOpts`]'s
//! `recv_timeout`, `FASTDP_RECV_TIMEOUT_MS`): a dead or straggling worker
//! yields [`LinkFault::Timeout`] instead of hanging the reduction forever.
//! TCP accepts happen inline on the leader thread (bounded by the same
//! deadline), so no extra acceptor thread exists.
//!
//! This module is the one sanctioned home for `std::net` in the crate —
//! fastdp-lint's `net-io` rule fires on raw socket use anywhere else.

use std::io::{Read, Write};
// fastdp-lint: allow(net-io) the transport module is the sanctioned socket layer
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::engine::EngineError;
use crate::runtime::env;
use crate::util::tensor::{
    f32s_from_bf16_le_bytes, f32s_from_le_bytes, f32s_to_bf16_le_bytes, f32s_to_le_bytes, Tensor,
    TensorData,
};

/// Which wire the replica exchange runs over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process `mpsc` channels (the PR 3 path; default).
    Channel,
    /// Framed TCP over localhost, one socket per worker.
    Tcp,
}

impl TransportKind {
    /// Parse the job-spec / CLI / env vocabulary.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "channel" => Some(TransportKind::Channel),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp => "tcp",
        }
    }

    /// `FASTDP_TRANSPORT`, warn-once on unrecognized values (the transport
    /// vocabulary lives here, with its consumer, like `KernelMode::from_env`).
    pub fn from_env() -> TransportKind {
        match env::transport() {
            None => TransportKind::Channel,
            Some(v) => match TransportKind::parse(v.trim()) {
                Some(k) => k,
                None => {
                    env::warn_invalid(&env::TRANSPORT, &v);
                    TransportKind::Channel
                }
            },
        }
    }
}

/// Byte layout of the per-exchange gradient/parameter payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireCodec {
    /// 4 bytes/element, the exact `f32s_to_le_bytes` layout (default):
    /// training stays bitwise identical to the single-replica path.
    RawF32le,
    /// 2 bytes/element via deterministic round-to-nearest-even truncation:
    /// halves `bytes_to_leader`/`bytes_from_leader` under the 1e-2-relative
    /// short-trajectory tolerance contract.
    Bf16,
}

impl WireCodec {
    pub fn parse(s: &str) -> Option<WireCodec> {
        match s {
            "raw-f32le" => Some(WireCodec::RawF32le),
            "bf16" => Some(WireCodec::Bf16),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WireCodec::RawF32le => "raw-f32le",
            WireCodec::Bf16 => "bf16",
        }
    }

    /// `FASTDP_WIRE`, warn-once on unrecognized values.
    pub fn from_env() -> WireCodec {
        match env::wire() {
            None => WireCodec::RawF32le,
            Some(v) => match WireCodec::parse(v.trim()) {
                Some(c) => c,
                None => {
                    env::warn_invalid(&env::WIRE, &v);
                    WireCodec::RawF32le
                }
            },
        }
    }

    /// Serialized bytes per f32 element.
    pub fn bytes_per_elem(self) -> usize {
        match self {
            WireCodec::RawF32le => 4,
            WireCodec::Bf16 => 2,
        }
    }

    /// Encode an f32 payload vector for the wire.
    pub fn encode(self, xs: &[f32]) -> Vec<u8> {
        match self {
            WireCodec::RawF32le => f32s_to_le_bytes(xs),
            WireCodec::Bf16 => f32s_to_bf16_le_bytes(xs),
        }
    }

    /// Decode a wire payload back to f32s; byte counts that do not divide
    /// into whole elements are a typed error (a decoder must never panic
    /// on wire data).
    pub fn decode(self, bytes: &[u8]) -> Result<Vec<f32>, String> {
        let w = self.bytes_per_elem();
        if bytes.len() % w != 0 {
            return Err(format!(
                "{} payload of {} bytes is not a whole number of {}-byte elements",
                self.name(),
                bytes.len(),
                w
            ));
        }
        Ok(match self {
            WireCodec::RawF32le => f32s_from_le_bytes(bytes),
            WireCodec::Bf16 => f32s_from_bf16_le_bytes(bytes),
        })
    }
}

/// Per-group transport configuration, resolved from the `JobSpec` (which
/// itself falls back to the `FASTDP_TRANSPORT`/`FASTDP_WIRE`/
/// `FASTDP_RECV_TIMEOUT_MS` knobs).
#[derive(Debug, Clone, Copy)]
pub struct TransportOpts {
    pub kind: TransportKind,
    pub wire: WireCodec,
    /// Leader-side deadline for any single worker reply (ready waits,
    /// batch replies, resync acks) before the exchange fails typed.
    pub recv_timeout: Duration,
}

/// The documented `FASTDP_RECV_TIMEOUT_MS` fallback.
pub const DEFAULT_RECV_TIMEOUT_MS: u64 = 30_000;

impl Default for TransportOpts {
    fn default() -> TransportOpts {
        TransportOpts {
            kind: TransportKind::Channel,
            wire: WireCodec::RawF32le,
            recv_timeout: Duration::from_millis(DEFAULT_RECV_TIMEOUT_MS),
        }
    }
}

impl TransportOpts {
    /// Resolve every field from its environment knob (the fallback path
    /// the `JobSpec` builder uses when no explicit choice was made).
    pub fn from_env() -> TransportOpts {
        TransportOpts {
            kind: TransportKind::from_env(),
            wire: WireCodec::from_env(),
            recv_timeout: Duration::from_millis(
                env::recv_timeout_ms().unwrap_or(DEFAULT_RECV_TIMEOUT_MS),
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// Frame layer (TCP): "FDPF" | len u32 LE | payload | crc32(payload) LE
// ---------------------------------------------------------------------------

/// Frame magic, so stream desync is caught before a bogus length is trusted.
pub const FRAME_MAGIC: [u8; 4] = *b"FDPF";

/// Upper bound on a single frame payload; a length prefix past this is
/// rejected *before* any allocation (a corrupt 4-byte prefix must not OOM
/// the leader).
pub const MAX_FRAME: usize = 1 << 30;

/// Typed frame-read failures; never a panic, never a hang past the socket
/// deadline the caller configured.
#[derive(Debug)]
pub enum FrameError {
    /// The socket read deadline expired.
    Timeout,
    /// The peer closed (or the stream broke) mid-frame or between frames.
    Closed(String),
    /// The length prefix exceeds [`MAX_FRAME`].
    TooLarge(usize),
    /// Bad magic or CRC mismatch: the stream carried corrupted bytes.
    Corrupt(String),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Timeout => write!(f, "frame read deadline expired"),
            FrameError::Closed(e) => write!(f, "stream closed mid-frame: {e}"),
            FrameError::TooLarge(n) => {
                write!(f, "frame length prefix {n} exceeds the {MAX_FRAME}-byte cap")
            }
            FrameError::Corrupt(e) => write!(f, "corrupt frame: {e}"),
        }
    }
}

/// CRC-32 (IEEE 802.3, reflected 0xEDB88320) — same polynomial and test
/// vector as the checkpoint format's trailer.
fn crc32(data: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { (c >> 1) ^ 0xedb8_8320 } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xffff_ffffu32;
    for &b in data {
        crc = (crc >> 8) ^ table[((crc ^ b as u32) & 0xff) as usize];
    }
    !crc
}

/// Write one framed payload.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&FRAME_MAGIC)?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&crc32(payload).to_le_bytes())?;
    w.flush()
}

fn classify_io(e: std::io::Error) -> FrameError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => FrameError::Timeout,
        std::io::ErrorKind::UnexpectedEof => FrameError::Closed("unexpected EOF".to_string()),
        _ => FrameError::Closed(e.to_string()),
    }
}

/// Read one framed payload.  The caller owns the deadline (socket read
/// timeout); timeouts, truncation, oversized prefixes and CRC mismatches
/// all come back as typed [`FrameError`]s.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, FrameError> {
    let mut head = [0u8; 8];
    r.read_exact(&mut head).map_err(classify_io)?;
    if head[..4] != FRAME_MAGIC {
        return Err(FrameError::Corrupt(format!(
            "bad frame magic {:02x?} (stream desync?)",
            &head[..4]
        )));
    }
    let len = u32::from_le_bytes([head[4], head[5], head[6], head[7]]) as usize;
    if len > MAX_FRAME {
        return Err(FrameError::TooLarge(len));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(classify_io)?;
    let mut trailer = [0u8; 4];
    r.read_exact(&mut trailer).map_err(classify_io)?;
    let want = u32::from_le_bytes(trailer);
    let got = crc32(&payload);
    if want != got {
        return Err(FrameError::Corrupt(format!(
            "payload CRC mismatch (frame says {want:#010x}, computed {got:#010x})"
        )));
    }
    Ok(payload)
}

// ---------------------------------------------------------------------------
// Wire messages (shared by both transports; serialized only for TCP)
// ---------------------------------------------------------------------------

/// One microbatch assigned to a replica: its global chunk index plus the
/// filled fixed-shape step inputs.
pub(crate) struct ChunkWork {
    pub(crate) index: usize,
    pub(crate) x: Tensor,
    pub(crate) y: Tensor,
    pub(crate) mask: Tensor,
}

/// Leader -> worker messages.
pub(crate) enum ToWorker {
    /// Serialized frozen parameter vector (once per phase; bootstrap;
    /// always raw f32 LE regardless of the job's wire codec).
    Frozen(Vec<u8>),
    /// One logical-batch assignment: current trainable parameters (encoded
    /// with the job's wire codec) plus the chunks this replica owns, in
    /// ascending chunk order.
    Run { train: Vec<u8>, clip_r: f32, chunks: Vec<ChunkWork> },
    /// Rejoin barrier: the worker echoes the nonce so the leader can drain
    /// replies stranded by an aborted round.
    Sync(u64),
}

/// One chunk's result: raw summed loss and the codec-encoded clipped
/// gradient sum, still keyed by the global chunk index.
pub(crate) struct ChunkResult {
    pub(crate) index: usize,
    pub(crate) loss: f32,
    pub(crate) grad: Vec<u8>,
}

/// Worker -> leader messages.
pub(crate) enum FromWorker {
    /// Step loaded; the worker is ready for traffic.
    Ready,
    /// The factory failed inside the worker thread.
    Failed(String),
    /// Results for one `Run` assignment, in the assigned chunk order.
    Batch(Vec<ChunkResult>),
    /// A step execution failed.
    Error(String),
    /// Echo of a `Sync` nonce.
    SyncAck(u64),
}

// --- message byte codecs (the TCP frame payloads) ---

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    match &t.data {
        TensorData::F32(_) => out.push(0),
        TensorData::I32(_) => out.push(1),
    }
    out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
    for &d in &t.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    match &t.data {
        TensorData::F32(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        TensorData::I32(v) => {
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// Bounded little-endian reader over a frame payload; every accessor is a
/// typed error past the end (truncated payloads must not panic).
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        // `i` never passes the end, so the subtraction cannot underflow
        if n > self.b.len() - self.i {
            return Err(format!("message truncated: wanted {n} bytes at offset {}", self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn string(&mut self) -> Result<String, String> {
        String::from_utf8(self.bytes()?).map_err(|e| format!("non-UTF8 string field: {e}"))
    }

    fn tensor(&mut self) -> Result<Tensor, String> {
        let dtype = self.u8()?;
        let ndim = self.u32()? as usize;
        if ndim > 8 {
            return Err(format!("tensor rank {ndim} is not plausible wire data"));
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.u32()? as usize);
        }
        let count: usize = shape.iter().product();
        Ok(match dtype {
            0 => {
                let raw = self.take(count.checked_mul(4).ok_or("tensor size overflow")?)?;
                Tensor::f32(shape, f32s_from_le_bytes(raw))
            }
            1 => {
                let raw = self.take(count.checked_mul(4).ok_or("tensor size overflow")?)?;
                let data = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Tensor::i32(shape, data)
            }
            d => return Err(format!("unknown tensor dtype tag {d}")),
        })
    }

    fn done(&self) -> Result<(), String> {
        if self.i != self.b.len() {
            return Err(format!("{} trailing bytes after the message", self.b.len() - self.i));
        }
        Ok(())
    }
}

pub(crate) fn encode_to_worker(msg: &ToWorker) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        ToWorker::Frozen(b) => {
            out.push(0);
            put_bytes(&mut out, b);
        }
        ToWorker::Run { train, clip_r, chunks } => {
            out.push(1);
            out.extend_from_slice(&clip_r.to_le_bytes());
            put_bytes(&mut out, train);
            out.extend_from_slice(&(chunks.len() as u32).to_le_bytes());
            for c in chunks {
                out.extend_from_slice(&(c.index as u32).to_le_bytes());
                put_tensor(&mut out, &c.x);
                put_tensor(&mut out, &c.y);
                put_tensor(&mut out, &c.mask);
            }
        }
        ToWorker::Sync(n) => {
            out.push(2);
            out.extend_from_slice(&n.to_le_bytes());
        }
    }
    out
}

pub(crate) fn decode_to_worker(b: &[u8]) -> Result<ToWorker, String> {
    let mut rd = Rd { b, i: 0 };
    let msg = match rd.u8()? {
        0 => ToWorker::Frozen(rd.bytes()?),
        1 => {
            let clip_r = rd.f32()?;
            let train = rd.bytes()?;
            let n = rd.u32()? as usize;
            let mut chunks = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                let index = rd.u32()? as usize;
                let x = rd.tensor()?;
                let y = rd.tensor()?;
                let mask = rd.tensor()?;
                chunks.push(ChunkWork { index, x, y, mask });
            }
            ToWorker::Run { train, clip_r, chunks }
        }
        2 => ToWorker::Sync(rd.u64()?),
        t => return Err(format!("unknown leader message tag {t}")),
    };
    rd.done()?;
    Ok(msg)
}

pub(crate) fn encode_from_worker(msg: &FromWorker) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        FromWorker::Ready => out.push(0),
        FromWorker::Failed(e) => {
            out.push(1);
            put_bytes(&mut out, e.as_bytes());
        }
        FromWorker::Batch(results) => {
            out.push(2);
            out.extend_from_slice(&(results.len() as u32).to_le_bytes());
            for r in results {
                out.extend_from_slice(&(r.index as u32).to_le_bytes());
                out.extend_from_slice(&r.loss.to_le_bytes());
                put_bytes(&mut out, &r.grad);
            }
        }
        FromWorker::Error(e) => {
            out.push(3);
            put_bytes(&mut out, e.as_bytes());
        }
        FromWorker::SyncAck(n) => {
            out.push(4);
            out.extend_from_slice(&n.to_le_bytes());
        }
    }
    out
}

pub(crate) fn decode_from_worker(b: &[u8]) -> Result<FromWorker, String> {
    let mut rd = Rd { b, i: 0 };
    let msg = match rd.u8()? {
        0 => FromWorker::Ready,
        1 => FromWorker::Failed(rd.string()?),
        2 => {
            let n = rd.u32()? as usize;
            let mut results = Vec::with_capacity(n.min(1 << 16));
            for _ in 0..n {
                let index = rd.u32()? as usize;
                let loss = rd.f32()?;
                let grad = rd.bytes()?;
                results.push(ChunkResult { index, loss, grad });
            }
            FromWorker::Batch(results)
        }
        3 => FromWorker::Error(rd.string()?),
        4 => FromWorker::SyncAck(rd.u64()?),
        t => return Err(format!("unknown worker message tag {t}")),
    };
    rd.done()?;
    Ok(msg)
}

// ---------------------------------------------------------------------------
// Links: one leader<->worker duplex connection per replica
// ---------------------------------------------------------------------------

/// Typed leader-side link failures, mapped to `EngineError`s (with the
/// replica index) by `coordinator::distributed`.
#[derive(Debug)]
pub(crate) enum LinkFault {
    /// No reply within the configured deadline (straggler or dead worker).
    Timeout,
    /// The worker hung up / the stream broke.
    Closed(String),
    /// The wire carried bytes that do not decode (CRC, framing, codec).
    Corrupt(String),
}

/// Leader-side end of one worker connection.
pub(crate) trait LeaderLink {
    fn send(&mut self, msg: ToWorker) -> Result<(), LinkFault>;
    /// Receive one worker message, bounded by `timeout`.
    fn recv(&mut self, timeout: Duration) -> Result<FromWorker, LinkFault>;
    /// Close the connection so the worker's receive loop ends.
    fn hangup(&mut self);
}

/// Worker-side end; lives inside the worker thread.
pub(crate) trait WorkerLink {
    /// `None` means the leader hung up (or the stream broke): exit cleanly.
    fn recv(&mut self) -> Option<ToWorker>;
    /// `false` means the leader is gone: exit cleanly.
    fn send(&mut self, msg: FromWorker) -> bool;
}

struct ChannelLeader {
    tx: Option<mpsc::Sender<ToWorker>>,
    rx: mpsc::Receiver<FromWorker>,
}

impl LeaderLink for ChannelLeader {
    fn send(&mut self, msg: ToWorker) -> Result<(), LinkFault> {
        match &self.tx {
            Some(tx) => {
                tx.send(msg).map_err(|_| LinkFault::Closed("channel receiver dropped".into()))
            }
            None => Err(LinkFault::Closed("link already hung up".into())),
        }
    }

    fn recv(&mut self, timeout: Duration) -> Result<FromWorker, LinkFault> {
        match self.rx.recv_timeout(timeout) {
            Ok(msg) => Ok(msg),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(LinkFault::Timeout),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(LinkFault::Closed("channel sender dropped".into()))
            }
        }
    }

    fn hangup(&mut self) {
        self.tx = None;
    }
}

struct ChannelWorker {
    rx: mpsc::Receiver<ToWorker>,
    tx: mpsc::Sender<FromWorker>,
}

impl WorkerLink for ChannelWorker {
    fn recv(&mut self) -> Option<ToWorker> {
        self.rx.recv().ok()
    }

    fn send(&mut self, msg: FromWorker) -> bool {
        self.tx.send(msg).is_ok()
    }
}

struct TcpLeader {
    /// Still waiting for the worker to dial in; replaced by `stream` on the
    /// first send/recv (accepts are bounded by `accept_timeout`).
    listener: Option<TcpListener>,
    stream: Option<TcpStream>,
    accept_timeout: Duration,
}

impl TcpLeader {
    /// Accept the worker's connection if it has not arrived yet, bounded by
    /// the configured deadline — a worker that died before dialing must not
    /// hang the leader.
    fn ensure_accepted(&mut self) -> Result<&mut TcpStream, LinkFault> {
        if self.stream.is_none() {
            let listener = self
                .listener
                .as_ref()
                .ok_or_else(|| LinkFault::Closed("link already hung up".into()))?;
            let deadline = Instant::now() + self.accept_timeout;
            loop {
                match listener.accept() {
                    Ok((s, _)) => {
                        let _ = s.set_nodelay(true);
                        s.set_nonblocking(false)
                            .map_err(|e| LinkFault::Closed(e.to_string()))?;
                        self.stream = Some(s);
                        self.listener = None;
                        break;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        if Instant::now() >= deadline {
                            return Err(LinkFault::Timeout);
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                    Err(e) => return Err(LinkFault::Closed(e.to_string())),
                }
            }
        }
        Ok(self.stream.as_mut().expect("stream just ensured"))
    }
}

impl LeaderLink for TcpLeader {
    fn send(&mut self, msg: ToWorker) -> Result<(), LinkFault> {
        let payload = encode_to_worker(&msg);
        let stream = self.ensure_accepted()?;
        write_frame(stream, &payload).map_err(|e| LinkFault::Closed(e.to_string()))
    }

    fn recv(&mut self, timeout: Duration) -> Result<FromWorker, LinkFault> {
        let stream = self.ensure_accepted()?;
        stream
            .set_read_timeout(Some(timeout))
            .map_err(|e| LinkFault::Closed(e.to_string()))?;
        let payload = match read_frame(stream) {
            Ok(p) => p,
            Err(FrameError::Timeout) => return Err(LinkFault::Timeout),
            Err(e @ (FrameError::TooLarge(_) | FrameError::Corrupt(_))) => {
                return Err(LinkFault::Corrupt(e.to_string()))
            }
            Err(FrameError::Closed(e)) => return Err(LinkFault::Closed(e)),
        };
        decode_from_worker(&payload).map_err(LinkFault::Corrupt)
    }

    fn hangup(&mut self) {
        if let Some(s) = self.stream.take() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        self.listener = None;
    }
}

struct TcpWorker {
    stream: TcpStream,
}

impl WorkerLink for TcpWorker {
    fn recv(&mut self) -> Option<ToWorker> {
        // blocking read: the worker waits for the leader indefinitely and
        // exits on EOF / any stream fault (the leader's deadline is the
        // liveness authority)
        let payload = read_frame(&mut self.stream).ok()?;
        decode_to_worker(&payload).ok()
    }

    fn send(&mut self, msg: FromWorker) -> bool {
        write_frame(&mut self.stream, &encode_from_worker(&msg)).is_ok()
    }
}

/// The worker half of a freshly created connection, sent into the worker
/// thread; TCP connects lazily *inside* the thread so the socket lives
/// where it is used.
pub(crate) enum WorkerSeed {
    Channel { rx: mpsc::Receiver<ToWorker>, tx: mpsc::Sender<FromWorker> },
    Tcp { addr: SocketAddr },
}

impl WorkerSeed {
    /// Materialize the worker end (dials the leader for TCP).
    pub(crate) fn connect(self) -> Result<Box<dyn WorkerLink>, String> {
        match self {
            WorkerSeed::Channel { rx, tx } => Ok(Box::new(ChannelWorker { rx, tx })),
            WorkerSeed::Tcp { addr } => {
                let stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
                let _ = stream.set_nodelay(true);
                Ok(Box::new(TcpWorker { stream }))
            }
        }
    }
}

/// Create one leader<->worker connection of the requested kind.  For TCP
/// this binds an ephemeral localhost listener per worker; the accept is
/// deferred to the leader's first send/recv and bounded by `accept_timeout`.
pub(crate) fn pair(
    kind: TransportKind,
    accept_timeout: Duration,
) -> Result<(Box<dyn LeaderLink>, WorkerSeed), EngineError> {
    match kind {
        TransportKind::Channel => {
            let (to_tx, to_rx) = mpsc::channel::<ToWorker>();
            let (from_tx, from_rx) = mpsc::channel::<FromWorker>();
            Ok((
                Box::new(ChannelLeader { tx: Some(to_tx), rx: from_rx }),
                WorkerSeed::Channel { rx: to_rx, tx: from_tx },
            ))
        }
        TransportKind::Tcp => {
            let listener = TcpListener::bind(("127.0.0.1", 0)).map_err(|e| {
                EngineError::backend("transport", format!("cannot bind loopback listener: {e}"))
            })?;
            listener.set_nonblocking(true).map_err(|e| {
                EngineError::backend("transport", format!("cannot configure listener: {e}"))
            })?;
            let addr = listener.local_addr().map_err(|e| {
                EngineError::backend("transport", format!("listener has no local addr: {e}"))
            })?;
            Ok((
                Box::new(TcpLeader { listener: Some(listener), stream: None, accept_timeout }),
                WorkerSeed::Tcp { addr },
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn vocab_parses_and_rejects() {
        assert_eq!(TransportKind::parse("channel"), Some(TransportKind::Channel));
        assert_eq!(TransportKind::parse("tcp"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
        assert_eq!(WireCodec::parse("raw-f32le"), Some(WireCodec::RawF32le));
        assert_eq!(WireCodec::parse("bf16"), Some(WireCodec::Bf16));
        assert_eq!(WireCodec::parse("fp8"), None);
        assert_eq!(TransportKind::Tcp.name(), "tcp");
        assert_eq!(WireCodec::Bf16.name(), "bf16");
    }

    #[test]
    fn codec_raw_is_bitwise_and_bf16_is_half_width() {
        let xs = vec![0.0f32, -1.5, 3.25e-3, 0.0625, -7.75];
        let raw = WireCodec::RawF32le.encode(&xs);
        assert_eq!(raw.len(), xs.len() * 4);
        let back = WireCodec::RawF32le.decode(&raw).unwrap();
        assert_eq!(
            back.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        let bf = WireCodec::Bf16.encode(&xs);
        assert_eq!(bf.len(), xs.len() * 2);
        let back = WireCodec::Bf16.decode(&bf).unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() / 256.0, "{a} -> {b}");
        }
        // ragged byte counts are typed errors, not panics
        assert!(WireCodec::RawF32le.decode(&raw[..5]).is_err());
        assert!(WireCodec::Bf16.decode(&bf[..3]).is_err());
    }

    #[test]
    fn frame_roundtrip_over_a_buffer() {
        let payload = b"the quick brown fox".to_vec();
        let mut wire = Vec::new();
        write_frame(&mut wire, &payload).unwrap();
        assert_eq!(wire.len(), 4 + 4 + payload.len() + 4);
        let got = read_frame(&mut wire.as_slice()).unwrap();
        assert_eq!(got, payload);
        // empty payloads frame fine too
        let mut wire = Vec::new();
        write_frame(&mut wire, &[]).unwrap();
        assert!(read_frame(&mut wire.as_slice()).unwrap().is_empty());
    }

    #[test]
    fn truncated_frame_is_a_typed_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"payload bytes").unwrap();
        for cut in [0, 3, 9, wire.len() - 1] {
            let err = read_frame(&mut &wire[..cut]).unwrap_err();
            assert!(matches!(err, FrameError::Closed(_)), "cut={cut}: {err}");
        }
    }

    #[test]
    fn corrupted_crc_is_a_typed_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"payload bytes").unwrap();
        let mid = 8 + 4; // flip a payload byte
        wire[mid] ^= 0x40;
        let err = read_frame(&mut wire.as_slice()).unwrap_err();
        assert!(matches!(err, FrameError::Corrupt(_)), "{err}");
        assert!(err.to_string().contains("CRC"), "{err}");
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&FRAME_MAGIC);
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&mut wire.as_slice()).unwrap_err();
        assert!(matches!(err, FrameError::TooLarge(_)), "{err}");
    }

    #[test]
    fn bad_magic_is_a_typed_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"ok").unwrap();
        wire[0] = b'X';
        let err = read_frame(&mut wire.as_slice()).unwrap_err();
        assert!(matches!(err, FrameError::Corrupt(_)), "{err}");
    }

    #[test]
    fn to_worker_messages_roundtrip() {
        let chunks = vec![
            ChunkWork {
                index: 7,
                x: Tensor::f32(vec![2, 3], vec![1.0, -2.0, 0.5, 0.0, 3.0, -0.25]),
                y: Tensor::i32(vec![2], vec![4, -9]),
                mask: Tensor::f32(vec![2], vec![1.0, 0.0]),
            },
            ChunkWork {
                index: 8,
                x: Tensor::f32(vec![1], vec![9.5]),
                y: Tensor::i32(vec![1], vec![3]),
                mask: Tensor::f32(vec![1], vec![1.0]),
            },
        ];
        let msg = ToWorker::Run { train: vec![1, 2, 3, 4], clip_r: 0.125, chunks };
        let bytes = encode_to_worker(&msg);
        match decode_to_worker(&bytes).unwrap() {
            ToWorker::Run { train, clip_r, chunks } => {
                assert_eq!(train, vec![1, 2, 3, 4]);
                assert_eq!(clip_r, 0.125);
                assert_eq!(chunks.len(), 2);
                assert_eq!(chunks[0].index, 7);
                assert_eq!(chunks[0].x.shape, vec![2, 3]);
                assert_eq!(chunks[0].x.as_f32()[1], -2.0);
                assert_eq!(chunks[0].y.as_i32(), &[4, -9]);
                assert_eq!(chunks[1].index, 8);
                assert_eq!(chunks[1].mask.as_f32(), &[1.0]);
            }
            _ => panic!("wrong variant"),
        }
        let bytes = encode_to_worker(&ToWorker::Frozen(vec![0xAB; 9]));
        assert!(matches!(decode_to_worker(&bytes).unwrap(), ToWorker::Frozen(b) if b.len() == 9));
        let bytes = encode_to_worker(&ToWorker::Sync(0xDEAD_BEEF_0042));
        assert!(matches!(decode_to_worker(&bytes).unwrap(), ToWorker::Sync(0xDEAD_BEEF_0042)));
    }

    #[test]
    fn from_worker_messages_roundtrip() {
        for (msg, check) in [
            (FromWorker::Ready, 0u8),
            (FromWorker::Failed("no such artifact".into()), 1),
            (
                FromWorker::Batch(vec![ChunkResult {
                    index: 3,
                    loss: 2.5,
                    grad: vec![1, 2, 3, 4, 5, 6, 7, 8],
                }]),
                2,
            ),
            (FromWorker::Error("exploded".into()), 3),
            (FromWorker::SyncAck(11), 4),
        ] {
            let bytes = encode_from_worker(&msg);
            assert_eq!(bytes[0], check);
            match (msg, decode_from_worker(&bytes).unwrap()) {
                (FromWorker::Ready, FromWorker::Ready) => {}
                (FromWorker::Failed(a), FromWorker::Failed(b)) => assert_eq!(a, b),
                (FromWorker::Batch(a), FromWorker::Batch(b)) => {
                    assert_eq!(a.len(), b.len());
                    assert_eq!(a[0].index, b[0].index);
                    assert_eq!(a[0].loss.to_bits(), b[0].loss.to_bits());
                    assert_eq!(a[0].grad, b[0].grad);
                }
                (FromWorker::Error(a), FromWorker::Error(b)) => assert_eq!(a, b),
                (FromWorker::SyncAck(a), FromWorker::SyncAck(b)) => assert_eq!(a, b),
                _ => panic!("variant changed across the wire"),
            }
        }
    }

    #[test]
    fn truncated_messages_decode_to_typed_errors() {
        let msg = ToWorker::Run {
            train: vec![1, 2, 3, 4],
            clip_r: 0.5,
            chunks: vec![ChunkWork {
                index: 0,
                x: Tensor::f32(vec![2], vec![1.0, 2.0]),
                y: Tensor::i32(vec![1], vec![1]),
                mask: Tensor::f32(vec![1], vec![1.0]),
            }],
        };
        let bytes = encode_to_worker(&msg);
        for cut in [0, 1, 5, 9, bytes.len() - 1] {
            assert!(decode_to_worker(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // trailing garbage is rejected too
        let mut padded = bytes.clone();
        padded.push(0);
        assert!(decode_to_worker(&padded).is_err());
        assert!(decode_from_worker(&[9]).is_err());
    }

    #[test]
    fn tcp_pair_moves_frames_end_to_end() {
        let (mut leader, seed) = pair(TransportKind::Tcp, Duration::from_secs(5)).unwrap();
        let worker = std::thread::spawn(move || {
            let mut link = seed.connect().unwrap();
            let msg = link.recv().expect("leader message");
            match msg {
                ToWorker::Frozen(b) => {
                    assert_eq!(b, vec![1, 2, 3, 4]);
                    assert!(link.send(FromWorker::Ready));
                }
                _ => panic!("wrong message"),
            }
            // leader hangs up -> recv drains to None and the loop exits
            assert!(link.recv().is_none());
        });
        leader.send(ToWorker::Frozen(vec![1, 2, 3, 4])).unwrap();
        match leader.recv(Duration::from_secs(5)).unwrap() {
            FromWorker::Ready => {}
            _ => panic!("expected Ready"),
        }
        leader.hangup();
        worker.join().unwrap();
    }

    #[test]
    fn tcp_leader_times_out_when_no_worker_dials() {
        let (mut leader, seed) = pair(TransportKind::Tcp, Duration::from_millis(80)).unwrap();
        drop(seed); // the worker never connects
        let err = leader.recv(Duration::from_millis(80)).unwrap_err();
        assert!(matches!(err, LinkFault::Timeout), "{err:?}");
    }

    #[test]
    fn transport_opts_default_is_the_pre_transport_behavior() {
        let opts = TransportOpts::default();
        assert_eq!(opts.kind, TransportKind::Channel);
        assert_eq!(opts.wire, WireCodec::RawF32le);
        assert_eq!(opts.recv_timeout, Duration::from_millis(DEFAULT_RECV_TIMEOUT_MS));
    }
}
