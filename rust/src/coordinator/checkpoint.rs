//! Versioned binary checkpoints for full parameter vectors.
//!
//! Format (little-endian):
//!   magic "FDPC" | version u32 | model-name len u32 + utf8 | step u64 |
//!   n_params u64 | f32 payload | crc32 of payload
//!
//! The CRC catches torn writes; loading a corrupt or mismatched checkpoint
//! is a hard error, never silent garbage.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

const MAGIC: &[u8; 4] = b"FDPC";
const VERSION: u32 = 1;

/// A checkpoint: model name + step + full flat params.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub params: Vec<f32>,
}

/// CRC-32 (IEEE) — table-driven, no external crate.
fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, t) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *t = c;
    }
    let mut crc = 0xFFFFFFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFFFFFF
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(self.model.len() as u32).to_le_bytes())?;
        f.write_all(self.model.as_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        let payload: Vec<u8> = self.params.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&payload)?;
        f.write_all(&crc32(&payload).to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut buf4 = [0u8; 4];
        let mut buf8 = [0u8; 8];
        f.read_exact(&mut buf4)?;
        anyhow::ensure!(&buf4 == MAGIC, "bad magic (not a fastdp checkpoint)");
        f.read_exact(&mut buf4)?;
        let version = u32::from_le_bytes(buf4);
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        f.read_exact(&mut buf4)?;
        let name_len = u32::from_le_bytes(buf4) as usize;
        anyhow::ensure!(name_len < 4096, "implausible model-name length");
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let model = String::from_utf8(name).context("model name not utf8")?;
        f.read_exact(&mut buf8)?;
        let step = u64::from_le_bytes(buf8);
        f.read_exact(&mut buf8)?;
        let n = u64::from_le_bytes(buf8) as usize;
        let mut payload = vec![0u8; n * 4];
        f.read_exact(&mut payload)?;
        f.read_exact(&mut buf4)?;
        let want_crc = u32::from_le_bytes(buf4);
        anyhow::ensure!(crc32(&payload) == want_crc, "checkpoint CRC mismatch (corrupt file)");
        let params = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint { model, step, params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fastdp-ckpt-test-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let c = Checkpoint {
            model: "cls-base".into(),
            step: 42,
            params: (0..1000).map(|i| i as f32 * 0.5).collect(),
        };
        let p = tmp("rt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, c);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corruption_detected() {
        let c = Checkpoint { model: "m".into(), step: 1, params: vec![1.0; 64] };
        let p = tmp("corrupt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
