//! Versioned binary checkpoints: full parameter vectors ([`Checkpoint`])
//! and complete mid-run session snapshots ([`SessionState`]).
//!
//! `Checkpoint` format (little-endian):
//!   magic "FDPC" | version u32 | model-name len u32 + utf8 | step u64 |
//!   n_params u64 | f32 payload | crc32 of payload
//!
//! `SessionState` ("FDPS") additionally captures everything a resumed
//! session needs to continue **bit-identically**: phase position, the
//! optimizer's moment vectors, the noise/data/sampler RNG states and the
//! RDP accountant's accumulated orders.  All floats are stored as raw LE
//! bit patterns, so a save/load round-trip is exact.
//!
//! The CRC catches torn writes; loading a corrupt or mismatched checkpoint
//! is a hard error, never silent garbage.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::rng::RNG_STATE_WORDS;
use crate::util::tensor::{f32s_from_le_bytes, f32s_to_le_bytes};

const MAGIC: &[u8; 4] = b"FDPC";
const VERSION: u32 = 1;
const STATE_MAGIC: &[u8; 4] = b"FDPS";
const STATE_VERSION: u32 = 1;

/// A checkpoint: model name + step + full flat params.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub model: String,
    pub step: u64,
    pub params: Vec<f32>,
}

/// CRC-32 (IEEE) — table-driven, no external crate.
fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, t) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *t = c;
    }
    let mut crc = 0xFFFFFFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFFFFFF
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(self.model.len() as u32).to_le_bytes())?;
        f.write_all(self.model.as_bytes())?;
        f.write_all(&self.step.to_le_bytes())?;
        f.write_all(&(self.params.len() as u64).to_le_bytes())?;
        let payload: Vec<u8> = self.params.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&payload)?;
        f.write_all(&crc32(&payload).to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref();
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut buf4 = [0u8; 4];
        let mut buf8 = [0u8; 8];
        f.read_exact(&mut buf4)?;
        anyhow::ensure!(&buf4 == MAGIC, "bad magic (not a fastdp checkpoint)");
        f.read_exact(&mut buf4)?;
        let version = u32::from_le_bytes(buf4);
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        f.read_exact(&mut buf4)?;
        let name_len = u32::from_le_bytes(buf4) as usize;
        anyhow::ensure!(name_len < 4096, "implausible model-name length");
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let model = String::from_utf8(name).context("model name not utf8")?;
        f.read_exact(&mut buf8)?;
        let step = u64::from_le_bytes(buf8);
        f.read_exact(&mut buf8)?;
        let n = u64::from_le_bytes(buf8) as usize;
        let mut payload = vec![0u8; n * 4];
        f.read_exact(&mut payload)?;
        f.read_exact(&mut buf4)?;
        let want_crc = u32::from_le_bytes(buf4);
        anyhow::ensure!(crc32(&payload) == want_crc, "checkpoint CRC mismatch (corrupt file)");
        let params = payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint { model, step, params })
    }
}

/// A complete mid-run session snapshot (see `engine::Session::save_state`).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionState {
    pub model: String,
    /// Steps taken so far.
    pub step: u64,
    /// Index of the active phase (0 except after an X+BiTFiT switch).
    pub active_phase: u32,
    /// Steps remaining before the active phase ends.
    pub phase_left: u64,
    /// Merged full parameter vector at save time.
    pub params: Vec<f32>,
    /// Optimizer step counter and Adam moment vectors (empty-moment SGD
    /// still round-trips: the vectors are sized but zero).
    pub optim_t: u64,
    pub optim_m: Vec<f64>,
    pub optim_v: Vec<f64>,
    pub noise_rng: [u32; RNG_STATE_WORDS],
    pub data_rng: [u32; RNG_STATE_WORDS],
    /// `None` for non-private sessions (no Poisson sampler).
    pub sampler_rng: Option<[u32; RNG_STATE_WORDS]>,
    /// Accumulated RDP per grid order; empty when the session had no
    /// accountant (non-private, or sigma = 0).
    pub rdp_acc: Vec<f64>,
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Little-endian cursor over a payload buffer; every read is bounds-checked.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // overflow-safe: pos <= len always holds, so len - pos cannot wrap
        anyhow::ensure!(n <= self.buf.len() - self.pos, "session state truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = n.checked_mul(4).context("implausible element count")?;
        Ok(f32s_from_le_bytes(self.take(bytes)?))
    }

    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        Ok((0..n).map(|_| self.u64()).collect::<Result<Vec<u64>>>()?
            .into_iter()
            .map(f64::from_bits)
            .collect())
    }

    fn rng(&mut self) -> Result<[u32; RNG_STATE_WORDS]> {
        let mut w = [0u32; RNG_STATE_WORDS];
        for v in w.iter_mut() {
            *v = self.u32()?;
        }
        Ok(w)
    }
}

impl SessionState {
    fn payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        put_u32(&mut p, self.model.len() as u32);
        p.extend_from_slice(self.model.as_bytes());
        put_u64(&mut p, self.step);
        put_u32(&mut p, self.active_phase);
        put_u64(&mut p, self.phase_left);
        put_u64(&mut p, self.params.len() as u64);
        p.extend_from_slice(&f32s_to_le_bytes(&self.params));
        put_u64(&mut p, self.optim_t);
        assert_eq!(self.optim_m.len(), self.optim_v.len(), "moment vectors must pair");
        put_u64(&mut p, self.optim_m.len() as u64);
        for v in self.optim_m.iter().chain(&self.optim_v) {
            put_u64(&mut p, v.to_bits());
        }
        for w in self.noise_rng.iter().chain(&self.data_rng) {
            put_u32(&mut p, *w);
        }
        p.push(self.sampler_rng.is_some() as u8);
        if let Some(s) = &self.sampler_rng {
            for w in s {
                put_u32(&mut p, *w);
            }
        }
        put_u64(&mut p, self.rdp_acc.len() as u64);
        for v in &self.rdp_acc {
            put_u64(&mut p, v.to_bits());
        }
        p
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let payload = self.payload();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(STATE_MAGIC)?;
        f.write_all(&STATE_VERSION.to_le_bytes())?;
        f.write_all(&payload)?;
        f.write_all(&crc32(&payload).to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<SessionState> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("opening {}", path.display()))?;
        anyhow::ensure!(bytes.len() >= 12, "file too short for a session state");
        anyhow::ensure!(&bytes[..4] == STATE_MAGIC, "bad magic (not a fastdp session state)");
        let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
        anyhow::ensure!(version == STATE_VERSION, "unsupported session-state version {version}");
        let payload = &bytes[8..bytes.len() - 4];
        let tail = &bytes[bytes.len() - 4..];
        let want_crc = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
        anyhow::ensure!(crc32(payload) == want_crc, "session state CRC mismatch (corrupt file)");
        let mut c = Cursor { buf: payload, pos: 0 };
        let name_len = c.u32()? as usize;
        anyhow::ensure!(name_len < 4096, "implausible model-name length");
        let model = String::from_utf8(c.take(name_len)?.to_vec()).context("model name not utf8")?;
        let step = c.u64()?;
        let active_phase = c.u32()?;
        let phase_left = c.u64()?;
        let n_params = c.u64()? as usize;
        let params = c.f32s(n_params)?;
        let optim_t = c.u64()?;
        let n_m = c.u64()? as usize;
        let optim_m = c.f64s(n_m)?;
        let optim_v = c.f64s(n_m)?;
        let noise_rng = c.rng()?;
        let data_rng = c.rng()?;
        let has_sampler = c.take(1)?[0];
        let sampler_rng = if has_sampler != 0 { Some(c.rng()?) } else { None };
        let n_acc = c.u64()? as usize;
        let rdp_acc = c.f64s(n_acc)?;
        anyhow::ensure!(c.pos == payload.len(), "trailing bytes after session state");
        Ok(SessionState {
            model,
            step,
            active_phase,
            phase_left,
            params,
            optim_t,
            optim_m,
            optim_v,
            noise_rng,
            data_rng,
            sampler_rng,
            rdp_acc,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fastdp-ckpt-test-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let c = Checkpoint {
            model: "cls-base".into(),
            step: 42,
            params: (0..1000).map(|i| i as f32 * 0.5).collect(),
        };
        let p = tmp("rt");
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back, c);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn corruption_detected() {
        let c = Checkpoint { model: "m".into(), step: 1, params: vec![1.0; 64] };
        let p = tmp("corrupt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmp("magic");
        std::fs::write(&p, b"NOTACKPTxxxxxxxxxxxxxxx").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn crc32_known_vector() {
        // CRC-32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    fn sample_state(private: bool) -> SessionState {
        SessionState {
            model: "cls-base".into(),
            step: 17,
            active_phase: 1,
            phase_left: 3,
            params: (0..300).map(|i| (i as f32).sin()).collect(),
            optim_t: 17,
            optim_m: (0..40).map(|i| i as f64 * 0.1).collect(),
            optim_v: (0..40).map(|i| i as f64 * 0.01).collect(),
            noise_rng: [7u32; RNG_STATE_WORDS],
            data_rng: [9u32; RNG_STATE_WORDS],
            sampler_rng: if private { Some([11u32; RNG_STATE_WORDS]) } else { None },
            rdp_acc: if private { (0..71).map(|i| i as f64 * 1e-3).collect() } else { vec![] },
        }
    }

    #[test]
    fn session_state_roundtrips_exactly() {
        for private in [true, false] {
            let st = sample_state(private);
            let p = tmp(if private { "state-dp" } else { "state-nondp" });
            st.save(&p).unwrap();
            assert_eq!(SessionState::load(&p).unwrap(), st);
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn session_state_corruption_and_magic_detected() {
        let st = sample_state(true);
        let p = tmp("state-corrupt");
        st.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let err = SessionState::load(&p).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
        // a parameter Checkpoint is not a SessionState
        let ck = Checkpoint { model: "m".into(), step: 1, params: vec![1.0; 8] };
        ck.save(&p).unwrap();
        let err = SessionState::load(&p).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        std::fs::remove_file(&p).ok();
    }
}
