//! L3 coordinator: the DP fine-tuning orchestrator.
//!
//! * [`trainer`] — Algorithm 1 at the logical-batch level (Poisson sampling,
//!   masked microbatch accumulation, noise, optimizer step, accounting).
//! * [`phase`] — two-phase X+BiTFiT scheduling (App. A.2.2).
//! * [`optim`] — SGD / DP-Adam / DP-AdamW on flat parameter vectors.
//! * [`task_data`] — dataset -> fixed-shape artifact inputs with masks.
//! * [`workloads`] — manifest-driven synthetic dataset construction.
//! * [`decode`] — batched greedy decoding for the generation tasks.
//! * [`checkpoint`] — CRC-protected binary checkpoints.
//! * [`metrics`] — JSONL run logs.
//! * [`distributed`] — simulated data-parallel communication accounting.
//! * [`cli`] — the `fastdp` binary's subcommands.

pub mod checkpoint;
pub mod cli;
pub mod decode;
pub mod distributed;
pub mod metrics;
pub mod optim;
pub mod phase;
pub mod pretrain;
pub mod task_data;
pub mod trainer;
pub mod workloads;
