//! L3 coordinator: orchestration building blocks consumed by
//! [`crate::engine`].
//!
//! The training loop itself lives in `engine::Session`; this module holds
//! the substrates it composes:
//!
//! * [`optim`] — SGD / DP-Adam / DP-AdamW on flat parameter vectors.
//! * [`task_data`] — dataset -> fixed-shape step inputs with masks.
//! * [`workloads`] — shape-driven synthetic dataset construction.
//! * [`decode`] — batched greedy decoding for the generation tasks.
//! * [`pretrain`] — cached non-private pretraining of the small models.
//! * [`checkpoint`] — CRC-protected binary checkpoints (parameter vectors
//!   and complete mid-run session snapshots).
//! * [`metrics`] — JSONL run logs.
//! * [`distributed`] — real data-parallel replica workers with on-the-wire
//!   communication accounting (bit-identical aggregation contract).
//! * [`transport`] — the replica wire itself: in-process channels or framed
//!   TCP loopback, plus the per-job payload codecs (`raw-f32le`/`bf16`).
//! * [`cli`] — the `fastdp` binary's subcommands (a thin flag/TOML ->
//!   `JobSpec` translator).

pub mod checkpoint;
pub mod cli;
pub mod decode;
pub mod distributed;
pub mod metrics;
pub mod optim;
pub mod pretrain;
pub mod task_data;
pub mod transport;
pub mod workloads;
