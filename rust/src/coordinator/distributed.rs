//! Real data-parallel replicated training: N replica workers on real
//! threads, each running the configured kernel tier of [`crate::kernels`]
//! (fused by default; ghost/blocked/simd propagate from the leader's
//! backend config) over a
//! disjoint microbatch shard of the Poisson logical batch, shipping their
//! clipped gradient sums to the leader over channels.  Bytes are counted on
//! the wire (the payloads really are serialized byte vectors), so
//! `benches/comm_cost.rs` measures the paper's §3.1 claim — 64·M·D bits per
//! exchange for full fine-tuning vs 64·M·D_bias for DP-BiTFiT — on an
//! actual training run instead of the synthetic `simulate()` this module
//! used to ship.
//!
//! ## Determinism contract (the cross-replica analog of `runtime::pool`)
//!
//! The logical batch is split into the same fixed-shape microbatch chunks
//! the single-replica path uses, and each replica owns a **contiguous run
//! of chunks** (`ceil(C / N)` per replica, like the pool's row sharding).
//! Workers return one clipped gradient sum *per owned chunk*, in chunk
//! order; the leader reduces replies **in fixed replica order**, which —
//! because the assignment is contiguous — is exactly the global chunk
//! order.  The leader therefore performs the identical sequence of f32
//! `axpy` accumulations (and f64 loss additions) as the single-replica
//! loop in `engine::Session::run_step`, so training is **bit-identical for
//! any replica count**, including 1.  Gaussian noise is added exactly once
//! per logical batch, by the leader, after the reduction.
//!
//! ## Wire accounting
//!
//! [`CommStats`] counts the two payload terms of the paper's formula:
//! clipped gradient sums shipped up (`bytes_to_leader`) and updated
//! trainable parameters broadcast back down (`bytes_from_leader`), both as
//! real serialized f32 little-endian buffers.  Fixed-size control headers
//! (chunk indices, per-chunk losses, the clip radius) and the one-time
//! frozen-backbone broadcast at phase start (`bytes_bootstrap`) are
//! tracked separately or not at all — they are provisioning, not the
//! per-exchange traffic §3.1 is about.
//!
//! Replication is driven by `engine::Session` (see `JobSpec::replicas`);
//! workers are handed a backend factory so this module never hard-codes an
//! execution backend.

use std::rc::Rc;
use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::engine::{EngineError, Pinned, StepRunner};
use crate::util::tensor::{f32s_from_le_bytes, f32s_to_le_bytes, Tensor};

/// Traffic of one (or many, when merged) all-to-leader gradient exchanges.
#[derive(Debug, Clone, Copy, Default)]
pub struct CommStats {
    /// Replica workers in the group.
    pub workers: usize,
    /// Elements of the exchanged gradient/parameter vectors (D or D_bias).
    pub grad_len: usize,
    /// Logical-batch exchange rounds counted.
    pub rounds: usize,
    /// Serialized clipped-gradient bytes received by the leader.
    pub bytes_to_leader: u64,
    /// Serialized updated-parameter bytes broadcast back to workers.
    pub bytes_from_leader: u64,
    /// One-time provisioning traffic (frozen-backbone broadcasts), kept out
    /// of `total_bytes` because §3.1 counts per-exchange traffic only.
    pub bytes_bootstrap: u64,
    pub wall_seconds: f64,
}

impl CommStats {
    /// Per-exchange traffic (gradients up + parameter broadcasts down).
    pub fn total_bytes(&self) -> u64 {
        self.bytes_to_leader + self.bytes_from_leader
    }

    /// Fold another measurement into this one (bytes/rounds/wall add;
    /// workers and vector length keep their maximum, so merging the two
    /// phases of an X+BiTFiT job reports the wider exchange).
    pub fn merge(&mut self, other: &CommStats) {
        self.workers = self.workers.max(other.workers);
        self.grad_len = self.grad_len.max(other.grad_len);
        self.rounds += other.rounds;
        self.bytes_to_leader += other.bytes_to_leader;
        self.bytes_from_leader += other.bytes_from_leader;
        self.bytes_bootstrap += other.bytes_bootstrap;
        self.wall_seconds += other.wall_seconds;
    }
}

/// The paper's §3.1 analytic per-round exchange volume: each of `workers`
/// replicas ships a `grad_len`-element f32 gradient up and receives the
/// `grad_len` updated parameters back — 64·M·D bits per round with 32-bit
/// floats each way.  Used by `benches/comm_cost.rs` to project the measured
/// small-model traffic onto the paper's published architectures.
pub fn paper_round_bytes(workers: usize, grad_len: usize) -> u64 {
    2 * 4 * workers as u64 * grad_len as u64
}

/// One microbatch assigned to a replica: its global chunk index plus the
/// filled fixed-shape step inputs.
struct ChunkWork {
    index: usize,
    x: Tensor,
    y: Tensor,
    mask: Tensor,
}

/// Leader -> worker messages.
enum ToWorker {
    /// Serialized frozen parameter vector (once per phase; bootstrap).
    Frozen(Vec<u8>),
    /// One logical-batch assignment: current trainable parameters plus the
    /// chunks this replica owns, in ascending chunk order.
    Run { train: Vec<u8>, clip_r: f32, chunks: Vec<ChunkWork> },
}

/// One chunk's result: raw summed loss and the serialized clipped
/// gradient sum, still keyed by the global chunk index.
struct ChunkResult {
    index: usize,
    loss: f32,
    grad: Vec<u8>,
}

/// Worker -> leader messages.
enum FromWorker {
    /// Step loaded; the worker is ready for traffic.
    Ready,
    /// The factory failed inside the worker thread.
    Failed(String),
    /// Results for one `Run` assignment, in the assigned chunk order.
    Batch(Vec<ChunkResult>),
    /// A step execution failed.
    Error(String),
}

/// The loop each replica worker thread runs: build the step via the
/// factory, then serve `Frozen` / `Run` messages until the leader hangs up.
fn worker_loop<F>(factory: F, rx: mpsc::Receiver<ToWorker>, tx: mpsc::Sender<FromWorker>)
where
    F: FnOnce() -> Result<Rc<dyn StepRunner>, EngineError>,
{
    let runner = match factory() {
        Ok(r) => {
            if tx.send(FromWorker::Ready).is_err() {
                return;
            }
            r
        }
        Err(e) => {
            let _ = tx.send(FromWorker::Failed(e.to_string()));
            return;
        }
    };
    let meta = runner.meta().clone();
    let mut pinned_frozen: Option<Pinned> = None;
    for msg in rx {
        match msg {
            ToWorker::Frozen(bytes) => {
                let t = Tensor::f32(vec![meta.pf], f32s_from_le_bytes(&bytes));
                match runner.pin(&t) {
                    Ok(p) => pinned_frozen = Some(p),
                    Err(e) => {
                        if tx.send(FromWorker::Error(e.to_string())).is_err() {
                            return;
                        }
                    }
                }
            }
            ToWorker::Run { train, clip_r, chunks } => {
                let Some(frozen) = pinned_frozen.as_ref() else {
                    if tx
                        .send(FromWorker::Error(
                            "replica received a batch before the frozen broadcast".to_string(),
                        ))
                        .is_err()
                    {
                        return;
                    }
                    continue;
                };
                let train_t = Tensor::f32(vec![meta.pt], f32s_from_le_bytes(&train));
                let clip_t = Tensor::scalar_f32(clip_r);
                let mut results = Vec::with_capacity(chunks.len());
                let mut failed = false;
                for c in &chunks {
                    let out = runner.run_pinned(
                        &[frozen],
                        &[
                            None,
                            Some(&train_t),
                            Some(&c.x),
                            Some(&c.y),
                            Some(&c.mask),
                            Some(&clip_t),
                        ],
                    );
                    match out {
                        Ok(out) => results.push(ChunkResult {
                            index: c.index,
                            loss: out[0].item_f32(),
                            grad: f32s_to_le_bytes(out[1].as_f32()),
                        }),
                        Err(e) => {
                            if tx.send(FromWorker::Error(e.to_string())).is_err() {
                                return;
                            }
                            failed = true;
                            break;
                        }
                    }
                }
                if !failed && tx.send(FromWorker::Batch(results)).is_err() {
                    return;
                }
            }
        }
    }
}

/// One live replica: its channel pair plus the join handle.
struct Worker {
    tx: Option<mpsc::Sender<ToWorker>>,
    rx: mpsc::Receiver<FromWorker>,
    handle: Option<JoinHandle<()>>,
}

/// A group of N persistent replica workers executing one train artifact.
///
/// Spawned once per training phase (workers keep their loaded step and
/// pinned frozen parameters across logical batches), fed one logical batch
/// at a time by [`ReplicaGroup::run_batch`], and joined on drop.
pub struct ReplicaGroup {
    workers: Vec<Worker>,
    stats: CommStats,
    /// Set when a round failed: replies may still be queued mid-stream, so
    /// further rounds would reduce stale gradients.  Poisoned groups refuse
    /// all traffic instead.
    poisoned: bool,
}

impl ReplicaGroup {
    /// Spawn `n` replica workers.  Each worker thread invokes its own clone
    /// of `factory` to build the step runner it will serve (backends are
    /// per-thread: `StepRunner`s are deliberately not `Send`).
    ///
    /// Fails — after joining every thread — if any worker's factory fails.
    pub fn spawn<F>(n: usize, factory: F) -> Result<ReplicaGroup, EngineError>
    where
        F: Fn() -> Result<Rc<dyn StepRunner>, EngineError> + Send + Clone + 'static,
    {
        if n == 0 {
            return Err(EngineError::spec("replica group needs at least one worker"));
        }
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let (to_tx, to_rx) = mpsc::channel::<ToWorker>();
            let (from_tx, from_rx) = mpsc::channel::<FromWorker>();
            let f = factory.clone();
            // Replica workers are long-lived and their results merge
            // through the fixed-order reduction below.
            // fastdp-lint: allow(thread-spawn) long-lived replica workers
            let handle = std::thread::spawn(move || worker_loop(f, to_rx, from_tx));
            workers.push(Worker { tx: Some(to_tx), rx: from_rx, handle: Some(handle) });
        }
        let group = ReplicaGroup {
            workers,
            stats: CommStats { workers: n, ..CommStats::default() },
            poisoned: false,
        };
        for (i, w) in group.workers.iter().enumerate() {
            match w.rx.recv() {
                Ok(FromWorker::Ready) => {}
                Ok(FromWorker::Failed(e)) => {
                    return Err(EngineError::backend(
                        "replica",
                        format!("replica {i} failed to load its step: {e}"),
                    ));
                }
                Ok(_) => {
                    return Err(EngineError::backend(
                        "replica",
                        format!("replica {i} sent an unexpected first message"),
                    ));
                }
                Err(_) => {
                    return Err(EngineError::backend(
                        "replica",
                        format!("replica {i} died before reporting ready"),
                    ));
                }
            }
        }
        Ok(group)
    }

    /// Number of replica workers in the group.
    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// Broadcast the frozen parameter vector to every replica (once per
    /// phase).  Counted as bootstrap traffic, not per-exchange traffic.
    pub fn broadcast_frozen(&mut self, frozen: &[f32]) -> Result<(), EngineError> {
        self.check_poisoned()?;
        for (i, w) in self.workers.iter().enumerate() {
            let bytes = f32s_to_le_bytes(frozen);
            self.stats.bytes_bootstrap += bytes.len() as u64;
            let tx = w.tx.as_ref().expect("replica group already shut down");
            if tx.send(ToWorker::Frozen(bytes)).is_err() {
                self.poisoned = true;
                return Err(EngineError::backend(
                    "replica",
                    format!("replica {i} hung up during broadcast"),
                ));
            }
        }
        Ok(())
    }

    /// Run one logical batch: partition `chunks` contiguously over the
    /// replicas, broadcast the current trainable parameters down, collect
    /// per-chunk clipped gradient sums up, and reduce them **in fixed
    /// replica order** (= global chunk order) into `grad`.
    ///
    /// Returns the raw summed loss (the same f64 chunk-order fold the
    /// single-replica path computes) and this round's [`CommStats`].
    ///
    /// An `Err` abandons the round: replies still in flight stay queued,
    /// so the group **poisons itself** — every later call returns a hard
    /// error instead of silently reducing stale gradients.
    pub fn run_batch(
        &mut self,
        train: &[f32],
        clip_r: f32,
        chunks: Vec<(Tensor, Tensor, Tensor)>,
        grad: &mut [f32],
    ) -> Result<(f64, CommStats), EngineError> {
        self.check_poisoned()?;
        let out = self.run_batch_inner(train, clip_r, chunks, grad);
        if out.is_err() {
            self.poisoned = true;
        }
        out
    }

    fn check_poisoned(&self) -> Result<(), EngineError> {
        if self.poisoned {
            return Err(EngineError::backend(
                "replica",
                "replica group was poisoned by an earlier failed exchange; \
                 start a new session",
            ));
        }
        Ok(())
    }

    fn run_batch_inner(
        &mut self,
        train: &[f32],
        clip_r: f32,
        chunks: Vec<(Tensor, Tensor, Tensor)>,
        grad: &mut [f32],
    ) -> Result<(f64, CommStats), EngineError> {
        let t0 = std::time::Instant::now();
        let n = self.workers.len();
        let mut round = CommStats {
            workers: n,
            grad_len: grad.len(),
            rounds: 1,
            ..CommStats::default()
        };
        let c = chunks.len();
        // contiguous chunk ranges per replica, like the pool's row sharding
        let per = if c == 0 { 0 } else { (c + n - 1) / n };
        let mut assigned = vec![false; n];
        if per > 0 {
            let mut it = chunks.into_iter().enumerate();
            'outer: for (w, slot) in assigned.iter_mut().enumerate() {
                let mut work = Vec::with_capacity(per);
                for _ in 0..per {
                    match it.next() {
                        Some((index, (x, y, mask))) => {
                            work.push(ChunkWork { index, x, y, mask })
                        }
                        None => break,
                    }
                }
                if work.is_empty() {
                    break 'outer;
                }
                *slot = true;
                let train_bytes = f32s_to_le_bytes(train);
                round.bytes_from_leader += train_bytes.len() as u64;
                let tx = self.workers[w].tx.as_ref().expect("replica group already shut down");
                tx.send(ToWorker::Run { train: train_bytes, clip_r, chunks: work }).map_err(
                    |_| {
                        EngineError::backend(
                            "replica",
                            format!("replica {w} hung up before the batch"),
                        )
                    },
                )?;
            }
        }
        // collect in fixed replica order; within a reply, chunks arrive in
        // the worker's assigned (ascending) order, so the whole reduction
        // is the single-replica chunk-order fold
        let mut loss_sum = 0.0f64;
        let mut next_index = 0usize;
        for (w, was_assigned) in assigned.iter().enumerate() {
            if !*was_assigned {
                continue;
            }
            match self.workers[w].rx.recv() {
                Ok(FromWorker::Batch(results)) => {
                    for r in results {
                        debug_assert_eq!(
                            r.index, next_index,
                            "replica replies must arrive in global chunk order"
                        );
                        next_index += 1;
                        round.bytes_to_leader += r.grad.len() as u64;
                        let g = f32s_from_le_bytes(&r.grad);
                        if g.len() != grad.len() {
                            return Err(EngineError::backend(
                                "replica",
                                format!(
                                    "replica {w} shipped a {}-element gradient, expected {}",
                                    g.len(),
                                    grad.len()
                                ),
                            ));
                        }
                        crate::util::tensor::axpy(grad, 1.0, &g);
                        loss_sum += r.loss as f64;
                    }
                }
                Ok(FromWorker::Error(e)) => {
                    return Err(EngineError::backend("replica", format!("replica {w}: {e}")));
                }
                Ok(_) => {
                    return Err(EngineError::backend(
                        "replica",
                        format!("replica {w} sent an unexpected message"),
                    ));
                }
                Err(_) => {
                    return Err(EngineError::backend(
                        "replica",
                        format!("replica {w} died mid-batch"),
                    ));
                }
            }
        }
        round.wall_seconds = t0.elapsed().as_secs_f64();
        self.stats.merge(&round);
        Ok((loss_sum, round))
    }

    /// Cumulative traffic since the group was spawned.
    pub fn stats(&self) -> CommStats {
        self.stats
    }
}

impl Drop for ReplicaGroup {
    fn drop(&mut self) {
        // hang up first so every worker's recv loop ends, then join
        for w in &mut self.workers {
            w.tx = None;
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Backend, InterpreterBackend};

    fn factory(artifact: &'static str) -> impl Fn() -> Result<Rc<dyn StepRunner>, EngineError>
           + Send
           + Clone
           + 'static {
        move || InterpreterBackend::new().load(artifact)
    }

    /// Fill `c` synthetic chunks shaped for `meta` (all rows active).
    fn synth_chunks(artifact: &str, c: usize) -> (usize, usize, Vec<(Tensor, Tensor, Tensor)>) {
        let backend = InterpreterBackend::new();
        let meta = backend.artifact_meta(artifact).unwrap();
        let chunks = (0..c)
            .map(|i| {
                let inputs =
                    crate::bench::synth_step_inputs(&backend, &meta, 100 + i as u64).unwrap();
                (inputs[2].clone(), inputs[3].clone(), inputs[4].clone())
            })
            .collect();
        (meta.pf, meta.pt, chunks)
    }

    fn split_params(artifact: &str) -> (Vec<f32>, Vec<f32>) {
        let backend = InterpreterBackend::new();
        let meta = backend.artifact_meta(artifact).unwrap();
        let layout = backend.layout(&meta.model).unwrap();
        let full = backend.init_params(&meta.model).unwrap();
        layout.split(&full, &meta.subset)
    }

    #[test]
    fn replica_count_never_changes_the_reduction() {
        let artifact = "cls-base__dp-bitfit";
        let (_, pt, _) = synth_chunks(artifact, 1);
        let (frozen, train) = split_params(artifact);
        let run = |n: usize| -> (f64, Vec<u32>, CommStats) {
            let mut g = ReplicaGroup::spawn(n, factory(artifact)).unwrap();
            g.broadcast_frozen(&frozen).unwrap();
            let (_, _, chunks) = synth_chunks(artifact, 5);
            let mut grad = vec![0.0f32; pt];
            let (loss, stats) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
            (loss, grad.iter().map(|v| v.to_bits()).collect(), stats)
        };
        let (loss1, grad1, _) = run(1);
        for n in [2usize, 3, 4, 8] {
            let (loss, grad, stats) = run(n);
            assert_eq!(loss.to_bits(), loss1.to_bits(), "replicas={n}");
            assert_eq!(grad, grad1, "replicas={n}");
            assert_eq!(stats.workers, n);
        }
    }

    #[test]
    fn wire_accounting_counts_payloads_exactly() {
        let artifact = "cls-base__dp-bitfit";
        let (pf, pt, chunks) = synth_chunks(artifact, 3);
        let (frozen, train) = split_params(artifact);
        let mut g = ReplicaGroup::spawn(2, factory(artifact)).unwrap();
        g.broadcast_frozen(&frozen).unwrap();
        let mut grad = vec![0.0f32; pt];
        let (_, stats) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
        // 3 chunks of pt-element clipped gradient sums up
        assert_eq!(stats.bytes_to_leader, 3 * pt as u64 * 4);
        // ceil(3/2)=2 chunks to replica 0, 1 to replica 1: both active, each
        // got one pt-element parameter broadcast down
        assert_eq!(stats.bytes_from_leader, 2 * pt as u64 * 4);
        assert_eq!(stats.rounds, 1);
        // frozen bootstrap went to both replicas and stays out of total_bytes
        let total = g.stats();
        assert_eq!(total.bytes_bootstrap, 2 * pf as u64 * 4);
        assert_eq!(total.total_bytes(), stats.bytes_to_leader + stats.bytes_from_leader);
    }

    #[test]
    fn idle_replicas_get_no_traffic() {
        let artifact = "cls-base__dp-bitfit";
        let (_, pt, chunks) = synth_chunks(artifact, 2);
        let (frozen, train) = split_params(artifact);
        // 4 replicas, 2 chunks: ceil(2/4)=1 each for replicas 0 and 1
        let mut g = ReplicaGroup::spawn(4, factory(artifact)).unwrap();
        g.broadcast_frozen(&frozen).unwrap();
        let mut grad = vec![0.0f32; pt];
        let (_, stats) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
        assert_eq!(stats.bytes_from_leader, 2 * pt as u64 * 4);
        assert_eq!(stats.bytes_to_leader, 2 * pt as u64 * 4);
        // empty logical batch: nothing crosses the wire, round still counted
        let (loss, stats) = g.run_batch(&train, 0.05, Vec::new(), &mut grad).unwrap();
        assert_eq!(loss, 0.0);
        assert_eq!(stats.total_bytes(), 0);
        assert_eq!(stats.rounds, 1);
    }

    #[test]
    fn bad_artifact_fails_at_spawn_with_joined_threads() {
        let err = ReplicaGroup::spawn(2, factory("cls-base__dp-quantum")).unwrap_err();
        assert!(matches!(err, EngineError::Backend { .. }), "{err}");
    }

    #[test]
    fn failed_exchange_poisons_the_group() {
        let artifact = "cls-base__dp-bitfit";
        let (_, pt, chunks) = synth_chunks(artifact, 2);
        let (frozen, train) = split_params(artifact);
        let mut g = ReplicaGroup::spawn(2, factory(artifact)).unwrap();
        g.broadcast_frozen(&frozen).unwrap();
        // a wrong-sized leader accumulator makes the round fail mid-reduce
        let mut bad_grad = vec![0.0f32; pt + 1];
        let err = g.run_batch(&train, 0.05, chunks, &mut bad_grad).unwrap_err();
        assert!(err.to_string().contains("gradient"), "{err}");
        // the group must now refuse all traffic rather than reduce the
        // stale replies still queued in the worker channels
        let (_, _, chunks) = synth_chunks(artifact, 2);
        let mut grad = vec![0.0f32; pt];
        let err = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        let err = g.broadcast_frozen(&frozen).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
    }

    #[test]
    fn paper_round_bytes_matches_the_formula() {
        // 64·M·D bits per exchange = M·D·4 bytes up + M·D·4 bytes down
        assert_eq!(paper_round_bytes(4, 1000), 4 * 1000 * 8);
        assert_eq!(paper_round_bytes(1, 1), 8);
    }

    #[test]
    fn comm_stats_merge_adds_traffic() {
        let mut a = CommStats {
            workers: 2,
            grad_len: 10,
            rounds: 1,
            bytes_to_leader: 100,
            bytes_from_leader: 50,
            bytes_bootstrap: 7,
            wall_seconds: 0.5,
        };
        let b = CommStats {
            workers: 4,
            grad_len: 5,
            rounds: 2,
            bytes_to_leader: 10,
            bytes_from_leader: 5,
            bytes_bootstrap: 1,
            wall_seconds: 0.25,
        };
        a.merge(&b);
        assert_eq!(a.workers, 4);
        assert_eq!(a.grad_len, 10);
        assert_eq!(a.rounds, 3);
        assert_eq!(a.total_bytes(), 165);
        assert_eq!(a.bytes_bootstrap, 8);
        assert!((a.wall_seconds - 0.75).abs() < 1e-12);
    }
}
