//! Real data-parallel replicated training: N replica workers on real
//! threads, each running the configured kernel tier of [`crate::kernels`]
//! (fused by default; ghost/blocked/simd propagate from the leader's
//! backend config) over a disjoint microbatch shard of the Poisson logical
//! batch, shipping their clipped gradient sums to the leader over a
//! pluggable [`crate::coordinator::transport`]: in-process channels (the
//! default — byte-for-byte the PR 3 behavior) or framed TCP loopback
//! sockets, with the per-exchange payloads encoded by a per-job
//! [`WireCodec`] (`raw-f32le` bitwise, `bf16` half-width).  Bytes are
//! counted on the wire as the *encoded* payload sizes, so
//! `benches/comm_cost.rs` measures the paper's §3.1 claim — 64·M·D bits per
//! exchange for full fine-tuning vs 64·M·D_bias for DP-BiTFiT — on an
//! actual training run over an actual socket.
//!
//! ## Determinism contract (the cross-replica analog of `runtime::pool`)
//!
//! The logical batch is split into the same fixed-shape microbatch chunks
//! the single-replica path uses, and each replica owns a **contiguous run
//! of chunks** (`ceil(C / N)` per replica, like the pool's row sharding).
//! Workers return one clipped gradient sum *per owned chunk*, in chunk
//! order; the leader reduces replies **in fixed replica order**, which —
//! because the assignment is contiguous — is exactly the global chunk
//! order.  The leader therefore performs the identical sequence of f32
//! `axpy` accumulations (and f64 loss additions) as the single-replica
//! loop in `engine::Session::run_step`, so with the `raw-f32le` codec
//! training is **bit-identical for any replica count and either
//! transport**, including 1.  The `bf16` codec trades that for half the
//! wire under the ghost/simd-style tolerance contract (1e-2 relative on
//! short trajectories).  Gaussian noise is added exactly once per logical
//! batch, by the leader, after the reduction.
//!
//! ## Straggler tolerance and rejoin
//!
//! Every leader-side receive is bounded by the job's `recv_timeout`
//! ([`TransportOpts`], `FASTDP_RECV_TIMEOUT_MS`): a dead or straggling
//! worker yields a typed [`EngineError`] within the deadline instead of
//! hanging the reduction, and the group **poisons** (replies may still be
//! in flight, so reducing further rounds would fold in stale gradients).
//! [`ReplicaGroup::rejoin`] recovers without abandoning the phase: it
//! spawns fresh workers for the dead slots, replays the cached frozen
//! bootstrap to them, drains stranded replies from the survivors behind a
//! sync barrier, and clears the poison — training state lives on the
//! leader (parameters are re-broadcast every round), so the next
//! `run_batch` continues the exact trajectory.  When the *leader* itself
//! must move, pair this with `Session::save_state` /
//! `Engine::resume_session` (the PR 3 snapshot).
//!
//! ## Wire accounting
//!
//! [`CommStats`] counts the two payload terms of the paper's formula:
//! clipped gradient sums shipped up (`bytes_to_leader`) and updated
//! trainable parameters broadcast back down (`bytes_from_leader`), both as
//! real serialized buffers in the job's wire codec.  Fixed-size control
//! headers (chunk indices, per-chunk losses, the clip radius, frame
//! magic/length/CRC) and the one-time frozen-backbone broadcast at phase
//! start (`bytes_bootstrap`, always raw f32 LE) are tracked separately or
//! not at all — they are provisioning, not the per-exchange traffic §3.1
//! is about.
//!
//! Replication is driven by `engine::Session` (see `JobSpec::replicas`);
//! workers are handed a backend factory so this module never hard-codes an
//! execution backend.

use std::rc::Rc;
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::coordinator::transport::{
    self, ChunkResult, ChunkWork, FromWorker, LeaderLink, LinkFault, ToWorker, TransportOpts,
    WireCodec, WorkerLink,
};
use crate::engine::{EngineError, Pinned, StepRunner};
use crate::util::tensor::{f32s_from_le_bytes, f32s_to_le_bytes, Tensor};

/// Traffic of one (or many, when merged) all-to-leader gradient exchanges.
#[derive(Debug, Clone, Copy, Default)]
pub struct CommStats {
    /// Replica workers in the group.
    pub workers: usize,
    /// Elements of the exchanged gradient/parameter vectors (D or D_bias).
    pub grad_len: usize,
    /// Logical-batch exchange rounds counted.
    pub rounds: usize,
    /// Serialized clipped-gradient bytes received by the leader.
    pub bytes_to_leader: u64,
    /// Serialized updated-parameter bytes broadcast back to workers.
    pub bytes_from_leader: u64,
    /// One-time provisioning traffic (frozen-backbone broadcasts), kept out
    /// of `total_bytes` because §3.1 counts per-exchange traffic only.
    pub bytes_bootstrap: u64,
    pub wall_seconds: f64,
}

impl CommStats {
    /// Per-exchange traffic (gradients up + parameter broadcasts down).
    pub fn total_bytes(&self) -> u64 {
        self.bytes_to_leader + self.bytes_from_leader
    }

    /// Fold another measurement into this one (bytes/rounds/wall add;
    /// workers and vector length keep their maximum, so merging the two
    /// phases of an X+BiTFiT job reports the wider exchange).
    pub fn merge(&mut self, other: &CommStats) {
        self.workers = self.workers.max(other.workers);
        self.grad_len = self.grad_len.max(other.grad_len);
        self.rounds += other.rounds;
        self.bytes_to_leader += other.bytes_to_leader;
        self.bytes_from_leader += other.bytes_from_leader;
        self.bytes_bootstrap += other.bytes_bootstrap;
        self.wall_seconds += other.wall_seconds;
    }
}

/// The paper's §3.1 analytic per-round exchange volume: each of `workers`
/// replicas ships a `grad_len`-element f32 gradient up and receives the
/// `grad_len` updated parameters back — 64·M·D bits per round with 32-bit
/// floats each way.  Used by `benches/comm_cost.rs` to project the measured
/// small-model traffic onto the paper's published architectures.
pub fn paper_round_bytes(workers: usize, grad_len: usize) -> u64 {
    2 * 4 * workers as u64 * grad_len as u64
}

/// The loop each replica worker thread runs: build the step via the
/// factory, then serve `Frozen` / `Run` / `Sync` messages until the leader
/// hangs up (or the link breaks — the leader's deadline notices).
fn worker_loop<F>(factory: F, mut link: Box<dyn WorkerLink>, codec: WireCodec)
where
    F: FnOnce() -> Result<Rc<dyn StepRunner>, EngineError>,
{
    let runner = match factory() {
        Ok(r) => {
            if !link.send(FromWorker::Ready) {
                return;
            }
            r
        }
        Err(e) => {
            let _ = link.send(FromWorker::Failed(e.to_string()));
            return;
        }
    };
    let meta = runner.meta().clone();
    let mut pinned_frozen: Option<Pinned> = None;
    while let Some(msg) = link.recv() {
        match msg {
            ToWorker::Frozen(bytes) => {
                // bootstrap traffic is always raw f32 LE, codec-independent
                let t = Tensor::f32(vec![meta.pf], f32s_from_le_bytes(&bytes));
                match runner.pin(&t) {
                    Ok(p) => pinned_frozen = Some(p),
                    Err(e) => {
                        if !link.send(FromWorker::Error(e.to_string())) {
                            return;
                        }
                    }
                }
            }
            ToWorker::Sync(nonce) => {
                if !link.send(FromWorker::SyncAck(nonce)) {
                    return;
                }
            }
            ToWorker::Run { train, clip_r, chunks } => {
                let Some(frozen) = pinned_frozen.as_ref() else {
                    if !link.send(FromWorker::Error(
                        "replica received a batch before the frozen broadcast".to_string(),
                    )) {
                        return;
                    }
                    continue;
                };
                let train = match codec.decode(&train) {
                    Ok(v) => v,
                    Err(e) => {
                        if !link.send(FromWorker::Error(format!(
                            "undecodable parameter payload: {e}"
                        ))) {
                            return;
                        }
                        continue;
                    }
                };
                let train_t = Tensor::f32(vec![meta.pt], train);
                let clip_t = Tensor::scalar_f32(clip_r);
                let mut results = Vec::with_capacity(chunks.len());
                let mut failed = false;
                for c in &chunks {
                    let out = runner.run_pinned(
                        &[frozen],
                        &[
                            None,
                            Some(&train_t),
                            Some(&c.x),
                            Some(&c.y),
                            Some(&c.mask),
                            Some(&clip_t),
                        ],
                    );
                    match out {
                        Ok(out) => results.push(ChunkResult {
                            index: c.index,
                            loss: out[0].item_f32(),
                            grad: codec.encode(out[1].as_f32()),
                        }),
                        Err(e) => {
                            if !link.send(FromWorker::Error(e.to_string())) {
                                return;
                            }
                            failed = true;
                            break;
                        }
                    }
                }
                if !failed && !link.send(FromWorker::Batch(results)) {
                    return;
                }
            }
        }
    }
}

/// One live replica: its leader-side link plus the join handle.
struct Worker {
    link: Box<dyn LeaderLink>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for Worker {
    fn drop(&mut self) {
        self.link.hangup();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Map a link fault to the typed replica error, with the worker index and
/// what the leader was doing at the time.
fn link_err(w: usize, when: &str, fault: LinkFault) -> EngineError {
    EngineError::backend(
        "replica",
        match fault {
            LinkFault::Timeout => {
                format!("replica {w} missed the reply deadline {when} (straggler or dead worker)")
            }
            LinkFault::Closed(e) => format!("replica {w} died {when} ({e})"),
            LinkFault::Corrupt(e) => format!("replica {w} shipped a corrupt frame {when}: {e}"),
        },
    )
}

/// The shared worker factory: each (re)spawned worker thread builds its own
/// step runner through it (`StepRunner`s are deliberately not `Send`).
type WorkerFactory = Arc<dyn Fn() -> Result<Rc<dyn StepRunner>, EngineError> + Send + Sync>;

/// A group of N persistent replica workers executing one train artifact.
///
/// Spawned once per training phase (workers keep their loaded step and
/// pinned frozen parameters across logical batches), fed one logical batch
/// at a time by [`ReplicaGroup::run_batch`], and joined on drop.
pub struct ReplicaGroup {
    workers: Vec<Worker>,
    stats: CommStats,
    /// Set when a round failed: replies may still be queued mid-stream, so
    /// further rounds would reduce stale gradients.  Poisoned groups refuse
    /// all traffic until [`ReplicaGroup::rejoin`] resynchronizes them.
    poisoned: bool,
    opts: TransportOpts,
    factory: WorkerFactory,
    /// Raw f32 LE frozen broadcast, cached so a rejoined worker can be
    /// bootstrapped mid-phase.
    frozen: Option<Vec<u8>>,
    sync_nonce: u64,
}

impl ReplicaGroup {
    /// Spawn `n` replica workers on the default transport (in-process
    /// channels, `raw-f32le` payloads — the byte-for-byte PR 3 path).
    pub fn spawn<F>(n: usize, factory: F) -> Result<ReplicaGroup, EngineError>
    where
        F: Fn() -> Result<Rc<dyn StepRunner>, EngineError> + Send + Sync + 'static,
    {
        Self::spawn_with(n, factory, TransportOpts::default())
    }

    /// Spawn `n` replica workers over the configured transport.  Each
    /// worker thread invokes the shared `factory` to build the step runner
    /// it will serve.
    ///
    /// Fails — with every spawned thread joined — if any worker's factory
    /// fails or misses the ready deadline.
    pub fn spawn_with<F>(
        n: usize,
        factory: F,
        opts: TransportOpts,
    ) -> Result<ReplicaGroup, EngineError>
    where
        F: Fn() -> Result<Rc<dyn StepRunner>, EngineError> + Send + Sync + 'static,
    {
        if n == 0 {
            return Err(EngineError::spec("replica group needs at least one worker"));
        }
        let factory: WorkerFactory = Arc::new(factory);
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            workers.push(Self::spawn_worker(&factory, &opts)?);
        }
        let mut group = ReplicaGroup {
            workers,
            stats: CommStats { workers: n, ..CommStats::default() },
            poisoned: false,
            opts,
            factory,
            frozen: None,
            sync_nonce: 0,
        };
        for i in 0..n {
            group.wait_ready(i)?;
        }
        Ok(group)
    }

    /// Create one worker: a fresh transport connection plus the thread that
    /// serves it (the worker end connects inside its own thread).
    fn spawn_worker(factory: &WorkerFactory, opts: &TransportOpts) -> Result<Worker, EngineError> {
        let (link, seed) = transport::pair(opts.kind, opts.recv_timeout)?;
        let f = Arc::clone(factory);
        let codec = opts.wire;
        // Replica workers are long-lived and their results merge
        // through the fixed-order reduction below.
        // fastdp-lint: allow(thread-spawn) long-lived replica workers
        let handle = std::thread::spawn(move || match seed.connect() {
            Ok(worker_link) => worker_loop(move || f(), worker_link, codec),
            // a failed dial is reported by the leader's ready deadline
            Err(_) => {}
        });
        Ok(Worker { link, handle: Some(handle) })
    }

    /// Block (bounded by the ready deadline) until worker `i` reports in.
    fn wait_ready(&mut self, i: usize) -> Result<(), EngineError> {
        let timeout = self.opts.recv_timeout;
        match self.workers[i].link.recv(timeout) {
            Ok(FromWorker::Ready) => Ok(()),
            Ok(FromWorker::Failed(e)) => Err(EngineError::backend(
                "replica",
                format!("replica {i} failed to load its step: {e}"),
            )),
            Ok(_) => Err(EngineError::backend(
                "replica",
                format!("replica {i} sent an unexpected first message"),
            )),
            Err(fault) => Err(link_err(i, "before reporting ready", fault)),
        }
    }

    /// Number of replica workers in the group.
    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// The transport configuration the group was spawned with.
    pub fn opts(&self) -> TransportOpts {
        self.opts
    }

    /// Broadcast the frozen parameter vector to every replica (once per
    /// phase).  Counted as bootstrap traffic, not per-exchange traffic, and
    /// always raw f32 LE (provisioning accuracy is not the codec's to
    /// trade); the bytes are cached for mid-phase worker rejoin.
    pub fn broadcast_frozen(&mut self, frozen: &[f32]) -> Result<(), EngineError> {
        self.check_poisoned()?;
        let bytes = f32s_to_le_bytes(frozen);
        for i in 0..self.workers.len() {
            self.stats.bytes_bootstrap += bytes.len() as u64;
            if let Err(fault) = self.workers[i].link.send(ToWorker::Frozen(bytes.clone())) {
                self.poisoned = true;
                return Err(link_err(i, "during the frozen broadcast", fault));
            }
        }
        self.frozen = Some(bytes);
        Ok(())
    }

    /// Run one logical batch: partition `chunks` contiguously over the
    /// replicas, broadcast the current trainable parameters down, collect
    /// per-chunk clipped gradient sums up, and reduce them **in fixed
    /// replica order** (= global chunk order) into `grad`.
    ///
    /// Returns the raw summed loss (the same f64 chunk-order fold the
    /// single-replica path computes) and this round's [`CommStats`].
    ///
    /// An `Err` abandons the round: replies still in flight stay queued,
    /// so the group **poisons itself** — every later call returns a hard
    /// error instead of silently reducing stale gradients (recover with
    /// [`ReplicaGroup::rejoin`]).
    pub fn run_batch(
        &mut self,
        train: &[f32],
        clip_r: f32,
        chunks: Vec<(Tensor, Tensor, Tensor)>,
        grad: &mut [f32],
    ) -> Result<(f64, CommStats), EngineError> {
        self.check_poisoned()?;
        let out = self.run_batch_inner(train, clip_r, chunks, grad);
        if out.is_err() {
            self.poisoned = true;
        }
        out
    }

    fn check_poisoned(&self) -> Result<(), EngineError> {
        if self.poisoned {
            return Err(EngineError::backend(
                "replica",
                "replica group was poisoned by an earlier failed exchange; \
                 rejoin the dead workers or start a new session",
            ));
        }
        Ok(())
    }

    fn run_batch_inner(
        &mut self,
        train: &[f32],
        clip_r: f32,
        chunks: Vec<(Tensor, Tensor, Tensor)>,
        grad: &mut [f32],
    ) -> Result<(f64, CommStats), EngineError> {
        let t0 = std::time::Instant::now();
        let n = self.workers.len();
        let codec = self.opts.wire;
        let timeout = self.opts.recv_timeout;
        let mut round = CommStats {
            workers: n,
            grad_len: grad.len(),
            rounds: 1,
            ..CommStats::default()
        };
        let c = chunks.len();
        // contiguous chunk ranges per replica, like the pool's row sharding
        let per = if c == 0 { 0 } else { (c + n - 1) / n };
        let mut assigned = vec![false; n];
        if per > 0 {
            let train_bytes = codec.encode(train);
            let mut it = chunks.into_iter().enumerate();
            'outer: for (w, slot) in assigned.iter_mut().enumerate() {
                let mut work = Vec::with_capacity(per);
                for _ in 0..per {
                    match it.next() {
                        Some((index, (x, y, mask))) => {
                            work.push(ChunkWork { index, x, y, mask })
                        }
                        None => break,
                    }
                }
                if work.is_empty() {
                    break 'outer;
                }
                *slot = true;
                round.bytes_from_leader += train_bytes.len() as u64;
                self.workers[w]
                    .link
                    .send(ToWorker::Run { train: train_bytes.clone(), clip_r, chunks: work })
                    .map_err(|fault| link_err(w, "before the batch", fault))?;
            }
        }
        // collect in fixed replica order; within a reply, chunks arrive in
        // the worker's assigned (ascending) order, so the whole reduction
        // is the single-replica chunk-order fold
        let mut loss_sum = 0.0f64;
        let mut next_index = 0usize;
        for (w, was_assigned) in assigned.iter().enumerate() {
            if !*was_assigned {
                continue;
            }
            match self.workers[w].link.recv(timeout) {
                Ok(FromWorker::Batch(results)) => {
                    for r in results {
                        debug_assert_eq!(
                            r.index, next_index,
                            "replica replies must arrive in global chunk order"
                        );
                        next_index += 1;
                        round.bytes_to_leader += r.grad.len() as u64;
                        let g = codec.decode(&r.grad).map_err(|e| {
                            EngineError::backend(
                                "replica",
                                format!("replica {w} shipped undecodable gradient bytes: {e}"),
                            )
                        })?;
                        if g.len() != grad.len() {
                            return Err(EngineError::backend(
                                "replica",
                                format!(
                                    "replica {w} shipped a {}-element gradient, expected {}",
                                    g.len(),
                                    grad.len()
                                ),
                            ));
                        }
                        crate::util::tensor::axpy(grad, 1.0, &g);
                        loss_sum += r.loss as f64;
                    }
                }
                Ok(FromWorker::Error(e)) => {
                    return Err(EngineError::backend("replica", format!("replica {w}: {e}")));
                }
                Ok(_) => {
                    return Err(EngineError::backend(
                        "replica",
                        format!("replica {w} sent an unexpected message"),
                    ));
                }
                Err(fault) => return Err(link_err(w, "mid-batch", fault)),
            }
        }
        round.wall_seconds = t0.elapsed().as_secs_f64();
        self.stats.merge(&round);
        Ok((loss_sum, round))
    }

    /// Replace the listed (dead or straggling) workers with freshly spawned
    /// ones, replay the cached frozen bootstrap to them, drain any replies
    /// the surviving workers still have stranded from an aborted round
    /// (behind a sync barrier), and clear the poison flag.
    ///
    /// Training state lives on the leader — the trainable parameters are
    /// re-broadcast every round — so the next [`ReplicaGroup::run_batch`]
    /// continues the **exact** trajectory the group was on.  An empty
    /// `dead` list is a pure resynchronize-and-unpoison.  When the leader
    /// itself must move, replay the `Session::save_state` snapshot through
    /// `Engine::resume_session` instead (that path spawns a fresh group).
    pub fn rejoin(&mut self, dead: &[usize]) -> Result<(), EngineError> {
        for &w in dead {
            if w >= self.workers.len() {
                return Err(EngineError::spec(format!(
                    "no replica {w} to rejoin (group has {})",
                    self.workers.len()
                )));
            }
        }
        for &w in dead {
            let fresh = Self::spawn_worker(&self.factory, &self.opts)?;
            let mut old = std::mem::replace(&mut self.workers[w], fresh);
            old.link.hangup();
            // detach: a hung worker thread must not block its replacement
            drop(old.handle.take());
            drop(old);
            self.wait_ready(w)?;
            if let Some(bytes) = self.frozen.clone() {
                self.stats.bytes_bootstrap += bytes.len() as u64;
                self.workers[w]
                    .link
                    .send(ToWorker::Frozen(bytes))
                    .map_err(|fault| link_err(w, "during the rejoin bootstrap", fault))?;
            }
        }
        // resync survivors: anything still queued belongs to an aborted
        // round and must not leak into the next reduction
        self.sync_nonce += 1;
        let nonce = self.sync_nonce;
        let timeout = self.opts.recv_timeout;
        for w in 0..self.workers.len() {
            if dead.contains(&w) {
                continue;
            }
            self.workers[w]
                .link
                .send(ToWorker::Sync(nonce))
                .map_err(|fault| link_err(w, "during resync", fault))?;
            loop {
                match self.workers[w].link.recv(timeout) {
                    Ok(FromWorker::SyncAck(n)) if n == nonce => break,
                    // stale replies from the aborted round: discard
                    Ok(_) => continue,
                    Err(fault) => return Err(link_err(w, "during resync", fault)),
                }
            }
        }
        self.poisoned = false;
        Ok(())
    }

    /// Cumulative traffic since the group was spawned.
    pub fn stats(&self) -> CommStats {
        self.stats
    }
}

impl Drop for ReplicaGroup {
    fn drop(&mut self) {
        // hang up first so every worker's recv loop ends, then join
        for w in &mut self.workers {
            w.link.hangup();
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::TransportKind;
    use crate::engine::{Backend, InterpreterBackend};
    use crate::runtime::ArtifactMeta;
    use crate::util::tensor::l2_norm;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::{Duration, Instant};

    fn factory(
        artifact: &'static str,
    ) -> impl Fn() -> Result<Rc<dyn StepRunner>, EngineError> + Send + Sync + Clone + 'static
    {
        move || InterpreterBackend::new().load(artifact)
    }

    fn opts(kind: TransportKind, wire: WireCodec, ms: u64) -> TransportOpts {
        TransportOpts { kind, wire, recv_timeout: Duration::from_millis(ms) }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Fill `c` synthetic chunks shaped for `meta` (all rows active).
    fn synth_chunks(artifact: &str, c: usize) -> (usize, usize, Vec<(Tensor, Tensor, Tensor)>) {
        let backend = InterpreterBackend::new();
        let meta = backend.artifact_meta(artifact).unwrap();
        let chunks = (0..c)
            .map(|i| {
                let inputs =
                    crate::bench::synth_step_inputs(&backend, &meta, 100 + i as u64).unwrap();
                (inputs[2].clone(), inputs[3].clone(), inputs[4].clone())
            })
            .collect();
        (meta.pf, meta.pt, chunks)
    }

    fn split_params(artifact: &str) -> (Vec<f32>, Vec<f32>) {
        let backend = InterpreterBackend::new();
        let meta = backend.artifact_meta(artifact).unwrap();
        let layout = backend.layout(&meta.model).unwrap();
        let full = backend.init_params(&meta.model).unwrap();
        layout.split(&full, &meta.subset)
    }

    #[test]
    fn replica_count_never_changes_the_reduction() {
        let artifact = "cls-base__dp-bitfit";
        let (_, pt, _) = synth_chunks(artifact, 1);
        let (frozen, train) = split_params(artifact);
        let run = |n: usize| -> (f64, Vec<u32>, CommStats) {
            let mut g = ReplicaGroup::spawn(n, factory(artifact)).unwrap();
            g.broadcast_frozen(&frozen).unwrap();
            let (_, _, chunks) = synth_chunks(artifact, 5);
            let mut grad = vec![0.0f32; pt];
            let (loss, stats) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
            (loss, bits(&grad), stats)
        };
        let (loss1, grad1, _) = run(1);
        for n in [2usize, 3, 4, 8] {
            let (loss, grad, stats) = run(n);
            assert_eq!(loss.to_bits(), loss1.to_bits(), "replicas={n}");
            assert_eq!(grad, grad1, "replicas={n}");
            assert_eq!(stats.workers, n);
        }
    }

    #[test]
    fn tcp_raw_exchange_is_bit_identical_to_channel() {
        let artifact = "cls-base__dp-bitfit";
        let (_, pt, _) = synth_chunks(artifact, 1);
        let (frozen, train) = split_params(artifact);
        let run = |o: TransportOpts, n: usize| -> (f64, Vec<u32>, CommStats) {
            let mut g = ReplicaGroup::spawn_with(n, factory(artifact), o).unwrap();
            g.broadcast_frozen(&frozen).unwrap();
            let (_, _, chunks) = synth_chunks(artifact, 5);
            let mut grad = vec![0.0f32; pt];
            let (loss, stats) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
            (loss, bits(&grad), stats)
        };
        let (loss_ch, grad_ch, stats_ch) = run(TransportOpts::default(), 2);
        for n in [1usize, 2, 4] {
            let (loss, grad, stats) =
                run(opts(TransportKind::Tcp, WireCodec::RawF32le, 10_000), n);
            assert_eq!(loss.to_bits(), loss_ch.to_bits(), "tcp replicas={n}");
            assert_eq!(grad, grad_ch, "tcp replicas={n}");
            // the gradient payload volume is transport-independent
            assert_eq!(stats.bytes_to_leader, stats_ch.bytes_to_leader, "tcp replicas={n}");
        }
    }

    #[test]
    fn bf16_codec_halves_the_wire_within_tolerance() {
        let artifact = "cls-base__dp-bitfit";
        let (_, pt, _) = synth_chunks(artifact, 1);
        let (frozen, train) = split_params(artifact);
        let run = |o: TransportOpts| -> (Vec<f32>, CommStats) {
            let mut g = ReplicaGroup::spawn_with(2, factory(artifact), o).unwrap();
            g.broadcast_frozen(&frozen).unwrap();
            let (_, _, chunks) = synth_chunks(artifact, 4);
            let mut grad = vec![0.0f32; pt];
            let (_, stats) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
            (grad, stats)
        };
        let (grad_raw, stats_raw) = run(TransportOpts::default());
        for kind in [TransportKind::Channel, TransportKind::Tcp] {
            let (grad_bf, stats_bf) = run(opts(kind, WireCodec::Bf16, 10_000));
            // exactly half the payload bytes in both directions
            assert_eq!(stats_bf.bytes_to_leader * 2, stats_raw.bytes_to_leader, "{kind:?}");
            assert_eq!(stats_bf.bytes_from_leader * 2, stats_raw.bytes_from_leader, "{kind:?}");
            // and the reduced gradient stays close to the raw one
            let diff: Vec<f32> =
                grad_raw.iter().zip(&grad_bf).map(|(a, b)| a - b).collect();
            let rel = l2_norm(&diff) / l2_norm(&grad_raw).max(1e-12);
            assert!(rel <= 5e-2, "{kind:?}: bf16 gradient drifted {rel}");
        }
    }

    #[test]
    fn wire_accounting_counts_payloads_exactly() {
        let artifact = "cls-base__dp-bitfit";
        let (pf, pt, chunks) = synth_chunks(artifact, 3);
        let (frozen, train) = split_params(artifact);
        let mut g = ReplicaGroup::spawn(2, factory(artifact)).unwrap();
        g.broadcast_frozen(&frozen).unwrap();
        let mut grad = vec![0.0f32; pt];
        let (_, stats) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
        // 3 chunks of pt-element clipped gradient sums up
        assert_eq!(stats.bytes_to_leader, 3 * pt as u64 * 4);
        // ceil(3/2)=2 chunks to replica 0, 1 to replica 1: both active, each
        // got one pt-element parameter broadcast down
        assert_eq!(stats.bytes_from_leader, 2 * pt as u64 * 4);
        assert_eq!(stats.rounds, 1);
        // frozen bootstrap went to both replicas and stays out of total_bytes
        let total = g.stats();
        assert_eq!(total.bytes_bootstrap, 2 * pf as u64 * 4);
        assert_eq!(total.total_bytes(), stats.bytes_to_leader + stats.bytes_from_leader);
    }

    #[test]
    fn idle_replicas_get_no_traffic() {
        let artifact = "cls-base__dp-bitfit";
        let (_, pt, chunks) = synth_chunks(artifact, 2);
        let (frozen, train) = split_params(artifact);
        // 4 replicas, 2 chunks: ceil(2/4)=1 each for replicas 0 and 1
        let mut g = ReplicaGroup::spawn(4, factory(artifact)).unwrap();
        g.broadcast_frozen(&frozen).unwrap();
        let mut grad = vec![0.0f32; pt];
        let (_, stats) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
        assert_eq!(stats.bytes_from_leader, 2 * pt as u64 * 4);
        assert_eq!(stats.bytes_to_leader, 2 * pt as u64 * 4);
        // empty logical batch: nothing crosses the wire, round still counted
        let (loss, stats) = g.run_batch(&train, 0.05, Vec::new(), &mut grad).unwrap();
        assert_eq!(loss, 0.0);
        assert_eq!(stats.total_bytes(), 0);
        assert_eq!(stats.rounds, 1);
    }

    #[test]
    fn bad_artifact_fails_at_spawn_with_joined_threads() {
        let err = ReplicaGroup::spawn(2, factory("cls-base__dp-quantum")).unwrap_err();
        assert!(matches!(err, EngineError::Backend { .. }), "{err}");
    }

    #[test]
    fn failed_exchange_poisons_the_group() {
        let artifact = "cls-base__dp-bitfit";
        let (_, pt, chunks) = synth_chunks(artifact, 2);
        let (frozen, train) = split_params(artifact);
        let mut g = ReplicaGroup::spawn(2, factory(artifact)).unwrap();
        g.broadcast_frozen(&frozen).unwrap();
        // a wrong-sized leader accumulator makes the round fail mid-reduce
        let mut bad_grad = vec![0.0f32; pt + 1];
        let err = g.run_batch(&train, 0.05, chunks, &mut bad_grad).unwrap_err();
        assert!(err.to_string().contains("gradient"), "{err}");
        // the group must now refuse all traffic rather than reduce the
        // stale replies still queued in the worker channels
        let (_, _, chunks) = synth_chunks(artifact, 2);
        let mut grad = vec![0.0f32; pt];
        let err = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
        let err = g.broadcast_frozen(&frozen).unwrap_err();
        assert!(err.to_string().contains("poisoned"), "{err}");
    }

    #[test]
    fn resync_after_a_failed_exchange_drains_stale_replies() {
        let artifact = "cls-base__dp-bitfit";
        let (_, pt, _) = synth_chunks(artifact, 1);
        let (frozen, train) = split_params(artifact);
        // reference reduction from a group that never failed
        let mut healthy = ReplicaGroup::spawn(2, factory(artifact)).unwrap();
        healthy.broadcast_frozen(&frozen).unwrap();
        let (_, _, chunks) = synth_chunks(artifact, 2);
        let mut want = vec![0.0f32; pt];
        let (want_loss, _) = healthy.run_batch(&train, 0.05, chunks, &mut want).unwrap();

        let mut g = ReplicaGroup::spawn(2, factory(artifact)).unwrap();
        g.broadcast_frozen(&frozen).unwrap();
        let (_, _, chunks) = synth_chunks(artifact, 2);
        let mut bad_grad = vec![0.0f32; pt + 1];
        g.run_batch(&train, 0.05, chunks, &mut bad_grad).unwrap_err();
        // replica 1's Batch reply is still stranded in its link; an empty
        // rejoin is a pure resync + unpoison
        g.rejoin(&[]).unwrap();
        let (_, _, chunks) = synth_chunks(artifact, 2);
        let mut grad = vec![0.0f32; pt];
        let (loss, _) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
        assert_eq!(loss.to_bits(), want_loss.to_bits());
        assert_eq!(bits(&grad), bits(&want));
    }

    /// Delegating runner that stalls every step while `stall` is set —
    /// the straggler/dead-worker stand-in (threads cannot be killed).
    struct SlowRunner {
        inner: Rc<dyn StepRunner>,
        stall: Arc<AtomicBool>,
    }

    impl StepRunner for SlowRunner {
        fn meta(&self) -> &ArtifactMeta {
            self.inner.meta()
        }

        fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, EngineError> {
            self.inner.run(inputs)
        }

        fn pin(&self, t: &Tensor) -> Result<Pinned, EngineError> {
            self.inner.pin(t)
        }

        fn run_pinned(
            &self,
            pinned: &[&Pinned],
            host: &[Option<&Tensor>],
        ) -> Result<Vec<Tensor>, EngineError> {
            if self.stall.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(3000));
            }
            self.inner.run_pinned(pinned, host)
        }
    }

    fn slow_factory(
        artifact: &'static str,
        stall: Arc<AtomicBool>,
    ) -> impl Fn() -> Result<Rc<dyn StepRunner>, EngineError> + Send + Sync + Clone + 'static
    {
        move || {
            let inner = InterpreterBackend::new().load(artifact)?;
            Ok(Rc::new(SlowRunner { inner, stall: stall.clone() }) as Rc<dyn StepRunner>)
        }
    }

    #[test]
    fn straggler_misses_the_deadline_then_rejoins_bit_identically() {
        for kind in [TransportKind::Channel, TransportKind::Tcp] {
            let artifact = "cls-base__dp-bitfit";
            let (_, pt, _) = synth_chunks(artifact, 1);
            let (frozen, train) = split_params(artifact);
            // reference reduction from a healthy group on the same transport
            let mut healthy = ReplicaGroup::spawn_with(
                2,
                factory(artifact),
                opts(kind, WireCodec::RawF32le, 10_000),
            )
            .unwrap();
            healthy.broadcast_frozen(&frozen).unwrap();
            let (_, _, chunks) = synth_chunks(artifact, 4);
            let mut want = vec![0.0f32; pt];
            let (want_loss, _) = healthy.run_batch(&train, 0.05, chunks, &mut want).unwrap();

            let stall = Arc::new(AtomicBool::new(true));
            let mut g = ReplicaGroup::spawn_with(
                2,
                slow_factory(artifact, stall.clone()),
                opts(kind, WireCodec::RawF32le, 300),
            )
            .unwrap();
            g.broadcast_frozen(&frozen).unwrap();
            let (_, _, chunks) = synth_chunks(artifact, 4);
            let mut grad = vec![0.0f32; pt];
            let t0 = Instant::now();
            let err = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap_err();
            // the deadline fired (no silent hang), with a typed error
            assert!(t0.elapsed() < Duration::from_millis(2500), "{kind:?}: deadline ignored");
            assert!(err.to_string().contains("deadline"), "{kind:?}: {err}");
            let (_, _, chunks) = synth_chunks(artifact, 4);
            let err = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap_err();
            assert!(err.to_string().contains("poisoned"), "{kind:?}: {err}");
            // replace both stalled workers and continue the exact trajectory
            stall.store(false, Ordering::SeqCst);
            g.rejoin(&[0, 1]).unwrap();
            let (_, _, chunks) = synth_chunks(artifact, 4);
            let mut grad = vec![0.0f32; pt];
            let (loss, _) = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap();
            assert_eq!(loss.to_bits(), want_loss.to_bits(), "{kind:?}");
            assert_eq!(bits(&grad), bits(&want), "{kind:?}");
        }
    }

    /// Delegating runner that dies mid-step: the worker thread panics, so
    /// its link drops mid-exchange (the TCP stream closes / the channel
    /// disconnects) — the "kill -9 the worker" stand-in.
    struct DyingRunner {
        inner: Rc<dyn StepRunner>,
    }

    impl StepRunner for DyingRunner {
        fn meta(&self) -> &ArtifactMeta {
            self.inner.meta()
        }

        fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>, EngineError> {
            self.inner.run(inputs)
        }

        fn pin(&self, t: &Tensor) -> Result<Pinned, EngineError> {
            self.inner.pin(t)
        }

        fn run_pinned(
            &self,
            _pinned: &[&Pinned],
            _host: &[Option<&Tensor>],
        ) -> Result<Vec<Tensor>, EngineError> {
            panic!("worker killed mid-step (test)");
        }
    }

    #[test]
    fn mid_exchange_disconnect_is_a_typed_error_on_both_transports() {
        for kind in [TransportKind::Channel, TransportKind::Tcp] {
            let artifact = "cls-base__dp-bitfit";
            let (_, pt, chunks) = synth_chunks(artifact, 2);
            let (frozen, train) = split_params(artifact);
            let f = move || -> Result<Rc<dyn StepRunner>, EngineError> {
                let inner = InterpreterBackend::new().load(artifact)?;
                Ok(Rc::new(DyingRunner { inner }) as Rc<dyn StepRunner>)
            };
            let mut g =
                ReplicaGroup::spawn_with(2, f, opts(kind, WireCodec::RawF32le, 10_000)).unwrap();
            g.broadcast_frozen(&frozen).unwrap();
            let mut grad = vec![0.0f32; pt];
            let err = g.run_batch(&train, 0.05, chunks, &mut grad).unwrap_err();
            assert!(matches!(err, EngineError::Backend { .. }), "{kind:?}: {err}");
            assert!(err.to_string().contains("replica"), "{kind:?}: {err}");
        }
    }

    #[test]
    fn paper_round_bytes_matches_the_formula() {
        // 64·M·D bits per exchange = M·D·4 bytes up + M·D·4 bytes down
        assert_eq!(paper_round_bytes(4, 1000), 4 * 1000 * 8);
        assert_eq!(paper_round_bytes(1, 1), 8);
    }

    #[test]
    fn comm_stats_merge_adds_traffic() {
        let mut a = CommStats {
            workers: 2,
            grad_len: 10,
            rounds: 1,
            bytes_to_leader: 100,
            bytes_from_leader: 50,
            bytes_bootstrap: 7,
            wall_seconds: 0.5,
        };
        let b = CommStats {
            workers: 4,
            grad_len: 5,
            rounds: 2,
            bytes_to_leader: 10,
            bytes_from_leader: 5,
            bytes_bootstrap: 1,
            wall_seconds: 0.25,
        };
        a.merge(&b);
        assert_eq!(a.workers, 4);
        assert_eq!(a.grad_len, 10);
        assert_eq!(a.rounds, 3);
        assert_eq!(a.total_bytes(), 165);
        assert_eq!(a.bytes_bootstrap, 8);
        assert!((a.wall_seconds - 0.75).abs() < 1e-12);
    }
}
