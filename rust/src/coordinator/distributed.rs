//! Simulated data-parallel training: measures the communication volume the
//! paper's §3.1 claims DP-BiTFiT reduces ~1000x (64 M D bits for full
//! fine-tuning vs 64 M D_bias for BiTFiT).
//!
//! Workers run on real threads and ship serialized gradient vectors to the
//! leader over channels; bytes are counted on the wire.  Gradient *values*
//! are synthetic (the point of this harness is the traffic, not the math —
//! numerical training happens in `trainer.rs` on the PJRT runtime).

use std::sync::mpsc;
use std::thread;

/// Result of a simulated all-to-leader gradient exchange.
#[derive(Debug, Clone, Copy)]
pub struct CommStats {
    pub workers: usize,
    pub grad_len: usize,
    pub rounds: usize,
    /// Total bytes received by the leader.
    pub bytes_to_leader: u64,
    /// Total bytes broadcast back (updated params).
    pub bytes_from_leader: u64,
    pub wall_seconds: f64,
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_to_leader + self.bytes_from_leader
    }
}

/// Run `rounds` of an M-worker parameter-server exchange with `grad_len`
/// f32 gradients (e.g. `grad_len` = D for full fine-tuning, D_bias for
/// DP-BiTFiT).
pub fn simulate(workers: usize, grad_len: usize, rounds: usize) -> CommStats {
    let t0 = std::time::Instant::now();
    let mut bytes_up = 0u64;
    let mut bytes_down = 0u64;
    for round in 0..rounds {
        let (tx, rx) = mpsc::channel::<Vec<u8>>();
        let mut handles = Vec::new();
        for w in 0..workers {
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                // serialize a synthetic gradient (values derived from ids so
                // the leader can verify integrity)
                let grad: Vec<f32> =
                    (0..grad_len).map(|i| ((i + w + round) % 7) as f32).collect();
                let bytes: Vec<u8> = grad.iter().flat_map(|v| v.to_le_bytes()).collect();
                tx.send(bytes).unwrap();
            }));
        }
        drop(tx);
        let mut agg = vec![0.0f64; grad_len];
        for bytes in rx {
            bytes_up += bytes.len() as u64;
            for (i, c) in bytes.chunks_exact(4).enumerate() {
                agg[i] += f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64;
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        // broadcast updated parameters back to every worker
        bytes_down += (workers * grad_len * 4) as u64;
        std::hint::black_box(&agg);
    }
    CommStats {
        workers,
        grad_len,
        rounds,
        bytes_to_leader: bytes_up,
        bytes_from_leader: bytes_down,
        wall_seconds: t0.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_accounting_is_exact() {
        let s = simulate(4, 1000, 3);
        assert_eq!(s.bytes_to_leader, 4 * 1000 * 4 * 3);
        assert_eq!(s.bytes_from_leader, 4 * 1000 * 4 * 3);
    }

    #[test]
    fn bitfit_reduction_matches_param_ratio() {
        // full D vs bias D/1000 => ~1000x traffic reduction (§3.1)
        let full = simulate(2, 100_000, 1);
        let bias = simulate(2, 100, 1);
        let ratio = full.total_bytes() as f64 / bias.total_bytes() as f64;
        assert!((ratio - 1000.0).abs() < 1.0, "{ratio}");
    }
}
