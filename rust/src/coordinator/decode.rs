//! Batched greedy decoding via the `*__decode` steps (E2E generation).

use crate::engine::{EngineError, StepRunner};
use crate::util::tensor::Tensor;

/// Greedy-decode completions for a batch of prompts.
///
/// `prompts[i]` are token ids (unpadded).  Returns per-prompt completions
/// (token ids after the prompt, EOS excluded).  Prompts are processed in
/// chunks of the step's fixed batch size.
pub fn greedy_decode(
    step: &dyn StepRunner,
    full: &[f32],
    prompts: &[Vec<i32>],
    max_new: usize,
    eos: i32,
) -> Result<Vec<Vec<u32>>, EngineError> {
    let meta = step.meta();
    if meta.step != "decode" {
        return Err(EngineError::Data(format!("{} is not a decode artifact", meta.name)));
    }
    let b = meta.batch;
    let t = meta
        .inputs
        .iter()
        .find(|i| i.name == "x")
        .ok_or_else(|| EngineError::Data(format!("{}: no x input", meta.name)))?
        .shape[1];
    let full_t = Tensor::f32(vec![full.len()], full.to_vec());
    let empty = Tensor::f32(vec![0], vec![]);
    let vocab = meta.outputs[0].shape[1];

    let mut out: Vec<Vec<u32>> = Vec::with_capacity(prompts.len());
    for chunk in prompts.chunks(b) {
        let mut x = vec![0i32; b * t];
        let mut pos = vec![0i32; b];
        let mut done = vec![false; b];
        let mut completions: Vec<Vec<u32>> = vec![Vec::new(); b];
        for (row, p) in chunk.iter().enumerate() {
            let len = p.len().min(t);
            x[row * t..row * t + len].copy_from_slice(&p[..len]);
            pos[row] = len as i32 - 1;
        }
        for _ in 0..max_new {
            if done.iter().take(chunk.len()).all(|&d| d) {
                break;
            }
            let logits = step.run(&[
                empty.clone(),
                full_t.clone(),
                Tensor::i32(vec![b, t], x.clone()),
                Tensor::i32(vec![b], pos.clone()),
            ])?;
            let l = logits[0].as_f32();
            for row in 0..chunk.len() {
                if done[row] {
                    continue;
                }
                let slice = &l[row * vocab..(row + 1) * vocab];
                let next = slice
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32;
                let np = pos[row] + 1;
                if next == eos || np as usize >= t {
                    done[row] = true;
                    continue;
                }
                x[row * t + np as usize] = next;
                pos[row] = np;
                completions[row].push(next as u32);
            }
        }
        out.extend(completions.into_iter().take(chunk.len()));
    }
    Ok(out)
}
