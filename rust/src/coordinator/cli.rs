//! The `fastdp` command-line interface.
//!
//! Subcommands:
//!   train       — run a (DP) fine-tuning job from a TOML config / flags
//!   eval        — evaluate a checkpoint with a model's eval artifact
//!   accountant  — query the RDP/GDP accountants or calibrate sigma
//!   zoo         — print the Table 1/11 parameter-efficiency table
//!   complexity  — print the Table 2/7 complexity table
//!   artifacts   — list AOT artifacts in the artifact directory

use anyhow::{Context, Result};

use super::checkpoint::Checkpoint;
use super::metrics::JsonlSink;
use super::optim::{LrSchedule, OptimKind};
use super::trainer::{evaluate_params, Trainer, TrainerConfig};
use super::workloads;
use crate::analysis::complexity::{layer_complexity, LayerDims, Method};
use crate::dp::{calibrate, gdp, rdp};
use crate::util::args::Args;
use crate::util::config::Config;
use crate::util::table::Table;

const USAGE: &str = "usage: fastdp <train|eval|accountant|zoo|complexity|artifacts>
  train      --artifact cls-base__dp-bitfit [--task sst2] [--steps N] [--batch N]
             [--lr F] [--eps F | --sigma F] [--delta F] [--clip F] [--optim adam]
             [--n N] [--seed N] [--pretrained ckpt] [--save ckpt] [--log out.jsonl]
             [--config cfg.toml] [--artifacts DIR]
  eval       --model cls-base --ckpt path [--task sst2] [--n N]
  accountant --q F --sigma F --steps N [--delta F]   (report eps, RDP + GDP)
  accountant --q F --steps N --target-eps F          (calibrate sigma)
  zoo
  complexity [--b N --t N --d N --p N]
  artifacts  [--artifacts DIR]";

pub fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("accountant") => cmd_accountant(&args),
        Some("zoo") => cmd_zoo(),
        Some("complexity") => cmd_complexity(&args),
        Some("artifacts") => cmd_artifacts(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.str("artifacts", "artifacts")
}

fn cmd_train(args: &Args) -> Result<()> {
    // config file first, flags override
    let mut cfg = match args.get("config") {
        Some(p) => Config::load(p).map_err(|e| anyhow::anyhow!(e))?,
        None => Config::default(),
    };
    for kv in args.get_all("set") {
        let (k, v) = kv.split_once('=').context("--set expects key=value")?;
        cfg.set(k, v).map_err(|e| anyhow::anyhow!(e))?;
    }
    let artifact = args.str("artifact", &cfg.str("train.artifact", ""));
    anyhow::ensure!(!artifact.is_empty(), "--artifact (or train.artifact) required");
    let steps = args.usize("steps", cfg.i64("train.steps", 100) as usize);
    let n = args.usize("n", cfg.i64("train.n", 4096) as usize);
    let seed = args.usize("seed", cfg.i64("train.seed", 0) as usize) as u64;
    let delta = args.f64("delta", cfg.f64("train.delta", 1e-5));
    let batch = args.usize("batch", cfg.i64("train.batch", 64) as usize);

    let mut rt = crate::runtime::Runtime::open(artifacts_dir(args))?;
    let exe = rt.load(&artifact)?;
    let meta = exe.meta.clone();
    let model = meta.model.clone();
    let default_task = workloads::default_task(&workloads::model_shape(&rt, &model)?.kind);
    let task = args.str("task", &cfg.str("train.task", default_task));
    let data = workloads::build(&rt, &model, &task, n, seed)?;

    let is_dp = meta.method.starts_with("dp-");
    let sigma = if !is_dp {
        0.0
    } else if let Some(s) = args.get("sigma") {
        s.parse::<f64>().context("--sigma")?
    } else {
        let eps = args.f64("eps", cfg.f64("train.eps", 8.0));
        let q = batch as f64 / n as f64;
        let sigma = calibrate::calibrate_sigma(q, steps as u64, eps, delta);
        println!("calibrated sigma = {sigma:.4} for eps = {eps} over {steps} steps (q = {q:.4})");
        sigma
    };

    let mut tc = TrainerConfig::new(&artifact);
    tc.logical_batch = batch;
    tc.lr = args.f64("lr", cfg.f64("train.lr", 5e-3));
    tc.optim = OptimKind::parse(&args.str("optim", &cfg.str("train.optim", "adam")))
        .context("bad --optim")?;
    tc.schedule = LrSchedule::Warmup { warmup: cfg.i64("train.warmup", 0) as u64 };
    tc.clip_r = args.f64("clip", cfg.f64("train.clip_r", 0.1));
    tc.sigma = sigma;
    tc.delta = delta;
    tc.seed = seed;

    let pretrained = match args.get("pretrained") {
        Some(p) => {
            let ck = Checkpoint::load(p)?;
            anyhow::ensure!(ck.model == model, "checkpoint is for {}", ck.model);
            Some(ck.params)
        }
        None => None,
    };
    let mut trainer = Trainer::new(&mut rt, tc, data.len(), pretrained)?;
    let mut sink = match args.get("log") {
        Some(p) => Some(JsonlSink::create(p)?),
        None => None,
    };
    println!(
        "training {artifact} on {task}: {} examples, {} trainable params ({:.3}% of {}), {} steps",
        data.len(),
        trainer.trainable_len(),
        100.0 * trainer.trainable_len() as f64 / rt.manifest.models[&model].n_params as f64,
        rt.manifest.models[&model].n_params,
        steps,
    );
    for i in 0..steps {
        let s = trainer.train_step(&data)?;
        if let Some(sink) = &mut sink {
            sink.step(s.step, s.loss, s.epsilon)?;
        }
        if i % 10 == 0 || i + 1 == steps {
            println!(
                "step {:>5}  loss {:.4}  |B| {:>4}  eps {:.3}",
                s.step, s.loss, s.batch, s.epsilon
            );
        }
    }
    for (label, secs, calls) in trainer.timers.report() {
        println!("  timer {label:<8} {secs:>8.3}s over {calls} calls");
    }
    if let Some(path) = args.get("save") {
        Checkpoint { model, step: trainer.step, params: trainer.full_params() }.save(path)?;
        println!("saved checkpoint to {path}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.str("model", "");
    anyhow::ensure!(!model.is_empty(), "--model required");
    let mut rt = crate::runtime::Runtime::open(artifacts_dir(args))?;
    let exe = rt.load(&format!("{model}__eval"))?;
    let params = match args.get("ckpt") {
        Some(p) => Checkpoint::load(p)?.params,
        None => rt.init_params(&model)?,
    };
    let shape = workloads::model_shape(&rt, &model)?;
    let task = args.str("task", workloads::default_task(&shape.kind));
    let n = args.usize("n", 1024);
    let data = workloads::build(&rt, &model, &task, n, args.usize("seed", 1) as u64)?;
    let (a, b, n) = evaluate_params(&exe, &params, &data, n)?;
    if shape.kind == "lm" {
        println!("nll/token = {:.4}  perplexity = {:.3}  ({b:.0} tokens)", a / b, (a / b).exp());
    } else {
        println!("loss = {:.4}  accuracy = {:.2}%  ({n} examples)", a / n as f64, 100.0 * b / n as f64);
    }
    Ok(())
}

fn cmd_accountant(args: &Args) -> Result<()> {
    let q = args.f64("q", 0.01);
    let steps = args.usize("steps", 1000) as u64;
    let delta = args.f64("delta", 1e-5);
    if let Some(te) = args.get("target-eps") {
        let target: f64 = te.parse().context("--target-eps")?;
        let sigma = calibrate::calibrate_sigma(q, steps, target, delta);
        println!("sigma = {sigma:.4} reaches eps <= {target} (q={q}, T={steps}, delta={delta})");
        return Ok(());
    }
    let sigma = args.f64("sigma", 1.0);
    let e_rdp = rdp::epsilon(q, sigma, steps, delta);
    let e_gdp = gdp::epsilon(q, sigma, steps, delta);
    println!("q={q} sigma={sigma} T={steps} delta={delta}");
    println!("  eps (RDP accountant) = {e_rdp:.4}");
    println!("  eps (GDP accountant) = {e_gdp:.4}");
    Ok(())
}

fn cmd_zoo() -> Result<()> {
    let mut t = Table::new(&["model", "params", "% bias (ours)", "% bias (paper)"]);
    for z in crate::models::zoo::zoo() {
        t.row(vec![
            z.name.to_string(),
            format!("{:.1}M", z.counts.total() as f64 / 1e6),
            format!("{:.3}", z.bias_pct()),
            format!("{:.3}", z.paper_bias_pct),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_complexity(args: &Args) -> Result<()> {
    let l = LayerDims {
        b: args.usize("b", 16) as u64,
        t: args.usize("t", 256) as u64,
        d: args.usize("d", 768) as u64,
        p: args.usize("p", 768) as u64,
    };
    let methods = [
        Method::NonDpFull,
        Method::OpacusFull,
        Method::GhostClipFull,
        Method::BookKeeping,
        Method::DpLora { rank: 16 },
        Method::DpAdapter { rank: 16 },
        Method::NonDpBias,
        Method::DpBias,
    ];
    println!(
        "per-layer complexity at B={} T={} d={} p={} (paper Table 2/7)",
        l.b, l.t, l.d, l.p
    );
    let mut t = Table::new(&[
        "method", "time (flops)", "+DP time", "space (floats)", "+DP space", "acts?", "backprops",
    ]);
    for m in methods {
        let c = layer_complexity(m, l);
        t.row(vec![
            m.name(),
            format!("{:.2e}", (c.base_time + c.train_time) as f64),
            format!("{:.2e}", c.dp_time as f64),
            format!("{:.2e}", c.base_space as f64),
            format!("{:.2e}", c.dp_space as f64),
            if m.stores_activations() { "yes" } else { "NO" }.into(),
            m.backprops().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let rt = crate::runtime::Runtime::open(artifacts_dir(args))?;
    println!("platform: {}", rt.platform());
    let mut t = Table::new(&["artifact", "model", "step", "B", "Pt"]);
    for name in &rt.manifest.artifacts {
        let meta = crate::runtime::ArtifactMeta::load(rt.artifact_dir(), name)?;
        t.row(vec![
            name.clone(),
            meta.model,
            meta.step,
            meta.batch.to_string(),
            meta.pt.to_string(),
        ]);
    }
    t.print();
    Ok(())
}
