//! The `fastdp` command-line interface — a thin translator from flags/TOML
//! into `engine::JobSpec`s.  All execution goes through `fastdp::engine`.
//!
//! Subcommands:
//!   train       — run a (DP) fine-tuning job (`--dry-run` prints the plan)
//!   serve       — multiplex N tenant jobs through the serve scheduler
//!   eval        — evaluate a checkpoint with a model's eval step
//!   accountant  — query the RDP/GDP accountants or calibrate sigma
//!   zoo         — print the Table 1/11 parameter-efficiency table
//!   complexity  — print the Table 2/7 complexity table
//!   artifacts   — list the steps the selected backend can serve

use anyhow::{Context, Result};

use crate::analysis::complexity::{layer_complexity, LayerDims, Method as CMethod};
use crate::dp::clip::ClipMode;
use crate::dp::{calibrate, gdp, rdp};
use crate::engine::{
    evaluate_params, Engine, JobSpec, LrSchedule, Method, OptimKind, TransportKind, WireCodec,
};
use crate::util::args::Args;
use crate::util::config::Config;
use crate::util::table::Table;

use super::metrics::JsonlSink;

const USAGE: &str = "usage: fastdp <train|serve|eval|accountant|zoo|complexity|artifacts>
  train      --model cls-base --method bitfit [--task sst2] [--steps N] [--batch N]
             [--lr F] [--eps F | --sigma F] [--delta F] [--clip F] [--clip-mode abadi|autos]
             [--optim sgd|adam|adamw] [--warmup N] [--n N] [--seed N]
             [--replicas N]     (data-parallel workers; bit-identical to 1)
             [--transport channel|tcp] [--wire raw-f32le|bf16]
             [--recv-timeout-ms N]  (replica reply deadline before poison)
             [--full-steps N --full-lr F]            (method two-phase)
             [--pretrained ckpt] [--save ckpt] [--log out.jsonl]
             [--config cfg.toml] [--set k=v]... [--artifacts DIR]
             [--backend auto|pjrt|interp] [--dry-run]
             (legacy: --artifact cls-base__dp-bitfit instead of --model/--method)
  serve      --model cls-base --method bitfit [--tenants N] [--max-tenants N]
             [--mem-mb N] [--no-batching] [--workers N] [--eps-cap F]
             (plus the train flags; tenant i trains with seed + i;
              env fallbacks: FASTDP_SERVE_TENANTS/_WORKERS/_MEM_MB/_BATCHING)
  eval       --model cls-base --ckpt path [--task sst2] [--n N]
  accountant --q F --sigma F --steps N [--delta F]   (report eps, RDP + GDP)
  accountant --q F --steps N --target-eps F          (calibrate sigma)
  zoo
  complexity [--b N --t N --d N --p N]
  artifacts  [--artifacts DIR] [--backend auto|pjrt|interp]";

pub fn main() -> Result<()> {
    // production refusal: a stray FASTDP_FAULT must be loud and inert —
    // only the audit harness may weaken the DP mechanism, never the CLI
    crate::dp::fault::refuse_outside_audit();
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("accountant") => cmd_accountant(&args),
        Some("zoo") => cmd_zoo(),
        Some("complexity") => cmd_complexity(&args),
        Some("artifacts") => cmd_artifacts(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.str("artifacts", "artifacts")
}

/// Open the engine the flags ask for.
fn open_engine(args: &Args) -> Result<Engine> {
    let dir = artifacts_dir(args);
    let engine = match args.str("backend", "auto").as_str() {
        "pjrt" => Engine::pjrt(&dir)?,
        "interp" | "interpreter" => Engine::interpreter(),
        "auto" => Engine::auto(&dir),
        other => anyhow::bail!("unknown --backend {other:?} (auto|pjrt|interp)"),
    };
    Ok(engine)
}

/// Resolve flags + TOML into a validated `JobSpec`.  Pure — no backend.
fn build_spec(args: &Args) -> Result<JobSpec> {
    // config file first, flags override
    let mut cfg = match args.get("config") {
        Some(p) => Config::load(p).map_err(|e| anyhow::anyhow!(e))?,
        None => Config::default(),
    };
    for kv in args.get_all("set") {
        let (k, v) = kv.split_once('=').context("--set expects key=value")?;
        cfg.set(k, v).map_err(|e| anyhow::anyhow!(e))?;
    }

    // model + method, either split or as a legacy artifact name
    let mut model = args.str("model", &cfg.str("train.model", ""));
    let mut method_str = args.str("method", &cfg.str("train.method", ""));
    let mut clip_mode_str = args.str("clip-mode", &cfg.str("train.clip_mode", "abadi"));
    let mut forced_private: Option<bool> = None;
    let artifact = args.str("artifact", &cfg.str("train.artifact", ""));
    if !artifact.is_empty() {
        // conflict check covers flags AND config-file keys: model/method_str
        // are non-empty here only if one of those supplied them
        anyhow::ensure!(
            model.is_empty() && method_str.is_empty(),
            "--artifact (or train.artifact) conflicts with --model/--method \
             (or train.model/train.method); pass one or the other"
        );
        let parts: Vec<&str> = artifact.split("__").collect();
        anyhow::ensure!(
            parts.len() == 2 || parts.len() == 3,
            "--artifact must look like model__method[__clipmode]"
        );
        model = parts[0].to_string();
        method_str = parts[1].to_string();
        if let Some(c) = parts.get(2) {
            clip_mode_str = c.to_string();
        }
        let (_, private) =
            Method::parse(&method_str).with_context(|| format!("bad method in --artifact {artifact:?}"))?;
        forced_private = Some(private);
    }
    anyhow::ensure!(!model.is_empty(), "--model (or --artifact / train.model) required");
    anyhow::ensure!(!method_str.is_empty(), "--method (or --artifact / train.method) required");
    // an explicit dp-/nondp- prefix on --method pins the privacy regime just
    // like a legacy artifact name does (dp-* with no budget defaults to eps=8)
    if forced_private.is_none() {
        if method_str.starts_with("dp-") {
            forced_private = Some(true);
        } else if method_str.starts_with("nondp-") {
            forced_private = Some(false);
        }
    }

    let method = if method_str == "two-phase" {
        Method::TwoPhase {
            full_steps: args.usize("full-steps", cfg.i64("train.full_steps", 0) as usize) as u64,
            full_lr: args.f64("full-lr", cfg.f64("train.full_lr", 5e-4)),
        }
    } else {
        Method::parse(&method_str)
            .with_context(|| format!("unknown --method {method_str:?}"))?
            .0
    };
    let clip_mode = ClipMode::parse(&clip_mode_str)
        .with_context(|| format!("unknown --clip-mode {clip_mode_str:?}"))?;

    let mut b = JobSpec::builder(&model, method)
        .optim(
            OptimKind::parse(&args.str("optim", &cfg.str("train.optim", "adam")))
                .context("bad --optim")?,
        )
        .lr(args.f64("lr", cfg.f64("train.lr", 5e-3)))
        .schedule(LrSchedule::Warmup {
            warmup: args.usize("warmup", cfg.i64("train.warmup", 0) as usize) as u64,
        })
        .clip_r(args.f64("clip", cfg.f64("train.clip_r", 0.1)))
        .clip_mode(clip_mode)
        .batch(args.usize("batch", cfg.i64("train.batch", 64) as usize))
        .steps(args.usize("steps", cfg.i64("train.steps", 100) as usize) as u64)
        .n_train(args.usize("n", cfg.i64("train.n", 4096) as usize))
        .seed(args.usize("seed", cfg.i64("train.seed", 0) as usize) as u64)
        .replicas(args.usize("replicas", cfg.i64("train.replicas", 1) as usize));
    // replica transport: unset flags/keys leave the builder on its
    // env-registry fallbacks (channel / raw-f32le / 30000 ms)
    let transport = args.str("transport", &cfg.str("train.transport", ""));
    if !transport.is_empty() {
        b = b.transport(
            TransportKind::parse(&transport)
                .with_context(|| format!("unknown --transport {transport:?} (channel|tcp)"))?,
        );
    }
    let wire = args.str("wire", &cfg.str("train.wire", ""));
    if !wire.is_empty() {
        b = b.wire(
            WireCodec::parse(&wire)
                .with_context(|| format!("unknown --wire {wire:?} (raw-f32le|bf16)"))?,
        );
    }
    if let Some(ms) = args.get("recv-timeout-ms") {
        b = b.recv_timeout_ms(ms.parse::<u64>().context("--recv-timeout-ms")?);
    } else if let Some(ms) = cfg.values.get("train.recv_timeout_ms").and_then(|v| v.as_i64()) {
        b = b.recv_timeout_ms(ms.max(0) as u64);
    }
    let task = args.str("task", &cfg.str("train.task", ""));
    if !task.is_empty() {
        b = b.task(&task);
    }

    // privacy: --sigma wins over --eps; legacy nondp-* artifacts force
    // non-private; legacy dp-* artifacts default to eps=8 like before
    let delta = args.f64("delta", cfg.f64("train.delta", 1e-5));
    let sigma_flag = args.get("sigma").map(|s| s.parse::<f64>()).transpose().context("--sigma")?;
    let sigma_cfg = cfg.values.get("train.sigma").and_then(|v| v.as_f64());
    let eps_flag = args.get("eps").map(|s| s.parse::<f64>()).transpose().context("--eps")?;
    let eps_cfg = cfg.values.get("train.eps").and_then(|v| v.as_f64());
    match forced_private {
        Some(false) => {} // non-private artifact: ignore any budget flags
        Some(true) => {
            b = b.delta(delta);
            if let Some(s) = sigma_flag.or(sigma_cfg) {
                b = b.sigma(s);
            } else {
                b = b.eps(eps_flag.or(eps_cfg).unwrap_or(8.0));
            }
        }
        None => {
            if let Some(s) = sigma_flag.or(sigma_cfg) {
                b = b.sigma(s).delta(delta);
            } else if let Some(e) = eps_flag.or(eps_cfg) {
                b = b.eps(e).delta(delta);
            }
        }
    }
    Ok(b.build()?)
}

fn cmd_train(args: &Args) -> Result<()> {
    let spec = build_spec(args)?;
    if args.flag("dry-run") {
        // resolve + validate + pretty-print, never touching a backend
        let plan = spec.plan();
        print!("{}", plan.describe(&spec));
        println!("  (dry run: no backend touched)");
        return Ok(());
    }

    let mut engine = open_engine(args)?;
    let task = match &spec.task {
        Some(t) => t.clone(),
        None => engine.default_task(&spec.model)?.to_string(),
    };
    let data = engine.dataset(&spec.model, &task, spec.n_train, spec.seed)?;

    let pretrained = match args.get("pretrained") {
        Some(p) => Some(engine.load_checkpoint(&spec.model, p)?),
        None => None,
    };
    let mut session = match pretrained {
        Some(params) => engine.session_from(&spec, params)?,
        None => engine.session(&spec)?,
    };
    let mut sink = match args.get("log") {
        Some(p) => Some(JsonlSink::create(p)?),
        None => None,
    };
    let info = engine.model_info(&spec.model)?;
    println!(
        "training {} on {task} [{} backend]: {} examples, {} trainable params ({:.3}% of {}), {} steps",
        spec.run_name(),
        engine.backend_name(),
        data.len(),
        session.trainable_len(),
        100.0 * session.trainable_len() as f64 / info.n_params.max(1) as f64,
        info.n_params,
        spec.steps,
    );
    if spec.privacy.is_private() {
        let spent = session.privacy_spent();
        println!("privacy plan: sigma = {:.4}, q = {:.4}, delta = {}", spent.sigma, spent.q, spent.delta);
    }
    let steps = spec.steps;
    for i in 0..steps {
        let s = session.run_step(&data)?;
        if let Some(sink) = &mut sink {
            sink.step(s.step, s.loss, s.epsilon)?;
        }
        if i % 10 == 0 || i + 1 == steps {
            println!(
                "step {:>5}  loss {:.4}  |B| {:>4}  eps {:.3}",
                s.step, s.loss, s.batch, s.epsilon
            );
        }
    }
    for (label, secs, calls) in session.timers.report() {
        println!("  timer {label:<8} {secs:>8.3}s over {calls} calls");
    }
    if let Some(comm) = session.comm_stats() {
        println!(
            "replica traffic: {} workers, {} rounds, {} B up + {} B down \
             ({} B bootstrap, excluded)",
            comm.workers,
            comm.rounds,
            comm.bytes_to_leader,
            comm.bytes_from_leader,
            comm.bytes_bootstrap,
        );
    }
    if let Some(path) = args.get("save") {
        session.checkpoint(path)?;
        println!("saved checkpoint to {path}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use crate::engine::InterpreterBackend;
    use crate::serve::{capacity_report, Scheduler, ServeConfig, TenantExit};

    let base = build_spec(args)?;
    anyhow::ensure!(
        base.replicas <= 1,
        "serve multiplexes sessions itself; --replicas is not supported"
    );
    let n_tenants = args.usize(
        "tenants",
        crate::runtime::env::serve_tenants().unwrap_or(4),
    );
    anyhow::ensure!(n_tenants >= 1, "--tenants must be >= 1");

    let mut cfg = ServeConfig::from_env();
    if let Some(m) = args.get("max-tenants") {
        cfg.max_tenants = m.parse().context("--max-tenants")?;
    }
    if let Some(mb) = args.get("mem-mb") {
        cfg.mem_budget_bytes = Some(mb.parse::<usize>().context("--mem-mb")? << 20);
    }
    if args.flag("no-batching") {
        cfg.batching = false;
    }
    if let Some(w) = args.get("workers") {
        cfg.workers = Some(w.parse().context("--workers")?);
    }
    let eps_cap = args.get("eps-cap").map(|s| s.parse::<f64>()).transpose().context("--eps-cap")?;

    // the worker budget applies to the interpreter's kernel pool; an
    // explicit --backend pjrt keeps its own executor configuration
    let engine = match (cfg.workers, args.str("backend", "auto").as_str()) {
        (Some(w), "auto" | "interp" | "interpreter") => {
            Engine::new(Box::new(InterpreterBackend::with_threads(w)))
        }
        _ => open_engine(args)?,
    };
    let mut sched = Scheduler::new(engine, cfg);
    let task = match &base.task {
        Some(t) => t.clone(),
        None => sched.engine().default_task(&base.model)?.to_string(),
    };

    println!(
        "serving {} x {} on {task} [{} backend]: batching {}, max {} tenants, mem budget {}",
        n_tenants,
        base.run_name(),
        sched.engine().backend_name(),
        if sched.config().batching { "on" } else { "off" },
        sched.config().max_tenants,
        match sched.config().mem_budget_bytes {
            Some(b) => format!("{} MiB", b >> 20),
            None => "unlimited".to_string(),
        },
    );
    for i in 0..n_tenants {
        // each tenant is an independent job: own data draw, own DP state
        let mut spec = base.clone();
        spec.seed = base.seed + i as u64;
        let data = sched.engine().dataset(&spec.model, &task, spec.n_train, spec.seed)?;
        let name = format!("tenant-{i}");
        match sched.admit(&name, &spec, data, eps_cap) {
            Ok(id) => println!(
                "  admitted {name} (id {id}, seed {}, {} B resident)",
                spec.seed,
                sched.session(id).resident_bytes(),
            ),
            Err(e) => {
                println!("  refused {name}: {e}");
                break;
            }
        }
    }
    anyhow::ensure!(!sched.is_empty(), "no tenant admitted");

    let t0 = std::time::Instant::now();
    let mut rounds = 0u64;
    loop {
        let stepped = sched.run_round().map_err(|e| anyhow::anyhow!("{e}"))?;
        if stepped == 0 {
            break;
        }
        rounds += 1;
        if rounds % 10 == 0 {
            println!("  round {rounds:>5}: {stepped} tenants stepped");
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let total_steps: u64 = (0..sched.len()).map(|id| sched.session(id).step()).sum();

    for id in 0..sched.len() {
        let spent = sched.session(id).privacy_spent();
        match sched.exit(id) {
            Some(TenantExit::Completed { steps, eps_spent }) => println!(
                "  {}: completed {} steps, eps {:.3}",
                sched.name(id),
                steps,
                eps_spent
            ),
            Some(TenantExit::EpsCapReached { spent, projected, cap }) => println!(
                "  {}: retired at eps cap (spent {:.3}, next step projects {:.3} > cap {:.3})",
                sched.name(id),
                spent,
                projected,
                cap
            ),
            None => println!("  {}: still active (eps {:.3})", sched.name(id), spent.epsilon),
        }
    }
    let cap = capacity_report(&sched);
    println!(
        "{} rounds, {} total steps in {:.2}s ({:.1} steps/s aggregate, {:.1} per tenant)",
        rounds,
        total_steps,
        secs,
        total_steps as f64 / secs.max(1e-9),
        total_steps as f64 / secs.max(1e-9) / sched.len() as f64,
    );
    println!(
        "capacity: {} tenants, frozen {} B shared ({} B if unshared), \
         {} B/tenant mutable -> {:.0} sessions/GB",
        cap.tenants,
        cap.shared_frozen_bytes,
        cap.unshared_frozen_bytes,
        cap.per_tenant_bytes,
        cap.sessions_per_gb,
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.str("model", "");
    anyhow::ensure!(!model.is_empty(), "--model required");
    let mut engine = open_engine(args)?;
    let params = match args.get("ckpt") {
        Some(p) => engine.load_checkpoint(&model, p)?,
        None => engine.init_params(&model)?,
    };
    let info = engine.model_info(&model)?;
    let task = args.str("task", engine.default_task(&model)?);
    let n = args.usize("n", 1024);
    let data = engine.dataset(&model, &task, n, args.usize("seed", 1) as u64)?;
    let eval = engine.evaluator(&model)?;
    let out = evaluate_params(eval.as_ref(), &params, &data, n)?;
    if info.shape.kind == "lm" {
        println!(
            "nll/token = {:.4}  perplexity = {:.3}  ({:.0} tokens)",
            out.metric_a / out.metric_b,
            out.perplexity(),
            out.metric_b
        );
    } else {
        println!(
            "loss = {:.4}  accuracy = {:.2}%  ({} examples)",
            out.metric_a / out.n as f64,
            100.0 * out.accuracy(),
            out.n
        );
    }
    Ok(())
}

fn cmd_accountant(args: &Args) -> Result<()> {
    let q = args.f64("q", 0.01);
    let steps = args.usize("steps", 1000) as u64;
    let delta = args.f64("delta", 1e-5);
    if let Some(te) = args.get("target-eps") {
        let target: f64 = te.parse().context("--target-eps")?;
        let sigma = calibrate::calibrate_sigma(q, steps, target, delta);
        println!("sigma = {sigma:.4} reaches eps <= {target} (q={q}, T={steps}, delta={delta})");
        return Ok(());
    }
    let sigma = args.f64("sigma", 1.0);
    let e_rdp = rdp::epsilon(q, sigma, steps, delta);
    let e_gdp = gdp::epsilon(q, sigma, steps, delta);
    println!("q={q} sigma={sigma} T={steps} delta={delta}");
    println!("  eps (RDP accountant) = {e_rdp:.4}");
    println!("  eps (GDP accountant) = {e_gdp:.4}");
    Ok(())
}

fn cmd_zoo() -> Result<()> {
    let mut t = Table::new(&["model", "params", "% bias (ours)", "% bias (paper)"]);
    for z in crate::models::zoo::zoo() {
        t.row(vec![
            z.name.to_string(),
            format!("{:.1}M", z.counts.total() as f64 / 1e6),
            format!("{:.3}", z.bias_pct()),
            format!("{:.3}", z.paper_bias_pct),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_complexity(args: &Args) -> Result<()> {
    let l = LayerDims {
        b: args.usize("b", 16) as u64,
        t: args.usize("t", 256) as u64,
        d: args.usize("d", 768) as u64,
        p: args.usize("p", 768) as u64,
    };
    let methods = [
        CMethod::NonDpFull,
        CMethod::OpacusFull,
        CMethod::GhostClipFull,
        CMethod::BookKeeping,
        CMethod::DpLora { rank: 16 },
        CMethod::DpAdapter { rank: 16 },
        CMethod::NonDpBias,
        CMethod::DpBias,
    ];
    println!(
        "per-layer complexity at B={} T={} d={} p={} (paper Table 2/7)",
        l.b, l.t, l.d, l.p
    );
    let mut t = Table::new(&[
        "method", "time (flops)", "+DP time", "space (floats)", "+DP space", "acts?", "backprops",
    ]);
    for m in methods {
        let c = layer_complexity(m, l);
        t.row(vec![
            m.name(),
            format!("{:.2e}", (c.base_time + c.train_time) as f64),
            format!("{:.2e}", c.dp_time as f64),
            format!("{:.2e}", c.base_space as f64),
            format!("{:.2e}", c.dp_space as f64),
            if m.stores_activations() { "yes" } else { "NO" }.into(),
            m.backprops().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let engine = open_engine(args)?;
    println!("backend: {}  ({})", engine.backend_name(), engine.platform());
    let mut t = Table::new(&["artifact", "model", "step", "B", "Pt"]);
    for name in engine.artifacts() {
        let meta = engine.artifact_meta(&name)?;
        t.row(vec![
            name.clone(),
            meta.model,
            meta.step,
            meta.batch.to_string(),
            meta.pt.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Privacy;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn spec_from_flags() {
        let args = parse(
            "train --model cls-base --method bitfit --task sst2 --eps 4 --batch 128 \
             --steps 30 --n 2048 --lr 0.005 --seed 3",
        );
        let spec = build_spec(&args).unwrap();
        assert_eq!(spec.model, "cls-base");
        assert_eq!(spec.method, Method::BiTFiT);
        assert_eq!(spec.task.as_deref(), Some("sst2"));
        assert_eq!(spec.privacy, Privacy::Eps { eps: 4.0, delta: 1e-5 });
        assert_eq!(spec.logical_batch, 128);
        assert_eq!(spec.steps, 30);
        assert_eq!(spec.phases()[0].artifact, "cls-base__dp-bitfit");
    }

    #[test]
    fn spec_from_legacy_artifact_flag() {
        let args = parse("train --artifact cls-base__nondp-full --steps 10");
        let spec = build_spec(&args).unwrap();
        assert_eq!(spec.model, "cls-base");
        assert_eq!(spec.privacy, Privacy::NonPrivate);
        assert_eq!(spec.phases()[0].artifact, "cls-base__nondp-full");
        // dp artifact defaults to eps = 8 like the old CLI
        let args = parse("train --artifact cls-base__dp-bitfit --steps 10");
        let spec = build_spec(&args).unwrap();
        assert_eq!(spec.privacy, Privacy::Eps { eps: 8.0, delta: 1e-5 });
        // clip-mode suffix survives
        let args = parse("train --artifact cls-base__dp-bitfit__autos --steps 10");
        let spec = build_spec(&args).unwrap();
        assert_eq!(spec.clip_mode, ClipMode::AutoS);
        assert_eq!(spec.phases()[0].artifact, "cls-base__dp-bitfit__autos");
    }

    #[test]
    fn dp_prefixed_method_pins_privacy() {
        // an explicit dp- method without a budget must NOT silently train
        // non-private: it defaults to eps = 8 like the legacy artifact path
        let args = parse("train --model cls-base --method dp-bitfit --steps 10");
        let spec = build_spec(&args).unwrap();
        assert_eq!(spec.privacy, Privacy::Eps { eps: 8.0, delta: 1e-5 });
        assert_eq!(spec.phases()[0].artifact, "cls-base__dp-bitfit");
        // and nondp- pins non-private even if an eps flag is present
        let args = parse("train --model cls-base --method nondp-bitfit --eps 4 --steps 10");
        let spec = build_spec(&args).unwrap();
        assert_eq!(spec.privacy, Privacy::NonPrivate);
    }

    #[test]
    fn cli_sigma_wins_over_eps() {
        // the CLI resolves the conflict (explicit multiplier beats target);
        // the builder-level both-set rejection is tested in engine::spec
        let args = parse("train --model cls-base --method bitfit --eps 8 --sigma 1.0");
        let spec = build_spec(&args).unwrap();
        assert!(matches!(spec.privacy, Privacy::Sigma { .. }));
    }

    #[test]
    fn two_phase_flags() {
        let args = parse(
            "train --model vit-c10 --method two-phase --full-steps 8 --full-lr 0.001 \
             --sigma 1.0 --steps 32",
        );
        let spec = build_spec(&args).unwrap();
        assert_eq!(spec.phases().len(), 2);
        assert_eq!(spec.phases()[0].steps, 8);
    }

    #[test]
    fn missing_model_is_an_error() {
        let args = parse("train --method bitfit");
        assert!(build_spec(&args).is_err());
    }

    #[test]
    fn replicas_flag_flows_into_the_spec() {
        let args = parse("train --model cls-base --method bitfit --sigma 1.0 --replicas 4");
        let spec = build_spec(&args).unwrap();
        assert_eq!(spec.replicas, 4);
        // default stays in-process; zero is rejected by the builder
        let args = parse("train --model cls-base --method bitfit --sigma 1.0");
        assert_eq!(build_spec(&args).unwrap().replicas, 1);
        let args = parse("train --model cls-base --method bitfit --sigma 1.0 --replicas 0");
        assert!(build_spec(&args).is_err());
    }

    #[test]
    fn transport_flags_flow_into_the_spec() {
        let args = parse(
            "train --model cls-base --method bitfit --sigma 1.0 --replicas 2 \
             --transport tcp --wire bf16 --recv-timeout-ms 750",
        );
        let spec = build_spec(&args).unwrap();
        assert_eq!(spec.transport, TransportKind::Tcp);
        assert_eq!(spec.wire, WireCodec::Bf16);
        assert_eq!(spec.recv_timeout_ms, 750);
        // vocabulary errors are caught at the flag layer
        let args = parse("train --model cls-base --method bitfit --transport smoke-signals");
        assert!(build_spec(&args).unwrap_err().to_string().contains("transport"));
        let args = parse("train --model cls-base --method bitfit --wire fp8");
        assert!(build_spec(&args).unwrap_err().to_string().contains("wire"));
        // a zero deadline is rejected by the spec builder
        let args = parse("train --model cls-base --method bitfit --recv-timeout-ms 0");
        assert!(build_spec(&args).is_err());
    }
}
