//! Metric sinks: JSONL run logs + loss-curve summaries.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// Appends one JSON object per line; used for training curves and bench rows.
pub struct JsonlSink {
    file: std::fs::File,
}

impl JsonlSink {
    pub fn create(path: impl AsRef<Path>) -> Result<JsonlSink> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        Ok(JsonlSink { file })
    }

    pub fn write(&mut self, record: &Json) -> Result<()> {
        writeln!(self.file, "{}", json::write(record))?;
        Ok(())
    }

    /// Convenience: write a step record.
    pub fn step(&mut self, step: u64, loss: f64, eps: f64) -> Result<()> {
        self.write(&json::obj(vec![
            ("step", Json::Num(step as f64)),
            ("loss", Json::Num(loss)),
            ("epsilon", Json::Num(eps)),
        ]))
    }
}

/// Read a JSONL file back (tests, plotting).
pub fn read_jsonl(path: impl AsRef<Path>) -> Result<Vec<Json>> {
    let src = std::fs::read_to_string(path.as_ref())?;
    src.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| json::parse(l).map_err(|e| anyhow::anyhow!(e)))
        .collect()
}

/// Simple online mean/min/max accumulator for loss curves.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub first: f64,
    pub last: f64,
}

impl Summary {
    pub fn push(&mut self, v: f64) {
        if self.n == 0 {
            self.min = v;
            self.max = v;
            self.first = v;
        }
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        self.last = v;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jsonl_roundtrip() {
        let p = std::env::temp_dir().join(format!("fastdp-jsonl-{}", std::process::id()));
        {
            let mut s = JsonlSink::create(&p).unwrap();
            s.step(1, 2.5, 0.1).unwrap();
            s.step(2, 2.0, 0.2).unwrap();
        }
        let recs = read_jsonl(&p).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].req("loss").as_f64().unwrap(), 2.0);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn summary_tracks_extremes() {
        let mut s = Summary::default();
        for v in [3.0, 1.0, 2.0] {
            s.push(v);
        }
        assert_eq!(s.first, 3.0);
        assert_eq!(s.last, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean() - 2.0).abs() < 1e-12);
    }
}
