//! Artifact metadata: `manifest.json`, `<name>.meta.json`, `<model>.layout.json`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// One input/output tensor spec of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String, // "float32" | "int32"
    pub shape: Vec<usize>,
}

impl IoSpec {
    fn from_json(v: &Json) -> IoSpec {
        IoSpec {
            name: v.req("name").as_str().unwrap().to_string(),
            dtype: v.req("dtype").as_str().unwrap().to_string(),
            shape: v
                .req("shape")
                .as_arr()
                .unwrap()
                .iter()
                .map(|x| x.as_usize().unwrap())
                .collect(),
        }
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed `<name>.meta.json`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub model: String,
    pub method: String,
    pub step: String,   // "train" | "eval" | "decode"
    pub clip: Option<String>,
    pub subset: String, // trainable subset name ("bitfit", "full", ...)
    pub batch: usize,
    pub pf: usize,
    pub pt: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactMeta {
    pub fn load(dir: &Path, name: &str) -> Result<ArtifactMeta> {
        let path = dir.join(format!("{name}.meta.json"));
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&src).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Ok(ArtifactMeta {
            name: v.req("name").as_str().unwrap().to_string(),
            model: v.req("model").as_str().unwrap().to_string(),
            method: v.req("method").as_str().unwrap().to_string(),
            step: v.req("step").as_str().unwrap().to_string(),
            clip: v.get("clip").and_then(|c| c.as_str()).map(|s| s.to_string()),
            subset: v.req("subset").as_str().unwrap().to_string(),
            batch: v.req("batch").as_usize().unwrap(),
            pf: v.req("pf").as_usize().unwrap(),
            pt: v.req("pt").as_usize().unwrap(),
            inputs: v.req("inputs").as_arr().unwrap().iter().map(IoSpec::from_json).collect(),
            outputs: v.req("outputs").as_arr().unwrap().iter().map(IoSpec::from_json).collect(),
        })
    }
}

/// Convenience: an artifact name + its metadata.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub meta: ArtifactMeta,
}

/// One leaf in the canonical flat parameter layout.
#[derive(Debug, Clone)]
pub struct LayoutLeaf {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    pub offset: usize,
    pub is_head: bool,
}

/// Parsed `<model>.layout.json`: the contract that lets L3 split/merge
/// full <-> (frozen, trainable) vectors and re-init heads (DESIGN.md §6).
#[derive(Debug, Clone)]
pub struct Layout {
    pub model: String,
    pub kind: String,
    pub n_params: usize,
    pub leaves: Vec<LayoutLeaf>,
    pub subsets: BTreeMap<String, Vec<bool>>,
}

impl Layout {
    pub fn load(dir: &Path, model: &str) -> Result<Layout> {
        let path = dir.join(format!("{model}.layout.json"));
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&src).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let leaves = v
            .req("leaves")
            .as_arr()
            .unwrap()
            .iter()
            .map(|l| LayoutLeaf {
                name: l.req("name").as_str().unwrap().to_string(),
                shape: l.req("shape").as_arr().unwrap().iter().map(|x| x.as_usize().unwrap()).collect(),
                size: l.req("size").as_usize().unwrap(),
                offset: l.req("offset").as_usize().unwrap(),
                is_head: l.req("is_head").as_bool().unwrap(),
            })
            .collect();
        let mut subsets = BTreeMap::new();
        if let Json::Obj(m) = v.req("subsets") {
            for (k, arr) in m {
                subsets.insert(
                    k.clone(),
                    arr.as_arr().unwrap().iter().map(|b| b.as_bool().unwrap()).collect(),
                );
            }
        }
        Ok(Layout {
            model: v.req("model").as_str().unwrap().to_string(),
            kind: v.req("kind").as_str().unwrap().to_string(),
            n_params: v.req("n_params").as_usize().unwrap(),
            leaves,
            subsets,
        })
    }

    /// Split a full flat vector into (frozen, trainable) for a subset.
    pub fn split(&self, full: &[f32], subset: &str) -> (Vec<f32>, Vec<f32>) {
        let mask = &self.subsets[subset];
        let mut frozen = Vec::new();
        let mut train = Vec::new();
        for (leaf, &tr) in self.leaves.iter().zip(mask) {
            let slice = &full[leaf.offset..leaf.offset + leaf.size];
            if tr {
                train.extend_from_slice(slice);
            } else {
                frozen.extend_from_slice(slice);
            }
        }
        (frozen, train)
    }

    /// Merge (frozen, trainable) back into a full flat vector.
    pub fn merge(&self, frozen: &[f32], train: &[f32], subset: &str) -> Vec<f32> {
        let mask = &self.subsets[subset];
        let mut full = vec![0.0f32; self.n_params];
        let (mut fo, mut to) = (0usize, 0usize);
        for (leaf, &tr) in self.leaves.iter().zip(mask) {
            let dst = &mut full[leaf.offset..leaf.offset + leaf.size];
            if tr {
                dst.copy_from_slice(&train[to..to + leaf.size]);
                to += leaf.size;
            } else {
                dst.copy_from_slice(&frozen[fo..fo + leaf.size]);
                fo += leaf.size;
            }
        }
        debug_assert_eq!(fo, frozen.len());
        debug_assert_eq!(to, train.len());
        full
    }

    /// Number of trainable parameters in a subset.
    pub fn subset_size(&self, subset: &str) -> usize {
        self.leaves
            .iter()
            .zip(&self.subsets[subset])
            .filter(|(_, &tr)| tr)
            .map(|(l, _)| l.size)
            .sum()
    }

    /// Copy values for head leaves from `src` full-vector into `dst`.
    pub fn copy_head(&self, dst: &mut [f32], src: &[f32]) {
        for leaf in self.leaves.iter().filter(|l| l.is_head) {
            dst[leaf.offset..leaf.offset + leaf.size]
                .copy_from_slice(&src[leaf.offset..leaf.offset + leaf.size]);
        }
    }

    /// Copy all *non-head* leaves whose names match between two layouts
    /// (pretrained-backbone transfer, e.g. cls-base -> cls-lora).
    pub fn transfer_backbone(&self, dst: &mut [f32], src_layout: &Layout, src: &[f32]) {
        let by_name: BTreeMap<&str, &LayoutLeaf> =
            src_layout.leaves.iter().map(|l| (l.name.as_str(), l)).collect();
        for leaf in self.leaves.iter().filter(|l| !l.is_head) {
            if let Some(s) = by_name.get(leaf.name.as_str()) {
                if s.size == leaf.size {
                    dst[leaf.offset..leaf.offset + leaf.size]
                        .copy_from_slice(&src[s.offset..s.offset + s.size]);
                }
            }
        }
    }
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub models: BTreeMap<String, ModelEntry>,
    pub artifacts: Vec<String>,
}

/// A model entry in the manifest.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub kind: String,
    pub n_params: usize,
    pub cfg: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let v = json::parse(&src).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut models = BTreeMap::new();
        if let Json::Obj(m) = v.req("models") {
            for (k, e) in m {
                models.insert(
                    k.clone(),
                    ModelEntry {
                        kind: e.req("kind").as_str().unwrap().to_string(),
                        n_params: e.req("n_params").as_usize().unwrap(),
                        cfg: e.req("cfg").clone(),
                    },
                );
            }
        }
        let artifacts = v
            .req("artifacts")
            .as_arr()
            .unwrap()
            .iter()
            .map(|a| a.as_str().unwrap().to_string())
            .collect();
        Ok(Manifest { models, artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_layout() -> Layout {
        Layout {
            model: "m".into(),
            kind: "cls".into(),
            n_params: 6,
            leaves: vec![
                LayoutLeaf { name: "w".into(), shape: vec![2, 2], size: 4, offset: 0, is_head: false },
                LayoutLeaf { name: "b".into(), shape: vec![1], size: 1, offset: 4, is_head: false },
                LayoutLeaf { name: "head/w".into(), shape: vec![1], size: 1, offset: 5, is_head: true },
            ],
            subsets: BTreeMap::from([
                ("bitfit".to_string(), vec![false, true, true]),
                ("full".to_string(), vec![true, true, true]),
            ]),
        }
    }

    #[test]
    fn split_merge_roundtrip() {
        let l = demo_layout();
        let full: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let (frozen, train) = l.split(&full, "bitfit");
        assert_eq!(frozen, vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(train, vec![4.0, 5.0]);
        assert_eq!(l.merge(&frozen, &train, "bitfit"), full);
        assert_eq!(l.subset_size("bitfit"), 2);
        assert_eq!(l.subset_size("full"), 6);
    }

    #[test]
    fn head_copy() {
        let l = demo_layout();
        let mut dst = vec![0.0f32; 6];
        let src: Vec<f32> = (10..16).map(|i| i as f32).collect();
        l.copy_head(&mut dst, &src);
        assert_eq!(dst, vec![0.0, 0.0, 0.0, 0.0, 0.0, 15.0]);
    }
}
