//! Central registry for every `FASTDP_*` environment knob.
//!
//! Every knob the crate reads is declared here as a [`Knob`] (name,
//! accepted values, fallback, one-line doc) and read through a typed
//! accessor, so the full surface is enumerable in one place: the README
//! env-var table is checked against [`REGISTRY`] by `fastdp-lint`'s
//! doc-drift rule, and the lint's env-registry rule rejects any raw
//! `std::env::var("FASTDP_*")` read outside this module.
//!
//! Unparseable values never abort: each accessor falls back to the knob's
//! documented default and warns **once per knob** on stderr (the PR 4
//! `KernelMode::from_env` behavior, generalized — a typo'd knob should be
//! loud, not silently ignored).  Presence-only knobs (`FASTDP_BENCH_QUICK`,
//! `FASTDP_DEVICE_RESIDENT`) treat any value as "set".

use std::sync::Mutex;

/// One declared environment knob.
pub struct Knob {
    /// The environment variable name (`FASTDP_*`).
    pub name: &'static str,
    /// Human description of the accepted value syntax.
    pub expected: &'static str,
    /// What the crate does when the knob is unset or unparseable.
    pub fallback: &'static str,
    /// One-line description (mirrored by the README env-var table).
    pub doc: &'static str,
}

pub const THREADS: Knob = Knob {
    name: "FASTDP_THREADS",
    expected: "integer >= 1",
    fallback: "host parallelism",
    doc: "worker threads for the interpreter row pool",
};

pub const KERNELS: Knob = Knob {
    name: "FASTDP_KERNELS",
    expected: "fused|ghost|blocked|simd|legacy",
    fallback: "fused",
    doc: "kernel tier for the interpreter train step",
};

pub const BLOCK_ROWS: Knob = Knob {
    name: "FASTDP_BLOCK_ROWS",
    expected: "integer >= 1",
    fallback: "32",
    doc: "block width (rows / LM positions) for the blocked tier",
};

pub const SIMD: Knob = Knob {
    name: "FASTDP_SIMD",
    expected: "avx2|sse2|scalar",
    fallback: "runtime feature detection",
    doc: "force a (lower) instruction-set level for the simd tier",
};

pub const DEVICE_RESIDENT: Knob = Knob {
    name: "FASTDP_DEVICE_RESIDENT",
    expected: "set/unset",
    fallback: "unset (literal path)",
    doc: "opt in to device-resident pinned params on the PJRT backend",
};

pub const BENCH_STEPS: Knob = Knob {
    name: "FASTDP_BENCH_STEPS",
    expected: "integer >= 1",
    fallback: "per-bench default",
    doc: "fine-tuning steps per bench run",
};

pub const BENCH_QUICK: Knob = Knob {
    name: "FASTDP_BENCH_QUICK",
    expected: "set/unset",
    fallback: "unset (full sweep)",
    doc: "set to skip the slowest bench sweep points",
};

pub const BENCH_THREADS: Knob = Knob {
    name: "FASTDP_BENCH_THREADS",
    expected: "comma list of integers >= 1",
    fallback: "1,2,8",
    doc: "worker counts swept by benches/throughput.rs",
};

pub const BENCH_BLOCKS: Knob = Knob {
    name: "FASTDP_BENCH_BLOCKS",
    expected: "comma list of integers >= 1",
    fallback: "4,8,16,32 (quick: 8,32)",
    doc: "blocked-tier block widths swept by benches/throughput.rs",
};

pub const BENCH_OUT: Knob = Knob {
    name: "FASTDP_BENCH_OUT",
    expected: "file path",
    fallback: "BENCH_step_throughput.json at the repo root",
    doc: "output path override for the throughput bench document",
};

pub const BENCH_BASELINE: Knob = Knob {
    name: "FASTDP_BENCH_BASELINE",
    expected: "file path",
    fallback: "unset (gate skipped)",
    doc: "baseline snapshot the throughput regression gate compares against",
};

pub const FAULT: Knob = Knob {
    name: "FASTDP_FAULT",
    expected: "none|skip-noise|skip-clip|half-sigma",
    fallback: "none",
    doc: "DP fault injection for the audit harness; refused by the CLI",
};

pub const AUDIT_TRIALS: Knob = Knob {
    name: "FASTDP_AUDIT_TRIALS",
    expected: "integer >= 1",
    fallback: "8",
    doc: "paired membership-inference trials per privacy-audit cell",
};

pub const AUDIT_OUT: Knob = Knob {
    name: "FASTDP_AUDIT_OUT",
    expected: "file path",
    fallback: "BENCH_privacy_audit.json at the repo root",
    doc: "output path override for the privacy-audit bench document",
};

pub const SERVE_TENANTS: Knob = Knob {
    name: "FASTDP_SERVE_TENANTS",
    expected: "integer >= 1",
    fallback: "8 (quick: 4)",
    doc: "tenant count for the serve CLI mode and capacity bench",
};

pub const SERVE_WORKERS: Knob = Knob {
    name: "FASTDP_SERVE_WORKERS",
    expected: "integer >= 1",
    fallback: "FASTDP_THREADS, else host parallelism",
    doc: "global worker-thread budget for the serve scheduler",
};

pub const SERVE_MEM_MB: Knob = Knob {
    name: "FASTDP_SERVE_MEM_MB",
    expected: "integer >= 1 (MiB)",
    fallback: "unlimited",
    doc: "admission-control memory budget for serve sessions",
};

pub const SERVE_BATCHING: Knob = Knob {
    name: "FASTDP_SERVE_BATCHING",
    expected: "on|off|1|0|true|false",
    fallback: "on",
    doc: "cross-tenant coalesced panel sweeps in the serve scheduler",
};

pub const SERVE_OUT: Knob = Knob {
    name: "FASTDP_SERVE_OUT",
    expected: "file path",
    fallback: "BENCH_serve_capacity.json at the repo root",
    doc: "output path override for the serve-capacity bench document",
};

pub const TRANSPORT: Knob = Knob {
    name: "FASTDP_TRANSPORT",
    expected: "channel|tcp",
    fallback: "channel",
    doc: "replica exchange transport (in-process channels or framed TCP loopback)",
};

pub const WIRE: Knob = Knob {
    name: "FASTDP_WIRE",
    expected: "raw-f32le|bf16",
    fallback: "raw-f32le",
    doc: "wire codec for replica gradient/parameter payloads",
};

pub const RECV_TIMEOUT_MS: Knob = Knob {
    name: "FASTDP_RECV_TIMEOUT_MS",
    expected: "integer >= 1 (milliseconds)",
    fallback: "30000",
    doc: "leader-side deadline for replica replies before the group poisons",
};

pub const COMM_OUT: Knob = Knob {
    name: "FASTDP_COMM_OUT",
    expected: "file path",
    fallback: "BENCH_comm_cost.json at the repo root",
    doc: "output path override for the comm-cost bench document",
};

/// Every knob the crate reads, in README table order.
pub const REGISTRY: &[&Knob] = &[
    &THREADS,
    &KERNELS,
    &BLOCK_ROWS,
    &SIMD,
    &DEVICE_RESIDENT,
    &BENCH_STEPS,
    &BENCH_QUICK,
    &BENCH_THREADS,
    &BENCH_BLOCKS,
    &BENCH_OUT,
    &BENCH_BASELINE,
    &FAULT,
    &AUDIT_TRIALS,
    &AUDIT_OUT,
    &SERVE_TENANTS,
    &SERVE_WORKERS,
    &SERVE_MEM_MB,
    &SERVE_BATCHING,
    &SERVE_OUT,
    &TRANSPORT,
    &WIRE,
    &RECV_TIMEOUT_MS,
    &COMM_OUT,
];

/// The raw environment read — the single `std::env::var` chokepoint for
/// the whole crate (enforced by fastdp-lint's env-registry rule).
fn raw(k: &Knob) -> Option<String> {
    std::env::var(k.name).ok()
}

/// Warn about an unparseable knob value, once per knob per process.
///
/// A `Vec` (not a hash set) keeps the bookkeeping trivially deterministic;
/// the registry is small enough that linear scans are free.
pub fn warn_invalid(k: &Knob, got: &str) {
    static WARNED: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());
    let mut warned = match WARNED.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    if !warned.contains(&k.name) {
        warned.push(k.name);
        eprintln!(
            "fastdp: unrecognized {} value {:?} (expected {}); falling back to {}",
            k.name, got, k.expected, k.fallback
        );
    }
}

/// Read + parse a knob; unparseable set values warn once and yield `None`
/// so the caller applies the knob's documented fallback.
fn parsed<T>(k: &Knob, parse: impl Fn(&str) -> Option<T>) -> Option<T> {
    let v = raw(k)?;
    match parse(v.trim()) {
        Some(t) => Some(t),
        None => {
            warn_invalid(k, &v);
            None
        }
    }
}

fn positive(s: &str) -> Option<usize> {
    s.parse::<usize>().ok().filter(|&n| n >= 1)
}

/// Comma list of integers >= 1; entries that fail to parse are dropped,
/// and a set-but-empty result counts as unparseable.
fn positive_list(s: &str) -> Option<Vec<usize>> {
    let v: Vec<usize> = s.split(',').filter_map(|p| positive(p.trim())).collect();
    if v.is_empty() {
        None
    } else {
        Some(v)
    }
}

/// `FASTDP_THREADS`: worker count override (>= 1).
pub fn threads() -> Option<usize> {
    parsed(&THREADS, positive)
}

/// `FASTDP_KERNELS`: the raw tier name, if set.  Parsing (and the
/// warn-once fallback via [`warn_invalid`]) stays with
/// `kernels::KernelMode::from_env` so the tier vocabulary lives in one
/// place.
pub fn kernels() -> Option<String> {
    raw(&KERNELS)
}

/// `FASTDP_BLOCK_ROWS`: blocked-tier block width override (>= 1).
pub fn block_rows() -> Option<usize> {
    parsed(&BLOCK_ROWS, positive)
}

/// `FASTDP_SIMD`: the raw forced feature level, if set.  Parsing (and
/// the warn-once fallback via [`warn_invalid`], plus clamping to what
/// the host supports) stays with `kernels::simd::level_from_env` so the
/// level vocabulary lives in one place, like [`kernels`].
pub fn simd() -> Option<String> {
    raw(&SIMD)
}

/// `FASTDP_DEVICE_RESIDENT`: presence-only opt-in.
pub fn device_resident() -> bool {
    raw(&DEVICE_RESIDENT).is_some()
}

/// `FASTDP_BENCH_STEPS`: timed steps per bench run (>= 1).
pub fn bench_steps() -> Option<usize> {
    parsed(&BENCH_STEPS, positive)
}

/// `FASTDP_BENCH_QUICK`: presence-only quick-sweep switch.
pub fn bench_quick() -> bool {
    raw(&BENCH_QUICK).is_some()
}

/// `FASTDP_BENCH_THREADS`: worker counts swept by the throughput bench.
pub fn bench_threads() -> Option<Vec<usize>> {
    parsed(&BENCH_THREADS, positive_list)
}

/// `FASTDP_BENCH_BLOCKS`: block widths swept by the throughput bench.
pub fn bench_blocks() -> Option<Vec<usize>> {
    parsed(&BENCH_BLOCKS, positive_list)
}

/// `FASTDP_BENCH_OUT`: output path override (empty counts as unset).
pub fn bench_out() -> Option<String> {
    raw(&BENCH_OUT).filter(|p| !p.trim().is_empty())
}

/// `FASTDP_BENCH_BASELINE`: gate baseline path (empty counts as unset).
pub fn bench_baseline() -> Option<String> {
    raw(&BENCH_BASELINE).filter(|p| !p.trim().is_empty())
}

/// `FASTDP_FAULT`: the raw fault name, if set.  Parsing (and the
/// warn-once fallback via [`warn_invalid`]) stays with
/// `dp::fault::FaultMode::parse` so the fault vocabulary lives in one
/// place; non-audit entry points refuse the knob entirely
/// (`dp::fault::refuse_outside_audit`).
pub fn fault() -> Option<String> {
    raw(&FAULT)
}

/// `FASTDP_AUDIT_TRIALS`: MI trials per privacy-audit cell (>= 1).
pub fn audit_trials() -> Option<usize> {
    parsed(&AUDIT_TRIALS, positive)
}

/// `FASTDP_AUDIT_OUT`: output path override (empty counts as unset).
pub fn audit_out() -> Option<String> {
    raw(&AUDIT_OUT).filter(|p| !p.trim().is_empty())
}

/// `FASTDP_SERVE_TENANTS`: serve-mode tenant count (>= 1).
pub fn serve_tenants() -> Option<usize> {
    parsed(&SERVE_TENANTS, positive)
}

/// `FASTDP_SERVE_WORKERS`: serve scheduler worker budget (>= 1).
pub fn serve_workers() -> Option<usize> {
    parsed(&SERVE_WORKERS, positive)
}

/// `FASTDP_SERVE_MEM_MB`: admission memory budget in MiB (>= 1).
pub fn serve_mem_mb() -> Option<usize> {
    parsed(&SERVE_MEM_MB, positive)
}

/// `FASTDP_SERVE_BATCHING`: cross-tenant sweep coalescing switch.
pub fn serve_batching() -> Option<bool> {
    parsed(&SERVE_BATCHING, |s| match s.to_ascii_lowercase().as_str() {
        "on" | "1" | "true" => Some(true),
        "off" | "0" | "false" => Some(false),
        _ => None,
    })
}

/// `FASTDP_SERVE_OUT`: output path override (empty counts as unset).
pub fn serve_out() -> Option<String> {
    raw(&SERVE_OUT).filter(|p| !p.trim().is_empty())
}

/// `FASTDP_TRANSPORT`: the raw transport name, if set.  Parsing (and the
/// warn-once fallback via [`warn_invalid`]) stays with
/// `coordinator::transport::TransportKind::from_env` so the transport
/// vocabulary lives in one place, like [`kernels`].
pub fn transport() -> Option<String> {
    raw(&TRANSPORT)
}

/// `FASTDP_WIRE`: the raw wire-codec name, if set.  Parsing (and the
/// warn-once fallback via [`warn_invalid`]) stays with
/// `coordinator::transport::WireCodec::from_env` so the codec vocabulary
/// lives in one place, like [`kernels`].
pub fn wire() -> Option<String> {
    raw(&WIRE)
}

/// `FASTDP_RECV_TIMEOUT_MS`: leader-side replica reply deadline (>= 1 ms).
pub fn recv_timeout_ms() -> Option<u64> {
    parsed(&RECV_TIMEOUT_MS, positive).map(|ms| ms as u64)
}

/// `FASTDP_COMM_OUT`: output path override (empty counts as unset).
pub fn comm_out() -> Option<String> {
    raw(&COMM_OUT).filter(|p| !p.trim().is_empty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_prefixed() {
        for (i, k) in REGISTRY.iter().enumerate() {
            assert!(k.name.starts_with("FASTDP_"), "{} lacks the FASTDP_ prefix", k.name);
            for other in &REGISTRY[i + 1..] {
                assert_ne!(k.name, other.name, "duplicate registry entry");
            }
        }
    }

    #[test]
    fn parse_helpers() {
        assert_eq!(positive("4"), Some(4));
        assert_eq!(positive("0"), None);
        assert_eq!(positive("four"), None);
        assert_eq!(positive_list("1, 2,8"), Some(vec![1, 2, 8]));
        assert_eq!(positive_list("2,x,8"), Some(vec![2, 8]));
        assert_eq!(positive_list("x"), None);
        assert_eq!(positive_list(""), None);
    }

    #[test]
    fn warn_invalid_is_idempotent() {
        warn_invalid(&BLOCK_ROWS, "zero");
        warn_invalid(&BLOCK_ROWS, "zero"); // second call must not print again
    }
}
