//! PJRT runtime: load AOT HLO-text artifacts and execute them on the hot path.
//!
//! This wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `compile` -> `execute`.  Python is
//! never invoked here — the artifacts under `artifacts/` are self-contained.
//!
//! Key perf property (EXPERIMENTS.md §Perf): inputs that do not change
//! between steps (the frozen parameter vector, which dominates bytes) are
//! kept **device-resident** as `PjRtBuffer`s and re-used via `execute_b`,
//! so per-step host->device traffic is only the trainable vector + batch.

mod artifact;
mod convert;
pub mod env;
pub mod pool;

pub use artifact::{Artifact, ArtifactMeta, IoSpec, Layout, LayoutLeaf, Manifest};
pub use convert::{literal_to_tensor, tensor_to_literal};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::util::tensor::Tensor;

/// A PJRT client + executable cache over an artifact directory.
pub struct Runtime {
    client: Rc<xla::PjRtClient>,
    dir: PathBuf,
    cache: HashMap<String, Rc<Executable>>,
    pub manifest: Manifest,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = Rc::new(xla::PjRtClient::cpu().context("creating PJRT CPU client")?);
        Ok(Runtime { client, dir, cache: HashMap::new(), manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Load (and cache) a compiled executable by artifact name.
    pub fn load(&mut self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let meta = ArtifactMeta::load(&self.dir, name)
            .with_context(|| format!("loading meta for artifact {name:?}"))?;
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .with_context(|| format!("parsing {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name:?}"))?;
        let e = Rc::new(Executable { exe, meta, client: self.client.clone() });
        // Warmup with zero inputs through the literal path: the first
        // buffer-path execution (`execute_b`) on a cold process trips a
        // pointer_size assertion inside xla_extension 0.5.1; one literal
        // execute initializes the runtime state and also fronts lazy
        // compilation costs so training-step timings are clean.
        e.warmup().with_context(|| format!("warming up artifact {name:?}"))?;
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Load the parameter layout for a model.
    pub fn layout(&self, model: &str) -> Result<Layout> {
        Layout::load(&self.dir, model)
    }

    /// Read a model's deterministic init vector (`<model>.init.bin`).
    pub fn init_params(&self, model: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{model}.init.bin"));
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "init.bin not a multiple of 4 bytes");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// A device-resident input that survives across steps.
pub struct DeviceInput {
    buffer: xla::PjRtBuffer,
}

/// A compiled artifact, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    client: Rc<xla::PjRtClient>,
}

impl Executable {
    /// One zero-input execution through the literal path (see `Runtime::load`).
    fn warmup(&self) -> Result<()> {
        let zeros: Vec<Tensor> = self
            .meta
            .inputs
            .iter()
            .map(|s| {
                let n = s.elements();
                if s.dtype == "int32" {
                    Tensor::i32(s.shape.clone(), vec![0; n])
                } else {
                    Tensor::f32(s.shape.clone(), vec![0.0; n])
                }
            })
            .collect();
        self.run(&zeros).map(|_| ())
    }

    /// Validate tensors against the artifact's input spec (shape + dtype).
    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        anyhow::ensure!(
            inputs.len() == self.meta.inputs.len(),
            "artifact {} expects {} inputs, got {}",
            self.meta.name,
            self.meta.inputs.len(),
            inputs.len()
        );
        for (t, spec) in inputs.iter().zip(&self.meta.inputs) {
            anyhow::ensure!(
                t.shape == spec.shape,
                "input {} of {}: shape {:?} != expected {:?}",
                spec.name,
                self.meta.name,
                t.shape,
                spec.shape
            );
        }
        Ok(())
    }

    /// Execute with host tensors; returns host tensors (the output tuple).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        self.collect(result)
    }

    /// Upload one input to the device for reuse across steps.
    pub fn upload(&self, t: &Tensor) -> Result<DeviceInput> {
        let lit = tensor_to_literal(t)?;
        let device = self.client.devices().into_iter().next().context("no device")?;
        let buffer = self.client.buffer_from_host_literal(Some(&device), &lit)?;
        Ok(DeviceInput { buffer })
    }

    /// Execute with a mix of device-resident and host inputs.
    ///
    /// `inputs[i]` slots that are `None` are taken from `resident` in order.
    pub fn run_mixed(
        &self,
        resident: &[&DeviceInput],
        host: &[Option<&Tensor>],
    ) -> Result<Vec<Tensor>> {
        anyhow::ensure!(host.len() == self.meta.inputs.len(), "run_mixed arity");
        let device = self.client.devices().into_iter().next().context("no device")?;
        // NOTE: host literals must outlive execute_b — buffer_from_host_-
        // literal may copy asynchronously, so dropping a literal before the
        // execution is a use-after-free inside xla_extension.
        let mut literals: Vec<xla::Literal> = Vec::new();
        let mut uploaded: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<usize> = Vec::new(); // index into resident (usize::MAX => uploaded)
        let mut ri = 0;
        for slot in host {
            match slot {
                Some(t) => {
                    let lit = tensor_to_literal(t)?;
                    uploaded.push(self.client.buffer_from_host_literal(Some(&device), &lit)?);
                    literals.push(lit);
                    order.push(usize::MAX);
                }
                None => {
                    anyhow::ensure!(ri < resident.len(), "not enough resident inputs");
                    order.push(ri);
                    ri += 1;
                }
            }
        }
        let mut up_iter = uploaded.iter();
        let refs: Vec<&xla::PjRtBuffer> = order
            .iter()
            .map(|&i| {
                if i == usize::MAX {
                    up_iter.next().unwrap()
                } else {
                    &resident[i].buffer
                }
            })
            .collect();
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(&refs)?;
        drop(refs);
        drop(literals); // keep host literals alive past the execution
        self.collect(result)
    }

    fn collect(&self, result: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Tensor>> {
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        let parts = lit.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for (p, spec) in parts.iter().zip(&self.meta.outputs) {
            out.push(literal_to_tensor(p, spec)?);
        }
        Ok(out)
    }
}
