//! Host `Tensor` <-> `xla::Literal` conversion.

use anyhow::Result;

use super::artifact::IoSpec;
use crate::util::tensor::{Tensor, TensorData};

/// Build an `xla::Literal` from a host tensor (f32 / i32).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        TensorData::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
        TensorData::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
    };
    Ok(lit)
}

/// Read a literal back into a host tensor using the artifact's output spec.
pub fn literal_to_tensor(lit: &xla::Literal, spec: &IoSpec) -> Result<Tensor> {
    let data = match spec.dtype.as_str() {
        "int32" => TensorData::I32(lit.to_vec::<i32>()?),
        _ => TensorData::F32(lit.to_vec::<f32>()?),
    };
    let n = match &data {
        TensorData::F32(v) => v.len(),
        TensorData::I32(v) => v.len(),
    };
    anyhow::ensure!(
        n == spec.elements(),
        "output {}: {} elements, spec says {:?}",
        spec.name,
        n,
        spec.shape
    );
    Ok(Tensor { shape: spec.shape.clone(), data })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = Tensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = tensor_to_literal(&t).unwrap();
        let spec = IoSpec { name: "x".into(), dtype: "float32".into(), shape: vec![2, 3] };
        let back = literal_to_tensor(&lit, &spec).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar_f32(3.5);
        let lit = tensor_to_literal(&t).unwrap();
        let spec = IoSpec { name: "r".into(), dtype: "float32".into(), shape: vec![] };
        assert_eq!(literal_to_tensor(&lit, &spec).unwrap().item_f32(), 3.5);
    }

    #[test]
    fn i32_roundtrip() {
        let t = Tensor::i32(vec![4], vec![1, 2, 3, 4]);
        let lit = tensor_to_literal(&t).unwrap();
        let spec = IoSpec { name: "x".into(), dtype: "int32".into(), shape: vec![4] };
        assert_eq!(literal_to_tensor(&lit, &spec).unwrap().as_i32(), &[1, 2, 3, 4]);
    }
}
