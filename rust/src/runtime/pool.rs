//! Dependency-free persistent data-parallel worker pool.
//!
//! Per-sample gradients are embarrassingly parallel: each microbatch row is
//! computed independently, then reduced.  This module shards task indices
//! across workers with a **deterministic contract** — a "task" being
//! whatever granularity the kernel tier picks: one microbatch row
//! (fused/ghost phase A), one gradient-matrix row (ghost/blocked phase
//! B), or one row-*block* with a multi-row buffer shard (the blocked
//! tier's panel kernels, which reuse the same fixed-order shard
//! reduction unchanged):
//!
//! * each task's result is written to a slot (and buffer shard) owned by
//!   that task index, never to a worker-local accumulator;
//! * the caller reduces the per-task slots **in fixed index order** on the
//!   calling thread.
//!
//! Which worker computes a task therefore cannot affect the result: outputs
//! are bit-identical across any worker count (including 1), which is what
//! lets `FASTDP_THREADS` be a pure throughput knob.
//!
//! ## Parked workers, not scoped spawns
//!
//! Workers are **persistent**: spawned once (lazily, growing to
//! max(host parallelism, largest worker count requested)) and parked on a
//! job channel between calls; a rotating cursor spreads concurrent
//! dispatchers (e.g. replica threads) across the registry so they do not
//! all queue behind the same few workers.
//! The previous implementation spawned and joined scoped threads per call —
//! fine for one coarse dispatch per microbatch, but the ghost kernel tier
//! issues several finer-grained dispatches per step (per-leaf gradient
//! accumulation), where tens of microseconds of spawn/join each would
//! dominate.  Chunking is unchanged (contiguous index ranges, one per
//! worker context), so the determinism contract is exactly the scoped
//! pool's: scheduling is invisible to the caller.
//!
//! A dispatch runs its first chunk inline on the calling thread and ships
//! the rest to parked workers as lifetime-erased jobs; the call does not
//! return until every shipped job has reported completion (panics
//! included, via a drop guard), so borrowed chunks never outlive the call.
//! Jobs must not themselves dispatch pool work — nested calls (detected by
//! worker-thread name) degrade to inline serial execution rather than risk
//! a worker waiting on its own queue.
//!
//! The worker count comes from the caller (one scratch context per
//! worker); [`default_threads`] resolves the `FASTDP_THREADS` environment
//! variable, falling back to `std::thread::available_parallelism`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Mutex, OnceLock};

/// Worker count from `FASTDP_THREADS`, else the host parallelism.
/// Invalid or zero values warn once (see [`super::env`]) and fall back to
/// the host parallelism; the result is always >= 1.
pub fn default_threads() -> usize {
    super::env::threads().unwrap_or_else(host_parallelism)
}

/// The host's available parallelism (>= 1).
pub fn host_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A lifetime-erased unit of work shipped to a parked worker.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Thread-name prefix of pool workers (the nested-dispatch guard).
const WORKER_NAME: &str = "fastdp-pool-";

/// The global registry of parked workers, one job channel each.  Grows
/// lazily to max(host parallelism, largest remote-worker count ever
/// requested) and is never torn down (parked workers cost one blocked
/// thread apiece and do not keep the process alive).
static WORKERS: OnceLock<Mutex<Vec<Sender<Job>>>> = OnceLock::new();

/// Rotating start offset so concurrent dispatchers (e.g. data-parallel
/// replica threads, each pooling its own rows) land on different workers
/// instead of all queueing behind `workers[0..n]`.
static CURSOR: AtomicUsize = AtomicUsize::new(0);

/// Clone `n` worker senders starting at the rotating cursor, spawning
/// parked workers (up to the registry capacity) as needed.
fn workers(n: usize) -> Vec<Sender<Job>> {
    let cap = n.max(host_parallelism());
    let reg = WORKERS.get_or_init(|| Mutex::new(Vec::new()));
    let mut ws = reg.lock().unwrap_or_else(|e| e.into_inner());
    while ws.len() < cap {
        let (tx, rx) = channel::<Job>();
        let name = format!("{WORKER_NAME}{}", ws.len());
        std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    // a panicking job must not kill the parked worker; its
                    // DoneGuard reports the failure to the dispatcher
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                }
            })
            .expect("spawn fastdp pool worker");
        ws.push(tx);
    }
    let start = CURSOR.fetch_add(n, Ordering::Relaxed);
    (0..n).map(|i| ws[(start + i) % ws.len()].clone()).collect()
}

/// Sends completion (and success/panic status) back to the dispatcher even
/// when the job unwinds.
struct DoneGuard {
    tx: Sender<bool>,
    ok: bool,
}

impl Drop for DoneGuard {
    fn drop(&mut self) {
        let _ = self.tx.send(self.ok);
    }
}

/// Run every job to completion before returning: the first inline on the
/// calling thread, the rest on parked workers.
///
/// This is the one place borrowed data crosses a thread boundary: each job
/// is transmuted to `'static` for the channel, which is sound because this
/// function blocks on the done channel until every shipped job has
/// reported back (the `DoneGuard` fires even on panic), so no job outlives
/// the borrows it captured.
fn run_jobs(jobs: Vec<Box<dyn FnOnce() + Send + '_>>) {
    if jobs.is_empty() {
        return;
    }
    let nested =
        std::thread::current().name().is_some_and(|n| n.starts_with(WORKER_NAME));
    if jobs.len() == 1 || nested {
        // nothing to ship — or we *are* a pool worker, where shipping work
        // could queue a job behind ourselves; run everything inline
        for job in jobs {
            job();
        }
        return;
    }
    let n_remote = jobs.len() - 1;
    let (done_tx, done_rx) = channel::<bool>();
    let mut iter = jobs.into_iter();
    let local = iter.next().expect("at least one job");
    let senders = workers(n_remote);
    for (job, sender) in iter.zip(&senders) {
        let tx = done_tx.clone();
        let wrapped: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            let mut guard = DoneGuard { tx, ok: false };
            job();
            guard.ok = true;
        });
        // SAFETY: run_jobs blocks on done_rx below until every shipped job
        // has sent through its DoneGuard (which fires on normal return and
        // on unwind alike), so the borrows captured in `wrapped` strictly
        // outlive its execution on the worker.
        let wrapped: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(wrapped)
        };
        if let Err(back) = sender.send(wrapped) {
            // worker unavailable (cannot happen in practice: workers park
            // forever) — run the job here, still before any return
            (back.0)();
        }
    }
    drop(done_tx);
    // run our own chunk while the workers run theirs; defer any panic
    // until every remote job has finished so no borrow is left dangling
    let local_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(local));
    let mut remote_ok = true;
    for _ in 0..n_remote {
        // Err means every guard already reported and dropped — all done
        remote_ok &= done_rx.recv().unwrap_or(false);
    }
    if let Err(p) = local_result {
        std::panic::resume_unwind(p);
    }
    assert!(remote_ok, "a pool worker task panicked");
}

/// Run `out[i] = f(i, ctx)` for `i in 0..n`, sharding contiguous index
/// ranges across one worker per context in `ctxs`.
///
/// `ctxs` supplies per-worker scratch (e.g. a kernel workspace); its length
/// caps the parallelism.  With one context (or one task) everything runs
/// inline on the calling thread.
pub fn for_each<S, C, F>(n: usize, ctxs: &mut [C], out: &mut [S], f: F)
where
    S: Send,
    C: Send,
    F: Fn(usize, &mut C) -> S + Sync,
{
    assert_eq!(out.len(), n, "for_each: out slot per task");
    assert!(!ctxs.is_empty(), "for_each: need at least one worker context");
    let workers = ctxs.len().min(n.max(1));
    if workers <= 1 {
        let ctx = &mut ctxs[0];
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i, ctx);
        }
        return;
    }
    // contiguous index ranges per worker; which worker runs a task can
    // never change its result, so scheduling is invisible to the caller
    let chunk = (n + workers - 1) / workers;
    let f = &f;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
    for (w, (o_chunk, ctx)) in out.chunks_mut(chunk).zip(ctxs.iter_mut()).enumerate() {
        let first = w * chunk;
        jobs.push(Box::new(move || {
            for (k, o) in o_chunk.iter_mut().enumerate() {
                *o = f(first + k, ctx);
            }
        }));
    }
    run_jobs(jobs);
}

/// Like [`for_each`], but each task additionally owns an exclusive
/// `stride`-element shard of `buf`: `f(i, ctx, &mut buf[i*stride..(i+1)*stride])`.
///
/// This is the per-sample shape: task `i` writes its result into shard
/// `i`, and the caller reduces shards in index order.
pub fn for_each_sharded<S, C, T, F>(
    n: usize,
    ctxs: &mut [C],
    out: &mut [S],
    buf: &mut [T],
    stride: usize,
    f: F,
) where
    S: Send,
    C: Send,
    T: Send,
    F: Fn(usize, &mut C, &mut [T]) -> S + Sync,
{
    assert_eq!(out.len(), n, "for_each_sharded: out slot per task");
    assert!(stride > 0, "for_each_sharded: stride must be positive");
    assert_eq!(buf.len(), n * stride, "for_each_sharded: buf holds n*stride elements");
    assert!(!ctxs.is_empty(), "for_each_sharded: need at least one worker context");
    let workers = ctxs.len().min(n.max(1));
    if workers <= 1 {
        let ctx = &mut ctxs[0];
        for (i, (o, shard)) in out.iter_mut().zip(buf.chunks_mut(stride)).enumerate() {
            *o = f(i, ctx, shard);
        }
        return;
    }
    // contiguous index ranges per worker, with the matching buffer shards
    let chunk = (n + workers - 1) / workers;
    let f = &f;
    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(workers);
    let work = out.chunks_mut(chunk).zip(buf.chunks_mut(chunk * stride)).zip(ctxs.iter_mut());
    for (w, ((o_chunk, b_chunk), ctx)) in work.enumerate() {
        let first = w * chunk;
        jobs.push(Box::new(move || {
            for (k, (o, shard)) in o_chunk.iter_mut().zip(b_chunk.chunks_mut(stride)).enumerate()
            {
                *o = f(first + k, ctx, shard);
            }
        }));
    }
    run_jobs(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_each_matches_serial_for_any_worker_count() {
        let n = 13;
        let expect: Vec<u64> = (0..n as u64).map(|i| i * i + 1).collect();
        for workers in 1..=5 {
            let mut ctxs = vec![0u8; workers];
            let mut out = vec![0u64; n];
            for_each(n, &mut ctxs, &mut out, |i, _ctx| (i as u64) * (i as u64) + 1);
            assert_eq!(out, expect, "workers={workers}");
        }
    }

    #[test]
    fn sharded_rows_and_reduction_are_worker_count_invariant() {
        let n = 9;
        let stride = 4;
        let run = |workers: usize| {
            let mut ctxs = vec![(); workers];
            let mut out = vec![0.0f64; n];
            let mut buf = vec![0.0f64; n * stride];
            for_each_sharded(n, &mut ctxs, &mut out, &mut buf, stride, |i, _ctx, shard| {
                for (k, s) in shard.iter_mut().enumerate() {
                    *s = (i * stride + k) as f64 * 0.5;
                }
                i as f64
            });
            // fixed-order reduction on the caller thread
            let mut sum = 0.0f64;
            for shard in buf.chunks(stride) {
                for &v in shard {
                    sum += v;
                }
            }
            (out, buf, sum)
        };
        let base = run(1);
        for workers in 2..=4 {
            assert_eq!(run(workers), base, "workers={workers}");
        }
    }

    #[test]
    fn worker_contexts_stay_private() {
        // each worker bumps its own context; total visits == n
        let n = 20;
        let mut ctxs = vec![0usize; 3];
        let mut out = vec![0usize; n];
        for_each(n, &mut ctxs, &mut out, |i, ctx| {
            *ctx += 1;
            i
        });
        assert_eq!(ctxs.iter().sum::<usize>(), n);
    }

    #[test]
    fn pool_workers_are_reused_across_calls() {
        // many small dispatches against the same persistent workers; the
        // per-call results stay correct and deterministic throughout
        for round in 0..50usize {
            let n = 7 + round % 5;
            let mut ctxs = vec![(); 4];
            let mut out = vec![0usize; n];
            for_each(n, &mut ctxs, &mut out, |i, _| i * round);
            let expect: Vec<usize> = (0..n).map(|i| i * round).collect();
            assert_eq!(out, expect, "round={round}");
        }
    }

    #[test]
    fn pool_recovers_after_a_panicking_task() {
        let boom = std::panic::catch_unwind(|| {
            let mut ctxs = vec![(); 4];
            let mut out = vec![0u8; 8];
            for_each(8, &mut ctxs, &mut out, |i, _ctx| {
                if i == 7 {
                    panic!("boom");
                }
                1u8
            });
        });
        assert!(boom.is_err(), "panic must propagate to the dispatcher");
        // the parked workers survive and keep serving work
        let mut ctxs = vec![(); 4];
        let mut out = vec![0usize; 16];
        for_each(16, &mut ctxs, &mut out, |i, _ctx| i);
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn threads_resolution_is_positive() {
        assert!(default_threads() >= 1);
        assert!(host_parallelism() >= 1);
    }

    #[test]
    #[should_panic(expected = "stride")]
    fn sharded_rejects_zero_stride() {
        let mut ctxs = vec![(); 1];
        let mut out = vec![0u8; 2];
        let mut buf: Vec<u8> = Vec::new();
        for_each_sharded(2, &mut ctxs, &mut out, &mut buf, 0, |_, _, _| 0u8);
    }
}
